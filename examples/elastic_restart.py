"""Fault-tolerance / elasticity example: train on a (2,4) mesh, checkpoint,
then restart the SAME run on a (2,2) mesh (half the devices lost) — the
planner re-solves for the new topology and the checkpoint reshards on load.

    PYTHONPATH=src python examples/elastic_restart.py
"""

import subprocess
import sys

if __name__ == "__main__":
    sys.exit(subprocess.call(
        [sys.executable, "-m", "repro.launch.elastic",
         "--arch", "llama3.2-3b", "--steps", "4"]))
