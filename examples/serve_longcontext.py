"""Long-context serving example (deliverable b): pipelined flash-decode with
a sequence-sharded KV cache, batched requests.

    PYTHONPATH=src python examples/serve_longcontext.py
"""

import subprocess
import sys

if __name__ == "__main__":
    sys.exit(subprocess.call(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "gemma3-1b",
         "--reduced", "--batch", "4", "--cache-len", "256",
         "--decode-steps", "4"]))
