"""Serving example: the continuous-batching engine on a skewed request
trace — chunked prefill co-scheduled with speculative (k=2) decode streams
over the slotted KV pool, replayed twice to show the closed compile-cache
bucket set (pass 2 compiles nothing).

    PYTHONPATH=src python examples/serve_longcontext.py
"""

import subprocess
import sys

if __name__ == "__main__":
    sys.exit(subprocess.call(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "gemma3-1b",
         "--reduced", "--requests", "16", "--passes", "2", "--k", "2",
         "--verify", "2"]))
