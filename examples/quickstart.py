"""Quickstart: plan + train a reduced llama3.2 with EPP on 8 fake CPU devices.

    PYTHONPATH=src python examples/quickstart.py
"""

import os
# 4 fake devices: this box has 1 core; more device threads than
# that trip XLA's CPU-collective rendezvous watchdog under load.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax

from repro.configs import get_arch
from repro.launch.train import TrainLoopConfig, train


def main():
    cfg = get_arch("llama3.2-3b").reduced()
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    loop = TrainLoopConfig(steps=4, global_batch=6, context=256,
                           dataset="github", compute_dtype="float32")
    _, _, history = train(cfg, mesh, loop)
    # convergence proper is proven by benchmarks fig13 / the equivalence
    # tests; 4 steps only sanity-check that training is stable.
    assert all(h["loss"] < 12.0 for h in history), "loss diverged"
    print("quickstart OK — loss", [round(h["loss"], 3) for h in history])


if __name__ == "__main__":
    main()
