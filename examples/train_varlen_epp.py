"""End-to-end driver (deliverable b): train a ~100M-param model for a few
hundred steps on a skewed variable-length corpus with the full InfiniPipe
stack — planner (chunking + grouping + ckpt ILP) overlapped with the
executor, checkpointing every 50 steps.

    PYTHONPATH=src python examples/train_varlen_epp.py --steps 300
"""

import argparse
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax

from repro.configs import get_arch
from repro.launch.train import TrainLoopConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()
    # ~100M params: gemma3 family reduced to 8 layers x 512 width
    cfg = get_arch("gemma3-1b").reduced(n_layers=8, d_model=512, n_heads=8,
                                        head_dim=64, vocab=8192)
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    loop = TrainLoopConfig(steps=args.steps, global_batch=16, context=2048,
                           dataset="github", ckpt_dir="runs/quickckpt",
                           ckpt_every=50, compute_dtype="float32")
    _, _, hist = train(cfg, mesh, loop)
    print(f"final loss {hist[-1]['loss']:.4f} over {len(hist)} steps")


if __name__ == "__main__":
    main()
