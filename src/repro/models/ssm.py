"""Mamba-1 selective-scan mixer with packed-segment resets and O(1)
cross-chunk state carry.

Split-chunk context for an SSM layer is just ``(h, conv_tail)`` — a
[d_inner, d_state] state plus the trailing ``d_conv-1`` conv inputs — which
is why token-level PP is essentially free in memory for SSM/hybrid archs
(DESIGN.md §4). Resets are encoded as ``a_t = 0`` at every sequence start
(``pos == 0``), which simultaneously stops the carried state from leaking
into packed neighbors.

The scan runs as a *block-chunked associative scan*: within a block of
``BLOCK`` timesteps a parallel ``associative_scan`` materializes
[BLOCK, d_inner, d_state]; blocks chain sequentially. This bounds memory at
long context (the same decomposition the Pallas kernel uses on TPU).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import dense_init

__all__ = ["init_mamba", "mamba_apply", "ssm_state_shape"]

BLOCK = 128


def ssm_state_shape(cfg: ArchConfig) -> Tuple[Tuple[int, int], Tuple[int, int]]:
    s = cfg.spec
    return ((s.inner, s.ssm_state), (s.ssm_conv - 1, s.inner))


def init_mamba(cfg: ArchConfig, key, dtype=jnp.float32) -> Dict:
    s = cfg.spec
    D, di, ds = s.d_model, s.inner, s.ssm_state
    dt_rank = max(1, math.ceil(D / 16))
    ks = jax.random.split(key, 6)
    a_init = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :],
                      (di, 1))
    return {
        "in_proj": dense_init(ks[0], D, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (s.ssm_conv, di)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], di, dt_rank + 2 * ds, dtype),
        "dt_proj": dense_init(ks[3], dt_rank, di, dtype),
        "dt_bias": jnp.full((di,), -4.6, dtype),   # softplus^-1(0.01)
        "a_log": jnp.log(a_init).astype(dtype),
        "d_skip": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[4], di, D, dtype),
    }


def dt_rank_of(cfg: ArchConfig) -> int:
    return max(1, math.ceil(cfg.spec.d_model / 16))


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 tail: jnp.ndarray, reset: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv over time with segment masking.

    x: [T, di]; w: [K, di]; tail: [K-1, di] carried inputs preceding token 0;
    reset: [T] bool, True where a new sequence starts. Window contributions
    that cross a reset boundary are zeroed.
    """
    K = w.shape[0]
    T = x.shape[0]
    xp = jnp.concatenate([tail, x], axis=0)        # [T+K-1, di]
    # block[i] counts resets up to token i (inclusive); a window element j
    # may contribute to output t only if no reset occurred in (j, t]. The
    # carried tail belongs to block 0: it is only reachable by tokens before
    # the first in-chunk reset (i.e. when the chunk continues a sequence —
    # if pos[0] == 0 then blk[0] == 1 and the tail is correctly blocked).
    blk = jnp.cumsum(reset.astype(jnp.int32))      # [T]
    blk_p = jnp.concatenate([jnp.zeros((K - 1,), jnp.int32), blk])
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for j in range(K):                             # K is small (4)
        seg_ok = blk_p[j:j + T] == blk             # same block as output tok
        contrib = xp[j:j + T].astype(jnp.float32) * w[j].astype(jnp.float32)
        out = out + jnp.where(seg_ok[:, None], contrib, 0.0)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _blocked_ssm(a: jnp.ndarray, bx: jnp.ndarray, h0: jnp.ndarray
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """h_t = a_t * h_{t-1} + bx_t over T steps. a, bx: [T, di, ds].

    Returns (h over time [T, di, ds], final state [di, ds]).
    """
    T = a.shape[0]
    pad = (-T) % BLOCK
    if pad:
        a = jnp.concatenate([a, jnp.ones((pad,) + a.shape[1:], a.dtype)])
        bx = jnp.concatenate([bx, jnp.zeros((pad,) + bx.shape[1:], bx.dtype)])
    nb = a.shape[0] // BLOCK
    a_b = a.reshape(nb, BLOCK, *a.shape[1:])
    bx_b = bx.reshape(nb, BLOCK, *bx.shape[1:])

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    def block_step(h, inp):
        ab, bb = inp
        aa, hh = jax.lax.associative_scan(combine, (ab, bb), axis=0)
        hh = hh + aa * h[None]
        return hh[-1], hh

    h_last, hs = jax.lax.scan(block_step, h0, (a_b, bx_b))
    hs = hs.reshape(nb * BLOCK, *h0.shape)[:T]
    return hs, h_last


def mamba_apply(cfg: ArchConfig, p: Dict, x: jnp.ndarray, *,
                pos: jnp.ndarray,
                state: Optional[jnp.ndarray] = None,
                conv_tail: Optional[jnp.ndarray] = None,
                scan_fn=None,
                tail_exchange=None
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: [T, D] packed tokens. Returns (out [T, D], h_final, conv_tail_out).

    ``pos`` drives resets: a token with pos == 0 starts a fresh sequence.
    ``scan_fn(a, bx, h0) -> (hs, h_last)`` and ``tail_exchange(xs, tail) ->
    tail`` are the distributed-runtime injection points (sequence-parallel
    prefix scan and cross-shard conv halo, repro.runtime.sp).
    """
    s = cfg.spec
    di, ds = s.inner, s.ssm_state
    T = x.shape[0]
    dt = x.dtype
    dt_rank = dt_rank_of(cfg)
    if state is None:
        state = jnp.zeros((di, ds), jnp.float32)
    if conv_tail is None:
        conv_tail = jnp.zeros((s.ssm_conv - 1, di), dt)
    if scan_fn is None:
        scan_fn = _blocked_ssm

    xz = jnp.einsum("td,dh->th", x, p["in_proj"].astype(dt))
    xs, z = xz[:, :di], xz[:, di:]
    reset = pos == 0
    if tail_exchange is not None:
        conv_tail = tail_exchange(xs, conv_tail)
    xc = _causal_conv(xs, p["conv_w"], p["conv_b"], conv_tail, reset)
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(dt)

    proj = jnp.einsum("td,dh->th", xc, p["x_proj"].astype(dt))
    dt_in = proj[:, :dt_rank]
    B = proj[:, dt_rank:dt_rank + ds].astype(jnp.float32)        # [T, ds]
    C = proj[:, dt_rank + ds:dt_rank + 2 * ds].astype(jnp.float32)
    delta = jax.nn.softplus(
        jnp.einsum("tr,rd->td", dt_in, p["dt_proj"].astype(dt)
                   ).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))                      # [T, di]
    A = -jnp.exp(p["a_log"].astype(jnp.float32))                 # [di, ds]
    a = jnp.exp(delta[:, :, None] * A[None])                     # [T, di, ds]
    # reset: kill the recurrence into tokens that start a sequence
    a = jnp.where(reset[:, None, None], 0.0, a)
    bx = (delta[:, :, None] * B[:, None, :]) * \
        xc.astype(jnp.float32)[:, :, None]                       # [T, di, ds]

    hs, h_last = scan_fn(a, bx, state)
    y = jnp.einsum("tds,ts->td", hs, C)                          # [T, di]
    y = y + p["d_skip"].astype(jnp.float32)[None] * xc.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("td,dh->th", y.astype(dt), p["out_proj"].astype(dt))

    K = s.ssm_conv
    tail_src = jnp.concatenate([conv_tail, xs], axis=0)
    new_tail = jax.lax.dynamic_slice_in_dim(tail_src, T, K - 1, axis=0)
    return out, h_last, new_tail
