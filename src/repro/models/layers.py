"""Shared neural building blocks (pure JAX, init/apply style, no flax).

Parameters are plain nested dicts of ``jnp.ndarray`` so they compose with
pjit/shard_map PartitionSpecs and with the ZeRO-3 optimizer without any
framework adapter.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["dense_init", "rms_norm", "layer_norm", "swiglu_init",
           "swiglu_apply", "embed_init", "rope_freqs", "apply_rope",
           "apply_mrope", "Params"]

Params = Dict[str, jnp.ndarray]


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32,
               scale: Optional[float] = None) -> jnp.ndarray:
    """Truncated-normal fan-in init (matches common LLM practice)."""
    s = scale if scale is not None else 1.0 / math.sqrt(d_in)
    w = jax.random.truncated_normal(key, -3.0, 3.0, (d_in, d_out)) * s
    return w.astype(dtype)


def embed_init(key, vocab: int, d_model: int, dtype=jnp.float32) -> jnp.ndarray:
    w = jax.random.normal(key, (vocab, d_model)) * 0.02
    return w.astype(dtype)


def rms_norm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-6
             ) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + gamma.astype(jnp.float32))).astype(dt)


def layer_norm(x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray,
               eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def swiglu_init(key, d_model: int, d_ff: int, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def swiglu_apply(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    g = jnp.einsum("td,df->tf", x, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("td,df->tf", x, p["w_up"].astype(x.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("tf,fd->td", h, p["w_down"].astype(x.dtype))


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard, partial, and qwen2-vl's M-RoPE).
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies for half the head dim."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def _rotate(x: jnp.ndarray, angles: jnp.ndarray) -> jnp.ndarray:
    """x: [T, H, Dh(rot part)], angles: [T, Dh/2]."""
    dt = x.dtype
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = jnp.cos(angles)[:, None, :].astype(jnp.float32)
    sin = jnp.sin(angles)[:, None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1).astype(dt)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
               rot_dim: Optional[int] = None) -> jnp.ndarray:
    """x: [T, H, Dh]; positions: [T] int32. ``rot_dim`` < Dh => partial RoPE
    (the leading rot_dim channels rotate, the rest pass through)."""
    Dh = x.shape[-1]
    rd = rot_dim or Dh
    freqs = rope_freqs(rd, theta)
    angles = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    if rd == Dh:
        return _rotate(x, angles)
    rot, rest = x[..., :rd], x[..., rd:]
    return jnp.concatenate([_rotate(rot, angles), rest], axis=-1)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray, theta: float,
                sections: Tuple[int, int, int]) -> jnp.ndarray:
    """qwen2-vl M-RoPE. ``positions3``: [3, T] (temporal, height, width ids —
    all equal for text tokens). ``sections`` split the Dh/2 frequency bands
    among the three axes."""
    T = x.shape[0]
    Dh = x.shape[-1]
    half = Dh // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(Dh, theta)  # [half]
    angles_parts = []
    off = 0
    for axis, sec in enumerate(sections):
        f = freqs[off:off + sec]
        p = positions3[axis].astype(jnp.float32)
        angles_parts.append(p[:, None] * f[None, :])
        off += sec
    angles = jnp.concatenate(angles_parts, axis=-1)  # [T, half]
    return _rotate(x, angles)
