"""Architecture configuration shared by the model zoo, configs/ and launch/.

``ArchConfig`` embeds the solver's :class:`repro.core.ModelSpec` (cost-model
view) and adds the executor-facing details (rope, norms, layer patterns,
modality frontends).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import List, Tuple

from repro.core.plan import ModelSpec

__all__ = ["ArchConfig", "LayerKind"]


class LayerKind:
    ATTN = "attn"            # attention + MLP block
    MAMBA = "mamba"          # mamba mixer only (falcon-mamba: no MLP)
    HYBRID = "hybrid"        # parallel attn + mamba heads, then MLP (hymba)
    MOE = "moe"              # attention + MoE block


@dataclass(frozen=True)
class ArchConfig:
    spec: ModelSpec
    # --- attention details ---
    rope_theta: float = 10000.0
    rope_kind: str = "rope"        # "rope" | "mrope" | "none"
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # t/h/w split of Dh/2
    rms_eps: float = 1e-6
    # sliding-window pattern: window size for "local" layers; 0 => all global
    local_window: int = 0
    local_global_ratio: int = 0    # N locals per 1 global; 0 => all global
    # --- families ---
    layer_kind: str = LayerKind.ATTN
    # --- embeddings ---
    tie_embeddings: bool = True
    embed_scale: bool = False      # gemma multiplies embeddings by sqrt(D)
    # --- modality frontend stub ("none" | "vision" | "audio") ---
    frontend: str = "none"
    # enc-dec only
    is_encoder_decoder: bool = False
    # serving behaviour
    supports_long_decode: bool = False  # sub-quadratic => run long_500k

    # ------------------------------------------------------------------
    @property
    def name(self) -> str:
        return self.spec.name

    def layer_window(self, idx: int) -> int:
        """Sliding window for layer ``idx`` (0 = full/global attention).

        gemma3 pattern: ``ratio`` local layers followed by 1 global layer.
        """
        if self.local_window <= 0:
            return 0
        if self.local_global_ratio <= 0:
            return self.local_window
        period = self.local_global_ratio + 1
        return 0 if (idx % period == period - 1) else self.local_window

    def layer_windows(self) -> List[int]:
        return [self.layer_window(i) for i in range(self.spec.n_layers)]

    def reduced(self, *, n_layers: int = 4, d_model: int = 64,
                n_heads: int = 4, head_dim: int = 16, vocab: int = 512
                ) -> "ArchConfig":
        """A small same-family config for CPU smoke tests."""
        s = self.spec
        kv = max(1, min(s.n_kv_heads, n_heads // 2)) if not s.attn_free else 0
        if s.attn_free:
            n_heads_r, kv = 0, 0
        else:
            n_heads_r = n_heads
        new_spec = dataclasses.replace(
            s,
            n_layers=n_layers,
            d_model=d_model,
            n_heads=n_heads_r if not s.attn_free else 4,
            n_kv_heads=kv if not s.attn_free else 4,
            head_dim=head_dim,
            d_ff=0 if s.d_ff == 0 else d_model * 3,
            vocab=vocab,
            n_experts=4 if s.n_experts else 0,
            n_shared_experts=1 if s.n_shared_experts else 0,
            top_k=2 if s.top_k else 0,
            d_ff_expert=d_model if s.n_experts else 0,
            kv_lora_rank=16 if s.kv_lora_rank else 0,
            qk_rope_dim=8 if s.kv_lora_rank else 0,
            ssm_state=4 if s.ssm_state else 0,
            d_inner=2 * d_model if s.ssm_state else 0,
            n_encoder_layers=n_layers if s.is_encoder_decoder else 0,
        )
        return dataclasses.replace(
            self, spec=new_spec,
            local_window=min(self.local_window, 8) if self.local_window else 0,
            mrope_sections=(4, 2, 2) if self.rope_kind == "mrope" else self.mrope_sections,
        )
