"""Decoder language model composing every assigned family.

Layout decisions are driven by the pipeline executor:

* layer parameters are **stacked** along a leading ``L`` axis (one pytree
  whose leaves have shape ``[L, ...]``) so a pipeline stage can hold the
  ``[L/d_p, ...]`` shard and scan over its layers;
* every layer exposes a *context carry* — attention KV (or MLA latent)
  buffers plus SSM ``(h, conv_tail)`` — so split chunks thread their causal
  context through the 1F1B schedule; the carry's autodiff cotangent is
  exactly the paper's dKV term (Eq. 5);
* embedding and the (fused, vocab-tiled) CE head live OUTSIDE the layer
  stack: the executor runs them before/after the pipeline region.

The reference path (`forward_chunk` / `chunk_loss`) is single-device,
exact, and differentiable — the oracle for executor-equivalence tests.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ref import streaming_cross_entropy

from .attention import (attention_block, init_attention,
                        make_local_attention_policy)
from .config import ArchConfig, LayerKind
from .layers import embed_init, rms_norm, swiglu_apply, swiglu_init
from .moe import init_moe, moe_apply_dense
from .ssm import init_mamba, mamba_apply, ssm_state_shape

__all__ = ["DecoderLM", "LayerCtx", "kv_buffer_shape"]


class LayerCtx(NamedTuple):
    """Per-layer split-chunk context carry (None fields where inapplicable)."""
    k: Optional[jnp.ndarray]          # [C_cap, Hkv, Dh] or MLA rows [C_cap,1,r+rr]
    v: Optional[jnp.ndarray]
    ssm_h: Optional[jnp.ndarray]      # [di, ds] fp32
    ssm_tail: Optional[jnp.ndarray]   # [K-1, di]


def kv_buffer_shape(cfg: ArchConfig, cap: int) -> Optional[Tuple[Tuple[int, ...], Tuple[int, ...]]]:
    s = cfg.spec
    if s.attn_free:
        return None
    if s.kv_lora_rank > 0:
        return ((cap, 1, s.kv_lora_rank + s.qk_rope_dim), (cap, 1, 0))
    return ((cap, s.n_kv_heads, s.head_dim), (cap, s.n_kv_heads, s.head_dim))


class DecoderLM:
    """init/apply-style decoder LM parameterized by ArchConfig."""

    def __init__(self, cfg: ArchConfig, *,
                 attn_fn: Optional[Callable] = None,
                 moe_fn: Optional[Callable] = None,
                 ssm_scan_fn: Optional[Callable] = None,
                 ssm_tail_exchange: Optional[Callable] = None):
        """``attn_fn`` is an attention *policy* (see models/attention.py);
        ``moe_fn``/``ssm_scan_fn``/``ssm_tail_exchange`` are the MoE and SSM
        injection points the distributed runtime replaces."""
        self.cfg = cfg
        self.attn_fn = attn_fn or make_local_attention_policy()
        self.moe_fn = moe_fn or moe_apply_dense
        self.ssm_scan_fn = ssm_scan_fn
        self.ssm_tail_exchange = ssm_tail_exchange

    # ------------------------------------------------------------------
    # Init.
    # ------------------------------------------------------------------
    def _init_layer(self, key, dtype) -> Dict:
        cfg, s = self.cfg, self.cfg.spec
        ks = jax.random.split(key, 4)
        p: Dict[str, Any] = {"ln1": jnp.zeros((s.d_model,), dtype)}
        kind = cfg.layer_kind
        if kind in (LayerKind.ATTN, LayerKind.MOE, LayerKind.HYBRID):
            p["attn"] = init_attention(cfg, ks[0], dtype)
        if kind in (LayerKind.MAMBA, LayerKind.HYBRID):
            p["mamba"] = init_mamba(cfg, ks[1], dtype)
        if kind != LayerKind.MAMBA:
            p["ln2"] = jnp.zeros((s.d_model,), dtype)
            if s.n_experts > 0:
                p["moe"] = init_moe(cfg, ks[2], dtype)
            else:
                p["mlp"] = swiglu_init(ks[2], s.d_model, s.d_ff, dtype)
        return p

    def init(self, key, dtype=jnp.float32) -> Dict:
        cfg, s = self.cfg, self.cfg.spec
        k_embed, k_layers, k_head = jax.random.split(key, 3)
        layer_keys = jax.random.split(k_layers, s.n_layers)
        layers = jax.vmap(lambda k: self._init_layer(k, dtype))(layer_keys)
        params = {
            "embed": embed_init(k_embed, s.vocab, s.d_model, dtype),
            "layers": layers,
            "final_norm": jnp.zeros((s.d_model,), dtype),
        }
        if not cfg.tie_embeddings:
            params["unembed"] = embed_init(k_head, s.vocab, s.d_model, dtype)
        return params

    def head_weights(self, params: Dict) -> jnp.ndarray:
        return params.get("unembed", params["embed"])

    # ------------------------------------------------------------------
    # Embedding / head.
    # ------------------------------------------------------------------
    def embed(self, params: Dict, tokens: jnp.ndarray,
              compute_dtype=jnp.bfloat16) -> jnp.ndarray:
        cfg, s = self.cfg, self.cfg.spec
        x = params["embed"][tokens].astype(compute_dtype)
        if cfg.embed_scale:
            x = x * jnp.asarray(s.d_model ** 0.5, compute_dtype)
        return x

    def chunk_loss(self, params: Dict, hidden: jnp.ndarray,
                   targets: jnp.ndarray, seg: jnp.ndarray
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """(sum_loss, n_valid) via the streaming fused CE."""
        cfg = self.cfg
        h = rms_norm(hidden, params["final_norm"], cfg.rms_eps)
        valid = (seg >= 0) & (targets >= 0)
        return streaming_cross_entropy(h, self.head_weights(params),
                                       jnp.maximum(targets, 0), valid)

    # ------------------------------------------------------------------
    # One layer (the unit the pipeline scans).
    # ------------------------------------------------------------------
    def layer_apply(self, lparams: Dict, x: jnp.ndarray, *,
                    pos: jnp.ndarray, seg: jnp.ndarray,
                    ctx: LayerCtx, ctx_len: jnp.ndarray,
                    window: jnp.ndarray | int,
                    positions3: Optional[jnp.ndarray] = None,
                    memory: Optional[Tuple] = None
                    ) -> Tuple[jnp.ndarray, LayerCtx]:
        """x: [T, D] -> (x', updated context carry).

        The carry update *appends* the current chunk's KV rows at offset
        ``ctx_len`` (dynamic_update_slice) and advances the SSM state; the
        executor decides when to reset (tail chunk completed).
        """
        cfg, s = self.cfg, self.cfg.spec
        kind = cfg.layer_kind
        h = rms_norm(x, lparams["ln1"], cfg.rms_eps)
        mixer_out = jnp.zeros_like(x)
        new_k, new_v = ctx.k, ctx.v
        new_h, new_tail = ctx.ssm_h, ctx.ssm_tail

        if kind in (LayerKind.ATTN, LayerKind.MOE, LayerKind.HYBRID):
            attn_out, nk, nv = attention_block(
                cfg, lparams["attn"], h, pos=pos, seg=seg,
                ctx_k=ctx.k, ctx_v=ctx.v, ctx_len=ctx_len,
                window=window, attn_fn=self.attn_fn, positions3=positions3)
            mixer_out = mixer_out + attn_out
            if ctx.k is not None:
                new_k = nk
                new_v = nv if nv is not None else ctx.v
        if kind in (LayerKind.MAMBA, LayerKind.HYBRID):
            m_out, new_h, new_tail = mamba_apply(
                cfg, lparams["mamba"], h, pos=pos,
                state=ctx.ssm_h, conv_tail=ctx.ssm_tail,
                scan_fn=self.ssm_scan_fn,
                tail_exchange=self.ssm_tail_exchange)
            if kind == LayerKind.HYBRID:
                mixer_out = 0.5 * (mixer_out + m_out)
            else:
                mixer_out = m_out
        x = x + mixer_out

        if kind != LayerKind.MAMBA:
            h2 = rms_norm(x, lparams["ln2"], cfg.rms_eps)
            if s.n_experts > 0:
                x = x + self.moe_fn(cfg, lparams["moe"], h2)
            else:
                x = x + swiglu_apply(lparams["mlp"], h2)
        return x, LayerCtx(new_k, new_v, new_h, new_tail)

    # ------------------------------------------------------------------
    # Whole-model reference forward over one packed chunk.
    # ------------------------------------------------------------------
    def init_ctx(self, cap: int, compute_dtype=jnp.bfloat16,
                 n_layers: Optional[int] = None) -> LayerCtx:
        """Stacked context carry for ``n_layers`` (default: all layers)."""
        cfg, s = self.cfg, self.cfg.spec
        L = n_layers if n_layers is not None else s.n_layers
        kv = kv_buffer_shape(cfg, cap)
        k = v = hh = tail = None
        if kv is not None:
            k = jnp.zeros((L, *kv[0]), compute_dtype)
            v = jnp.zeros((L, *kv[1]), compute_dtype)
        if s.ssm_state > 0:
            (hs, ts) = ssm_state_shape(cfg)
            hh = jnp.zeros((L, *hs), jnp.float32)
            tail = jnp.zeros((L, *ts), compute_dtype)
        return LayerCtx(k, v, hh, tail)

    def forward_chunk(self, params: Dict, tokens: jnp.ndarray,
                      seg: jnp.ndarray, pos: jnp.ndarray, *,
                      ctx: Optional[LayerCtx] = None,
                      ctx_len: jnp.ndarray | int = 0,
                      positions3: Optional[jnp.ndarray] = None,
                      compute_dtype=jnp.bfloat16
                      ) -> Tuple[jnp.ndarray, Optional[LayerCtx]]:
        """Run all layers over one packed chunk. Returns (hidden, new ctx)."""
        cfg, s = self.cfg, self.cfg.spec
        x = self.embed(params, tokens, compute_dtype)
        windows = jnp.asarray(cfg.layer_windows(), jnp.int32)
        ctx_len = jnp.asarray(ctx_len, jnp.int32)
        if ctx is None:
            # context-free execution: None fields skip both the ctx concat in
            # attention and the buffer append (pytree-transparent).
            ctx = LayerCtx(None, None, None, None)

        def body(x, per_layer):
            lp, w, lctx = per_layer
            x, new_ctx = self.layer_apply(
                lp, x, pos=pos, seg=seg, ctx=lctx, ctx_len=ctx_len,
                window=w, positions3=positions3)
            return x, new_ctx

        x, new_ctx = jax.lax.scan(body, x, (params["layers"], windows, ctx))
        return x, new_ctx

    def loss(self, params: Dict, tokens, targets, seg, pos, *,
             positions3=None, compute_dtype=jnp.bfloat16
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        hidden, _ = self.forward_chunk(params, tokens, seg, pos,
                                       positions3=positions3,
                                       compute_dtype=compute_dtype)
        return self.chunk_loss(params, hidden, targets, seg)


def _append_rows(buf: jnp.ndarray, rows: jnp.ndarray,
                 offset: jnp.ndarray) -> jnp.ndarray:
    """Write ``rows`` into ``buf`` starting at ``offset`` (clamped)."""
    return jax.lax.dynamic_update_slice_in_dim(
        buf, rows.astype(buf.dtype), offset, axis=0)
