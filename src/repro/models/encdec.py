"""Encoder-decoder backbone (seamless-m4t-v2): bidirectional encoder over
(stub) audio-frame embeddings + causal decoder with cross-attention.

Pipeline mapping (runtime): stages are split proportionally between encoder
and decoder layers; a chunk's activation is the pair ``(hidden, memory)`` —
encoder stages advance ``hidden`` over frames, the boundary stage promotes
the encoder output to ``memory``, and decoder stages advance token hidden
states while carrying ``memory`` for cross-attention (DESIGN.md §4).

EPP applicability: the encoder is non-causal, so *splitting* its input would
change the math — encoder chunks are packed only (batched). The decoder gets
full EPP with a self-attention context carry like any decoder LM.
"""

from __future__ import annotations

import math
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ref import blocked_flash_attention, streaming_cross_entropy

from .attention import (attention_block, init_attention,
                        make_local_attention_policy)
from .config import ArchConfig
from .layers import dense_init, embed_init, rms_norm, swiglu_apply, swiglu_init
from .model import LayerCtx, kv_buffer_shape

__all__ = ["EncDecLM"]


def _init_cross(cfg: ArchConfig, key, dtype) -> Dict:
    s = cfg.spec
    D, Dh, Hq, Hkv = s.d_model, s.head_dim, s.n_heads, s.n_kv_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, D, Hq * Dh, dtype),
        "wk": dense_init(k2, D, Hkv * Dh, dtype),
        "wv": dense_init(k3, D, Hkv * Dh, dtype),
        "wo": dense_init(k4, Hq * Dh, D, dtype),
    }


class EncDecLM:
    def __init__(self, cfg: ArchConfig, *,
                 flash_impl: Optional[Callable] = None,
                 attn_policy: Optional[Callable] = None):
        """``flash_impl``: raw flash core (cross/encoder attention);
        ``attn_policy``: decoder self-attention policy (runtime-injectable)."""
        assert cfg.spec.is_encoder_decoder
        self.cfg = cfg
        self.flash = flash_impl or blocked_flash_attention
        self.attn_policy = attn_policy or make_local_attention_policy(self.flash)

    # ------------------------------------------------------------------
    def _init_enc_layer(self, key, dtype) -> Dict:
        s = self.cfg.spec
        k1, k2 = jax.random.split(key)
        return {
            "ln1": jnp.zeros((s.d_model,), dtype),
            "attn": init_attention(self.cfg, k1, dtype),
            "ln2": jnp.zeros((s.d_model,), dtype),
            "mlp": swiglu_init(k2, s.d_model, s.d_ff, dtype),
        }

    def _init_dec_layer(self, key, dtype) -> Dict:
        s = self.cfg.spec
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "ln1": jnp.zeros((s.d_model,), dtype),
            "attn": init_attention(self.cfg, k1, dtype),
            "ln_x": jnp.zeros((s.d_model,), dtype),
            "cross": _init_cross(self.cfg, k2, dtype),
            "ln2": jnp.zeros((s.d_model,), dtype),
            "mlp": swiglu_init(k3, s.d_model, s.d_ff, dtype),
        }

    def init(self, key, dtype=jnp.float32) -> Dict:
        s = self.cfg.spec
        k1, k2, k3, k4 = jax.random.split(key, 4)
        enc_keys = jax.random.split(k1, s.n_encoder_layers)
        dec_keys = jax.random.split(k2, s.n_layers)
        return {
            "embed": embed_init(k3, s.vocab, s.d_model, dtype),
            "enc_layers": jax.vmap(
                lambda k: self._init_enc_layer(k, dtype))(enc_keys),
            "enc_norm": jnp.zeros((s.d_model,), dtype),
            "dec_layers": jax.vmap(
                lambda k: self._init_dec_layer(k, dtype))(dec_keys),
            "final_norm": jnp.zeros((s.d_model,), dtype),
        }

    # ------------------------------------------------------------------
    def enc_layer_apply(self, lp: Dict, x: jnp.ndarray, *,
                        seg: jnp.ndarray, pos: jnp.ndarray) -> jnp.ndarray:
        cfg = self.cfg
        h = rms_norm(x, lp["ln1"], cfg.rms_eps)
        out, _, _ = attention_block(
            cfg, lp["attn"], h, pos=pos, seg=seg, ctx_k=None, ctx_v=None,
            ctx_len=None, window=0, attn_fn=self._noncausal_policy)
        x = x + out
        h2 = rms_norm(x, lp["ln2"], cfg.rms_eps)
        return x + swiglu_apply(lp["mlp"], h2)

    def _noncausal_policy(self, q, k, v, *, seg, pos, ctx_k, ctx_v, ctx_len,
                          causal, window, scale, expand_fn=None):
        out = self.flash(q, k, v, seg, seg, pos, pos,
                         causal=False, window=0, scale=scale)
        return out, None, None

    def encode(self, params: Dict, frames: jnp.ndarray, seg: jnp.ndarray,
               pos: jnp.ndarray) -> jnp.ndarray:
        """frames: [S, D] precomputed frame embeddings (frontend stub)."""
        def body(x, lp):
            return self.enc_layer_apply(lp, x, seg=seg, pos=pos), None
        x, _ = jax.lax.scan(body, frames, params["enc_layers"])
        return rms_norm(x, params["enc_norm"], self.cfg.rms_eps)

    # ------------------------------------------------------------------
    def cross_attend(self, lp: Dict, h: jnp.ndarray, memory: jnp.ndarray, *,
                     seg_q: jnp.ndarray, seg_mem: jnp.ndarray) -> jnp.ndarray:
        cfg, s = self.cfg, self.cfg.spec
        dt = h.dtype
        Dh, Hq, Hkv = s.head_dim, s.n_heads, s.n_kv_heads
        q = jnp.einsum("td,dh->th", h, lp["wq"].astype(dt)).reshape(-1, Hq, Dh)
        k = jnp.einsum("sd,dh->sh", memory,
                       lp["wk"].astype(dt)).reshape(-1, Hkv, Dh)
        v = jnp.einsum("sd,dh->sh", memory,
                       lp["wv"].astype(dt)).reshape(-1, Hkv, Dh)
        zero_q = jnp.zeros(q.shape[0], jnp.int32)
        zero_k = jnp.zeros(k.shape[0], jnp.int32)
        out = self.flash(q, k, v, seg_q, seg_mem, zero_q, zero_k,
                         causal=False, window=0,
                         scale=1.0 / math.sqrt(Dh))
        return jnp.einsum("th,hd->td", out.reshape(h.shape[0], -1),
                          lp["wo"].astype(dt))

    def dec_layer_apply(self, lp: Dict, x: jnp.ndarray, *,
                        pos: jnp.ndarray, seg: jnp.ndarray,
                        memory: jnp.ndarray, seg_mem: jnp.ndarray,
                        ctx: LayerCtx, ctx_len: jnp.ndarray
                        ) -> Tuple[jnp.ndarray, LayerCtx]:
        cfg = self.cfg
        h = rms_norm(x, lp["ln1"], cfg.rms_eps)
        attn_out, new_k, new_v = attention_block(
            cfg, lp["attn"], h, pos=pos, seg=seg,
            ctx_k=ctx.k, ctx_v=ctx.v, ctx_len=ctx_len, window=0,
            attn_fn=self.attn_policy)
        x = x + attn_out
        hx = rms_norm(x, lp["ln_x"], cfg.rms_eps)
        x = x + self.cross_attend(lp["cross"], hx, memory,
                                  seg_q=seg, seg_mem=seg_mem)
        h2 = rms_norm(x, lp["ln2"], cfg.rms_eps)
        x = x + swiglu_apply(lp["mlp"], h2)
        return x, LayerCtx(new_k, new_v, None, None)

    # ------------------------------------------------------------------
    def init_ctx(self, cap: int, compute_dtype=jnp.bfloat16,
                 n_layers: Optional[int] = None) -> LayerCtx:
        s = self.cfg.spec
        L = n_layers if n_layers is not None else s.n_layers
        (ks, vs) = kv_buffer_shape(self.cfg, cap)
        return LayerCtx(jnp.zeros((L, *ks), compute_dtype),
                        jnp.zeros((L, *vs), compute_dtype), None, None)

    def decode(self, params: Dict, tokens: jnp.ndarray, seg: jnp.ndarray,
               pos: jnp.ndarray, memory: jnp.ndarray, seg_mem: jnp.ndarray, *,
               ctx: Optional[LayerCtx] = None, ctx_len=0,
               compute_dtype=jnp.bfloat16
               ) -> Tuple[jnp.ndarray, Optional[LayerCtx]]:
        x = params["embed"][tokens].astype(compute_dtype)
        ctx_len = jnp.asarray(ctx_len, jnp.int32)
        if ctx is None:
            ctx = LayerCtx(None, None, None, None)

        def body(x, per):
            lp, lctx = per
            x, new_ctx = self.dec_layer_apply(
                lp, x, pos=pos, seg=seg, memory=memory, seg_mem=seg_mem,
                ctx=lctx, ctx_len=ctx_len)
            return x, new_ctx

        x, new_ctx = jax.lax.scan(body, x, (params["dec_layers"], ctx))
        return x, new_ctx

    def loss(self, params: Dict, frames, seg_enc, pos_enc, tokens, targets,
             seg, pos, *, compute_dtype=jnp.bfloat16):
        memory = self.encode(params, frames.astype(compute_dtype),
                             seg_enc, pos_enc)
        hidden, _ = self.decode(params, tokens, seg, pos, memory, seg_enc,
                                compute_dtype=compute_dtype)
        h = rms_norm(hidden, params["final_norm"], self.cfg.rms_eps)
        valid = (seg >= 0) & (targets >= 0)
        return streaming_cross_entropy(h, params["embed"],
                                       jnp.maximum(targets, 0), valid)
