"""Modality frontend STUBS (per the assignment: [vlm]/[audio] entries specify
the transformer backbone only — ``input_specs()`` provides precomputed
frame/patch embeddings).

The stubs generate (a) random embeddings for smoke tests and (b)
ShapeDtypeStruct stand-ins for the dry-run, plus the position metadata the
backbone needs (M-RoPE 3D ids for qwen2-vl, frame positions for seamless).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig

__all__ = ["vision_patch_stub", "audio_frame_stub", "mrope_positions_stub"]


def mrope_positions_stub(n_text: int, n_patches: int, grid: Tuple[int, int]
                         ) -> jnp.ndarray:
    """[3, T] (t, h, w) position ids: image patches get 2-D coordinates at a
    fixed temporal index, text continues sequentially after the image."""
    gh, gw = grid
    assert gh * gw == n_patches
    t_img = jnp.zeros((n_patches,), jnp.int32)
    h_img = jnp.repeat(jnp.arange(gh, dtype=jnp.int32), gw)
    w_img = jnp.tile(jnp.arange(gw, dtype=jnp.int32), gh)
    base = max(gh, gw)
    t_txt = base + jnp.arange(n_text, dtype=jnp.int32)
    pos3 = jnp.stack([
        jnp.concatenate([t_img, t_txt]),
        jnp.concatenate([h_img, t_txt]),
        jnp.concatenate([w_img, t_txt]),
    ])
    return pos3


def vision_patch_stub(cfg: ArchConfig, key, n_patches: int,
                      dtype=jnp.bfloat16) -> jnp.ndarray:
    """Precomputed image-patch embeddings [n_patches, D] (the real model's
    ViT tower output after the patch-merger)."""
    return (jax.random.normal(key, (n_patches, cfg.spec.d_model)) * 0.02
            ).astype(dtype)


def audio_frame_stub(cfg: ArchConfig, key, n_frames: int,
                     dtype=jnp.bfloat16) -> jnp.ndarray:
    """Precomputed audio-frame embeddings [n_frames, D] (the real model's
    feature extractor + conformer adaptor output)."""
    return (jax.random.normal(key, (n_frames, cfg.spec.d_model)) * 0.02
            ).astype(dtype)
