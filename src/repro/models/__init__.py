"""Model zoo: every assigned architecture family as composable JAX modules."""

from .config import ArchConfig, LayerKind
from .model import DecoderLM, LayerCtx, kv_buffer_shape
from .encdec import EncDecLM

__all__ = ["ArchConfig", "LayerKind", "DecoderLM", "EncDecLM", "LayerCtx",
           "kv_buffer_shape"]
