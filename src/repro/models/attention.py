"""Attention family: GQA (+RoPE/M-RoPE/qk-norm/sliding window) and MLA.

The functions are deliberately granular — projection, rope, core attention
and output projection are separate — because the distributed runtime
(`repro.runtime.sp`) splices its all-to-all / all-gather collectives between
projection and the attention core. The reference single-device path simply
composes them.

Packed-varlen semantics: a chunk is a flat token buffer ``[T]`` with
``seg_ids`` (segment id per token, -1 = padding) and ``pos_ids`` (position
within the owning sequence). Split-chunk context arrives as KV buffers of
capacity ``C_cap`` whose first ``ctx_len`` entries are valid; context tokens
belong to segment 0 (the chunking layer guarantees the split slice is
segment 0) and carry positions ``0..ctx_len-1``.
"""

from __future__ import annotations

import functools
import math
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import apply_mrope, apply_rope, dense_init, rms_norm

__all__ = ["init_attention", "attention_block", "project_qkv",
           "mla_expand_ctx", "make_local_attention_policy", "AttnFn"]

# attn_fn(q[T,Hq,Dh], k[S,Hkv,Dh], v[S,Hkv,Dh], seg_q[T], seg_kv[S],
#         pos_q[T], pos_kv[S], *, causal, window, scale) -> [T,Hq,Dh]
AttnFn = Callable[..., jnp.ndarray]


def init_attention(cfg: ArchConfig, key, dtype=jnp.float32) -> Dict:
    s = cfg.spec
    D, Dh, Hq, Hkv = s.d_model, s.head_dim, s.n_heads, s.n_kv_heads
    ks = jax.random.split(key, 8)
    if s.kv_lora_rank > 0:  # MLA
        r, rr = s.kv_lora_rank, s.qk_rope_dim
        p = {
            "wq": dense_init(ks[0], D, Hq * (Dh + rr), dtype),
            "w_dkv": dense_init(ks[1], D, r, dtype),
            "w_kr": dense_init(ks[2], D, rr, dtype),
            "w_uk": dense_init(ks[3], r, Hq * Dh, dtype),
            "w_uv": dense_init(ks[4], r, Hq * Dh, dtype),
            "wo": dense_init(ks[5], Hq * Dh, D, dtype),
        }
        return p
    p = {
        "wq": dense_init(ks[0], D, Hq * Dh, dtype),
        "wk": dense_init(ks[1], D, Hkv * Dh, dtype),
        "wv": dense_init(ks[2], D, Hkv * Dh, dtype),
        "wo": dense_init(ks[3], Hq * Dh, D, dtype),
    }
    if s.qk_norm:
        p["q_norm"] = jnp.zeros((Dh,), dtype)
        p["k_norm"] = jnp.zeros((Dh,), dtype)
    return p


# ---------------------------------------------------------------------------
# Projection (+rope, +qk-norm). Returns per-token heads.
# ---------------------------------------------------------------------------

def project_qkv(cfg: ArchConfig, p: Dict, x: jnp.ndarray,
                pos: jnp.ndarray,
                positions3: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: [T, D] -> q [T, Hq, Dh(+rr)], k [T, Hkv, Dh(+rr)], v [T, Hkv, Dh].

    For MLA, ``k`` is the *cache row* [T, 1, r+rr] (latent ‖ rope-key) and
    ``v`` is a zero-width placeholder — the expansion happens in
    :func:`attention_block` via :func:`mla_expand_ctx`.
    """
    s = cfg.spec
    D, Dh, Hq, Hkv = s.d_model, s.head_dim, s.n_heads, s.n_kv_heads
    dt = x.dtype
    if s.kv_lora_rank > 0:
        r, rr = s.kv_lora_rank, s.qk_rope_dim
        q = jnp.einsum("td,dh->th", x, p["wq"].astype(dt))
        q = q.reshape(-1, Hq, Dh + rr)
        q_nope, q_rope = q[..., :Dh], q[..., Dh:]
        q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        c_kv = jnp.einsum("td,dr->tr", x, p["w_dkv"].astype(dt))
        k_r = jnp.einsum("td,dr->tr", x, p["w_kr"].astype(dt))
        k_r = apply_rope(k_r[:, None, :], pos, cfg.rope_theta)[:, 0, :]
        cache = jnp.concatenate([c_kv, k_r], axis=-1)[:, None, :]  # [T,1,r+rr]
        return q, cache, jnp.zeros((x.shape[0], 1, 0), dt)
    q = jnp.einsum("td,dh->th", x, p["wq"].astype(dt)).reshape(-1, Hq, Dh)
    k = jnp.einsum("td,dh->th", x, p["wk"].astype(dt)).reshape(-1, Hkv, Dh)
    v = jnp.einsum("td,dh->th", x, p["wv"].astype(dt)).reshape(-1, Hkv, Dh)
    if s.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.rms_eps)
        k = rms_norm(k, p["k_norm"], cfg.rms_eps)
    if cfg.rope_kind == "mrope":
        if positions3 is None:
            positions3 = jnp.stack([pos, pos, pos])
        q = apply_mrope(q, positions3, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions3, cfg.rope_theta, cfg.mrope_sections)
    elif cfg.rope_kind == "rope":
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    return q, k, v


def mla_expand_ctx(cfg: ArchConfig, p: Dict, cache: jnp.ndarray
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Expand MLA cache rows [S, 1, r+rr] into per-head K [S, Hq, Dh+rr] and
    V [S, Hq, Dh]. The latent is up-projected; the rope key is shared across
    heads (decoupled MLA rope)."""
    s = cfg.spec
    Dh, Hq, r = s.head_dim, s.n_heads, s.kv_lora_rank
    dt = cache.dtype
    c, k_r = cache[:, 0, :r], cache[:, 0, r:]
    k_nope = jnp.einsum("tr,rh->th", c, p["w_uk"].astype(dt)).reshape(-1, Hq, Dh)
    v = jnp.einsum("tr,rh->th", c, p["w_uv"].astype(dt)).reshape(-1, Hq, Dh)
    k_rope = jnp.broadcast_to(k_r[:, None, :], (k_r.shape[0], Hq, k_r.shape[-1]))
    k = jnp.concatenate([k_nope, k_rope], axis=-1)
    return k, v


# ---------------------------------------------------------------------------
# Full block: project -> policy (comm + context + core) -> output projection.
#
# The *policy* (``attn_fn``) owns everything between projection and the
# output projection: SP collectives (ulysses all-to-all / allgather-KV),
# context-buffer concat + append, and the flash core. This is where
# ``repro.runtime.sp`` splices its distributed variants; the default is
# :func:`local_attention_policy`.
#
# Policy signature:
#   attn_fn(q, k_cur, v_cur, *, seg, pos, ctx_k, ctx_v, ctx_len, causal,
#           window, scale, expand_fn) -> (out [T, Hq, Dv], new_ctx_k,
#                                         new_ctx_v)
# where q/k_cur/v_cur are the LOCAL projected tensors, ctx buffers follow
# the policy's own layout, and expand_fn (MLA) maps cache rows -> (K, V).
# ---------------------------------------------------------------------------


def make_local_attention_policy(flash_impl=None) -> AttnFn:
    """Single-device reference policy (also the oracle for the SP policies).

    ``flash_impl`` defaults to the blocked-jnp flash; tests can pass the
    naive reference or the Pallas kernel.
    """
    from repro.kernels.ref import blocked_flash_attention
    flash = flash_impl or blocked_flash_attention

    def policy(q, k_cur, v_cur, *, seg, pos, ctx_k, ctx_v, ctx_len,
               causal, window, scale, expand_fn=None):
        # MLA ships zero-width v (values live in the latent cache rows);
        # one condition gates both the attend-path concat and the
        # update-path write — mirrored in runtime/sp.py's policies
        has_v = ctx_v is not None and ctx_v.shape[-1] != 0
        if ctx_k is not None:
            C_cap = ctx_k.shape[0]
            kk = jnp.concatenate([ctx_k, k_cur.astype(ctx_k.dtype)], axis=0)
            vv = jnp.concatenate([ctx_v, v_cur.astype(ctx_v.dtype)], axis=0) \
                if has_v else ctx_v
            kv_seg = jnp.concatenate([
                jnp.where(jnp.arange(C_cap) < ctx_len, 0, -1), seg])
            kv_pos = jnp.concatenate([jnp.arange(C_cap, dtype=pos.dtype), pos])
            new_k = jax.lax.dynamic_update_slice_in_dim(
                ctx_k, k_cur.astype(ctx_k.dtype), ctx_len, axis=0)
            new_v = jax.lax.dynamic_update_slice_in_dim(
                ctx_v, v_cur.astype(ctx_v.dtype), ctx_len, axis=0) \
                if has_v else ctx_v
        else:
            kk, vv, kv_seg, kv_pos = k_cur, v_cur, seg, pos
            new_k = new_v = None
        if expand_fn is not None:
            kk, vv = expand_fn(kk)
        out = flash(q, kk, vv, seg, kv_seg, pos, kv_pos,
                    causal=causal, window=window, scale=scale)
        return out, new_k, new_v

    return policy


def attention_block(cfg: ArchConfig, p: Dict, x: jnp.ndarray, *,
                    pos: jnp.ndarray, seg: jnp.ndarray,
                    ctx_k: Optional[jnp.ndarray], ctx_v: Optional[jnp.ndarray],
                    ctx_len: Optional[jnp.ndarray],
                    window: jnp.ndarray | int,
                    attn_fn: AttnFn,
                    positions3: Optional[jnp.ndarray] = None,
                    causal: bool = True
                    ) -> Tuple[jnp.ndarray, Optional[jnp.ndarray],
                               Optional[jnp.ndarray]]:
    """Returns (block_out [T, D], new_ctx_k, new_ctx_v)."""
    s = cfg.spec
    dt = x.dtype
    T = x.shape[0]
    q, k_cur, v_cur = project_qkv(cfg, p, x, pos, positions3)

    expand_fn = None
    scale = 1.0 / math.sqrt(s.head_dim)
    if s.kv_lora_rank > 0:
        scale = 1.0 / math.sqrt(s.head_dim + s.qk_rope_dim)
        expand_fn = functools.partial(mla_expand_ctx, cfg, p)

    out, new_k, new_v = attn_fn(
        q, k_cur, v_cur, seg=seg, pos=pos, ctx_k=ctx_k, ctx_v=ctx_v,
        ctx_len=ctx_len, causal=causal, window=window, scale=scale,
        expand_fn=expand_fn)
    if s.kv_lora_rank > 0:
        out = out[..., :s.head_dim]  # value width (drop rope channels)
    out = out.reshape(T, -1)
    y = jnp.einsum("th,hd->td", out, p["wo"].astype(dt))
    return y, new_k, new_v
