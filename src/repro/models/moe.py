"""Mixture-of-Experts block: top-k router, routed + shared experts.

Two execution paths share the same parameters:

* :func:`moe_apply_dense` — reference path: computes every expert densely and
  combines with the routing weights. Exact, differentiable, O(T * E * ff);
  used for smoke tests, equivalence tests, and as the oracle for the EP path.
* ``repro.runtime.ep.moe_apply_ep`` — expert-parallel path: experts are
  sharded over the "model" mesh axis; tokens are dispatched with an
  all-to-all under a capacity factor. Used by the distributed executor.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import dense_init

__all__ = ["init_moe", "router_weights", "moe_apply_dense"]


def init_moe(cfg: ArchConfig, key, dtype=jnp.float32) -> Dict:
    s = cfg.spec
    D, E, F = s.d_model, s.n_experts, s.d_ff_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], D, E, dtype, scale=0.02),
        # stacked expert weights: [E, D, F] / [E, F, D]
        "w_gate": jax.vmap(lambda k: dense_init(k, D, F, dtype))(
            jax.random.split(ks[1], E)),
        "w_up": jax.vmap(lambda k: dense_init(k, D, F, dtype))(
            jax.random.split(ks[2], E)),
        "w_down": jax.vmap(lambda k: dense_init(k, F, D, dtype))(
            jax.random.split(ks[3], E)),
    }
    if s.n_shared_experts > 0:
        Fs = F * s.n_shared_experts
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(k1, D, Fs, dtype),
            "w_up": dense_init(k2, D, Fs, dtype),
            "w_down": dense_init(k3, Fs, D, dtype),
        }
    return p


def router_weights(cfg: ArchConfig, p: Dict, x: jnp.ndarray
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k routing. Returns (weights [T, k], expert_ids [T, k]).

    Softmax over the selected experts (renormalized), matching
    OLMoE/DeepSeek practice."""
    s = cfg.spec
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    top_w, top_i = jax.lax.top_k(logits, s.top_k)
    top_w = jax.nn.softmax(top_w, axis=-1)
    return top_w, top_i


def _expert_ffn(w_gate, w_up, w_down, x):
    g = jnp.einsum("td,df->tf", x, w_gate.astype(x.dtype))
    u = jnp.einsum("td,df->tf", x, w_up.astype(x.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("tf,fd->td", h, w_down.astype(x.dtype))


def moe_apply_dense(cfg: ArchConfig, p: Dict, x: jnp.ndarray) -> jnp.ndarray:
    """Reference: dense one-hot combine over all experts."""
    s = cfg.spec
    T, D = x.shape
    w, idx = router_weights(cfg, p, x)             # [T,k], [T,k]
    combine = jnp.zeros((T, s.n_experts), jnp.float32)
    combine = combine.at[jnp.arange(T)[:, None], idx].add(w)
    # per-expert dense computation, scan over experts to bound memory
    def body(acc, ew):
        wg, wu, wd, cw = ew
        y = _expert_ffn(wg, wu, wd, x)
        return acc + y.astype(jnp.float32) * cw[:, None], None
    acc0 = jnp.zeros((T, D), jnp.float32)
    acc, _ = jax.lax.scan(
        body, acc0,
        (p["w_gate"], p["w_up"], p["w_down"], combine.T))
    out = acc.astype(x.dtype)
    if s.n_shared_experts > 0:
        sh = p["shared"]
        out = out + _expert_ffn(sh["w_gate"], sh["w_up"], sh["w_down"], x)
    return out
