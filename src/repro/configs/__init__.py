from .registry import ARCHS, arch_names, get_arch
from .shapes import SHAPES, ShapeSpec

__all__ = ["ARCHS", "arch_names", "get_arch", "SHAPES", "ShapeSpec"]
