"""The paper's own evaluation models: LLaMA-series 7B / 13B / 30B
(§V-A workloads), used by the paper-figure benchmarks with the paper's
A800 cluster spec (4 nodes x 8 GPUs; SP intra-node d_s=8, PP inter-node).
"""

from repro.core.plan import ClusterSpec, ModelSpec
from repro.models.config import ArchConfig

__all__ = ["llama_7b", "llama_13b", "llama_30b", "paper_cluster"]


def llama_7b() -> ArchConfig:
    return ArchConfig(spec=ModelSpec(
        name="llama-7b", n_layers=32, d_model=4096, n_heads=32,
        n_kv_heads=32, head_dim=128, d_ff=11008, vocab=32000,
        tie_embeddings=False))


def llama_13b() -> ArchConfig:
    return ArchConfig(spec=ModelSpec(
        name="llama-13b", n_layers=40, d_model=5120, n_heads=40,
        n_kv_heads=40, head_dim=128, d_ff=13824, vocab=32000,
        tie_embeddings=False))


def llama_30b() -> ArchConfig:
    return ArchConfig(spec=ModelSpec(
        name="llama-30b", n_layers=60, d_model=6656, n_heads=52,
        n_kv_heads=52, head_dim=128, d_ff=17920, vocab=32000,
        tie_embeddings=False))


def paper_cluster(d_p: int = 4, d_s: int = 8) -> ClusterSpec:
    """4x8 A800-80GB: NVLink 400GB/s intra-node, 400Gb/s IB inter-node."""
    return ClusterSpec(d_p=d_p, d_s=d_s, n_pods=1,
                       flops_per_chip=312e12,      # A800 bf16
                       hbm_bytes=80e9, hbm_bw=2.0e12,
                       ici_bw=200e9,               # NVLink per direction
                       dcn_bw=50e9)                # 400Gb/s IB
