"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, parallel attention + mamba heads, ssm_state=16.
[arXiv:2411.13676; hf]
"""

from repro.core.plan import ModelSpec
from repro.models.config import ArchConfig, LayerKind


def config() -> ArchConfig:
    return ArchConfig(
        spec=ModelSpec(
            name="hymba-1.5b",
            n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
            d_ff=5504, vocab=32001,
            ssm_state=16, d_inner=3200, hybrid_parallel=True,
        ),
        rope_theta=10_000.0,
        layer_kind=LayerKind.HYBRID,
        tie_embeddings=True,
        supports_long_decode=True,  # hybrid: SSM path is O(1) in context
    )
