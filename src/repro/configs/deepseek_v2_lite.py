"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H (MLA kv_lora=512,
qk_rope=64) d_ff(expert)=1408 vocab=102400, 64 routed experts top-6 + 2
shared (per the assignment's "MoE 64e top-6"; the HF card's 160-routed
full-size variant is a config edit away). All layers MoE (the HF model's
dense first layer is homogenized for stage stacking — noted in DESIGN.md).
[arXiv:2405.04434; hf]
"""

from repro.core.plan import ModelSpec
from repro.models.config import ArchConfig, LayerKind


def config() -> ArchConfig:
    return ArchConfig(
        spec=ModelSpec(
            name="deepseek-v2-lite",
            n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
            d_ff=1408, vocab=102400,
            n_experts=64, n_shared_experts=2, top_k=6, d_ff_expert=1408,
            kv_lora_rank=512, qk_rope_dim=64,
        ),
        rope_theta=10_000.0,
        layer_kind=LayerKind.MOE,
        tie_embeddings=False,
    )
