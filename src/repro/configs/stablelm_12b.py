"""stablelm-12b [dense]: 40L d_model=5120 32H (GQA kv=8) d_ff=13824
vocab=100352. [hf:stabilityai/stablelm-2-1_6b; hf]
"""

from repro.core.plan import ModelSpec
from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        spec=ModelSpec(
            name="stablelm-12b",
            n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=160,
            d_ff=13824, vocab=100352,
        ),
        rope_theta=10_000.0,
        tie_embeddings=False,
    )
