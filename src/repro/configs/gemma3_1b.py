"""gemma3-1b [dense]: 26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144,
5:1 local:global sliding-window pattern, 128k context.
[hf:google/gemma-3-1b-pt; unverified]
"""

from repro.core.plan import ModelSpec
from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        spec=ModelSpec(
            name="gemma3-1b",
            n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
            d_ff=6912, vocab=262144,
            local_window=512, local_global_ratio=5,
        ),
        rope_theta=1_000_000.0,
        local_window=512, local_global_ratio=5,
        tie_embeddings=True, embed_scale=True,
        # 5/6 of layers are 512-window local attention; the few global layers
        # hold a sequence-sharded KV cache -> long_500k decode is runnable.
        supports_long_decode=True,
    )
