"""Architecture registry: --arch <id> resolution for every launcher."""

from typing import Callable, Dict, List

from repro.models.config import ArchConfig

from . import (deepseek_v2_lite, falcon_mamba_7b, gemma3_1b, hymba_1_5b,
               llama32_3b, olmoe_1b_7b, qwen2_vl_7b, qwen3_4b,
               seamless_m4t_v2, stablelm_12b)

__all__ = ["ARCHS", "get_arch", "arch_names"]

ARCHS: Dict[str, Callable[[], ArchConfig]] = {
    "gemma3-1b": gemma3_1b.config,
    "llama3.2-3b": llama32_3b.config,
    "stablelm-12b": stablelm_12b.config,
    "qwen3-4b": qwen3_4b.config,
    "olmoe-1b-7b": olmoe_1b_7b.config,
    "deepseek-v2-lite": deepseek_v2_lite.config,
    "hymba-1.5b": hymba_1_5b.config,
    "qwen2-vl-7b": qwen2_vl_7b.config,
    "seamless-m4t-v2": seamless_m4t_v2.config,
    "falcon-mamba-7b": falcon_mamba_7b.config,
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]()


def arch_names() -> List[str]:
    return list(ARCHS)
