"""olmoe-1b-7b [moe]: 16L d_model=2048 16H (GQA kv=16) d_ff(expert)=1024
vocab=50304, 64 experts top-8. [arXiv:2409.02060; hf]
"""

from repro.core.plan import ModelSpec
from repro.models.config import ArchConfig, LayerKind


def config() -> ArchConfig:
    return ArchConfig(
        spec=ModelSpec(
            name="olmoe-1b-7b",
            n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
            d_ff=1024, vocab=50304,
            n_experts=64, top_k=8, d_ff_expert=1024,
        ),
        rope_theta=10_000.0,
        layer_kind=LayerKind.MOE,
        tie_embeddings=False,
    )
