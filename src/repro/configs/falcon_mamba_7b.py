"""falcon-mamba-7b [ssm]: 64L d_model=4096 attn-free mamba-1, d_inner=8192,
ssm_state=16, vocab=65024. [arXiv:2410.05355; unverified]
"""

from repro.core.plan import ModelSpec
from repro.models.config import ArchConfig, LayerKind


def config() -> ArchConfig:
    return ArchConfig(
        spec=ModelSpec(
            name="falcon-mamba-7b",
            n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0, head_dim=0,
            d_ff=0, vocab=65024,
            ssm_state=16, d_inner=8192, attn_free=True,
        ),
        rope_kind="none",
        layer_kind=LayerKind.MAMBA,
        tie_embeddings=True,
        supports_long_decode=True,  # O(1)-state SSM
    )
