"""qwen2-vl-7b [vlm]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064, M-RoPE, dynamic resolution. Backbone only — the ViT tower is a
stub providing precomputed patch embeddings (models/frontends.py).
[arXiv:2409.12191; hf]
"""

from repro.core.plan import ModelSpec
from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        spec=ModelSpec(
            name="qwen2-vl-7b",
            n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, head_dim=128,
            d_ff=18944, vocab=152064,
        ),
        rope_theta=1_000_000.0,
        rope_kind="mrope", mrope_sections=(16, 24, 24),
        tie_embeddings=False,
        frontend="vision",
    )
