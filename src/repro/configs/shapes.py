"""Assigned input shapes (one set, shared by all ten LM-family archs).

  train_4k     seq_len=4,096   global_batch=256   -> train_step
  prefill_32k  seq_len=32,768  global_batch=32    -> prefill_step
  decode_32k   seq_len=32,768  global_batch=128   -> serve_step (1 new token)
  long_500k    seq_len=524,288 global_batch=1     -> serve_step, sub-quadratic
                                                     archs only (see DESIGN.md)
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

__all__ = ["ShapeSpec", "SHAPES"]


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # "train" | "prefill" | "decode"
    needs_subquadratic: bool = False


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode",
                           needs_subquadratic=True),
}
