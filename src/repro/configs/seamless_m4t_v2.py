"""seamless-m4t-large-v2 [audio]: enc-dec, 24L encoder + 24L decoder,
d_model=1024 16H (kv=16) d_ff=8192 vocab=256206. Backbone only — the speech
feature extractor is a stub providing precomputed frame embeddings.
[arXiv:2308.11596; hf]
"""

from repro.core.plan import ModelSpec
from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        spec=ModelSpec(
            name="seamless-m4t-v2",
            n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
            d_ff=8192, vocab=256206,
            is_encoder_decoder=True, n_encoder_layers=24,
        ),
        rope_kind="none",
        tie_embeddings=True,
        frontend="audio",
        is_encoder_decoder=True,
    )
