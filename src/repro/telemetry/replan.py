"""Online re-planning controller: drift → calibrate → re-solve → hot-swap.

The controller closes the ROADMAP's loop from live telemetry back to the
solver. It owns the drift detectors (CUSUM on relative prediction
residuals, fast/slow-EMA length-mix tracker), the sample window the
calibration fits from, and the swap policy:

* Per-step solves in the training loop always use the **active**
  calibration (frozen between adoptions — so plan buckets stay stable and
  the compile cache stays closed).
* On a trigger (drift, mix shift, elastic mesh change, or the bootstrap
  fit once ``min_samples`` have arrived) a re-plan job runs — inline, or on
  a background thread (``ReplanConfig.background``) so the training loop
  never blocks on the ILP: fit a candidate :class:`CostCalibration`,
  re-solve the latest batch with it, and re-cost the incumbent plan under
  the *same* candidate model (like against like).
* If the candidate keeps the incumbent's bucket the calibration is adopted
  silently (free — no new executable, no swap). If it changes bucket it
  must beat the incumbent by ``min_win`` (hysteresis, default >5%) AND pass
  the plan lint; then the fresh bucket is precompiled off-thread before
  adoption so the hot-swap at the next step boundary never blocks on XLA.
  A previously-seen bucket is a warm hit from CompileCache/CacheStore — the
  zero-fresh-compile steady state.
* ``observe`` mode runs the whole machinery (fits, residuals, would-swap
  decisions in the stats) but ``cost_model()`` keeps returning the base
  model, so plans — and therefore numerics — are untouched.

Adoption happens only in :meth:`ReplanController.poll`, which the driver
calls at a step boundary — the swap point the ISSUE specifies.

Calibrations persist to ``<telemetry-dir>/calibration.json`` keyed by mesh
fingerprint: an elastic restart onto the same mesh warm-starts its
calibration; a restart onto a *different* mesh (shrink/grow) finds only
foreign fingerprints and forces an immediate re-solve instead of replaying
the bootstrap plan.
"""

from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.core.costs import CostModel
from repro.core.plan import ExecutionPlan
from repro.core.planner import estimate_plan_time

from .calibrate import (CostCalibration, Cusum, MixTracker, StepSample,
                        fit_calibration, plan_components)
from .stats_io import atomic_write_json, read_json
from .timeline import StepTimeline

__all__ = ["ReplanConfig", "ReplanController", "ReplanDecision"]


@dataclass
class ReplanConfig:
    mode: str = "off"              # "off" | "observe" | "auto"
    min_win: float = 0.05          # hysteresis: swap needs >5% predicted win
    cooldown_steps: int = 8        # min steps between re-plan jobs
    min_samples: int = 4           # samples before the first fit
    window: int = 32               # sample window the fit sees
    probe_window: int = 8          # per-stage probe vectors kept for slowdowns
    cusum_k: float = 0.05
    cusum_h: float = 0.5
    mix_rel: float = 0.3
    background: bool = False       # re-plan jobs on a worker thread

    @property
    def enabled(self) -> bool:
        return self.mode in ("observe", "auto")


@dataclass
class ReplanDecision:
    """Outcome of one re-plan job (returned from :meth:`poll` on adoption)."""
    step: int
    reason: str
    decision: str = ""             # swap | recalibrate | hysteresis | lint-reject
    calibration: Optional[CostCalibration] = None
    plan: Optional[ExecutionPlan] = None
    old_bucket: str = ""
    new_bucket: str = ""
    t_candidate: float = 0.0
    t_incumbent: float = 0.0
    lint_errors: List[str] = field(default_factory=list)
    precompiled: bool = False

    @property
    def win(self) -> float:
        if self.t_incumbent <= 0:
            return 0.0
        return 1.0 - self.t_candidate / self.t_incumbent

    @property
    def is_swap(self) -> bool:
        return self.decision == "swap"


class ReplanController:
    """One controller per training run. The driver supplies the closures
    that tie it to its stack: ``solve(cm, lengths) -> ExecutionPlan``,
    ``bucket_of(plan) -> str`` (the compile-cache identity), optional
    ``lint(plan) -> [error, ...]`` and ``precompile(plan)``."""

    def __init__(self, base_cm: CostModel, cfg: ReplanConfig,
                 solve: Callable[[CostModel, Sequence[int]], ExecutionPlan],
                 bucket_of: Callable[[ExecutionPlan], str], *,
                 evaluate: Callable[[CostModel, ExecutionPlan], float]
                 = estimate_plan_time,
                 lint: Optional[Callable[[ExecutionPlan], List[str]]] = None,
                 precompile: Optional[Callable[[ExecutionPlan], Any]] = None,
                 resolve_incumbent: Optional[
                     Callable[[CostModel, Sequence[int], ExecutionPlan],
                              ExecutionPlan]] = None,
                 timeline: Optional[StepTimeline] = None,
                 telemetry_dir: Optional[str] = None,
                 fingerprint: str = "", log=None) -> None:
        self.base_cm = base_cm
        self.cfg = cfg
        self.solve = solve
        self.bucket_of = bucket_of
        self.evaluate = evaluate
        self.lint = lint
        self.precompile = precompile
        # "what would the NEXT steps cost if we kept the incumbent's
        # bucket" — the driver supplies a bucket-constrained re-solve so
        # hysteresis compares both plans on the SAME batch; the default
        # costs the incumbent plan as-is (fine when mixes are stationary)
        self.resolve_incumbent = resolve_incumbent or (
            lambda cm, lengths, inc: inc)
        self.timeline = timeline
        self.fingerprint = fingerprint or (
            f"{base_cm.cluster.d_p}x{base_cm.cluster.d_s}:"
            f"{base_cm.model.name}")
        self.log = log or (lambda *_: None)
        self._cal_path = (Path(telemetry_dir) / "calibration.json"
                          if telemetry_dir else None)

        self.active: Optional[CostCalibration] = None
        self.version = 0
        self.cusum = Cusum(k=cfg.cusum_k, h=cfg.cusum_h)
        self.mix = MixTracker(rel=cfg.mix_rel)
        self._samples: deque = deque(maxlen=cfg.window)
        self._probes: deque = deque(maxlen=cfg.probe_window)
        self._last_plan: Optional[ExecutionPlan] = None
        # the incumbent REFERENCE: the last plan this controller adopted
        # (seeded by the first executed plan). Per-step solves may ride the
        # length mix freely — a "swap" is the control-plane event where the
        # adopted reference moves to a different bucket (and the fresh
        # bucket gets precompiled before the step boundary needs it).
        self._adopted_plan: Optional[ExecutionPlan] = None
        self._last_lengths: List[int] = []
        self._last_trigger_step = -10 ** 9
        self._force: Optional[str] = None
        self._lock = threading.Lock()
        self._pending: Optional[ReplanDecision] = None
        self._worker: Optional[threading.Thread] = None
        self._active_cm_cache: Optional[CostModel] = None
        self.counters: Dict[str, int] = {
            "fits": 0, "swaps": 0, "would_swaps": 0, "recalibrations": 0,
            "hysteresis_rejects": 0, "lint_rejects": 0, "forced": 0}
        self.trigger_reasons: Dict[str, int] = {}
        self.swap_steps: List[int] = []
        self._load_persisted()

    # -- the model the per-step solver uses --------------------------------

    def cost_model(self) -> CostModel:
        """Active calibrated model in ``auto`` mode; the base model
        otherwise (``observe`` never perturbs the plans)."""
        if self.cfg.mode != "auto" or self.active is None:
            return self.base_cm
        if self._active_cm_cache is None:
            self._active_cm_cache = self.active.apply(self.base_cm)
        return self._active_cm_cache

    def _residual_cm(self) -> CostModel:
        """The model residuals are measured against: the active calibration
        in BOTH observe and auto mode (observe still tracks drift — it just
        never feeds plans)."""
        if self.active is None:
            return self.base_cm
        if self.cfg.mode == "auto":
            return self.cost_model()
        return self.active.apply(self.base_cm)

    # -- collection --------------------------------------------------------

    def observe_step(self, step: int, plan: ExecutionPlan,
                     measured_s: float, lengths: Sequence[int], *,
                     per_stage_s: Optional[Sequence[float]] = None,
                     comm_s: Optional[float] = None,
                     bucket: Optional[str] = None) -> Optional[str]:
        """Feed one executed step. ``per_stage_s`` / ``comm_s`` are probe
        measurements (per-stage walls, collective seconds) when the driver
        ran this step in probe mode. Returns the trigger reason when a
        re-plan job was launched this step, else None."""
        if not self.cfg.enabled:
            return None
        sp_pol = plan.sp.policy if plan.sp is not None else "none"
        self._samples.append(StepSample(
            step=step, measured_s=float(measured_s),
            components=plan_components(self.base_cm, plan),
            sp_policy=sp_pol,
            bucket=bucket if bucket is not None else self.bucket_of(plan),
            tokens=float(sum(lengths)),
            comm_s=float(comm_s) if comm_s else 0.0,
            predicted_s=self.evaluate(self.base_cm, plan)))
        if per_stage_s is not None:
            self._probes.append([float(x) for x in per_stage_s])
        self._last_plan = plan
        if self._adopted_plan is None:
            self._adopted_plan = plan  # bootstrap incumbent
        self._last_lengths = list(lengths)

        predicted = self.evaluate(self._residual_cm(), plan)
        r = ((measured_s - predicted) / predicted) if predicted > 0 else 0.0
        drifted = self.cusum.update(r)
        shifted = self.mix.update(lengths)

        reason = None
        if self._force:
            reason, self._force = self._force, None
            self.counters["forced"] += 1
        elif self.active is None and len(self._samples) >= self.cfg.min_samples:
            reason = "bootstrap"   # first fit absorbs the sim-vs-wall scale
        elif drifted:
            reason = "drift"
        elif shifted:
            reason = "mix-shift"
        if reason is None:
            return None
        if reason not in ("elastic", "forced"):
            if len(self._samples) < self.cfg.min_samples:
                return None
            if step - self._last_trigger_step < self.cfg.cooldown_steps:
                return None
        if self._worker is not None and self._worker.is_alive():
            return None  # a job is already in flight
        self._last_trigger_step = step
        self.trigger_reasons[reason] = self.trigger_reasons.get(reason, 0) + 1
        if self.timeline is not None:
            self.timeline.record("replan", step, phase="trigger",
                                 reason=reason, cusum=self.cusum.state(),
                                 mix=self.mix.state())
        job_args = (step, reason, list(self._samples), list(self._probes),
                    self._adopted_plan or self._last_plan,
                    list(self._last_lengths))
        if self.cfg.background:
            self._worker = threading.Thread(
                target=self._replan_job, args=job_args,
                name="replan-worker", daemon=True)
            self._worker.start()
        else:
            self._replan_job(*job_args)
        return reason

    def force_replan(self, reason: str = "forced") -> None:
        """Queue an unconditional re-plan at the next observed step —
        elastic shrink/grow events route through here."""
        self._force = reason

    # -- the re-plan job (worker thread or inline) -------------------------

    def _replan_job(self, step: int, reason: str,
                    samples: List[StepSample], probes: List[List[float]],
                    incumbent: Optional[ExecutionPlan],
                    lengths: List[int]) -> None:
        try:
            cal: Optional[CostCalibration] = None
            if samples:
                cal = fit_calibration(
                    samples, probes=probes, d_p=self.base_cm.cluster.d_p,
                    fingerprint=self.fingerprint, version=self.version + 1,
                    prior=self.active, created_step=step)
                self.counters["fits"] += 1
            cand_cm = (cal.apply(self.base_cm) if cal is not None
                       else self._residual_cm())
            candidate = self.solve(cand_cm, lengths)
            dec = ReplanDecision(step=step, reason=reason, calibration=cal,
                                 plan=candidate,
                                 new_bucket=self.bucket_of(candidate))
            if incumbent is not None:
                dec.old_bucket = self.bucket_of(incumbent)
                dec.t_candidate = self.evaluate(cand_cm, candidate)
                # like against like: the incumbent's BUCKET re-planned on
                # the trigger step's batch (resolve_incumbent), both costed
                # under the candidate calibration
                held = self.resolve_incumbent(cand_cm, lengths, incumbent)
                dec.t_incumbent = self.evaluate(cand_cm, held)
            if incumbent is None or dec.new_bucket == dec.old_bucket:
                dec.decision = "recalibrate"
            elif reason == "bootstrap":
                # the bootstrap fit exists to absorb the units conversion —
                # a bucket move proposed by a model that just changed
                # wholesale is not evidence; adopt the calibration only and
                # let a real drift trigger argue for the move
                dec.decision, dec.plan = "recalibrate", None
            elif dec.t_candidate >= (1.0 - self.cfg.min_win) * dec.t_incumbent:
                dec.decision = "hysteresis"
            else:
                errs = list(self.lint(candidate)) if self.lint else []
                if errs:
                    dec.decision, dec.lint_errors = "lint-reject", errs
                elif self.cfg.mode == "auto":
                    if self.precompile is not None:
                        self.precompile(candidate)
                        dec.precompiled = True
                    dec.decision = "swap"
                else:
                    dec.decision = "swap"  # observe: counted as would-swap
            with self._lock:
                self._pending = dec
        except Exception as e:  # noqa: BLE001 — telemetry never kills training
            self.log(f"[replan] job failed ({reason} @ step {step}): {e!r}")
            if self.timeline is not None:
                self.timeline.record("replan", step, phase="error",
                                     reason=reason, error=repr(e))

    # -- adoption at the step boundary -------------------------------------

    def poll(self) -> Optional[ReplanDecision]:
        """Collect a finished re-plan job and adopt its outcome. Call once
        per step, at the boundary. Returns the decision when a SWAP (auto)
        or would-swap (observe) was adopted this poll, else None."""
        with self._lock:
            dec, self._pending = self._pending, None
        if dec is None:
            return None
        adopt = dec.decision in ("swap", "recalibrate")
        # hysteresis rejects the BUCKET MOVE, not the fit: the calibration
        # still explains the measurements better, and dropping it would
        # leave residuals high and re-fire the same trigger every window
        adopt_cal = adopt or dec.decision == "hysteresis"
        if dec.decision == "hysteresis":
            self.counters["hysteresis_rejects"] += 1
        elif dec.decision == "lint-reject":
            self.counters["lint_rejects"] += 1
            self.log(f"[replan] candidate bucket {dec.new_bucket} REJECTED "
                     f"by plan lint: {dec.lint_errors[:3]}")
        if adopt and dec.plan is not None:
            self._adopted_plan = dec.plan
        if adopt_cal and dec.calibration is not None:
            self.active = dec.calibration
            self.version = dec.calibration.version
            self._active_cm_cache = None
            if dec.reason in ("drift", "elastic"):
                # a detected regime change means the window's older rows
                # describe a reality that no longer exists; refitting on a
                # window that straddles the change makes the regimes fight
                # and rotates the split every trigger. Restart collection
                # from the change point.
                self._samples.clear()
                self._probes.clear()
            self._persist()
            if self.timeline is not None:
                self.timeline.record("calibration", dec.step,
                                     version=self.version,
                                     deltas=dec.calibration.deltas(),
                                     rms=dec.calibration.residual_rel_rms)
        if dec.decision == "recalibrate":
            self.counters["recalibrations"] += 1
        swap = None
        if dec.is_swap:
            key = "swaps" if self.cfg.mode == "auto" else "would_swaps"
            self.counters[key] += 1
            if self.cfg.mode == "auto":
                self.swap_steps.append(dec.step)
            swap = dec
            self.log(f"[replan] {'swap' if self.cfg.mode == 'auto' else 'would swap'} "
                     f"@ step {dec.step} ({dec.reason}): "
                     f"{dec.old_bucket} -> {dec.new_bucket} "
                     f"predicted win {dec.win:.1%}"
                     + (" (precompiled)" if dec.precompiled else ""))
        if self.timeline is not None:
            self.timeline.record(
                "replan", dec.step, phase="decision",
                decision=dec.decision, reason=dec.reason,
                win=round(dec.win, 4), old=dec.old_bucket,
                new=dec.new_bucket, precompiled=dec.precompiled,
                mode=self.cfg.mode)
        # one trigger -> one decision: reset the detectors so the same
        # residual history cannot re-fire next step
        self.cusum.reset()
        self.mix.settle()
        return swap

    def drain(self, timeout: float = 30.0) -> None:
        """Wait for an in-flight background job (end of run)."""
        w = self._worker
        if w is not None and w.is_alive():
            w.join(timeout)

    # -- persistence -------------------------------------------------------

    def _load_persisted(self) -> None:
        if self._cal_path is None:
            return
        data = read_json(str(self._cal_path))
        if not isinstance(data, dict) or not data:
            return
        mine = data.get(self.fingerprint)
        if mine:
            self.active = CostCalibration.from_dict(mine)
            self.version = self.active.version
            self._active_cm_cache = None
            self.log(f"[replan] warm calibration v{self.version} for "
                     f"{self.fingerprint} from {self._cal_path}")
        else:
            # calibrations exist but none for THIS mesh: an elastic
            # shrink/grow changed the topology under the run — re-solve
            # immediately instead of replaying the bootstrap plan
            self.force_replan("elastic")
            self.log(f"[replan] mesh {self.fingerprint} has no calibration "
                     f"(store has {sorted(data)}); forcing elastic re-solve")

    def _persist(self) -> None:
        if self._cal_path is None or self.active is None:
            return
        data = read_json(str(self._cal_path), default={}) or {}
        data[self.fingerprint] = self.active.to_dict()
        atomic_write_json(str(self._cal_path), data)

    # -- reporting ---------------------------------------------------------

    def snapshot(self) -> Dict[str, Any]:
        return {
            "mode": self.cfg.mode,
            "fingerprint": self.fingerprint,
            "calibration_version": self.version,
            "calibration": (self.active.to_dict()
                            if self.active is not None else None),
            "calibration_deltas": (self.active.deltas()
                                   if self.active is not None else {}),
            "counters": dict(self.counters),
            "triggers": dict(self.trigger_reasons),
            "swap_steps": list(self.swap_steps),
            "cusum": self.cusum.state(),
            "mix": self.mix.state(),
            "samples": len(self._samples),
        }
