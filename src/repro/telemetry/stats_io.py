"""Atomic stats-artifact I/O.

Every ``--stats-json`` consumer (train / serve / dryrun and the CI scrapers
that poll those files while the run is still alive) goes through
:func:`atomic_write_json`: the record is serialized to a temp file in the
*same directory* and published with ``os.replace``, so a reader either sees
the previous complete artifact or the new complete artifact — never a torn
half-dump, even if the writer is SIGKILLed mid-write
(tests/test_telemetry.py kills a writer subprocess in the middle of the dump
and asserts the survivor parses).
"""

from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Optional


def atomic_write_json(path: str, obj: Any, *, indent: int = 1,
                      default=str) -> None:
    """Serialize ``obj`` to ``path`` atomically (tmp file + ``os.replace``)."""
    path = os.fspath(path)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, prefix=".tmp-stats-", suffix=".json")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(obj, f, indent=indent, default=default)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def read_json(path: str, default: Optional[Any] = None) -> Any:
    """Best-effort read of a stats artifact; returns ``default`` when the
    file is absent or unparseable (a scraper should never crash the host)."""
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return default


def read_jsonl(path: str):
    """Yield parsed records from a JSONL spill, skipping torn tail lines
    (the spill is append-only; a crash can leave one partial last line)."""
    try:
        f = open(path)
    except OSError:
        return
    with f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                yield json.loads(line)
            except ValueError:
                continue
