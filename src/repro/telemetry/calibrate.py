"""Fit measured step times back onto ``core/costs.py`` terms.

The fit is deliberately *structured*: rather than free-fitting Eq. 1's
coefficients from scratch (ill-posed from a handful of step samples), each
step's predicted time is decomposed under the **base** analytic model into
five work components — quadratic attention, linear compute, per-stage
dispatch overhead, checkpoint recompute, SP collectives — and a robust
(Huber-IRLS, ridge-regularized toward 1.0) regression fits one
multiplicative scale per component:

    measured ≈ s_quad·P_quad + s_lin·P_lin + s_over·P_over
               + s_rec·P_rec + s_comm(policy)·P_comm

The scales then re-enter the model exactly where they came from:
``alpha1' = s_quad·alpha1``, ``alpha2' = s_lin·alpha2``, ``beta1' =
s_over·beta1``, ``recompute_factor = s_rec``, and the collective bandwidth
per SP policy divides by ``s_comm`` — so a :class:`CostCalibration` is just
a versioned, serializable recipe for constructing a calibrated
:class:`~repro.core.costs.CostModel`. Components that carry no signal in
the sample window (all-zero or non-varying columns) keep their base scale
of 1.0 instead of absorbing noise.

Drift detection is a two-sided CUSUM on relative prediction residuals
(:class:`Cusum`) plus a fast/slow-EMA length-mix tracker
(:class:`MixTracker`); both are consumed by ``telemetry/replan.py``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.core.costs import BWD_MULT, CostModel
from repro.core.plan import ExecutionPlan

__all__ = ["CostCalibration", "StepSample", "Cusum", "MixTracker",
           "plan_components", "predicted_work", "fit_calibration",
           "fit_stage_slowdowns"]

COMPONENTS = ("quad", "lin", "over", "rec", "comm")
# a stage is a straggler when its mean relative tick time exceeds this
SLOWDOWN_THRESHOLD = 1.1


# ---------------------------------------------------------------------------
# Work decomposition under the base model.
# ---------------------------------------------------------------------------

def plan_components(cm: CostModel, plan: ExecutionPlan) -> Dict[str, float]:
    """Decompose one plan's predicted *work* (fwd+bwd+recompute, summed over
    chunks; no bubble) into the five calibratable components, evaluated at
    the plan's own SP point."""
    out = {k: 0.0 for k in COMPONENTS}
    pcm = cm
    if plan.sp is not None:
        pcm = cm.with_sp(plan.sp.policy, plan.sp.d_s_eff)
    cl, co = pcm.cluster, pcm.coeffs
    both = 1.0 + BWD_MULT
    for pp in plan.pipelines:
        for k, ck in enumerate(pp.chunks):
            C, s0 = float(ck.context), float(ck.s0)
            quad = (C + s0) ** 2 - C ** 2 if s0 else 0.0
            lin = s0
            for s in ck.short_slices:
                quad += float(s.length) ** 2
                lin += float(s.length)
            geom = pcm.sp_replication / cl.n_devices / pcm.utilization(ck)
            p_quad = both * co.alpha1 * 0.5 * quad * geom
            p_lin = both * co.alpha2 * lin * geom
            out["quad"] += p_quad
            out["lin"] += p_lin
            out["over"] += both * co.beta1 / cl.d_p
            out["comm"] += both * pcm.t_sp_comm(ck)
            # ckpt[p][k]: each stage re-runs its own checkpointed depth;
            # the total equals the mean depth's whole-model fraction
            if pp.ckpt:
                l_mean = sum(row[k] for row in pp.ckpt) / len(pp.ckpt)
                if l_mean > 0:
                    frac = min(1.0, l_mean * cl.d_p / pcm.model.n_layers)
                    out["rec"] += frac * ((p_quad + p_lin) / both
                                          + pcm.t_sp_comm(ck))
    return out


def predicted_work(cm: CostModel, plan: ExecutionPlan) -> float:
    return sum(plan_components(cm, plan).values())


@dataclass
class StepSample:
    """One measured step, with its work decomposition frozen at record time
    (under the base model — the design matrix must not move as calibrations
    are adopted)."""
    step: int
    measured_s: float
    components: Dict[str, float]
    sp_policy: str = "none"
    bucket: str = ""
    tokens: float = 0.0
    # measured collective seconds this step (profiler/NCCL-style timing),
    # 0 = not probed. A direct comm probe pins the bandwidth scale exactly;
    # without it comm is identifiable only when the comm SHARE varies
    # across the sample window (it often doesn't — a uniform bandwidth
    # collapse inflates every row alike and the regression misattributes
    # it to whichever compute column varies most)
    comm_s: float = 0.0
    # the BASE model's simulated makespan for this plan, 0 = unknown. When
    # present, the fit renormalizes the row so its components sum to this
    # value: the work-sum surrogate cannot represent per-mix bubble
    # differences, and without the renormalization that structural mismatch
    # is real in-sample signal the regression "explains" by rotating
    # coefficients — distorting the planner's trade-offs even when
    # measured == base prediction exactly
    predicted_s: float = 0.0


# ---------------------------------------------------------------------------
# The calibration artifact.
# ---------------------------------------------------------------------------

@dataclass
class CostCalibration:
    """A versioned recipe for constructing a calibrated CostModel."""
    version: int = 0
    scales: Dict[str, float] = field(
        default_factory=lambda: {k: 1.0 for k in COMPONENTS})
    comm_scales: Dict[str, float] = field(default_factory=dict)
    stage_slowdowns: Optional[List[float]] = None
    fingerprint: str = ""           # mesh identity (d_p x d_s : arch)
    n_samples: int = 0
    residual_rel_rms: float = 0.0
    created_step: int = -1

    # -- derived views ------------------------------------------------------

    def deltas(self) -> Dict[str, float]:
        """Relative change per term vs the analytic base (0.0 = unchanged);
        the quantities BENCH_replan's ``meta`` records."""
        d = {k: round(v - 1.0, 4) for k, v in self.scales.items()}
        for pol, s in self.comm_scales.items():
            d[f"comm[{pol}]"] = round(s - 1.0, 4)
        if self.stage_slowdowns:
            d["max_stage_slowdown"] = round(max(self.stage_slowdowns) - 1.0, 4)
        return d

    def apply(self, base: CostModel) -> CostModel:
        """Construct the calibrated model. The SP policy/degree of ``base``
        is preserved; ``stage_slowdowns`` replace any on ``base``."""
        s = self.scales
        co = replace(base.coeffs,
                     alpha1=base.coeffs.alpha1 * s.get("quad", 1.0),
                     alpha2=base.coeffs.alpha2 * s.get("lin", 1.0),
                     beta1=base.coeffs.beta1 * s.get("over", 1.0))
        comm = self.comm_scales.get(base.sp_policy, s.get("comm", 1.0))
        if comm > 0 and comm != 1.0:
            co = replace(co, a2a_bw=co.a2a_bw / comm, ag_bw=co.ag_bw / comm)
        slow = self.stage_slowdowns
        if slow is not None and len(slow) != base.cluster.d_p:
            slow = None  # stale mesh shape — drop rather than crash
        return CostModel(base.model, base.cluster, co,
                         sp_policy=base.sp_policy, sp_degree=base.sp_degree,
                         stage_slowdowns=slow, sat_half=base.sat_half,
                         ce_mode=base.ce_mode,
                         recompute_factor=s.get("rec", 1.0))

    # recovered per-token times (whole model / cluster), for the round-trip
    # gate: t_b/t_w derive from t_f exactly as the schedule layer does
    def t_f_per_token(self, base: CostModel) -> float:
        return (base.coeffs.alpha2 * self.scales.get("lin", 1.0)
                / base.cluster.n_devices)

    def t_b_per_token(self, base: CostModel) -> float:
        return BWD_MULT * self.t_f_per_token(base)

    def t_w_per_token(self, base: CostModel) -> float:
        from repro.core.schedule import WGRAD_FRACTION
        return WGRAD_FRACTION * self.t_b_per_token(base)

    # -- serialization ------------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        return {"version": self.version, "scales": dict(self.scales),
                "comm_scales": dict(self.comm_scales),
                "stage_slowdowns": (list(self.stage_slowdowns)
                                    if self.stage_slowdowns else None),
                "fingerprint": self.fingerprint,
                "n_samples": self.n_samples,
                "residual_rel_rms": round(self.residual_rel_rms, 6),
                "created_step": self.created_step}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "CostCalibration":
        return cls(version=int(d.get("version", 0)),
                   scales={k: float(v)
                           for k, v in d.get("scales", {}).items()},
                   comm_scales={k: float(v)
                                for k, v in d.get("comm_scales", {}).items()},
                   stage_slowdowns=d.get("stage_slowdowns"),
                   fingerprint=d.get("fingerprint", ""),
                   n_samples=int(d.get("n_samples", 0)),
                   residual_rel_rms=float(d.get("residual_rel_rms", 0.0)),
                   created_step=int(d.get("created_step", -1)))


# ---------------------------------------------------------------------------
# Robust fit.
# ---------------------------------------------------------------------------

def fit_calibration(samples: Sequence[StepSample], *,
                    probes: Optional[Sequence[Sequence[float]]] = None,
                    d_p: int = 0,
                    huber_delta: float = 1.345, ridge: float = 1e-4,
                    iters: int = 8, fingerprint: str = "",
                    version: int = 1, prior: Optional[CostCalibration] = None,
                    created_step: int = -1) -> CostCalibration:
    """Huber-IRLS fit of per-component scales on relative (y-normalized)
    rows. Columns with no usable signal keep scale 1.0; fitted scales are
    ridge-pulled toward the best common multiplier (so an overall unit
    conversion is free) and clipped so one wild outlier window can never
    invert the model.
    ``probes`` (per-stage second vectors) additionally fit stage slowdowns.
    ``prior`` (the currently-active calibration): the refit is returned
    only if it explains this window meaningfully better than the prior —
    an under-determined window (all rows one length regime) must not churn
    a split that was identified from a richer one.
    """
    samples = [s for s in samples if s.measured_s > 0]
    if not samples:
        raise ValueError("fit_calibration needs at least one sample")
    slow = None
    if probes and d_p:
        slow = fit_stage_slowdowns(probes, d_p)
    elif prior is not None:
        # no probes this window: stage health is unobservable here, so the
        # prior's view is still the best knowledge (a recovered straggler
        # is re-measured as healthy the next time probes run)
        slow = prior.stage_slowdowns
    policies = sorted({s.sp_policy for s in samples
                       if s.components.get("comm", 0.0) > 0})
    cols = list(COMPONENTS[:4]) + [f"comm[{p}]" for p in policies]
    A = np.zeros((len(samples), len(cols)))
    for i, s in enumerate(samples):
        for j, c in enumerate(COMPONENTS[:4]):
            A[i, j] = s.components.get(c, 0.0)
        if s.components.get("comm", 0.0) > 0:
            A[i, cols.index(f"comm[{s.sp_policy}]")] = s.components["comm"]
    # anchor each row to the base simulator's makespan (see StepSample
    # .predicted_s): components become makespan SHARES, so scales == 1
    # reproduces the base prediction exactly and a calibration fitted from
    # a drift-free window is the identity — not a mix-dependent rotation
    for i, s in enumerate(samples):
        tot = float(A[i].sum())
        if s.predicted_s > 0 and tot > 0:
            A[i] *= s.predicted_s / tot
    if slow is not None:
        # the simulator already models stragglers explicitly: inflate the
        # COMPUTE columns by the fitted slowdown (a pipeline's steady state
        # runs at the slowest stage's rate) so the regression does not
        # re-absorb the straggler into the coefficient scales — apply()
        # would then double-count it
        A[:, :4] *= max(slow)
    y = np.array([s.measured_s for s in samples])
    # relative regression: scale every row by its measurement
    Ar = A / y[:, None]
    # a column is fittable when it carries a non-trivial share of the
    # prediction *relative to the largest component* — the absolute scale of
    # model units vs wall seconds is exactly what the fit has to absorb, so
    # the threshold must be scale-free; frozen columns stay at scale 1.0
    col_max = Ar.max(axis=0)
    active = col_max > 1e-3 * max(float(col_max.max()), 1e-30)
    theta = np.ones(len(cols))
    # direct comm probes (collective timings) pin the comm scale per
    # policy EXACTLY — those columns leave the regression, which then only
    # splits what probes cannot see. Ratios divide by the matrix column
    # (renormalized units), not the raw work component
    for p in policies:
        j = cols.index(f"comm[{p}]")
        ratios = [s.comm_s / A[i, j] for i, s in enumerate(samples)
                  if s.sp_policy == p and s.comm_s > 0 and A[i, j] > 0]
        if ratios:
            # median of the most RECENT probes: bandwidth is the term that
            # genuinely shifts regime (contention), so a full-window median
            # would let the stale regime outvote the current one
            # wide absolute bounds: the ratio carries the model-units →
            # wall-seconds conversion, which is legitimately huge on real
            # hardware; the RELATIVE split clip below is the safety rail
            theta[j] = float(np.clip(np.median(ratios[-5:]), 1e-9, 1e9))
            active[j] = False
    if active.any():
        Aa = Ar[:, active]
        resid_target = 1.0 - Ar[:, ~active] @ theta[~active]
        # the ridge pulls toward the best COMMON multiplier theta0, not
        # toward 1.0: an overall unit conversion (model units vs wall
        # seconds) must be absorbed freely — regularization should only
        # shape the SPLIT between components
        rowsum = Aa.sum(axis=1)
        denom = float(rowsum @ rowsum)
        theta0 = float(rowsum @ resid_target) / denom if denom > 0 else 1.0
        theta0 = float(np.clip(theta0, 1e-9, 1e9))
        w = np.ones(len(samples))
        k = int(active.sum())
        # identifiability: the SPLIT between components is fittable only
        # along directions where the window's compositions actually vary. A
        # window of near-identical mixes is nearly rank-1 — the data pins
        # the common level and NOTHING else, and an unrestricted solve
        # would rotate collinear columns against each other until the
        # planner's trade-offs invert, faking bucket wins out of noise. So
        # the fit is RESTRICTED to the identified subspace (singular value
        # >= 10% of the leading one); the orthogonal complement is frozen
        # at the common multiplier theta0.
        U, sv, Vt = np.linalg.svd(Aa, full_matrices=False)
        keep = sv >= 0.1 * sv[0] if sv.size else np.zeros(0, bool)
        th = np.full(k, theta0)
        if keep.any():
            V = Vt[keep].T                       # (k, r) identified basis
            B = Aa @ V                           # (n, r)
            t2 = resid_target - theta0 * rowsum  # fit the residual split
            lam = ridge * max(1.0, len(samples))
            z0 = np.zeros(V.shape[1])
            for _ in range(max(1, iters)):
                Bw = B * w[:, None]
                lhs = B.T @ Bw + lam * np.eye(B.shape[1])
                z0 = np.linalg.solve(lhs, B.T @ (w * t2))
                r = B @ z0 - t2
                mad = np.median(np.abs(r - np.median(r)))
                sc = max(1.4826 * mad, 1e-9)
                zz = np.abs(r) / sc
                w = np.where(zz <= huber_delta, 1.0,
                             huber_delta / np.maximum(zz, 1e-12))
            th = theta0 + V @ z0
        # the fit absorbs the model-units → wall-seconds conversion through
        # theta0, so the COMMON level can be orders of magnitude — but the
        # RELATIVE split between regression-fitted terms is physically
        # bounded (coefficient drift is 1.x–3x, not 100x; regime-sized
        # shifts like a bandwidth collapse enter via probes, which bypass
        # this clip). An unbounded split lets two collinear columns rotate
        # against each other and invert the planner's trade-offs.
        theta[active] = np.clip(th, theta0 / 3.0, theta0 * 3.0)
    resid = Ar @ theta - 1.0
    rms = float(np.sqrt(np.mean(resid ** 2))) if len(resid) else 0.0
    scales = {c: float(theta[j]) for j, c in enumerate(COMPONENTS[:4])}
    comm_scales = {p: float(theta[cols.index(f"comm[{p}]")])
                   for p in policies}
    scales["comm"] = (float(np.mean(list(comm_scales.values())))
                      if comm_scales else 1.0)
    cal = CostCalibration(version=version, scales=scales,
                          comm_scales=comm_scales, stage_slowdowns=slow,
                          fingerprint=fingerprint, n_samples=len(samples),
                          residual_rel_rms=rms, created_step=created_step)
    if prior is not None:
        # score the PRIOR's theta on this exact window; keep the prior's
        # compute/comm split (refreshing probed terms) unless the refit is
        # a clear improvement — a one-regime window cannot identify the
        # split and would otherwise churn it every trigger
        th_p = np.array([prior.scales.get(c, 1.0) for c in COMPONENTS[:4]]
                        + [prior.comm_scales.get(p, prior.scales.get("comm", 1.0))
                           for p in policies])
        for p in policies:           # probed comm is current-regime truth
            j = cols.index(f"comm[{p}]")
            if not active[j]:
                th_p[j] = theta[j]
        r_p = Ar @ th_p - 1.0
        rms_p = float(np.sqrt(np.mean(r_p ** 2)))
        if rms >= 0.9 * rms_p:
            cal = CostCalibration(
                version=version,
                scales={c: float(th_p[j])
                        for j, c in enumerate(COMPONENTS[:4])}
                | {"comm": (float(np.mean([th_p[cols.index(f"comm[{p}]")]
                                           for p in policies]))
                            if policies else prior.scales.get("comm", 1.0))},
                comm_scales={p: float(th_p[cols.index(f"comm[{p}]")])
                             for p in policies},
                stage_slowdowns=slow, fingerprint=fingerprint,
                n_samples=len(samples), residual_rel_rms=rms_p,
                created_step=created_step)
    if prior is not None and prior.comm_scales:
        # SP policies not exercised in THIS window (e.g. every post-swap
        # plan is sp=none, so no collective ran) are unobservable here —
        # carry the prior's pricing forward instead of silently resetting
        # it to 1.0, which would let the very next re-solve flip straight
        # back into the collapsed fabric
        missing = {pol: v for pol, v in prior.comm_scales.items()
                   if pol not in cal.comm_scales}
        if missing:
            cal.comm_scales = {**cal.comm_scales, **missing}
            cal.scales["comm"] = float(
                np.mean(list(cal.comm_scales.values())))
    return cal


def fit_stage_slowdowns(probes: Sequence[Sequence[float]], d_p: int,
                        threshold: float = SLOWDOWN_THRESHOLD
                        ) -> Optional[List[float]]:
    """Per-stage slowdown multipliers from probe vectors: each probe is
    normalized by its median stage time, averaged across probes, and stages
    under ``threshold`` snap to exactly 1.0 (no phantom stragglers from
    probe jitter). Returns None when no stage is slow."""
    rows = [list(map(float, p)) for p in probes if len(p) == d_p]
    if not rows:
        return None
    arr = np.asarray(rows)
    med = np.median(arr, axis=1, keepdims=True)
    med = np.where(med <= 0, 1.0, med)
    rel = (arr / med).mean(axis=0)
    slow = [float(r) if r >= threshold else 1.0 for r in rel]
    return slow if any(s > 1.0 for s in slow) else None


# ---------------------------------------------------------------------------
# Drift detection.
# ---------------------------------------------------------------------------

@dataclass
class Cusum:
    """Two-sided CUSUM on relative residuals r = (measured - predicted) /
    predicted. ``k`` is the slack (residual drift smaller than k never
    accumulates), ``h`` the decision threshold in the same units."""
    k: float = 0.05
    h: float = 0.5
    pos: float = 0.0
    neg: float = 0.0

    def update(self, r: float) -> bool:
        if not math.isfinite(r):
            return False
        self.pos = max(0.0, self.pos + r - self.k)
        self.neg = max(0.0, self.neg - r - self.k)
        return self.drifted

    @property
    def drifted(self) -> bool:
        return self.pos > self.h or self.neg > self.h

    def reset(self) -> None:
        self.pos = self.neg = 0.0

    def state(self) -> Dict[str, float]:
        return {"pos": round(self.pos, 4), "neg": round(self.neg, 4),
                "k": self.k, "h": self.h}


@dataclass
class MixTracker:
    """Length-mix shift detector: fast vs slow EMA of the batch's mean and
    p95 sequence length. A shift fires when the fast view departs from the
    slow view by ``rel`` on either statistic."""
    rel: float = 0.3
    fast: float = 0.5
    slow: float = 0.05
    warmup: int = 3
    _n: int = 0
    _fast_mean: float = 0.0
    _slow_mean: float = 0.0
    _fast_p95: float = 0.0
    _slow_p95: float = 0.0

    def update(self, lengths: Sequence[int]) -> bool:
        if not len(lengths):
            return False
        mean = float(np.mean(lengths))
        p95 = float(np.percentile(lengths, 95))
        self._n += 1
        if self._n == 1:
            self._fast_mean = self._slow_mean = mean
            self._fast_p95 = self._slow_p95 = p95
            return False
        self._fast_mean = self.fast * mean + (1 - self.fast) * self._fast_mean
        self._slow_mean = self.slow * mean + (1 - self.slow) * self._slow_mean
        self._fast_p95 = self.fast * p95 + (1 - self.fast) * self._fast_p95
        self._slow_p95 = self.slow * p95 + (1 - self.slow) * self._slow_p95
        if self._n <= self.warmup:
            return False
        return self.shifted

    @property
    def shifted(self) -> bool:
        def rel(f, s):
            return abs(f - s) / max(abs(s), 1e-9)
        return (rel(self._fast_mean, self._slow_mean) > self.rel
                or rel(self._fast_p95, self._slow_p95) > self.rel)

    def settle(self) -> None:
        """Adopt the fast view as the new normal (called after a re-solve
        so one shift triggers one re-plan, not one per step)."""
        self._slow_mean = self._fast_mean
        self._slow_p95 = self._fast_p95

    def state(self) -> Dict[str, float]:
        return {"fast_mean": round(self._fast_mean, 1),
                "slow_mean": round(self._slow_mean, 1),
                "fast_p95": round(self._fast_p95, 1),
                "slow_p95": round(self._slow_p95, 1)}
