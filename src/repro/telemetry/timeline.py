"""StepTimeline: the low-overhead collection half of online re-planning.

One recorder per driver (train / serve). Events go into a bounded ring
buffer (``collections.deque``) and, when ``--telemetry-dir`` is set, are
mirrored line-by-line into an append-only JSONL spill the analysis tooling
tails (``launch/analysis.telemetry_report``). Always-on accounting is cheap
(a dict update + an EMA multiply per step); the expensive per-stage probe —
``jax.block_until_ready`` brackets around the step — is opt-in and sampled
every ``probe_every`` steps by the caller.

Event kinds (the schema documented in runtime/README.md):

* ``step``         — one training step: bucket, wall seconds, tokens, loss,
                     optional per-stage seconds (probe mode only).
* ``probe``        — per-stage breakdown sampled under block_until_ready.
* ``compile``      — compile-cache event (cold miss / warm load / hit rates).
* ``lint``         — program-auditor findings attributed to a bucket.
* ``engine``       — serve-engine sample: TTFT/TPOT percentiles, occupancy.
* ``calibration``  — a new CostCalibration version was adopted.
* ``replan``       — re-plan trigger / decision / swap (replan.py).
"""

from __future__ import annotations

import json
import time
from collections import defaultdict, deque
from pathlib import Path
from typing import Any, Dict, List, Optional

__all__ = ["StepEvent", "StepTimeline"]

EMA_DECAY = 0.3  # weight of the newest sample in the per-bucket step EMA


class StepEvent(dict):
    """A timeline event is a plain dict (JSON-ready); attribute sugar only."""

    @property
    def kind(self) -> str:
        return self.get("kind", "?")


class StepTimeline:
    """Ring buffer + JSONL spill + always-on per-bucket EMA counters."""

    def __init__(self, capacity: int = 1024,
                 spill_dir: Optional[str] = None,
                 name: str = "train", clock=time.time) -> None:
        self.name = name
        self._clock = clock
        self._events: deque = deque(maxlen=max(4, capacity))
        self._by_kind: Dict[str, int] = defaultdict(int)
        # per-bucket always-on counters: EMA step seconds, count, last value
        self._buckets: Dict[str, Dict[str, float]] = {}
        self._spill_path: Optional[Path] = None
        self._spill = None
        self.dropped_spill_writes = 0
        if spill_dir:
            d = Path(spill_dir)
            d.mkdir(parents=True, exist_ok=True)
            self._spill_path = d / f"timeline-{name}.jsonl"
            self._spill = open(self._spill_path, "a", buffering=1)

    # -- recording ---------------------------------------------------------

    def record(self, kind: str, step: int = -1, **data: Any) -> StepEvent:
        ev = StepEvent(kind=kind, step=step, t=round(self._clock(), 6),
                       **data)
        self._events.append(ev)
        self._by_kind[kind] += 1
        if self._spill is not None:
            try:
                self._spill.write(json.dumps(ev, default=str) + "\n")
            except (OSError, ValueError):
                # telemetry must never take the training loop down
                self.dropped_spill_writes += 1
        return ev

    def record_step(self, step: int, bucket: Any, wall_s: float, *,
                    tokens: float = 0.0, loss: Optional[float] = None,
                    per_stage_s: Optional[List[float]] = None,
                    probed: bool = False, **extra: Any) -> StepEvent:
        """The always-on per-step sample. ``bucket`` is any hashable bucket
        identity (a ``BucketKey`` or its string form); ``per_stage_s`` is
        only present on probed steps."""
        b = str(bucket)
        st = self._buckets.setdefault(
            b, {"ema_s": 0.0, "n": 0, "last_s": 0.0})
        st["n"] += 1
        st["last_s"] = wall_s
        st["ema_s"] = (wall_s if st["n"] == 1 else
                       EMA_DECAY * wall_s + (1 - EMA_DECAY) * st["ema_s"])
        data: Dict[str, Any] = {"bucket": b, "wall_s": round(wall_s, 6),
                                "tokens": tokens, "probed": probed}
        if loss is not None:
            data["loss"] = loss
        if per_stage_s is not None:
            data["per_stage_s"] = [round(float(x), 6) for x in per_stage_s]
        data.update(extra)
        if probed and per_stage_s is not None:
            self.record("probe", step, bucket=b,
                        per_stage_s=data["per_stage_s"])
        return self.record("step", step, **data)

    # -- reading -----------------------------------------------------------

    def ema(self, bucket: Any) -> float:
        """Smoothed step seconds for a bucket (0.0 if never seen)."""
        st = self._buckets.get(str(bucket))
        return float(st["ema_s"]) if st else 0.0

    def events(self, kind: Optional[str] = None) -> List[StepEvent]:
        if kind is None:
            return list(self._events)
        return [e for e in self._events if e.kind == kind]

    def snapshot(self) -> Dict[str, Any]:
        """The ``--stats-json``-ready summary (never the full ring)."""
        return {
            "name": self.name,
            "events": sum(self._by_kind.values()),
            "by_kind": dict(self._by_kind),
            "per_bucket": {
                b: {"ema_s": round(st["ema_s"], 6), "n": int(st["n"]),
                    "last_s": round(st["last_s"], 6)}
                for b, st in self._buckets.items()},
            "spill": str(self._spill_path) if self._spill_path else None,
            "dropped_spill_writes": self.dropped_spill_writes,
        }

    def close(self) -> None:
        if self._spill is not None:
            try:
                self._spill.close()
            finally:
                self._spill = None

    def __enter__(self) -> "StepTimeline":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
