"""Telemetry + calibration + online re-planning (the control plane).

Three layers, consumed bottom-up by the launch drivers:

* :mod:`~repro.telemetry.timeline` — ``StepTimeline``: ring-buffered event
  recorder with JSONL spill and always-on per-bucket EMA counters.
* :mod:`~repro.telemetry.calibrate` — ``CostCalibration``: robust fit of
  measured step times back onto ``core/costs.py`` terms, plus the CUSUM /
  length-mix drift detectors.
* :mod:`~repro.telemetry.replan` — ``ReplanController``: drift → fit →
  re-solve → hysteresis-gated hot-swap at a step boundary (with off-thread
  precompile, plan-lint rejection, and per-mesh calibration persistence).

Pure Python/NumPy — importable without JAX, like ``repro.core``.
"""

from .calibrate import (CostCalibration, Cusum, MixTracker, StepSample,
                        fit_calibration, fit_stage_slowdowns,
                        plan_components, predicted_work)
from .replan import ReplanConfig, ReplanController, ReplanDecision
from .stats_io import atomic_write_json, read_json, read_jsonl
from .timeline import StepEvent, StepTimeline

__all__ = [
    "CostCalibration", "Cusum", "MixTracker", "StepSample",
    "fit_calibration", "fit_stage_slowdowns", "plan_components",
    "predicted_work",
    "ReplanConfig", "ReplanController", "ReplanDecision",
    "atomic_write_json", "read_json", "read_jsonl",
    "StepEvent", "StepTimeline",
]
