"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state; the dry-run sets its
placeholder-device XLA flag before any jax import (launch/dryrun.py).

Axis semantics (DESIGN.md §2.2):
  pod   — data parallel across pods (gradient all-reduce, optionally int8)
  data  — pipeline stages d_p (stage-stacked params + ppermute)
  model — SP/FSDP/EP d_s (ulysses / allgather-KV, ZeRO-3, expert parallel)
"""

from __future__ import annotations

from typing import Optional, Tuple


def make_production_mesh(*, multi_pod: bool = False):
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary meshes for tests/elastic rescale."""
    import jax
    return jax.make_mesh(shape, axes)
