"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state; the dry-run sets its
placeholder-device XLA flag before any jax import (launch/dryrun.py).

Axis semantics (DESIGN.md §2.2):
  pod   — data parallel across pods (gradient all-reduce, optionally int8)
  data  — pipeline stages d_p (stage-stacked params + ppermute)
  model — SP/FSDP/EP d_s (ulysses / allgather-KV, ZeRO-3, expert parallel)
"""

from __future__ import annotations

import math
import os
from typing import Optional, Tuple

# XLA flags that let the compiled hand-off actually overlap: async
# collectives (on by default since XLA 2024; older jaxlibs spelled it
# --xla_gpu_enable_async_collectives, since removed) run the stream
# ppermute on its own stream, and the latency-hiding scheduler hoists its
# start above independent compute (runtime/executor.py issues the
# ppermute before the accumulator fold for exactly this reason). The
# triton fusion/gemm flags ride along from the same production recipe.
# Every flag here must parse on the pinned jaxlib — XLA aborts the
# process on unknown XLA_FLAGS entries.
LATENCY_HIDING_FLAGS = (
    "--xla_gpu_enable_triton_softmax_fusion=true "
    "--xla_gpu_triton_gemm_any=True "
    "--xla_gpu_enable_latency_hiding_scheduler=true "
    "--xla_gpu_enable_highest_priority_async_stream=true"
)

# set to any non-empty value to leave XLA_FLAGS alone
OPT_OUT_ENV = "REPRO_NO_LATENCY_HIDING"


def configure_latency_hiding(*, enable: Optional[bool] = None) -> bool:
    """Prepend the latency-hiding XLA flags to ``XLA_FLAGS``.

    Must run before the first ``import jax`` (XLA reads the env var once
    at backend init); launchers call it at the top of ``main()``. On by
    default; opt out with ``enable=False`` or by setting the
    ``REPRO_NO_LATENCY_HIDING`` env var. Idempotent — flags already
    present are not duplicated. Returns True when the flags are (now) in
    ``XLA_FLAGS``.
    """
    if enable is None:
        enable = not os.environ.get(OPT_OUT_ENV)
    if not enable:
        return False
    import sys
    if "jax" in sys.modules:
        import warnings
        warnings.warn(
            "configure_latency_hiding() called after jax was imported; "
            "XLA may already have initialized its backend and will ignore "
            "the new flags. Call it before the first jax import.",
            stacklevel=2)
    current = os.environ.get("XLA_FLAGS", "")
    if LATENCY_HIDING_FLAGS in current:
        return True
    os.environ["XLA_FLAGS"] = (LATENCY_HIDING_FLAGS + " " + current).strip()
    return True


def latency_hiding_active() -> bool:
    """True when the latency-hiding scheduler flag is in ``XLA_FLAGS``.

    Used by the program auditor: blocking collectives are only a hazard
    when the run claims to overlap them.
    """
    return ("--xla_gpu_enable_latency_hiding_scheduler"
            in os.environ.get("XLA_FLAGS", ""))


def make_production_mesh(*, multi_pod: bool = False):
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    have = jax.device_count()
    if have < need:
        raise ValueError(
            f"make_production_mesh(multi_pod={multi_pod}) needs {need} "
            f"devices for mesh {dict(zip(axes, shape))} but "
            f"jax.device_count() == {have}; use launch.mesh.make_mesh() "
            f"with a shape matching your slice, or (CPU dry-runs) raise "
            f"--xla_force_host_platform_device_count.")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary meshes for tests/elastic rescale."""
    import jax
    return jax.make_mesh(shape, axes)
