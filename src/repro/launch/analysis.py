"""Dry-run artifact analysis helpers (NO jax/env side effects — safe to
import from benchmarks)."""

from __future__ import annotations

import re

def collective_scan(hlo: str) -> dict:
    """Static per-occurrence operand bytes of every collective in the HLO.

    Ops inside while loops appear once; the roofline multiplies by the known
    scan trip counts (geometry), and the analytic model cross-checks.
    """
    dtype_bytes = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                   "s8": 1, "u8": 1, "pred": 1, "f64": 8, "s64": 8}
    pat = re.compile(
        r"(\w[\w.-]*) = (\w+)\[([\d,]*)\][^ ]* "
        r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
        r"[ (]")
    out: dict = {}
    for m in pat.finditer(hlo):
        dt, dims, kind = m.group(2), m.group(3), m.group(4)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        b = n * dtype_bytes.get(dt, 4)
        rec = out.setdefault(kind, {"count": 0, "bytes": 0})
        rec["count"] += 1
        rec["bytes"] += b
    return out


def compile_cache_report() -> dict:
    """Process-wide compile-cache statistics (live buckets, recompiles,
    warm hits served by persistent stores, hit rate, compile seconds) in
    the shape the train-loop log and benchmarks/run.py emit. Caches backed
    by a store carry a per-cache ``store`` block (entry count, on-disk
    bytes, stale/corrupt skips). Lazy import keeps this module jax-free at
    import time."""
    from repro.runtime.compile_cache import global_cache_stats
    return global_cache_stats()


def format_cache_report(stats: dict) -> str:
    """One-line human summary of :func:`compile_cache_report` output."""
    line = (f"buckets={stats['buckets_live']} "
            f"recompiles={stats['recompiles']} hits={stats['hits']} "
            f"warm_hits={stats.get('warm_hits', 0)} "
            f"hit_rate={stats['hit_rate']:.2%} "
            f"compile_s={stats['compile_seconds']:.2f}")
    stores = [c["store"] for c in stats.get("caches", {}).values()
              if "store" in c]
    if stores:
        line += (f" store_entries={sum(s['entries'] for s in stores)}"
                 f" store_mb="
                 f"{sum(s['size_bytes'] for s in stores) / 1e6:.2f}")
    return line


def telemetry_report(telemetry_dir: str) -> dict:
    """Digest a run's ``--telemetry-dir``: the timeline JSONL spill(s),
    the per-fingerprint calibration store, and the plan journal.

    Returns one dict per artifact class so tooling (and the CI replan job)
    can assert on it without re-parsing JSONL:

    * ``steps``: count, wall-time mean/p95 per bucket, probed-step count;
    * ``replan``: trigger/decision/swap counts and the swap steps;
    * ``compile``: cold/warm event counts from the cache's timeline hook;
    * ``calibrations``: the persisted store keyed by mesh fingerprint;
    * ``journal_steps``: entries in plans.jsonl (0 = journaling off).
    """
    import json
    from pathlib import Path

    d = Path(telemetry_dir)
    out: dict = {"dir": str(d), "steps": {"count": 0, "probed": 0},
                 "buckets": {}, "replan": {"triggers": {}, "decisions": {},
                                           "swaps": 0, "swap_steps": []},
                 "compile": {}, "calibrations": {}, "journal_steps": 0}
    walls: dict = {}
    for spill in sorted(d.glob("timeline-*.jsonl")):
        with open(spill) as f:
            for line in f:
                try:
                    ev = json.loads(line)
                except ValueError:
                    continue   # torn tail line of a live run
                kind = ev.get("kind")
                if kind == "step":
                    out["steps"]["count"] += 1
                    if ev.get("probed"):
                        out["steps"]["probed"] += 1
                    walls.setdefault(str(ev.get("bucket")), []).append(
                        float(ev.get("wall_s", 0.0)))
                elif kind == "compile":
                    evt = ev.get("event", "?")
                    out["compile"][evt] = out["compile"].get(evt, 0) + 1
                elif kind == "replan":
                    ph = ev.get("phase")
                    if ph == "trigger":
                        r = ev.get("reason", "?")
                        out["replan"]["triggers"][r] = \
                            out["replan"]["triggers"].get(r, 0) + 1
                    elif ph == "decision":
                        dec = ev.get("decision", "?")
                        out["replan"]["decisions"][dec] = \
                            out["replan"]["decisions"].get(dec, 0) + 1
                        if dec == "swap" and ev.get("mode") == "auto":
                            out["replan"]["swaps"] += 1
                            out["replan"]["swap_steps"].append(
                                int(ev.get("step", -1)))
    for bucket, ws in walls.items():
        ws = sorted(ws)
        out["buckets"][bucket] = {
            "steps": len(ws),
            "wall_s_mean": round(sum(ws) / len(ws), 6),
            "wall_s_p95": round(ws[min(len(ws) - 1,
                                       int(0.95 * len(ws)))], 6)}
    cal = d / "calibration.json"
    if cal.exists():
        try:
            out["calibrations"] = json.loads(cal.read_text())
        except ValueError:
            out["calibrations"] = {}
    journal = d / "plans.jsonl"
    if journal.exists():
        with open(journal) as f:
            out["journal_steps"] = sum(1 for line in f if line.strip())
    return out


def format_telemetry_report(rep: dict) -> str:
    """One-line human summary of :func:`telemetry_report` output."""
    cals = rep.get("calibrations", {})
    vers = {fp: c.get("version") for fp, c in cals.items()}
    return (f"steps={rep['steps']['count']} "
            f"(probed={rep['steps']['probed']}) "
            f"buckets={len(rep['buckets'])} "
            f"swaps={rep['replan']['swaps']}@{rep['replan']['swap_steps']} "
            f"triggers={rep['replan']['triggers']} "
            f"compile={rep['compile']} "
            f"calibrations={vers} journal={rep['journal_steps']}")


def analytic_collectives(cfg, geom, kind: str) -> dict:
    """Exact per-step collective volume (bytes moved per device) from the
    executor's own schedule — every collective in runtime/ is enumerated
    here with its trip count."""
    s = cfg.spec
    e = 2  # bf16
    d_s, d_p = geom.d_s, geom.d_p
    out = {"ici_bytes": 0.0, "p2p_bytes": 0.0, "dcn_bytes": 0.0}
    if kind in ("train", "prefill"):
        n, cap = geom.n_chunks, geom.cap
        # plans may run SP below the mesh degree: attention collectives span
        # d_eff-member sub-groups and compute replicates rep x (the batch
        # still rests sharded over the full axis, so ZeRO volumes keep d_s)
        d_eff = getattr(geom, "d_s_eff", 0) or d_s
        rep = d_s // d_eff
        cap_loc = cap // d_eff
        ticks = n + d_p - 1
        L_s = geom.layers_per_stage
        D = s.d_model
        per_layer = 0.0
        body = s.param_count() - s.vocab * D * (1 if s.tie_embeddings else 2)
        if s.n_experts:
            body -= s.n_layers * s.n_experts * 3 * D * s.d_ff_expert
        zero_layer_vol = e * body / s.n_layers * (d_s - 1) / d_s
        if getattr(geom, "zero3_mode", "per_tick") == "per_tick":
            # ZeRO-3 param gather per layer PER TICK (skips EP experts)
            per_layer += zero_layer_vol
        if not s.attn_free:
            if geom.policy == "ulysses":
                per_layer += e * 2 * (s.d_head_total + s.d_kv) * cap / d_eff
            elif geom.policy == "allgather_kv":
                per_layer += e * 2 * s.d_kv * cap * (d_eff - 1) / d_eff
            # "none": attention is token-local, no SP collective
        if s.ssm_state:
            # scan summaries all-gather within the d_eff-member sub-group
            per_layer += 4 * 2 * d_eff * s.inner * s.ssm_state
        if s.n_experts:
            # EP rides the full model axis on rep x replicated rows
            per_layer += e * 2 * cap * rep * D * (d_s - 1) / d_s
        per_tick = L_s * per_layer
        # vocab-parallel embed/CE gather over the FULL axis on rep x rows
        per_tick += e * cap * rep * D * (d_s - 1) / d_s  # embed psum_scatter
        per_tick += e * cap * rep * D * (d_s - 1) / d_s  # CE hidden all-gather
        out["ici_bytes"] = ticks * per_tick
        out["p2p_bytes"] = ticks * e * cap_loc * D    # stage ppermute
        if kind == "train":
            # every forward collective transposes once in backward
            # (all_gather <-> reduce_scatter, a2a <-> a2a); checkpointed
            # layers re-run their forward gathers during recompute.
            # stage-aware tables recompute a different depth per (stage,
            # chunk); the mean depth gives the exact aggregate re-gather
            # volume (collapses to l_ckpt for uniform geometries)
            tab = getattr(geom, "ckpt_table", None)
            if tab is not None:
                vals = [v for row in tab for v in row]
                l_ck = sum(vals) / max(len(vals), 1)
            else:
                l_ck = getattr(geom, "l_ckpt", 0)
            n_layers = max(s.n_layers, 1)
            remat_frac = min(1.0, l_ck * d_p / n_layers)
            out["ici_bytes"] *= (2.0 + remat_frac)
            out["dcn_bytes"] = 2 * s.param_count() * 4 / max(d_s * d_p, 1)
        if getattr(geom, "zero3_mode", "per_tick") == "per_step":
            # one stage-wide gather (+ grad reduce-scatter in train)
            once = L_s * zero_layer_vol * (2.0 if kind == "train" else 1.0)
            out["ici_bytes"] += once
    else:  # decode
        nm, bm = geom.n_micro, geom.bm
        ticks = nm + d_p - 1
        L_s = geom.layers_per_stage
        D = s.d_model
        per_layer = e * (s.param_count() / s.n_layers) * (d_s - 1) / d_s
        per_layer += 4 * bm * s.n_heads * (2 + s.head_dim)  # LSE psum merge
        per_tick = L_s * per_layer + e * bm * D * 2
        out["ici_bytes"] = ticks * per_tick
        out["p2p_bytes"] = ticks * e * bm * D
    return out


