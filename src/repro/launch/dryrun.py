import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede every other import (jax locks the device count on first
# init). The 512 placeholder CPU devices exist only in this process; tests
# and benches see the single real device.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. runs the InfiniPipe PLANNER (host-side) on the shape's workload to get
     the chunk geometry + the ILP checkpointing level — the same path real
     training takes;
  2. builds the jit'd step (train_step / prefill / decode) for the
     production mesh and calls ``.lower().compile()`` on ShapeDtypeStructs
     (no allocation);
  3. records ``memory_analysis()`` / ``cost_analysis()`` + an HLO collective
     scan + analytic collective volumes into a JSON cache that
     benchmarks/roofline.py consumes.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k
  python -m repro.launch.dryrun --all [--multipod] [--out runs/dryrun]
"""

import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import numpy as np


def _cost_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` returns a dict on recent jax and a
    one-element list of dicts on 0.4.x — normalize to a flat dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


def _cell_skip_reason(cfg, shape) -> str:
    if shape.needs_subquadratic and not cfg.supports_long_decode:
        return ("skipped: pure full-attention arch at 500K decode "
                "(DESIGN.md §4.1)")
    return ""


from repro.launch.analysis import analytic_collectives, collective_scan
from repro.runtime.compile_cache import CompileCache

# one executable per geometry bucket across cells: identical buckets
# (e.g. two shapes landing on the same plan geometry) compile once.
# Bounded: compiled 256+-device programs are large, and cross-cell hits
# are the exception — don't retain the whole sweep in host memory.
# Cost-aware eviction keeps the expensive-to-recompile cells resident.
_CELL_CACHE = CompileCache(name="dryrun-cell", capacity=2, eviction="cost")


def attach_cell_store(cache_dir: str) -> None:
    """Back the cell cache with a persistent store: re-running a sweep
    (or resuming an interrupted one) warm-starts compiled cells. The cell
    key already carries arch/shape/mesh, so the fingerprint only pins the
    process topology (jax version, backend, device count)."""
    from repro.runtime.cache_store import CacheStore, store_fingerprint
    _CELL_CACHE.store = CacheStore(cache_dir, store_fingerprint(),
                                   log=print)


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             remat_override=None, note: str = "",
             zero3_mode: str = "per_tick",
             ckpt_policy: str = "stage-aware",
             sp_policy: str = "auto", sp_degree: int = 0) -> dict:
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.configs import SHAPES, get_arch
    from repro.core import ClusterSpec, CostModel, PlannerConfig, plan_batch
    from repro.launch.mesh import make_production_mesh
    from repro.runtime import TrainStepBuilder, batch_struct, make_geometry
    from repro.runtime.serve_step import (decode_state_specs,
                                          decode_state_struct,
                                          decode_step_fn,
                                          make_decode_geometry)
    from repro.runtime.sharding import (mesh_axis_names, shard_dim_tree,
                                        shard_map_compat)
    from repro.runtime.pipeline import pipeline_loss_fn

    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    t0 = time.perf_counter()
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16", "note": note}
    reason = _cell_skip_reason(cfg, shape)
    if reason:
        rec["status"] = "skipped"
        rec["reason"] = reason
        return rec
    if cfg.spec.is_encoder_decoder and shape.kind == "decode":
        pass  # decoder-side decode is supported

    mesh = make_production_mesh(multi_pod=multi_pod)
    pod, data, model = mesh_axis_names(mesh)
    n_pods = mesh.shape[pod] if pod else 1
    d_p, d_s = mesh.shape[data], mesh.shape[model]
    per_pod_batch = max(1, shape.global_batch // n_pods)

    if cfg.spec.is_encoder_decoder and shape.kind in ("train", "prefill"):
        return _run_encdec_cell(rec, cfg, shape, mesh, per_pod_batch, t0,
                                ckpt_policy=ckpt_policy)

    if shape.kind in ("train", "prefill"):
        cm = CostModel(cfg.spec, ClusterSpec(d_p=d_p, d_s=d_s,
                                             n_pods=n_pods))
        lengths = [shape.seq_len] * per_pod_batch
        remat_mode = ("stage_aware" if ckpt_policy == "stage-aware"
                      else "uniform")
        # prefill cells pin the full model axis: the token-sharded greedy
        # fold assumes every device owns a distinct token shard, which
        # sub-degree replication (d_s_eff < d_s) breaks — the planner's
        # SP sweep only applies to train cells.
        cell_sp_degree = d_s if shape.kind == "prefill" else sp_degree
        plan = plan_batch(cm, lengths, PlannerConfig(remat_mode=remat_mode,
                                                     sp_policy=sp_policy,
                                                     sp_degree=cell_sp_degree))
        chunks = [c for p in plan.pipelines for c in p.chunks]
        cap = ((plan.chunk_capacity + d_s - 1) // d_s) * d_s
        max_ctx = max((c.context for c in chunks), default=0)
        ctx_cap = max_ctx + cap
        # per-stage remat axis of the sweep: an explicit --remat override
        # forces a uniform depth; otherwise the plan's canonical policy
        # (stage-aware => the per-(stage, chunk) vector) is baked in
        if remat_override is not None:
            l_ckpt, table, digest = remat_override, None, \
                f"u{remat_override}"
        else:
            l_ckpt, table, digest = plan.ckpt_policy(len(chunks))
        geom = make_geometry(cfg, mesh, n_chunks=len(chunks), cap=cap,
                             ctx_cap=ctx_cap, l_ckpt=l_ckpt,
                             zero3_mode=zero3_mode,
                             schedule=plan.schedule,
                             v_stages=plan.v_stages,
                             ckpt_table=table,
                             sp_policy=(plan.sp.policy
                                        if plan.sp is not None else None),
                             sp_degree=(plan.sp.d_s_eff
                                        if plan.sp is not None else 0))
        rec["plan"] = {"K": plan.k_split, "n_chunks": len(chunks),
                       "cap": cap, "ctx_cap": ctx_cap, "l_ckpt": l_ckpt,
                       "ckpt_policy": ckpt_policy, "ckpt_digest": digest,
                       "l_ckpt_stage": plan.ckpt_per_stage_max(),
                       "schedule": plan.schedule, "v_stages": plan.v_stages,
                       "sp_policy": (plan.sp.policy
                                     if plan.sp is not None else "auto"),
                       "d_s_eff": (plan.sp.d_s_eff
                                   if plan.sp is not None else d_s),
                       "pipelines": len(plan.pipelines),
                       "est_time_s": plan.est_total_time,
                       "solve_time_s": plan.solve_time}
        builder = TrainStepBuilder(cfg, mesh, geom)
        params_shape = builder.abstract_params()
        pspecs, ospecs, bspecs = builder.specs(params_shape)
        bstruct = batch_struct(geom, n_pods)
        if shape.kind == "train":
            step = builder.build(params_shape)
            opt_shape = jax.eval_shape(
                lambda p: __import__("repro.optim", fromlist=["x"]
                                     ).init_opt_state(p), params_shape)
            lowered = step.lower(params_shape, opt_shape, None, bstruct)
        else:
            shard_dims = shard_dim_tree(params_shape["stages"], d_s)
            fn = pipeline_loss_fn(cfg, geom, shard_dims, pod_axis=pod,
                                  data_axis=data, model_axis=model,
                                  mode="prefill")
            def prefill(params, batch):
                if pod:
                    batch = jax.tree.map(lambda x: x[0], batch)
                return fn(params, batch)
            mapped = shard_map_compat(prefill, mesh=mesh,
                                   in_specs=(pspecs, bspecs),
                                   out_specs=(P(None, model),
                                              _ctx_specs(cfg, geom,
                                                         pod, data, model)),
                                   check_vma=False)
            lowered = jax.jit(mapped).lower(params_shape, bstruct)
    else:  # decode
        geom = make_decode_geometry(cfg, mesh, batch_per_pod=per_pod_batch,
                                    cache_len=shape.seq_len)
        rec["plan"] = {"n_micro": geom.n_micro, "bm": geom.bm,
                       "cache_len": geom.cache_len}
        if cfg.spec.is_encoder_decoder:
            from repro.models import EncDecLM
            from repro.runtime.encdec_pipeline import \
                prepare_encdec_decode_params
            from repro.runtime.train_step import param_pspecs
            raw_shape = jax.eval_shape(
                lambda k: EncDecLM(cfg).init(k, jnp.float32),
                jax.random.PRNGKey(0))
            params_shape = jax.eval_shape(
                lambda r: prepare_encdec_decode_params(cfg, r, d_p, d_s),
                raw_shape)
            pspecs = param_pspecs(cfg, params_shape, mesh)
        else:
            builder = TrainStepBuilder(cfg, mesh, make_geometry(
                cfg, mesh, n_chunks=1, cap=d_s, ctx_cap=d_s))
            params_shape = builder.abstract_params()
            pspecs, _, _ = builder.specs(params_shape)
        shard_dims = shard_dim_tree(params_shape["stages"], d_s)
        fn = decode_step_fn(cfg, geom, shard_dims, pod_axis=pod,
                            data_axis=data, model_axis=model)
        sspecs = decode_state_specs(cfg, geom, pod=pod, data=data,
                                    model=model)
        mapped = shard_map_compat(fn, mesh=mesh,
                               in_specs=(pspecs, sspecs),
                               out_specs=(P(), sspecs),
                               check_vma=False)
        sstruct = decode_state_struct(cfg, geom, n_pods)
        lowered = jax.jit(mapped, donate_argnums=(1,)).lower(
            params_shape, sstruct)

    t_lower = time.perf_counter()
    compiled = _CELL_CACHE.get(
        (arch, shape.kind, geom, zero3_mode, rec["mesh"]), lowered.compile)
    t_compile = time.perf_counter()

    mem = compiled.memory_analysis()
    cost = _cost_dict(compiled)
    hlo = compiled.as_text()
    rec.update({
        "status": "ok",
        "lower_s": round(t_lower - t0, 2),
        "compile_s": round(t_compile - t_lower, 2),
        "memory_analysis": {
            k: int(getattr(mem, k, 0)) for k in
            ("temp_size_in_bytes", "argument_size_in_bytes",
             "output_size_in_bytes", "alias_size_in_bytes",
             "generated_code_size_in_bytes")},
        "cost_analysis": {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float))
                          and k in ("flops", "bytes accessed",
                                    "bytes accessed0{}", "transcendentals",
                                    "utilization operand 0 {}")},
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "hlo_collectives_static": collective_scan(hlo),
        "n_devices": int(np.prod(list(mesh.shape.values()))),
        "compile_cache": _CELL_CACHE.stats.as_dict(),
    })
    kind = shape.kind
    gg = geom
    rec["analytic_collectives"] = analytic_collectives(cfg, gg, kind)
    rec["geometry"] = {
        k: getattr(gg, k) for k in
        (("n_chunks", "cap", "ctx_cap", "l_ckpt", "layers_per_stage",
          "policy", "d_s_eff", "zero3_mode")
         if kind in ("train", "prefill") else
         ("n_micro", "cache_len", "layers_per_stage"))}
    return rec


def _run_encdec_cell(rec, cfg, shape, mesh, per_pod_batch, t0,
                     ckpt_policy: str = "stage-aware"):
    """seamless-m4t train/prefill: the stage-split enc-dec pipeline."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.core import ClusterSpec, CostModel, PlannerConfig, plan_batch
    from repro.models import EncDecLM
    from repro.optim import AdamWConfig, adamw_update, init_opt_state
    from repro.runtime.encdec_pipeline import (encdec_batch_struct,
                                               encdec_pipeline_loss_fn,
                                               make_encdec_geometry,
                                               prepare_encdec_params)
    from repro.runtime.sharding import (batch_specs, mesh_axis_names,
                                        shard_dim_tree, shard_map_compat,
                                        stage_param_specs)
    import time as _time

    pod, data, model = mesh_axis_names(mesh)
    n_pods = mesh.shape[pod] if pod else 1
    d_p, d_s = mesh.shape[data], mesh.shape[model]
    cm = CostModel(cfg.spec, ClusterSpec(d_p=d_p, d_s=d_s, n_pods=n_pods))
    lengths = [shape.seq_len] * per_pod_batch
    # encoder is pack-only: force K=1 (DESIGN.md §4 — splitting a
    # bidirectional encoder changes the math); decoder chunks follow.
    remat_mode = ("stage_aware" if ckpt_policy == "stage-aware"
                  else "uniform")
    # v_stages=1 pin: the grouped enc+dec stacking has no interleaved
    # placement, so restrict the schedule pick to single-virtual-stage
    # backends — and actually RUN the pick (the compiled cell must be the
    # schedule the recorded plan stats describe)
    # enc-dec geometry does not carry the SP axis — pin the plan to the
    # full model axis so its recorded stats match the compiled cell.
    plan = plan_batch(cm, lengths, PlannerConfig(fixed_k=1,
                                                 remat_mode=remat_mode,
                                                 v_stages=1,
                                                 sp_degree=d_s))
    chunks = [c for p in plan.pipelines for c in p.chunks]
    cap = ((plan.chunk_capacity + d_s - 1) // d_s) * d_s
    l_max, table, digest = plan.ckpt_policy(len(chunks))
    geom = make_encdec_geometry(cfg, mesh, n_chunks=len(chunks), cap=cap,
                                cap_enc=cap, ctx_cap=cap + d_s,
                                l_ckpt=l_max, ckpt_table=table,
                                schedule=plan.schedule)
    rec["plan"] = {"K": plan.k_split, "n_chunks": len(chunks), "cap": cap,
                   "schedule": plan.schedule,
                   "ckpt_policy": ckpt_policy, "ckpt_digest": digest,
                   "l_ckpt_stage": plan.ckpt_per_stage_max()}

    raw_shape = jax.eval_shape(
        lambda k: EncDecLM(cfg).init(k, jnp.float32), jax.random.PRNGKey(0))
    params_shape = jax.eval_shape(
        lambda r: prepare_encdec_params(cfg, r, geom), raw_shape)
    pspecs = {
        "stages": stage_param_specs(params_shape["stages"], d_s, pod=pod,
                                    data=data, model=model),
        "embed": P(model, None),
        "enc_norm": P(model) if cfg.spec.d_model % d_s == 0 else P(),
        "final_norm": P(model) if cfg.spec.d_model % d_s == 0 else P(),
    }
    shard_dims = shard_dim_tree(params_shape["stages"], d_s)
    bstruct = encdec_batch_struct(geom, cfg, n_pods)
    bspecs = batch_specs(bstruct, pod=pod, model=model)
    fn = encdec_pipeline_loss_fn(cfg, geom, shard_dims, pod_axis=pod,
                                 data_axis=data, model_axis=model)

    if shape.kind == "train":
        ospecs = {"master": pspecs, "m": pspecs, "v": pspecs, "step": P()}
        acfg = AdamWConfig()

        def step(params, opt, batch):
            if pod:
                batch = jax.tree.map(lambda x: x[0], batch)

            def obj(p):
                loss, n = fn(p, batch)
                return loss, n
            (loss, n), grads = jax.value_and_grad(obj, has_aux=True)(params)
            for name in ("embed", "enc_norm", "final_norm"):
                grads[name] = jax.lax.psum(grads[name], data)
            if pod:
                grads = jax.lax.psum(grads, pod)
                loss = jax.lax.psum(loss, pod)
                n = jax.lax.psum(n, pod)
            new_p, new_o, _ = adamw_update(acfg, params, grads, opt,
                                           grad_scale=1.0 / jnp.maximum(n, 1),
                                           gnorm=jnp.float32(1.0))
            return new_p, new_o, loss / jnp.maximum(n, 1)

        mapped = shard_map_compat(step, mesh=mesh,
                               in_specs=(pspecs, ospecs, bspecs),
                               out_specs=(pspecs, ospecs, P()),
                               check_vma=False)
        opt_shape = jax.eval_shape(init_opt_state, params_shape)
        lowered = jax.jit(mapped, donate_argnums=(0, 1)).lower(
            params_shape, opt_shape, bstruct)
    else:
        def fwd(params, batch):
            if pod:
                batch = jax.tree.map(lambda x: x[0], batch)
            return fn(params, batch)
        mapped = shard_map_compat(fwd, mesh=mesh, in_specs=(pspecs, bspecs),
                               out_specs=(P(), P()), check_vma=False)
        lowered = jax.jit(mapped).lower(params_shape, bstruct)

    t_lower = _time.perf_counter()
    compiled = _CELL_CACHE.get(
        (rec["arch"], shape.kind, geom, "encdec", rec["mesh"]),
        lowered.compile)
    t_compile = _time.perf_counter()
    mem = compiled.memory_analysis()
    cost = _cost_dict(compiled)
    hlo = compiled.as_text()
    import numpy as _np
    rec.update({
        "status": "ok",
        "lower_s": round(t_lower - t0, 2),
        "compile_s": round(t_compile - t_lower, 2),
        "memory_analysis": {
            k: int(getattr(mem, k, 0)) for k in
            ("temp_size_in_bytes", "argument_size_in_bytes",
             "output_size_in_bytes", "alias_size_in_bytes",
             "generated_code_size_in_bytes")},
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "hlo_collectives_static": collective_scan(hlo),
        "n_devices": int(_np.prod(list(mesh.shape.values()))),
        "compile_cache": _CELL_CACHE.stats.as_dict(),
        "analytic_collectives": analytic_collectives(cfg, geom, shape.kind),
        "geometry": {"n_chunks": geom.n_chunks, "cap": geom.cap,
                     "cap_enc": geom.cap_enc,
                     "enc_stages": geom.enc_stages,
                     "layers_per_stage": geom.layers_per_stage,
                     "policy": geom.policy, "l_ckpt": geom.l_ckpt},
    })
    return rec


def _ctx_specs(cfg, geom, pod, data, model):
    """out_specs for the prefill context buffers: [L_s, ...] per stage =>
    stage dim over "data"; ulysses KV is head-sharded over "model"; the
    allgather_kv buffers and SSM state are replicated over "model"; the conv
    tail is rank-local (per-shard trailing rows).

    Prefill cells always run at d_s_eff == d_s (run_cell pins the planner),
    so the ulysses head dim is evenly sharded over the full model axis —
    sub-degree would leave it replicated within contiguous replica groups,
    which these specs do not express."""
    from jax.sharding import PartitionSpec as P
    from repro.models import LayerCtx
    s = cfg.spec
    k = v = hh = tail = None
    if not s.attn_free:
        if geom.policy == "ulysses":
            k = P(data, None, model, None)
            v = P(data, None, model, None)
        else:
            k = P(data, None, None, None)
            v = P(data, None, None, None)
    if s.ssm_state > 0:
        hh = P(data, None, None)
        # the conv tail is rank-local (each rank's trailing rows); the
        # dry-run output takes one representative — decode resharding
        # recomputes it from the cache anyway.
        tail = P(data, None, None)
    return LayerCtx(k, v, hh, tail)


CELLS = None


def all_cells():
    from repro.configs import SHAPES, arch_names
    cells = []
    for arch in arch_names():
        for shape in SHAPES:
            cells.append((arch, shape))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="runs/dryrun")
    ap.add_argument("--remat", type=int, default=None)
    ap.add_argument("--zero3", default="per_tick",
                    choices=["per_tick", "per_step"])
    ap.add_argument("--ckpt-policy", default="stage-aware",
                    choices=["stage-aware", "uniform"],
                    help="per-stage remat axis of the sweep: bake the "
                         "ILP's per-(stage, chunk) vector into each cell "
                         "(stage-aware) or its collapsed max (uniform)")
    ap.add_argument("--sp-policy", default="auto",
                    choices=["auto", "none", "ulysses", "allgather_kv"],
                    help="pin the plan's SP policy (train cells only; "
                         "prefill always runs the full model axis)")
    ap.add_argument("--sp-degree", type=int, default=0,
                    help="pin the effective SP degree (0 = planner-chosen; "
                         "must divide the model-axis size)")
    ap.add_argument("--note", default="")
    ap.add_argument("--cache-dir", default="",
                    help="persistent compile-cache directory shared across "
                         "sweep runs (warm-starts recompiled cells)")
    ap.add_argument("--lint", default="off",
                    choices=["off", "warn", "error"],
                    help="program auditor on each cell's cold compile "
                         "(HLO text tier). Default off: auditing re-"
                         "renders the 256+-device HLO text per cell; "
                         "the offline `python -m repro.lint` CLI audits "
                         "the same programs with full jaxpr visibility")
    args = ap.parse_args()

    if args.lint != "off":
        from repro.launch.mesh import latency_hiding_active
        from repro.lint import make_cache_lint
        _CELL_CACHE.lint = make_cache_lint(
            args.lint, log=print, latency_hiding=latency_hiding_active())
    if args.cache_dir:
        attach_cell_store(args.cache_dir)
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    cells = all_cells() if args.all else [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multipod]

    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
            if args.note:
                tag += f"__{args.note}"
            path = out_dir / f"{tag}.json"
            if path.exists():
                print(f"[skip-cached] {tag}")
                continue
            print(f"[run] {tag}", flush=True)
            try:
                rec = run_cell(arch, shape, mp, out_dir,
                               remat_override=args.remat, note=args.note,
                               zero3_mode=args.zero3,
                               ckpt_policy=args.ckpt_policy,
                               sp_policy=args.sp_policy,
                               sp_degree=args.sp_degree)
            except Exception as e:  # noqa: BLE001
                rec = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if mp else "16x16",
                       "status": "error", "error": repr(e),
                       "traceback": traceback.format_exc()[-4000:]}
                failures += 1
            # atomic: a concurrent sweep aggregator never reads a torn cell
            from repro.telemetry import atomic_write_json
            atomic_write_json(path, rec)
            print(f"  -> {rec['status']}"
                  + (f" compile={rec.get('compile_s')}s"
                     f" flops={rec.get('flops', 0):.3e}"
                     if rec["status"] == "ok" else
                     f" {rec.get('reason', rec.get('error', ''))[:200]}"),
                  flush=True)
    print(f"[compile-cache] {_CELL_CACHE.stats.summary()}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
