"""End-to-end EPP training driver (the paper's Fig. 4 runtime).

Disaggregated solver/executor: while step i executes on devices, the host
plans batch i+1 (the planner is pure NumPy). Plans are bucketed so compiled
executables are reused; fault tolerance comes from CheckpointManager
(restart) + StragglerMonitor (replanning with per-stage slowdowns).

Runs end-to-end on CPU at reduced scale (examples/quickstart.py) and lowers
unchanged for the production meshes.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \\
      --reduced --steps 20 --batch 16 --context 2048 --mesh 2x4
"""

from __future__ import annotations

import argparse
import json
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Optional

import numpy as np


@dataclass
class TrainLoopConfig:
    steps: int = 50
    global_batch: int = 16
    context: int = 2048
    dataset: str = "github"
    seed: int = 0
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 20
    resume: bool = False
    # persistent compile-cache directory. None derives a sibling of
    # ckpt_dir (<ckpt_dir>_compile_cache) when checkpointing is on; ""
    # disables the store (in-memory cache only).
    cache_dir: Optional[str] = None
    # cache-store gc at startup: drop entries not loaded within
    # cache_gc_age_s seconds / shrink the store to cache_gc_bytes payload
    # bytes. None disables the corresponding limit.
    cache_gc_age_s: Optional[float] = None
    cache_gc_bytes: Optional[int] = None
    bucket_rounding: int = 256
    compute_dtype: str = "bfloat16"
    # pipeline schedule backend (core/schedule.py registry name); None lets
    # the planner's bubble model pick on the bootstrap plan. Either way the
    # choice is pinned for the whole run — interleaved stacking bakes the
    # virtual-stage count into the parameter layout.
    schedule: Optional[str] = None
    v_stages: int = 0                 # 0 => auto (interleaved only)
    # remat policy the compiled step applies: "stage-aware" threads the
    # ILP's per-(stage, chunk) checkpoint vector into the executor
    # (encoder/decoder stages and hot/cold chunks remat differently);
    # "uniform" collapses it to one max depth (the pre-vector behavior).
    # Parity is guaranteed either way — remat never changes the math
    # (tests/test_remat_parity.py). Tradeoff: the vector is part of the
    # compiled step's identity, so memory-pressured workloads whose solved
    # tables vary step to step fragment the compile cache one bucket per
    # distinct table — pass "uniform" to maximize executable reuse
    # (workloads whose table solves to a constant, incl. the common
    # all-zero case, collapse to the uniform digest automatically).
    ckpt_policy: str = "stage-aware"
    # zero-bubble B/W backward split: "auto" follows the schedule backend
    # (split for zero-bubble-h1, fused otherwise), "on"/"off" force it.
    # Parity is guaranteed either way (tests/test_split_backward.py).
    split_bwd: str = "auto"
    # program auditor (repro.lint): "warn" logs findings from the plan
    # and program passes on every cold compile, "error" aborts before a
    # hazardous executable enters the cache, "off" skips the audit.
    lint: str = "warn"
    # sequence-parallel axis pins forwarded to the planner: "auto" lets
    # the solver choose (policy, d_s_eff) jointly with chunking per plan;
    # a policy name and/or a degree (0 = auto) pins that coordinate. Pins
    # are part of the plan, so they get their own bucket-key / cache-store
    # identity — no cross-SP executable aliasing.
    sp_policy: str = "auto"
    sp_degree: int = 0
    # --- online re-planning (src/repro/telemetry/) ---
    # "off" = static cost model (legacy behavior); "observe" = collect
    # telemetry, fit calibrations and log would-be swaps without touching
    # the plans (numerics provably unchanged); "auto" = close the loop:
    # per-step solves use the active calibration and drift-triggered
    # re-plans hot-swap at step boundaries (hysteresis + cooldown +
    # plan-lint gated, fresh buckets precompiled off-thread).
    replan: str = "off"
    telemetry_dir: Optional[str] = None  # JSONL spill + plan journal +
    #                                      per-mesh calibration persistence
    # every Nth step is a probe: jax.block_until_ready brackets the step
    # so its wall time excludes async dispatch, and a per-stage breakdown
    # is attributed (0 = never probe; EMA counters stay always-on)
    probe_every: int = 0
    replan_min_win: float = 0.05
    replan_cooldown: int = 8
    replan_min_samples: int = 4
    # re-plan jobs on a background thread (the training loop never blocks
    # on the fit/ILP/precompile); False runs them inline — deterministic
    # swap timing for tests
    replan_background: bool = True
    # deterministic telemetry-only straggler injection for tests/CI:
    # "STAGE:FACTOR[,...][@START]", e.g. "2:2.5@3" (ft.StragglerInjector)
    inject_straggler: str = ""
    # two-phase drifting traces: switch the length-mix preset to dataset2
    # at step drift_at (0 = never) — the CI replan job's short-uniform ->
    # long-skewed trace
    dataset2: Optional[str] = None
    context2: int = 0              # 0 = keep --context across the drift
    drift_at: int = 0
    # replay the per-step plans from this JSONL journal instead of solving
    # (written to <telemetry_dir>/plans.jsonl by any telemetry-enabled
    # run): the pinned-plan baseline the CI job compares bitwise against
    plan_journal: Optional[str] = None


def train(cfg_arch, mesh, loop: TrainLoopConfig, *, log=print):
    import jax
    import jax.numpy as jnp

    from repro.ckpt import CheckpointManager
    from repro.core import (ClusterSpec, CostModel, ExecutionPlan,
                            PlannerConfig, plan_batch)
    from repro.data import materialize_plan, sample_corpus_batch
    from repro.ft import (StragglerInjector, StragglerMonitor,
                          replan_costmodel)
    from repro.launch.mesh import latency_hiding_active
    from repro.lint import make_cache_lint, run_plan_checks
    from repro.optim import init_opt_state
    from repro.runtime import (CacheStore, CompileCache, TrainStepBuilder,
                               batch_struct, make_geometry,
                               store_fingerprint)
    from repro.runtime.sharding import mesh_axis_names
    from repro.telemetry import (ReplanConfig, ReplanController,
                                 StepTimeline, read_jsonl)

    pod, data, model = mesh_axis_names(mesh)
    n_pods = mesh.shape[pod] if pod else 1
    d_p, d_s = mesh.shape[data], mesh.shape[model]
    dtype = jnp.bfloat16 if loop.compute_dtype == "bfloat16" else jnp.float32

    base_cm = CostModel(cfg_arch.spec, ClusterSpec(d_p=d_p, d_s=d_s,
                                                   n_pods=n_pods))
    monitor = StragglerMonitor(d_p=d_p)
    mgr = CheckpointManager(loop.ckpt_dir) if loop.ckpt_dir else None

    # persistent compile cache: warm-start buckets across (elastic)
    # restarts. Entries are fingerprinted by topology + config so a
    # resharded mesh or changed arch falls back to cold compile.
    cache_dir = loop.cache_dir
    if cache_dir is None and loop.ckpt_dir:
        p = Path(loop.ckpt_dir)
        cache_dir = str(p.with_name(p.name + "_compile_cache"))
    store = None
    gc_report = None
    if cache_dir:
        store = CacheStore(cache_dir,
                           store_fingerprint(mesh, spec=cfg_arch.spec,
                                             compute_dtype=dtype),
                           log=log)
        # age/size-budget gc before the run touches the store: stale
        # topologies and cold buckets age out, recently-loaded entries
        # survive (load() refreshes their mtime)
        gc_report = store.gc(max_age_s=loop.cache_gc_age_s,
                             max_bytes=loop.cache_gc_bytes)
    # program auditor: every cold compile is linted once; the build
    # closure stashes the Lowered's StableHLO so the donation pass can see
    # buffer-donor markers the compiled HLO no longer carries
    lint_stash = {}
    lint_hook = make_cache_lint(loop.lint, log=log,
                                latency_hiding=latency_hiding_active(),
                                stash=lint_stash)
    step_cache = CompileCache(name="train-step", log=log, store=store,
                              lint=lint_hook)
    params = opt = None
    start_step = 0

    # --- telemetry: collection + (optionally) the re-planning loop ---
    timeline = StepTimeline(spill_dir=loop.telemetry_dir, name="train")
    injector = (StragglerInjector.parse(loop.inject_straggler, d_p,
                                        seed=loop.seed)
                if loop.inject_straggler else None)
    # plan journal: every telemetry-enabled run records the plan it
    # EXECUTED each step; a journal replay run re-executes exactly those
    # plans (--plan-journal), which is how CI proves the control plane is
    # numerically non-intrusive (bitwise-equal losses)
    journal_out = None
    if loop.telemetry_dir:
        jp = Path(loop.telemetry_dir) / "plans.jsonl"
        jp.parent.mkdir(parents=True, exist_ok=True)
        journal_out = open(jp, "w", buffering=1)
    journal_in = {}
    if loop.plan_journal:
        for rec in read_jsonl(loop.plan_journal):
            journal_in[int(rec["step"])] = ExecutionPlan.loads(rec["plan"])
        if not journal_in:
            raise ValueError(f"--plan-journal {loop.plan_journal} holds no "
                             f"replayable plans")
        log(f"[journal] replaying {len(journal_in)} plans from "
            f"{loop.plan_journal}")

    # schedule backend is pinned after the bootstrap plan: interleaved
    # stacking bakes v_stages into the parameter layout, so mid-run
    # schedule switches would scramble live training state
    pinned = {"schedule": loop.schedule, "v_stages": loop.v_stages}
    remat_mode = ("stage_aware" if loop.ckpt_policy == "stage-aware"
                  else "uniform")

    def solve(cm, lengths):
        plan = plan_batch(cm, lengths,
                          PlannerConfig(bucket_rounding=loop.bucket_rounding,
                                        schedule=pinned["schedule"],
                                        v_stages=pinned["v_stages"],
                                        remat_mode=remat_mode,
                                        sp_policy=loop.sp_policy,
                                        sp_degree=loop.sp_degree))
        pinned["schedule"], pinned["v_stages"] = plan.schedule, plan.v_stages
        return plan

    def bucket_of(plan):
        return str(plan.bucket_key(d_s, split_bwd=loop.split_bwd,
                                   dtype=loop.compute_dtype))

    def plan_lint(plan):
        """Plan-invariant errors for a re-plan candidate: a hazardous
        re-planned program is rejected BEFORE the swap."""
        if loop.lint == "off":
            return []
        prep = run_plan_checks(plan, d_s, d_p, model=cfg_arch.spec,
                               key_kwargs={"split_bwd": loop.split_bwd,
                                           "dtype": loop.compute_dtype})
        return [str(e) for e in prep.errors]

    def resolve_incumbent(cm, lengths, inc):
        """The hysteresis strawman: this batch re-planned under the
        incumbent's bucket — capacity AND sp policy pinned, else the
        "held" solve silently makes the candidate's own move and the
        comparison degenerates. Does not touch ``pinned`` (it is a
        what-if, never executed)."""
        key = inc.bucket_key(d_s, split_bwd=loop.split_bwd,
                             dtype=loop.compute_dtype)
        return plan_batch(cm, lengths,
                          PlannerConfig(bucket_rounding=loop.bucket_rounding,
                                        schedule=pinned["schedule"],
                                        v_stages=pinned["v_stages"],
                                        remat_mode=remat_mode,
                                        sp_policy=key.sp_policy,
                                        sp_degree=key.d_s_eff,
                                        token_capacity=key.cap))

    controller = None
    if loop.replan in ("observe", "auto") and not journal_in:
        controller = ReplanController(
            base_cm,
            ReplanConfig(mode=loop.replan, min_win=loop.replan_min_win,
                         cooldown_steps=loop.replan_cooldown,
                         min_samples=loop.replan_min_samples,
                         background=loop.replan_background),
            solve, bucket_of, lint=plan_lint,
            resolve_incumbent=resolve_incumbent,
            precompile=lambda p: get_step(p),
            timeline=timeline, telemetry_dir=loop.telemetry_dir,
            fingerprint=(f"{d_p}x{d_s}:{cfg_arch.spec.name}"),
            log=log)

    def mix_for(step: int):
        if loop.drift_at and step >= loop.drift_at and (
                loop.dataset2 or loop.context2):
            return (loop.dataset2 or loop.dataset,
                    loop.context2 or loop.context)
        return loop.dataset, loop.context

    def plan_for(step: int):
        ds, ctx = mix_for(step)
        corpus = sample_corpus_batch(ds, loop.global_batch,
                                     ctx, cfg_arch.spec.vocab,
                                     seed=loop.seed + step)
        if journal_in:
            # replay: past the journal's end (the final overlap solve) the
            # last journaled plan stands in — it is never executed
            plan = journal_in.get(step) or journal_in[max(journal_in)]
            pinned["schedule"], pinned["v_stages"] = (plan.schedule,
                                                      plan.v_stages)
            return plan, corpus
        cm = (controller.cost_model() if controller is not None
              else base_cm)
        cm = replan_costmodel(cm, monitor)
        lengths = [len(v) for v in corpus.values()]
        return solve(cm, lengths), corpus

    def get_step(plan):
        # split_bwd and dtype are key fields now (plan-bucket-key lint
        # proves every axis that changes the lowering changes the key), so
        # a forced B/W split no longer needs an out-of-band cache identity
        key = plan.bucket_key(d_s, split_bwd=loop.split_bwd,
                              dtype=loop.compute_dtype)
        # the builder is cheap host-side state (geometry + specs); only
        # the compiled executable is cached — and, via the store, persisted.
        # ckpt_policy() canonicalizes the remat vector (padded to the
        # bucket's chunk count; constant tables collapse to the uniform
        # scalar) — the same canonical form key.ckpt digests, so the cache
        # can never hand this geometry a wrong-remat executable.
        l_max, table, _digest = plan.ckpt_policy(key.n_chunks)
        split = (None if loop.split_bwd == "auto"
                 else loop.split_bwd == "on")
        # the SP axis rides the bucket key (legacy sp-less plans resolve
        # to policy "auto" / full degree there, which make_geometry maps
        # back to the old rederive-at-full-d_s behavior)
        geom = make_geometry(cfg_arch, mesh, n_chunks=key.n_chunks,
                             cap=key.cap, ctx_cap=key.ctx_cap,
                             l_ckpt=l_max, compute_dtype=dtype,
                             schedule=key.schedule, v_stages=key.v_stages,
                             ckpt_table=table, split_bwd=split,
                             sp_policy=(None if key.sp_policy == "auto"
                                        else key.sp_policy),
                             sp_degree=key.d_s_eff)
        builder = TrainStepBuilder(cfg_arch, mesh, geom, param_dtype=dtype)

        def build():
            # plan invariants are audited before the (expensive) compile:
            # a schedule whose ticks don't cover every (item, v) slot or a
            # ckpt table that disagrees with the geometry never lowers
            if lint_hook is not None:
                prep = run_plan_checks(
                    plan, d_s, d_p, model=cfg_arch.spec,
                    key_kwargs={"split_bwd": loop.split_bwd,
                                "dtype": loop.compute_dtype})
                for f in prep.findings:
                    log(f"[lint] {f}")
                step_cache.stats.lint_findings += len(prep.findings)
                step_cache.stats.lint_errors += len(prep.errors)
                if prep.findings:
                    timeline.record("lint", bucket=str(key),
                                    findings=len(prep.findings),
                                    errors=len(prep.errors))
                if loop.lint == "error":
                    prep.raise_if_findings()
            # AOT lower+compile against abstract shapes: the resulting
            # jax.stages.Compiled is what serialize_executable can persist
            params_shape = builder.abstract_params()
            opt_shape = jax.eval_shape(init_opt_state, params_shape)
            bstruct = batch_struct(geom, n_pods)
            lowered = builder.build(params_shape).lower(
                params_shape, opt_shape, None, bstruct)
            if lint_hook is not None:
                lint_stash["stablehlo"] = lowered.as_text()
            return lowered.compile()
        m0, w0 = step_cache.stats.misses, step_cache.stats.warm_hits
        compiled = step_cache.get(key, build)
        if step_cache.stats.misses > m0:
            timeline.record("compile", bucket=str(key), event="cold",
                            compile_s=step_cache.stats
                            .compile_seconds_per_key.get(repr(key), 0.0))
        elif step_cache.stats.warm_hits > w0:
            timeline.record("compile", bucket=str(key), event="warm")
        return builder, compiled

    # --- bootstrap: plan step 0 to learn the first bucket ---
    plan, corpus = plan_for(0)
    log(f"[schedule] {plan.schedule} v={plan.v_stages} "
        f"(pinned for this run)")
    if plan.sp is not None:
        log(f"[sp] policy={plan.sp.policy} d_s_eff={plan.sp.d_s_eff}/{d_s}"
            + (" (planner-chosen)" if loop.sp_policy == "auto"
               and not loop.sp_degree else " (pinned)"))
    _key0 = plan.bucket_key(d_s)
    log(f"[ckpt] policy={loop.ckpt_policy} digest={_key0.ckpt} "
        f"l_max={_key0.l_ckpt}"
        + ("" if _key0.ckpt.startswith("u") else
           f" per_stage_max={plan.ckpt_per_stage_max()}"))
    builder, step_fn = get_step(plan)
    params, opt, _ = builder.init_all(jax.random.PRNGKey(loop.seed))
    def _restack(saved: np.ndarray, tmpl) -> Optional[np.ndarray]:
        """Elastic reshard: stage-stacked [d_p_old, L_s_old, ...] leaves
        restack for the current pipeline depth (un-permute the interleaved
        placement with the run's pinned v, strip old padding, re-pad).
        The v_stages guard after restore rejects checkpoints written at a
        different v, so assuming the pinned v here is sound."""
        if saved.ndim != len(tmpl.shape) or saved.ndim < 2 \
                or tuple(saved.shape[2:]) != tuple(tmpl.shape[2:]):
            return None
        from repro.runtime.sharding import restack_elastic
        return restack_elastic(saved, tmpl.shape[0], tmpl.shape[1],
                               cfg_arch.spec.n_layers, v=plan.v_stages)

    if mgr and loop.resume:
        latest = mgr.latest_step()
        if latest is not None:
            (params, opt), extra = mgr.restore((params, opt),
                                               adapt=_restack)
            # interleaved stacking permutes layers WITHOUT changing leaf
            # shapes, so a v_stages mismatch cannot be shape-detected —
            # loading it silently would scramble layers across virtual
            # stages. Checkpoints from before the schedule field are v=1.
            saved_v = int(extra.get("v_stages", 1))
            if saved_v != plan.v_stages:
                raise ValueError(
                    f"checkpoint was written with v_stages={saved_v} but "
                    f"this run pinned {plan.schedule} v={plan.v_stages}; "
                    f"pass --schedule/--v-stages matching the checkpoint "
                    f"(layer stacking is v-dependent and cannot be "
                    f"restacked across v)")
            start_step = int(extra.get("step", latest)) + 1
            log(f"[resume] from step {start_step - 1}")

    def mat(plan, corpus, cap, n_chunks):
        cb = materialize_plan(plan, corpus)
        b = {k: np.asarray(v) for k, v in cb.as_dict().items()}
        b["tokens"] = np.where(b["seg"] >= 0, b["tokens"], 0)
        b["pos"] = np.where(b["seg"] >= 0, b["pos"], 0)
        pad = cap - b["tokens"].shape[1]
        if pad > 0:
            for k, fill in (("tokens", 0), ("targets", -1), ("seg", -1),
                            ("pos", 0)):
                b[k] = np.pad(b[k], ((0, 0), (0, pad)),
                              constant_values=fill)
        padc = n_chunks - b["tokens"].shape[0]
        if padc > 0:  # bucket padding: fully-masked empty chunks
            for k, fill in (("tokens", 0), ("targets", -1), ("seg", -1),
                            ("pos", 0)):
                b[k] = np.pad(b[k], ((0, padc), (0, 0)),
                              constant_values=fill)
            b["ctx_len"] = np.pad(b["ctx_len"], (0, padc))
        if n_pods > 1:
            b = {k: v.reshape(n_pods, v.shape[0] // n_pods, *v.shape[1:])
                 for k, v in b.items()}
        return {k: jnp.asarray(v) for k, v in b.items()}

    history = []
    next_plan, next_corpus = plan, corpus
    for step in range(start_step, loop.steps):
        plan, corpus = next_plan, next_corpus
        if journal_out is not None:
            journal_out.write(json.dumps({"step": step,
                                          "plan": plan.dumps()}) + "\n")
        builder, step_fn = get_step(plan)
        key = plan.bucket_key(d_s)
        bucket = bucket_of(plan)
        batch = mat(plan, corpus, key.cap, key.n_chunks)
        probed = bool(loop.probe_every) and step % loop.probe_every == 0
        t0 = time.perf_counter()
        params, opt, _err, metrics = step_fn(params, opt, None, batch)
        dt_probe = None
        if probed:
            # probe mode: block_until_ready brackets the device step so
            # the sample excludes async dispatch (and the overlapped
            # solver below); per-stage attribution divides it across the
            # pipeline — the injector can then skew individual stages
            jax.block_until_ready(metrics["loss"])
            dt_probe = time.perf_counter() - t0
        # overlap: next iteration's plan solves while devices run
        next_plan, next_corpus = plan_for(step + 1)
        loss = float(metrics["loss"])
        dt_step = time.perf_counter() - t0
        wall = dt_probe if dt_probe is not None else dt_step
        per_stage = None
        wall_rep = wall
        if probed:
            per_stage = [wall / d_p] * d_p
            if injector is not None:
                per_stage = injector.per_stage(per_stage, step)
        if injector is not None:
            wall_rep = injector.wall(wall, step)
        timeline.record_step(step, bucket, wall_rep,
                             tokens=float(metrics["tokens"]), loss=loss,
                             per_stage_s=per_stage, probed=probed)
        history.append({"step": step, "loss": loss, "time": dt_step,
                        "tokens": float(metrics["tokens"]),
                        "solve_time": plan.solve_time})
        log(f"step {step:5d} loss {loss:.4f} tokens "
            f"{int(metrics['tokens'])} wall {dt_step:.2f}s "
            f"(solver {plan.solve_time:.2f}s overlapped)")
        if controller is not None:
            lengths = [len(v) for v in corpus.values()]
            controller.observe_step(step, plan, wall_rep, lengths,
                                    per_stage_s=per_stage, bucket=bucket)
            swap = controller.poll()
            if swap is not None and loop.replan == "auto":
                # hot-swap at the step boundary: the overlapped solve
                # above used the pre-swap calibration, so re-solve the
                # next step under the newly adopted one (a previously-seen
                # bucket is a warm hit; a fresh one was precompiled by the
                # re-plan job before adoption)
                next_plan, next_corpus = plan_for(step + 1)
        if mgr and (step + 1) % loop.ckpt_every == 0:
            mgr.save(step, (params, opt),
                     extra={"step": step, "schedule": plan.schedule,
                            "v_stages": plan.v_stages})
    if mgr:
        mgr.wait()
    if controller is not None:
        controller.drain()
        controller.poll()  # account a job that outlived the loop
        log(f"[replan] version={controller.version} "
            f"counters={controller.counters} "
            f"triggers={controller.trigger_reasons}")
    if journal_out is not None:
        journal_out.close()
    log(f"[compile-cache] {step_cache.stats.summary()}")
    rep = store.report() if store is not None else None
    if rep is not None:
        log(f"[cache-store] dir={rep['dir']} entries={rep['entries']} "
            f"({rep['size_bytes'] / 1e6:.2f} MB) saves={rep['saves']} "
            f"warm_loads={rep['loads']} stale={rep['stale_skips']} "
            f"corrupt={rep['corrupt_skips']}")
    if history:
        history[-1]["compile_cache"] = step_cache.stats.as_dict()
        if rep is not None:
            history[-1]["cache_store"] = rep
            history[-1]["cache_store_gc"] = gc_report
        history[-1]["telemetry"] = timeline.snapshot()
        if controller is not None:
            history[-1]["replan"] = controller.snapshot()
    timeline.close()
    return params, opt, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--context", type=int, default=2048)
    ap.add_argument("--dataset", default="github")
    ap.add_argument("--mesh", default="2x4",
                    help="DPxSP for CPU runs, e.g. 2x4 (needs "
                         "xla_force_host_platform_device_count)")
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--cache-dir", default=None,
                    help="persistent compile-cache directory (warm-starts "
                         "plan buckets across restarts); default: "
                         "<ckpt-dir>_compile_cache when --ckpt-dir is set, "
                         "'' disables")
    ap.add_argument("--cache-gc-age-s", type=float, default=0.0,
                    help="cache-store gc at startup: drop entries not "
                         "loaded in this many seconds (0 = off)")
    ap.add_argument("--cache-gc-bytes", type=int, default=0,
                    help="cache-store gc at startup: shrink the store to "
                         "this many payload bytes (0 = off)")
    ap.add_argument("--stats-json", default="",
                    help="write the run history + compile-cache/store "
                         "stats to this JSON file (CI artifact)")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--schedule", default=None,
                    help="pipeline schedule backend (gpipe-1f1b, "
                         "interleaved-1f1b, zero-bubble-h1); default: "
                         "planner's bubble model picks")
    ap.add_argument("--v-stages", type=int, default=0,
                    help="virtual stages per device for interleaved-1f1b "
                         "(0 = auto; must divide layers per stage)")
    ap.add_argument("--ckpt-policy", default="stage-aware",
                    choices=["stage-aware", "uniform"],
                    help="remat policy baked into the compiled step: "
                         "'stage-aware' threads the ILP's per-(stage, "
                         "chunk) checkpoint vector into the executor; "
                         "'uniform' collapses it to one max depth")
    ap.add_argument("--split-bwd", default="auto",
                    choices=["auto", "on", "off"],
                    help="zero-bubble B/W backward split: 'auto' follows "
                         "the schedule backend (split for zero-bubble-h1), "
                         "'on'/'off' force it for any backend (parity is "
                         "guaranteed either way)")
    ap.add_argument("--no-latency-hiding", action="store_true",
                    help="do not prepend the async-collective / "
                         "latency-hiding-scheduler XLA flags (also: set "
                         "REPRO_NO_LATENCY_HIDING=1)")
    ap.add_argument("--lint", default="warn",
                    choices=["off", "warn", "error"],
                    help="program auditor on cold compiles: 'warn' logs "
                         "findings (and counts them in --stats-json), "
                         "'error' aborts before a hazardous executable "
                         "enters the compile cache, 'off' skips the audit")
    ap.add_argument("--sp-policy", default="auto",
                    choices=["auto", "none", "ulysses", "allgather_kv"],
                    help="sequence-parallel policy pin: 'auto' lets the "
                         "planner choose (policy, degree) jointly with "
                         "chunking; a name pins the policy (the pin gets "
                         "its own plan bucket / compile-cache identity)")
    ap.add_argument("--sp-degree", type=int, default=0,
                    help="effective SP degree pin (sub-groups of the "
                         "model axis; must divide the mesh's SP size); "
                         "0 = planner-chosen")
    ap.add_argument("--replan", default="off",
                    choices=["off", "observe", "auto"],
                    help="online re-planning: 'observe' collects telemetry "
                         "and logs would-be swaps without touching plans; "
                         "'auto' closes the loop — calibrated per-step "
                         "solves + drift-triggered hysteresis-gated plan "
                         "hot-swaps at step boundaries")
    ap.add_argument("--telemetry-dir", default="",
                    help="directory for the timeline JSONL spill, the "
                         "per-step plan journal (plans.jsonl) and the "
                         "per-mesh calibration store (calibration.json)")
    ap.add_argument("--probe-every", type=int, default=0,
                    help="bracket every Nth step with "
                         "jax.block_until_ready and record a per-stage "
                         "breakdown (0 = never; EMA counters stay on)")
    ap.add_argument("--replan-min-win", type=float, default=0.05,
                    help="hysteresis: a bucket-changing swap needs at "
                         "least this predicted relative win")
    ap.add_argument("--replan-cooldown", type=int, default=8,
                    help="minimum steps between re-plan jobs")
    ap.add_argument("--replan-min-samples", type=int, default=4,
                    help="telemetry samples before the first fit")
    ap.add_argument("--replan-sync", action="store_true",
                    help="run re-plan jobs inline instead of on the "
                         "background thread (deterministic swap timing)")
    ap.add_argument("--inject-straggler", default="",
                    help="deterministic telemetry-only straggler "
                         "injection 'STAGE:FACTOR[,...][@START]' (e.g. "
                         "'2:2.5@3'); perturbs measurements, never math")
    ap.add_argument("--dataset2", default="",
                    help="switch the length-mix preset to this at "
                         "--drift-at (two-phase drifting traces)")
    ap.add_argument("--context2", type=int, default=0,
                    help="context limit for the post-drift phase "
                         "(0 = keep --context)")
    ap.add_argument("--drift-at", type=int, default=0,
                    help="step at which --dataset2/--context2 take over "
                         "(0 = never)")
    ap.add_argument("--plan-journal", default="",
                    help="replay per-step plans from this plans.jsonl "
                         "instead of solving (pinned-plan baseline)")
    args = ap.parse_args()

    import os

    from repro.launch.mesh import configure_latency_hiding
    configure_latency_hiding(
        enable=False if args.no_latency_hiding else None)
    # append (not setdefault — the latency-hiding flags may already be in
    # XLA_FLAGS) the CPU placeholder-device count unless the caller set one
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={args.devices}"
        ).strip()
    import jax

    from repro.configs import get_arch
    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    dp, ds = (int(x) for x in args.mesh.split("x"))
    mesh = jax.make_mesh((dp, ds), ("data", "model"))
    loop = TrainLoopConfig(steps=args.steps, global_batch=args.batch,
                           context=args.context, dataset=args.dataset,
                           ckpt_dir=args.ckpt_dir, resume=args.resume,
                           cache_dir=args.cache_dir,
                           cache_gc_age_s=args.cache_gc_age_s or None,
                           cache_gc_bytes=args.cache_gc_bytes or None,
                           compute_dtype="float32" if args.reduced
                           else "bfloat16",
                           schedule=args.schedule, v_stages=args.v_stages,
                           ckpt_policy=args.ckpt_policy,
                           split_bwd=args.split_bwd,
                           lint=args.lint,
                           sp_policy=args.sp_policy,
                           sp_degree=args.sp_degree,
                           replan=args.replan,
                           telemetry_dir=args.telemetry_dir or None,
                           probe_every=args.probe_every,
                           replan_min_win=args.replan_min_win,
                           replan_cooldown=args.replan_cooldown,
                           replan_min_samples=args.replan_min_samples,
                           replan_background=not args.replan_sync,
                           inject_straggler=args.inject_straggler,
                           dataset2=args.dataset2 or None,
                           context2=args.context2,
                           drift_at=args.drift_at,
                           plan_journal=args.plan_journal or None)
    _, _, history = train(cfg, mesh, loop)
    if args.stats_json:
        from repro.telemetry import atomic_write_json
        last = history[-1] if history else {}
        atomic_write_json(args.stats_json,
                          {"history": history,
                           "compile_cache": last.get("compile_cache", {}),
                           "cache_store": last.get("cache_store", {}),
                           "cache_store_gc": last.get("cache_store_gc"),
                           "telemetry": last.get("telemetry", {}),
                           "replan": last.get("replan", {})})


if __name__ == "__main__":
    main()
