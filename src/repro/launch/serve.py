"""Long-context serving driver: prefill via the EPP pipeline (split chunks
fill the KV cache), then pipelined flash-decode steps.

CPU demo:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced
"""

from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--decode-steps", type=int, default=4)
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--cache-dir", default="",
                    help="persistent compile-cache directory: a serving "
                         "restart warm-starts the decode bucket instead of "
                         "recompiling")
    args = ap.parse_args()

    import os
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={args.devices}")
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.configs import get_arch
    from repro.runtime import (CacheStore, CompileCache, TrainStepBuilder,
                               make_geometry, store_fingerprint)
    from repro.runtime.compile_cache import decode_bucket_key
    from repro.runtime.serve_step import (decode_state_specs,
                                          decode_state_struct,
                                          decode_step_fn,
                                          make_decode_geometry)
    from repro.runtime.sharding import (mesh_axis_names, shard_dim_tree,
                                        shard_map_compat)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    pod, data, model = mesh_axis_names(mesh)
    geom = make_decode_geometry(cfg, mesh, batch_per_pod=args.batch,
                                cache_len=args.cache_len,
                                compute_dtype=jnp.float32)
    builder = TrainStepBuilder(cfg, mesh, make_geometry(
        cfg, mesh, n_chunks=1, cap=4, ctx_cap=4,
        compute_dtype=jnp.float32), param_dtype=jnp.float32)
    params, _, _ = builder.init_all(jax.random.PRNGKey(0))
    pspecs, _, _ = builder.specs(jax.eval_shape(lambda: params))
    shard_dims = shard_dim_tree(params["stages"], mesh.shape[model])
    store = None
    if args.cache_dir:
        store = CacheStore(args.cache_dir,
                           store_fingerprint(mesh, spec=cfg.spec,
                                             compute_dtype=jnp.float32),
                           log=print)
    cache = CompileCache(name="decode-step", log=print, store=store)
    struct = decode_state_struct(cfg, geom, 1)

    def build_step():
        fn = decode_step_fn(cfg, geom, shard_dims, pod_axis=pod,
                            data_axis=data, model_axis=model)
        sspecs = decode_state_specs(cfg, geom, pod=pod, data=data,
                                    model=model)
        jitted = jax.jit(shard_map_compat(
            fn, mesh=mesh, in_specs=(pspecs, sspecs),
            out_specs=(P(), sspecs), check_vma=False))
        # AOT so the compiled decode step is serializable to the store
        return jitted.lower(jax.eval_shape(lambda: params), struct).compile()

    rng = np.random.default_rng(0)
    state = {k: jnp.asarray(rng.normal(0, 0.3, v.shape).astype(
        np.float32) * 0 + (rng.integers(0, cfg.spec.vocab, v.shape)
                           if v.dtype == jnp.int32 else
                           rng.normal(0, 0.3, v.shape))
        , dtype=v.dtype) for k, v in struct.items()}
    for i in range(args.decode_steps):
        # per-step lookup, as a serving loop would do per request batch:
        # the first step compiles the bucket, the rest hit the cache
        step = cache.get(decode_bucket_key(geom), build_step)
        ids, state = step(params, state)
        print(f"decode step {i}: ids[0,:8] = {np.asarray(ids)[0, :8]}")
    print(f"[compile-cache] {cache.stats.summary()}")
    if store is not None:
        print(f"[cache-store] {store.report()}")
    print("serve OK")


if __name__ == "__main__":
    main()
