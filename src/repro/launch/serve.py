"""Continuous-batching serving driver: the thin launcher for
``repro.serve.ServeEngine`` over a synthetic Poisson/lognormal request
trace (``data/synth.sample_request_trace`` presets).

Each engine step packs chunked-prefill segments and (speculative) decode
streams into ONE fixed-shape compiled program over a paged, sequence-sharded
KV pool; a second tiny program services copy-on-write page copies. The
compile cache therefore holds exactly two buckets — ``--passes 2`` replays
the identical trace and asserts the second pass compiles nothing.
``--system-prompt N`` prepends a shared N-token prefix to every request,
the regime the content-addressed prefix cache exists for (contrast with
``--no-prefix-cache`` to see the prefill-token saving). ``--cache-dir`` persists the
executable so even a fresh process warm-starts; ``--gc-max-age-s`` /
``--gc-max-bytes`` garbage-collect the store at startup.

CPU demo (4 fake devices):

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \\
      --requests 24 --passes 2 --k 2 --stats-json serve-stats.json
"""

from __future__ import annotations

import argparse
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default="2x2", help="DPxSP, e.g. 2x2")
    ap.add_argument("--devices", type=int, default=4)
    # trace
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--trace", default="github",
                    help="length preset (github/commoncrawl/uniform)")
    ap.add_argument("--context-limit", type=int, default=96,
                    help="max prompt length the trace samples")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--arrival-rate", type=float, default=2.0,
                    help="Poisson arrivals per simulated second")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--passes", type=int, default=1,
                    help="replay the identical trace N times; pass 2+ must "
                         "report zero fresh compiles (closed bucket set)")
    # engine geometry (the closed two-bucket compile-cache set)
    ap.add_argument("--items", type=int, default=4,
                    help="packed chunk items per engine step")
    ap.add_argument("--cap-t", type=int, default=32,
                    help="tokens per item (= max prefill chunk)")
    ap.add_argument("--pages", type=int, default=0,
                    help="KV pages pool-wide; 0 = auto (6 concurrent "
                         "max-context requests, rounded to the model axis)")
    ap.add_argument("--page-sz", type=int, default=16,
                    help="cache rows per KV page")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable content-addressed page sharing (the "
                         "prefix-cache OFF baseline the benchmark contrasts)")
    ap.add_argument("--system-prompt", type=int, default=0,
                    help="prepend a shared system prompt of this many "
                         "tokens to every trace request (prefix-cache "
                         "regime; 0 = off)")
    ap.add_argument("--k", type=int, default=1,
                    help="decode tokens per stream per step (speculative "
                         "draft width; k=1 is plain greedy)")
    # scheduling policy (no recompile across these)
    ap.add_argument("--prefill-mode", default="interleaved",
                    choices=["interleaved", "serial"],
                    help="'serial' = naive stop-the-world prefill baseline")
    ap.add_argument("--decode-budget", type=int, default=0,
                    help="decode tokens per step (0 = auto)")
    ap.add_argument("--prefill-budget", type=int, default=0,
                    help="prefill tokens per step (0 = auto)")
    # persistence
    ap.add_argument("--cache-dir", default="",
                    help="persistent compile-cache directory: a serving "
                         "restart warm-starts the engine bucket instead of "
                         "recompiling")
    ap.add_argument("--gc-max-age-s", type=float, default=0.0,
                    help="cache-store gc at startup: drop entries not "
                         "loaded in this many seconds (0 = off)")
    ap.add_argument("--gc-max-bytes", type=int, default=0,
                    help="cache-store gc at startup: shrink the store to "
                         "this many payload bytes (0 = off)")
    ap.add_argument("--stats-json", default="",
                    help="write per-pass engine stats + cache/store stats "
                         "to this JSON file (CI artifact)")
    ap.add_argument("--telemetry-dir", default="",
                    help="spill a telemetry timeline (engine TTFT/TPOT/"
                         "occupancy events) to this directory as JSONL")
    ap.add_argument("--verify", type=int, default=0,
                    help="cross-check the first N requests' output ids "
                         "against the one-shot reference path")
    ap.add_argument("--lint", default="warn",
                    choices=["off", "warn", "error"],
                    help="program auditor on the engine's cold compile: "
                         "'warn' logs findings, 'error' aborts before a "
                         "hazardous executable enters the cache")
    args = ap.parse_args()

    import os
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={args.devices}")
    import jax
    import jax.numpy as jnp

    from repro.configs import get_arch
    from repro.data import sample_request_trace
    from repro.runtime import CacheStore, store_fingerprint
    from repro.runtime.compile_cache import CompileCache
    from repro.serve import (EngineConfig, Request, ServeEngine,
                             one_shot_generate)
    from repro.telemetry import StepTimeline, atomic_write_json

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    dp, ds = (int(x) for x in args.mesh.split("x"))
    mesh = jax.make_mesh((dp, ds), ("data", "model"))

    page_sz = args.page_sz
    pages_per_seq = -(-(args.context_limit + args.max_new) // page_sz)
    if args.pages:
        n_pages = args.pages
    else:
        # auto: room for ~6 concurrent max-context requests, rounded up to
        # a multiple of the model axis (the pool is sequence-sharded)
        n_pages = -(-(6 * pages_per_seq) // ds) * ds
    trace = sample_request_trace(args.trace, args.requests,
                                 args.context_limit, cfg.spec.vocab,
                                 seed=args.seed,
                                 arrival_rate=args.arrival_rate,
                                 max_new_tokens=args.max_new,
                                 system_prompt_len=args.system_prompt)
    # admission validation UP FRONT: the old driver silently truncated an
    # over-long prompt's context; the engine (and this check) reject it
    longest = max(len(t["prompt"]) for t in trace)
    if longest + args.max_new > pages_per_seq * page_sz:
        print(f"error: longest sampled prompt ({longest}) + --max-new "
              f"({args.max_new}) exceeds the per-request page budget "
              f"({pages_per_seq} pages x {page_sz} rows); raise "
              f"--page-sz or lower --context-limit — context is never "
              f"silently truncated",
              file=sys.stderr)
        return 2

    store = gc_report = None
    if args.cache_dir:
        store = CacheStore(args.cache_dir,
                           store_fingerprint(mesh, spec=cfg.spec,
                                             compute_dtype=jnp.float32),
                           log=print)
        gc_report = store.gc(
            max_age_s=args.gc_max_age_s or None,
            max_bytes=args.gc_max_bytes or None)
    from repro.launch.mesh import latency_hiding_active
    from repro.lint import make_cache_lint
    cache = CompileCache(name="serve-engine", log=print, store=store,
                         lint=make_cache_lint(
                             args.lint, log=print,
                             latency_hiding=latency_hiding_active()))

    econf = EngineConfig(
        n_items=args.items, cap_t=args.cap_t, n_pages=n_pages,
        page_sz=page_sz, pages_per_seq=pages_per_seq, k=args.k,
        prefix_cache=not args.no_prefix_cache,
        decode_token_budget=args.decode_budget or None,
        prefill_token_budget=args.prefill_budget or None,
        prefill_mode=args.prefill_mode)

    def requests():
        return [Request(req_id=i, prompt=t["prompt"],
                        max_new_tokens=t["max_new_tokens"],
                        arrival=t["arrival"]) for i, t in enumerate(trace)]

    timeline = StepTimeline(spill_dir=args.telemetry_dir or None,
                            name="serve")
    passes = []
    params = None
    rc = 0
    error = None
    for p in range(max(1, args.passes)):
        try:
            engine = ServeEngine(cfg, mesh, econf, params=params,
                                 param_dtype=jnp.float32, cache=cache,
                                 seed=args.seed, log=print,
                                 timeline=timeline)
        except NotImplementedError as e:
            # SSM/hybrid, enc-dec and MLA archs have no engine path yet;
            # their pipelined one-shot decode step (decode_step_fn) is
            # still exercised by the dryrun decode cells
            rc, error = 5, (f"arch {args.arch!r} is not servable by the "
                            f"continuous-batching engine: {e}")
            break
        params = engine.params
        misses_before = cache.stats.misses
        results = engine.run(requests())
        st = engine.stats()
        st["pass"] = p
        st["fresh_compiles"] = cache.stats.misses - misses_before
        # per-request output ids so CI can assert cache-on == cache-off
        # bitwise (the prefix cache must never change what comes out)
        st["outputs"] = {int(r): results[r].output_ids
                        for r in sorted(results)}
        passes.append(st)
        print(f"[pass {p}] completed={st['completed']}/{len(trace)} "
              f"steps={st['steps']} tok/s={st['tokens_per_s']} "
              f"ttft_p95={st['ttft_s_p95']}s "
              f"occupancy={st['kv_pool']['mean_occupancy']} "
              f"prefix_hits={st['kv_pool']['prefix_hit_rows']} "
              f"prefill_fed={st['prefill_tokens_fed']} "
              f"accept={st['speculative']['acceptance_rate']} "
              f"fresh_compiles={st['fresh_compiles']}")
        if p > 0 and st["fresh_compiles"]:
            rc, error = 3, ("pass > 0 compiled fresh executables — the "
                            "engine bucket set is not closed")
            break
        if p == 0 and args.verify:
            n_v = min(args.verify, len(trace))
            ref = one_shot_generate(cfg, mesh, params,
                                    [t["prompt"] for t in trace[:n_v]],
                                    args.max_new)
            for i in range(n_v):
                got = results[i].output_ids
                if got != ref[i]:
                    rc, error = 4, (f"request {i} engine ids {got} != "
                                    f"one-shot ids {ref[i]}")
                    break
            if rc:
                break
            print(f"[verify] {n_v} requests match the one-shot path")

    print(f"[compile-cache] {cache.stats.summary()}")
    out = {"config": vars(args), "passes": passes,
           "compile_cache": cache.stats.as_dict(), "error": error,
           "telemetry": timeline.snapshot()}
    timeline.close()
    if store is not None:
        rep = store.report()
        out["cache_store"] = rep
        out["cache_store_gc"] = gc_report
        print(f"[cache-store] {rep}")
    # the stats artifact is written even on a failed run — CI diagnoses
    # exactly the failing case from it. Atomic (tmp + os.replace): an
    # external scraper can never read a torn file
    if args.stats_json:
        atomic_write_json(args.stats_json, out)
    if error:
        print(f"error: {error}", file=sys.stderr)
        return rc
    print("serve OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
