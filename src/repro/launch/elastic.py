"""Elastic rescale demonstration: EPP is natively elastic because plans are
functions of (mesh, workload), not baked state.

Shrink/grow flow:
  1. checkpoint on the old mesh (CheckpointManager — reshard-on-load),
  2. build a new mesh (lost pod => fewer devices, or scale-out),
  3. re-plan with the new ClusterSpec (the solver re-balances chunks, the
     ILP re-solves checkpointing for the new memory budget),
  4. restore parameters with the new shardings and continue.

The compile-cache store rides along: every phase shares one ``cache_dir``.
The shrink phase's mesh change invalidates the store fingerprint, so its
buckets cold-compile (stale entries are *skipped*, never loaded wrong);
the grow-back phase returns to the original topology and warm-starts the
phase-1 buckets with zero fresh compiles.

``python -m repro.launch.elastic --arch llama3.2-3b`` runs the whole cycle
at reduced scale on CPU (4 fake devices -> 2 -> 4) and verifies the loss
continues smoothly across both restarts. See examples/elastic_restart.py.
"""

from __future__ import annotations

import argparse


def _assert_loss_continuity(prev_hist, next_hist, phase: str,
                            rel_tol: float = 0.25) -> None:
    """The restarted run must CONTINUE the previous one: it resumes right
    after a step the previous phase RAN (the last checkpointed one — not
    necessarily the last step, when steps isn't a multiple of ckpt_every)
    and its first loss stays close to that step's (a scrambled restore
    shows up as a jump back toward the init loss)."""
    nxt = next_hist[0]
    by_step = {h["step"]: h for h in prev_hist}
    prev = by_step.get(nxt["step"] - 1)
    assert prev is not None, \
        f"{phase}: resumed at step {nxt['step']}, but the previous phase " \
        f"never ran step {nxt['step'] - 1} (ran " \
        f"{prev_hist[0]['step']}..{prev_hist[-1]['step']})"
    rel = abs(nxt["loss"] - prev["loss"]) / max(prev["loss"], 1e-9)
    assert rel < rel_tol, \
        f"{phase}: loss discontinuity across restart — " \
        f"{prev['loss']:.4f} (step {prev['step']}) -> " \
        f"{nxt['loss']:.4f} (step {nxt['step']}) ({rel:.1%})"
    print(f"[{phase}] loss continuity OK: {prev['loss']:.4f} -> "
          f"{nxt['loss']:.4f} ({rel:.2%})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--steps", type=int, default=6)
    args = ap.parse_args()

    import os
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=4")
    import tempfile

    import jax

    from repro.configs import get_arch
    from repro.launch.train import TrainLoopConfig, train

    cfg = get_arch(args.arch).reduced()
    with tempfile.TemporaryDirectory() as d:
        ckpt = os.path.join(d, "ckpt")
        cache = os.path.join(d, "compile_cache")
        common = dict(global_batch=6, context=256, ckpt_dir=ckpt,
                      ckpt_every=2, cache_dir=cache,
                      compute_dtype="float32")
        loop = TrainLoopConfig(steps=args.steps, **common)
        mesh_a = jax.make_mesh((2, 2), ("data", "model"))
        print(f"== phase 1: mesh {dict(mesh_a.shape)} ==")
        _, _, hist_a = train(cfg, mesh_a, loop)

        # "lose half the machine": restart on a (1, 2) mesh. The mesh
        # change flips the store fingerprint, so phase 1's persisted
        # buckets are skipped as stale and this phase cold-compiles.
        mesh_b = jax.make_mesh((1, 2), ("data", "model"))
        loop_b = TrainLoopConfig(steps=args.steps + 2, resume=True,
                                 **common)
        print(f"== phase 2 (elastic shrink): mesh {dict(mesh_b.shape)} ==")
        _, _, hist_b = train(cfg, mesh_b, loop_b)
        _assert_loss_continuity(hist_a, hist_b, "shrink")
        store_b = hist_b[-1]["cache_store"]
        assert store_b["stale_skips"] >= 1, \
            f"shrink phase should have skipped phase 1's stale buckets, " \
            f"store report: {store_b}"
        assert hist_b[-1]["compile_cache"]["warm_hits"] == 0

        # the lost half comes back: grow to the original (2, 2) mesh.
        # Same topology fingerprint as phase 1 => repeated buckets
        # warm-start from the store with zero fresh compiles.
        loop_c = TrainLoopConfig(steps=args.steps + 4, resume=True,
                                 **common)
        print(f"== phase 3 (elastic grow): mesh {dict(mesh_a.shape)} ==")
        _, _, hist_c = train(cfg, mesh_a, loop_c)
        _assert_loss_continuity(hist_b, hist_c, "grow")
        cc = hist_c[-1]["compile_cache"]
        assert cc["warm_hits"] >= 1, \
            f"grow phase should warm-start phase 1's buckets, got {cc}"
        print("elastic restart OK (shrink cold-compiled, grow "
              f"warm-started {cc['warm_hits']} bucket(s), "
              f"{cc['misses']} cold)")


if __name__ == "__main__":
    main()
