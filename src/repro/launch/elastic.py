"""Elastic rescale demonstration: EPP is natively elastic because plans are
functions of (mesh, workload), not baked state.

Shrink/grow flow:
  1. checkpoint on the old mesh (CheckpointManager — reshard-on-load),
  2. build a new mesh (lost pod => fewer devices, or scale-out),
  3. re-plan with the new ClusterSpec (the solver re-balances chunks, the
     ILP re-solves checkpointing for the new memory budget),
  4. restore parameters with the new shardings and continue.

``python -m repro.launch.elastic --arch llama3.2-3b`` runs the whole cycle
at reduced scale on CPU (8 fake devices -> 4) and verifies the loss
continues smoothly. See examples/elastic_restart.py.
"""

from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--steps", type=int, default=6)
    args = ap.parse_args()

    import os
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=4")
    import tempfile

    import jax

    from repro.configs import get_arch
    from repro.launch.train import TrainLoopConfig, train

    cfg = get_arch(args.arch).reduced()
    with tempfile.TemporaryDirectory() as d:
        loop = TrainLoopConfig(steps=args.steps, global_batch=6,
                               context=256, ckpt_dir=d, ckpt_every=3,
                               compute_dtype="float32")
        mesh_a = jax.make_mesh((2, 2), ("data", "model"))
        print(f"== phase 1: mesh {dict(mesh_a.shape)} ==")
        train(cfg, mesh_a, loop)

        # "lose half the machine": restart on a (2, 2) mesh
        mesh_b = jax.make_mesh((1, 2), ("data", "model"))
        loop_b = TrainLoopConfig(steps=args.steps + 2, global_batch=6,
                                 context=256, ckpt_dir=d, ckpt_every=3,
                                 resume=True, compute_dtype="float32")
        print(f"== phase 2 (elastic shrink): mesh {dict(mesh_b.shape)} ==")
        train(cfg, mesh_b, loop_b)
        print("elastic restart OK")


if __name__ == "__main__":
    main()
