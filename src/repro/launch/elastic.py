"""Elastic rescale demonstration: EPP is natively elastic because plans are
functions of (mesh, workload), not baked state.

Shrink/grow flow:
  1. checkpoint on the old mesh (CheckpointManager — reshard-on-load),
  2. build a new mesh (lost pod => fewer devices, or scale-out),
  3. re-plan with the new ClusterSpec (the solver re-balances chunks, the
     ILP re-solves checkpointing for the new memory budget),
  4. restore parameters with the new shardings and continue.

The compile-cache store rides along: every phase shares one ``cache_dir``.
The shrink phase's mesh change invalidates the store fingerprint, so its
buckets cold-compile (stale entries are *skipped*, never loaded wrong);
the grow-back phase returns to the original topology and warm-starts the
phase-1 buckets with zero fresh compiles.

``python -m repro.launch.elastic --arch llama3.2-3b`` runs the whole cycle
at reduced scale on CPU (4 fake devices -> 2 -> 4) and verifies the loss
continues smoothly across both restarts. See examples/elastic_restart.py.
"""

from __future__ import annotations

import argparse


def _assert_loss_continuity(prev_hist, next_hist, phase: str,
                            rel_tol: float = 0.25) -> None:
    """The restarted run must CONTINUE the previous one: it resumes right
    after a step the previous phase RAN (the last checkpointed one — not
    necessarily the last step, when steps isn't a multiple of ckpt_every)
    and its first loss stays close to that step's (a scrambled restore
    shows up as a jump back toward the init loss)."""
    nxt = next_hist[0]
    by_step = {h["step"]: h for h in prev_hist}
    prev = by_step.get(nxt["step"] - 1)
    assert prev is not None, \
        f"{phase}: resumed at step {nxt['step']}, but the previous phase " \
        f"never ran step {nxt['step'] - 1} (ran " \
        f"{prev_hist[0]['step']}..{prev_hist[-1]['step']})"
    rel = abs(nxt["loss"] - prev["loss"]) / max(prev["loss"], 1e-9)
    assert rel < rel_tol, \
        f"{phase}: loss discontinuity across restart — " \
        f"{prev['loss']:.4f} (step {prev['step']}) -> " \
        f"{nxt['loss']:.4f} (step {nxt['step']}) ({rel:.1%})"
    print(f"[{phase}] loss continuity OK: {prev['loss']:.4f} -> "
          f"{nxt['loss']:.4f} ({rel:.2%})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--steps", type=int, default=6)
    ap.add_argument("--replan", default="observe",
                    choices=["off", "observe", "auto"],
                    help="online re-planning across the elastic phases: the "
                         "shared telemetry dir persists calibrations per "
                         "mesh fingerprint, so the shrink phase (foreign "
                         "fingerprint) forces an immediate elastic re-solve "
                         "and the grow phase warm-starts phase 1's "
                         "calibration")
    args = ap.parse_args()

    import os
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=4")
    import tempfile

    import jax

    from repro.configs import get_arch
    from repro.launch.train import TrainLoopConfig, train

    cfg = get_arch(args.arch).reduced()
    with tempfile.TemporaryDirectory() as d:
        ckpt = os.path.join(d, "ckpt")
        cache = os.path.join(d, "compile_cache")
        tele = os.path.join(d, "telemetry")
        common = dict(global_batch=6, context=256, ckpt_dir=ckpt,
                      ckpt_every=2, cache_dir=cache,
                      compute_dtype="float32",
                      replan=args.replan,
                      # one telemetry dir across every phase: calibrations
                      # persist keyed by mesh fingerprint, which is what
                      # makes the shrink/grow behavior below observable
                      telemetry_dir=(tele if args.replan != "off" else None),
                      replan_min_samples=2, replan_background=False)
        loop = TrainLoopConfig(steps=args.steps, **common)
        mesh_a = jax.make_mesh((2, 2), ("data", "model"))
        print(f"== phase 1: mesh {dict(mesh_a.shape)} ==")
        _, _, hist_a = train(cfg, mesh_a, loop)
        if args.replan != "off":
            rep_a = hist_a[-1].get("replan", {})
            assert rep_a.get("calibration_version", 0) >= 1, \
                f"phase 1 should adopt a bootstrap calibration: {rep_a}"

        # "lose half the machine": restart on a (1, 2) mesh. The mesh
        # change flips the store fingerprint, so phase 1's persisted
        # buckets are skipped as stale and this phase cold-compiles.
        mesh_b = jax.make_mesh((1, 2), ("data", "model"))
        loop_b = TrainLoopConfig(steps=args.steps + 2, resume=True,
                                 **common)
        print(f"== phase 2 (elastic shrink): mesh {dict(mesh_b.shape)} ==")
        _, _, hist_b = train(cfg, mesh_b, loop_b)
        _assert_loss_continuity(hist_a, hist_b, "shrink")
        if args.replan != "off":
            # the (1,2) mesh has no calibration in the shared store — the
            # controller must force an immediate elastic re-solve instead
            # of replaying the bootstrap plan
            rep_b = hist_b[-1].get("replan", {})
            assert "elastic" in rep_b.get("triggers", {}), \
                f"shrink phase should force an elastic re-solve: {rep_b}"
        store_b = hist_b[-1]["cache_store"]
        # phase 1's entries sit in the shared store under a foreign
        # fingerprint and must never be loaded. That is observable two
        # ways: a stale skip when a bucket key collides across the two
        # topologies, or — when the planner legitimately picks different
        # geometry per mesh (d_p=1 solves gpipe-1f1b where d_p=2 solves
        # zero-bubble-h1, so the keys never collide) — foreign entries
        # coexisting with zero warm loads.
        assert (store_b["stale_skips"] >= 1
                or store_b["entries"] > store_b["entries_current_fingerprint"]), \
            f"shrink phase should see phase 1's buckets only as foreign, " \
            f"store report: {store_b}"
        assert store_b["loads"] == 0, store_b
        assert hist_b[-1]["compile_cache"]["warm_hits"] == 0

        # the lost half comes back: grow to the original (2, 2) mesh.
        # Same topology fingerprint as phase 1 => repeated buckets
        # warm-start from the store with zero fresh compiles.
        loop_c = TrainLoopConfig(steps=args.steps + 4, resume=True,
                                 **common)
        print(f"== phase 3 (elastic grow): mesh {dict(mesh_a.shape)} ==")
        _, _, hist_c = train(cfg, mesh_a, loop_c)
        _assert_loss_continuity(hist_b, hist_c, "grow")
        cc = hist_c[-1]["compile_cache"]
        assert cc["warm_hits"] >= 1, \
            f"grow phase should warm-start phase 1's buckets, got {cc}"
        if args.replan != "off":
            # back on the original topology: phase 1's calibration warm-
            # starts (same fingerprint), so no elastic re-solve is forced
            rep_c = hist_c[-1].get("replan", {})
            assert rep_c.get("calibration_version", 0) >= 1, \
                f"grow phase should warm-start phase 1's calibration: {rep_c}"
            assert "elastic" not in rep_c.get("triggers", {}), \
                f"grow phase must not force an elastic re-solve: {rep_c}"
        print("elastic restart OK (shrink cold-compiled, grow "
              f"warm-started {cc['warm_hits']} bucket(s), "
              f"{cc['misses']} cold)")


if __name__ == "__main__":
    main()
