"""Sharded checkpoint save/restore with manifest + async writes.

Layout (one directory per step):

    step_00001230/
      manifest.json       <- written LAST: its presence marks the commit
      leaf_000000.npy ... <- one file per pytree leaf (host numpy)

* ``save`` is asynchronous by default: arrays are fetched to host
  (device_get) synchronously — cheap relative to a step — and the file
  writes happen on a background thread, double-buffered so at most one
  pending save exists (a second save waits, it never corrupts).
* ``restore`` is reshard-on-load: leaves are read on host and
  ``jax.device_put`` against whatever mesh/sharding the *caller* provides —
  a checkpoint from a 512-chip run restores onto 256 chips (elastic
  restart, DESIGN.md §6).
* integrity: a crash mid-save leaves no manifest => ``latest_step`` skips
  the partial directory; ``gc_keep`` prunes old steps.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return flat, treedef


class CheckpointManager:
    def __init__(self, directory: str | Path, *, async_save: bool = True,
                 keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.async_save = async_save
        self.keep = keep
        self._pending: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> Path:
        return self.dir / f"step_{step:08d}"

    @staticmethod
    def _parse_step(name: str) -> Optional[int]:
        """``step_00000042`` -> 42; None for anything unparseable (editor
        backups, ``step_tmp`` scratch dirs, a crashed save's
        ``step_*.tmp``) — stray directories must never crash discovery."""
        tail = name[len("step_"):]
        return int(tail) if tail.isdigit() else None

    def latest_step(self) -> Optional[int]:
        steps = []
        for p in self.dir.glob("step_*"):
            step = self._parse_step(p.name)
            if step is not None and (p / "manifest.json").exists():
                steps.append(step)
        return max(steps) if steps else None

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, *, extra: Optional[Dict] = None
             ) -> None:
        """Fetch to host now; write on the background thread."""
        self.wait()
        flat, treedef = _flatten_with_paths(tree)
        host = [np.asarray(jax.device_get(x)) for x in flat]
        meta = {
            "step": step,
            "treedef": jax.tree_util.tree_structure(tree).serialize_using_proto().hex()
            if hasattr(jax.tree_util.tree_structure(tree),
                       "serialize_using_proto") else None,
            "n_leaves": len(host),
            "shapes": [list(a.shape) for a in host],
            "dtypes": [str(a.dtype) for a in host],
            "extra": extra or {},
            "wall_time": time.time(),
        }

        def _write():
            d = self._step_dir(step)
            tmp = d.with_suffix(".tmp")
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            for i, a in enumerate(host):
                np.save(tmp / f"leaf_{i:06d}.npy", a)
            (tmp / "manifest.json").write_text(json.dumps(meta))
            if d.exists():
                shutil.rmtree(d)
            tmp.rename(d)
            self._gc()

        if self.async_save:
            t = threading.Thread(target=_write, daemon=True)
            t.start()
            self._pending = t
        else:
            _write()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self) -> None:
        # order by parsed step number, not lexically: a stray
        # step_xxx.tmp (crash between manifest write and rename) must not
        # displace a real step from the keep window
        steps = sorted((p for p in self.dir.glob("step_*")
                        if self._parse_step(p.name) is not None
                        and (p / "manifest.json").exists()),
                       key=lambda p: self._parse_step(p.name))
        for p in steps[:-self.keep]:
            shutil.rmtree(p, ignore_errors=True)

    # ------------------------------------------------------------------
    def restore(self, template: Any, *, step: Optional[int] = None,
                shardings: Any = None, adapt=None) -> Tuple[Any, Dict]:
        """Restore into ``template``'s tree structure. ``shardings`` (same
        structure, NamedSharding leaves) reshards onto the current mesh.

        ``adapt(saved_np, template_leaf) -> np | None`` converts leaves whose
        layout depends on the mesh (stage-stacked [d_p, L_s, ...] arrays
        restack when the pipeline depth changes — elastic restarts)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.dir}")
        d = self._step_dir(step)
        meta = json.loads((d / "manifest.json").read_text())
        flat_t, treedef = _flatten_with_paths(template)
        if meta["n_leaves"] != len(flat_t):
            raise ValueError(
                f"checkpoint has {meta['n_leaves']} leaves, template "
                f"{len(flat_t)} — incompatible trees")
        host = [np.load(d / f"leaf_{i:06d}.npy")
                for i in range(meta["n_leaves"])]
        for i, (a, t) in enumerate(zip(host, flat_t)):
            if tuple(a.shape) != tuple(t.shape):
                conv = adapt(a, t) if adapt is not None else None
                if conv is None or tuple(conv.shape) != tuple(t.shape):
                    raise ValueError(f"shape mismatch {a.shape} vs {t.shape}")
                host[i] = conv
        if shardings is not None:
            flat_s, _ = _flatten_with_paths(shardings)
            out = [jax.device_put(a, s) for a, s in zip(host, flat_s)]
        else:
            out = [jax.numpy.asarray(a) for a in host]
        return jax.tree_util.tree_unflatten(treedef, out), meta["extra"]
