"""InfiniPipe core: the paper's contribution as a host-side solver stack.

Pure Python/NumPy — no JAX imports — so planning runs on CPU workers and
overlaps with training (the paper's disaggregated solver/executor split).
"""

from .plan import (BucketKey, Chunk, ChunkKind, ClusterSpec, Coefficients,
                   ExecutionPlan, ModelSpec, PipelinePlan, SequenceInfo,
                   Slice, Tick, TickOp)
from .sp import (SPConfig, SP_POLICIES, choose_sp_policy, legal_degrees,
                 sp_candidates, sp_legal)
from .costs import CostModel, analytic_coefficients, fit_coefficients
from .chunking import ChunkingResult, chunk_sequences, seq_workload
from .ilp import IlpResult, greedy_cover, simplex_lp, solve_cover_ilp
from .checkpointing import (CkptSolution, diag_index, encoder_stage_split,
                            solve_checkpointing, stage_roles)
from .grouping import GroupingResult, group_sequences
from .schedule import (Occupancy, PipelineSimulator, ScheduleSpec, SimResult,
                       available_schedules, backward_order, build_schedule,
                       choose_schedule, enumerate_windows, get_schedule,
                       register_schedule, simulate_occupancy,
                       simulate_schedule, window_limit)
from .planner import PlannerConfig, estimate_plan_time, plan_batch

__all__ = [
    "BucketKey", "Chunk", "ChunkKind", "ClusterSpec", "Coefficients",
    "ExecutionPlan",
    "ModelSpec", "PipelinePlan", "SequenceInfo", "Slice", "Tick", "TickOp",
    "SPConfig", "SP_POLICIES", "choose_sp_policy", "legal_degrees",
    "sp_candidates", "sp_legal",
    "CostModel", "analytic_coefficients", "fit_coefficients",
    "ChunkingResult", "chunk_sequences", "seq_workload",
    "IlpResult", "greedy_cover", "simplex_lp", "solve_cover_ilp",
    "CkptSolution", "diag_index", "encoder_stage_split",
    "solve_checkpointing", "stage_roles",
    "GroupingResult", "group_sequences",
    "Occupancy", "PipelineSimulator", "ScheduleSpec", "SimResult",
    "available_schedules", "backward_order", "build_schedule",
    "choose_schedule", "enumerate_windows", "get_schedule",
    "register_schedule", "simulate_occupancy", "simulate_schedule",
    "window_limit",
    "PlannerConfig", "estimate_plan_time", "plan_batch",
]
