"""InfiniPipe cost model (paper §III-A, Eq. 1-11), adapted to TPU v5e.

The model estimates, for every chunk ``{C_k, S_k}``:

* compute time (Eq. 1)  — quadratic causal-attention term + linear term,
* SP communication time (Eq. 2-3) — Ulysses all-to-all *or* allgather-KV,
* stage-aware activation memory (Eq. 5-10) including the split-chunk dKV
  term and the chunks-window peak model,
* gradient-checkpointing recompute time (Eq. 11).

Coefficients are derived *analytically* from the architecture + hardware
specs (so the model works out of the box for any of the ten assigned
architectures), and can be *refined by regression* against measured samples
via :func:`fit_coefficients` — mirroring the paper's "built at a theoretical
standpoint, verified and refined via offline profiling and regression
fitting".

Conventions
-----------
* All times are seconds for the *whole model* pass of one chunk divided
  across the cluster exactly as Eq. 1 does: the ``1/N`` factor (``N = d_s *
  d_p``) is applied inside, the ``beta1 / d_p`` per-stage overhead added.
* ``per_stage=True`` variants return the time one pipeline stage spends on
  the chunk (the quantity a tick of the 1F1B schedule costs) — i.e. the
  whole-model time divided by ``d_p`` (stages are layer-uniform).
* Backward compute is modelled as ``bwd_mult``x forward (2.0: dgrad+wgrad).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .plan import Chunk, ClusterSpec, Coefficients, ModelSpec
from .sp import choose_sp_policy, sp_legal

__all__ = ["CostModel", "fit_coefficients", "analytic_coefficients"]

BWD_MULT = 2.0  # backward flops / forward flops


# ---------------------------------------------------------------------------
# Analytic coefficient derivation.
# ---------------------------------------------------------------------------

def _linear_flops_per_token(m: ModelSpec) -> float:
    """Forward FLOPs per token that do NOT depend on context length.

    Counts every matmul 2*MAC. Attention score/AV flops are excluded — they
    form the quadratic term. Vocabulary head included (it runs once, on the
    last stage, but Eq. 1's alpha2 is a whole-model constant).
    """
    D, Dh, Hq, Hkv = m.d_model, m.head_dim, m.n_heads, m.n_kv_heads
    per_layer = 0.0
    if not m.attn_free:
        if m.kv_lora_rank > 0:
            r, rr = m.kv_lora_rank, m.qk_rope_dim
            per_layer += 2 * D * (Hq * (Dh + rr))        # q
            per_layer += 2 * D * (r + rr)                # kv down-proj
            per_layer += 2 * r * (Hq * Dh * 2)           # k/v up-proj
            per_layer += 2 * Hq * Dh * D                 # o
        else:
            per_layer += 2 * D * Hq * Dh                 # q
            per_layer += 2 * 2 * D * Hkv * Dh            # k, v
            per_layer += 2 * Hq * Dh * D                 # o
    if m.ssm_state > 0:
        di, ds = m.inner, m.ssm_state
        per_layer += 2 * D * 2 * di                      # in-proj (x, z)
        per_layer += 2 * m.ssm_conv * di                 # depthwise conv
        per_layer += 2 * di * (2 * ds + 2)               # B, C, dt projections
        per_layer += 9 * di * ds                         # selective scan update
        per_layer += 2 * di * D                          # out-proj
    if m.n_experts > 0:
        per_layer += 2 * D * m.n_experts                 # router
        act = m.top_k + m.n_shared_experts
        per_layer += 2 * 3 * D * m.d_ff_expert * act     # routed+shared SwiGLU
    elif m.d_ff > 0:
        per_layer += 2 * 3 * D * m.d_ff                  # dense SwiGLU
    total = m.n_layers * per_layer
    total += 2 * D * m.vocab                             # LM head (last stage)
    return total


def _attn_flops_per_token_pair(m: ModelSpec) -> float:
    """Forward FLOPs per (query-token, key-position) pair, whole model.

    QK^T and AV are each 2 flops/MAC over head_dim, for every query head,
    on every *global attention* layer. Local-window layers contribute to the
    linear term instead (their context is capped at the window).
    """
    if m.attn_free:
        return 0.0
    return 4.0 * m.n_heads * m.head_dim * m.n_global_layers()


def _local_attn_flops_per_token(m: ModelSpec) -> float:
    """Sliding-window layers: attention flops per token (linear, window-capped)."""
    if m.attn_free or m.local_window <= 0:
        return 0.0
    return 4.0 * m.n_heads * m.head_dim * m.n_local_layers() * m.local_window


def _act_bytes_per_token(m: ModelSpec) -> float:
    """M_token: activation bytes per token for the whole model (no ckpt).

    Counts the tensors autodiff keeps live per layer under the flash-attn
    regime (no S^2 score materialization): layer input, normed input, q/k/v,
    attn out, o-proj out, MLP gate/up/act/down inputs. This matches the
    standard ~(18..34)*D*e per layer ballpark used by Megatron's activation
    analysis, specialised per family.
    """
    e, D = m.bytes_per_act, m.d_model
    per_layer = 0.0
    if not m.attn_free:
        qw = m.n_heads * m.head_dim
        kw = 2 * m.d_kv  # k + v as stored
        per_layer += e * (2 * D + qw + kw + qw + D)  # ln, q, k, v, attn-out, o-out
        per_layer += 4 * m.n_heads  # softmax stats (fp32 lse per token per head)
    if m.ssm_state > 0:
        di = m.inner
        per_layer += e * (2 * D + 2 * di + 3 * di)   # ln, in-proj, conv/scan/gate
    if m.n_experts > 0:
        act = m.top_k + m.n_shared_experts
        per_layer += e * (D + act * (3 * m.d_ff_expert) + D)
        per_layer += 4 * m.top_k * 2                 # router logits/weights
    elif m.d_ff > 0:
        per_layer += e * (D + 3 * m.d_ff + D)
    return m.n_layers * per_layer


def analytic_coefficients(m: ModelSpec, c: ClusterSpec,
                          ce_mode: str = "streaming") -> Coefficients:
    """Derive Eq. 1/3/5 coefficients from first principles.

    ``ce_mode`` selects the cross-entropy memory regime (paper §IV):
      * ``"naive"``     — full fp32 logits + intermediates: 8*V bytes/token.
      * ``"inplace"``   — Megatron's fused in-place CE (the paper's
                          executor): logits materialized once, grad written
                          in place: e*V + stats bytes/token.
      * ``"streaming"`` — our Pallas vocab-tiled online-logsumexp kernel
                          (beyond-paper): logits are never materialized; only
                          per-token fp32 (max, lse) stats remain.
    """
    eff = c.effective_flops * c.n_devices  # aggregate effective flops
    lin = _linear_flops_per_token(m) + _local_attn_flops_per_token(m)
    quad = _attn_flops_per_token_pair(m)
    # alpha1/alpha2 are "seconds per unit, whole model, on ONE device";
    # Eq. 1 divides by N, so scale by per-chip effective flops here.
    alpha1 = quad / c.effective_flops
    alpha2 = lin / c.effective_flops
    beta1 = 5e-6  # per-stage dispatch overhead (one fused XLA program region)
    # Ulysses all-to-all: volume/d_s per device per collective, ICI-limited.
    a2a_bw = c.ici_bw * 0.8
    ag_bw = c.ici_bw * 0.8
    if ce_mode == "streaming":
        m_logits = 16.0
    elif ce_mode == "inplace":
        m_logits = float(m.bytes_per_act * m.vocab + 8)
    else:
        m_logits = 8.0 * m.vocab
    return Coefficients(
        alpha1=alpha1,
        alpha2=alpha2,
        beta1=beta1,
        a2a_bw=a2a_bw,
        a2a_latency=1.5e-6,
        ag_bw=ag_bw,
        m_token=_act_bytes_per_token(m),
        m_logits=m_logits,
    )


# ---------------------------------------------------------------------------
# The cost model proper.
# ---------------------------------------------------------------------------


@dataclass
class CostModel:
    model: ModelSpec
    cluster: ClusterSpec
    coeffs: Optional[Coefficients] = None
    sp_policy: str = "auto"          # "none" | "ulysses" | "allgather_kv" | "auto"
    # effective SP degree d_s_eff (sub-groups of the model axis); 0 => the
    # full d_s. Tokens shard 1/d_s_eff per device and compute replicates
    # d_s/d_s_eff times — the planner trades that waste against the
    # saturation gain (utilization) and the per-layer collective cost.
    sp_degree: int = 0
    # straggler mitigation: per-stage slowdown multipliers (>= 1.0)
    stage_slowdowns: Optional[Sequence[float]] = None
    # Fig. 1(a) utilization model: tokens/SP-rank at which the MXU pipeline
    # reaches half of peak efficiency.
    sat_half: float = 256.0
    # cross-entropy memory regime (see analytic_coefficients)
    ce_mode: str = "streaming"
    # measured-recompute correction (telemetry calibration): Eq. 11's
    # analytic recompute fraction times this factor. 1.0 = analytic.
    recompute_factor: float = 1.0

    def __post_init__(self) -> None:
        if self.coeffs is None:
            self.coeffs = analytic_coefficients(self.model, self.cluster,
                                                self.ce_mode)
        if self.sp_degree == 0:
            self.sp_degree = self.cluster.d_s
        if (self.sp_degree < 1 or self.cluster.d_s % self.sp_degree
                or self.sp_degree > self.cluster.d_s):
            raise ValueError(
                f"sp_degree={self.sp_degree} must divide the model axis "
                f"d_s={self.cluster.d_s}")
        # ONE policy heuristic for cost model and runtime alike:
        # core/sp.choose_sp_policy (tests/test_sp_policy.py pins that the
        # two consumers can never diverge again)
        if self.sp_policy == "auto":
            self.sp_policy = choose_sp_policy(self.model, self.sp_degree)
        if not sp_legal(self.model, self.sp_policy, self.sp_degree):
            raise ValueError(
                f"SP policy {self.sp_policy!r} is illegal for "
                f"{self.model.name} at d_s_eff={self.sp_degree} "
                f"(heads {self.model.n_heads}/{self.model.n_kv_heads}, "
                f"mla={self.model.kv_lora_rank > 0}, "
                f"attn_free={self.model.attn_free})")
        if self.stage_slowdowns is not None:
            if len(self.stage_slowdowns) != self.cluster.d_p:
                raise ValueError("stage_slowdowns must have d_p entries")

    # -- helpers ------------------------------------------------------------
    def _slowdown(self, p: Optional[int]) -> float:
        if self.stage_slowdowns is None or p is None:
            return 1.0
        return float(self.stage_slowdowns[p - 1])

    @property
    def sp_replication(self) -> int:
        """Chunk-compute replication across the model axis: every SP
        sub-group of ``d_s_eff`` devices holds the whole chunk, so
        ``r = d_s / d_s_eff`` replicas do identical work."""
        return self.cluster.d_s // self.sp_degree

    # ------------------------------------------------------------------
    # Eq. 1: computation time.
    # ------------------------------------------------------------------
    def utilization(self, chunk: Chunk) -> float:
        """Fig. 1(a)'s computational-intensity degradation: with few tokens
        per SP rank, the MXU pipeline cannot be kept full. Saturation curve
        ``u = t / (t + t_half)`` with t = tokens per device along the SP axis,
        t_half = half-saturation point (~a few MXU tiles). A reduced
        ``d_s_eff`` leaves MORE tokens per device, so short chunks regain
        saturation — the gain the planner weighs against the replicated
        compute (:attr:`sp_replication`)."""
        tpd = chunk.tokens / self.sp_degree
        return tpd / (tpd + self.sat_half)

    def t_comp(self, chunk: Chunk, *, per_stage: bool = False,
               stage: Optional[int] = None) -> float:
        co, cl = self.coeffs, self.cluster
        C, s0 = float(chunk.context), float(chunk.s0)
        quad = (C + s0) ** 2 - C ** 2 if s0 else 0.0
        lin = s0
        for s in chunk.short_slices:
            quad += float(s.length) ** 2
            lin += float(s.length)
        # compute parallelism along the sequence axis is d_s_eff, not d_s:
        # the r = d_s/d_s_eff replicas repeat the same work
        t = (co.alpha1 * 0.5 * quad + co.alpha2 * lin) \
            * self.sp_replication / cl.n_devices
        t /= self.utilization(chunk)
        t += co.beta1 / cl.d_p
        t *= self._slowdown(stage)
        if per_stage:
            # a single stage holds L/d_p of the layers => 1/d_p of the time,
            # but beta1/d_p is already per stage.
            t = (t - co.beta1 / cl.d_p * self._slowdown(stage)) / cl.d_p \
                + co.beta1 / cl.d_p * self._slowdown(stage)
        return t

    def t_comp_bwd(self, chunk: Chunk, **kw) -> float:
        return BWD_MULT * self.t_comp(chunk, **kw)

    # ------------------------------------------------------------------
    # Eq. 2-3: SP communication.
    # ------------------------------------------------------------------
    def t_sp_comm(self, chunk: Chunk, *, per_stage: bool = False) -> float:
        """Per-layer SP communication for one chunk, whole model (or stage).

        ulysses: Eq. 3's four all-to-alls (q, k, v, attn-out). The split-chunk
        context KV is stored *head-sharded*, so attending to it is free of
        communication.

        allgather_kv: K/V of the chunk's own tokens are all-gathered across
        the "model" axis once per layer; the gathered KV is appended to a
        *replicated* context buffer, so later slices re-read it locally
        (communication is linear in chunk tokens, NOT in context — the
        memory price is the replication factor in :meth:`m_dkv`).
        """
        m, co, cl = self.model, self.coeffs, self.cluster
        d = self.sp_degree
        if m.attn_free or self.sp_policy == "none" or d <= 1:
            return 0.0
        toks = float(chunk.tokens)
        e = m.bytes_per_act
        layers = m.n_layers if not per_stage else max(1, m.n_layers // cl.d_p)
        if self.sp_policy == "ulysses":
            vol = e * 2 * (m.d_head_total + m.d_kv) * toks / d
            t_layer = vol / co.a2a_bw + 4 * co.a2a_latency
        else:
            vol = e * 2 * m.d_kv * toks * (d - 1) / d
            t_layer = vol / co.ag_bw + co.a2a_latency
        return layers * t_layer

    @property
    def kv_replication(self) -> int:
        """Context-KV replication across the FULL model axis (relative to
        a 1/d_s shard): ulysses keeps context head-sharded over its
        sub-group (``d_s/d_s_eff`` replicas); allgather_kv and "none"
        hold the whole context per device (``d_s``)."""
        if self.sp_policy == "ulysses":
            return self.cluster.d_s // self.sp_degree
        return self.cluster.d_s

    # ------------------------------------------------------------------
    # Eq. 4: total chunk time.
    # ------------------------------------------------------------------
    def t_tot(self, chunk: Chunk, *, bwd: bool = False, per_stage: bool = False,
              stage: Optional[int] = None) -> float:
        mult = BWD_MULT if bwd else 1.0
        return (mult * self.t_comp(chunk, per_stage=per_stage, stage=stage)
                + mult * self.t_sp_comm(chunk, per_stage=per_stage))

    def t_fwd_bwd(self, chunk: Chunk, l_ckpt: int = 0) -> float:
        return (self.t_tot(chunk) + self.t_tot(chunk, bwd=True)
                + self.t_recompute(chunk, l_ckpt))

    def avg_stage_times(self, chunks: Sequence[Chunk]
                        ) -> Tuple[float, float]:
        """Mean per-stage ``(t_fwd, t_bwd)`` tick durations over a chunk set
        — the inputs to the schedule backends' bubble model
        (:meth:`repro.core.schedule.ScheduleSpec.bubble_time`); the W-grad
        share of ``t_bwd`` is ``schedule.WGRAD_FRACTION``."""
        if not chunks:
            return 0.0, 0.0
        t_f = sum(self.t_tot(c, per_stage=True) for c in chunks)
        t_b = sum(self.t_tot(c, bwd=True, per_stage=True) for c in chunks)
        return t_f / len(chunks), t_b / len(chunks)

    def t_p2p(self, chunk: Chunk) -> float:
        """Stage-boundary activation hand-off for one chunk: the (token-
        sharded) hidden state over ICI plus a launch latency. The pipeline
        simulator charges it per stage crossing; the schedule picker
        charges interleaving's extra ring trips with it."""
        m, cl = self.model, self.cluster
        vol = m.bytes_per_act * m.d_model * chunk.tokens / self.sp_degree
        return vol / cl.ici_bw + 1e-6

    # ------------------------------------------------------------------
    # Eq. 11: recompute cost of checkpointing l_ckpt layers (per stage).
    # ------------------------------------------------------------------
    def t_recompute(self, chunk: Chunk, l_ckpt: int) -> float:
        """Re-running l_ckpt layers of THIS stage forward during backward.

        The paper's Eq. 11 normalizes by (L * d_s); physically the stage
        re-runs l_ckpt of its L/d_p layers, i.e. a fraction l_ckpt*d_p/L of
        the whole-model forward — which equals Eq. 11 up to the paper's
        normalization convention. We use the physical form.
        """
        if l_ckpt <= 0:
            return 0.0
        frac = min(1.0, l_ckpt * self.cluster.d_p / self.model.n_layers)
        return self.recompute_factor * frac * self.t_tot(chunk)

    def t_layer_fwd(self) -> float:
        """F-hat of Eq. 17: estimated forward time of ONE model layer for a
        workload-balanced chunk (uses the mean chunk cost; callers override
        with the actual chunk set when available)."""
        m, co, cl = self.model, self.coeffs, self.cluster
        # fall back to a 'capacity' chunk of T_m tokens
        toks = self.token_capacity()
        t = (co.alpha2 * toks) / cl.n_devices / m.n_layers
        return t

    # ------------------------------------------------------------------
    # Eq. 5 / 9 / 10: stage-aware activation memory (bytes per device).
    # ------------------------------------------------------------------
    def m_dkv(self, chunk: Chunk) -> float:
        """KV (+grad) residency for chunks whose KV has dependents (Eq. 5),
        scaled by the SP policy's context replication factor."""
        m, cl = self.model, self.cluster
        if not chunk.has_dependents or m.attn_free:
            return 0.0
        e = m.bytes_per_act
        repl = self.kv_replication
        return (repl * 2.0 * e * m.n_layers * m.d_kv / cl.n_devices) * chunk.tokens

    def m_ckpt(self, chunk: Chunk, l_ckpt: int) -> float:
        """Checkpoint storage (Eq. 9): layer inputs + un-freeable KV.
        Layer inputs are token-sharded at ``d_s_eff`` (the replication
        factor re-inflates the per-1/d_s normalization)."""
        m, cl = self.model, self.cluster
        e = m.bytes_per_act
        kv = 2 * m.d_kv * self.kv_replication if chunk.has_dependents else 0
        d_model = m.d_model * self.sp_replication
        return (e * (d_model + kv) * l_ckpt / cl.d_s) * chunk.tokens

    def m_act(self, stage: int, chunk: Chunk, l_ckpt: int = 0) -> float:
        """Eq. 10. ``stage`` is 1-based (p == d_p carries the logits)."""
        m, co, cl = self.model, self.coeffs, self.cluster
        toks = chunk.tokens
        live_frac = max(0.0, (m.n_layers - l_ckpt * cl.d_p) / m.n_layers)
        a = live_frac * co.m_token * self.sp_replication / cl.n_devices
        if stage == cl.d_p:
            a += co.m_logits / cl.d_s
        return self.m_dkv(chunk) + self.m_ckpt(chunk, l_ckpt) + a * toks

    def m_model_states(self, stage: int) -> float:
        """M_ms(p): params(bf16) + fp32 master + adam m/v + grad, ZeRO-3 over d_s.

        Stage 1 additionally hosts the (vocab-sharded) embedding; stage d_p
        the LM head when untied.
        """
        m, cl = self.model, self.cluster
        body = m.param_count() - m.vocab * m.d_model * (1 if m.tie_embeddings else 2)
        per_stage = body / cl.d_p
        if stage == 1:
            per_stage += m.vocab * m.d_model
        if stage == cl.d_p and not m.tie_embeddings:
            per_stage += m.vocab * m.d_model
        if stage == cl.d_p and m.tie_embeddings:
            per_stage += m.vocab * m.d_model  # tied head still materialized on use
        bytes_per_param = 2 + 4 + 4 + 4 + 4   # bf16 + master + m + v + fp32 grad
        return per_stage * bytes_per_param / cl.d_s

    # ------------------------------------------------------------------
    # Token capacity (Alg. 1 input C): max tokens resident at once.
    # ------------------------------------------------------------------
    def token_capacity(self) -> int:
        """Tokens whose *un-checkpointed* activations fit beside model states
        on the worst stage, for a window of d_p chunks (Eq. 7-8 worst case)."""
        m, co, cl = self.model, self.coeffs, self.cluster
        worst_ms = max(self.m_model_states(p) for p in (1, 2, cl.d_p))
        free = cl.capacity_bytes - worst_ms
        if free <= 0:
            raise ValueError(
                f"model states ({worst_ms/1e9:.1f} GB) exceed capacity "
                f"({cl.capacity_bytes/1e9:.1f} GB) — increase d_p or d_s")
        per_token = (co.m_token * self.sp_replication / cl.n_devices
                     + (2.0 * m.bytes_per_act * m.n_layers * m.d_kv
                        * self.kv_replication / cl.n_devices)
                     + co.m_logits / cl.d_s / cl.d_p)
        return int(free / per_token)

    # ------------------------------------------------------------------
    # Alg. 1 line 1: split the longest sequence into K balanced slices.
    # ------------------------------------------------------------------
    def split_balanced(self, length: int, k: int) -> List[int]:
        """Slice ``length`` into K slices of (approximately) equal *backward*
        cost under the quadratic attention model. Earlier slices are longer
        (they have less context), the tail is shortest — the paper's mesh.

        Closed form: slice boundaries are at equal increments of the
        cumulative cost function  g(x) = 0.5*alpha1*x^2 + alpha2*x.
        """
        if k <= 1 or length <= 0:
            return [length] if length > 0 else []
        a1 = self.coeffs.alpha1 * 0.5
        a2 = self.coeffs.alpha2
        total = a1 * length ** 2 + a2 * length
        bounds = [0]
        for i in range(1, k):
            target = total * i / k
            # solve a1*x^2 + a2*x = target
            if a1 > 0:
                x = (-a2 + math.sqrt(a2 * a2 + 4 * a1 * target)) / (2 * a1)
            else:
                x = target / a2 if a2 > 0 else length * i / k
            bounds.append(int(round(x)))
        bounds.append(length)
        # enforce monotone, nonzero slices (tiny sequences & large K)
        out: List[int] = []
        prev = 0
        for b in bounds[1:]:
            b = max(b, prev + 1) if b < length else b
            b = min(b, length)
            if b > prev:
                out.append(b - prev)
            prev = b
        if sum(out) != length:  # absorb rounding into the tail
            out[-1] += length - sum(out)
        return [s for s in out if s > 0]

    # convenience used throughout the scheduler
    def delta_warmup(self, chunks: Sequence[Chunk]) -> float:
        """Eq. 13's δ = (d_p - 1) * avg(T_tot) warmup-cooldown overhead."""
        if not chunks:
            return 0.0
        avg = sum(self.t_tot(c, per_stage=True)
                  + self.t_tot(c, bwd=True, per_stage=True)
                  for c in chunks) / len(chunks)
        return (self.cluster.d_p - 1) * avg

    def with_slowdowns(self, slowdowns: Sequence[float]) -> "CostModel":
        return CostModel(self.model, self.cluster, self.coeffs,
                         sp_policy=self.sp_policy, sp_degree=self.sp_degree,
                         stage_slowdowns=list(slowdowns),
                         sat_half=self.sat_half, ce_mode=self.ce_mode,
                         recompute_factor=self.recompute_factor)

    def with_sp(self, policy: str, degree: int) -> "CostModel":
        """This model re-costed at another point of the SP axis (shares
        the analytic coefficients) — the planner's sweep primitive."""
        return CostModel(self.model, self.cluster, self.coeffs,
                         sp_policy=policy, sp_degree=degree,
                         stage_slowdowns=self.stage_slowdowns,
                         sat_half=self.sat_half, ce_mode=self.ce_mode,
                         recompute_factor=self.recompute_factor)


# ---------------------------------------------------------------------------
# Regression refinement (paper: "verified and refined via offline profiling
# and regression fitting"). Samples are (chunk, measured_seconds) pairs; we
# refit (alpha1, alpha2, beta1) by least squares on the Eq. 1 basis.
# ---------------------------------------------------------------------------

def fit_coefficients(base: Coefficients, cluster: ClusterSpec,
                     samples: Iterable[Tuple[Chunk, float]]) -> Coefficients:
    rows: List[List[float]] = []
    ys: List[float] = []
    for chunk, seconds in samples:
        C, s0 = float(chunk.context), float(chunk.s0)
        quad = ((C + s0) ** 2 - C ** 2) * 0.5 if s0 else 0.0
        lin = s0
        for s in chunk.short_slices:
            quad += 0.5 * float(s.length) ** 2
            lin += float(s.length)
        rows.append([quad / cluster.n_devices, lin / cluster.n_devices,
                     1.0 / cluster.d_p])
        ys.append(seconds)
    A = np.asarray(rows, dtype=np.float64)
    y = np.asarray(ys, dtype=np.float64)
    sol, *_ = np.linalg.lstsq(A, y, rcond=None)
    a1, a2, b1 = (max(float(v), 0.0) for v in sol)
    return replace(base, alpha1=a1, alpha2=a2, beta1=b1)
