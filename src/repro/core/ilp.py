"""Integer linear programming for Alg. 2 — an in-repo replacement for SCIP.

The checkpointing ILP (Eq. 20) is an integer *covering* program:

    min  sum(x)           s.t.   A x >= b,   0 <= x <= ub,   x integer

with A >= 0 (raising any variable only helps). We solve it exactly with
branch-and-bound over a dense two-phase simplex LP relaxation, warm-started
by a greedy cover. Like the paper's SCIP setup (§V-F), a relative optimality
``gap`` (default 2%) terminates the search early with a certificate.

Problem sizes produced by InfiniPipe are tiny by ILP standards
(n + d_p - 1 <= ~100 variables, a few hundred window constraints), so a
dense NumPy simplex is more than fast enough (<10 ms typical).
"""

from __future__ import annotations

import heapq
import itertools
import math
from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

__all__ = ["IlpResult", "solve_cover_ilp", "simplex_lp", "greedy_cover"]

_EPS = 1e-9


@dataclass
class IlpResult:
    status: str                  # "optimal" | "feasible" | "infeasible"
    x: Optional[np.ndarray]      # integer solution (or None)
    objective: float
    lower_bound: float
    nodes: int = 0
    gap: float = 0.0


# ---------------------------------------------------------------------------
# Dense two-phase simplex for:  min c^T x  s.t.  A x >= b, 0 <= x <= ub.
# ---------------------------------------------------------------------------

def simplex_lp(c: np.ndarray, A: np.ndarray, b: np.ndarray,
               ub: np.ndarray, max_iter: int = 20000
               ) -> Tuple[str, Optional[np.ndarray], float]:
    """Two-phase primal simplex (Bland's rule; dense tableau).

    Returns (status, x, objective) with status in {"optimal", "infeasible"}.
    The feasible region is always bounded (box constraints), so "unbounded"
    cannot occur.
    """
    c = np.asarray(c, dtype=np.float64)
    A = np.asarray(A, dtype=np.float64).reshape(-1, c.size)
    b = np.asarray(b, dtype=np.float64)
    ub = np.asarray(ub, dtype=np.float64)
    n = c.size
    m1 = A.shape[0]

    # Standard form rows:
    #   A x - s = b          (surplus s >= 0)          [m1 rows]
    #   x + w   = ub         (slack   w >= 0)          [n  rows]
    # Negative-b covering rows are trivially satisfiable with s; but to get a
    # basic feasible start we add artificials on rows whose rhs > 0 (after
    # making rhs nonnegative).
    m = m1 + n
    ncols = n + m1 + n  # x | s | w
    T = np.zeros((m, ncols))
    rhs = np.zeros(m)
    T[:m1, :n] = A
    T[:m1, n:n + m1] = -np.eye(m1)
    rhs[:m1] = b
    T[m1:, :n] = np.eye(n)
    T[m1:, n + m1:] = np.eye(n)
    rhs[m1:] = ub

    # Make all rhs >= 0.
    neg = rhs < 0
    T[neg] *= -1.0
    rhs[neg] *= -1.0

    # Choose an initial basis: prefer natural slack columns where they carry
    # +1 coefficient; otherwise artificials.
    basis = np.full(m, -1, dtype=np.int64)
    art_cols: List[int] = []
    full = np.hstack([T, np.zeros((m, 0))])
    for i in range(m):
        # natural candidate: the surplus/slack column of this row if its sign
        # ended up +1 after the flip.
        cand = n + i if i < m1 else n + m1 + (i - m1)
        if full[i, cand] > 0.5:
            basis[i] = cand
        else:
            art_cols.append(i)
    n_art = len(art_cols)
    if n_art:
        art = np.zeros((m, n_art))
        for j, i in enumerate(art_cols):
            art[i, j] = 1.0
            basis[i] = ncols + j
        full = np.hstack([full, art])

    def _pivot(tab: np.ndarray, rhs_: np.ndarray, basis_: np.ndarray,
               obj: np.ndarray, obj_rhs: List[float], max_it: int,
               ban_from: Optional[int] = None) -> str:
        """``ban_from``: columns >= ban_from (phase-1 artificials) are barred
        from re-entering the basis once they leave it."""
        stall = 0
        banned = np.zeros(tab.shape[1], dtype=bool)
        for it in range(max_it):
            # Dantzig rule (vectorized); fall back to Bland's rule when the
            # objective stalls, which guarantees anti-cycling.
            cand_obj = np.where(banned, 0.0, obj)
            if stall < 40:
                enter = int(np.argmin(cand_obj))
                if cand_obj[enter] >= -_EPS:
                    return "optimal"
            else:
                neg = np.nonzero(cand_obj < -_EPS)[0]
                if neg.size == 0:
                    return "optimal"
                enter = int(neg[0])
            # vectorized ratio test
            col = tab[:, enter]
            mask = col > _EPS
            if not mask.any():
                return "unbounded"
            ratios = np.full(tab.shape[0], np.inf)
            ratios[mask] = rhs_[mask] / col[mask]
            best = ratios.min()
            ties = np.nonzero(ratios <= best + _EPS)[0]
            leave = int(ties[np.argmin(basis_[ties])])  # Bland tie-break
            piv = tab[leave, enter]
            tab[leave] /= piv
            rhs_[leave] /= piv
            factors = tab[:, enter].copy()
            factors[leave] = 0.0
            nz = np.abs(factors) > _EPS
            if nz.any():
                tab[nz] -= factors[nz, None] * tab[leave]
                rhs_[nz] -= factors[nz] * rhs_[leave]
            f = obj[enter]
            before = obj_rhs[0]
            if abs(f) > _EPS:
                obj -= f * tab[leave]
                obj_rhs[0] -= f * rhs_[leave]
            stall = stall + 1 if abs(obj_rhs[0] - before) <= _EPS else 0
            if ban_from is not None and basis_[leave] >= ban_from:
                banned[basis_[leave]] = True
            basis_[leave] = enter
        return "maxiter"

    # ---- phase 1: minimize sum of artificials ----
    if n_art:
        obj1 = np.zeros(full.shape[1])
        obj1[ncols:] = 1.0
        obj_rhs = [0.0]
        # price out the basic artificials
        for i in range(m):
            if basis[i] >= ncols:
                obj1 -= full[i]
                obj_rhs[0] -= rhs[i]
        st = _pivot(full, rhs, basis, obj1, obj_rhs, max_iter, ban_from=ncols)
        art_sum = float(sum(rhs[i] for i in range(m) if basis[i] >= ncols))
        if st == "maxiter" or art_sum > 1e-6:
            return "infeasible", None, math.inf
        # drive remaining artificials out of the basis if possible
        for i in range(m):
            if basis[i] >= ncols:
                for j in range(ncols):
                    if abs(full[i, j]) > 1e-7:
                        piv = full[i, j]
                        full[i] /= piv
                        rhs[i] /= piv
                        for r in range(m):
                            if r != i and abs(full[r, j]) > _EPS:
                                f = full[r, j]
                                full[r] -= f * full[i]
                                rhs[r] -= f * rhs[i]
                        basis[i] = j
                        break
        full = full[:, :ncols]

    # ---- phase 2 ----
    obj2 = np.zeros(full.shape[1])
    obj2[:n] = c
    obj_rhs = [0.0]
    for i in range(m):
        if basis[i] < full.shape[1] and abs(obj2[basis[i]]) > _EPS:
            f = obj2[basis[i]]
            obj2 -= f * full[i]
            obj_rhs[0] -= f * rhs[i]
    st = _pivot(full, rhs, basis, obj2, obj_rhs, max_iter)
    if st != "optimal":
        return "infeasible", None, math.inf
    x = np.zeros(n)
    for i in range(m):
        if basis[i] < n:
            x[basis[i]] = rhs[i]
    # Defensive verification: a correct run always satisfies these.
    tol = 1e-6 * max(1.0, float(np.abs(b).max() if b.size else 1.0))
    if ((x < -1e-7).any() or (x > ub + 1e-7).any()
            or (A @ x - b < -tol).any()):  # pragma: no cover
        raise RuntimeError("simplex returned an infeasible vertex — "
                           "numerical failure")
    return "optimal", np.clip(x, 0.0, ub), float(c @ x)


# ---------------------------------------------------------------------------
# Greedy cover: fast feasible incumbent for the B&B.
# ---------------------------------------------------------------------------

def greedy_cover(A: np.ndarray, b: np.ndarray, ub: np.ndarray
                 ) -> Optional[np.ndarray]:
    """Greedy integer cover for  A x >= b, 0 <= x <= ub  (A >= 0)."""
    A = np.asarray(A, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    n = A.shape[1]
    x = np.zeros(n)
    resid = b - A @ x
    for _ in range(int(np.sum(ub)) + n + 8):
        viol = resid > 1e-9
        if not viol.any():
            return x
        # score: total violated-residual reduction per unit of each variable
        head = np.minimum(A[viol], resid[viol, None])
        score = head.sum(axis=0)
        score[x >= ub - 1e-9] = -1.0
        j = int(np.argmax(score))
        if score[j] <= 0:
            return None  # saturated but still violated => infeasible
        # raise x_j as much as useful (cover the largest violated row it serves)
        need = 0.0
        for i in np.nonzero(viol)[0]:
            if A[i, j] > 1e-12:
                need = max(need, resid[i] / A[i, j])
        step = min(math.ceil(need - 1e-12), ub[j] - x[j])
        step = max(step, 1.0)
        x[j] = min(ub[j], x[j] + step)
        resid = b - A @ x
    return None


def _reduce_then_round(xf: np.ndarray, A: np.ndarray, b: np.ndarray,
                       ub: np.ndarray) -> Optional[np.ndarray]:
    """Round an LP solution up, then greedily decrement while feasible."""
    x = np.minimum(np.ceil(xf - 1e-9), ub)
    resid = A @ x - b
    if (resid < -1e-7).any():
        return None
    order = np.argsort(-x)
    for j in order:
        while x[j] > 0:
            col = A[:, j]
            if (resid - col < -1e-9).any():
                break
            x[j] -= 1
            resid = resid - col
    return x


# ---------------------------------------------------------------------------
# Branch and bound.
# ---------------------------------------------------------------------------

def solve_cover_ilp(A: np.ndarray, b: np.ndarray, ub: np.ndarray, *,
                    gap: float = 0.02, max_nodes: int = 2000) -> IlpResult:
    """Exact-to-``gap`` solver for  min sum(x) s.t. A x >= b, 0<=x<=ub, x∈Z."""
    A = np.atleast_2d(np.asarray(A, dtype=np.float64))
    b = np.asarray(b, dtype=np.float64).ravel()
    ub = np.asarray(ub, dtype=np.float64).ravel()
    n = ub.size
    if A.size == 0 or not (b > 1e-9).any():
        return IlpResult("optimal", np.zeros(n), 0.0, 0.0)
    # drop trivially satisfied rows
    keep = b > 1e-9
    A, b = A[keep], b[keep]
    # quick infeasibility check: even x == ub violates some row
    if (A @ ub - b < -1e-7).any():
        return IlpResult("infeasible", None, math.inf, math.inf)

    # Row equilibration: memory constraints mix ~1e9 rhs with ~1e6
    # coefficients; scaling each row by its largest entry keeps the simplex
    # well-conditioned. The feasible set (and integer optimum) is unchanged.
    scale = np.maximum(np.abs(A).max(axis=1), np.abs(b))
    scale[scale <= 0] = 1.0
    A = A / scale[:, None]
    b = b / scale

    c = np.ones(n)
    incumbent = greedy_cover(A, b, ub)
    best_obj = float(incumbent.sum()) if incumbent is not None else math.inf

    # node = (lp_bound, counter, lb_vec, ub_vec)
    counter = itertools.count()
    status0, x0, obj0 = simplex_lp(c, A, b, ub)
    if status0 != "optimal":
        if incumbent is not None:  # LP numeric trouble but greedy worked
            return IlpResult("feasible", incumbent, best_obj, 0.0)
        return IlpResult("infeasible", None, math.inf, math.inf)

    rounded = _reduce_then_round(x0, A, b, ub)
    if rounded is not None and rounded.sum() < best_obj:
        incumbent, best_obj = rounded, float(rounded.sum())

    heap: List[Tuple[float, int, np.ndarray, np.ndarray]] = [
        (obj0, next(counter), np.zeros(n), ub.copy())]
    nodes = 0
    global_lb = obj0
    while heap and nodes < max_nodes:
        lb_bound, _, lo, hi = heapq.heappop(heap)
        global_lb = lb_bound
        # Integral objective (c == 1): an absolute gap < 1 certifies optimality.
        if (best_obj <= 1e-12
                or best_obj - lb_bound < 1.0 - 1e-9
                or (best_obj - lb_bound) <= gap * max(best_obj, 1.0)):
            break
        nodes += 1
        # re-solve with node bounds: substitute x = lo + y, 0 <= y <= hi - lo
        span = hi - lo
        bb = b - A @ lo
        status, y, obj = simplex_lp(c, A, bb, span)
        if status != "optimal":
            continue
        obj += float(lo.sum())
        if obj >= best_obj - 1e-9:
            continue
        x = lo + y
        frac = np.abs(x - np.round(x))
        j = int(np.argmax(frac))
        if frac[j] < 1e-6:
            xi = np.round(x)
            if (A @ xi - b >= -1e-7).all() and xi.sum() < best_obj:
                incumbent, best_obj = xi, float(xi.sum())
            continue
        rounded = _reduce_then_round(x, A, b, ub)
        if rounded is not None and rounded.sum() < best_obj:
            incumbent, best_obj = rounded, float(rounded.sum())
        floor_v = math.floor(x[j])
        hi2 = hi.copy(); hi2[j] = floor_v
        lo2 = lo.copy(); lo2[j] = floor_v + 1
        if hi2[j] >= lo[j] - 1e-9:
            heapq.heappush(heap, (obj, next(counter), lo.copy(), hi2))
        if lo2[j] <= hi[j] + 1e-9:
            heapq.heappush(heap, (obj, next(counter), lo2, hi.copy()))

    if incumbent is None:
        return IlpResult("infeasible", None, math.inf, math.inf, nodes=nodes)
    lb = min(global_lb, best_obj)
    rel_gap = (best_obj - lb) / max(best_obj, 1.0)
    status = "optimal" if rel_gap <= gap + 1e-9 else "feasible"
    return IlpResult(status, incumbent, best_obj, lb, nodes=nodes, gap=rel_gap)
