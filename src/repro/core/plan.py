"""Core datatypes for the InfiniPipe solver stack.

Everything in ``repro.core`` is pure Python/NumPy (host-side "solver" of the
paper's disaggregated architecture, Fig. 4). JAX is deliberately not imported
here so the planner can run on CPU workers that never initialize a device
runtime, and so planning can overlap with the executor's training step.

The uniform chunk representation follows §III-A.1 of the paper: every chunk is
``{C, S}`` where ``C`` is the causal context length already processed by
preceding slices of the same sequence (0 for batched chunks) and ``S`` is the
set of slice lengths packed into the chunk.
"""

from __future__ import annotations

import dataclasses
import enum
import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, NamedTuple, Optional, Sequence, Tuple

from .sp import SPConfig

__all__ = [
    "BucketKey",
    "ChunkKind",
    "Slice",
    "Chunk",
    "SequenceInfo",
    "ModelSpec",
    "ClusterSpec",
    "Coefficients",
    "PipelinePlan",
    "ExecutionPlan",
    "SPConfig",
    "TickOp",
    "Tick",
]


class ChunkKind(str, enum.Enum):
    BATCHED = "batched"  # pack of short sequences, C == 0
    SPLIT = "split"      # one slice of a long sequence, C > 0 or more slices follow
    HYBRID = "hybrid"    # tail slice of a long sequence packed with shorts


@dataclass(frozen=True)
class Slice:
    """A contiguous token range of one logical sequence."""

    seq_id: int
    start: int          # token offset within the sequence
    length: int
    is_tail: bool       # last slice of its sequence (or a whole short sequence)

    def __post_init__(self) -> None:
        if self.length <= 0:
            raise ValueError(f"slice length must be positive, got {self.length}")
        if self.start < 0:
            raise ValueError(f"slice start must be >= 0, got {self.start}")


@dataclass(frozen=True)
class Chunk:
    """EPP micro-batch: the paper's uniform ``{C, S}`` representation.

    ``has_dependents`` is the indicator the paper writes as ``(1 - I_k)``:
    True iff some *later* chunk of the same sequence will still attend to this
    chunk's keys/values. Only non-tail split chunks have dependents; their KV
    cannot be freed under checkpointing (Eq. 9) and their dKV is materialized
    throughout the tail's backward (Eq. 5, the ``M_dkv`` term).
    """

    kind: ChunkKind
    context: int                 # C: causal context length (tokens) preceding s0
    slices: Tuple[Slice, ...]    # S (for SPLIT/HYBRID, slices[0] is s0, the sequence slice)

    def __post_init__(self) -> None:
        if self.kind is ChunkKind.BATCHED and self.context != 0:
            raise ValueError("batched chunks must have zero context")
        if self.kind is not ChunkKind.BATCHED and not self.slices:
            raise ValueError("split/hybrid chunks need at least the sequence slice")

    # -- token accounting ---------------------------------------------------
    @property
    def tokens(self) -> int:
        return sum(s.length for s in self.slices)

    @property
    def s0(self) -> int:
        """Length of the (split) sequence slice; 0 for batched chunks."""
        if self.kind is ChunkKind.BATCHED:
            return 0
        return self.slices[0].length

    @property
    def seq_id(self) -> Optional[int]:
        """The long sequence this chunk belongs to (None for batched)."""
        if self.kind is ChunkKind.BATCHED:
            return None
        return self.slices[0].seq_id

    @property
    def has_dependents(self) -> bool:
        if self.kind is ChunkKind.BATCHED:
            return False
        return not self.slices[0].is_tail

    @property
    def short_slices(self) -> Tuple[Slice, ...]:
        """Packed short sequences (everything but s0)."""
        if self.kind is ChunkKind.BATCHED:
            return self.slices
        return self.slices[1:]

    def to_json(self) -> Dict[str, Any]:
        return {
            "kind": self.kind.value,
            "context": self.context,
            "slices": [dataclasses.asdict(s) for s in self.slices],
        }

    @staticmethod
    def from_json(d: Dict[str, Any]) -> "Chunk":
        return Chunk(
            kind=ChunkKind(d["kind"]),
            context=d["context"],
            slices=tuple(Slice(**s) for s in d["slices"]),
        )


@dataclass
class SequenceInfo:
    """Per-sequence bookkeeping produced by the sequence processor."""

    seq_id: int
    length: int
    n_chunks: int            # how many chunks this sequence spans
    chunk_ids: List[int]     # indices into the global chunk list, slice order


# ---------------------------------------------------------------------------
# Specs shared between the solver and the executor.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelSpec:
    """The subset of an architecture config the cost model needs.

    All dimension names follow the paper's notation where one exists:
    ``D`` = d_model, ``D_kv`` = total KV width (n_kv_heads * head_dim), ``L`` =
    n_layers, ``e`` = bytes per activation element.
    """

    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    # --- optional family extensions ---
    n_experts: int = 0            # routed experts (0 => dense MLP)
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    kv_lora_rank: int = 0         # > 0 => MLA (deepseek): context stores latent
    qk_rope_dim: int = 0          # MLA decoupled rope dim
    ssm_state: int = 0            # > 0 => mamba mixer present
    ssm_conv: int = 4
    d_inner: int = 0              # mamba inner width (default 2*d_model)
    attn_free: bool = False       # pure SSM (falcon-mamba): no attention at all
    hybrid_parallel: bool = False # hymba: attention and mamba heads in parallel
    local_window: int = 0         # sliding-window size for local layers
    local_global_ratio: int = 0   # N local layers per 1 global layer (gemma3: 5)
    qk_norm: bool = False
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    tie_embeddings: bool = True
    bytes_per_act: int = 2        # e: bf16 activations

    # ------------------------------------------------------------------
    @property
    def d_kv(self) -> int:
        """D_kv: total key (or value) width per layer as stored for context."""
        if self.kv_lora_rank > 0:
            # MLA: the context buffer stores the compressed latent + rope key.
            # (Halved because the latent is shared by K and V; the cost model
            # multiplies KV storage by 2.)
            return (self.kv_lora_rank + self.qk_rope_dim) // 2 or 1
        if self.attn_free:
            return 0
        return self.n_kv_heads * self.head_dim

    @property
    def d_head_total(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def inner(self) -> int:
        return self.d_inner if self.d_inner else 2 * self.d_model

    def n_global_layers(self) -> int:
        """Number of full-attention (global) layers."""
        if self.attn_free:
            return 0
        if self.local_global_ratio <= 0:
            return self.n_layers
        period = self.local_global_ratio + 1
        return (self.n_layers + period - 1) // period

    def n_local_layers(self) -> int:
        if self.attn_free:
            return 0
        return self.n_layers - self.n_global_layers()

    # --- parameter counting (used for M_ms, roofline MODEL_FLOPS) --------
    def param_count(self) -> int:
        D, Dh, Hq, Hkv = self.d_model, self.head_dim, self.n_heads, self.n_kv_heads
        per_layer = 0
        if not self.attn_free:
            if self.kv_lora_rank > 0:
                r, rr = self.kv_lora_rank, self.qk_rope_dim
                per_layer += D * (Hq * (Dh + rr))                   # q proj (+rope part)
                per_layer += D * (r + rr)                           # kv down
                per_layer += r * (Hq * Dh * 2)                      # k/v up
                per_layer += Hq * Dh * D                            # o proj
            else:
                per_layer += D * Hq * Dh + 2 * D * Hkv * Dh + Hq * Dh * D
        if self.ssm_state > 0:
            di, ds = self.inner, self.ssm_state
            per_layer += D * 2 * di            # in proj (x, z)
            per_layer += di * self.ssm_conv    # conv
            per_layer += di * (2 * ds + 2)     # B, C, dt projections (approx)
            per_layer += di * D                # out proj
            per_layer += di * ds               # A
        if self.n_experts > 0:
            per_layer += D * self.n_experts    # router
            per_layer += self.n_experts * 3 * D * self.d_ff_expert
            per_layer += self.n_shared_experts * 3 * D * self.d_ff_expert
        elif self.d_ff > 0 and not (self.attn_free and self.ssm_state > 0):
            per_layer += 3 * D * self.d_ff     # SwiGLU
        per_layer += 2 * D                     # norms
        total = self.n_layers * per_layer
        if self.is_encoder_decoder:
            enc_per_layer = per_layer + D * Hq * Dh + 2 * D * Hkv * Dh + Hq * Dh * D
            total += self.n_encoder_layers * enc_per_layer
        total += self.vocab * D * (1 if self.tie_embeddings else 2)
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed top-k + shared)."""
        if self.n_experts == 0:
            return self.param_count()
        dead = (self.n_experts - self.top_k) * 3 * self.d_model * self.d_ff_expert
        return self.param_count() - self.n_layers * dead


@dataclass(frozen=True)
class ClusterSpec:
    """Target hardware. Defaults = TPU v5e per the assignment constants."""

    d_p: int = 16                # pipeline stages (mesh axis "data")
    d_s: int = 16                # SP/FSDP/EP degree (mesh axis "model")
    n_pods: int = 1              # DP over pods (mesh axis "pod")
    flops_per_chip: float = 197e12      # bf16 peak
    hbm_bytes: float = 16e9             # v5e HBM capacity
    hbm_bw: float = 819e9               # bytes/s
    ici_bw: float = 50e9                # bytes/s per link
    dcn_bw: float = 25e9 / 8            # inter-pod, per host
    mfu: float = 0.5                    # achievable fraction of peak (refined by fit)
    mem_fraction: float = 0.92          # usable fraction of HBM

    @property
    def n_devices(self) -> int:
        return self.d_p * self.d_s  # per pod (the paper's N = d_s * d_p)

    @property
    def effective_flops(self) -> float:
        return self.flops_per_chip * self.mfu

    @property
    def capacity_bytes(self) -> float:
        return self.hbm_bytes * self.mem_fraction

    def with_(self, **kw: Any) -> "ClusterSpec":
        return dataclasses.replace(self, **kw)


@dataclass
class Coefficients:
    """Regression-refined cost-model coefficients (§III-A: 'verified and
    refined via offline profiling and regression fitting').

    alpha1: seconds per (token-pair) of causal attention  [quadratic term]
    alpha2: seconds per token of position-independent work [linear term]
    beta1:  fixed per-chunk overhead per stage (launch/dispatch)
    All are *whole-model* coefficients; Eq. 1 divides by N and d_p.
    """

    alpha1: float
    alpha2: float
    beta1: float
    a2a_bw: float          # effective all-to-all bandwidth (bytes/s per device)
    a2a_latency: float     # per-collective latency (s)
    ag_bw: float           # effective all-gather bandwidth for allgather-kv SP
    m_token: float         # activation bytes per token, whole model (M_token)
    m_logits: float        # logits bytes per token (M_logits)

    def to_json(self) -> Dict[str, float]:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: Dict[str, float]) -> "Coefficients":
        return Coefficients(**d)


# ---------------------------------------------------------------------------
# Schedule / plan artifacts.
# ---------------------------------------------------------------------------


class TickOp(str, enum.Enum):
    FWD = "F"
    BWD = "B"
    BUBBLE = "."


@dataclass(frozen=True)
class Tick:
    op: TickOp
    chunk: int = -1  # chunk index within the pipeline; -1 for bubbles


@dataclass
class PipelinePlan:
    """One 1F1B pipeline: an ordered set of chunks + schedule + ckpt config."""

    chunks: List[Chunk]
    # forward execution order is list order; f2b maps fwd index -> bwd index
    f2b: List[int]
    # per-stage tick schedule (stage-major): schedule[p] is the list of Ticks
    schedule: List[List[Tick]] = field(default_factory=list)
    # ckpt[p][k]: checkpointed layers for chunk k (fwd index) at stage p
    ckpt: List[List[int]] = field(default_factory=list)
    # the diagonal variables C of Eq. 15 (len == n + d_p - 1)
    ckpt_diag: List[int] = field(default_factory=list)
    n_split: int = 1          # N_split: max #chunks of any sequence in this pipeline
    est_time: float = 0.0     # simulator makespan estimate (s)
    est_recompute: float = 0.0
    est_peak_mem: List[float] = field(default_factory=list)  # per stage (bytes)
    # schedule backend the bubble model prefers for THIS pipeline
    # (core/schedule.py registry name + virtual-stage count)
    sched_backend: str = "gpipe-1f1b"
    v_stages: int = 1

    @property
    def n_chunks(self) -> int:
        return len(self.chunks)

    @property
    def b2f(self) -> List[int]:
        inv = [0] * len(self.f2b)
        for f, b in enumerate(self.f2b):
            inv[b] = f
        return inv

    def to_json(self) -> Dict[str, Any]:
        return {
            "chunks": [c.to_json() for c in self.chunks],
            "f2b": self.f2b,
            "ckpt": self.ckpt,
            "ckpt_diag": self.ckpt_diag,
            "n_split": self.n_split,
            "est_time": self.est_time,
            "est_recompute": self.est_recompute,
            "est_peak_mem": self.est_peak_mem,
            "schedule": [[(t.op.value, t.chunk) for t in row] for row in self.schedule],
            "sched_backend": self.sched_backend,
            "v_stages": self.v_stages,
        }

    @staticmethod
    def from_json(d: Dict[str, Any]) -> "PipelinePlan":
        return PipelinePlan(
            chunks=[Chunk.from_json(c) for c in d["chunks"]],
            f2b=list(d["f2b"]),
            schedule=[[Tick(TickOp(op), ch) for op, ch in row] for row in d["schedule"]],
            ckpt=[list(r) for r in d["ckpt"]],
            ckpt_diag=list(d["ckpt_diag"]),
            n_split=d["n_split"],
            est_time=d["est_time"],
            est_recompute=d["est_recompute"],
            est_peak_mem=list(d["est_peak_mem"]),
            sched_backend=d.get("sched_backend", "gpipe-1f1b"),
            v_stages=d.get("v_stages", 1),
        )


class BucketKey(NamedTuple):
    """Compiled-executable bucket identity (``ExecutionPlan.bucket_key``).

    A ``NamedTuple`` rather than a bare tuple so consumers access fields by
    NAME — positional slicing (``key[2:4]``) broke silently when PR 2
    reordered the tuple to lead with the schedule, and only survived by
    luck. It is still a tuple: hashing, equality and iteration (compile
    cache keys, test comparisons) are unchanged.
    """

    schedule: str       # schedule backend name (leads: layout is schedule-shaped)
    v_stages: int       # virtual stages per device (interleaved-1f1b)
    n_chunks: int       # chunk count rounded UP to chunk_rounding
    cap: int            # chunk token capacity rounded up to d_s
    ctx_cap: int        # context capacity rounded up to cap
    l_ckpt: int         # max ILP recompute depth baked into the step
    ckpt: str           # canonical remat-policy digest ("uN" uniform depth
                        # N; "v<sha12>" a per-(stage, chunk) vector) — plans
                        # with different remat never alias one executable
    split_bwd: bool = False   # RESOLVED zero-bubble B/W split: "auto"
                        # resolves to the schedule backend's capability,
                        # so auto and an explicit matching force share
                        # one bucket (identical HLO) while a true
                        # override gets its own executable
    dtype: str = "bfloat16"   # compute dtype baked into the step — a
                        # float32 (--reduced) and a bf16 run must never
                        # alias one executable
    sp_policy: str = "auto"   # RESOLVED sequence-parallel policy (none /
                        # ulysses / allgather_kv). "auto" only for legacy
                        # plans that carry no SPConfig — those keep the
                        # pre-SP-axis identity (runtime rederives the
                        # policy at full degree)
    d_s_eff: int = 0    # effective SP degree (sub-groups of the model
                        # axis); 0 only for legacy sp-less plans, which
                        # bucket_key() resolves to the full d_s. The
                        # collective pattern AND the local token shapes
                        # (cap // d_s_eff) are degree-shaped, so two
                        # plans differing only here must never alias an
                        # executable or a cache-store entry


@dataclass
class ExecutionPlan:
    """The solver's full output for one global batch (per pod)."""

    pipelines: List[PipelinePlan]
    sequences: List[SequenceInfo]
    k_split: int                       # the tuned hyper-parameter K of Alg. 1
    chunk_capacity: int                # T_m rounded up to the bucket geometry
    mesh_slices: List[int]             # Alg. 1 line 1 slice-length mesh
    est_total_time: float = 0.0
    solve_time: float = 0.0
    remat_mode: str = "uniform"        # "uniform" | "per_chunk"
    # schedule backend the executor runs (one compiled program covers every
    # pipeline of the plan, so this is the cross-pipeline consensus pick;
    # per-pipeline preferences live on PipelinePlan.sched_backend)
    schedule: str = "gpipe-1f1b"
    v_stages: int = 1                  # virtual stages per device (interleaved)
    # sequence-parallel axis: (policy, d_s_eff) chosen by the planner
    # jointly with chunking/checkpointing (core/sp.py). None = legacy
    # plan solved before the SP axis existed: bucket_key() emits the
    # back-compatible ("auto", d_s) identity and the runtime rederives
    # the policy at full degree.
    sp: Optional[SPConfig] = None
    meta: Dict[str, Any] = field(default_factory=dict)

    @property
    def n_chunks(self) -> int:
        return sum(p.n_chunks for p in self.pipelines)

    @property
    def total_tokens(self) -> int:
        return sum(c.tokens for p in self.pipelines for c in p.chunks)

    def uniform_ckpt(self) -> int:
        """Max ILP l_ckpt over all (p, k): the 'uniform' executor policy."""
        best = 0
        for p in self.pipelines:
            for row in p.ckpt:
                for v in row:
                    best = max(best, v)
        return best

    def ckpt_table(self, n_chunks: Optional[int] = None
                   ) -> List[List[int]]:
        """The per-(stage, chunk) checkpoint matrix for the WHOLE plan:
        rows are pipeline stages, columns follow the executor's chunk
        order (all pipelines' chunks concatenated). ``n_chunks`` pads the
        columns to the compiled bucket's rounded chunk count — padding
        chunks are fully masked, so their remat depth is 0.
        """
        d_p = max((len(p.ckpt) for p in self.pipelines), default=0)
        cols: List[List[int]] = []
        for p in self.pipelines:
            n = p.n_chunks
            ck = p.ckpt if p.ckpt else [[0] * n for _ in range(d_p)]
            for k in range(n):
                cols.append([int(ck[r][k]) if r < len(ck) else 0
                             for r in range(d_p)])
        if n_chunks is not None:
            while len(cols) < n_chunks:
                cols.append([0] * d_p)
            cols = cols[:n_chunks]
        return [[col[r] for col in cols] for r in range(d_p)]

    def ckpt_per_stage_max(self) -> List[int]:
        """Max remat depth each stage ever applies (one entry per stage) —
        the per-stage remat axis dry-run sweep records and the train
        bootstrap log prints."""
        return [max(r) if r else 0 for r in self.ckpt_table()]

    def ckpt_policy(self, n_chunks: Optional[int] = None
                    ) -> Tuple[int, Optional[Tuple[Tuple[int, ...], ...]], str]:
        """Canonicalized remat policy for the executor: ``(l_max, table,
        digest)``.

        * ``remat_mode == "uniform"``: every (stage, chunk) remats the max
          ILP depth — ``table`` is None (static split), digest ``"uN"``.
        * vector modes (``"stage_aware"`` / legacy ``"per_chunk"``): the
          padded per-(stage, chunk) matrix — collapsed back to None (and a
          ``"uN"`` digest) when every REAL entry agrees (bucket-padding
          columns are fully-masked chunks, so their depth is arbitrary and
          must not block the collapse), because a constant vector compiles
          to exactly the uniform program and SHOULD share its executable;
          otherwise a ``"v" + sha256[:12]`` digest over the canonical
          padded row-major bytes.
        """
        l_max = self.uniform_ckpt()
        if self.remat_mode == "uniform" or not self.pipelines:
            return l_max, None, f"u{l_max}"
        flat = [v for row in self.ckpt_table() for v in row]
        if not flat or all(v == flat[0] for v in flat):
            c = flat[0] if flat else 0
            return c, None, f"u{c}"
        import hashlib
        table = self.ckpt_table(n_chunks)
        blob = json.dumps(table).encode()
        return (l_max, tuple(tuple(row) for row in table),
                "v" + hashlib.sha256(blob).hexdigest()[:12])

    def bucket_key(self, d_s: int, *, chunk_rounding: int = 8,
                   cap_quantum: int = 0, split_bwd: Any = "auto",
                   dtype: str = "bfloat16") -> BucketKey:
        """The compiled-executable bucket this plan lands in:
        :class:`BucketKey` ``(schedule, v_stages, n_chunks, cap, ctx_cap,
        l_ckpt, ckpt, split_bwd, dtype, sp_policy, d_s_eff)`` — access
        fields by name, not position.

        The schedule backend leads the key: tick count, stream routing and
        layer stacking are all schedule-shaped, so two plans that agree on
        geometry but not on schedule must NOT share an executable (a
        cross-schedule cache hit would run the wrong program).

        n_chunks rounds UP to a multiple of ``chunk_rounding`` (padding
        chunks are fully masked — zero loss/grad), cap to the SP degree
        ``d_s`` (token sharding), and ctx_cap to the capacity, so
        consecutive iterations reuse one compiled executable
        (runtime/compile_cache.py).

        ``cap_quantum`` optionally coarsens the capacity grid beyond the
        planner's bucket_rounding: long-context batches produce widely
        varying chunk capacities, so a coarser quantum trades masked
        padding tokens for executable reuse (benchmarks/run.py's
        ``cache_bucket_reuse`` measures the curve).

        ``split_bwd`` / ``dtype`` mirror the executor knobs of the same
        names (launch/train.py ``--split-bwd`` / compute dtype). Both
        change the compiled HLO without changing the geometry, so both
        are key fields — the lint pass ``plan-bucket-key`` enforces that
        every such axis stays visible here. ``split_bwd`` accepts the
        tri-state ``"auto"``/``"on"``/``"off"`` (or a bool) and stores
        the RESOLVED bool: "auto" on zero-bubble-h1 and a forced "on"
        compile the same program and share one bucket.
        """
        chunks = [c for p in self.pipelines for c in p.chunks]
        n = -(-len(chunks) // chunk_rounding) * chunk_rounding
        # the quantum itself must respect d_s alignment or cap would break
        # token sharding (cap_loc = cap // d_s)
        q = -(-max(d_s, cap_quantum) // d_s) * d_s
        cap = -(-self.chunk_capacity // q) * q
        max_ctx = max((c.context for c in chunks), default=0)
        ctx_cap = -(-(max_ctx + cap) // cap) * cap
        # the remat policy is baked into the compiled step (a constant
        # table in HLO), so its canonical digest must disambiguate the
        # bucket: two plans agreeing on geometry but not on remat would
        # otherwise warm-hit a wrong-remat executable
        l_max, _, digest = self.ckpt_policy(n)
        if isinstance(split_bwd, str):
            if split_bwd == "auto":
                # lazy import: core/schedule.py imports this module at
                # load time, so the resolution direction must defer
                from .schedule import get_schedule
                split = get_schedule(self.schedule, self.v_stages).split_bwd
            elif split_bwd in ("on", "off"):
                split = split_bwd == "on"
            else:
                raise ValueError(
                    f"split_bwd must be 'auto'/'on'/'off' or a bool, "
                    f"got {split_bwd!r}")
        else:
            split = bool(split_bwd)
        # the SP axis is part of executable identity: the collective
        # pattern (sub-group a2a vs KV all-gather vs none) and the local
        # token shapes (cap // d_s_eff) are both policy/degree-shaped.
        # Legacy sp-less plans keep the pre-axis ("auto", d_s) identity
        # so existing cache-store entries stay warm.
        sp_policy = self.sp.policy if self.sp is not None else "auto"
        d_s_eff = self.sp.d_s_eff if self.sp is not None else d_s
        return BucketKey(schedule=self.schedule, v_stages=self.v_stages,
                         n_chunks=n, cap=cap, ctx_cap=ctx_cap,
                         l_ckpt=l_max, ckpt=digest, split_bwd=split,
                         dtype=str(dtype), sp_policy=sp_policy,
                         d_s_eff=d_s_eff)

    def to_json(self) -> Dict[str, Any]:
        return {
            "pipelines": [p.to_json() for p in self.pipelines],
            "sequences": [dataclasses.asdict(s) for s in self.sequences],
            "k_split": self.k_split,
            "chunk_capacity": self.chunk_capacity,
            "mesh_slices": self.mesh_slices,
            "est_total_time": self.est_total_time,
            "solve_time": self.solve_time,
            "remat_mode": self.remat_mode,
            "schedule": self.schedule,
            "v_stages": self.v_stages,
            "sp": self.sp.to_json() if self.sp is not None else None,
            "meta": self.meta,
        }

    def dumps(self) -> str:
        return json.dumps(self.to_json())

    @staticmethod
    def loads(s: str) -> "ExecutionPlan":
        d = json.loads(s)
        return ExecutionPlan(
            pipelines=[PipelinePlan.from_json(p) for p in d["pipelines"]],
            sequences=[SequenceInfo(**q) for q in d["sequences"]],
            k_split=d["k_split"],
            chunk_capacity=d["chunk_capacity"],
            mesh_slices=list(d["mesh_slices"]),
            est_total_time=d["est_total_time"],
            solve_time=d["solve_time"],
            remat_mode=d.get("remat_mode", "uniform"),
            schedule=d.get("schedule", "gpipe-1f1b"),
            v_stages=d.get("v_stages", 1),
            sp=SPConfig.from_json(d.get("sp")),
            meta=d.get("meta", {}),
        )
