"""Stage-Aware Chunk-Level Adaptive Checkpointing — Alg. 2 / Eq. 15-20.

The key structural insight (Fig. 6b): a recompute bubble at (stage p, bwd
slot k) propagates along the schedule anti-diagonal, so checkpointing amounts
may be tied along diagonals without losing anything:

    ckpt'(p, k) = C[(d_p - p) + k']   with  k' = f2b[k]

which shrinks the variable count from ``n * d_p`` to ``n + d_p - 1`` and
makes the pipeline-time penalty exactly ``F_hat * sum(C)`` (Eq. 17): each
diagonal contributes one propagated bubble of F_hat per checkpointed layer.

The ILP (Eq. 20) minimizes ``sum(C)`` subject to every chunks window fitting
in device memory. With Eq. 19's linearization the constraint matrix is
non-negative => an integer covering program handled by ``repro.core.ilp``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .costs import CostModel
from .ilp import IlpResult, solve_cover_ilp
from .plan import Chunk, ModelSpec
from .schedule import enumerate_windows

__all__ = ["CkptSolution", "solve_checkpointing", "diag_index",
           "encoder_stage_split", "stage_roles"]


def diag_index(d_p: int, stage: int, bwd_idx: int) -> int:
    """Index into the diagonal variable vector C (stage is 1-based).

    Eq. 16: ckpt(p, k) = C[f2b[k] + d_p - p]. Range [0, n + d_p - 2].
    """
    return (d_p - stage) + bwd_idx


def encoder_stage_split(n_enc_layers: int, n_dec_layers: int,
                        d_p: int) -> Tuple[int, int]:
    """(enc_stages, dec_stages): pipeline stages holding encoder vs decoder
    layers, proportional to layer counts with both sides >= 1. The single
    source of truth — ``runtime.encdec_pipeline.encdec_stage_split``
    delegates here so the solver's stage roles and the executor's stage
    split can never drift apart."""
    total = max(1, n_enc_layers + n_dec_layers)
    enc_stages = max(1, round(d_p * n_enc_layers / total))
    enc_stages = min(enc_stages, d_p - 1)
    return enc_stages, d_p - enc_stages


def stage_roles(spec: ModelSpec, d_p: int) -> Tuple[str, ...]:
    """Per-stage role vector (1-based stage p at index p-1): ``"encoder"``
    for the leading encoder stages of an enc-dec arch, ``"decoder"``
    everywhere else. This is what makes the checkpointing ILP *stage-aware*
    across heterogeneous stages: encoder stages carry no causal KV (nothing
    un-freeable under Eq. 9), so their per-layer checkpoint saving F and
    base residency I use encoder coefficients."""
    if not spec.is_encoder_decoder or d_p <= 1:
        return ("decoder",) * d_p
    enc_st, dec_st = encoder_stage_split(spec.n_encoder_layers,
                                         spec.n_layers, d_p)
    return ("encoder",) * enc_st + ("decoder",) * dec_st


@dataclass
class CkptSolution:
    status: str                      # "optimal" | "feasible" | "infeasible"
    diag: List[int]                  # C, length n + d_p - 1
    table: List[List[int]]           # ckpt[p-1][k] per (stage, fwd chunk idx)
    recompute_time: float            # Eq. 17 pipeline-time penalty
    ilp: Optional[IlpResult] = None
    roles: Optional[Tuple[str, ...]] = None  # per-stage role vector, if any

    @property
    def total_layers(self) -> int:
        return int(sum(self.diag))

    def as_matrix(self) -> np.ndarray:
        """The per-(stage, chunk) layer-count matrix, shape (d_p, n) — the
        first-class artifact the executor consumes (rows = stages, columns
        = forward chunk indices)."""
        if not self.table:
            return np.zeros((0, 0), dtype=np.int64)
        return np.asarray(self.table, dtype=np.int64)

    def per_stage_max(self) -> List[int]:
        """Max remat depth each stage ever applies (one entry per stage) —
        the single-pipeline counterpart of
        ``ExecutionPlan.ckpt_per_stage_max()``."""
        return [int(max(row)) if row else 0 for row in self.table]


def _coefficients(cm: CostModel, chunks: Sequence[Chunk],
                  role: str = "decoder", layers: Optional[int] = None
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Alg. 2 lines 1-3: per-chunk I (base bytes) and F (bytes freed per
    checkpointed layer); plus the last-stage logits add-on.

    ``role`` selects the stage-aware coefficient set (Eq. 9-11): decoder
    stages pay the un-freeable dependent-KV residency and recover less per
    checkpointed layer (the layer input AND its KV must persist); encoder
    stages are non-causal — no context carry, no dependent KV — so every
    checkpointed layer frees the full per-layer activation slab. ``layers``
    is the layer count of that role's path (the per-layer slab is
    ``m_token / layers``): encoder stacks with ``n_encoder_layers !=
    n_layers`` free a different slab per layer than decoder stacks.
    """
    m, co, cl = cm.model, cm.coeffs, cm.cluster
    n_lay = layers if layers else m.n_layers
    n = len(chunks)
    I = np.zeros(n)
    F = np.zeros(n)
    logits = np.zeros(n)
    e = m.bytes_per_act
    repl = cm.kv_replication
    for k, c in enumerate(chunks):
        toks = c.tokens
        dep = 1.0 if (c.has_dependents and role == "decoder") else 0.0
        kv_keep = 2.0 * dep * repl * m.d_kv
        I[k] = (co.m_token / cl.n_devices
                + dep * repl * 2.0 * e * m.n_layers * m.d_kv / cl.n_devices) * toks
        per_layer_saving = (co.m_token / (n_lay * cl.d_s)
                            - e * (m.d_model + kv_keep) / cl.d_s)
        F[k] = max(0.0, per_layer_saving) * toks
        logits[k] = co.m_logits / cl.d_s * toks
    return I, F, logits


def solve_checkpointing(cm: CostModel, chunks: Sequence[Chunk],
                        f2b: Sequence[int], n_split: int, *,
                        capacity: Optional[float] = None,
                        gap: float = 0.02,
                        f_hat: Optional[float] = None,
                        max_windows_per_stage: int = 64,
                        roles: Optional[Sequence[str]] = None
                        ) -> CkptSolution:
    """Solve Eq. 20 for one 1F1B pipeline.

    ``capacity`` defaults to the cluster's usable HBM (G). ``f_hat`` is the
    per-layer forward time of a balanced chunk (Eq. 17); derived from the
    pipeline's actual chunks when not supplied. ``roles`` (optional,
    one entry per stage — see :func:`stage_roles`) switches each stage's
    memory coefficients between the encoder and decoder sets, letting the
    ILP hand encoder and decoder stages *different* checkpoint depths; the
    default is all-decoder, which reproduces the role-free problem exactly.
    """
    m, cl = cm.model, cm.cluster
    n = len(chunks)
    d_p = cl.d_p
    if roles is not None and len(roles) != d_p:
        raise ValueError(f"roles must have one entry per stage "
                         f"({d_p}), got {len(roles)}")
    if n == 0:
        return CkptSolution("optimal", [], [], 0.0,
                            roles=tuple(roles) if roles else None)
    G = capacity if capacity is not None else cl.capacity_bytes
    n_vars = n + d_p - 1
    # per-stage layer capacity: without roles the classic uniform bound;
    # with roles, encoder stages hold ceil(n_enc / enc_stages) layers and
    # decoder stages ceil(n_dec / dec_stages) — the executor's actual
    # split — so the ILP never certifies a depth a stage cannot realize
    if roles is None or "encoder" not in roles:
        stage_cap = [max(1, m.n_layers // d_p)] * d_p
        n_enc_layers = m.n_layers
    else:
        enc_st = sum(1 for r in roles if r == "encoder")
        dec_st = max(1, d_p - enc_st)
        n_enc_layers = m.n_encoder_layers if m.n_encoder_layers > 0 \
            else m.n_layers
        cap_enc = max(1, -(-n_enc_layers // max(enc_st, 1)))
        cap_dec = max(1, -(-m.n_layers // dec_st))
        stage_cap = [cap_enc if r == "encoder" else cap_dec for r in roles]

    coeff = {"decoder": _coefficients(cm, chunks, "decoder")}
    if roles is not None and "encoder" in roles:
        coeff["encoder"] = _coefficients(cm, chunks, "encoder",
                                         layers=n_enc_layers)
    windows = enumerate_windows(n, d_p, n_split, f2b)

    rows: List[np.ndarray] = []
    rhs: List[float] = []
    for p in range(1, d_p + 1):
        role = roles[p - 1] if roles is not None else "decoder"
        I, F, logits = coeff[role]
        budget = G - cm.m_model_states(p)
        stage_rows: List[Tuple[float, np.ndarray]] = []
        for w in windows[p - 1]:
            base = 0.0
            row = np.zeros(n_vars)
            for k in w:
                base += I[k] + (logits[k] if p == d_p else 0.0)
                row[diag_index(d_p, p, f2b[k])] += F[k]
            need = base - budget
            if need > 0:
                stage_rows.append((need, row))
        # Large pipelines produce hundreds of near-identical steady-state
        # windows; keep the tightest (largest residual-need) ones. The chunks
        # are workload-balanced by construction, so the binding constraints
        # are among the deepest windows.
        if len(stage_rows) > max_windows_per_stage:
            stage_rows.sort(key=lambda t: -t[0])
            stage_rows = stage_rows[:max_windows_per_stage]
        for need, row in stage_rows:
            rows.append(row)
            rhs.append(need)

    rtup = tuple(roles) if roles is not None else None
    # the diagonal tying (Eq. 16) shares one variable across several
    # (stage, chunk) cells, so each variable's bound is the TIGHTEST layer
    # capacity among the stages it serves (uniform-capacity case reduces
    # to the classic single bound)
    ub = np.full(n_vars, float(max(stage_cap)))
    for p in range(1, d_p + 1):
        capv = float(stage_cap[p - 1])
        for k in range(n):
            j = diag_index(d_p, p, f2b[k])
            if capv < ub[j]:
                ub[j] = capv
    if not rows:
        diag = [0] * n_vars
        table = [[0] * n for _ in range(d_p)]
        return CkptSolution("optimal", diag, table, 0.0, roles=rtup)

    res = solve_cover_ilp(np.vstack(rows), np.asarray(rhs), ub, gap=gap)
    if res.status == "infeasible" or res.x is None:
        return CkptSolution("infeasible", [], [], math.inf, ilp=res,
                            roles=rtup)

    diag = [int(round(v)) for v in res.x]
    table = [[0] * n for _ in range(d_p)]
    for p in range(1, d_p + 1):
        for k in range(n):
            table[p - 1][k] = diag[diag_index(d_p, p, f2b[k])]

    if f_hat is None:
        avg_fwd = sum(cm.t_tot(c) for c in chunks) / n
        f_hat = avg_fwd / m.n_layers
    recompute = f_hat * sum(diag)
    return CkptSolution(res.status, diag, table, recompute, ilp=res,
                        roles=rtup)
