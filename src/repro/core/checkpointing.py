"""Stage-Aware Chunk-Level Adaptive Checkpointing — Alg. 2 / Eq. 15-20.

The key structural insight (Fig. 6b): a recompute bubble at (stage p, bwd
slot k) propagates along the schedule anti-diagonal, so checkpointing amounts
may be tied along diagonals without losing anything:

    ckpt'(p, k) = C[(d_p - p) + k']   with  k' = f2b[k]

which shrinks the variable count from ``n * d_p`` to ``n + d_p - 1`` and
makes the pipeline-time penalty exactly ``F_hat * sum(C)`` (Eq. 17): each
diagonal contributes one propagated bubble of F_hat per checkpointed layer.

The ILP (Eq. 20) minimizes ``sum(C)`` subject to every chunks window fitting
in device memory. With Eq. 19's linearization the constraint matrix is
non-negative => an integer covering program handled by ``repro.core.ilp``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .costs import CostModel
from .ilp import IlpResult, solve_cover_ilp
from .plan import Chunk
from .schedule import enumerate_windows

__all__ = ["CkptSolution", "solve_checkpointing", "diag_index"]


def diag_index(d_p: int, stage: int, bwd_idx: int) -> int:
    """Index into the diagonal variable vector C (stage is 1-based).

    Eq. 16: ckpt(p, k) = C[f2b[k] + d_p - p]. Range [0, n + d_p - 2].
    """
    return (d_p - stage) + bwd_idx


@dataclass
class CkptSolution:
    status: str                      # "optimal" | "feasible" | "infeasible"
    diag: List[int]                  # C, length n + d_p - 1
    table: List[List[int]]           # ckpt[p-1][k] per (stage, fwd chunk idx)
    recompute_time: float            # Eq. 17 pipeline-time penalty
    ilp: Optional[IlpResult] = None

    @property
    def total_layers(self) -> int:
        return int(sum(self.diag))


def _coefficients(cm: CostModel, chunks: Sequence[Chunk]
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Alg. 2 lines 1-3: per-chunk I (base bytes) and F (bytes freed per
    checkpointed layer); plus the last-stage logits add-on."""
    m, co, cl = cm.model, cm.coeffs, cm.cluster
    n = len(chunks)
    I = np.zeros(n)
    F = np.zeros(n)
    logits = np.zeros(n)
    e = m.bytes_per_act
    repl = cm.kv_replication
    for k, c in enumerate(chunks):
        toks = c.tokens
        dep = 1.0 if c.has_dependents else 0.0
        I[k] = (co.m_token / cl.n_devices
                + dep * repl * 2.0 * e * m.n_layers * m.d_kv / cl.n_devices) * toks
        per_layer_saving = (co.m_token / (m.n_layers * cl.d_s)
                            - e * (m.d_model + 2.0 * dep * repl * m.d_kv) / cl.d_s)
        F[k] = max(0.0, per_layer_saving) * toks
        logits[k] = co.m_logits / cl.d_s * toks
    return I, F, logits


def solve_checkpointing(cm: CostModel, chunks: Sequence[Chunk],
                        f2b: Sequence[int], n_split: int, *,
                        capacity: Optional[float] = None,
                        gap: float = 0.02,
                        f_hat: Optional[float] = None,
                        max_windows_per_stage: int = 64) -> CkptSolution:
    """Solve Eq. 20 for one 1F1B pipeline.

    ``capacity`` defaults to the cluster's usable HBM (G). ``f_hat`` is the
    per-layer forward time of a balanced chunk (Eq. 17); derived from the
    pipeline's actual chunks when not supplied.
    """
    m, cl = cm.model, cm.cluster
    n = len(chunks)
    d_p = cl.d_p
    if n == 0:
        return CkptSolution("optimal", [], [], 0.0)
    G = capacity if capacity is not None else cl.capacity_bytes
    n_vars = n + d_p - 1
    layers_per_stage = max(1, m.n_layers // d_p)

    I, F, logits = _coefficients(cm, chunks)
    windows = enumerate_windows(n, d_p, n_split, f2b)

    rows: List[np.ndarray] = []
    rhs: List[float] = []
    for p in range(1, d_p + 1):
        budget = G - cm.m_model_states(p)
        stage_rows: List[Tuple[float, np.ndarray]] = []
        for w in windows[p - 1]:
            base = 0.0
            row = np.zeros(n_vars)
            for k in w:
                base += I[k] + (logits[k] if p == d_p else 0.0)
                row[diag_index(d_p, p, f2b[k])] += F[k]
            need = base - budget
            if need > 0:
                stage_rows.append((need, row))
        # Large pipelines produce hundreds of near-identical steady-state
        # windows; keep the tightest (largest residual-need) ones. The chunks
        # are workload-balanced by construction, so the binding constraints
        # are among the deepest windows.
        if len(stage_rows) > max_windows_per_stage:
            stage_rows.sort(key=lambda t: -t[0])
            stage_rows = stage_rows[:max_windows_per_stage]
        for need, row in stage_rows:
            rows.append(row)
            rhs.append(need)

    ub = np.full(n_vars, float(layers_per_stage))
    if not rows:
        diag = [0] * n_vars
        table = [[0] * n for _ in range(d_p)]
        return CkptSolution("optimal", diag, table, 0.0)

    res = solve_cover_ilp(np.vstack(rows), np.asarray(rhs), ub, gap=gap)
    if res.status == "infeasible" or res.x is None:
        return CkptSolution("infeasible", [], [], math.inf, ilp=res)

    diag = [int(round(v)) for v in res.x]
    table = [[0] * n for _ in range(d_p)]
    for p in range(1, d_p + 1):
        for k in range(n):
            table[p - 1][k] = diag[diag_index(d_p, p, f2b[k])]

    if f_hat is None:
        avg_fwd = sum(cm.t_tot(c) for c in chunks) / n
        f_hat = avg_fwd / m.n_layers
    recompute = f_hat * sum(diag)
    return CkptSolution(res.status, diag, table, recompute, ilp=res)
