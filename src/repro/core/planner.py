"""Top-level InfiniPipe solver (the "solver" box of Fig. 4).

For one global (per-pod) batch of sequence lengths it:

1. sweeps the slice-count hyper-parameter ``K`` over ``[1, d_p + 4]``
   (§III-B: automatically tuned),
2. runs Alg. 1 chunking, the Eq. 14 grouping DP (which internally solves the
   Alg. 2 checkpointing ILP per candidate pipeline),
3. scores each K by the cycle-accurate simulator's makespan summed over the
   scheduled 1F1B pipelines (gradient accumulation between them),
4. emits an :class:`ExecutionPlan` with bucketed chunk geometry so the
   executor's compiled program is reused across iterations.

The planner is pure host-side Python; `launch/train.py` overlaps it with the
executor's previous step, reproducing the paper's disaggregated architecture.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from .chunking import ChunkingResult, chunk_sequences
from .costs import CostModel
from .grouping import GroupingResult, group_sequences
from .plan import ExecutionPlan
from .schedule import build_schedule, choose_schedule
from .sp import SPConfig, sp_candidates, sp_legal

__all__ = ["plan_batch", "estimate_plan_time", "PlannerConfig"]


@dataclass
class PlannerConfig:
    k_min: int = 1
    k_max: Optional[int] = None       # default: d_p + 4 (paper's range)
    ilp_gap: float = 0.02             # SCIP-style optimality gap (§V-F)
    # remat policy the EXECUTOR applies (the ILP always solves the full
    # per-(stage, chunk) table): "uniform" collapses it to one max depth
    # (the pre-vector behavior); "stage_aware" threads the table itself
    # into the compiled step ("per_chunk" is the legacy alias)
    remat_mode: str = "uniform"       # "uniform" | "stage_aware"
    capacity_bytes: Optional[float] = None
    token_capacity: Optional[int] = None
    bucket_rounding: int = 512        # chunk-capacity bucket granularity
    fixed_k: Optional[int] = None     # pin K (Seq1F1B-style baselines)
    uniform_split: bool = False       # ablate: evenly split (w/o wbc)
    disable_ckpt: bool = False        # ablate: no checkpointing
    full_ckpt: bool = False          # ablate: checkpoint everything
    # schedule backend: None => pick per plan from the bubble model
    # (core/schedule.choose_schedule); a registry name pins it. v_stages=0
    # lets the picker choose the virtual-stage count (interleaved only),
    # a value pins it. Training runs MUST pin after the first plan — the
    # interleaved layer stacking bakes v into the parameter layout.
    schedule: Optional[str] = None
    v_stages: int = 0
    # sequence-parallel axis: "auto" sweeps every legal (policy, d_s_eff)
    # candidate (core/sp.sp_candidates) and solves the best-ranked one
    # jointly with K / chunking / checkpointing; a policy name and/or a
    # degree pins that coordinate. Like schedule pins, training runs keep
    # these fixed across steps so one compiled step per bucket suffices.
    sp_policy: str = "auto"           # "auto" | "none" | "ulysses" | ...
    sp_degree: int = 0                # 0 = auto; else must divide d_s


def _round_up(v: int, q: int) -> int:
    return ((max(v, 1) + q - 1) // q) * q


def _apply_ablations(cm: CostModel, cfg: PlannerConfig,
                     grouping: GroupingResult) -> GroupingResult:
    if cfg.disable_ckpt or cfg.full_ckpt:
        per_stage = max(1, cm.model.n_layers // cm.cluster.d_p)
        val = 0 if cfg.disable_ckpt else per_stage
        for p in grouping.pipelines:
            n = len(p.chunks)
            p.ckpt = [[val] * n for _ in range(cm.cluster.d_p)]
            p.ckpt_diag = [val] * (n + cm.cluster.d_p - 1)
            avg_fwd = sum(cm.t_tot(c) for c in p.chunks) / max(n, 1)
            p.est_recompute = (avg_fwd / cm.model.n_layers) * sum(p.ckpt_diag)
    return grouping


def _quick_estimate(cm: CostModel, chunking: ChunkingResult) -> float:
    """Cheap makespan proxy for K pre-selection: steady-state per-stage work
    plus the Eq. 13 warmup-cooldown delta (no grouping/ILP/simulation)."""
    chunks = chunking.chunks
    if not chunks:
        return 0.0
    per_stage = sum(cm.t_tot(c, per_stage=True)
                    + cm.t_tot(c, bwd=True, per_stage=True) for c in chunks)
    return per_stage + cm.delta_warmup(chunks)


def _sp_pins(cm: CostModel, cfg: PlannerConfig) -> List[SPConfig]:
    """The SP points the solver may place this plan on, best-guess first.

    With no pins this is every legal ``(policy, d_s_eff)`` pair for the
    model at the mesh's ``d_s`` (``core/sp.sp_candidates``); a
    ``cfg.sp_policy``/``cfg.sp_degree`` pin filters that set down (and a
    fully-pinned illegal combination is an error, not a fallback)."""
    d_s = cm.cluster.d_s
    if cfg.sp_degree and d_s % cfg.sp_degree:
        raise ValueError(f"sp_degree={cfg.sp_degree} does not divide the "
                         f"model-axis size d_s={d_s}")
    if cfg.sp_degree and cfg.sp_policy != "auto":
        if not sp_legal(cm.model, cfg.sp_policy, cfg.sp_degree):
            raise ValueError(
                f"pinned sp_policy={cfg.sp_policy!r} is illegal at "
                f"d_s_eff={cfg.sp_degree} for this model "
                f"(heads={cm.model.n_heads}/{cm.model.n_kv_heads}, "
                f"mla={cm.model.kv_lora_rank > 0}, "
                f"attn_free={cm.model.attn_free})")
        return [SPConfig(cfg.sp_policy, cfg.sp_degree)]
    cands = sp_candidates(cm.model, d_s)
    if cfg.sp_degree:
        cands = [c for c in cands if c.d_s_eff == cfg.sp_degree]
    if cfg.sp_policy != "auto":
        cands = [c for c in cands if c.policy == cfg.sp_policy]
    if not cands:
        raise ValueError(
            f"no legal SP candidate for pins (policy={cfg.sp_policy!r}, "
            f"degree={cfg.sp_degree}) at d_s={d_s}")
    return cands


def _rank_sp(cm: CostModel, lengths: Sequence[int], cfg: PlannerConfig,
             cands: List[SPConfig],
             sweep: Dict[str, float]) -> List[Tuple[SPConfig, CostModel]]:
    """Rank SP candidates by the cheap K-proxy at a single probe K.

    The estimate sees everything that distinguishes the candidates: the
    utilization gain of longer per-device shards, the ``sp_replication``
    compute tax of sub-degrees, the 4×a2a vs KV-all-gather comm terms,
    and — through ``token_capacity()`` — the memory pressure of KV
    replication (tighter capacity → more, shorter chunks). Ties keep the
    candidate order (higher degree first, default policy first)."""
    k_probe = cfg.fixed_k if cfg.fixed_k is not None else cm.cluster.d_p
    scored: List[Tuple[float, int, SPConfig, CostModel]] = []
    for i, sp in enumerate(cands):
        cm_c = cm.with_sp(sp.policy, sp.d_s_eff)
        try:
            est = _quick_estimate(
                cm_c, chunk_sequences(cm_c, lengths, k_probe,
                                      capacity=cfg.token_capacity))
        except (ValueError, RuntimeError):
            est = math.inf  # e.g. token_capacity() <= 0 under replication
        sweep[f"{sp.policy}@{sp.d_s_eff}"] = est
        scored.append((est, i, sp, cm_c))
    scored.sort(key=lambda t: (t[0], t[1]))
    return [(sp, cm_c) for _, _, sp, cm_c in scored]


def _solve_k_sweep(cm: CostModel, lengths: Sequence[int], cfg: PlannerConfig
                   ) -> Optional[Tuple[float, ChunkingResult, GroupingResult,
                                       Dict[int, float]]]:
    """The per-SP-point K sweep (Alg. 1 + grouping DP + ILP + simulation).
    Returns ``None`` when no K is memory-feasible at this SP point."""
    d_p = cm.cluster.d_p
    k_max = cfg.k_max if cfg.k_max is not None else d_p + 4
    ks = ([cfg.fixed_k] if cfg.fixed_k is not None
          else list(range(cfg.k_min, k_max + 1)))

    # Two-phase sweep: rank all K by a cheap analytic proxy, then run the
    # full grouping-DP + ILP + simulation only for the most promising ones
    # (falling back down the ranking if memory-infeasible).
    if len(ks) > 5:
        ranked = sorted(
            ks, key=lambda k: _quick_estimate(
                cm, chunk_sequences(cm, lengths, k,
                                    capacity=cfg.token_capacity)))
        ks = ranked

    best: Optional[Tuple[float, ChunkingResult, GroupingResult]] = None
    tried: Dict[int, float] = {}
    full_solves = 0
    for k in ks:
        if best is not None and full_solves >= 4:
            break
        full_solves += 1
        chunking = chunk_sequences(cm, lengths, k,
                                   capacity=cfg.token_capacity)
        if cfg.uniform_split and k > 1:
            chunking = _uniform_chunking(cm, lengths, k, cfg)
        grouping = group_sequences(cm, chunking, gap=cfg.ilp_gap,
                                   capacity=cfg.capacity_bytes)
        if not grouping.feasible:
            tried[k] = math.inf
            continue
        grouping = _apply_ablations(cm, cfg, grouping)
        total = sum(p.est_time for p in grouping.pipelines)
        if cfg.disable_ckpt or cfg.full_ckpt:
            # re-simulate with the forced ckpt tables
            from .schedule import PipelineSimulator
            total = 0.0
            for p in grouping.pipelines:
                res = PipelineSimulator(cm, p.chunks, p.f2b, p.n_split,
                                        p.ckpt).run()
                p.est_time = res.makespan
                p.est_peak_mem = res.per_stage_peak_mem
                total += res.makespan
        tried[k] = total
        if best is None or total < best[0]:
            best = (total, chunking, grouping)
    if best is None:
        return None
    return (*best, tried)


def estimate_plan_time(cm: CostModel, plan: ExecutionPlan) -> float:
    """Predicted step time of an EXISTING plan under ``cm``: the cycle-
    accurate simulator's makespan summed over the plan's pipelines
    (gradient accumulation runs them back to back), evaluated at the
    plan's own SP point. This is the re-planner's comparison primitive —
    it re-costs an incumbent plan under a *newly calibrated* model so the
    candidate-vs-incumbent hysteresis compares like against like."""
    from .schedule import PipelineSimulator

    cm_c = cm
    if plan.sp is not None:
        cm_c = cm.with_sp(plan.sp.policy, plan.sp.d_s_eff)
    total = 0.0
    for p in plan.pipelines:
        res = PipelineSimulator(cm_c, p.chunks, p.f2b, p.n_split,
                                p.ckpt or None).run()
        total += res.makespan
    return total


def plan_batch(cm: CostModel, lengths: Sequence[int],
               cfg: Optional[PlannerConfig] = None) -> ExecutionPlan:
    cfg = cfg or PlannerConfig()
    t0 = time.perf_counter()
    d_p = cm.cluster.d_p

    # SP is a plan axis: rank the legal (policy, d_s_eff) candidates by
    # the cheap proxy, then full-solve the best one — falling down the
    # ranking only when a point is memory-infeasible at every K. The
    # chosen CostModel (cm_c) is the one every downstream estimate,
    # schedule pick, and ILP solve sees.
    cands = _sp_pins(cm, cfg)
    sp_sweep: Dict[str, float] = {}
    if len(cands) == 1:
        order = [(cands[0], cm.with_sp(cands[0].policy, cands[0].d_s_eff))]
    else:
        order = _rank_sp(cm, lengths, cfg, cands, sp_sweep)
    solved = None
    sp = cm_c = None
    for sp, cm_c in order:
        solved = _solve_k_sweep(cm_c, lengths, cfg)
        if solved is not None:
            break
    if solved is None:
        raise RuntimeError(
            f"no feasible plan at any SP point in "
            f"{[(c.policy, c.d_s_eff) for c in cands]}; "
            f"lengths={list(lengths)[:8]}…")

    total, chunking, grouping, tried = solved
    cap = _round_up(max(chunking.max_chunk_tokens, 1), cfg.bucket_rounding)
    for p in grouping.pipelines:
        p.schedule = build_schedule(len(p.chunks), d_p, p.n_split, p.f2b)
    sched_name, v_stages = _pick_schedules(cm_c, grouping.pipelines, cfg)
    plan = ExecutionPlan(
        pipelines=grouping.pipelines,
        sequences=chunking.sequences,
        k_split=chunking.k_split,
        chunk_capacity=cap,
        mesh_slices=chunking.mesh,
        est_total_time=total,
        solve_time=time.perf_counter() - t0,
        remat_mode=cfg.remat_mode,
        schedule=sched_name,
        v_stages=v_stages,
        sp=sp,
        meta={"k_sweep": {str(k): v for k, v in tried.items()},
              "sp_policy": cm_c.sp_policy,
              "sp_sweep": sp_sweep},
    )
    return plan


def _pick_schedules(cm: CostModel, pipelines, cfg: PlannerConfig
                    ) -> Tuple[str, int]:
    """Schedule-backend selection from the bubble model.

    Each pipeline records its own preferred backend
    (``PipelinePlan.sched_backend``); the plan-level pick — the one the
    single compiled executable actually runs, and the one ``bucket_key()``
    carries — minimizes the summed *realized* executor bubble across
    pipelines. The realized model is backend-capability-aware: with the
    executor's B/W backward split compiled in (``schedule.
    SPLIT_BWD_REALIZED``, the default), zero-bubble-h1's realized bubble
    is ``(d_p-1)(t_f+t_b-t_w)`` — its W-grad cooldown fill exists in the
    HLO — so it competes on real footing; with the split disabled it
    falls back to the fused wasted-slot model and never shadows
    interleaving's gain. A pinned ``cfg.schedule`` restricts the
    candidates to that backend
    (with the ``v`` sweep still running for interleaved unless ``v_stages``
    pins it too); a pinned ``v_stages`` — including an explicit 1 — is
    honored, and one that cannot divide the stage's layer block is an
    error, not a silent fallback.
    """
    from .schedule import (candidate_schedules, rank_schedule,
                           schedule_tiebreak)

    d_p = cm.cluster.d_p
    l_s = max(1, -(-cm.model.n_layers // d_p))
    if cfg.v_stages > 1 and l_s % cfg.v_stages:
        raise ValueError(
            f"v_stages={cfg.v_stages} does not divide layers_per_stage="
            f"{l_s} (n_layers={cm.model.n_layers}, d_p={d_p})")
    candidates = candidate_schedules(l_s, schedule=cfg.schedule,
                                     v_stages=cfg.v_stages)

    times = [cm.avg_stage_times(p.chunks) for p in pipelines]
    p2ps = [sum(cm.t_p2p(c) for c in p.chunks) / max(len(p.chunks), 1)
            for p in pipelines]
    for p, tfb, t_p in zip(pipelines, times, p2ps):
        best = choose_schedule(cm, p.chunks, layers_per_stage=l_s,
                               candidates=candidates, avg_times=tfb,
                               avg_p2p=t_p)
        p.sched_backend, p.v_stages = best.name, best.v

    def total_cost(spec) -> Tuple[float, int, str]:
        tot = sum(rank_schedule(spec, len(p.chunks), d_p, t_f, t_b, t_p)[0]
                  for p, (t_f, t_b), t_p in zip(pipelines, times, p2ps))
        return (tot, *schedule_tiebreak(spec))

    best = min(candidates, key=total_cost)
    return best.name, best.v


def _uniform_chunking(cm: CostModel, lengths: Sequence[int], k: int,
                      cfg: PlannerConfig) -> ChunkingResult:
    """'w/o wbc' ablation + the Seq1F1B baseline: split every long sequence
    into K *equal-length* slices and pack shorts into fixed-size chunks."""
    from .plan import Chunk, ChunkKind, SequenceInfo, Slice

    max_len = max(lengths)
    slice_len = (max_len + k - 1) // k
    chunks: List = []
    seqinfos: List[SequenceInfo] = []
    order = sorted(range(len(lengths)), key=lambda i: -lengths[i])
    pack: List[Slice] = []
    pack_tokens = 0

    def flush_pack() -> None:
        nonlocal pack, pack_tokens
        if pack:
            chunks.append(Chunk(kind=ChunkKind.BATCHED, context=0,
                                slices=tuple(pack)))
            pack, pack_tokens = [], 0

    for sid in order:
        ln = lengths[sid]
        if ln > slice_len:
            ids = []
            off = 0
            while off < ln:
                cur = min(slice_len, ln - off)
                sl = Slice(seq_id=sid, start=off, length=cur,
                           is_tail=(off + cur == ln))
                chunks.append(Chunk(kind=ChunkKind.SPLIT, context=off,
                                    slices=(sl,)))
                ids.append(len(chunks) - 1)
                off += cur
            seqinfos.append(SequenceInfo(sid, ln, len(ids), ids))
        else:
            if pack_tokens + ln > slice_len:
                flush_pack()
            pack.append(Slice(seq_id=sid, start=0, length=ln, is_tail=True))
            pack_tokens += ln
            seqinfos.append(SequenceInfo(sid, ln, 1, []))
    flush_pack()
    # fix chunk ids for packed sequences
    for ci, c in enumerate(chunks):
        for sl in c.slices:
            for si in seqinfos:
                if si.seq_id == sl.seq_id and not si.chunk_ids:
                    si.chunk_ids = [ci]
    return ChunkingResult(chunks=chunks, sequences=seqinfos,
                          mesh=[slice_len] * k, t_t=0.0, t_m=slice_len,
                          k_split=k)
