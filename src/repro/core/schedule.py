"""Schedule backends, 1F1B schedule construction, chunks-window
enumeration, and pipeline simulators.

Four consumers:

1. :class:`ScheduleSpec` / :func:`get_schedule` name a pipeline schedule
   backend (``gpipe-1f1b``, ``interleaved-1f1b``, ``zero-bubble-h1``) and
   own its *executor geometry*: the forward ``lax.scan`` tick count, the
   per-tick ``(item, virtual-stage)`` mapping every device follows, and the
   bubble fraction both imply. :func:`simulate_occupancy` replays the
   mapping tick by tick (the parity oracle the executor is tested against)
   and :func:`simulate_schedule` is a unit-duration event simulator that
   also models zero-bubble B-grad/W-grad splitting.
2. :func:`enumerate_windows` feeds Alg. 2's ILP the distinct chunks windows
   ``W_p(t)`` (Eq. 7-8). Window *content* is duration-independent — it only
   depends on the per-stage op order, which the 1F1B policy fixes — so the
   ILP never needs timing.
3. :func:`build_schedule` emits the per-stage tick list the executor and the
   simulator share.
4. :class:`PipelineSimulator` is an event-driven simulator with true chunk
   durations (from the cost model) and token-level-PP dependencies. It
   produces makespan, per-stage bubble ratios, a time breakdown
   (compute / SP-comm / P2P / bubble / recompute) and per-stage peak memory —
   the measurement substrate for the paper-figure benchmarks (Figs. 7-12)
   and the straggler-mitigation loop.

Token-level PP dependency (§II-A): forward of slice i must follow forward of
slices < i of the same sequence; backward of slice i must follow backward of
slices > i. Both are encoded via the fwd order (slices emitted causally) and
the ``f2b`` map (slices reversed within each sequence).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import (Callable, Dict, FrozenSet, List, Optional, Sequence, Set,
                    Tuple)

from .costs import CostModel
from .plan import Chunk, Tick, TickOp

__all__ = [
    "ScheduleSpec",
    "Occupancy",
    "available_schedules",
    "get_schedule",
    "register_schedule",
    "stream_perm",
    "simulate_occupancy",
    "simulate_schedule",
    "candidate_schedules",
    "choose_schedule",
    "rank_schedule",
    "schedule_tiebreak",
    "backward_order",
    "enumerate_windows",
    "build_schedule",
    "PipelineSimulator",
    "SimResult",
]

# fraction of one backward pass that is weight-grad work (dgrad ~= wgrad for
# matmul-dominated transformer layers) — the zero-bubble split point
WGRAD_FRACTION = 0.5

# Executor capability: the compiled step realizes the B-grad/W-grad split for
# ``split_bwd`` backends (runtime/executor.py's split-backward stage wrapper +
# W-drain tick map). With this on, ``realized_bubble_time`` prices ZB-H1's
# W-grad fill instead of collapsing it to plain 1F1B — tests monkeypatch it
# to model executors without the split path.
SPLIT_BWD_REALIZED = True


# ---------------------------------------------------------------------------
# Schedule backends.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ScheduleSpec:
    """One named pipeline schedule backend over the StageProgram executor.

    A spec owns the *geometry* a schedule imposes on the executor's lockstep
    forward scan:

    * :meth:`scan_ticks` — how many ticks the ``lax.scan`` runs;
    * :meth:`tick_coords` — which ``(item, virtual-stage)`` device ``p``
      works on at tick ``t`` (the mapping the executor mirrors in traced
      arithmetic — ``tests/test_schedule_backends.py`` keeps them equal);
    * :meth:`scan_bubble_fraction` — the fraction of ``(device, tick)``
      slots that are bubbles, i.e. the compiled-FLOPs inflation of the
      lockstep-SPMD program (``(n + d_p - 1)/n`` for plain 1F1B);
    * :meth:`bubble_time` — the per-stage idle seconds of one fwd+bwd
      iteration under schedule theory, the planner's selection objective.

    ``v`` is the number of virtual stages per device (``interleaved-1f1b``;
    1 otherwise). ``split_bwd`` marks zero-bubble schedules whose backward
    splits into a B-grad (activation-grad) tick on the critical path and a
    W-grad (weight-grad) tick that fills trailing bubbles.
    """

    name: str
    v: int = 1
    split_bwd: bool = False

    def __post_init__(self) -> None:
        if self.v < 1:
            raise ValueError(f"v must be >= 1, got {self.v}")

    # -- executor geometry --------------------------------------------------
    def n_groups(self, n_items: int, d_p: int) -> int:
        """Interleaved round-robin groups: microbatches advance through the
        ``v * d_p`` virtual-stage ring in groups of ``d_p``."""
        return -(-n_items // d_p) if n_items > 0 else 0

    def scan_ticks(self, n_items: int, d_p: int) -> int:
        """Tick count of the executor's forward ``lax.scan``.

        ``v == 1``: the classic ``n + d_p - 1``. ``v > 1``: every device
        runs each item once per virtual stage (``n * v`` useful ticks,
        rounded up to whole groups) plus the ``d_p - 1`` fill diagonal —
        each tick now being ``1/v`` of a stage, which is where interleaving
        wins: the fill is paid in short ticks.
        """
        if n_items <= 0:
            return 0
        if self.v == 1:
            return n_items + d_p - 1
        return self.n_groups(n_items, d_p) * self.v * d_p + d_p - 1

    def drain_ticks(self, n_items: int, d_p: int) -> int:
        """W-grad drain ticks appended to the tick map by ``split_bwd``
        backends: one per (item, virtual stage) a device runs. In the
        compiled program these are a primal no-op scan *preceding* the
        forward scan whose autodiff transpose runs *after* every B-grad
        tick — the backward cooldown — popping the per-item weight-grad
        stash (see runtime/executor.py). Zero for fused backends."""
        if not self.split_bwd or n_items <= 0:
            return 0
        return n_items * self.v

    def total_ticks(self, n_items: int, d_p: int) -> int:
        """Forward-scan ticks plus the split-backward drain ticks — the
        tick count of the whole stage program."""
        return self.scan_ticks(n_items, d_p) + self.drain_ticks(n_items, d_p)

    def tick_coords(self, t: int, p: int, n_items: int,
                    d_p: int) -> Tuple[int, int, bool]:
        """``(item, v_idx, valid)`` device ``p`` handles at forward tick
        ``t``. Pure-python mirror of the executor's traced mapping.

        ``v == 1``: the classic diagonal ``item = t - p``. ``v > 1``: with
        wave index ``u = t - p``, round ``r = u // d_p`` and in-round
        offset ``q = u % d_p``, the device runs local virtual stage
        ``j = r % v`` on item ``m = (r // v) * d_p + q`` — i.e. microbatches
        advance through the ``v * d_p`` virtual-stage ring (global virtual
        stage ``j * d_p + p``) in round-robin groups of ``d_p``.
        """
        u = t - p
        if self.v == 1:
            return u, 0, (0 <= u < n_items)
        lim = self.n_groups(n_items, d_p) * self.v * d_p
        if not 0 <= u < lim:
            return -1, 0, False
        r, q = divmod(u, d_p)
        j = r % self.v
        m = (r // self.v) * d_p + q
        return m, j, m < n_items

    # -- bubble models ------------------------------------------------------
    def scan_bubble_fraction(self, n_items: int, d_p: int) -> float:
        """Bubble share of the lockstep forward scan: wasted
        ``(device, tick)`` slots over total. Useful ticks per device are
        ``n * v`` (each item visits each of the device's virtual stages
        once); everything else computes masked garbage. Equal to what
        :func:`simulate_occupancy` measures — tested."""
        ticks = self.scan_ticks(n_items, d_p)
        if ticks <= 0:
            return 0.0
        return 1.0 - (n_items * self.v) / ticks

    def bubble_time(self, n_items: int, d_p: int, t_f: float, t_b: float,
                    t_w: Optional[float] = None) -> float:
        """Per-stage idle seconds of one fwd+bwd iteration — the planner's
        schedule-selection objective.

        * ``gpipe-1f1b``: the classic ``(d_p - 1) * (t_f + t_b)`` ramp.
        * ``interleaved-1f1b``: every wasted scan slot costs ``1/v`` of a
          stage's fwd+bwd, so ``wasted * (t_f + t_b) / v`` — the
          ``(d_p - 1)/v`` Megatron interleaving gain, plus the exact
          group-padding waste when ``d_p`` does not divide ``n``.
        * ``zero-bubble-h1``: B splits into B-grad (``t_b - t_w``, critical
          path) and W-grad (``t_w``, fills the cooldown), leaving
          ``(d_p - 1) * (t_f + t_b - 2 t_w)`` — one third of 1F1B's bubble
          at ``t_b = 2 t_f``, ``t_w = t_b / 2`` (ZB-H1).
        """
        if n_items <= 0 or d_p <= 1:
            return 0.0
        if self.split_bwd:
            if t_w is None:
                t_w = WGRAD_FRACTION * t_b
            return (d_p - 1) * max(t_f + t_b - 2.0 * t_w, 0.0)
        wasted = self.scan_ticks(n_items, d_p) - n_items * self.v
        return wasted * (t_f + t_b) / self.v

    def bubble_fraction(self, n_items: int, d_p: int, t_f: float = 1.0,
                        t_b: float = 2.0,
                        t_w: Optional[float] = None) -> float:
        """``bubble_time`` normalized by per-stage makespan (work + idle)."""
        work = n_items * (t_f + t_b)
        if work <= 0:
            return 0.0
        bub = self.bubble_time(n_items, d_p, t_f, t_b, t_w)
        return bub / (work + bub)

    def realized_bubble_time(self, n_items: int, d_p: int, t_f: float,
                             t_b: float, t_w: Optional[float] = None,
                             split_realized: Optional[bool] = None) -> float:
        """Per-stage idle seconds the lockstep-SPMD executor actually
        realizes: wasted scan slots at ``1/v`` of a stage's fwd+bwd each.

        For ``split_bwd`` backends this is backend-capability-aware
        (``split_realized``, default the module's
        :data:`SPLIT_BWD_REALIZED`). With the split compiled
        (runtime/executor.py): B-grad ticks genuinely drop the weight-grad
        work from the critical path and the W-drain ticks are bubble-free
        (stash slots hold real items only), but the lockstep scan cannot
        retask its own (d_p - 1) cooldown garbage B-ticks — every tick runs
        the same HLO — so the realized bubble is

            (d_p - 1) * (t_f + t_b - t_w)

        sitting between :meth:`bubble_time`'s ideal
        ``(d_p - 1) * (t_f + t_b - 2 t_w)`` (free-form W placement) and
        plain 1F1B's ``(d_p - 1) * (t_f + t_b)``; the two converge as the
        weight-grad share shrinks — exactly the long-context regime, where
        attention dgrad is O(T^2 d) but wgrad only O(T d^2). Without the
        capability, W stays fused in the autodiff transpose and the
        realized bubble equals plain 1F1B's. The planner's default pick
        ranks by THIS, so a modeled-but-unpaid advantage can never shadow
        interleaving's real one.
        """
        if n_items <= 0 or d_p <= 1:
            return 0.0
        if split_realized is None:
            split_realized = SPLIT_BWD_REALIZED
        if self.split_bwd and split_realized:
            if t_w is None:
                t_w = WGRAD_FRACTION * t_b
            return (d_p - 1) * max(t_f + t_b - t_w, 0.0)
        wasted = self.scan_ticks(n_items, d_p) - n_items * self.v
        return wasted * (t_f + t_b) / self.v

    def comm_overhead_time(self, n_items: int, d_p: int,
                           t_p2p: float) -> float:
        """Extra stream hand-off seconds vs the ``v = 1`` diagonal.

        Interleaving sends the same activations around the ring once per
        virtual stage (forward + the backward transpose), so every scan
        tick beyond the ``n + d_p - 1`` baseline pays one more chunk
        hand-off each way — the price that caps how far raising ``v``
        keeps paying off.
        """
        if n_items <= 0 or d_p <= 1:
            return 0.0
        extra = self.scan_ticks(n_items, d_p) - (n_items + d_p - 1)
        return 2.0 * extra * t_p2p


_SCHEDULE_REGISTRY: Dict[str, Callable[[int], ScheduleSpec]] = {}


def register_schedule(name: str,
                      factory: Callable[[int], ScheduleSpec]) -> None:
    """Register a schedule backend: ``factory(v) -> ScheduleSpec``."""
    _SCHEDULE_REGISTRY[name] = factory


def available_schedules() -> Tuple[str, ...]:
    return tuple(sorted(_SCHEDULE_REGISTRY))


def get_schedule(name: str, v: int = 1) -> ScheduleSpec:
    """Resolve a schedule name (+ virtual-stage count) to its spec."""
    try:
        factory = _SCHEDULE_REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown schedule {name!r}; known: {available_schedules()}")
    return factory(v)


def _mk_gpipe(v: int) -> ScheduleSpec:
    if v != 1:
        raise ValueError("gpipe-1f1b has no virtual stages (v must be 1)")
    return ScheduleSpec("gpipe-1f1b")


def _mk_interleaved(v: int) -> ScheduleSpec:
    return ScheduleSpec("interleaved-1f1b", v=v)


def _mk_zb_h1(v: int) -> ScheduleSpec:
    if v != 1:
        raise ValueError("zero-bubble-h1 has no virtual stages (v must be 1)")
    return ScheduleSpec("zero-bubble-h1", split_bwd=True)


register_schedule("gpipe-1f1b", _mk_gpipe)
register_schedule("interleaved-1f1b", _mk_interleaved)
register_schedule("zero-bubble-h1", _mk_zb_h1)


def stream_perm(d_p: int, *, ring: bool = False) -> List[Tuple[int, int]]:
    """(src, dst) pairs of the stage hand-off ppermute: every stream
    moves stage ``p -> p + 1``; ``ring=True`` closes the loop
    (``d_p - 1 -> 0``) for interleaved virtual-stage routing.

    This is the single definition both the executor
    (``runtime/executor.ppermute_streams``) and the plan lint pass
    (``lint/plan_checks``: ``plan-ppermute-ring``) consume, so the
    audited permutation is by construction the one that runs."""
    if d_p <= 1:
        return []
    if ring:
        return [(i, (i + 1) % d_p) for i in range(d_p)]
    return [(i, i + 1) for i in range(d_p - 1)]


@dataclass
class Occupancy:
    """Tick-by-tick forward-scan occupancy of one schedule backend."""

    spec: ScheduleSpec
    n_items: int
    d_p: int
    # grid[t][p] = (item, v_idx) or None for a bubble slot
    grid: List[List[Optional[Tuple[int, int]]]]

    @property
    def total_slots(self) -> int:
        return len(self.grid) * self.d_p

    @property
    def useful_slots(self) -> int:
        return sum(1 for row in self.grid for cell in row if cell is not None)

    @property
    def bubble_fraction(self) -> float:
        return (1.0 - self.useful_slots / self.total_slots
                if self.total_slots else 0.0)

    def render(self) -> str:
        """ASCII tick-occupancy diagram (stages as rows, ticks as columns):
        ``m`` for item m at v_idx 0, ``m'``/``m"`` for higher virtual
        stages, ``.`` for bubbles."""
        marks = ["", "'", '"', "`"]
        lines = []
        for p in range(self.d_p):
            cells = []
            for t in range(len(self.grid)):
                cell = self.grid[t][p]
                if cell is None:
                    cells.append(".")
                else:
                    m, j = cell
                    cells.append(f"{m}{marks[j % len(marks)]}")
            lines.append(f"p{p}: " + " ".join(f"{c:>3}" for c in cells))
        return "\n".join(lines)


def simulate_occupancy(spec: ScheduleSpec, n_items: int,
                       d_p: int) -> Occupancy:
    """Replay ``spec.tick_coords`` over the whole forward scan.

    Verifies the mapping is a schedule at all: every device handles every
    ``(item, v_idx)`` pair exactly once, virtual stages of one item run in
    causal ring order. Raises on violations — this is the oracle the traced
    executor mapping is tested against.
    """
    ticks = spec.scan_ticks(n_items, d_p)
    grid: List[List[Optional[Tuple[int, int]]]] = []
    seen: Dict[int, Set[Tuple[int, int]]] = {p: set() for p in range(d_p)}
    for t in range(ticks):
        row: List[Optional[Tuple[int, int]]] = []
        for p in range(d_p):
            m, j, valid = spec.tick_coords(t, p, n_items, d_p)
            if not valid:
                row.append(None)
                continue
            if not (0 <= m < n_items and 0 <= j < spec.v):
                raise ValueError(f"out-of-range coords {(m, j)} at {(t, p)}")
            if (m, j) in seen[p]:
                raise ValueError(f"device {p} repeats {(m, j)}")
            seen[p].add((m, j))
            row.append((m, j))
        grid.append(row)
    for p in range(d_p):
        if len(seen[p]) != n_items * spec.v:
            raise ValueError(
                f"device {p} covered {len(seen[p])} of "
                f"{n_items * spec.v} (item, v_idx) pairs")
    return Occupancy(spec, n_items, d_p, grid)


def simulate_schedule(spec: ScheduleSpec, n_items: int, d_p: int,
                      t_f: float = 1.0, t_b: float = 2.0,
                      t_w: Optional[float] = None) -> Dict[str, float]:
    """Event-driven fwd+bwd makespan of one schedule with uniform op
    durations — the validation substrate for :meth:`ScheduleSpec.bubble_time`.

    Dependencies: ``F(p, m)`` after ``F(p-1, m)``; ``B(p, m)`` (activation
    grad) after ``B(p+1, m)`` and the stage's own ``F``; ``W(p, m)`` (weight
    grad, ``split_bwd`` only) after ``B(p, m)``, schedulable whenever the
    stage would otherwise idle — ZB-H1's bubble filling. Virtual stages
    (``v > 1``) run on the global ``v * d_p`` ring with per-tick durations
    scaled by ``1/v``. Returns makespan, per-stage bubble time and fraction.
    """
    if n_items <= 0:
        return {"makespan": 0.0, "bubble_time": 0.0, "bubble_fraction": 0.0}
    v = spec.v
    if spec.split_bwd:
        if t_w is None:
            t_w = WGRAD_FRACTION * t_b
        dur = {"F": t_f, "B": t_b - t_w, "W": t_w}
    else:
        t_w = 0.0
        dur = {"F": t_f / v, "B": t_b / v}
    S = v * d_p  # virtual stages, stage s on device s % d_p
    f_done: Dict[Tuple[int, int], float] = {}
    b_done: Dict[Tuple[int, int], float] = {}
    w_left = {(s, m) for s in range(S) for m in range(n_items)} \
        if spec.split_bwd else set()
    nf = [0] * S           # next fwd item per virtual stage
    nb = [0] * S           # next bwd item per virtual stage
    free = [0.0] * d_p
    busy = [0.0] * d_p

    def f_ready(s: int, m: int) -> Optional[float]:
        if m >= n_items:
            return None
        return f_done.get((s - 1, m), 0.0) if s > 0 else 0.0

    def b_ready(s: int, m: int) -> Optional[float]:
        if m >= n_items or (s, m) not in f_done:
            return None
        return f_done[(s, m)] if s == S - 1 else b_done.get((s + 1, m))

    total_ops = n_items * S * (3 if spec.split_bwd else 2)
    done_ops = 0
    while done_ops < total_ops:
        # pick the globally earliest-startable op; per-device 1F1B priority:
        # once the Eq. 7 in-flight window fills (or fwds are exhausted) B
        # beats F at equal start times; W only fills otherwise-idle time.
        cands = []  # (start, priority, kind, s, m)
        for s in range(S):
            p = s % d_p
            cap = S - s  # Eq. 7 on the virtual-stage ring (N_split = 1)
            want_bwd = (nf[s] - nb[s]) >= cap or nf[s] >= n_items
            rb = b_ready(s, nb[s]) if nb[s] < n_items else None
            rf = f_ready(s, nf[s]) if nf[s] < n_items else None
            if rb is not None:
                cands.append((max(rb, free[p]), 0 if want_bwd else 1,
                              "B", s, nb[s]))
            if rf is not None:
                cands.append((max(rf, free[p]), 1 if want_bwd else 0,
                              "F", s, nf[s]))
        for (s, m) in w_left:
            rb = b_done.get((s, m))
            if rb is not None:
                cands.append((max(rb, free[s % d_p]), 2, "W", s, m))
        if not cands:
            raise RuntimeError("schedule simulator deadlock")
        start, _pri, kind, s, m = min(cands)
        p = s % d_p
        d = dur[kind]
        free[p] = start + d
        busy[p] += d
        done_ops += 1
        if kind == "F":
            f_done[(s, m)] = start + d
            nf[s] += 1
        elif kind == "B":
            b_done[(s, m)] = start + d
            nb[s] += 1
        else:
            w_left.discard((s, m))
    makespan = max(free)
    idle = sum(makespan - b for b in busy)
    return {
        "makespan": makespan,
        "bubble_time": idle / d_p,
        "bubble_fraction": idle / (d_p * makespan) if makespan else 0.0,
    }


def candidate_schedules(layers_per_stage: int, *,
                        schedule: Optional[str] = None,
                        v_stages: int = 0) -> List[ScheduleSpec]:
    """Candidate specs for schedule selection.

    Default (nothing pinned): every registered backend, interleaved swept
    over the divisors of ``layers_per_stage``. A pinned ``schedule``
    restricts to that backend (the ``v`` sweep stays on for interleaved
    unless ``v_stages`` pins it too). A pinned ``v_stages`` is honored
    strictly: ``1`` keeps only single-virtual-stage backends, ``> 1``
    implies interleaving at exactly that ``v`` (no other backend has
    virtual stages, so the pin cannot silently fall back to ``v = 1``).
    The one place both ``choose_schedule`` and the planner's consensus
    pick get their candidate set from.
    """
    l_s = max(1, layers_per_stage)
    divisors = [v for v in range(2, l_s + 1) if l_s % v == 0]
    if schedule == "interleaved-1f1b" or (schedule is None and v_stages > 1):
        vs = [v_stages] if v_stages > 0 else (divisors or [1])
        return [get_schedule("interleaved-1f1b", v) for v in vs]
    if schedule is not None:
        return [get_schedule(schedule, max(v_stages, 1))]  # validates
    vs = divisors if v_stages == 0 else []  # explicit v=1: no interleaving
    return ([get_schedule("gpipe-1f1b"), get_schedule("zero-bubble-h1")]
            + [get_schedule("interleaved-1f1b", v) for v in vs])


def schedule_tiebreak(spec: ScheduleSpec) -> Tuple[int, str]:
    """Equal-bubble tie-break: fewer virtual stages, then the plain backend
    (stable bucket keys). Since the executor compiles the B/W split
    (:data:`SPLIT_BWD_REALIZED`), zero-bubble-h1 normally wins or loses on
    its realized bubble and only reaches this tie-break at ``t_w == 0``."""
    return (spec.v, "" if spec.name == "gpipe-1f1b" else spec.name)


def rank_schedule(spec: ScheduleSpec, n_items: int, d_p: int, t_f: float,
                  t_b: float, t_p2p: float = 0.0, *,
                  realized: bool = True,
                  t_w: Optional[float] = None) -> Tuple[float, int, str]:
    """Schedule-selection sort key: lower (bubble + extra hand-off) cost
    first (the *realized* executor bubble by default — see
    ``realized_bubble_time``, which prices split-backward backends'
    W-grad fill whenever the executor compiles it; ``t_p2p`` charges
    interleaving's extra ring trips), then :func:`schedule_tiebreak`.
    ``t_w`` overrides the default ``WGRAD_FRACTION * t_b`` weight-grad
    share for split backends."""
    bub = (spec.realized_bubble_time(n_items, d_p, t_f, t_b, t_w) if realized
           else spec.bubble_time(n_items, d_p, t_f, t_b, t_w))
    bub += spec.comm_overhead_time(n_items, d_p, t_p2p)
    return (bub, *schedule_tiebreak(spec))


def choose_schedule(cm: CostModel, chunks: Sequence[Chunk], *,
                    layers_per_stage: Optional[int] = None,
                    candidates: Optional[Sequence[ScheduleSpec]] = None,
                    avg_times: Optional[Tuple[float, float]] = None,
                    avg_p2p: Optional[float] = None,
                    realized: bool = True) -> ScheduleSpec:
    """Pick the min-cost schedule backend for one pipeline.

    Average per-stage fwd/bwd chunk times and the per-chunk hand-off time
    come from the cost model (Eq. 1-4, :meth:`CostModel.t_p2p`) unless
    precomputed ``avg_times``/``avg_p2p`` are passed in; candidates default
    to every registered backend, with interleaved tried at every ``v`` that
    divides ``layers_per_stage`` (virtual stages must split a stage's layer
    block evenly). Ranking uses the *realized* executor bubble by default
    (``realized=False`` ranks by the modeled bubble instead, where ZB-H1's
    W-grad fill counts) plus interleaving's extra ring-trip communication;
    ties break toward ``gpipe-1f1b``.
    """
    n = len(chunks)
    d_p = cm.cluster.d_p
    if candidates is None:
        l_s = (layers_per_stage if layers_per_stage is not None
               else max(1, -(-cm.model.n_layers // d_p)))
        candidates = candidate_schedules(l_s)
    if n == 0 or d_p <= 1:
        return get_schedule("gpipe-1f1b")
    t_f, t_b = avg_times if avg_times is not None \
        else cm.avg_stage_times(chunks)
    t_p = avg_p2p if avg_p2p is not None \
        else sum(cm.t_p2p(c) for c in chunks) / n
    return min(candidates,
               key=lambda s: rank_schedule(s, n, d_p, t_f, t_b, t_p,
                                           realized=realized))


def backward_order(chunks: Sequence[Chunk]) -> List[int]:
    """f2b: fwd index -> bwd index. Slices of one sequence reverse; everything
    else keeps its fwd position (Fig. 2 semantics)."""
    n = len(chunks)
    f2b = [0] * n
    # group consecutive chunks belonging to the same long sequence
    i = 0
    pos = 0
    while i < n:
        sid = chunks[i].seq_id
        j = i
        if sid is not None:
            while j + 1 < n and chunks[j + 1].seq_id == sid:
                j += 1
        # fwd block [i..j] maps to bwd block [pos..pos+(j-i)] reversed
        blk = j - i + 1
        for t in range(blk):
            f2b[i + t] = pos + (blk - 1 - t)
        pos += blk
        i = j + 1
    return f2b


def window_limit(d_p: int, stage: int, n_split: int) -> int:
    """Eq. 7: |W_p| = d_p - p + N_split (stage is 1-based)."""
    return d_p - stage + n_split


def _stage_op_order(n: int, d_p: int, stage: int, n_split: int,
                    f2b: Sequence[int]) -> List[Tick]:
    """Per-stage op order under the 1F1B policy with in-flight cap Eq. 7.

    Forward ops run in fwd-index order; backward ops in bwd-index order; a
    backward with bwd index j requires its fwd done at this stage. The stage
    runs fwds until the in-flight cap, then strictly alternates B, F while
    both remain, then drains the remaining Bs (cooldown).
    """
    cap = max(1, window_limit(d_p, stage, n_split))
    b2f = [0] * n
    for f, b in enumerate(f2b):
        b2f[b] = f
    order: List[Tick] = []
    nf = nb = 0
    resident: Set[int] = set()
    while nb < n:
        want_bwd = (nf - nb) >= cap or nf == n
        if want_bwd and b2f[nb] in resident:
            resident.discard(b2f[nb])
            order.append(Tick(TickOp.BWD, b2f[nb]))
            nb += 1
        elif nf < n:
            resident.add(nf)
            order.append(Tick(TickOp.FWD, nf))
            nf += 1
        else:
            # forced wait: next bwd's fwd not yet at this stage (cannot happen
            # with in-order fwds since b2f[nb] < nf required; guard anyway)
            if b2f[nb] in resident or b2f[nb] < nf:
                resident.discard(b2f[nb])
                order.append(Tick(TickOp.BWD, b2f[nb]))
                nb += 1
            else:  # pragma: no cover - defensive
                raise RuntimeError("deadlocked schedule")
    return order


def build_schedule(n_chunks: int, d_p: int, n_split: int,
                   f2b: Sequence[int]) -> List[List[Tick]]:
    """Per-stage (1-based stages stored at index p-1) op order."""
    return [
        _stage_op_order(n_chunks, d_p, p, n_split, f2b)
        for p in range(1, d_p + 1)
    ]


def enumerate_windows(n_chunks: int, d_p: int, n_split: int,
                      f2b: Sequence[int]) -> List[List[FrozenSet[int]]]:
    """Distinct chunks windows per stage: the resident set right after each
    forward (the per-stage activation peaks Eq. 8 constrains)."""
    out: List[List[FrozenSet[int]]] = []
    for p in range(1, d_p + 1):
        order = _stage_op_order(n_chunks, d_p, p, n_split, f2b)
        resident: Set[int] = set()
        seen: Set[FrozenSet[int]] = set()
        windows: List[FrozenSet[int]] = []
        for t in order:
            if t.op is TickOp.FWD:
                resident.add(t.chunk)
                fs = frozenset(resident)
                if fs not in seen:
                    seen.add(fs)
                    windows.append(fs)
            else:
                resident.discard(t.chunk)
        out.append(windows)
    return out


# ---------------------------------------------------------------------------
# Event-driven simulator.
# ---------------------------------------------------------------------------


@dataclass
class SimResult:
    makespan: float
    bubble_ratio: float                 # aggregate idle / (d_p * makespan)
    per_stage_busy: List[float]
    per_stage_peak_mem: List[float]     # bytes (activations + model states)
    breakdown: Dict[str, float]         # compute / sp_comm / p2p / recompute / bubble
    op_times: Dict[Tuple[int, str, int], Tuple[float, float]]  # (stage,op,chunk)->(t0,t1)

    @property
    def total_device_time(self) -> float:
        return self.makespan * len(self.per_stage_busy)


class PipelineSimulator:
    """Cycle-accurate 1F1B simulation of one pipeline on ``d_p`` stages.

    Durations come from the cost model (per-stage fwd / bwd + SP comm +
    recompute per the ckpt table); stage boundaries add a P2P latency for the
    boundary activation. ``stage_slowdowns`` in the cost model propagate here,
    which is how straggler-aware replanning closes the loop.
    """

    def __init__(self, cm: CostModel, chunks: Sequence[Chunk],
                 f2b: Sequence[int], n_split: int,
                 ckpt: Optional[Sequence[Sequence[int]]] = None) -> None:
        self.cm = cm
        self.chunks = list(chunks)
        self.f2b = list(f2b)
        self.n_split = max(1, n_split)
        self.d_p = cm.cluster.d_p
        n = len(chunks)
        self.ckpt = ([[0] * n for _ in range(self.d_p)]
                     if ckpt is None else [list(r) for r in ckpt])
        self.b2f = [0] * n
        for f, b in enumerate(self.f2b):
            self.b2f[b] = f

    # -- durations ----------------------------------------------------------
    def _p2p_time(self, chunk: Chunk) -> float:
        return self.cm.t_p2p(chunk)

    def _dur(self, stage: int, op: TickOp, k: int) -> Tuple[float, float, float]:
        """(compute_s, sp_comm_s, recompute_s) for chunk k at 1-based stage.

        A straggler stage slows everything it executes — its compute AND the
        collectives it participates in — so the stage slowdown multiplies the
        whole op duration here.
        """
        c = self.chunks[k]
        slow = self.cm._slowdown(stage)
        if op is TickOp.FWD:
            comp = self.cm.t_comp(c, per_stage=True, stage=stage)
            comm = slow * self.cm.t_sp_comm(c, per_stage=True)
            return comp, comm, 0.0
        comp = self.cm.t_comp_bwd(c, per_stage=True, stage=stage)
        comm = slow * 2.0 * self.cm.t_sp_comm(c, per_stage=True)
        l = self.ckpt[stage - 1][k]
        rec = slow * (self.cm.t_recompute(c, l) / self.d_p) if l else 0.0
        return comp, comm, rec

    # -- run ------------------------------------------------------------------
    def run(self) -> SimResult:
        n = len(self.chunks)
        d_p = self.d_p
        orders = build_schedule(n, d_p, self.n_split, self.f2b)
        ptr = [0] * d_p                       # next op index per stage
        stage_free = [0.0] * d_p
        fwd_done: Dict[Tuple[int, int], float] = {}   # (stage, chunk) -> t
        bwd_done: Dict[Tuple[int, int], float] = {}
        op_times: Dict[Tuple[int, str, int], Tuple[float, float]] = {}
        busy = [0.0] * d_p
        breakdown = {"compute": 0.0, "sp_comm": 0.0, "p2p": 0.0,
                     "recompute": 0.0, "bubble": 0.0}

        def ready_time(p: int, t: Tick) -> Optional[float]:
            """Earliest start honoring cross-stage deps; None if dep missing."""
            k = t.chunk
            if t.op is TickOp.FWD:
                if p == 0:
                    return stage_free[p]
                dep = fwd_done.get((p - 1, k))
                if dep is None:
                    return None
                return max(stage_free[p], dep + self._p2p_time(self.chunks[k]))
            # BWD: needs bwd at stage p+1 and own fwd at p
            own = fwd_done.get((p, k))
            if own is None:
                return None
            if p == d_p - 1:
                return max(stage_free[p], own)
            dep = bwd_done.get((p + 1, k))
            if dep is None:
                return None
            return max(stage_free[p], own, dep + self._p2p_time(self.chunks[k]))

        remaining = sum(len(o) for o in orders)
        guard = 0
        while remaining > 0:
            guard += 1
            if guard > 8 * remaining + 64 + 8 * n * d_p:
                raise RuntimeError("simulator livelock — bad schedule")
            progressed = False
            # pick the stage whose next op can start earliest
            best: Optional[Tuple[float, int]] = None
            for p in range(d_p):
                if ptr[p] >= len(orders[p]):
                    continue
                rt = ready_time(p, orders[p][ptr[p]])
                if rt is None:
                    continue
                if best is None or rt < best[0]:
                    best = (rt, p)
            if best is None:  # pragma: no cover - defensive
                raise RuntimeError("deadlock: no ready op")
            rt, p = best
            t = orders[p][ptr[p]]
            comp, comm, rec = self._dur(p + 1, t.op, t.chunk)
            dur = comp + comm + rec
            start = rt
            end = start + dur
            breakdown["compute"] += comp
            breakdown["sp_comm"] += comm
            breakdown["recompute"] += rec
            if (t.op is TickOp.FWD and p > 0) or (t.op is TickOp.BWD and p < d_p - 1):
                breakdown["p2p"] += self._p2p_time(self.chunks[t.chunk])
            busy[p] += dur
            stage_free[p] = end
            if t.op is TickOp.FWD:
                fwd_done[(p, t.chunk)] = end
            else:
                bwd_done[(p, t.chunk)] = end
            op_times[(p + 1, t.op.value, t.chunk)] = (start, end)
            ptr[p] += 1
            remaining -= 1
            progressed = True
            if not progressed:  # pragma: no cover
                raise RuntimeError("no progress")

        makespan = max(stage_free)
        idle = sum(makespan - b for b in busy)
        breakdown["bubble"] = idle
        peak = self._peak_memory(orders)
        return SimResult(
            makespan=makespan,
            bubble_ratio=idle / (d_p * makespan) if makespan > 0 else 0.0,
            per_stage_busy=busy,
            per_stage_peak_mem=peak,
            breakdown=breakdown,
            op_times=op_times,
        )

    def _peak_memory(self, orders: List[List[Tick]]) -> List[float]:
        """Per-stage peak bytes under Eq. 8 with the solved ckpt table."""
        peaks: List[float] = []
        for p in range(1, self.d_p + 1):
            ms = self.cm.m_model_states(p)
            cur = ms
            pk = ms
            for t in orders[p - 1]:
                l = self.ckpt[p - 1][t.chunk]
                m = self.cm.m_act(p, self.chunks[t.chunk], l)
                if t.op is TickOp.FWD:
                    cur += m
                    pk = max(pk, cur)
                else:
                    cur -= m
            peaks.append(pk)
        return peaks
