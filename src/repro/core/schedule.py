"""1F1B schedule construction, chunks-window enumeration, and a
cycle-accurate pipeline simulator.

Three consumers:

1. :func:`enumerate_windows` feeds Alg. 2's ILP the distinct chunks windows
   ``W_p(t)`` (Eq. 7-8). Window *content* is duration-independent — it only
   depends on the per-stage op order, which the 1F1B policy fixes — so the
   ILP never needs timing.
2. :func:`build_schedule` emits the per-stage tick list the executor and the
   simulator share.
3. :class:`PipelineSimulator` is an event-driven simulator with true chunk
   durations (from the cost model) and token-level-PP dependencies. It
   produces makespan, per-stage bubble ratios, a time breakdown
   (compute / SP-comm / P2P / bubble / recompute) and per-stage peak memory —
   the measurement substrate for the paper-figure benchmarks (Figs. 7-12)
   and the straggler-mitigation loop.

Token-level PP dependency (§II-A): forward of slice i must follow forward of
slices < i of the same sequence; backward of slice i must follow backward of
slices > i. Both are encoded via the fwd order (slices emitted causally) and
the ``f2b`` map (slices reversed within each sequence).
"""

from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

from .costs import CostModel
from .plan import Chunk, ChunkKind, Tick, TickOp

__all__ = [
    "backward_order",
    "enumerate_windows",
    "build_schedule",
    "PipelineSimulator",
    "SimResult",
]


def backward_order(chunks: Sequence[Chunk]) -> List[int]:
    """f2b: fwd index -> bwd index. Slices of one sequence reverse; everything
    else keeps its fwd position (Fig. 2 semantics)."""
    n = len(chunks)
    f2b = [0] * n
    # group consecutive chunks belonging to the same long sequence
    i = 0
    pos = 0
    while i < n:
        sid = chunks[i].seq_id
        j = i
        if sid is not None:
            while j + 1 < n and chunks[j + 1].seq_id == sid:
                j += 1
        # fwd block [i..j] maps to bwd block [pos..pos+(j-i)] reversed
        blk = j - i + 1
        for t in range(blk):
            f2b[i + t] = pos + (blk - 1 - t)
        pos += blk
        i = j + 1
    return f2b


def window_limit(d_p: int, stage: int, n_split: int) -> int:
    """Eq. 7: |W_p| = d_p - p + N_split (stage is 1-based)."""
    return d_p - stage + n_split


def _stage_op_order(n: int, d_p: int, stage: int, n_split: int,
                    f2b: Sequence[int]) -> List[Tick]:
    """Per-stage op order under the 1F1B policy with in-flight cap Eq. 7.

    Forward ops run in fwd-index order; backward ops in bwd-index order; a
    backward with bwd index j requires its fwd done at this stage. The stage
    runs fwds until the in-flight cap, then strictly alternates B, F while
    both remain, then drains the remaining Bs (cooldown).
    """
    cap = max(1, window_limit(d_p, stage, n_split))
    b2f = [0] * n
    for f, b in enumerate(f2b):
        b2f[b] = f
    order: List[Tick] = []
    nf = nb = 0
    resident: Set[int] = set()
    while nb < n:
        want_bwd = (nf - nb) >= cap or nf == n
        if want_bwd and b2f[nb] in resident:
            resident.discard(b2f[nb])
            order.append(Tick(TickOp.BWD, b2f[nb]))
            nb += 1
        elif nf < n:
            resident.add(nf)
            order.append(Tick(TickOp.FWD, nf))
            nf += 1
        else:
            # forced wait: next bwd's fwd not yet at this stage (cannot happen
            # with in-order fwds since b2f[nb] < nf required; guard anyway)
            if b2f[nb] in resident or b2f[nb] < nf:
                resident.discard(b2f[nb])
                order.append(Tick(TickOp.BWD, b2f[nb]))
                nb += 1
            else:  # pragma: no cover - defensive
                raise RuntimeError("deadlocked schedule")
    return order


def build_schedule(n_chunks: int, d_p: int, n_split: int,
                   f2b: Sequence[int]) -> List[List[Tick]]:
    """Per-stage (1-based stages stored at index p-1) op order."""
    return [
        _stage_op_order(n_chunks, d_p, p, n_split, f2b)
        for p in range(1, d_p + 1)
    ]


def enumerate_windows(n_chunks: int, d_p: int, n_split: int,
                      f2b: Sequence[int]) -> List[List[FrozenSet[int]]]:
    """Distinct chunks windows per stage: the resident set right after each
    forward (the per-stage activation peaks Eq. 8 constrains)."""
    out: List[List[FrozenSet[int]]] = []
    for p in range(1, d_p + 1):
        order = _stage_op_order(n_chunks, d_p, p, n_split, f2b)
        resident: Set[int] = set()
        seen: Set[FrozenSet[int]] = set()
        windows: List[FrozenSet[int]] = []
        for t in order:
            if t.op is TickOp.FWD:
                resident.add(t.chunk)
                fs = frozenset(resident)
                if fs not in seen:
                    seen.add(fs)
                    windows.append(fs)
            else:
                resident.discard(t.chunk)
        out.append(windows)
    return out


# ---------------------------------------------------------------------------
# Event-driven simulator.
# ---------------------------------------------------------------------------


@dataclass
class SimResult:
    makespan: float
    bubble_ratio: float                 # aggregate idle / (d_p * makespan)
    per_stage_busy: List[float]
    per_stage_peak_mem: List[float]     # bytes (activations + model states)
    breakdown: Dict[str, float]         # compute / sp_comm / p2p / recompute / bubble
    op_times: Dict[Tuple[int, str, int], Tuple[float, float]]  # (stage,op,chunk)->(t0,t1)

    @property
    def total_device_time(self) -> float:
        return self.makespan * len(self.per_stage_busy)


class PipelineSimulator:
    """Cycle-accurate 1F1B simulation of one pipeline on ``d_p`` stages.

    Durations come from the cost model (per-stage fwd / bwd + SP comm +
    recompute per the ckpt table); stage boundaries add a P2P latency for the
    boundary activation. ``stage_slowdowns`` in the cost model propagate here,
    which is how straggler-aware replanning closes the loop.
    """

    def __init__(self, cm: CostModel, chunks: Sequence[Chunk],
                 f2b: Sequence[int], n_split: int,
                 ckpt: Optional[Sequence[Sequence[int]]] = None) -> None:
        self.cm = cm
        self.chunks = list(chunks)
        self.f2b = list(f2b)
        self.n_split = max(1, n_split)
        self.d_p = cm.cluster.d_p
        n = len(chunks)
        self.ckpt = ([[0] * n for _ in range(self.d_p)]
                     if ckpt is None else [list(r) for r in ckpt])
        self.b2f = [0] * n
        for f, b in enumerate(self.f2b):
            self.b2f[b] = f

    # -- durations ----------------------------------------------------------
    def _p2p_time(self, chunk: Chunk) -> float:
        m, cl = self.cm.model, self.cm.cluster
        vol = m.bytes_per_act * m.d_model * chunk.tokens / cl.d_s
        return vol / cl.ici_bw + 1e-6

    def _dur(self, stage: int, op: TickOp, k: int) -> Tuple[float, float, float]:
        """(compute_s, sp_comm_s, recompute_s) for chunk k at 1-based stage.

        A straggler stage slows everything it executes — its compute AND the
        collectives it participates in — so the stage slowdown multiplies the
        whole op duration here.
        """
        c = self.chunks[k]
        slow = self.cm._slowdown(stage)
        if op is TickOp.FWD:
            comp = self.cm.t_comp(c, per_stage=True, stage=stage)
            comm = slow * self.cm.t_sp_comm(c, per_stage=True)
            return comp, comm, 0.0
        comp = self.cm.t_comp_bwd(c, per_stage=True, stage=stage)
        comm = slow * 2.0 * self.cm.t_sp_comm(c, per_stage=True)
        l = self.ckpt[stage - 1][k]
        rec = slow * (self.cm.t_recompute(c, l) / self.d_p) if l else 0.0
        return comp, comm, rec

    # -- run ------------------------------------------------------------------
    def run(self) -> SimResult:
        n = len(self.chunks)
        d_p = self.d_p
        orders = build_schedule(n, d_p, self.n_split, self.f2b)
        ptr = [0] * d_p                       # next op index per stage
        stage_free = [0.0] * d_p
        fwd_done: Dict[Tuple[int, int], float] = {}   # (stage, chunk) -> t
        bwd_done: Dict[Tuple[int, int], float] = {}
        op_times: Dict[Tuple[int, str, int], Tuple[float, float]] = {}
        busy = [0.0] * d_p
        breakdown = {"compute": 0.0, "sp_comm": 0.0, "p2p": 0.0,
                     "recompute": 0.0, "bubble": 0.0}

        def ready_time(p: int, t: Tick) -> Optional[float]:
            """Earliest start honoring cross-stage deps; None if dep missing."""
            k = t.chunk
            if t.op is TickOp.FWD:
                if p == 0:
                    return stage_free[p]
                dep = fwd_done.get((p - 1, k))
                if dep is None:
                    return None
                return max(stage_free[p], dep + self._p2p_time(self.chunks[k]))
            # BWD: needs bwd at stage p+1 and own fwd at p
            own = fwd_done.get((p, k))
            if own is None:
                return None
            if p == d_p - 1:
                return max(stage_free[p], own)
            dep = bwd_done.get((p + 1, k))
            if dep is None:
                return None
            return max(stage_free[p], own, dep + self._p2p_time(self.chunks[k]))

        remaining = sum(len(o) for o in orders)
        guard = 0
        while remaining > 0:
            guard += 1
            if guard > 8 * remaining + 64 + 8 * n * d_p:
                raise RuntimeError("simulator livelock — bad schedule")
            progressed = False
            # pick the stage whose next op can start earliest
            best: Optional[Tuple[float, int]] = None
            for p in range(d_p):
                if ptr[p] >= len(orders[p]):
                    continue
                rt = ready_time(p, orders[p][ptr[p]])
                if rt is None:
                    continue
                if best is None or rt < best[0]:
                    best = (rt, p)
            if best is None:  # pragma: no cover - defensive
                raise RuntimeError("deadlock: no ready op")
            rt, p = best
            t = orders[p][ptr[p]]
            comp, comm, rec = self._dur(p + 1, t.op, t.chunk)
            dur = comp + comm + rec
            start = rt
            end = start + dur
            breakdown["compute"] += comp
            breakdown["sp_comm"] += comm
            breakdown["recompute"] += rec
            if (t.op is TickOp.FWD and p > 0) or (t.op is TickOp.BWD and p < d_p - 1):
                breakdown["p2p"] += self._p2p_time(self.chunks[t.chunk])
            busy[p] += dur
            stage_free[p] = end
            if t.op is TickOp.FWD:
                fwd_done[(p, t.chunk)] = end
            else:
                bwd_done[(p, t.chunk)] = end
            op_times[(p + 1, t.op.value, t.chunk)] = (start, end)
            ptr[p] += 1
            remaining -= 1
            progressed = True
            if not progressed:  # pragma: no cover
                raise RuntimeError("no progress")

        makespan = max(stage_free)
        idle = sum(makespan - b for b in busy)
        breakdown["bubble"] = idle
        peak = self._peak_memory(orders)
        return SimResult(
            makespan=makespan,
            bubble_ratio=idle / (d_p * makespan) if makespan > 0 else 0.0,
            per_stage_busy=busy,
            per_stage_peak_mem=peak,
            breakdown=breakdown,
            op_times=op_times,
        )

    def _peak_memory(self, orders: List[List[Tick]]) -> List[float]:
        """Per-stage peak bytes under Eq. 8 with the solved ckpt table."""
        peaks: List[float] = []
        for p in range(1, self.d_p + 1):
            ms = self.cm.m_model_states(p)
            cur = ms
            pk = ms
            for t in orders[p - 1]:
                l = self.ckpt[p - 1][t.chunk]
                m = self.cm.m_act(p, self.chunks[t.chunk], l)
                if t.op is TickOp.FWD:
                    cur += m
                    pk = max(pk, cur)
                else:
                    cur -= m
            peaks.append(pk)
        return peaks
