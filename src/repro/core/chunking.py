"""Sequence Processor — Alg. 1: workload-balanced sequence chunking.

Turns a global batch of variable-length sequences into the three chunk kinds
of §III-A.1:

* the longest sequence is split into ``K`` *workload-balanced* slices (the
  "mesh"); every sequence longer than the first mesh slice is sharded by the
  mesh prefix, leaving a shorter *tail slice*;
* tail slices seed packing buckets (one per tail — packing two tails would
  force co-scheduling two long sequences, footnote 1 of the paper);
* short sequences are packed Best-Fit-Decreasing under a time threshold
  ``T_t`` and a token threshold ``T_m``, preferring the bucket with the
  lowest ``tot_time / tot_tokens`` (pairs long-ish shorts with cheap tails);
  ``T_t`` is loosened *per placement* when ``T_m`` cannot otherwise be met
  (the forced short lands in the cheapest feasible bucket and the threshold
  is restored for the rest of the batch).

The output order is the pipeline execution order: longest sequences first
(§III-C1's fundamental scheduling rule), slices in causal order, the hybrid
chunk (containing the tail) last within its sequence, batched chunks after.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from .costs import CostModel
from .plan import Chunk, ChunkKind, SequenceInfo, Slice

__all__ = ["ChunkingResult", "chunk_sequences", "prompt_slices",
           "seq_workload"]


def seq_workload(cm: CostModel, length: int, context: int = 0) -> float:
    """Additive workload of one (sub)sequence: Eq. 1 without the chunk-level
    beta overhead (packing concatenates block-diagonal attention, so member
    workloads add)."""
    co, cl = cm.coeffs, cm.cluster
    c, s = float(context), float(length)
    quad = (c + s) ** 2 - c ** 2
    return (co.alpha1 * 0.5 * quad + co.alpha2 * s) / cl.n_devices


@dataclass
class _Bucket:
    tot_time: float = 0.0
    tot_tokens: int = 0
    tail: Optional[Slice] = None
    tail_context: int = 0
    shorts: List[Slice] = field(default_factory=list)

    @property
    def metric(self) -> float:
        if self.tot_tokens == 0:
            return 0.0
        return self.tot_time / self.tot_tokens

    def add(self, sl: Slice, time: float) -> None:
        self.shorts.append(sl)
        self.tot_time += time
        self.tot_tokens += sl.length


@dataclass
class ChunkingResult:
    chunks: List[Chunk]                  # pipeline execution order
    sequences: List[SequenceInfo]
    mesh: List[int]                      # Alg. 1's slice-length mesh
    t_t: float                           # T_t (line-1 value; loosening is
                                         # per-placement and never persists)
    t_m: int                             # token threshold
    k_split: int

    @property
    def max_chunk_tokens(self) -> int:
        return max((c.tokens for c in self.chunks), default=0)


def _mesh_thresholds(cm: CostModel, max_len: int, k: int,
                     capacity: Optional[int]) -> Tuple[List[int], float, int]:
    """Alg. 1 line 1: mesh + initial T_t + T_m.

    T_m derivation (the paper omits the closed form): the deepest chunks
    window holds ``d_p + K - 1`` chunks (Eq. 7 at p=1), all of whose
    activations must be resident, so a chunk may hold at most
    ``capacity / (d_p + K - 1)`` tokens — clamped below by the largest mesh
    slice (a slice must fit in one chunk).
    """
    mesh = cm.split_balanced(max_len, k)
    t_t = seq_workload(cm, mesh[0], 0) if mesh else 0.0
    cap = capacity if capacity is not None else cm.token_capacity()
    window = cm.cluster.d_p + max(k, 1) - 1
    t_m = max(int(cap / window), max(mesh) if mesh else 1)
    return mesh, t_t, t_m


def prompt_slices(cm: CostModel, length: int, capacity: int) -> List[int]:
    """Capacity-bounded, workload-balanced slices of ONE sequence — Alg. 1
    line 1 applied to a serving prompt (token-level PP reborn as chunked
    prefill). The smallest ``K`` whose balanced mesh fits ``capacity``
    tokens per slice is used, so later slices — which carry more causal
    context and therefore more attention work per token — get fewer tokens,
    exactly like the trainer's mesh.
    """
    if length <= 0:
        return []
    if capacity < 1:
        raise ValueError(f"capacity must be >= 1, got {capacity}")
    if length <= capacity:
        return [length]
    k = max(2, -(-length // capacity))
    while True:
        mesh = cm.split_balanced(length, k)
        if mesh and max(mesh) <= capacity:
            return mesh
        k += 1


def chunk_sequences(cm: CostModel, lengths: Sequence[int], k: int, *,
                    capacity: Optional[int] = None) -> ChunkingResult:
    """Alg. 1. ``lengths[i]`` is sequence i's token count."""
    if k < 1:
        raise ValueError("K must be >= 1")
    if not lengths:
        return ChunkingResult([], [], [], 0.0, 0, k)
    max_len = max(lengths)
    mesh, t_t, t_m = _mesh_thresholds(cm, max_len, k, capacity)

    # ---- line 2: shard long sequences by the mesh --------------------------
    order = sorted(range(len(lengths)), key=lambda i: -lengths[i])
    # per long sequence: list of split chunks + a tail slice
    long_parts: List[Tuple[int, List[Chunk], Slice, int]] = []
    shorts: List[Slice] = []
    for sid in order:
        ln = lengths[sid]
        if k == 1 or ln <= mesh[0]:
            shorts.append(Slice(seq_id=sid, start=0, length=ln, is_tail=True))
            continue
        splits: List[Chunk] = []
        off = 0
        for m_len in mesh[:-1]:
            remaining = ln - off
            if remaining <= m_len:
                break
            sl = Slice(seq_id=sid, start=off, length=m_len, is_tail=False)
            splits.append(Chunk(kind=ChunkKind.SPLIT, context=off, slices=(sl,)))
            off += m_len
        tail = Slice(seq_id=sid, start=off, length=ln - off, is_tail=True)
        long_parts.append((sid, splits, tail, off))

    # ---- lines 3-15: BFD packing -------------------------------------------
    buckets: List[_Bucket] = []
    for sid, _splits, tail, ctx in long_parts:
        b = _Bucket(tail=tail, tail_context=ctx)
        b.tot_time = seq_workload(cm, tail.length, ctx)
        b.tot_tokens = tail.length
        buckets.append(b)

    shorts.sort(key=lambda s: -seq_workload(cm, s.length))
    for s in shorts:
        t_s = seq_workload(cm, s.length)
        placed = False
        while not placed:
            if buckets:
                min_tok = min(b.tot_tokens for b in buckets)
            else:
                min_tok = t_m + 1  # force creation
            if min_tok + s.length > t_m:
                nb = _Bucket()
                nb.add(s, t_s)
                buckets.append(nb)
                placed = True
                break
            for b in sorted(buckets, key=lambda b: b.metric):
                if (b.tot_time + t_s <= t_t + 1e-18
                        and b.tot_tokens + s.length <= t_m):
                    b.add(s, t_s)
                    placed = True
                    break
            if not placed:
                # line 14: T_m cannot otherwise be met, so loosen T_t — for
                # THIS placement only. Force the short into the cheapest
                # token-feasible bucket (min tot_time, metric tie-break);
                # T_t itself stays put, so one outlier does not relax the
                # time threshold for every subsequent short (which would
                # silently degrade workload balance across the batch).
                feas = [b for b in buckets if b.tot_tokens + s.length <= t_m]
                if not feas:
                    nb = _Bucket()
                    nb.add(s, t_s)
                    buckets.append(nb)
                else:
                    t_min = min(b.tot_time for b in feas)
                    best = min((b for b in feas
                                if b.tot_time <= t_min + 1e-18),
                               key=lambda b: b.metric)
                    best.add(s, t_s)
                placed = True

    # ---- line 15-16: transform & order -------------------------------------
    chunks: List[Chunk] = []
    seq_chunks: Dict[int, List[int]] = {}

    def _note(cidx: int, sids: Sequence[int]) -> None:
        for sid in sids:
            seq_chunks.setdefault(sid, []).append(cidx)

    tail_bucket: Dict[int, _Bucket] = {
        b.tail.seq_id: b for b in buckets if b.tail is not None}

    # long sequences first, longest first (already sorted)
    for sid, splits, tail, ctx in long_parts:
        for ch in splits:
            chunks.append(ch)
            _note(len(chunks) - 1, [sid])
        b = tail_bucket[sid]
        kind = ChunkKind.HYBRID if b.shorts else ChunkKind.SPLIT
        ch = Chunk(kind=kind, context=ctx, slices=(tail, *b.shorts))
        chunks.append(ch)
        _note(len(chunks) - 1, [sid] + [s.seq_id for s in b.shorts])
    # pure batched buckets, heaviest first
    pure = [b for b in buckets if b.tail is None and b.shorts]
    pure.sort(key=lambda b: -b.tot_time)
    for b in pure:
        ch = Chunk(kind=ChunkKind.BATCHED, context=0, slices=tuple(b.shorts))
        chunks.append(ch)
        _note(len(chunks) - 1, [s.seq_id for s in b.shorts])

    sequences = [
        SequenceInfo(seq_id=sid, length=lengths[sid],
                     n_chunks=len(cids), chunk_ids=sorted(cids))
        for sid, cids in sorted(seq_chunks.items())
    ]
    return ChunkingResult(chunks=chunks, sequences=sequences, mesh=mesh,
                          t_t=t_t, t_m=t_m, k_split=k)
