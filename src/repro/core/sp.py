"""Sequence-parallel (context-parallel) policy: the single source of truth.

The SP axis is a *plan* axis, not a cluster constant: every
``ExecutionPlan`` carries an :class:`SPConfig` — a policy name plus an
effective degree ``d_s_eff <= d_s`` realized as sub-groups of the "model"
mesh axis — chosen by the planner jointly with chunking and
checkpointing. This module is the one definition of legality and the
default heuristic; both the cost model (``core/costs.py``) and the
runtime (``runtime/sp.py``) delegate here so they can never diverge
(tests/test_sp_policy.py pins it).

Pure Python — no JAX — like the rest of ``repro.core``, so planning runs
on CPU hosts that never initialize a device runtime.

Policy semantics (the runtime collectives live in ``runtime/sp.py``):

``none``
    No sequence sharding inside a chunk: every model-axis device in an
    SP sub-group of size 1 computes the full chunk. Legal for any model
    at ``d_s_eff == 1``, and for attention-free (pure-SSM) models at any
    degree (the SSM scan shards tokens without attention collectives).
``ulysses``
    Head-wise all-to-all: q/k/v redistribute from token-sharded to
    head-sharded (4 a2a per layer), context is HEAD-sharded. Requires
    ``n_heads % d == 0 and n_kv_heads % d == 0``; illegal for MLA
    (the latent cache has one logical head) and attention-free models.
``allgather_kv``
    Keys/values of the current chunk are all-gathered per layer;
    context is REPLICATED across the sub-group. Legal for any head
    count; the MLA latent cache prefers it.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["SP_POLICIES", "SPConfig", "choose_sp_policy", "sp_legal",
           "legal_degrees", "sp_candidates"]

SP_POLICIES: Tuple[str, ...] = ("none", "ulysses", "allgather_kv")


@dataclass(frozen=True)
class SPConfig:
    """One plan's sequence-parallel configuration.

    ``d_s_eff`` is the token-sharding degree of a chunk's sequence axis.
    It must divide the mesh's model-axis size ``d_s``; for
    ``d_s_eff < d_s`` the runtime forms ``d_s_eff`` sub-groups of the
    model axis (stride ``r = d_s // d_s_eff``) and replicates chunk
    compute ``r`` times — parameters and the vocab axis stay sharded
    over the FULL model axis regardless.
    """

    policy: str
    d_s_eff: int

    def __post_init__(self) -> None:
        if self.policy not in SP_POLICIES:
            raise ValueError(f"unknown SP policy {self.policy!r}; "
                             f"expected one of {SP_POLICIES}")
        if self.d_s_eff < 1:
            raise ValueError(f"d_s_eff must be >= 1, got {self.d_s_eff}")

    def to_json(self) -> Dict[str, Any]:
        return {"policy": self.policy, "d_s_eff": self.d_s_eff}

    @staticmethod
    def from_json(d: Optional[Dict[str, Any]]) -> Optional["SPConfig"]:
        if d is None:
            return None
        return SPConfig(policy=d["policy"], d_s_eff=int(d["d_s_eff"]))


def choose_sp_policy(spec, d: int) -> str:
    """Default SP policy for ``spec`` at effective degree ``d``.

    This is the ONE heuristic — ``runtime/sp.choose_policy`` and the
    cost model's ``"auto"`` resolution both call it:

    * attention-free (pure SSM): ``none`` — the distributed scan shards
      tokens with no attention collective at all;
    * ``d <= 1``: ``none`` — a sub-group of one device needs no policy;
    * MLA (``kv_lora_rank > 0``): ``allgather_kv`` — the latent cache
      has one logical head, so Ulysses cannot shard it;
    * heads divisible by ``d``: ``ulysses`` (4 small a2a beat gathering
      replicated KV, and context stays head-sharded);
    * otherwise: ``allgather_kv`` (legal for any head count).
    """
    if spec.attn_free:
        return "none"
    if d <= 1:
        return "none"
    if spec.kv_lora_rank > 0:
        return "allgather_kv"
    if spec.n_heads % d == 0 and spec.n_kv_heads % d == 0:
        return "ulysses"
    return "allgather_kv"


def sp_legal(spec, policy: str, d: int) -> bool:
    """Can ``policy`` run for ``spec`` at effective degree ``d``?"""
    if policy not in SP_POLICIES:
        return False
    if d < 1:
        return False
    if spec.attn_free:
        # pure-SSM models have no attention to shard; only "none" makes
        # sense (the SSM scan handles token sharding at any degree)
        return policy == "none"
    if policy == "none":
        # with attention present, "none" means each sub-group device
        # holds the whole chunk — only meaningful (and only correct) at
        # degree 1
        return d == 1
    if d == 1:
        return False  # a degree-1 sub-group must use "none"
    if policy == "ulysses":
        if spec.kv_lora_rank > 0:
            return False  # MLA latent cache: one logical head
        return spec.n_heads % d == 0 and spec.n_kv_heads % d == 0
    return True  # allgather_kv: any head count


def legal_degrees(spec, d_s: int) -> List[int]:
    """Divisors of ``d_s`` (descending) with at least one legal policy."""
    degs = [d for d in range(d_s, 0, -1) if d_s % d == 0]
    return [d for d in degs
            if any(sp_legal(spec, p, d) for p in SP_POLICIES)]


def sp_candidates(spec, d_s: int) -> List[SPConfig]:
    """Every legal ``(policy, d_s_eff)`` pair the planner may choose,
    default-policy-first per degree, degrees descending."""
    out: List[SPConfig] = []
    for d in legal_degrees(spec, d_s):
        default = choose_sp_policy(spec, d)
        for policy in (default,) + tuple(p for p in SP_POLICIES
                                         if p != default):
            if sp_legal(spec, policy, d):
                out.append(SPConfig(policy=policy, d_s_eff=d))
    return out
