"""Sequence Grouping — §III-C2, Eq. 14.

Sequences with similar chunk counts are grouped into the same 1F1B pipeline:
grouping a short sequence with a long one inflates N_split (Eq. 7), forcing
tighter checkpointing on everyone (Fig. 6a). Scheduling more pipelines
reduces recompute but pays one warmup-cooldown delta each (Eq. 13) —
gradient accumulation keeps optimization consistent across pipelines.

The DP runs over *chunk-count levels*: ``S[i]`` = chunks whose owning
sequence spans ``i`` chunks (batched chunks are level 1; a hybrid chunk takes
the level of the long sequence whose tail it carries). A pipeline serves a
contiguous level range (l, r]:

    dp[r] = min_l { dp[l] + delta(P(l+1..r)) + T_ckpt(P(l+1..r)) }
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from .checkpointing import solve_checkpointing, stage_roles
from .chunking import ChunkingResult
from .costs import CostModel
from .plan import Chunk, PipelinePlan
from .schedule import PipelineSimulator, backward_order

__all__ = ["group_sequences", "GroupingResult"]


@dataclass
class GroupingResult:
    pipelines: List[PipelinePlan]
    est_cost: float                 # Eq. 13 objective value
    feasible: bool


def _chunk_level(chunk: Chunk, seq_nchunks: Dict[int, int]) -> int:
    sid = chunk.seq_id
    if sid is None:
        return 1
    return seq_nchunks[sid]


def _candidate(cm: CostModel, chunks: List[Chunk], n_split: int, *,
               gap: float, capacity: Optional[float]
               ) -> Tuple[float, Optional[PipelinePlan]]:
    """Cost of serving ``chunks`` in one 1F1B pipeline: delta + T_ckpt."""
    if not chunks:
        return 0.0, None
    f2b = backward_order(chunks)
    # stage-aware roles: enc-dec arches get encoder coefficients on their
    # leading stages, so the ILP can hand encoder and decoder stages
    # different checkpoint depths (all-decoder otherwise — a no-op)
    roles = stage_roles(cm.model, cm.cluster.d_p)
    sol = solve_checkpointing(cm, chunks, f2b, n_split, gap=gap,
                              capacity=capacity,
                              roles=roles if "encoder" in roles else None)
    if sol.status == "infeasible":
        return math.inf, None
    delta = cm.delta_warmup(chunks)
    plan = PipelinePlan(
        chunks=chunks,
        f2b=f2b,
        ckpt=sol.table,
        ckpt_diag=sol.diag,
        n_split=n_split,
        est_recompute=sol.recompute_time,
    )
    return delta + sol.recompute_time, plan


def group_sequences(cm: CostModel, chunking: ChunkingResult, *,
                    gap: float = 0.02,
                    capacity: Optional[float] = None,
                    simulate: bool = True) -> GroupingResult:
    """Eq. 14 DP. Returns pipelines ordered long-levels-first."""
    chunks = chunking.chunks
    if not chunks:
        return GroupingResult([], 0.0, True)
    seq_nchunks = {s.seq_id: s.n_chunks for s in chunking.sequences}
    levels_of = [_chunk_level(c, seq_nchunks) for c in chunks]
    levels = sorted(set(levels_of), reverse=True)  # descending: longest first
    # chunks per level, preserving the execution order within each level
    by_level: Dict[int, List[int]] = {lv: [] for lv in levels}
    for idx, lv in enumerate(levels_of):
        by_level[lv].append(idx)

    L = len(levels)
    INF = math.inf
    dp = [INF] * (L + 1)
    dp[0] = 0.0
    choice: List[Optional[Tuple[int, PipelinePlan]]] = [None] * (L + 1)
    memo: Dict[Tuple[int, int], Tuple[float, Optional[PipelinePlan]]] = {}

    for r in range(1, L + 1):
        for l in range(r):
            if dp[l] == INF:
                continue
            key = (l, r)
            if key not in memo:
                sel: List[Chunk] = []
                for lv in levels[l:r]:
                    sel.extend(chunks[i] for i in by_level[lv])
                n_split = levels[l]  # max level in the range (desc order)
                memo[key] = _candidate(cm, sel, n_split, gap=gap,
                                       capacity=capacity)
            cost, plan = memo[key]
            if cost == INF or plan is None:
                continue
            if dp[l] + cost < dp[r]:
                dp[r] = dp[l] + cost
                choice[r] = (l, plan)

    if dp[L] == INF:
        return GroupingResult([], INF, False)

    # backtrack
    pipelines: List[PipelinePlan] = []
    r = L
    while r > 0:
        l, plan = choice[r]  # type: ignore[misc]
        pipelines.append(plan)
        r = l
    pipelines.reverse()

    if simulate:
        for p in pipelines:
            sim = PipelineSimulator(cm, p.chunks, p.f2b, p.n_split, p.ckpt)
            res = sim.run()
            p.est_time = res.makespan
            p.est_peak_mem = res.per_stage_peak_mem
    return GroupingResult(pipelines, dp[L], True)
