"""Fault tolerance: restart-from-checkpoint and straggler-aware replanning.

Designed for thousands of nodes:

* **Restart**: `launch/train.py --resume auto` finds the latest committed
  checkpoint (partial saves are invisible — ckpt/checkpoint.py commits via
  the manifest) and resumes; plans are NOT checkpointed — they are
  deterministic functions of (data seed, step, mesh), so a restart on a
  *different* mesh (elastic shrink after losing a pod) simply re-plans.
* **Straggler mitigation**: the trainer records per-stage step times
  (telemetry hook); when a stage's EWMA exceeds the median by
  ``threshold``, the planner re-solves with per-stage slowdown multipliers
  (CostModel.stage_slowdowns) — the chunking rebalances so the slow stage
  receives proportionally lighter chunks. This is the EPP-native answer to
  stragglers: reschedule work, don't wait.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import CostModel

__all__ = ["StragglerInjector", "StragglerMonitor", "replan_costmodel"]


@dataclass
class StragglerMonitor:
    d_p: int
    ewma: float = 0.3
    threshold: float = 1.25            # x median => flagged
    _t: Optional[np.ndarray] = None

    def observe(self, per_stage_seconds: Sequence[float]) -> None:
        x = np.asarray(per_stage_seconds, dtype=np.float64)
        if self._t is None:
            self._t = x
        else:
            self._t = (1 - self.ewma) * self._t + self.ewma * x

    def slowdowns(self) -> Optional[List[float]]:
        """Per-stage multipliers (>=1) if any straggler is flagged."""
        if self._t is None:
            return None
        med = float(np.median(self._t))
        if med <= 0:
            return None
        mult = np.maximum(self._t / med, 1.0)
        if (mult < self.threshold).all():
            return None
        return [float(m) for m in mult]


def replan_costmodel(cm: CostModel,
                     monitor: StragglerMonitor) -> CostModel:
    """Cost model for the next planning round, straggler-aware."""
    slow = monitor.slowdowns()
    if slow is None:
        return cm
    return cm.with_slowdowns(slow)


@dataclass
class StragglerInjector:
    """Deterministic straggler injection for tests/CI: from ``start_step``
    on, the *reported* telemetry (per-stage seconds and the wall clock the
    timeline records) is scaled as if the configured stages ran slow.

    It perturbs measurements, NOT computation — losses are bitwise
    unaffected — which is exactly what the re-planning tests need: prove
    the telemetry → calibration → re-solve loop detects the skew and
    shifts work off the slow stage, without depending on real host noise.
    ``jitter`` adds seeded relative noise so hysteresis sees realistic
    measurements; determinism is per ``(seed, step)``.

    Spec string (``--inject-straggler``): ``STAGE:FACTOR[,STAGE:FACTOR...]
    [@START]`` with 1-based stages, e.g. ``"2:2.5@3"`` = stage 2 runs 2.5x
    slow starting at step 3.
    """

    d_p: int
    factors: Dict[int, float] = field(default_factory=dict)  # 1-based stage
    start_step: int = 0
    jitter: float = 0.0
    seed: int = 0

    @staticmethod
    def parse(spec: str, d_p: int, *, jitter: float = 0.0,
              seed: int = 0) -> "StragglerInjector":
        spec = spec.strip()
        start = 0
        if "@" in spec:
            spec, s = spec.rsplit("@", 1)
            start = int(s)
        factors: Dict[int, float] = {}
        for part in spec.split(","):
            if not part.strip():
                continue
            stage, factor = part.split(":")
            p = int(stage)
            if not 1 <= p <= d_p:
                raise ValueError(f"injector stage {p} outside 1..{d_p}")
            factors[p] = float(factor)
        return StragglerInjector(d_p=d_p, factors=factors,
                                 start_step=start, jitter=jitter, seed=seed)

    def active(self, step: int) -> bool:
        return bool(self.factors) and step >= self.start_step

    def _noise(self, step: int, n: int) -> np.ndarray:
        if self.jitter <= 0:
            return np.ones(n)
        rng = np.random.default_rng((self.seed, step))
        return 1.0 + self.jitter * rng.standard_normal(n)

    def per_stage(self, per_stage_seconds: Sequence[float],
                  step: int) -> List[float]:
        """The per-stage vector a probe would have measured."""
        x = np.asarray(per_stage_seconds, dtype=np.float64)
        out = x * self._noise(step, len(x))
        if self.active(step):
            for p, f in self.factors.items():
                out[p - 1] *= f
        return [float(v) for v in out]

    def wall(self, wall_seconds: float, step: int) -> float:
        """The step wall clock under injection: a pipeline runs at the
        slowest stage's pace, so the worst factor gates the step."""
        w = float(wall_seconds) * float(self._noise(step, 1)[0])
        if self.active(step):
            w *= max(self.factors.values())
        return w
