"""Fault tolerance: restart-from-checkpoint and straggler-aware replanning.

Designed for thousands of nodes:

* **Restart**: `launch/train.py --resume auto` finds the latest committed
  checkpoint (partial saves are invisible — ckpt/checkpoint.py commits via
  the manifest) and resumes; plans are NOT checkpointed — they are
  deterministic functions of (data seed, step, mesh), so a restart on a
  *different* mesh (elastic shrink after losing a pod) simply re-plans.
* **Straggler mitigation**: the trainer records per-stage step times
  (telemetry hook); when a stage's EWMA exceeds the median by
  ``threshold``, the planner re-solves with per-stage slowdown multipliers
  (CostModel.stage_slowdowns) — the chunking rebalances so the slow stage
  receives proportionally lighter chunks. This is the EPP-native answer to
  stragglers: reschedule work, don't wait.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np

from repro.core import CostModel

__all__ = ["StragglerMonitor", "replan_costmodel"]


@dataclass
class StragglerMonitor:
    d_p: int
    ewma: float = 0.3
    threshold: float = 1.25            # x median => flagged
    _t: Optional[np.ndarray] = None

    def observe(self, per_stage_seconds: Sequence[float]) -> None:
        x = np.asarray(per_stage_seconds, dtype=np.float64)
        if self._t is None:
            self._t = x
        else:
            self._t = (1 - self.ewma) * self._t + self.ewma * x

    def slowdowns(self) -> Optional[List[float]]:
        """Per-stage multipliers (>=1) if any straggler is flagged."""
        if self._t is None:
            return None
        med = float(np.median(self._t))
        if med <= 0:
            return None
        mult = np.maximum(self._t / med, 1.0)
        if (mult < self.threshold).all():
            return None
        return [float(m) for m in mult]


def replan_costmodel(cm: CostModel,
                     monitor: StragglerMonitor) -> CostModel:
    """Cost model for the next planning round, straggler-aware."""
    slow = monitor.slowdowns()
    if slow is None:
        return cm
    return cm.with_slowdowns(slow)
