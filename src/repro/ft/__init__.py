from .failures import StragglerMonitor, replan_costmodel

__all__ = ["StragglerMonitor", "replan_costmodel"]
