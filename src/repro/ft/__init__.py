from .failures import StragglerInjector, StragglerMonitor, replan_costmodel

__all__ = ["StragglerInjector", "StragglerMonitor", "replan_costmodel"]
