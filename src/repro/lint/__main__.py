"""Offline pipeline-program audit: ``python -m repro.lint``.

Three audit surfaces, combinable in one invocation:

* ``--arch NAME`` / ``--all`` — plan the arch's workload at a tiny CPU
  geometry (the same planner path real training takes), run the plan
  passes (tick coverage, ckpt table, ppermute ring, bucket-key
  completeness), then AOT trace/lower/compile the train step and run the
  program passes over jaxpr + StableHLO + HLO. ``--target serve`` audits
  the continuous-batching engine step instead (HLO tier only — the
  engine builder returns a ``Compiled``).
* ``--cache-dir DIR`` — jax-free integrity audit of a persistent
  :class:`~repro.runtime.cache_store.CacheStore` (orphan sidecars,
  truncated payloads, sha mismatches, stale fingerprints).
* ``--lower`` — upgrade the bucket-key completeness pass from
  key-inequality to lowering-inequality (each perturbed plan is actually
  lowered; slower but proves distinct keys name distinct programs).

Exit status: 0 when clean (or mode ``warn``), 1 when ``--lint error``
and any finding survived. CI runs representative train + serve buckets
at ``--lint error`` against a committed zero-findings baseline.

Usage:
  PYTHONPATH=src python -m repro.lint --arch gemma3-1b --target train,serve
  PYTHONPATH=src python -m repro.lint --all --json lint-report.json
  PYTHONPATH=src python -m repro.lint --cache-dir runs/ckpt_compile_cache
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def _parse_args(argv=None):
    ap = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="static plan + program audit of the EPP pipeline")
    ap.add_argument("--arch", default=None,
                    help="registry arch(es) to audit, comma-separated "
                         "(configs/registry.py)")
    ap.add_argument("--all", action="store_true",
                    help="audit every registry arch")
    ap.add_argument("--target", default="train",
                    help="comma list of program surfaces: train,serve")
    ap.add_argument("--mesh", default="2x2", help="DPxSP, e.g. 2x2")
    ap.add_argument("--devices", type=int, default=4,
                    help="placeholder CPU device count")
    ap.add_argument("--lengths", default="256,256,128,384",
                    help="comma list of sequence lengths the planner packs")
    ap.add_argument("--bucket-rounding", type=int, default=64)
    ap.add_argument("--schedule", default=None,
                    help="pin a schedule backend (default: planner picks)")
    ap.add_argument("--split-bwd", default="auto",
                    choices=["auto", "on", "off"])
    ap.add_argument("--dtype", default="bfloat16",
                    choices=["bfloat16", "float32"])
    ap.add_argument("--lower", action="store_true",
                    help="bucket-key completeness compares actual "
                         "lowerings, not just key inequality (slow)")
    ap.add_argument("--plan-only", action="store_true",
                    help="skip the AOT compile; audit plan invariants only")
    ap.add_argument("--cache-dir", default=None,
                    help="audit a persistent compile-cache store directory "
                         "(jax-free; combinable with --arch/--all)")
    ap.add_argument("--lint", default="warn", choices=["warn", "error"],
                    help="'error' exits 1 on any finding")
    ap.add_argument("--json", default="",
                    help="write the full report to this JSON file")
    return ap.parse_args(argv)


def _audit_cache_dir(path: str) -> dict:
    from repro.runtime.cache_store import CacheStore
    # audit() is fingerprint-blind, so any fingerprint works here
    store = CacheStore(path, fingerprint={"purpose": "lint-audit"})
    rows = store.audit()
    bad = [r for r in rows if r["problems"]]
    return {"dir": path, "entries": len(rows),
            "corrupt": len(bad), "rows": rows,
            "findings": [f"{r['entry']}: {p}" for r in bad
                         for p in r["problems"]]}


def _train_lower_fn(cfg, mesh, dtype_name):
    """lower_fn(plan_variant, key_kwargs) -> StableHLO text, for the
    lowering tier of the bucket-key completeness pass."""
    import jax
    import jax.numpy as jnp

    from repro.optim import init_opt_state
    from repro.runtime import TrainStepBuilder, batch_struct, make_geometry
    from repro.runtime.sharding import mesh_axis_names

    _, data, model = mesh_axis_names(mesh)
    d_s = mesh.shape[model]

    def lower_fn(plan, key_kwargs):
        key = plan.bucket_key(d_s, **key_kwargs)
        dt = jnp.bfloat16 if key.dtype == "bfloat16" else jnp.float32
        l_max, table, _ = plan.ckpt_policy(key.n_chunks)
        geom = make_geometry(cfg, mesh, n_chunks=key.n_chunks, cap=key.cap,
                             ctx_cap=key.ctx_cap, l_ckpt=l_max,
                             compute_dtype=dt, schedule=key.schedule,
                             v_stages=key.v_stages, ckpt_table=table,
                             split_bwd=key.split_bwd)
        builder = TrainStepBuilder(cfg, mesh, geom, param_dtype=dt)
        params_shape = builder.abstract_params()
        opt_shape = jax.eval_shape(init_opt_state, params_shape)
        bstruct = batch_struct(geom, 1)
        return builder.build(params_shape).lower(
            params_shape, opt_shape, None, bstruct).as_text()

    return lower_fn


def _audit_train(cfg, mesh, plan, args) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.lint.runner import ProgramArtifacts, run_program_checks
    from repro.optim import init_opt_state
    from repro.runtime import TrainStepBuilder, batch_struct, make_geometry
    from repro.runtime.sharding import mesh_axis_names

    _, data, model = mesh_axis_names(mesh)
    d_s = mesh.shape[model]
    key = plan.bucket_key(d_s, split_bwd=args.split_bwd, dtype=args.dtype)
    dt = jnp.bfloat16 if args.dtype == "bfloat16" else jnp.float32
    l_max, table, _ = plan.ckpt_policy(key.n_chunks)
    geom = make_geometry(cfg, mesh, n_chunks=key.n_chunks, cap=key.cap,
                         ctx_cap=key.ctx_cap, l_ckpt=l_max,
                         compute_dtype=dt, schedule=key.schedule,
                         v_stages=key.v_stages, ckpt_table=table,
                         split_bwd=key.split_bwd)
    builder = TrainStepBuilder(cfg, mesh, geom, param_dtype=dt)
    params_shape = builder.abstract_params()
    opt_shape = jax.eval_shape(init_opt_state, params_shape)
    bstruct = batch_struct(geom, 1)
    traced = builder.build(params_shape).trace(params_shape, opt_shape,
                                              None, bstruct)
    lowered = traced.lower()
    compiled = lowered.compile()
    art = ProgramArtifacts(key=key, jaxpr=traced.jaxpr,
                           stablehlo=lowered.as_text(),
                           hlo=compiled.as_text(),
                           platform=jax.default_backend())
    report = run_program_checks(art)
    return {"key": repr(key), "report": report}


def _audit_serve(cfg, mesh, args) -> dict:
    import jax

    from repro.lint.runner import ProgramArtifacts, run_program_checks
    from repro.runtime.compile_cache import (engine_bucket_key,
                                             engine_copy_bucket_key)
    from repro.runtime.serve_step import (EngineStepBuilder,
                                          make_engine_geometry)

    geom = make_engine_geometry(cfg, mesh, n_items=4, cap_t=32, n_pages=8,
                                page_sz=8, k=1)
    builder = EngineStepBuilder(cfg, mesh, geom)
    compiled = builder.build()
    key = engine_bucket_key(geom)
    art = ProgramArtifacts(key=key, hlo=compiled.as_text(),
                           platform=jax.default_backend())
    report = run_program_checks(art)
    # the serve bucket set has a second member: the COW page-copy program
    copy_key = engine_copy_bucket_key(geom)
    copy_art = ProgramArtifacts(key=copy_key,
                                hlo=builder.build_copy().as_text(),
                                platform=jax.default_backend())
    return {"key": repr(key), "report": report,
            "copy_key": repr(copy_key),
            "copy_report": run_program_checks(copy_art)}


def _report_dict(report) -> dict:
    return report.as_dict()


def main(argv=None) -> int:
    args = _parse_args(argv)
    targets = [t for t in args.target.split(",") if t]
    bad_targets = set(targets) - {"train", "serve"}
    if bad_targets:
        print(f"error: unknown --target {sorted(bad_targets)} "
              f"(valid: train, serve)", file=sys.stderr)
        return 2
    if not (args.arch or args.all or args.cache_dir):
        print("error: nothing to audit — pass --arch NAME, --all, "
              "and/or --cache-dir DIR", file=sys.stderr)
        return 2

    out = {"subjects": [], "cache_store": None}
    n_findings = 0
    n_errors = 0

    if args.cache_dir:
        store_audit = _audit_cache_dir(args.cache_dir)
        out["cache_store"] = {k: store_audit[k]
                              for k in ("dir", "entries", "corrupt",
                                        "findings")}
        for f in store_audit["findings"]:
            print(f"[lint] error: cache-store: {f}")
        n_findings += len(store_audit["findings"])
        n_errors += len(store_audit["findings"])
        print(f"[cache-store] {store_audit['entries']} entries, "
              f"{store_audit['corrupt']} corrupt")

    if args.arch or args.all:
        # the placeholder-device flag must precede the first jax import
        flags = os.environ.get("XLA_FLAGS", "")
        if "--xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                flags +
                f" --xla_force_host_platform_device_count={args.devices}"
            ).strip()
        import jax

        from repro.configs import arch_names, get_arch
        from repro.core import (ClusterSpec, CostModel, PlannerConfig,
                                plan_batch)
        from repro.lint.plan_checks import run_plan_checks

        names = arch_names() if args.all else args.arch.split(",")
        d_p, d_s = (int(x) for x in args.mesh.split("x"))
        mesh = jax.make_mesh((d_p, d_s), ("data", "model"))
        lengths = [int(x) for x in args.lengths.split(",")]
        key_kwargs = {"split_bwd": args.split_bwd, "dtype": args.dtype}

        for name in names:
            cfg = get_arch(name).reduced()
            cm = CostModel(cfg.spec, ClusterSpec(d_p=d_p, d_s=d_s))
            plan = plan_batch(
                cm, lengths,
                PlannerConfig(bucket_rounding=args.bucket_rounding,
                              schedule=args.schedule))
            lower_fn = None
            if args.lower and not cfg.spec.is_encoder_decoder:
                lower_fn = _train_lower_fn(cfg, mesh, args.dtype)
            plan_rep = run_plan_checks(plan, d_s, d_p,
                                       key_kwargs=key_kwargs,
                                       lower_fn=lower_fn)
            subject = {"arch": name, "schedule": plan.schedule,
                       "v_stages": plan.v_stages,
                       "plan": _report_dict(plan_rep), "programs": {}}
            reports = [plan_rep]

            if not args.plan_only:
                if "train" in targets:
                    if cfg.spec.is_encoder_decoder:
                        subject["programs"]["train"] = {
                            "skipped": "enc-dec archs compile through the "
                                       "dryrun cell, not TrainStepBuilder"}
                    else:
                        res = _audit_train(cfg, mesh, plan, args)
                        subject["programs"]["train"] = {
                            "key": res["key"],
                            **_report_dict(res["report"])}
                        reports.append(res["report"])
                if "serve" in targets:
                    try:
                        res = _audit_serve(cfg, mesh, args)
                    except NotImplementedError as e:
                        subject["programs"]["serve"] = {
                            "skipped": f"not servable: {e}"}
                    else:
                        subject["programs"]["serve"] = {
                            "key": res["key"],
                            **_report_dict(res["report"])}
                        subject["programs"]["serve-copy"] = {
                            "key": res["copy_key"],
                            **_report_dict(res["copy_report"])}
                        reports.append(res["report"])
                        reports.append(res["copy_report"])

            for rep in reports:
                n_findings += len(rep.findings)
                n_errors += len(rep.errors)
                for f in rep.findings:
                    print(f"[lint] {name}: {f}")
            summaries = " | ".join(r.summary() for r in reports)
            print(f"[{name}] {summaries}")
            out["subjects"].append(subject)

    out["total_findings"] = n_findings
    out["total_errors"] = n_errors
    out["mode"] = args.lint
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(out, fh, indent=1, default=str)
        print(f"[report] wrote {args.json}")
    verdict = ("clean" if n_findings == 0 else
               f"{n_findings} finding(s) ({n_errors} error(s))")
    print(f"[lint] {verdict}")
    if args.lint == "error" and n_findings:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
