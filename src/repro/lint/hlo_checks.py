"""HLO/StableHLO text lint passes: hazards visible in the lowered and
compiled program text.

The compile-path hook runs these on every cold compile — a
``jax.stages.Compiled`` exposes post-optimization HLO via ``as_text()``
and the launch sites stash the pre-compile StableHLO from the
``Lowered`` stage, so no extra tracing happens.

Text anatomy this relies on (jax 0.4.x / XLA):

* StableHLO marks donated arguments with ``jax.buffer_donor = true`` on
  ``@main``'s parameters (arguments that could also be established as
  aliases at lowering time appear as ``tf.aliasing_output = N``).
* Compiled HLO records realized donation in the module header:
  ``input_output_alias={ {out}: (in, {}, may-alias), ... }``.
* Async collectives appear as ``-start``/``-done`` op pairs
  (``collective-permute-start`` etc.); a bare ``collective-permute(``
  is a blocking issue slot the latency-hiding scheduler cannot overlap.
"""

from __future__ import annotations

import re
from typing import Dict, List, Set, Tuple

from .registry import register_pass
from .report import SEV_WARNING, LintReport

__all__ = ["stablehlo_donors", "hlo_alias_map", "stablehlo_main_types"]

_ARG_RE = re.compile(r"%arg(\d+):((?:[^%])*)", re.S)
# output index is empty for a non-tuple (single-output) program:
# "input_output_alias={ {}: (0, {}, may-alias) }"
_ALIAS_PAIR_RE = re.compile(r"\{(\d*)\}:\s*\((\d+),\s*\{\}")
_TENSOR_RE = re.compile(r"tensor<([^>]*)>")


def _balanced(text: str, start: int) -> int:
    """Index of the ``)`` closing the ``(`` at ``start``."""
    depth = 0
    for k in range(start, len(text)):
        if text[k] == "(":
            depth += 1
        elif text[k] == ")":
            depth -= 1
            if depth == 0:
                return k
    return len(text)


def _main_signature(stablehlo: str) -> Tuple[str, str]:
    """``(args_blob, results_blob)`` of ``@main``. A lazy one-regex parse
    truncates at the first ``{jax.result_info = ...}`` attribute brace,
    so the argument and result lists are carved out by balanced parens."""
    i = stablehlo.find("@main(")
    if i < 0:
        return "", ""
    lparen = i + len("@main")
    rparen = _balanced(stablehlo, lparen)
    args = stablehlo[lparen + 1:rparen]
    m = re.match(r"\s*->\s*", stablehlo[rparen + 1:])
    if not m:
        return args, ""
    rest = stablehlo[rparen + 1 + m.end():]
    if rest.startswith("("):
        return args, rest[1:_balanced(rest, 0)]
    return args, re.split(r"[\s{]", rest, 1)[0]


# collectives the EPP hot loop issues every tick; all-reduce excluded —
# the gradient all-reduce at step end is outside the latency-critical
# tick loop and often legitimately synchronous
_BLOCKING_COLLECTIVES = ("collective-permute", "all-gather")


def stablehlo_donors(stablehlo: str) -> Set[int]:
    """Argument indices of ``@main`` marked as buffer donors."""
    args, _ = _main_signature(stablehlo)
    donors: Set[int] = set()
    for am in _ARG_RE.finditer(args):
        attrs = am.group(2)
        if "jax.buffer_donor" in attrs or "tf.aliasing_output" in attrs:
            donors.add(int(am.group(1)))
    return donors


def hlo_alias_map(hlo: str) -> Dict[int, int]:
    """``{input_index: output_index}`` pairs realized by the compiler
    (the ``input_output_alias`` module header)."""
    header_end = hlo.find("\n\n")
    header = hlo[:header_end] if header_end > 0 else hlo
    if "input_output_alias" not in header:
        return {}
    start = header.index("input_output_alias")
    return {int(i): int(o) if o else 0
            for o, i in _ALIAS_PAIR_RE.findall(header[start:])}


def stablehlo_main_types(stablehlo: str
                         ) -> Tuple[List[str], List[str]]:
    """``(arg_types, result_types)`` of ``@main`` as tensor-type strings
    (e.g. ``"4x8xf32"``)."""
    arg_blob, result_blob = _main_signature(stablehlo)
    args = [tm.group(1) for tm in _TENSOR_RE.finditer(arg_blob)]
    # the args blob contains only one tensor<> per %arg (attributes hold
    # no tensor types), so position == arg index
    results = [tm.group(1) for tm in _TENSOR_RE.finditer(result_blob)]
    return args, results


def _elems(tensor_type: str) -> int:
    n = 1
    for part in tensor_type.split("x")[:-1]:
        try:
            n *= int(part)
        except ValueError:
            return 0  # dynamic dim
    return n


# ---------------------------------------------------------------------------


@register_pass("program-donation", kind="program",
               needs=("stablehlo", "hlo"),
               doc="donated params/opt-state actually alias an output; "
                   "state-shaped inputs are donated at all")
def _donation(ctx, report: LintReport) -> None:
    stablehlo = getattr(ctx, "stablehlo", None)
    hlo = getattr(ctx, "hlo", None)
    if not stablehlo or not hlo:
        return
    donors = stablehlo_donors(stablehlo)
    aliased = set(hlo_alias_map(hlo))
    if not donors and not aliased:
        return  # program doesn't donate (dry-run cells): nothing to audit
    arg_types, result_types = stablehlo_main_types(stablehlo)

    dropped = sorted(donors - aliased)
    if dropped:
        shapes = [arg_types[i] if i < len(arg_types) else "?"
                  for i in dropped[:6]]
        report.add("program-donation", SEV_WARNING,
                   f"{len(dropped)} donated input(s) were not aliased to "
                   f"any output (args {dropped[:6]}: {shapes}) — the "
                   f"donation is silently dropped and the buffer is "
                   f"copied; an output dtype/shape drifted from its "
                   f"input, or the input is still live at the end of the "
                   f"step", where=f"args {dropped[:6]}")

    # state-shaped inputs that were never donated: an input whose exact
    # tensor type matches an un-aliased output is round-tripped state
    # paying a full copy per step (the train step's error-feedback
    # buffers were exactly this). Scalars and tiny tensors are ignored.
    aliased_out: Set[int] = set(hlo_alias_map(hlo).values())
    free_out_types = [t for i, t in enumerate(result_types)
                      if i not in aliased_out]
    suspects: List[int] = []
    for i, t in enumerate(arg_types):
        if i in donors or _elems(t) < 1024:
            continue
        if t in free_out_types:
            free_out_types.remove(t)  # one output matches one input
            suspects.append(i)
    if suspects:
        shapes = [arg_types[i] for i in suspects[:6]]
        report.add("program-donation", SEV_WARNING,
                   f"{len(suspects)} non-donated input(s) have the exact "
                   f"type of an un-aliased output (args {suspects[:6]}: "
                   f"{shapes}) — state round-tripped through the step "
                   f"without donation pays a device copy per call; add "
                   f"the argument to donate_argnums",
                   where=f"args {suspects[:6]}")


@register_pass("program-blocking-collective", kind="program",
               needs=("hlo",),
               doc="blocking ppermute/all-gather under a latency-hiding "
                   "schedule (gpu/tpu only)")
def _blocking_collective(ctx, report: LintReport) -> None:
    hlo = getattr(ctx, "hlo", None)
    if not hlo:
        return
    if getattr(ctx, "platform", "cpu") not in ("gpu", "tpu", "cuda",
                                               "rocm"):
        return  # CPU HLO has no async pairs; nothing to hide anyway
    if not getattr(ctx, "latency_hiding", False):
        return
    hits: List[Tuple[str, int]] = []
    for op in _BLOCKING_COLLECTIVES:
        # " op(" matches the synchronous form only: the async pair lowers
        # to "op-start(" / "op-done("
        blocking = len(re.findall(rf"(?<![\w-]){op}\(", hlo))
        if blocking:
            hits.append((op, blocking))
    for op, n in hits:
        report.add("program-blocking-collective", SEV_WARNING,
                   f"{n} blocking {op} op(s) in the compiled program "
                   f"while the latency-hiding scheduler is enabled — the "
                   f"collective serializes against compute instead of "
                   f"overlapping; check the async-collective XLA flags "
                   f"reached this compile", where=op)
