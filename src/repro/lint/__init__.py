"""Pipeline program auditor: static plan/schedule invariants + lowered-
program hazard checks, wired into the compile path and available as an
offline CLI (``python -m repro.lint``).

Two audit surfaces (see ``runtime/README.md``, "Program auditor"):

* **plan passes** (jax-free, run before compile): schedule tick
  coverage, ckpt-table geometry, ppermute ring validity, bucket-key
  completeness.
* **program passes** (run on each cold compile's jaxpr/StableHLO/HLO):
  f64 leakage, bf16->f32 upcast matmuls, dropped/missing donation,
  host callbacks, plan data baked as constants, blocking collectives
  under latency hiding.

Entry points: :func:`run_plan_checks`, :func:`run_program_checks`,
:func:`make_cache_lint` (the ``CompileCache(lint=...)`` hook factory).
"""

from .hlo_checks import stablehlo_donors
from .plan_checks import (BUCKET_KEY_AXES, PlanContext,
                          check_bucket_key_completeness,
                          check_ppermute_perm, run_plan_checks)
from .registry import LintPass, available_passes, get_pass, register_pass
from .report import (LINT_MODES, SEV_ERROR, SEV_WARNING, Finding,
                     LintError, LintReport)
from .runner import ProgramArtifacts, make_cache_lint, run_program_checks

__all__ = [
    "Finding", "LintReport", "LintError", "LINT_MODES",
    "SEV_ERROR", "SEV_WARNING",
    "LintPass", "register_pass", "get_pass", "available_passes",
    "PlanContext", "run_plan_checks", "check_ppermute_perm",
    "check_bucket_key_completeness", "BUCKET_KEY_AXES",
    "ProgramArtifacts", "run_program_checks", "make_cache_lint",
    "stablehlo_donors",
]
