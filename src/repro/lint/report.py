"""Findings, reports and severity for the pipeline program auditor.

A *finding* is one violated invariant: which pass saw it, how bad it is,
what the evidence was. A *report* collects findings for one audited
subject (a plan, or one cold-compiled program) plus the list of passes
that actually ran — "no findings" only means something when you know
which checks were applied.

Severity model (two levels, deliberately no "info" noise tier):

* ``error``   — correctness hazard: the program (or the plan metadata
  driving it) can produce wrong results or alias a wrong executable.
* ``warning`` — performance / operational hazard: the program is correct
  but pays for it (silent upcasts, un-donated state copies, blocking
  collectives under a latency-hiding scheduler).

``--lint`` maps onto reports as: ``off`` never runs passes, ``warn``
logs every finding, ``error`` raises :class:`LintError` when a report is
non-empty (warnings included — the CI baseline is *zero findings*, not
"zero errors plus tolerated noise").
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List

__all__ = ["Finding", "LintReport", "LintError", "LINT_MODES",
           "SEV_ERROR", "SEV_WARNING"]

LINT_MODES = ("off", "warn", "error")
SEV_ERROR = "error"
SEV_WARNING = "warning"


@dataclass(frozen=True)
class Finding:
    """One violated invariant."""

    pass_name: str      # registry name of the pass that found it
    severity: str       # SEV_ERROR | SEV_WARNING
    message: str        # human-readable statement of the violation
    where: str = ""     # locator: bucket key, op name, arg index, ...

    def as_dict(self) -> Dict[str, str]:
        return {"pass": self.pass_name, "severity": self.severity,
                "message": self.message, "where": self.where}

    def __str__(self) -> str:
        loc = f" [{self.where}]" if self.where else ""
        return f"{self.severity}: {self.pass_name}: {self.message}{loc}"


class LintError(RuntimeError):
    """Raised in ``--lint error`` mode when an audit finds anything."""

    def __init__(self, report: "LintReport"):
        self.report = report
        lines = [str(f) for f in report.findings]
        super().__init__(
            f"lint failed with {len(report.findings)} finding(s):\n  "
            + "\n  ".join(lines))


@dataclass
class LintReport:
    """Findings + provenance for one audited subject."""

    subject: str = ""                       # e.g. repr(bucket_key)
    findings: List[Finding] = field(default_factory=list)
    passes_run: List[str] = field(default_factory=list)

    def add(self, pass_name: str, severity: str, message: str,
            where: str = "") -> None:
        self.findings.append(Finding(pass_name, severity, message, where))

    def ran(self, pass_name: str) -> None:
        if pass_name not in self.passes_run:
            self.passes_run.append(pass_name)

    def extend(self, other: "LintReport") -> None:
        self.findings.extend(other.findings)
        for name in other.passes_run:
            self.ran(name)

    # ------------------------------------------------------------------
    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == SEV_ERROR]

    @property
    def warnings(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == SEV_WARNING]

    @property
    def ok(self) -> bool:
        return not self.findings

    def by_pass(self, pass_name: str) -> List[Finding]:
        return [f for f in self.findings if f.pass_name == pass_name]

    # ------------------------------------------------------------------
    def as_dict(self) -> Dict[str, Any]:
        return {"subject": self.subject,
                "passes_run": list(self.passes_run),
                "n_findings": len(self.findings),
                "n_errors": len(self.errors),
                "findings": [f.as_dict() for f in self.findings]}

    def summary(self) -> str:
        if self.ok:
            return (f"clean ({len(self.passes_run)} passes)"
                    + (f" {self.subject}" if self.subject else ""))
        return (f"{len(self.findings)} finding(s) "
                f"({len(self.errors)} error(s)) in "
                f"{len(self.passes_run)} passes"
                + (f" for {self.subject}" if self.subject else ""))

    def raise_if_findings(self) -> None:
        if self.findings:
            raise LintError(self)
