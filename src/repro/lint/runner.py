"""Audit runners: glue between the pass registry and the call sites.

Two entry points:

* :func:`run_program_checks` — run every registered program pass against
  one :class:`ProgramArtifacts` bundle (whatever subset of jaxpr /
  StableHLO / HLO the caller could produce; passes missing their inputs
  skip silently).
* :func:`make_cache_lint` — build the hook :class:`repro.runtime
  .compile_cache.CompileCache` calls on every **cold** compile. The hook
  extracts HLO text from the built executable (duck-typed ``as_text``),
  merges any artifacts the build closure stashed (train/serve stash the
  StableHLO text of the ``Lowered`` stage — free, no extra trace), runs
  the program passes, logs findings, and raises :class:`LintError` in
  ``error`` mode so a hazardous program never enters the cache or the
  persistent store.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

from .registry import available_passes
from .report import LINT_MODES, SEV_ERROR, LintReport

# registration side effects: importing the check modules populates the
# registry exactly once (registry.register_pass rejects duplicates)
from . import plan_checks    # noqa: F401  (plan passes)
from . import jaxpr_checks   # noqa: F401  (program passes, jaxpr tier)
from . import hlo_checks     # noqa: F401  (program passes, text tier)

__all__ = ["ProgramArtifacts", "run_program_checks", "make_cache_lint"]


@dataclass
class ProgramArtifacts:
    """Whatever one cold compile could surface for auditing."""

    key: Any = None                 # bucket key (subject line only)
    jaxpr: Any = None               # ClosedJaxpr (offline CLI / tests)
    stablehlo: Optional[str] = None  # Lowered.as_text()
    hlo: Optional[str] = None        # Compiled.as_text()
    platform: str = "cpu"            # jax.default_backend() at the site
    latency_hiding: bool = False     # launch/mesh.configure_latency_hiding
    const_threshold: int = 1 << 16   # program-baked-constant elements

    def available(self) -> Dict[str, bool]:
        return {"jaxpr": self.jaxpr is not None,
                "stablehlo": bool(self.stablehlo),
                "hlo": bool(self.hlo)}


def run_program_checks(artifacts: ProgramArtifacts) -> LintReport:
    """Run every program pass whose inputs are available."""
    have = artifacts.available()
    report = LintReport(
        subject=repr(artifacts.key) if artifacts.key is not None else "")
    for p in available_passes("program"):
        if p.needs and not any(have.get(n) for n in p.needs):
            continue
        report.ran(p.name)
        try:
            p.fn(artifacts, report)
        except Exception as e:  # noqa: BLE001 - a crashed pass is a finding
            report.add(p.name, SEV_ERROR,
                       f"pass crashed: {type(e).__name__}: {e}")
    return report


def make_cache_lint(mode: str, *, log: Optional[Callable[[str], None]] = None,
                    platform: Optional[str] = None,
                    latency_hiding: bool = False,
                    stash: Optional[Dict[str, Any]] = None) -> Optional[Callable]:
    """The ``CompileCache(lint=...)`` hook for one launch site.

    ``stash`` is a mutable dict the site's build closure may fill with
    richer artifacts (``"stablehlo"``, ``"jaxpr"``) during the cold
    build; the hook pops them so one build's artifacts never leak into
    the next bucket's audit. Returns None for mode ``"off"`` so the
    cache skips the hook entirely.
    """
    if mode not in LINT_MODES:
        raise ValueError(f"lint mode must be one of {LINT_MODES}, "
                         f"got {mode!r}")
    if mode == "off":
        return None
    if platform is None:
        try:
            import jax
            platform = jax.default_backend()
        except Exception:  # noqa: BLE001 - no runtime yet: stay generic
            platform = "cpu"

    def hook(key, value) -> LintReport:
        art = ProgramArtifacts(key=key, platform=platform,
                               latency_hiding=latency_hiding)
        if stash is not None:
            art.stablehlo = stash.pop("stablehlo", None)
            art.jaxpr = stash.pop("jaxpr", None)
        as_text = getattr(value, "as_text", None)
        if callable(as_text):
            try:
                art.hlo = as_text()
            except Exception:  # noqa: BLE001 - text is best-effort
                art.hlo = None
        report = run_program_checks(art)
        if log is not None and report.findings:
            for f in report.findings:
                log(f"[lint] {f}")
        if mode == "error":
            report.raise_if_findings()
        return report

    return hook
