"""Pass registry for the program auditor.

Passes come in two kinds, mirroring the two audit surfaces:

* ``plan`` passes run on solver output (:class:`repro.core.plan
  .ExecutionPlan` + mesh geometry) *before* anything is traced — they are
  jax-free and cheap enough to run on every plan.
* ``program`` passes run on the artifacts of one cold compile — the
  jaxpr, the StableHLO text, the post-compile HLO text, whichever the
  call site could produce. A pass declares which artifacts it can use
  via ``needs`` and is skipped (not failed) when none is available, so
  the same registry serves the inline compile-path hook (HLO text only)
  and the offline CLI (full trace -> jaxpr + both texts).

Adding a pass::

    @register_pass("program-my-check", kind="program", needs=("hlo",),
                   doc="one-line description for the CLI listing")
    def _my_check(ctx, report):
        ...
        report.add("program-my-check", SEV_ERROR, "what went wrong")

The pass function mutates the report; it must not raise for findings
(raising is reserved for broken inputs, which the runner converts into a
``lint-internal`` error finding rather than crashing the host).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

__all__ = ["LintPass", "register_pass", "get_pass", "available_passes"]

PASS_KINDS = ("plan", "program")


@dataclass(frozen=True)
class LintPass:
    name: str
    kind: str                   # "plan" | "program"
    needs: Tuple[str, ...]      # artifacts the pass can consume
    doc: str
    fn: Callable


_PASSES: Dict[str, LintPass] = {}


def register_pass(name: str, *, kind: str, needs: Tuple[str, ...] = (),
                  doc: str = "") -> Callable:
    if kind not in PASS_KINDS:
        raise ValueError(f"kind must be one of {PASS_KINDS}, got {kind!r}")

    def deco(fn: Callable) -> Callable:
        if name in _PASSES:
            raise ValueError(f"lint pass {name!r} already registered")
        _PASSES[name] = LintPass(name=name, kind=kind,
                                 needs=tuple(needs),
                                 doc=doc or (fn.__doc__ or "").strip(),
                                 fn=fn)
        return fn
    return deco


def get_pass(name: str) -> LintPass:
    try:
        return _PASSES[name]
    except KeyError:
        raise ValueError(f"unknown lint pass {name!r}; known: "
                         f"{sorted(_PASSES)}")


def available_passes(kind: Optional[str] = None) -> Tuple[LintPass, ...]:
    return tuple(p for p in _PASSES.values()
                 if kind is None or p.kind == kind)
