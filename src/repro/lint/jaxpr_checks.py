"""Jaxpr-level lint passes: hazards visible in the traced program.

These passes walk a ``ClosedJaxpr`` (the output of ``jax.make_jaxpr`` or
``jax.jit(f).trace(...).jaxpr``) recursively through every sub-jaxpr
(scan bodies, cond branches, remat/pjit calls, custom_vjp rules). They
run in the offline CLI audit and in golden-fixture tests; the compile
path gets the text-based equivalents in ``hlo_checks.py`` because a
``jax.stages.Compiled`` no longer carries its jaxpr.

Passes registered here:

* ``program-f64``            — float64/complex128 values anywhere in a
  program that is supposed to run the bf16 compute path (weak-type
  promotion or a stray ``astype``); doubles memory traffic and silently
  changes numerics across backends.
* ``program-f32-upcast``     — a ``dot_general`` whose operands are ALL
  produced by bf16 -> f32 ``convert_element_type``: the matmul runs in
  f32 instead of bf16-with-f32-accumulation
  (``preferred_element_type``), paying ~2x HBM and FLOP cost for
  bit-identical output. Operands that are natively f32 (e.g. a softmax
  over f32 statistics) do NOT trip this — only the convert-everything
  pattern does.
* ``program-host-callback``  — host callbacks baked into the step
  (``pure_callback``/``io_callback``/debug prints): a host round-trip
  per tick inside the lockstep scan, and a recompile hazard because the
  callback identity is part of the executable.
* ``program-baked-constant`` — large constants captured by the trace
  (plan tables, token buffers): plan *data* must flow in as arguments or
  every new plan recompiles; threshold ``ProgramArtifacts
  .const_threshold`` elements.
"""

from __future__ import annotations

from typing import Any, Dict, Iterator, List, Tuple

from .registry import register_pass
from .report import SEV_ERROR, SEV_WARNING, LintReport

__all__ = ["iter_jaxprs", "iter_eqns"]

MAX_FINDINGS_PER_PASS = 8

# primitives a value passes through without changing its dtype — the
# upcast pass looks through these when resolving an operand's origin
_PASSTHROUGH = frozenset({
    "transpose", "reshape", "broadcast_in_dim", "squeeze", "rev",
    "slice", "dynamic_slice", "stop_gradient", "copy",
})

_CALLBACK_PRIMS = frozenset({
    "pure_callback", "io_callback", "debug_callback", "outside_call",
    "host_callback_call", "callback",
})


def _as_jaxpr(obj: Any):
    """Unwrap ClosedJaxpr -> Jaxpr; return None for anything else."""
    if hasattr(obj, "jaxpr") and hasattr(obj, "consts"):   # ClosedJaxpr
        return obj.jaxpr
    if hasattr(obj, "eqns") and hasattr(obj, "invars"):    # Jaxpr
        return obj
    return None


def iter_jaxprs(jaxpr) -> Iterator[Any]:
    """Yield ``jaxpr`` and every sub-jaxpr reachable through eqn params
    (scan/while bodies, cond branches, pjit/remat/custom_vjp calls)."""
    root = _as_jaxpr(jaxpr)
    if root is None:
        return
    stack = [root]
    seen = set()
    while stack:
        j = stack.pop()
        if id(j) in seen:
            continue
        seen.add(id(j))
        yield j
        for eqn in j.eqns:
            for v in eqn.params.values():
                for cand in (v if isinstance(v, (list, tuple)) else (v,)):
                    sub = _as_jaxpr(cand)
                    if sub is not None:
                        stack.append(sub)


def iter_eqns(jaxpr) -> Iterator[Any]:
    for j in iter_jaxprs(jaxpr):
        yield from j.eqns


def _dtype_name(aval) -> str:
    return str(getattr(aval, "dtype", ""))


def _truncate(report: LintReport, pass_name: str, severity: str,
              messages: List[Tuple[str, str]]) -> None:
    for msg, where in messages[:MAX_FINDINGS_PER_PASS]:
        report.add(pass_name, severity, msg, where=where)
    extra = len(messages) - MAX_FINDINGS_PER_PASS
    if extra > 0:
        report.add(pass_name, severity,
                   f"... and {extra} more occurrence(s) of the same "
                   f"hazard", where="truncated")


# ---------------------------------------------------------------------------


@register_pass("program-f64", kind="program", needs=("jaxpr", "hlo"),
               doc="float64/complex128 values in the bf16 compute path")
def _f64(ctx, report: LintReport) -> None:
    hits: List[Tuple[str, str]] = []
    if getattr(ctx, "jaxpr", None) is not None:
        for eqn in iter_eqns(ctx.jaxpr):
            for var in eqn.outvars:
                name = _dtype_name(getattr(var, "aval", None))
                if name in ("float64", "complex128"):
                    hits.append((
                        f"{eqn.primitive.name} produces {name} "
                        f"{getattr(var.aval, 'shape', ())} — double-"
                        f"precision inside the bf16 compute path",
                        eqn.primitive.name))
                    break  # one finding per eqn
    elif getattr(ctx, "hlo", None):
        n = ctx.hlo.count("f64[") + ctx.hlo.count("c128[")
        if n:
            hits.append((f"{n} f64/c128-typed op(s) in compiled HLO — "
                         f"double-precision inside the bf16 compute "
                         f"path", "hlo-text"))
    _truncate(report, "program-f64", SEV_ERROR, hits)


# sub-jaxpr-carrying primitives whose eqn.invars align positionally with
# the sub-jaxpr's invars, so operand origins can be propagated across the
# scope boundary (scan: consts + carry + xs; the xs slice preserves
# dtype, which is all the upcast analysis needs)
_ALIGNED_CALLS = frozenset({
    "scan", "pjit", "remat", "checkpoint", "closed_call", "core_call",
    "custom_jvp_call", "custom_vjp_call", "custom_vjp_call_jaxpr",
    "shard_map",
})


def _is_bf16_upcast(eqn) -> bool:
    return (eqn is not None
            and eqn.primitive.name == "convert_element_type"
            and _dtype_name(eqn.invars[0].aval) == "bfloat16"
            and _dtype_name(eqn.outvars[0].aval) == "float32")


@register_pass("program-f32-upcast", kind="program", needs=("jaxpr",),
               doc="dot_general whose operands are all bf16->f32 converts "
                   "(use preferred_element_type instead)")
def _f32_upcast(ctx, report: LintReport) -> None:
    if getattr(ctx, "jaxpr", None) is None:
        return
    hits: List[Tuple[str, str]] = []

    def visit(j, invar_origins: Dict[Any, Any]) -> None:
        produced = {v: eqn for eqn in j.eqns for v in eqn.outvars}
        cache: Dict[Any, Any] = dict(invar_origins)

        def origin_of(var):
            # resolve to the defining eqn, looking through
            # dtype-preserving ops and (via invar_origins) scope
            # boundaries — the streaming-CE pattern converts OUTSIDE the
            # vocab-block scan whose body runs the dot
            chain = []
            for _ in range(32):
                if hasattr(var, "val"):   # Literal: unhashable, no producer
                    return None
                if var in cache:
                    break
                eqn = produced.get(var)
                if eqn is not None and eqn.primitive.name in _PASSTHROUGH:
                    chain.append(var)
                    var = eqn.invars[0]
                    continue
                cache[var] = eqn
                break
            result = cache.get(var)
            for v in chain:
                cache[v] = result
            return result

        for eqn in j.eqns:
            if eqn.primitive.name == "dot_general":
                operands = eqn.invars[:2]
                if (len(operands) == 2
                        and all(_dtype_name(getattr(op, "aval", None))
                                == "float32" for op in operands)
                        and all(_is_bf16_upcast(origin_of(op))
                                for op in operands)):
                    shapes = " x ".join(
                        str(tuple(getattr(op.aval, "shape", ())))
                        for op in operands)
                    hits.append((
                        f"dot_general {shapes} runs in f32 but every "
                        f"operand is a bf16->f32 convert — drop the "
                        f"converts and pass preferred_element_type="
                        f"float32 (bf16 products are exact in f32; ~2x "
                        f"less matmul HBM traffic)", "dot_general"))
            aligned = eqn.primitive.name in _ALIGNED_CALLS
            for v in eqn.params.values():
                for cand in (v if isinstance(v, (list, tuple)) else (v,)):
                    sub = _as_jaxpr(cand)
                    if sub is None:
                        continue
                    sub_origins: Dict[Any, Any] = {}
                    if aligned:
                        for outer, inner in zip(eqn.invars, sub.invars):
                            if not hasattr(inner, "val"):
                                sub_origins[inner] = origin_of(outer)
                    visit(sub, sub_origins)

    root = _as_jaxpr(ctx.jaxpr)
    if root is not None:
        visit(root, {})
    _truncate(report, "program-f32-upcast", SEV_WARNING, hits)


@register_pass("program-host-callback", kind="program",
               needs=("jaxpr", "hlo"),
               doc="host callbacks baked into the compiled step")
def _host_callback(ctx, report: LintReport) -> None:
    hits: List[Tuple[str, str]] = []
    if getattr(ctx, "jaxpr", None) is not None:
        for eqn in iter_eqns(ctx.jaxpr):
            name = eqn.primitive.name
            if name in _CALLBACK_PRIMS or name.endswith("_callback"):
                hits.append((
                    f"host callback {name!r} inside the compiled step: a "
                    f"host round-trip per invocation (per TICK if inside "
                    f"the scan) and a recompile hazard — move it out of "
                    f"the traced program", name))
    elif getattr(ctx, "hlo", None):
        for marker in ("custom-call target=\"xla_python_cpu_callback",
                       "custom-call target=\"xla_ffi_python_cpu_callback",
                       "custom_call_target=\"xla_python"):
            if marker in ctx.hlo:
                hits.append(("host-callback custom-call in compiled HLO "
                             "— a host round-trip inside the step",
                             "hlo-text"))
                break
    _truncate(report, "program-host-callback", SEV_WARNING, hits)


@register_pass("program-baked-constant", kind="program", needs=("jaxpr",),
               doc="large constants captured by the trace (plan data "
                   "belongs in arguments, not the executable)")
def _baked_constant(ctx, report: LintReport) -> None:
    jaxpr = getattr(ctx, "jaxpr", None)
    if jaxpr is None or not hasattr(jaxpr, "consts"):
        return
    threshold = int(getattr(ctx, "const_threshold", 1 << 16))
    hits: List[Tuple[str, str]] = []
    for const in jaxpr.consts:
        size = getattr(const, "size", 0)
        if size >= threshold:
            hits.append((
                f"constant of {size} elements "
                f"({getattr(const, 'dtype', '?')}"
                f"{tuple(getattr(const, 'shape', ()))}) baked into the "
                f"program — plan-sized data as a constant forces a "
                f"recompile per plan; pass it as an argument",
                "consts"))
    _truncate(report, "program-baked-constant", SEV_WARNING, hits)
