"""Plan-level lint passes: solver/schedule invariants checked before
anything is traced or compiled.

Everything here is jax-free (like ``repro.core``) so the passes can run
on planner hosts and inside the planning/executor overlap window at
effectively zero cost. The program-level counterparts live in
``jaxpr_checks.py`` / ``hlo_checks.py``.

The bucket-key completeness check is this repo's race-detector
equivalent: the compile cache hands out executables keyed by
``ExecutionPlan.bucket_key()``, so any plan axis that changes the
lowered program but not the key silently aliases a *wrong* executable
across buckets. The check perturbs each axis and demands the key move —
and, when a ``lower_fn`` is supplied, demands that equal keys really do
lower to byte-identical StableHLO.
"""

from __future__ import annotations

import copy
import re
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.schedule import get_schedule, simulate_occupancy, stream_perm
from repro.core.sp import SP_POLICIES, SPConfig, sp_legal

from .registry import register_pass
from .report import SEV_ERROR, LintReport

__all__ = ["PlanContext", "run_plan_checks", "check_ppermute_perm",
           "check_bucket_key_completeness", "BUCKET_KEY_AXES"]

_DIGEST_RE = re.compile(r"^(u\d+|v[0-9a-f]{12})$")

# the plan axes bucket_key() must separate; see
# check_bucket_key_completeness for how each one is perturbed
BUCKET_KEY_AXES = ("schedule", "v_stages", "ckpt", "split_bwd", "dtype",
                   "sp")


@dataclass
class PlanContext:
    """Inputs one plan audit runs against."""

    plan: Any                   # repro.core.plan.ExecutionPlan
    d_s: int
    d_p: int
    n_items: int = 0            # 0 => the key's rounded chunk count
    # kwargs forwarded to bucket_key() at the call site (split_bwd/dtype)
    key_kwargs: Dict[str, Any] = field(default_factory=dict)
    # optional: lower a plan variant to StableHLO text for the deep tier
    # of the bucket-key completeness check. Signature:
    #   lower_fn(plan_variant, key_kwargs) -> str
    lower_fn: Optional[Callable] = None
    # optional: the ModelSpec the plan was solved for — enables the
    # model-dependent tier of plan-sp-legality (head divisibility, MLA,
    # attn-free). Without it only mesh-shape legality is checked.
    model: Any = None

    def resolved_n_items(self) -> int:
        if self.n_items:
            return self.n_items
        return self.plan.bucket_key(self.d_s, **self.key_kwargs).n_chunks


def run_plan_checks(plan, d_s: int, d_p: int, *, n_items: int = 0,
                    key_kwargs: Optional[Dict[str, Any]] = None,
                    lower_fn: Optional[Callable] = None,
                    model: Any = None) -> LintReport:
    """Run every registered plan pass against one ExecutionPlan."""
    from .registry import available_passes
    ctx = PlanContext(plan=plan, d_s=d_s, d_p=d_p, n_items=n_items,
                      key_kwargs=dict(key_kwargs or {}), lower_fn=lower_fn,
                      model=model)
    report = LintReport(subject=repr(plan.bucket_key(d_s, **ctx.key_kwargs)))
    for p in available_passes("plan"):
        report.ran(p.name)
        try:
            p.fn(ctx, report)
        except Exception as e:  # noqa: BLE001 - a crashed pass is a finding
            report.add(p.name, SEV_ERROR,
                       f"pass crashed: {type(e).__name__}: {e}")
    return report


# ---------------------------------------------------------------------------
# tick coverage
# ---------------------------------------------------------------------------


@register_pass("plan-tick-coverage", kind="plan",
               doc="every (item, v_idx) slot mapped exactly once; drain "
                   "tick count matches n*v for split-backward schedules")
def _tick_coverage(ctx: PlanContext, report: LintReport) -> None:
    plan = ctx.plan
    try:
        spec = get_schedule(plan.schedule, plan.v_stages)
    except ValueError as e:
        report.add("plan-tick-coverage", SEV_ERROR,
                   f"schedule resolution failed: {e}",
                   where=f"{plan.schedule} v={plan.v_stages}")
        return
    n = ctx.resolved_n_items()
    try:
        # simulate_occupancy is the schedule oracle: it raises on
        # out-of-range coords, per-device repeats, and incomplete
        # (item, v_idx) coverage
        simulate_occupancy(spec, n, ctx.d_p)
    except ValueError as e:
        report.add("plan-tick-coverage", SEV_ERROR, str(e),
                   where=f"{spec.name} n={n} d_p={ctx.d_p}")
    if spec.split_bwd:
        drain = spec.drain_ticks(n, ctx.d_p)
        if drain != n * spec.v:
            report.add("plan-tick-coverage", SEV_ERROR,
                       f"split-backward drain must cover every W-grad "
                       f"slot: expected n*v = {n * spec.v} drain ticks, "
                       f"schedule reports {drain}",
                       where=f"{spec.name} n={n}")


# ---------------------------------------------------------------------------
# checkpoint table shape
# ---------------------------------------------------------------------------


@register_pass("plan-ckpt-table", kind="plan",
               doc="canonical remat table matches the mesh/bucket "
                   "geometry; digest well-formed")
def _ckpt_table(ctx: PlanContext, report: LintReport) -> None:
    n = ctx.resolved_n_items()
    l_max, table, digest = ctx.plan.ckpt_policy(n)
    if not _DIGEST_RE.match(digest):
        report.add("plan-ckpt-table", SEV_ERROR,
                   f"malformed remat digest {digest!r} (expected 'uN' or "
                   f"'v<sha12>')")
    if table is None:
        if not digest.startswith("u"):
            report.add("plan-ckpt-table", SEV_ERROR,
                       f"uniform policy must carry a 'uN' digest, got "
                       f"{digest!r}")
        return
    if len(table) != ctx.d_p:
        report.add("plan-ckpt-table", SEV_ERROR,
                   f"remat table has {len(table)} stage rows but the mesh "
                   f"runs d_p={ctx.d_p} stages (solved for a different "
                   f"pipeline depth?)", where=digest)
    for p, row in enumerate(table):
        if len(row) != n:
            report.add("plan-ckpt-table", SEV_ERROR,
                       f"stage {p} row has {len(row)} chunk columns, "
                       f"bucket holds {n}", where=digest)
            break
    flat = [v for row in table for v in row]
    bad = [v for v in flat if not isinstance(v, int) or v < 0]
    if bad:
        report.add("plan-ckpt-table", SEV_ERROR,
                   f"remat depths must be non-negative ints, got "
                   f"{bad[:4]}", where=digest)
    elif flat and max(flat) != l_max:
        report.add("plan-ckpt-table", SEV_ERROR,
                   f"l_ckpt={l_max} does not equal the table max "
                   f"{max(flat)} — the key would lie about peak remat",
                   where=digest)


# ---------------------------------------------------------------------------
# ppermute ring validity
# ---------------------------------------------------------------------------


def check_ppermute_perm(perm: List[Tuple[int, int]], d_p: int, *,
                        require_full: bool = False) -> List[str]:
    """Validate a ppermute (src, dst) pair list against ``d_p`` devices.

    ``require_full`` demands a total permutation (every device appears
    exactly once as source and once as destination) — the closed-ring
    hand-off interleaved schedules rely on. Returns a list of problem
    strings (empty == valid)."""
    problems: List[str] = []
    srcs = [s for s, _ in perm]
    dsts = [d for _, d in perm]
    for s, d in perm:
        if not (0 <= s < d_p) or not (0 <= d < d_p):
            problems.append(f"pair ({s}, {d}) out of range for d_p={d_p}")
    dup_src = sorted({s for s in srcs if srcs.count(s) > 1})
    dup_dst = sorted({d for d in dsts if dsts.count(d) > 1})
    if dup_src:
        problems.append(f"duplicate source device(s) {dup_src}: a device "
                        f"cannot send two streams in one ppermute")
    if dup_dst:
        problems.append(f"duplicate destination device(s) {dup_dst}: "
                        f"colliding writes clobber a stream")
    if require_full and (len(perm) != d_p or set(srcs) != set(range(d_p))
                         or set(dsts) != set(range(d_p))):
        problems.append(
            f"ring hand-off must be a total permutation of {d_p} "
            f"devices, got sources {sorted(set(srcs))} -> destinations "
            f"{sorted(set(dsts))}")
    return problems


@register_pass("plan-ppermute-ring", kind="plan",
               doc="stage hand-off permutation is a valid ring/shift for "
                   "the pipeline depth")
def _ppermute_ring(ctx: PlanContext, report: LintReport) -> None:
    plan, d_p = ctx.plan, ctx.d_p
    ring = plan.v_stages > 1  # interleaved routes d_p-1 -> 0
    perm = stream_perm(d_p, ring=ring)
    for msg in check_ppermute_perm(perm, d_p,
                                   require_full=ring and d_p > 1):
        report.add("plan-ppermute-ring", SEV_ERROR, msg,
                   where=f"d_p={d_p} ring={ring}")
    # the schedule's virtual-stage routing additionally needs the closed
    # ring even when this plan's consensus pick is v=1-capable
    if ring and d_p > 1:
        expected = [(i, (i + 1) % d_p) for i in range(d_p)]
        if sorted(perm) != sorted(expected):
            report.add("plan-ppermute-ring", SEV_ERROR,
                       f"interleaved hand-off must close the ring "
                       f"{expected}, got {perm}",
                       where=f"d_p={d_p}")


# ---------------------------------------------------------------------------
# bucket-key completeness
# ---------------------------------------------------------------------------


def _ckpt_variant(plan, cells: List[Tuple[int, int]]):
    """Deep-copied plan whose remat vector is zero except ``cells`` (each
    set to 1), in stage-aware mode — a digest-only perturbation: l_max
    stays 1, the vector (and so the 'v<sha>' digest) moves."""
    v = copy.deepcopy(plan)
    v.remat_mode = "stage_aware"
    if not v.pipelines:
        return None
    pipe = v.pipelines[0]
    n = max(pipe.n_chunks, 2)
    rows = max(len(pipe.ckpt), 2)
    pipe.ckpt = [[0] * n for _ in range(rows)]
    for r, c in cells:
        pipe.ckpt[r % rows][c % n] = 1
    return v


def check_bucket_key_completeness(plan, d_s: int, *,
                                  key_kwargs: Optional[Dict] = None,
                                  lower_fn: Optional[Callable] = None,
                                  ) -> List[Tuple[str, str]]:
    """Perturb each plan axis and demand ``bucket_key()`` separates it.

    Returns ``(axis, problem)`` pairs. For each axis two plan variants
    are synthesized that differ *only* in that axis; if their keys
    collide the axis is invisible to the compile cache and plans would
    alias each other's executables. With ``lower_fn`` the check is
    refined: colliding keys are tolerated iff both variants lower to
    byte-identical StableHLO (the axis is genuinely inert at this
    geometry)."""
    import dataclasses

    kw = dict(key_kwargs or {})
    kw.pop("split_bwd", None)
    kw.pop("dtype", None)

    def variants(axis: str):
        if axis == "schedule":
            a = dataclasses.replace(plan, schedule="gpipe-1f1b", v_stages=1)
            b = dataclasses.replace(plan, schedule="interleaved-1f1b",
                                    v_stages=1)
            return (a, dict(kw, split_bwd=False, dtype="bfloat16")), \
                   (b, dict(kw, split_bwd=False, dtype="bfloat16"))
        if axis == "v_stages":
            a = dataclasses.replace(plan, schedule="interleaved-1f1b",
                                    v_stages=2)
            b = dataclasses.replace(plan, schedule="interleaved-1f1b",
                                    v_stages=4)
            return (a, dict(kw, split_bwd=False, dtype="bfloat16")), \
                   (b, dict(kw, split_bwd=False, dtype="bfloat16"))
        if axis == "ckpt":
            a = _ckpt_variant(plan, [(0, 0)])
            b = _ckpt_variant(plan, [(0, 1)])
            if a is None or b is None:
                return None
            kk = dict(kw, split_bwd=False, dtype="bfloat16")
            return (a, kk), (b, kk)
        if axis == "split_bwd":
            return (plan, dict(kw, split_bwd=False, dtype="bfloat16")), \
                   (plan, dict(kw, split_bwd=True, dtype="bfloat16"))
        if axis == "dtype":
            return (plan, dict(kw, split_bwd=False, dtype="bfloat16")), \
                   (plan, dict(kw, split_bwd=False, dtype="float32"))
        if axis == "sp":
            # two SP points that always differ in BOTH fields; legality
            # is irrelevant here — only key separation is probed
            a = dataclasses.replace(plan, sp=SPConfig("none", 1))
            b = dataclasses.replace(
                plan, sp=SPConfig("allgather_kv", max(d_s, 2)))
            kk = dict(kw, split_bwd=False, dtype="bfloat16")
            return (a, kk), (b, kk)
        raise ValueError(f"unknown bucket-key axis {axis!r}")

    problems: List[Tuple[str, str]] = []
    for axis in BUCKET_KEY_AXES:
        pair = variants(axis)
        if pair is None:
            continue  # empty plan: nothing to perturb
        (pa, ka), (pb, kb) = pair
        try:
            key_a = pa.bucket_key(d_s, **ka)
            key_b = pb.bucket_key(d_s, **kb)
        except TypeError as e:
            problems.append((axis, f"bucket_key() rejected the "
                                   f"{axis} perturbation kwargs: {e}"))
            continue
        if key_a != key_b:
            continue
        if lower_fn is not None:
            try:
                if lower_fn(pa, ka) == lower_fn(pb, kb):
                    continue  # axis inert at this geometry: safe collision
                problems.append(
                    (axis, f"perturbing {axis} changes the lowered "
                           f"StableHLO but not bucket_key() — plans would "
                           f"alias a wrong executable (key={key_a!r})"))
                continue
            except Exception as e:  # noqa: BLE001 - lowering is best-effort
                problems.append((axis, f"lowering failed while probing "
                                       f"{axis}: {type(e).__name__}: {e}"))
                continue
        problems.append(
            (axis, f"perturbing {axis} does not change bucket_key() "
                   f"(key={key_a!r}); the compile cache cannot separate "
                   f"plans along this axis"))
    return problems


@register_pass("plan-bucket-key", kind="plan",
               doc="every plan axis (schedule, v_stages, ckpt digest, "
                   "split_bwd, dtype, sp) is visible to bucket_key()")
def _bucket_key(ctx: PlanContext, report: LintReport) -> None:
    for axis, msg in check_bucket_key_completeness(
            ctx.plan, ctx.d_s, key_kwargs=ctx.key_kwargs,
            lower_fn=ctx.lower_fn):
        report.add("plan-bucket-key", SEV_ERROR, msg, where=axis)


# ---------------------------------------------------------------------------
# sequence-parallel legality
# ---------------------------------------------------------------------------


@register_pass("plan-sp-legality", kind="plan",
               doc="plan's SP policy is known, the effective degree "
                   "divides the model axis, and (when the ModelSpec is "
                   "supplied) the policy is legal for the model")
def _sp_legality(ctx: PlanContext, report: LintReport) -> None:
    spc = getattr(ctx.plan, "sp", None)
    if spc is None:
        # legacy sp-less plan: bucket_key() resolves it to ("auto", d_s)
        # and the runtime rederives the policy at full degree — nothing
        # to validate
        return
    where = f"sp=({spc.policy}, {spc.d_s_eff}) d_s={ctx.d_s}"
    if spc.policy not in SP_POLICIES:
        report.add("plan-sp-legality", SEV_ERROR,
                   f"unknown SP policy {spc.policy!r} (expected one of "
                   f"{SP_POLICIES})", where=where)
        return
    if spc.d_s_eff < 1 or ctx.d_s % spc.d_s_eff:
        report.add("plan-sp-legality", SEV_ERROR,
                   f"effective SP degree {spc.d_s_eff} must divide the "
                   f"mesh's model-axis size {ctx.d_s} (sub-groups cannot "
                   f"tile the axis otherwise)", where=where)
        return
    if ctx.model is not None and not sp_legal(ctx.model, spc.policy,
                                              spc.d_s_eff):
        m = ctx.model
        report.add("plan-sp-legality", SEV_ERROR,
                   f"policy {spc.policy!r} is illegal at d_s_eff="
                   f"{spc.d_s_eff} for this model (heads={m.n_heads}/"
                   f"{m.n_kv_heads}, mla={m.kv_lora_rank > 0}, "
                   f"attn_free={m.attn_free}): ulysses needs divisible "
                   f"non-MLA heads, 'none' with attention needs degree 1, "
                   f"attn-free models shard only via 'none'", where=where)
