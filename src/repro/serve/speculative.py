"""Self-speculative decode streams: draft proposal + greedy verification.

Each decode tick carries ``k`` tokens per stream through the pipeline: the
last accepted token plus ``k - 1`` *draft* tokens. The engine step returns
the model's greedy id after every fed position, and :func:`verify_greedy`
accepts the longest draft prefix the model agrees with. For greedy
decoding this is **exact**: the emitted stream is bitwise the k=1 greedy
stream no matter how bad the draft is — draft quality only changes how
many tokens each tick advances (``SpecStats.acceptance_rate``), never
which tokens come out (tests/test_serve_engine.py asserts k=2 == k=1).

The draft itself is prompt-lookup style self-drafting (no draft model): the
longest recent n-gram suffix of the request's history is searched for an
earlier occurrence and its continuation proposed, falling back to repeating
the last token. Rows written for rejected drafts sit at positions beyond
the stream's committed length, so they are masked out of attention and
overwritten by the next tick — no cache cleanup step exists or is needed.
Under the paged pool the engine additionally caps the draft length to the
tokens the request can still emit (``min(k-1, max_new - emitted - 1)``):
a doomed draft would both skew ``acceptance_rate`` downward and write KV
rows past the request's own page table (the device trash-guards such
writes, but the host never plans them). Rejected-draft rows are never
*published*: the prefix cache only indexes pages whose every row is
committed, so sharing cannot observe draft garbage.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence

__all__ = ["SpecStats", "propose_draft", "verify_greedy"]


@dataclass
class SpecStats:
    decode_ticks: int = 0
    drafted: int = 0         # draft tokens proposed (k - 1 per tick)
    accepted: int = 0        # draft tokens the model agreed with
    emitted: int = 0         # tokens emitted by decode ticks (>= ticks)

    @property
    def acceptance_rate(self) -> float:
        if not self.drafted:
            return 0.0
        return self.accepted / self.drafted

    @property
    def tokens_per_tick(self) -> float:
        if not self.decode_ticks:
            return 0.0
        return self.emitted / self.decode_ticks

    def as_dict(self) -> Dict[str, float]:
        return {
            "decode_ticks": self.decode_ticks,
            "drafted": self.drafted,
            "accepted": self.accepted,
            "emitted": self.emitted,
            "acceptance_rate": round(self.acceptance_rate, 4),
            "tokens_per_tick": round(self.tokens_per_tick, 4),
        }


def propose_draft(history: Sequence[int], n: int, *,
                  ngram: int = 3) -> List[int]:
    """Propose ``n`` draft tokens from ``history`` (prompt + emitted so
    far). Tries the longest suffix n-gram (length ``ngram`` down to 1),
    takes the continuation of its most recent earlier occurrence, and pads
    by repeating the last proposed (or last history) token. Pure host-side
    — the device never sees whether a token was drafted or real."""
    if n <= 0:
        return []
    h = [int(t) for t in history]
    draft: List[int] = []
    for g in range(min(ngram, len(h)), 0, -1):
        key = h[-g:]
        # most recent earlier occurrence whose continuation exists
        for i in range(len(h) - g - 1, -1, -1):
            if h[i:i + g] == key:
                draft = h[i + g:i + g + n]
                break
        if draft:
            break
    last = draft[-1] if draft else (h[-1] if h else 0)
    while len(draft) < n:
        draft.append(last)
    return draft[:n]


def verify_greedy(fed_tokens: Sequence[int], out_ids: Sequence[int]
                  ) -> List[int]:
    """Greedy acceptance rule. ``fed_tokens = [t0, d1, .., d_{k-1}]`` were
    fed this tick (t0 = last accepted token, d_i = drafts); ``out_ids[i]``
    is the model's greedy id after consuming ``fed_tokens[:i + 1]``. Draft
    ``d_i`` is accepted iff it equals ``out_ids[i - 1]`` — i.e. iff greedy
    decode would have produced it — scanning left to right and stopping at
    the first disagreement. Returns the emitted tokens
    ``out_ids[0 .. n_accepted]`` (always at least one: the k=1 behavior)."""
    k = len(fed_tokens)
    if k == 0 or len(out_ids) < k:
        raise ValueError(f"need >= {k} output ids, got {len(out_ids)}")
    a = 0
    while a < k - 1 and int(fed_tokens[a + 1]) == int(out_ids[a]):
        a += 1
    return [int(x) for x in out_ids[:a + 1]]
