"""Continuous-batching serving engine on the EPP pipeline.

Request lifecycle::

    submit() ──> waiting ──admit (page table + prefix match)──> prefill ──> decode ──> done
                   │                                              │            │
                   └── queue (pages short / budget) ──────────────┴── step() packs both
                       into ONE fixed-shape engine program per step

Every :meth:`ServeEngine.step` builds one packed batch for the compiled
engine program (``runtime.serve_step.engine_step_fn``): decode segments
(k tokens per running stream — speculative drafts verified on the host)
co-scheduled with chunked-prefill segments (prompts sliced by the
trainer's ``core.chunking.prompt_slices`` capacity logic). KV rows live
in a PAGED pool (``kv_manager.PagedKVPool`` host-side, the
sequence-sharded device buffer in ``runtime.serve_step``): admission
reserves nothing, pages are allocated on write, and chunked prefill
skips whole pages whose chain hash is already resident (prefix cache) —
shared pages are refcounted and copy-on-write protected, with the page
copies batched through a second tiny compiled program. Because
per-request lengths and page tables are data rather than shape, the
compile cache sees exactly TWO bucket keys per engine configuration
(``compile_cache.engine_bucket_key`` + ``engine_copy_bucket_key``, both
built deterministically) — the second pass over any trace compiles
nothing, and a persistent :class:`CacheStore` warm-starts even the first.

Prefix sharing is exact: a cached page's rows are a deterministic
function of the full token prefix (the chain hash pins it) computed by
the SAME compiled program, and masked attention scores underflow to
exact zeros, so adopted pages are bitwise identical to recomputed ones
and greedy outputs cannot change (runtime/README.md §Paged KV pool).

:func:`one_shot_generate` is the parity oracle: the pre-engine one-shot
serve path (whole-prompt prefill through ``pipeline_loss_fn``'s prefill
mode, teacher-forced full recompute per emitted token — no KV reuse). The
engine's greedy output ids must match it exactly at every ``k``
(tests/test_serve_engine.py).
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .kv_manager import PagedKVPool
from .scheduler import SchedulerConfig, Segment, StepPlan, TickScheduler
from .speculative import SpecStats, propose_draft, verify_greedy

__all__ = ["EngineConfig", "Request", "RequestResult", "ServeEngine",
           "one_shot_generate"]


@dataclass
class EngineConfig:
    """Host-visible engine knobs. (n_items, cap_t, n_pages, page_sz,
    pages_per_seq, k, copy_cap) are the compiled geometry — one step + one
    copy bucket per distinct tuple; the budgets, the prefill mode and the
    prefix cache are pure host policy (no recompile)."""
    n_items: int = 4             # packed chunk items per engine step
    cap_t: int = 64              # tokens per item
    n_pages: int = 16            # KV pages pool-wide (scales with d_s)
    page_sz: int = 16            # cache rows per page
    pages_per_seq: Optional[int] = None   # table entries (None = n_pages);
    # pages_per_seq * page_sz is the max context of one request
    k: int = 1                   # decode tokens per stream per step
    copy_cap: int = 4            # COW page copies per copy-program call
    prefix_cache: bool = True    # content-addressed page sharing
    prefill_chunk: Optional[int] = None   # max prefill chunk (default cap_t)
    decode_token_budget: Optional[int] = None
    prefill_token_budget: Optional[int] = None
    prefill_mode: str = "interleaved"     # | "serial" (stop-the-world)
    draft_ngram: int = 3
    sim_dt: float = 1.0          # simulated seconds per engine step
    # preempt a decode stream when the admission queue's head has waited
    # this many steps without the pages to admit it (None = never): the
    # victim's pages are freed (but stay prefix-cached) and it requeues for
    # a resume-prefill of its history — outputs are unchanged (greedy is
    # deterministic), only latency moves
    preempt_waiting_steps: Optional[int] = None


@dataclass
class Request:
    req_id: int
    prompt: np.ndarray           # int32 [L]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    arrival: float = 0.0         # simulated arrival time


@dataclass
class RequestResult:
    req_id: int
    prompt_len: int
    output_ids: List[int]
    submitted_step: int
    first_token_step: int        # TTFT in engine steps
    finished_step: int
    ttft_s: float                # wall-clock submit -> first token
    # wall-clock mean per output token after the 1st; None when fewer than
    # 2 tokens were emitted (a single-token request HAS no inter-token
    # latency — reporting 0.0 and filtering ">0" silently biased the
    # percentiles optimistic on short-output traces)
    tpot_s: Optional[float]
    preempted: int = 0           # times this request lost its pages

    @property
    def ttft_steps(self) -> int:
        return self.first_token_step - self.submitted_step


@dataclass
class _ReqState:
    req: Request
    phase: str = "waiting"       # waiting | prefill | decode | done
    committed: int = 0           # valid cache rows (tokens fed & accepted)
    chunks: List[Tuple[int, int]] = field(default_factory=list)
    next_chunk: int = 0
    # tokens being prefilled: the prompt on first admission; on a resume
    # after preemption, history[:-1] (everything but the un-fed last token)
    prefill_target: List[int] = field(default_factory=list)
    waiting_since: int = 0
    next_token: int = -1         # last emitted, not yet fed token
    output: List[int] = field(default_factory=list)
    history: List[int] = field(default_factory=list)  # prompt + output
    submitted_step: int = 0
    submit_wall: float = 0.0
    first_token_step: int = -1
    first_wall: float = 0.0
    finished_step: int = -1
    done_wall: float = 0.0
    preempted: int = 0


class ServeEngine:
    """Continuous-batching engine over one compiled EPP stage program."""

    def __init__(self, cfg_arch, mesh, config: EngineConfig, *,
                 params: Optional[Dict] = None, param_dtype=None,
                 compute_dtype=None, cache=None, store=None,
                 seed: int = 0, log: Optional[Callable] = None,
                 timeline=None):
        import jax
        import jax.numpy as jnp

        from repro.core import ClusterSpec, CostModel
        from repro.runtime.compile_cache import CompileCache
        from repro.runtime.serve_step import (EngineStepBuilder,
                                              make_engine_geometry)

        self.cfg_arch = cfg_arch
        self.mesh = mesh
        self.config = config
        self.log = log
        # optional telemetry.StepTimeline: run() records one "engine"
        # event per drained trace (TTFT/TPOT percentiles + occupancy)
        self.timeline = timeline
        param_dtype = param_dtype or jnp.float32
        compute_dtype = compute_dtype or param_dtype
        self.geom = make_engine_geometry(
            cfg_arch, mesh, n_items=config.n_items, cap_t=config.cap_t,
            n_pages=config.n_pages, page_sz=config.page_sz,
            pages_per_seq=config.pages_per_seq, k=config.k,
            copy_cap=config.copy_cap, compute_dtype=compute_dtype)
        self.builder = EngineStepBuilder(cfg_arch, mesh, self.geom,
                                         param_dtype=param_dtype)
        self.params = params if params is not None else \
            self.builder.init_params(jax.random.PRNGKey(seed))
        self._params_shape = jax.eval_shape(lambda: self.params)
        self.cache = cache if cache is not None else \
            CompileCache(name="serve-engine", log=log, store=store)
        self.pool_state = self.builder.init_pool()
        self.pool = PagedKVPool(config.n_pages, config.page_sz,
                                prefix_cache=config.prefix_cache)
        self.scheduler = TickScheduler(SchedulerConfig(
            n_items=config.n_items, cap_t=config.cap_t, k=config.k,
            decode_token_budget=config.decode_token_budget,
            prefill_token_budget=config.prefill_token_budget,
            prefill_mode=config.prefill_mode))
        # prompt slicing reuses the trainer's workload-balanced capacity
        # logic (Alg. 1 line 1) — chunked prefill IS token-level PP
        pod, data, model = _axes(mesh)
        self._cm = CostModel(cfg_arch.spec,
                             ClusterSpec(d_p=mesh.shape[data],
                                         d_s=mesh.shape[model]))
        self.spec_stats = SpecStats()
        self._waiting: "deque[_ReqState]" = deque()
        self._running: List[_ReqState] = []      # prefill + decode phases
        self._states: Dict[int, _ReqState] = {}
        self.results: Dict[int, RequestResult] = {}
        self.rejected: Dict[int, str] = {}
        self.step_count = 0
        self.sim_time = 0.0
        self._emitted_total = 0
        self._prefill_fed = 0    # prompt tokens actually fed (prefix-cache
        self._run_wall = 0.0     # hits reduce this — the benchmark's gate)
        # build the COW copy program EAGERLY: the serve bucket set must be
        # deterministically closed (2 buckets) whether or not the trace
        # ever triggers a copy — pass 2 compiles nothing either way
        self._copy_fn = self.cache.get(self.copy_bucket_key,
                                       self.builder.build_copy)

    # ------------------------------------------------------------------
    @property
    def bucket_key(self):
        from repro.runtime.compile_cache import engine_bucket_key
        return engine_bucket_key(self.geom)

    @property
    def copy_bucket_key(self):
        from repro.runtime.compile_cache import engine_copy_bucket_key
        return engine_copy_bucket_key(self.geom)

    def _build_step(self):
        return self.builder.build(self._params_shape)

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        """Queue a request. Admission is validated against the page-table
        geometry up front — an over-long prompt is REJECTED with a clear
        error instead of silently truncating its context (the old
        launch/serve.py failure mode)."""
        plen = int(len(req.prompt))
        need = plen + req.max_new_tokens
        if plen < 1:
            raise ValueError(f"request {req.req_id}: empty prompt")
        if req.req_id in self._states:
            raise ValueError(f"request id {req.req_id} already submitted")
        if need > self.geom.max_ctx:
            raise ValueError(
                f"request {req.req_id}: prompt ({plen}) + max_new_tokens "
                f"({req.max_new_tokens}) = {need} exceeds the page-table "
                f"capacity pages_per_seq * page_sz = {self.geom.max_ctx}; "
                f"raise --pages / --page-sz or split the request (context "
                f"is never silently truncated)")
        st = _ReqState(req=req, submitted_step=self.step_count,
                       submit_wall=time.perf_counter(),
                       waiting_since=self.step_count,
                       history=[int(t) for t in req.prompt])
        self._states[req.req_id] = st
        self._waiting.append(st)

    @property
    def n_active(self) -> int:
        return len(self._waiting) + len(self._running)

    # ------------------------------------------------------------------
    def _admit(self) -> None:
        from repro.core.chunking import prompt_slices
        cap = min(self.config.prefill_chunk or self.geom.cap_t,
                  self.geom.cap_t)
        ps = self.geom.page_sz
        while self._waiting:
            st = self._waiting[0]
            rid = st.req.req_id
            # resume after preemption: re-prefill everything already fed
            # (history minus the un-fed last token); fresh requests prefill
            # the prompt — and must FEED at least its last token (the TTFT
            # token comes out of it), so the prefix match stops one short
            target = st.history[:-1] if st.output \
                else [int(t) for t in st.req.prompt]
            mr = len(target) if st.output else len(target) - 1
            pages_hit, rows_hit = self.pool.match_prefix(target, mr)
            remaining = len(target) - rows_hit
            # admission gate: the first chunk (or the resume's first decode
            # write) must be able to allocate its pages, else the stream
            # would be admitted only to stall — wait (or preempt) instead
            first_rows = min(remaining, cap) if remaining else 1
            end = rows_hit + first_rows
            needed = -(-end // ps) - len(pages_hit)
            if rows_hit % ps and pages_hit:
                needed += 1      # shared partial tail: first write may COW
            # adopting a free-but-cached page RESURRECTS it off the free
            # list — it costs a free slot exactly like a fresh allocation.
            # Not charging resurrections let admission drain the whole
            # free pool into doomed prefills while a running decode stream
            # starved on one page (preemption livelock, tested).
            needed += sum(1 for p in pages_hit
                          if self.pool.refcount(p) == 0)
            if needed > self.pool.n_free and self.pool.in_use > 0:
                if self._maybe_preempt(st):
                    continue    # retry against the freed pages
                return
            self._waiting.popleft()
            self.pool.alloc_table(rid)
            if pages_hit:
                self.pool.adopt_prefix(rid, pages_hit, rows_hit)
            st.phase = "prefill"
            st.committed = rows_hit
            st.next_chunk = 0
            st.prefill_target = target
            off, st.chunks = rows_hit, []
            if remaining:
                for ln in prompt_slices(self._cm, remaining, cap):
                    st.chunks.append((off, ln))
                    off += ln
            else:
                # resume fully served by the cache: straight back to decode
                st.phase = "decode"
            self._running.append(st)

    def _preempt_stream(self, victim: _ReqState) -> None:
        """Publish then free the victim's pages and requeue it for a
        resume-prefill. Its published pages stay cached, so the resume
        typically prefix-hits most of its own history. Greedy decode is
        deterministic, so preemption can never change a request's output
        ids — only its latency (tested)."""
        rid = victim.req.req_id
        self.pool.publish_ready(rid, victim.history, victim.committed)
        self.pool.preempt(rid)
        victim.phase = "waiting"
        victim.preempted += 1
        victim.waiting_since = self.step_count
        self._running.remove(victim)
        self._waiting.append(victim)

    def _maybe_preempt(self, head: _ReqState) -> bool:
        """Page-short admission policy: once the queue's head has waited
        ``preempt_waiting_steps`` steps, evict the most recently admitted
        decode stream (its first token is already out — decode-phase
        implies progress)."""
        n = self.config.preempt_waiting_steps
        if n is None or self.step_count - head.waiting_since < n:
            return False
        victims = [s for s in self._running if s.phase == "decode"]
        if not victims:
            return False
        self._preempt_stream(victims[-1])
        return True

    # ------------------------------------------------------------------
    def _candidates(self) -> Tuple[List[Segment], List[List[Segment]]]:
        dec: List[Segment] = []
        pre: List[List[Segment]] = []
        k = self.geom.k
        for st in self._running:
            rid = st.req.req_id
            if st.phase == "decode":
                # cap the draft so the stream never writes past its own
                # page table (pos <= plen + max_new - 2 < max_ctx) and the
                # last useful token isn't padded with doomed drafts
                n_draft = max(0, min(k - 1, st.req.max_new_tokens
                                     - len(st.output) - 1))
                draft = propose_draft(st.history, n_draft,
                                      ngram=self.config.draft_ngram)
                dec.append(Segment(
                    req_id=rid, kind="decode",
                    tokens=(st.next_token, *draft),
                    base=st.committed))
            elif st.phase == "prefill":
                segs = []
                for off, ln in st.chunks[st.next_chunk:]:
                    segs.append(Segment(
                        req_id=rid, kind="prefill",
                        tokens=tuple(st.prefill_target[off:off + ln]),
                        base=off))
                pre.append(segs)
        return dec, pre

    # ------------------------------------------------------------------
    def _secure_pages(self, plan: StepPlan) -> List[Tuple[int, int]]:
        """Walk the plan in execution order and make every page each
        segment will write allocated and writable: logical page ``idx``
        already in the table goes through :meth:`PagedKVPool.
        ensure_writable` (COW pairs are returned for the device copy
        program); pages past the table are allocated on write. A segment
        whose pages cannot be secured is dropped from the plan (deferred),
        along with every later segment of the same request."""
        ps, pp = self.geom.page_sz, self.geom.pages_per_seq
        copies: List[Tuple[int, int]] = []
        dropped: set = set()
        for item in plan.items:
            kept = []
            for sg in item:
                rid = sg.req_id
                ok = rid not in dropped
                if ok:
                    table = self.pool.table_of(rid)
                    end = min(sg.start + len(sg.tokens), pp * ps)
                    for idx in range(sg.start // ps,
                                     max(end - 1, sg.start) // ps + 1):
                        if idx < len(table):
                            status, pair = self.pool.ensure_writable(
                                rid, idx)
                            if status == "fail":
                                ok = False
                                break
                            if pair is not None:
                                copies.append(pair)
                        else:
                            while ok and len(table) <= idx:
                                ok = self.pool.append_page(rid) is not None
                            if not ok:
                                break
                if ok:
                    kept.append(sg)
                else:
                    dropped.add(rid)
                    if sg.kind == "decode":
                        plan.decode_tokens -= len(sg.tokens)
                        plan.deferred_decode += 1
                    else:
                        plan.prefill_tokens -= len(sg.tokens)
                        plan.deferred_prefill += 1
            item[:] = kept
        return copies

    def _run_copies(self, copies: List[Tuple[int, int]]) -> None:
        """Execute COW page copies on device, ``copy_cap`` pairs per call
        (sentinel-padded). MUST run before this step's engine program —
        and before any preemption can recycle a source page."""
        if not copies:
            return
        import jax.numpy as jnp
        cc = self.geom.copy_cap
        sent = self.geom.trash_page
        for i in range(0, len(copies), cc):
            src = np.full((cc,), sent, np.int32)
            dst = np.full((cc,), sent, np.int32)
            for j, (s_, d_) in enumerate(copies[i:i + cc]):
                src[j], dst[j] = s_, d_
            self.pool_state = self._copy_fn(
                self.pool_state,
                {"src": jnp.asarray(src), "dst": jnp.asarray(dst)})

    def _pack(self, plan: StepPlan):
        import jax.numpy as jnp
        g = self.geom
        n, c, pp = g.n_items, g.cap_t, g.pages_per_seq
        tokens = np.zeros((n, c), np.int32)
        pos = np.zeros((n, c), np.int32)
        seg = np.full((n, c), -1, np.int32)
        base = np.zeros((n, c), np.int32)
        pages = np.full((n, c, pp), g.trash_page, np.int32)
        placements = []
        for i, item in enumerate(plan.items):
            cur = 0
            for s_idx, sg in enumerate(item):
                ln = len(sg.tokens)
                tokens[i, cur:cur + ln] = sg.tokens
                pos[i, cur:cur + ln] = np.arange(sg.start, sg.start + ln)
                seg[i, cur:cur + ln] = s_idx
                base[i, cur:cur + ln] = sg.base
                table = self.pool.table_of(sg.req_id) or []
                pages[i, cur:cur + ln, :len(table)] = \
                    np.asarray(table, np.int32)[None, :]
                placements.append((sg, i, cur))
                cur += ln
        batch = {"tokens": jnp.asarray(tokens), "pos": jnp.asarray(pos),
                 "seg": jnp.asarray(seg), "ctx_base": jnp.asarray(base),
                 "pages": jnp.asarray(pages)}
        return batch, placements

    # ------------------------------------------------------------------
    def _finish(self, st: _ReqState) -> None:
        st.phase = "done"
        st.finished_step = self.step_count
        st.done_wall = time.perf_counter()
        rid = st.req.req_id
        # a finished request's full pages stay in the prefix cache
        # (free-but-cached) — the next request sharing its prefix hits them
        self.pool.publish_ready(rid, st.history, st.committed)
        self.pool.free_table(rid)
        self._running.remove(st)
        n_out = len(st.output)
        tpot = None
        if n_out > 1:
            tpot = (st.done_wall - st.first_wall) / (n_out - 1)
        self.results[rid] = RequestResult(
            req_id=rid, prompt_len=len(st.req.prompt),
            output_ids=list(st.output),
            submitted_step=st.submitted_step,
            first_token_step=st.first_token_step,
            finished_step=st.finished_step,
            ttft_s=st.first_wall - st.submit_wall, tpot_s=tpot,
            preempted=st.preempted)

    def _emit(self, st: _ReqState, token: int,
              events: List[Tuple[int, int]]) -> bool:
        """Append one output token; returns True when the request is
        done (caller must stop consuming further tokens this step)."""
        st.output.append(int(token))
        st.history.append(int(token))
        self._emitted_total += 1
        events.append((st.req.req_id, int(token)))
        if st.first_token_step < 0:
            st.first_token_step = self.step_count
            st.first_wall = time.perf_counter()
        eos = st.req.eos_id
        if (eos is not None and token == eos) \
                or len(st.output) >= st.req.max_new_tokens:
            self._finish(st)
            return True
        st.next_token = int(token)
        return False

    # ------------------------------------------------------------------
    def step(self) -> List[Tuple[int, int]]:
        """Run one engine step; returns the (req_id, token) stream emitted
        by this step (per-request output streams in arrival order)."""
        self._admit()
        # plan + secure pages; if page exhaustion kills EVERY segment, the
        # step would spin forever — force-preempt the newest page-holding
        # stream (LIFO keeps the oldest progressing) and re-plan. COW
        # copies run immediately so a later preemption can never recycle a
        # source page before its rows are duplicated.
        guard = 4 * (len(self._running) + len(self._waiting) + 1)
        while True:
            dec_c, pre_c = self._candidates()
            plan = self.scheduler.plan(dec_c, pre_c)
            self._run_copies(self._secure_pages(plan))
            guard -= 1
            if plan.n_segments or not self._running or guard <= 0:
                break
            victims = [s for s in self._running
                       if self.pool.table_of(s.req.req_id)]
            if not victims:
                break
            self._preempt_stream(victims[-1])
            self._admit()   # freed pages may unblock the queue head
        batch, placements = self._pack(plan)
        step_fn = self.cache.get(self.bucket_key, self._build_step)
        ids, self.pool_state = step_fn(self.params, self.pool_state, batch)
        ids = np.asarray(ids)

        events: List[Tuple[int, int]] = []
        for sg, item, off in placements:
            st = self._states[sg.req_id]
            if st.phase == "done":
                continue
            out = ids[item, off:off + len(sg.tokens)]
            if sg.kind == "prefill":
                self._prefill_fed += len(sg.tokens)
                st.committed += len(sg.tokens)
                st.next_chunk += 1
                if st.committed == len(st.prefill_target):
                    st.phase = "decode"
                    if not st.output:
                        # the final chunk's last-position greedy id is the
                        # first generated token (the TTFT token)
                        self._emit(st, int(out[-1]), events)
                    # resumed prefill: next_token (the un-fed last emitted
                    # token) is already set; out[-1] re-predicts it
            else:
                emitted = verify_greedy(sg.tokens, out)
                self.spec_stats.decode_ticks += 1
                self.spec_stats.drafted += len(sg.tokens) - 1
                self.spec_stats.accepted += len(emitted) - 1
                self.spec_stats.emitted += len(emitted)
                st.committed += len(emitted)
                for tok in emitted:
                    if self._emit(st, tok, events):
                        break
        # newly completed pages enter the prefix cache as soon as their
        # rows are committed — a concurrent request can share a page with
        # its still-running publisher
        for st in list(self._running):
            self.pool.publish_ready(st.req.req_id, st.history, st.committed)
        self.pool.note_tick()
        self.step_count += 1
        self.sim_time += self.config.sim_dt
        return events

    # ------------------------------------------------------------------
    def run(self, trace: Sequence[Request], *,
            max_steps: int = 100_000) -> Dict[int, RequestResult]:
        """Drive a full trace (simulated arrival times) to completion."""
        t0 = time.perf_counter()
        pending = sorted(trace, key=lambda r: r.arrival)
        i = 0
        while (i < len(pending) or self.n_active) \
                and self.step_count < max_steps:
            while i < len(pending) and pending[i].arrival <= self.sim_time:
                try:
                    self.submit(pending[i])
                except ValueError as e:
                    # one bad request (over-long, duplicate id) must not
                    # abort the trace — record the rejection and move on
                    self.rejected[pending[i].req_id] = str(e)
                i += 1
            if not self.n_active and i < len(pending):
                # idle: fast-forward simulated time to the next arrival
                self.sim_time = pending[i].arrival
                continue
            self.step()
        self._run_wall += time.perf_counter() - t0
        if self.timeline is not None:
            st = self.stats()
            self.timeline.record(
                "engine", self.step_count, bucket=str(self.bucket_key),
                completed=st["completed"], steps=st["steps"],
                wall_s=st["wall_s"], tokens_per_s=st["tokens_per_s"],
                ttft_s_p50=st["ttft_s_p50"], ttft_s_p95=st["ttft_s_p95"],
                tpot_s_p50=st["tpot_s_p50"], tpot_s_p95=st["tpot_s_p95"],
                occupancy=st["kv_pool"].get("mean_occupancy"))
        if self.n_active:
            raise RuntimeError(
                f"trace did not drain in {max_steps} steps: "
                f"{self.n_active} requests still active")
        return self.results

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        res = list(self.results.values())
        ttft_s = [r.ttft_s for r in res]
        ttft_steps = [r.ttft_steps for r in res]
        # n_out < 2 has no inter-token latency: excluded EXPLICITLY (None),
        # never conflated with a measured-0 tpot
        tpot = [r.tpot_s for r in res if r.tpot_s is not None]

        def pct(xs, q):
            return float(np.percentile(xs, q)) if xs else 0.0

        wall = max(self._run_wall, 1e-9)
        return {
            "completed": len(res),
            "rejected": len(self.rejected),
            "steps": self.step_count,
            "emitted_tokens": self._emitted_total,
            "prefill_tokens_fed": self._prefill_fed,
            "tokens_per_s": round(self._emitted_total / wall, 2),
            "wall_s": round(self._run_wall, 3),
            "ttft_s_p50": round(pct(ttft_s, 50), 4),
            "ttft_s_p95": round(pct(ttft_s, 95), 4),
            "ttft_steps_p50": pct(ttft_steps, 50),
            "ttft_steps_p95": pct(ttft_steps, 95),
            "tpot_s_p50": round(pct(tpot, 50), 5),
            "tpot_s_p95": round(pct(tpot, 95), 5),
            "tpot_measured": len(tpot),
            "kv_pool": self.pool.stats.as_dict(),
            "speculative": self.spec_stats.as_dict(),
            "compile_cache": self.cache.stats.as_dict(),
        }


def _axes(mesh):
    from repro.runtime.sharding import mesh_axis_names
    return mesh_axis_names(mesh)


# ===========================================================================
# The one-shot reference path (parity oracle).
# ===========================================================================

def one_shot_generate(cfg_arch, mesh, params, prompts: Sequence[Sequence[int]],
                      max_new: int, *, cap: Optional[int] = None,
                      compute_dtype=None,
                      eos_id: Optional[int] = None) -> List[List[int]]:
    """The pre-engine one-shot serve path: each output token is produced by
    a FULL teacher-forced prefill of (prompt + generated-so-far) through
    the EPP pipeline (``pipeline_loss_fn`` mode="prefill") — no KV reuse,
    no continuous batching, one request at a time. Quadratically slow and
    exactly right: the oracle the engine's paged-cache incremental
    decode is tested against (ids must match at every k).
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.models import LayerCtx
    from repro.runtime import make_geometry
    from repro.runtime.pipeline import pipeline_loss_fn
    from repro.runtime.sharding import (batch_specs, mesh_axis_names,
                                        shard_dim_tree, shard_map_compat)
    from repro.runtime.train_step import batch_struct, param_pspecs

    compute_dtype = compute_dtype or jnp.float32
    pod, data, model = mesh_axis_names(mesh)
    if pod is not None:
        raise NotImplementedError("one-shot reference runs on a "
                                  "(data, model) mesh")
    d_s = mesh.shape[model]
    need = max((len(p) for p in prompts), default=1) + max_new
    cap = max(cap or 0, need)
    cap = -(-cap // d_s) * d_s
    geom = make_geometry(cfg_arch, mesh, n_chunks=1, cap=cap, ctx_cap=cap,
                         l_ckpt=0, compute_dtype=compute_dtype)
    params_shape = jax.eval_shape(lambda: params)
    pspecs = param_pspecs(cfg_arch, params_shape, mesh)
    shard_dims = shard_dim_tree(params["stages"], d_s)
    bspecs = batch_specs(batch_struct(geom, 1), pod=None, model=model)
    if geom.policy == "ulysses":
        kspec = P(data, None, model, None)
    else:
        kspec = P(data, None, None, None)
    ctx_spec = LayerCtx(kspec, kspec, None, None)
    fn = pipeline_loss_fn(cfg_arch, geom, shard_dims, pod_axis=None,
                          data_axis=data, model_axis=model, mode="prefill")
    mapped = jax.jit(shard_map_compat(
        fn, mesh=mesh, in_specs=(pspecs, bspecs),
        out_specs=(P(None, model), ctx_spec), check_vma=False))

    outs: List[List[int]] = []
    for prompt in prompts:
        seq = [int(t) for t in prompt]
        gen: List[int] = []
        for _ in range(max_new):
            n = len(seq)
            if n > cap:
                raise ValueError(f"sequence length {n} exceeds cap {cap}")
            tokens = np.zeros((1, cap), np.int32)
            tokens[0, :n] = seq
            seg = np.full((1, cap), -1, np.int32)
            seg[0, :n] = 0
            pos = np.zeros((1, cap), np.int32)
            pos[0, :n] = np.arange(n)
            batch = {
                "tokens": jnp.asarray(tokens),
                "targets": jnp.asarray(np.full((1, cap), -1, np.int32)),
                "seg": jnp.asarray(seg),
                "pos": jnp.asarray(pos),
                "ctx_len": jnp.zeros((1,), jnp.int32),
            }
            ids, _ = mapped(params, batch)
            nxt = int(np.asarray(ids)[0, n - 1])
            gen.append(nxt)
            seq.append(nxt)
            if eos_id is not None and nxt == eos_id:
                break
        outs.append(gen)
    return outs
