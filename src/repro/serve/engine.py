"""Continuous-batching serving engine on the EPP pipeline.

Request lifecycle::

    submit() ──> waiting ──admit (KV slot alloc)──> prefill ──> decode ──> done
                   │                                  │            │
                   └── queue (pool full / budget) ────┴── step() packs both
                       into ONE fixed-shape engine program per step

Every :meth:`ServeEngine.step` builds one packed batch for the compiled
engine program (``runtime.serve_step.engine_step_fn``): decode segments
(k tokens per running stream — speculative drafts verified on the host)
co-scheduled with chunked-prefill segments (prompts sliced by the
trainer's ``core.chunking.prompt_slices`` capacity logic). Because
per-request lengths are data rather than shape, the compile cache sees
exactly ONE bucket key per engine configuration
(``compile_cache.engine_bucket_key``) — the second pass over any trace
compiles nothing, and a persistent :class:`CacheStore` warm-starts even
the first.

:func:`one_shot_generate` is the parity oracle: the pre-engine one-shot
serve path (whole-prompt prefill through ``pipeline_loss_fn``'s prefill
mode, teacher-forced full recompute per emitted token — no KV reuse). The
engine's greedy output ids must match it exactly at every ``k``
(tests/test_serve_engine.py).
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .kv_manager import KVSlotPool
from .scheduler import SchedulerConfig, Segment, StepPlan, TickScheduler
from .speculative import SpecStats, propose_draft, verify_greedy

__all__ = ["EngineConfig", "Request", "RequestResult", "ServeEngine",
           "one_shot_generate"]


@dataclass
class EngineConfig:
    """Host-visible engine knobs. (n_items, cap_t, n_slots, s_cap, k) are
    the compiled geometry — one bucket per distinct tuple; the budgets and
    the prefill mode are pure packing policy (no recompile)."""
    n_items: int = 4             # packed chunk items per engine step
    cap_t: int = 64              # tokens per item
    n_slots: int = 8             # KV slots (max concurrently-resident reqs)
    s_cap: int = 256             # cache rows per slot (prompt + generated)
    k: int = 1                   # decode tokens per stream per step
    prefill_chunk: Optional[int] = None   # max prefill chunk (default cap_t)
    decode_token_budget: Optional[int] = None
    prefill_token_budget: Optional[int] = None
    prefill_mode: str = "interleaved"     # | "serial" (stop-the-world)
    draft_ngram: int = 3
    sim_dt: float = 1.0          # simulated seconds per engine step
    # preempt a decode stream when the admission queue's head has waited
    # this many steps with the pool full (None = never): the victim's slot
    # is freed and it requeues for a resume-prefill of its history —
    # outputs are unchanged (greedy is deterministic), only latency moves
    preempt_waiting_steps: Optional[int] = None


@dataclass
class Request:
    req_id: int
    prompt: np.ndarray           # int32 [L]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    arrival: float = 0.0         # simulated arrival time


@dataclass
class RequestResult:
    req_id: int
    prompt_len: int
    output_ids: List[int]
    submitted_step: int
    first_token_step: int        # TTFT in engine steps
    finished_step: int
    ttft_s: float                # wall-clock submit -> first token
    tpot_s: float                # wall-clock mean per output token after 1st
    preempted: int = 0           # times this request lost its slot

    @property
    def ttft_steps(self) -> int:
        return self.first_token_step - self.submitted_step


@dataclass
class _ReqState:
    req: Request
    slot: int = -1
    phase: str = "waiting"       # waiting | prefill | decode | done
    committed: int = 0           # valid cache rows (tokens fed & accepted)
    chunks: List[Tuple[int, int]] = field(default_factory=list)
    next_chunk: int = 0
    # tokens being prefilled: the prompt on first admission; on a resume
    # after preemption, history[:-1] (everything but the un-fed last token)
    prefill_target: List[int] = field(default_factory=list)
    waiting_since: int = 0
    next_token: int = -1         # last emitted, not yet fed token
    output: List[int] = field(default_factory=list)
    history: List[int] = field(default_factory=list)  # prompt + output
    submitted_step: int = 0
    submit_wall: float = 0.0
    first_token_step: int = -1
    first_wall: float = 0.0
    finished_step: int = -1
    done_wall: float = 0.0
    preempted: int = 0


class ServeEngine:
    """Continuous-batching engine over one compiled EPP stage program."""

    def __init__(self, cfg_arch, mesh, config: EngineConfig, *,
                 params: Optional[Dict] = None, param_dtype=None,
                 compute_dtype=None, cache=None, store=None,
                 seed: int = 0, log: Optional[Callable] = None):
        import jax
        import jax.numpy as jnp

        from repro.core import ClusterSpec, CostModel
        from repro.runtime.compile_cache import CompileCache
        from repro.runtime.serve_step import (EngineStepBuilder,
                                              make_engine_geometry)

        self.cfg_arch = cfg_arch
        self.mesh = mesh
        self.config = config
        self.log = log
        param_dtype = param_dtype or jnp.float32
        compute_dtype = compute_dtype or param_dtype
        self.geom = make_engine_geometry(
            cfg_arch, mesh, n_items=config.n_items, cap_t=config.cap_t,
            n_slots=config.n_slots, s_cap=config.s_cap, k=config.k,
            compute_dtype=compute_dtype)
        self.builder = EngineStepBuilder(cfg_arch, mesh, self.geom,
                                         param_dtype=param_dtype)
        self.params = params if params is not None else \
            self.builder.init_params(jax.random.PRNGKey(seed))
        self._params_shape = jax.eval_shape(lambda: self.params)
        self.cache = cache if cache is not None else \
            CompileCache(name="serve-engine", log=log, store=store)
        self.pool_state = self.builder.init_pool()
        self.pool = KVSlotPool(config.n_slots, config.s_cap)
        self.scheduler = TickScheduler(SchedulerConfig(
            n_items=config.n_items, cap_t=config.cap_t, k=config.k,
            decode_token_budget=config.decode_token_budget,
            prefill_token_budget=config.prefill_token_budget,
            prefill_mode=config.prefill_mode))
        # prompt slicing reuses the trainer's workload-balanced capacity
        # logic (Alg. 1 line 1) — chunked prefill IS token-level PP
        pod, data, model = _axes(mesh)
        self._cm = CostModel(cfg_arch.spec,
                             ClusterSpec(d_p=mesh.shape[data],
                                         d_s=mesh.shape[model]))
        self.spec_stats = SpecStats()
        self._waiting: "deque[_ReqState]" = deque()
        self._running: List[_ReqState] = []      # prefill + decode phases
        self._states: Dict[int, _ReqState] = {}
        self.results: Dict[int, RequestResult] = {}
        self.rejected: Dict[int, str] = {}
        self.step_count = 0
        self.sim_time = 0.0
        self._emitted_total = 0
        self._run_wall = 0.0

    # ------------------------------------------------------------------
    @property
    def bucket_key(self):
        from repro.runtime.compile_cache import engine_bucket_key
        return engine_bucket_key(self.geom)

    def _build_step(self):
        return self.builder.build(self._params_shape)

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        """Queue a request. Admission is validated against the slot
        geometry up front — an over-long prompt is REJECTED with a clear
        error instead of silently truncating its context (the old
        launch/serve.py failure mode)."""
        plen = int(len(req.prompt))
        need = plen + req.max_new_tokens
        if plen < 1:
            raise ValueError(f"request {req.req_id}: empty prompt")
        if req.req_id in self._states:
            raise ValueError(f"request id {req.req_id} already submitted")
        if need > self.geom.s_cap:
            raise ValueError(
                f"request {req.req_id}: prompt ({plen}) + max_new_tokens "
                f"({req.max_new_tokens}) = {need} exceeds the KV slot "
                f"capacity s_cap={self.geom.s_cap}; raise --s-cap or split "
                f"the request (context is never silently truncated)")
        st = _ReqState(req=req, submitted_step=self.step_count,
                       submit_wall=time.perf_counter(),
                       waiting_since=self.step_count,
                       history=[int(t) for t in req.prompt])
        self._states[req.req_id] = st
        self._waiting.append(st)

    @property
    def n_active(self) -> int:
        return len(self._waiting) + len(self._running)

    # ------------------------------------------------------------------
    def _admit(self) -> None:
        from repro.core.chunking import prompt_slices
        cap = min(self.config.prefill_chunk or self.geom.cap_t,
                  self.geom.cap_t)
        while self._waiting:
            st = self._waiting[0]
            slot = self.pool.alloc(st.req.req_id)
            if slot is None:
                if self._maybe_preempt(st):
                    continue    # retry into the freed slot
                return
            self._waiting.popleft()
            st.slot = slot
            st.phase = "prefill"
            st.committed = 0
            st.next_chunk = 0
            # resume after preemption: re-prefill everything already fed
            # (history minus the un-fed last token); fresh requests
            # prefill the prompt
            st.prefill_target = st.history[:-1] if st.output \
                else [int(t) for t in st.req.prompt]
            off, st.chunks = 0, []
            for ln in prompt_slices(self._cm, len(st.prefill_target), cap):
                st.chunks.append((off, ln))
                off += ln
            self._running.append(st)

    def _maybe_preempt(self, head: _ReqState) -> bool:
        """Pool-full admission policy: once the queue's head has waited
        ``preempt_waiting_steps`` steps, evict the most recently admitted
        decode stream (its first token is already out — decode-phase
        implies progress) and requeue it for a resume-prefill. Greedy
        decode is deterministic, so preemption can never change a
        request's output ids — only its latency (tested)."""
        n = self.config.preempt_waiting_steps
        if n is None or self.step_count - head.waiting_since < n:
            return False
        victims = [s for s in self._running if s.phase == "decode"]
        if not victims:
            return False
        victim = victims[-1]
        self.pool.preempt(victim.slot)
        victim.slot = -1
        victim.phase = "waiting"
        victim.preempted += 1
        victim.waiting_since = self.step_count
        self._running.remove(victim)
        self._waiting.append(victim)
        return True

    # ------------------------------------------------------------------
    def _candidates(self) -> Tuple[List[Segment], List[List[Segment]]]:
        dec: List[Segment] = []
        pre: List[List[Segment]] = []
        k = self.geom.k
        for st in self._running:
            rid = st.req.req_id
            if st.phase == "decode":
                draft = propose_draft(st.history, k - 1,
                                      ngram=self.config.draft_ngram)
                dec.append(Segment(
                    req_id=rid, kind="decode",
                    tokens=(st.next_token, *draft),
                    slot=st.slot, base=st.committed))
            elif st.phase == "prefill":
                segs = []
                for off, ln in st.chunks[st.next_chunk:]:
                    segs.append(Segment(
                        req_id=rid, kind="prefill",
                        tokens=tuple(st.prefill_target[off:off + ln]),
                        slot=st.slot, base=off))
                pre.append(segs)
        return dec, pre

    def _pack(self, plan: StepPlan):
        import jax.numpy as jnp
        g = self.geom
        n, c = g.n_items, g.cap_t
        tokens = np.zeros((n, c), np.int32)
        slot = np.full((n, c), g.trash_slot, np.int32)
        pos = np.zeros((n, c), np.int32)
        seg = np.full((n, c), -1, np.int32)
        base = np.zeros((n, c), np.int32)
        placements = []
        for i, item in enumerate(plan.items):
            cur = 0
            for s_idx, sg in enumerate(item):
                ln = len(sg.tokens)
                tokens[i, cur:cur + ln] = sg.tokens
                slot[i, cur:cur + ln] = sg.slot
                pos[i, cur:cur + ln] = np.arange(sg.start, sg.start + ln)
                seg[i, cur:cur + ln] = s_idx
                base[i, cur:cur + ln] = sg.base
                placements.append((sg, i, cur))
                cur += ln
        batch = {"tokens": jnp.asarray(tokens), "slot": jnp.asarray(slot),
                 "pos": jnp.asarray(pos), "seg": jnp.asarray(seg),
                 "ctx_base": jnp.asarray(base)}
        return batch, placements

    # ------------------------------------------------------------------
    def _finish(self, st: _ReqState) -> None:
        st.phase = "done"
        st.finished_step = self.step_count
        st.done_wall = time.perf_counter()
        self.pool.free(st.slot)
        st.slot = -1
        self._running.remove(st)
        n_out = len(st.output)
        tpot = 0.0
        if n_out > 1:
            tpot = (st.done_wall - st.first_wall) / (n_out - 1)
        self.results[st.req.req_id] = RequestResult(
            req_id=st.req.req_id, prompt_len=len(st.req.prompt),
            output_ids=list(st.output),
            submitted_step=st.submitted_step,
            first_token_step=st.first_token_step,
            finished_step=st.finished_step,
            ttft_s=st.first_wall - st.submit_wall, tpot_s=tpot,
            preempted=st.preempted)

    def _emit(self, st: _ReqState, token: int,
              events: List[Tuple[int, int]]) -> bool:
        """Append one output token; returns True when the request is
        done (caller must stop consuming further tokens this step)."""
        st.output.append(int(token))
        st.history.append(int(token))
        self._emitted_total += 1
        events.append((st.req.req_id, int(token)))
        if st.first_token_step < 0:
            st.first_token_step = self.step_count
            st.first_wall = time.perf_counter()
        eos = st.req.eos_id
        if (eos is not None and token == eos) \
                or len(st.output) >= st.req.max_new_tokens:
            self._finish(st)
            return True
        st.next_token = int(token)
        return False

    # ------------------------------------------------------------------
    def step(self) -> List[Tuple[int, int]]:
        """Run one engine step; returns the (req_id, token) stream emitted
        by this step (per-request output streams in arrival order)."""
        self._admit()
        dec_c, pre_c = self._candidates()
        plan = self.scheduler.plan(dec_c, pre_c)
        batch, placements = self._pack(plan)
        step_fn = self.cache.get(self.bucket_key, self._build_step)
        ids, self.pool_state = step_fn(self.params, self.pool_state, batch)
        ids = np.asarray(ids)

        events: List[Tuple[int, int]] = []
        for sg, item, off in placements:
            st = self._states[sg.req_id]
            if st.phase == "done":
                continue
            out = ids[item, off:off + len(sg.tokens)]
            if sg.kind == "prefill":
                st.committed += len(sg.tokens)
                st.next_chunk += 1
                if st.committed == len(st.prefill_target):
                    st.phase = "decode"
                    if not st.output:
                        # the final chunk's last-position greedy id is the
                        # first generated token (the TTFT token)
                        self._emit(st, int(out[-1]), events)
                    # resumed prefill: next_token (the un-fed last emitted
                    # token) is already set; out[-1] re-predicts it
            else:
                emitted = verify_greedy(sg.tokens, out)
                self.spec_stats.decode_ticks += 1
                self.spec_stats.drafted += len(sg.tokens) - 1
                self.spec_stats.accepted += len(emitted) - 1
                self.spec_stats.emitted += len(emitted)
                st.committed += len(emitted)
                for tok in emitted:
                    if self._emit(st, tok, events):
                        break
        self.pool.note_tick()
        self.step_count += 1
        self.sim_time += self.config.sim_dt
        return events

    # ------------------------------------------------------------------
    def run(self, trace: Sequence[Request], *,
            max_steps: int = 100_000) -> Dict[int, RequestResult]:
        """Drive a full trace (simulated arrival times) to completion."""
        t0 = time.perf_counter()
        pending = sorted(trace, key=lambda r: r.arrival)
        i = 0
        while (i < len(pending) or self.n_active) \
                and self.step_count < max_steps:
            while i < len(pending) and pending[i].arrival <= self.sim_time:
                try:
                    self.submit(pending[i])
                except ValueError as e:
                    # one bad request (over-long, duplicate id) must not
                    # abort the trace — record the rejection and move on
                    self.rejected[pending[i].req_id] = str(e)
                i += 1
            if not self.n_active and i < len(pending):
                # idle: fast-forward simulated time to the next arrival
                self.sim_time = pending[i].arrival
                continue
            self.step()
        self._run_wall += time.perf_counter() - t0
        if self.n_active:
            raise RuntimeError(
                f"trace did not drain in {max_steps} steps: "
                f"{self.n_active} requests still active")
        return self.results

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        res = list(self.results.values())
        ttft_s = [r.ttft_s for r in res]
        ttft_steps = [r.ttft_steps for r in res]
        tpot = [r.tpot_s for r in res if r.tpot_s > 0]

        def pct(xs, q):
            return float(np.percentile(xs, q)) if xs else 0.0

        wall = max(self._run_wall, 1e-9)
        return {
            "completed": len(res),
            "rejected": len(self.rejected),
            "steps": self.step_count,
            "emitted_tokens": self._emitted_total,
            "tokens_per_s": round(self._emitted_total / wall, 2),
            "wall_s": round(self._run_wall, 3),
            "ttft_s_p50": round(pct(ttft_s, 50), 4),
            "ttft_s_p95": round(pct(ttft_s, 95), 4),
            "ttft_steps_p50": pct(ttft_steps, 50),
            "ttft_steps_p95": pct(ttft_steps, 95),
            "tpot_s_p50": round(pct(tpot, 50), 5),
            "tpot_s_p95": round(pct(tpot, 95), 5),
            "kv_pool": self.pool.stats.as_dict(),
            "speculative": self.spec_stats.as_dict(),
            "compile_cache": self.cache.stats.as_dict(),
        }


def _axes(mesh):
    from repro.runtime.sharding import mesh_axis_names
    return mesh_axis_names(mesh)


# ===========================================================================
# The one-shot reference path (parity oracle).
# ===========================================================================

def one_shot_generate(cfg_arch, mesh, params, prompts: Sequence[Sequence[int]],
                      max_new: int, *, cap: Optional[int] = None,
                      compute_dtype=None,
                      eos_id: Optional[int] = None) -> List[List[int]]:
    """The pre-engine one-shot serve path: each output token is produced by
    a FULL teacher-forced prefill of (prompt + generated-so-far) through
    the EPP pipeline (``pipeline_loss_fn`` mode="prefill") — no KV reuse,
    no continuous batching, one request at a time. Quadratically slow and
    exactly right: the oracle the engine's slotted-cache incremental
    decode is tested against (ids must match at every k).
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.models import LayerCtx
    from repro.runtime import make_geometry
    from repro.runtime.pipeline import pipeline_loss_fn
    from repro.runtime.sharding import (batch_specs, mesh_axis_names,
                                        shard_dim_tree, shard_map_compat)
    from repro.runtime.train_step import batch_struct, param_pspecs

    compute_dtype = compute_dtype or jnp.float32
    pod, data, model = mesh_axis_names(mesh)
    if pod is not None:
        raise NotImplementedError("one-shot reference runs on a "
                                  "(data, model) mesh")
    d_s = mesh.shape[model]
    need = max((len(p) for p in prompts), default=1) + max_new
    cap = max(cap or 0, need)
    cap = -(-cap // d_s) * d_s
    geom = make_geometry(cfg_arch, mesh, n_chunks=1, cap=cap, ctx_cap=cap,
                         l_ckpt=0, compute_dtype=compute_dtype)
    params_shape = jax.eval_shape(lambda: params)
    pspecs = param_pspecs(cfg_arch, params_shape, mesh)
    shard_dims = shard_dim_tree(params["stages"], d_s)
    bspecs = batch_specs(batch_struct(geom, 1), pod=None, model=model)
    if geom.policy == "ulysses":
        kspec = P(data, None, model, None)
    else:
        kspec = P(data, None, None, None)
    ctx_spec = LayerCtx(kspec, kspec, None, None)
    fn = pipeline_loss_fn(cfg_arch, geom, shard_dims, pod_axis=None,
                          data_axis=data, model_axis=model, mode="prefill")
    mapped = jax.jit(shard_map_compat(
        fn, mesh=mesh, in_specs=(pspecs, bspecs),
        out_specs=(P(None, model), ctx_spec), check_vma=False))

    outs: List[List[int]] = []
    for prompt in prompts:
        seq = [int(t) for t in prompt]
        gen: List[int] = []
        for _ in range(max_new):
            n = len(seq)
            if n > cap:
                raise ValueError(f"sequence length {n} exceeds cap {cap}")
            tokens = np.zeros((1, cap), np.int32)
            tokens[0, :n] = seq
            seg = np.full((1, cap), -1, np.int32)
            seg[0, :n] = 0
            pos = np.zeros((1, cap), np.int32)
            pos[0, :n] = np.arange(n)
            batch = {
                "tokens": jnp.asarray(tokens),
                "targets": jnp.asarray(np.full((1, cap), -1, np.int32)),
                "seg": jnp.asarray(seg),
                "pos": jnp.asarray(pos),
                "ctx_len": jnp.zeros((1,), jnp.int32),
            }
            ids, _ = mapped(params, batch)
            nxt = int(np.asarray(ids)[0, n - 1])
            gen.append(nxt)
            seq.append(nxt)
            if eos_id is not None and nxt == eos_id:
                break
        outs.append(gen)
    return outs
