"""Tick scheduler: co-schedule chunked prefill with decode streams.

Every engine step runs ONE fixed-shape compiled program of ``n_items``
packed token chunks of ``cap_t`` tokens. The scheduler decides what goes
into that shape:

* **decode first** — each running request contributes one ``k``-token
  segment (last accepted token + drafts), admitted round-robin under
  ``decode_token_budget`` so no stream starves (TPOT bound);
* **prefill fills the rest** — waiting prompts are sliced by the trainer's
  capacity logic (``core.chunking.prompt_slices``) and as many next chunks
  as fit under ``prefill_token_budget`` ride along (TTFT bound). Several
  chunks of the SAME prompt may be co-scheduled in one step, but only in
  strictly increasing item indices: item ``i`` clears every pipeline stage
  before item ``j > i`` arrives there, so chunk ``j``'s cache reads see
  chunk ``i``'s writes — the paper's chunk-level pipelining applied to
  prefill.

``prefill_mode="serial"`` is the deliberately naive baseline the serving
benchmark contrasts: while any prompt is mid-prefill, decode is stopped
entirely (stop-the-world prefill — TPOT spikes under skewed traces).

Packing is first-fit over the ``n_items`` items with the per-request
item-ordering constraint; anything that does not fit this step is simply
deferred (nothing is ever truncated).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = ["Segment", "SchedulerConfig", "StepPlan", "TickScheduler"]


@dataclass(frozen=True)
class Segment:
    """One contiguous run of tokens for one request inside one item."""
    req_id: int
    kind: str                    # "prefill" | "decode"
    tokens: Tuple[int, ...]      # fed token ids
    base: int                    # committed cache rows at step start
    # absolute position of tokens[0] in the sequence (== base: both decode
    # ticks and prefill chunks continue exactly where the cache ends).
    # Which KV pages back those rows is engine state (the per-request page
    # table), not scheduling state — the scheduler only packs tokens.

    @property
    def start(self) -> int:
        return self.base


@dataclass
class StepPlan:
    items: List[List[Segment]]
    decode_tokens: int = 0
    prefill_tokens: int = 0
    deferred_decode: int = 0     # decode streams pushed to the next step
    deferred_prefill: int = 0    # prefill chunks pushed to the next step

    @property
    def n_segments(self) -> int:
        return sum(len(it) for it in self.items)


@dataclass
class SchedulerConfig:
    n_items: int
    cap_t: int
    k: int = 1
    # token budgets per engine step; None = derived (decode gets what it
    # needs up to half the step, prefill gets the remainder)
    decode_token_budget: Optional[int] = None
    prefill_token_budget: Optional[int] = None
    prefill_mode: str = "interleaved"    # | "serial" (stop-the-world)

    def __post_init__(self):
        if self.prefill_mode not in ("interleaved", "serial"):
            raise ValueError(f"prefill_mode must be 'interleaved' or "
                             f"'serial', got {self.prefill_mode!r}")
        if self.k > self.cap_t:
            raise ValueError(f"k={self.k} exceeds cap_t={self.cap_t}")


class TickScheduler:
    def __init__(self, config: SchedulerConfig):
        self.config = config
        # round-robin anchor: the req_id of the stream served FIRST last
        # step (None before any decode ran). Keying the rotation on stable
        # req_id order — instead of an index advanced mod the CURRENT
        # stream count — keeps it fair when streams complete/join between
        # steps: an index pointer drifts with the population and can leave
        # one stream persistently ordered last (starvation; regression
        # test in tests/test_serve_engine.py).
        self._rr_last: Optional[int] = None

    # ------------------------------------------------------------------
    def plan(self, decode_candidates: Sequence[Segment],
             prefill_candidates: Sequence[Sequence[Segment]]) -> StepPlan:
        """``decode_candidates``: one k-token segment per running stream.
        ``prefill_candidates``: per waiting request, its REMAINING prompt
        chunks in causal order (a prefix of each list may be scheduled)."""
        c = self.config
        total_cap = c.n_items * c.cap_t
        plan = StepPlan(items=[[] for _ in range(c.n_items)])
        fill = [0] * c.n_items
        # a request's next segment may only land in items AFTER its
        # previous one (pipeline ordering makes the dependency real)
        min_item: Dict[int, int] = {}

        def place(seg: Segment) -> bool:
            lo = min_item.get(seg.req_id, 0)
            for i in range(lo, c.n_items):
                if fill[i] + len(seg.tokens) <= c.cap_t:
                    plan.items[i].append(seg)
                    fill[i] += len(seg.tokens)
                    min_item[seg.req_id] = i + 1
                    return True
            return False

        # ---- decode streams, round-robin under the decode budget -------
        dec = list(decode_candidates)
        if c.prefill_mode == "serial" and any(prefill_candidates):
            # stop-the-world: no decode while any prompt is mid-prefill
            plan.deferred_decode = len(dec)
            dec = []
        d_budget = c.decode_token_budget
        if d_budget is None:
            d_budget = total_cap if not any(prefill_candidates) \
                else max(c.k, total_cap // 2)
        if dec:
            # stable rotation: req_id order, starting just past the stream
            # served first last step (wrapping), so every stream reaches
            # the front within n steps no matter who completed meanwhile
            dec.sort(key=lambda s: s.req_id)
            start = 0
            if self._rr_last is not None:
                start = len(dec)
                for i, seg in enumerate(dec):
                    if seg.req_id > self._rr_last:
                        start = i
                        break
            order = [dec[(start + i) % len(dec)] for i in range(len(dec))]
            for seg in order:
                if plan.decode_tokens + len(seg.tokens) > d_budget \
                        or not place(seg):
                    plan.deferred_decode += 1
                    continue
                if plan.decode_tokens == 0:
                    # advance past the first stream actually SERVED (not
                    # merely considered) — a fully deferred step must not
                    # rotate the anchor
                    self._rr_last = seg.req_id
                plan.decode_tokens += len(seg.tokens)

        # ---- prefill chunks, FIFO under the prefill budget -------------
        p_budget = c.prefill_token_budget
        if p_budget is None:
            p_budget = total_cap - plan.decode_tokens
        for chunks in prefill_candidates:
            placed = 0
            for seg in chunks:
                if plan.prefill_tokens + len(seg.tokens) > p_budget \
                        or not place(seg):
                    # later chunks of this request depend on this one —
                    # defer the whole rest of the prompt, and COUNT every
                    # deferred chunk (the StepPlan field is a chunk count;
                    # one-per-request undercounted skewed traces)
                    break
                placed += 1
                plan.prefill_tokens += len(seg.tokens)
            plan.deferred_prefill += len(chunks) - placed
        return plan
