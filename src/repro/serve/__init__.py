"""Continuous-batching serving engine on the EPP runtime.

The package is split host/device: everything here is host-side
orchestration (admission, scheduling, paged-KV + prefix-cache
accounting, speculative verify); the compiled stage program lives in
``repro.runtime.serve_step`` (``engine_step_fn`` + ``EngineStepBuilder``)
and its bucket keys in ``repro.runtime.compile_cache``
(``engine_bucket_key`` + ``engine_copy_bucket_key``).

Heavy imports (jax, the model stack) resolve lazily through
:mod:`.engine`; the scheduler, page pool and speculative helpers are
import-light and usable from host-only tooling.
"""

from .kv_manager import PagedKVPool, PoolStats
from .scheduler import SchedulerConfig, Segment, StepPlan, TickScheduler
from .speculative import SpecStats, propose_draft, verify_greedy

__all__ = ["EngineConfig", "PagedKVPool", "PoolStats", "Request",
           "RequestResult", "SchedulerConfig", "Segment", "ServeEngine",
           "SpecStats", "StepPlan", "TickScheduler", "one_shot_generate",
           "propose_draft", "verify_greedy"]

_LAZY = {
    "EngineConfig": ".engine",
    "Request": ".engine",
    "RequestResult": ".engine",
    "ServeEngine": ".engine",
    "one_shot_generate": ".engine",
}


def __getattr__(name):
    if name in _LAZY:
        import importlib
        return getattr(importlib.import_module(_LAZY[name], __name__), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
