"""Paged KV-cache pool accounting (host side), with content-addressed
prefix caching.

The device-side pool (``runtime.serve_step.engine_pool_struct``) is a
fixed buffer of ``page_sz``-row pages, sequence-sharded over the model
axis — ``[d_p, L_s, n_pages + d_s, page_sz, Hkv, Dh]`` per stage, one
trash page per model rank (host sentinel page id ``n_pages``) so padding
and bubble-tick writes always have a local home. This module owns the
*host* view:

* **per-request page tables** — request ``r`` holds an ordered list of
  page ids; logical cache row ``i`` lives at row ``i % page_sz`` of page
  ``table[i // page_sz]``. Pages are allocated **on write** (admission
  reserves nothing), freed O(1), and fragmentation is impossible by
  construction: any free page serves any request.
* **content-addressed prefix cache** — a full page of committed tokens is
  *published* under its chain hash ``h_j = H(h_{j-1} || tokens_j)`` (so a
  page's identity covers its whole token prefix, which the KV rows depend
  on). A later prompt whose chain prefix is resident adopts those pages
  (refcounted sharing) instead of recomputing them; a prompt that diverges
  *inside* a published page adopts it partially and the first write
  triggers **copy-on-write** (``ensure_writable``). Freed pages keep their
  hash entry until actually reallocated (free-but-cached, vLLM-style), so
  a finished request's prefix keeps serving hits for free.

Invariants (property-tested in tests/test_serve_engine.py, asserted by
:meth:`PagedKVPool.check`):

* free pages and referenced pages partition ``range(n_pages)``;
* the trash page is never in a page table, the free list, or refcounted;
* ``refcount(p)`` == number of page tables referencing ``p``;
* published pages carry exactly ``page_sz`` recorded tokens and the
  hash index / children index / token store agree;
* COW never mutates a shared page — a write into a page with refcount
  > 1 swaps in a fresh page and leaves the shared one untouched.
"""

from __future__ import annotations

import hashlib
from collections import Counter, OrderedDict
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = ["PagedKVPool", "PoolStats"]


def _chain_hash(parent: Optional[bytes], tokens: Sequence[int]) -> bytes:
    """Chain hash of one page of tokens: covers the page's content AND its
    whole prefix (via ``parent``), because a KV row depends on every token
    before it, not just the tokens in its own page."""
    h = hashlib.sha256(parent or b"\x00")
    h.update(b"|")
    h.update(",".join(str(int(t)) for t in tokens).encode())
    return h.digest()


@dataclass
class PoolStats:
    allocs: int = 0              # fresh pages handed out (append + COW)
    frees: int = 0               # pages returned to the free list
    alloc_failures: int = 0      # page requests with an exhausted pool
    preemptions: int = 0         # running requests evicted for admission
    peak_in_use: int = 0         # max pages referenced at once
    peak_seqs: int = 0           # max concurrent page tables (resident reqs)
    prefix_hit_pages: int = 0    # pages adopted from the prefix cache
    prefix_hit_rows: int = 0     # cache rows those adoptions skipped
    cow_copies: int = 0          # shared pages copied before a write
    published: int = 0           # full pages entered into the hash index
    cache_evictions: int = 0     # cached-free pages reused (hash dropped)
    occupancy_sum: float = 0.0   # sum over sampled ticks of in_use/n_pages
    occupancy_ticks: int = 0

    @property
    def mean_occupancy(self) -> float:
        if not self.occupancy_ticks:
            return 0.0
        return self.occupancy_sum / self.occupancy_ticks

    def as_dict(self) -> Dict[str, float]:
        return {
            "allocs": self.allocs,
            "frees": self.frees,
            "alloc_failures": self.alloc_failures,
            "preemptions": self.preemptions,
            "peak_in_use": self.peak_in_use,
            "peak_seqs": self.peak_seqs,
            "prefix_hit_pages": self.prefix_hit_pages,
            "prefix_hit_rows": self.prefix_hit_rows,
            "cow_copies": self.cow_copies,
            "published": self.published,
            "cache_evictions": self.cache_evictions,
            "mean_occupancy": round(self.mean_occupancy, 4),
        }


class PagedKVPool:
    """Fixed pool of ``n_pages`` KV pages of ``page_sz`` rows each."""

    def __init__(self, n_pages: int, page_sz: int, *,
                 prefix_cache: bool = True):
        if n_pages < 1 or page_sz < 1:
            raise ValueError("n_pages and page_sz must be >= 1")
        self.n_pages = n_pages
        self.page_sz = page_sz
        self.prefix_cache = prefix_cache
        # two free queues, both O(1): plain pages first (nothing to lose),
        # then cached pages oldest-freed first (LRU eviction of the cache)
        self._free_plain: "OrderedDict[int, None]" = OrderedDict(
            (p, None) for p in range(n_pages))
        self._free_cached: "OrderedDict[int, None]" = OrderedDict()
        self._ref: Dict[int, int] = {}               # page -> refcount
        self._tables: Dict[int, List[int]] = {}      # req_id -> page list
        self._chains: Dict[int, List[bytes]] = {}    # req_id -> chain hashes
        # prefix index (published pages only)
        self._by_hash: Dict[bytes, int] = {}         # chain hash -> page
        self._hash_of: Dict[int, bytes] = {}         # page -> chain hash
        self._tokens: Dict[int, Tuple[int, ...]] = {}
        self._parent: Dict[int, Optional[bytes]] = {}
        self._children: Dict[Optional[bytes], "OrderedDict[int, None]"] = {}
        self.stats = PoolStats()

    # -- capacity --------------------------------------------------------
    @property
    def trash_page(self) -> int:
        """Device write target for padding/bubble rows; never allocatable."""
        return self.n_pages

    @property
    def in_use(self) -> int:
        return len(self._ref)

    @property
    def n_free(self) -> int:
        return len(self._free_plain) + len(self._free_cached)

    @property
    def n_seqs(self) -> int:
        return len(self._tables)

    def occupancy(self) -> float:
        return self.in_use / self.n_pages

    def note_tick(self) -> None:
        """Sample occupancy once per engine step (mean surfaces in stats)."""
        self.stats.occupancy_sum += self.occupancy()
        self.stats.occupancy_ticks += 1

    def table_of(self, req_id: int) -> Optional[List[int]]:
        """The request's page table (read-only view; mutate via the pool)."""
        return self._tables.get(req_id)

    def refcount(self, page: int) -> int:
        return self._ref.get(page, 0)

    def is_published(self, page: int) -> bool:
        return page in self._hash_of

    # -- free-list mechanics ---------------------------------------------
    def _take_free(self) -> Optional[int]:
        if self._free_plain:
            return self._free_plain.popitem(last=False)[0]
        if self._free_cached:
            # reuse the least-recently-freed cached page; its hash entry
            # dies with it (the cache is exactly the free-but-published set)
            page = self._free_cached.popitem(last=False)[0]
            self._unpublish(page)
            self.stats.cache_evictions += 1
            return page
        return None

    def _release(self, page: int) -> None:
        if page in self._hash_of:
            self._free_cached[page] = None
        else:
            self._free_plain[page] = None
        self.stats.frees += 1

    def _decref(self, page: int) -> None:
        self._ref[page] -= 1
        if self._ref[page] == 0:
            del self._ref[page]
            self._release(page)

    def _adopt(self, page: int) -> None:
        """Take one reference on a page; resurrects a cached-free page."""
        if page in self._ref:
            self._ref[page] += 1
            return
        # only published pages are discoverable, so a free adoptee must
        # sit in the cached queue
        del self._free_cached[page]
        self._ref[page] = 1

    def _unpublish(self, page: int) -> None:
        h = self._hash_of.pop(page)
        if self._by_hash.get(h) == page:
            del self._by_hash[h]
        parent = self._parent.pop(page)
        kids = self._children.get(parent)
        if kids is not None:
            kids.pop(page, None)
            if not kids:
                del self._children[parent]
        del self._tokens[page]

    # -- request lifecycle -----------------------------------------------
    def alloc_table(self, req_id: int) -> None:
        """Create an empty page table for an admitted request. Pages are
        allocated on write (:meth:`append_page`), never reserved."""
        if req_id in self._tables:
            raise ValueError(f"request {req_id} already holds a page table")
        self._tables[req_id] = []
        self._chains[req_id] = []
        self.stats.peak_seqs = max(self.stats.peak_seqs, len(self._tables))

    def free_table(self, req_id: int) -> List[int]:
        """Release every page reference the request holds (request done).
        Pages whose refcount drops to zero return to the free list but KEEP
        their hash entry until reused — the prefix cache outlives its
        publisher. Returns the released table."""
        if req_id not in self._tables:
            raise ValueError(f"request {req_id} holds no page table")
        table = self._tables.pop(req_id)
        del self._chains[req_id]
        for page in table:
            self._decref(page)
        return table

    def preempt(self, req_id: int) -> List[int]:
        """Evict a running request (the engine requeues it for a
        resume-prefill — which typically prefix-hits the victim's own
        still-cached pages). Same mechanics as :meth:`free_table`."""
        table = self.free_table(req_id)
        self.stats.preemptions += 1
        return table

    # -- prefix cache ----------------------------------------------------
    def match_prefix(self, tokens: Sequence[int],
                     max_rows: int) -> Tuple[List[int], int]:
        """Longest resident prefix of ``tokens`` (capped at ``max_rows``):
        whole pages via the chain-hash walk, then at most one partially
        matching published page (the tail). Pure query — no refcounts
        move; commit the result with :meth:`adopt_prefix`."""
        if not self.prefix_cache or max_rows <= 0:
            return [], 0
        ps = self.page_sz
        pages: List[int] = []
        rows = 0
        parent: Optional[bytes] = None
        while rows + ps <= max_rows:
            h = _chain_hash(parent, tokens[rows:rows + ps])
            page = self._by_hash.get(h)
            if page is None:
                break
            pages.append(page)
            rows += ps
            parent = h
        # partial tail: a published page continuing this exact prefix may
        # share its first rows even though the prompt diverges (or simply
        # ends) inside it
        best, best_n = None, 0
        for page in self._children.get(parent, ()):
            ptoks = self._tokens[page]
            lim = min(len(ptoks), max_rows - rows)
            n = 0
            while n < lim and int(ptoks[n]) == int(tokens[rows + n]):
                n += 1
            if n > best_n:
                best, best_n = page, n
        if best is not None:
            pages.append(best)
            rows += best_n
        return pages, rows

    def adopt_prefix(self, req_id: int, pages: Sequence[int],
                     rows: int) -> None:
        """Attach a :meth:`match_prefix` result to a fresh table: one ref
        per page; fully covered pages extend the request's publish chain
        (a partially covered tail does not — the request re-publishes its
        own version of that page once it completes it, after COW)."""
        table = self._tables[req_id]
        if table:
            raise ValueError(f"request {req_id} already holds pages")
        chain = self._chains[req_id]
        for page in pages:
            self._adopt(page)
            table.append(page)
        for page in pages[:rows // self.page_sz]:
            chain.append(self._hash_of[page])
        self.stats.prefix_hit_pages += len(pages)
        self.stats.prefix_hit_rows += rows
        self.stats.peak_in_use = max(self.stats.peak_in_use, self.in_use)

    def publish_ready(self, req_id: int, tokens: Sequence[int],
                      committed: int) -> int:
        """Publish every fully committed, not-yet-published page of the
        request into the hash index (``tokens`` = the request's history;
        row ``i`` of the cache was written by ``tokens[i]``). Returns the
        number of pages newly published."""
        if not self.prefix_cache:
            return 0
        ps = self.page_sz
        table = self._tables[req_id]
        chain = self._chains[req_id]
        n_new = 0
        while (len(chain) + 1) * ps <= committed and len(chain) < len(table):
            idx = len(chain)
            page = table[idx]
            ptoks = tuple(int(t) for t in tokens[idx * ps:(idx + 1) * ps])
            parent = chain[-1] if chain else None
            h = _chain_hash(parent, ptoks)
            chain.append(h)
            if page in self._hash_of or h in self._by_hash:
                # page already published, or identical content resident on
                # another page — never alias one hash to two pages
                continue
            self._by_hash[h] = page
            self._hash_of[page] = h
            self._tokens[page] = ptoks
            self._parent[page] = parent
            self._children.setdefault(parent, OrderedDict())[page] = None
            self.stats.published += 1
            n_new += 1
        return n_new

    # -- page allocation / COW -------------------------------------------
    def append_page(self, req_id: int) -> Optional[int]:
        """Grow the request's table by one fresh page (alloc-on-write);
        None (counted) when the pool — including its cached-free reserve —
        is exhausted."""
        page = self._take_free()
        if page is None:
            self.stats.alloc_failures += 1
            return None
        self._ref[page] = 1
        self._tables[req_id].append(page)
        self.stats.allocs += 1
        self.stats.peak_in_use = max(self.stats.peak_in_use, self.in_use)
        return page

    def ensure_writable(self, req_id: int,
                        idx: int) -> Tuple[str, Optional[Tuple[int, int]]]:
        """Make logical page ``idx`` of the request safe to write.

        * private & unpublished -> ``("ok", None)``: write in place.
        * private but published (sole owner of an adopted tail) ->
          ``("ok", None)``: the hash entry is dropped and the write goes
          in place — nobody else can be reading those rows.
        * shared (refcount > 1) -> copy-on-write: a fresh page replaces it
          in THIS table only; ``("cow", (src, dst))`` tells the engine to
          duplicate the device rows. The shared page is never mutated.
        * COW needed but the pool is exhausted -> ``("fail", None)``.
        """
        table = self._tables[req_id]
        page = table[idx]
        shared = self._ref[page] > 1
        published = page in self._hash_of
        chain = self._chains[req_id]
        if not shared:
            if published:
                self._unpublish(page)
                del chain[idx:]
            return "ok", None
        new = self._take_free()
        if new is None:
            self.stats.alloc_failures += 1
            return "fail", None
        self._ref[new] = 1
        self.stats.allocs += 1
        table[idx] = new
        self._decref(page)
        del chain[idx:]
        self.stats.cow_copies += 1
        self.stats.peak_in_use = max(self.stats.peak_in_use, self.in_use)
        return "cow", (page, new)

    # -- invariants ------------------------------------------------------
    def check(self) -> None:
        """Assert the pool invariants (tests; cheap enough for debug use).
        Unlike the old slot pool's vacuous trash assertion, the trash-page
        checks here range over state that COULD contain it: every page
        table, both free queues, the refcounts and the hash index."""
        free_p, free_c = set(self._free_plain), set(self._free_cached)
        assert not (free_p & free_c), "page in both free queues"
        free = free_p | free_c
        ref = set(self._ref)
        assert not (free & ref), f"page both free and referenced: {free & ref}"
        assert free | ref == set(range(self.n_pages)), \
            "free + referenced must partition the pool"
        counts = Counter(p for t in self._tables.values() for p in t)
        assert self.trash_page not in counts, \
            "trash page leaked into a page table"
        assert self.trash_page not in free and self.trash_page not in ref, \
            "trash page leaked into the free list / refcounts"
        assert dict(counts) == self._ref, \
            f"refcounts != table membership: {dict(counts)} vs {self._ref}"
        for rid, t in self._tables.items():
            assert len(set(t)) == len(t), f"duplicate page in table {rid}"
            assert rid in self._chains and \
                len(self._chains[rid]) <= len(t)
        assert all(v > 0 for v in self._ref.values())
        # hash index consistency
        assert set(self._by_hash.values()) == set(self._hash_of), \
            "hash index and page->hash map disagree"
        for page, h in self._hash_of.items():
            assert self._by_hash[h] == page
            assert len(self._tokens[page]) == self.page_sz
            assert page in self._children[self._parent[page]]
        assert sum(len(k) for k in self._children.values()) \
            == len(self._hash_of)
        assert free_c <= set(self._hash_of), \
            "cached-free queue holds an unpublished page"
        assert self.stats.peak_in_use >= self.in_use
        assert self.stats.peak_seqs >= len(self._tables)
