"""Slotted KV-cache pool accounting (host side).

The device-side pool (``runtime.serve_step.engine_pool_struct``) is a fixed
``[d_p, L_s, n_slots + 1, s_cap, Hkv, Dh]`` buffer per stage — slot
``n_slots`` is the trash row padding and bubble-tick writes land in. This
module owns the *host* view: which request holds which slot, a free list
with O(1) alloc/free and **no defragmentation ever** (slots are
fixed-size, so any free slot fits any request), and the occupancy /
failure / preemption counters the engine's stats and the serving benchmark
surface.

Invariants (property-tested in tests/test_serve_engine.py):

* the free list and the allocated set partition ``range(n_slots)``;
* request <-> slot is a bijection on the allocated set;
* the trash slot is never handed out;
* ``peak_in_use`` is a running max of the allocated-set size.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

__all__ = ["KVSlotPool", "PoolStats"]


@dataclass
class PoolStats:
    allocs: int = 0
    frees: int = 0
    alloc_failures: int = 0      # alloc() with an empty free list
    preemptions: int = 0         # running requests evicted for admission
    peak_in_use: int = 0
    occupancy_sum: float = 0.0   # sum over sampled ticks of in_use/n_slots
    occupancy_ticks: int = 0

    @property
    def mean_occupancy(self) -> float:
        if not self.occupancy_ticks:
            return 0.0
        return self.occupancy_sum / self.occupancy_ticks

    def as_dict(self) -> Dict[str, float]:
        return {
            "allocs": self.allocs,
            "frees": self.frees,
            "alloc_failures": self.alloc_failures,
            "preemptions": self.preemptions,
            "peak_in_use": self.peak_in_use,
            "mean_occupancy": round(self.mean_occupancy, 4),
        }


class KVSlotPool:
    """Fixed pool of ``n_slots`` KV slots of ``s_cap`` rows each."""

    def __init__(self, n_slots: int, s_cap: int):
        if n_slots < 1 or s_cap < 1:
            raise ValueError("n_slots and s_cap must be >= 1")
        self.n_slots = n_slots
        self.s_cap = s_cap
        # pop() hands out low slot ids first (stable, debuggable layouts)
        self._free: List[int] = list(range(n_slots - 1, -1, -1))
        self._owner: Dict[int, int] = {}      # slot -> req_id
        self._slot: Dict[int, int] = {}       # req_id -> slot
        self.stats = PoolStats()

    # ------------------------------------------------------------------
    @property
    def in_use(self) -> int:
        return len(self._owner)

    @property
    def n_free(self) -> int:
        return len(self._free)

    def occupancy(self) -> float:
        return self.in_use / self.n_slots

    def note_tick(self) -> None:
        """Sample occupancy once per engine step (mean surfaces in stats)."""
        self.stats.occupancy_sum += self.occupancy()
        self.stats.occupancy_ticks += 1

    def slot_of(self, req_id: int) -> Optional[int]:
        return self._slot.get(req_id)

    def owner_of(self, slot: int) -> Optional[int]:
        return self._owner.get(slot)

    # ------------------------------------------------------------------
    def alloc(self, req_id: int) -> Optional[int]:
        """Grab a free slot for ``req_id``; None (counted) when the pool is
        full — the engine keeps the request queued."""
        if req_id in self._slot:
            raise ValueError(f"request {req_id} already holds slot "
                             f"{self._slot[req_id]}")
        if not self._free:
            self.stats.alloc_failures += 1
            return None
        slot = self._free.pop()
        self._owner[slot] = req_id
        self._slot[req_id] = slot
        self.stats.allocs += 1
        self.stats.peak_in_use = max(self.stats.peak_in_use, self.in_use)
        return slot

    def free(self, slot: int) -> int:
        """Release ``slot`` (request completed). Returns the former owner.
        Slot reuse needs no cleanup: a new owner starts at ctx_base 0, so
        the previous tenant's rows are unreachable until overwritten."""
        if slot not in self._owner:
            raise ValueError(f"slot {slot} is not allocated")
        req_id = self._owner.pop(slot)
        del self._slot[req_id]
        self._free.append(slot)
        self.stats.frees += 1
        return req_id

    def preempt(self, slot: int) -> int:
        """Evict a running request from its slot (the engine requeues it
        for a fresh prefill). Same mechanics as :meth:`free`, counted
        separately."""
        req_id = self.free(slot)
        self.stats.preemptions += 1
        return req_id

    # ------------------------------------------------------------------
    def check(self) -> None:
        """Assert the pool invariants (tests; cheap enough for debug use)."""
        free = set(self._free)
        used = set(self._owner)
        assert len(free) == len(self._free), "duplicate slot in free list"
        assert not (free & used), f"slot both free and allocated: {free & used}"
        assert free | used == set(range(self.n_slots)), \
            "free + allocated must partition the pool"
        assert self.n_slots not in used and self.n_slots not in free, \
            "trash slot leaked into the pool"
        assert {s: r for r, s in self._slot.items()} == self._owner, \
            "request<->slot maps disagree"
        assert self.stats.peak_in_use >= self.in_use
