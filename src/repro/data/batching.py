"""Materialize an ExecutionPlan into the executor's fixed-shape chunk
buffers (§2.3 of DESIGN.md: the chunks are workload-balanced and capacity-
padded, so one bucket geometry serves many iterations).

Conventions the executor depends on:

* within a chunk, the split/tail slice (s0) is segment 0 — the context rows
  of a split chunk always belong to segment 0;
* ``pos`` is the token's position within its OWNING sequence (split slices
  continue from their context offset);
* ``targets`` are next-token ids across the whole sequence — the target of
  a non-tail slice's last token is the first token of the next slice;
* padding positions carry seg = -1, target = -1;
* ``ctx_len[k]`` = the chunk's context length C_k (0 => the context buffer
  and SSM state implicitly reset).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence

import numpy as np

from repro.core.plan import Chunk, ExecutionPlan

__all__ = ["ChunkBatch", "materialize_plan", "materialize_chunks"]


@dataclass
class ChunkBatch:
    tokens: np.ndarray      # [n_chunks, cap] int32
    targets: np.ndarray
    seg: np.ndarray
    pos: np.ndarray
    ctx_len: np.ndarray     # [n_chunks] int32

    def as_dict(self) -> Dict[str, np.ndarray]:
        return {"tokens": self.tokens, "targets": self.targets,
                "seg": self.seg, "pos": self.pos, "ctx_len": self.ctx_len}


def materialize_chunks(chunks: Sequence[Chunk],
                       corpus: Dict[int, np.ndarray],
                       cap: int) -> ChunkBatch:
    n = len(chunks)
    tokens = np.zeros((n, cap), np.int32)
    targets = np.full((n, cap), -1, np.int32)
    seg = np.full((n, cap), -1, np.int32)
    pos = np.zeros((n, cap), np.int32)
    ctx_len = np.zeros((n,), np.int32)

    for k, ch in enumerate(chunks):
        ctx_len[k] = ch.context
        off = 0
        for s_idx, sl in enumerate(ch.slices):
            toks = corpus[sl.seq_id]
            assert sl.start + sl.length <= len(toks), (sl, len(toks))
            t = toks[sl.start: sl.start + sl.length]
            nxt = np.full((sl.length,), -1, np.int32)
            # next-token targets across slice boundaries
            hi = min(sl.start + sl.length, len(toks) - 1)
            n_t = hi - sl.start
            if n_t > 0:
                nxt[:n_t] = toks[sl.start + 1: sl.start + 1 + n_t]
            end = off + sl.length
            assert end <= cap, f"chunk {k} overflows cap {cap}"
            tokens[k, off:end] = t
            targets[k, off:end] = nxt
            seg[k, off:end] = s_idx
            pos[k, off:end] = np.arange(sl.start, sl.start + sl.length)
            off = end
    return ChunkBatch(tokens, targets, seg, pos, ctx_len)


def materialize_plan(plan: ExecutionPlan, corpus: Dict[int, np.ndarray]
                     ) -> ChunkBatch:
    """All pipelines' chunks concatenated in execution order (gradient
    accumulation across 1F1B pipelines is the concatenated scan)."""
    chunks: List[Chunk] = []
    for p in plan.pipelines:
        chunks.extend(p.chunks)
    return materialize_chunks(chunks, corpus, plan.chunk_capacity)
