from .batching import ChunkBatch, materialize_chunks, materialize_plan
from .synth import (PRESETS, sample_corpus_batch, sample_lengths,
                    sample_request_trace)

__all__ = ["ChunkBatch", "materialize_chunks", "materialize_plan",
           "PRESETS", "sample_corpus_batch", "sample_lengths",
           "sample_request_trace"]
