"""Synthetic variable-length corpora with the skewed length distributions of
Fig. 1(b).

Presets mimic the paper's two datasets: most sequences short, a heavy
lognormal tail ("github" is more skewed than "commoncrawl"); a configurable
fraction of max-length sequences models LLaMA-3-style long-context mixing
(0.1% long documents).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

import numpy as np

__all__ = ["LengthDistribution", "PRESETS", "sample_lengths",
           "sample_corpus_batch", "sample_request_trace"]


@dataclass(frozen=True)
class LengthDistribution:
    name: str
    log_mu: float
    log_sigma: float
    min_len: int = 64
    long_frac: float = 0.002      # fraction pinned to the context limit


PRESETS: Dict[str, LengthDistribution] = {
    # GitHub: median ~2K, <0.6% above 64K (paper Fig. 1b)
    "github": LengthDistribution("github", log_mu=7.6, log_sigma=1.35,
                                 long_frac=0.004),
    # CommonCrawl: shorter documents, lighter tail
    "commoncrawl": LengthDistribution("commoncrawl", log_mu=6.9,
                                      log_sigma=1.1, long_frac=0.002),
    "uniform": LengthDistribution("uniform", log_mu=0.0, log_sigma=0.0),
}


def sample_lengths(preset: str, n: int, context_limit: int,
                   seed: int = 0) -> List[int]:
    dist = PRESETS[preset]
    rng = np.random.default_rng(seed)
    if dist.log_sigma == 0.0:      # uniform: everything at the limit
        return [context_limit] * n
    lens = rng.lognormal(dist.log_mu, dist.log_sigma, n)
    lens = np.clip(lens.astype(np.int64), dist.min_len, context_limit)
    n_long = max(1, int(round(dist.long_frac * n)))
    idx = rng.choice(n, n_long, replace=False)
    lens[idx] = context_limit
    return [int(x) for x in lens]


def sample_request_trace(preset: str, n: int, context_limit: int,
                         vocab: int, *, seed: int = 0,
                         arrival_rate: float = 1.0,
                         max_new_tokens: int = 16,
                         system_prompt_len: int = 0
                         ) -> List[Dict[str, object]]:
    """Synthetic serving trace: Poisson arrivals (exponential inter-arrival
    gaps at ``arrival_rate`` requests per simulated second) over the same
    skewed lognormal prompt-length presets the trainer uses — serving
    request lengths are even more skewed than training documents, which is
    exactly the regime chunked prefill exists for. Deterministic per seed,
    so two passes over one trace are identical (the engine's zero-recompile
    check relies on this).

    ``system_prompt_len`` > 0 prepends the SAME ``system_prompt_len``-token
    prefix (drawn once) to every prompt — the shared-system-prompt regime
    the engine's content-addressed prefix cache exists for. Per-request
    lengths (prefix + unique tail) still follow the preset, floored at
    ``system_prompt_len + 1`` so every request keeps a unique tail.

    Returns ``[{"arrival", "prompt", "max_new_tokens"}, ...]`` sorted by
    arrival; the driver wraps them into ``repro.serve.Request`` objects.
    """
    lengths = sample_lengths(preset, n, context_limit, seed)
    rng = np.random.default_rng(seed + 2)
    gaps = rng.exponential(1.0 / max(arrival_rate, 1e-9), n)
    arrivals = np.cumsum(gaps)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = 1.0 / ranks ** 1.1
    probs /= probs.sum()
    sys_prompt = None
    if system_prompt_len > 0:
        if system_prompt_len >= context_limit:
            raise ValueError(
                f"system_prompt_len={system_prompt_len} must leave room "
                f"for a unique tail under context_limit={context_limit}")
        sys_prompt = rng.choice(vocab, size=system_prompt_len,
                                p=probs).astype(np.int32)
    out = []
    for i, ln in enumerate(lengths):
        if sys_prompt is None:
            prompt = rng.choice(vocab, size=ln, p=probs).astype(np.int32)
        else:
            tail = max(1, ln - system_prompt_len)
            prompt = np.concatenate([
                sys_prompt,
                rng.choice(vocab, size=tail, p=probs).astype(np.int32)])
        out.append({
            "arrival": float(arrivals[i]),
            "prompt": prompt,
            "max_new_tokens": int(max_new_tokens),
        })
    return out


def sample_corpus_batch(preset: str, n: int, context_limit: int, vocab: int,
                        seed: int = 0) -> Dict[int, np.ndarray]:
    """{seq_id: token array} for a global batch. Tokens are drawn from a
    Zipf-ish distribution so the CE loss has learnable structure."""
    lengths = sample_lengths(preset, n, context_limit, seed)
    rng = np.random.default_rng(seed + 1)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = 1.0 / ranks ** 1.1
    probs /= probs.sum()
    return {
        i: rng.choice(vocab, size=ln, p=probs).astype(np.int32)
        for i, ln in enumerate(lengths)
    }
