"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth for the per-kernel allclose tests and the
reference substrate for small-model equivalence tests. Two attention
variants exist:

* :func:`flash_attention_reference` — naive O(T*S) score materialization;
  bitwise-simple, used as the oracle.
* :func:`blocked_flash_attention` — online-softmax over KV tiles in plain
  jnp (lax.scan). Same math, O(T * BLOCK) memory; this is what the dry-run
  lowers when the Mosaic kernel cannot (CPU backend), so the compiled HLO's
  memory profile is representative of the TPU kernel.

Packed-varlen mask rule (shared by every implementation):
  attend(qi, kj)  iff  seg_q[i] == seg_kv[j]  and  seg_q[i] >= 0
                  and (not causal or pos_kv[j] <= pos_q[i])
                  and (window <= 0 or pos_q[i] - pos_kv[j] < window)
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["flash_attention_reference", "blocked_flash_attention",
           "cross_entropy_reference", "streaming_cross_entropy",
           "mamba_scan_reference"]

NEG_INF = -1e30


def _mask(seg_q, seg_kv, pos_q, pos_kv, causal, window):
    m = (seg_q[:, None] == seg_kv[None, :]) & (seg_q[:, None] >= 0)
    if causal:
        m &= pos_kv[None, :] <= pos_q[:, None]
    big = jnp.int32(2 ** 30)
    w = jnp.where(window > 0, window, big)
    m &= (pos_q[:, None] - pos_kv[None, :]) < w
    return m


def _expand_kv(k: jnp.ndarray, n_q_heads: int) -> jnp.ndarray:
    """GQA: repeat kv heads to match query heads."""
    Hkv = k.shape[1]
    if Hkv == n_q_heads:
        return k
    rep = n_q_heads // Hkv
    return jnp.repeat(k, rep, axis=1)


def flash_attention_reference(q, k, v, seg_q, seg_kv, pos_q, pos_kv, *,
                              causal: bool = True, window=0,
                              scale: Optional[float] = None) -> jnp.ndarray:
    """q: [T, Hq, Dh]; k/v: [S, Hkv, Dh(v may differ)] -> [T, Hq, Dv]."""
    Hq = q.shape[1]
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    k = _expand_kv(k, Hq)
    v = _expand_kv(v, Hq)
    s = jnp.einsum("thd,shd->hts", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    m = _mask(seg_q, seg_kv, pos_q, pos_kv, causal, jnp.asarray(window))
    s = jnp.where(m[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows (padding) produce uniform p; zero them for hygiene
    any_valid = m.any(axis=-1)
    p = jnp.where(any_valid[None, :, None], p, 0.0)
    out = jnp.einsum("hts,shd->thd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def blocked_flash_attention(q, k, v, seg_q, seg_kv, pos_q, pos_kv, *,
                            causal: bool = True, window=0,
                            scale: Optional[float] = None,
                            block_kv: int = 512) -> jnp.ndarray:
    """Online-softmax over KV tiles; memory O(T * block_kv)."""
    T, Hq, Dh = q.shape
    S = k.shape[0]
    Dv = v.shape[-1]
    scale = scale if scale is not None else Dh ** -0.5
    k = _expand_kv(k, Hq)
    v = _expand_kv(v, Hq)
    pad = (-S) % block_kv
    if pad:
        k = jnp.concatenate([k, jnp.zeros((pad, *k.shape[1:]), k.dtype)])
        v = jnp.concatenate([v, jnp.zeros((pad, *v.shape[1:]), v.dtype)])
        seg_kv = jnp.concatenate([seg_kv, jnp.full((pad,), -2, seg_kv.dtype)])
        pos_kv = jnp.concatenate([pos_kv, jnp.zeros((pad,), pos_kv.dtype)])
    nb = k.shape[0] // block_kv
    kb = k.reshape(nb, block_kv, Hq, Dh)
    vb = v.reshape(nb, block_kv, Hq, Dv)
    sb = seg_kv.reshape(nb, block_kv)
    pb = pos_kv.reshape(nb, block_kv)

    window = jnp.asarray(window)

    def body(carry, blk):
        acc, m_run, l_run = carry
        kk, vv, sseg, ppos = blk
        # QK in the input dtype with f32 accumulation: bf16 products are
        # exact in f32, and the matmul reads half the HBM of upcast inputs
        s = jnp.einsum("thd,shd->hts", q, kk,
                       preferred_element_type=jnp.float32) * scale
        msk = _mask(seg_q, sseg, pos_q, ppos, causal, window)
        s = jnp.where(msk[None], s, NEG_INF)
        m_new = jnp.maximum(m_run, s.max(axis=-1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_run * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "hts,shd->htd", p, vv.astype(jnp.float32))
        return (acc, m_new, l_new), None

    acc0 = jnp.zeros((Hq, T, Dv), jnp.float32)
    m0 = jnp.full((Hq, T), NEG_INF, jnp.float32)
    l0 = jnp.zeros((Hq, T), jnp.float32)
    (acc, m_run, l_run), _ = jax.lax.scan(body, (acc0, m0, l0),
                                          (kb, vb, sb, pb))
    out = acc / jnp.maximum(l_run[..., None], 1e-30)
    out = jnp.where(l_run[..., None] > 0, out, 0.0)
    return jnp.transpose(out, (1, 0, 2)).astype(q.dtype)


# ---------------------------------------------------------------------------
# Cross entropy.
# ---------------------------------------------------------------------------

def cross_entropy_reference(hidden, w_vocab, targets, valid
                            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Naive full-logits CE. hidden: [T, D]; w_vocab: [V, D]; targets: [T];
    valid: [T] bool. Returns (sum_loss fp32 scalar, n_valid fp32 scalar)."""
    logits = jnp.einsum("td,vd->tv", hidden.astype(jnp.float32),
                        w_vocab.astype(jnp.float32))
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(
        logits, targets[:, None].astype(jnp.int32), axis=-1)[:, 0]
    loss = jnp.where(valid, lse - tgt, 0.0)
    return loss.sum(), valid.astype(jnp.float32).sum()


def streaming_cross_entropy(hidden, w_vocab, targets, valid, *,
                            block_v: int = 2048
                            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Vocab-tiled online-logsumexp CE: never materializes [T, V].

    Matches cross_entropy_reference exactly (up to fp reassociation); jnp
    analogue of the Pallas streaming kernel; differentiable (XLA re-derives
    the tiled backward through the scan).
    """
    T, D = hidden.shape
    V = w_vocab.shape[0]
    pad = (-V) % block_v
    if pad:
        w_vocab = jnp.concatenate(
            [w_vocab, jnp.zeros((pad, D), w_vocab.dtype)])
    nb = w_vocab.shape[0] // block_v
    wb = w_vocab.reshape(nb, block_v, D)
    tgt = targets.astype(jnp.int32)

    def body(carry, inp):
        m_run, l_run, t_run = carry
        w, bidx = inp
        # logits in f32 via accumulation dtype, operands stay bf16
        logits = jnp.einsum("td,vd->tv", hidden, w,
                            preferred_element_type=jnp.float32)
        vocab_ids = bidx * block_v + jnp.arange(block_v)
        live = vocab_ids[None, :] < V
        logits = jnp.where(live, logits, NEG_INF)
        m_new = jnp.maximum(m_run, logits.max(axis=-1))
        l_new = l_run * jnp.exp(m_run - m_new) + \
            jnp.exp(logits - m_new[:, None]).sum(axis=-1)
        hit = vocab_ids[None, :] == tgt[:, None]
        t_new = t_run + jnp.where(hit, logits, 0.0).sum(axis=-1)
        return (m_new, l_new, t_new), None

    m0 = jnp.full((T,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((T,), jnp.float32)
    t0 = jnp.zeros((T,), jnp.float32)
    (m_run, l_run, t_run), _ = jax.lax.scan(
        body, (m0, l0, t0), (wb, jnp.arange(nb)))
    lse = m_run + jnp.log(jnp.maximum(l_run, 1e-30))
    loss = jnp.where(valid, lse - t_run, 0.0)
    return loss.sum(), valid.astype(jnp.float32).sum()


def streaming_ce_stats(hidden, w_shard, local_targets, *,
                       block_v: int = 2048,
                       global_offset=0,
                       vocab_true: Optional[int] = None
                       ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Per-token softmax stats against a vocab SHARD: returns (m, l, tgt)
    where m = max logit, l = sum exp(logit - m), tgt = target logit if the
    target id falls inside this shard else 0. ``local_targets`` are already
    offset into shard-local ids (out-of-range => not in shard).

    ``global_offset``/``vocab_true`` mask executor-side vocab padding
    (Megatron-style: V is padded to a multiple of d_s; padded rows must not
    contaminate the logsumexp).

    The vocab-parallel CE merge (runtime/sp.py) combines shards with
      m_g = pmax(m); l_g = psum(l * exp(m - m_g)); tgt_g = psum(tgt).
    """
    T, D = hidden.shape
    Vs = w_shard.shape[0]
    pad = (-Vs) % block_v
    if pad:
        w_shard = jnp.concatenate([w_shard, jnp.zeros((pad, D), w_shard.dtype)])
    nb = w_shard.shape[0] // block_v
    wb = w_shard.reshape(nb, block_v, D)
    tgt_ids = local_targets.astype(jnp.int32)
    v_hi = Vs if vocab_true is None else vocab_true

    def body(carry, inp):
        m_run, l_run, t_run = carry
        w, bidx = inp
        # logits in f32 via accumulation dtype, operands stay bf16
        logits = jnp.einsum("td,vd->tv", hidden, w,
                            preferred_element_type=jnp.float32)
        ids = bidx * block_v + jnp.arange(block_v)
        live = (ids[None, :] < Vs) & \
            ((global_offset + ids)[None, :] < v_hi)
        logits = jnp.where(live, logits, NEG_INF)
        m_new = jnp.maximum(m_run, logits.max(axis=-1))
        l_new = l_run * jnp.exp(m_run - m_new) + \
            jnp.exp(logits - m_new[:, None]).sum(axis=-1)
        # dead (padded) rows must not match: a local target id from another
        # shard can collide with a padded row index here.
        hit = (ids[None, :] == tgt_ids[:, None]) & live
        t_new = t_run + jnp.where(hit, logits, 0.0).sum(axis=-1)
        return (m_new, l_new, t_new), None

    m0 = jnp.full((T,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((T,), jnp.float32)
    t0 = jnp.zeros((T,), jnp.float32)
    (m, l, t), _ = jax.lax.scan(body, (m0, l0, t0), (wb, jnp.arange(nb)))
    return m, l, t


# ---------------------------------------------------------------------------
# Mamba selective scan (oracle: straight sequential scan).
# ---------------------------------------------------------------------------

def mamba_scan_reference(a, bx, h0) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """h_t = a_t * h_{t-1} + bx_t, sequential. a/bx: [T, di, ds]."""
    def step(h, inp):
        at, bt = inp
        h = at * h + bt
        return h, h
    h_last, hs = jax.lax.scan(step, h0, (a, bx))
    return hs, h_last
