"""Pallas TPU selective-scan (mamba-1) kernel.

Grid = (n_channel_blocks, n_time_blocks): channel blocks are independent
(parallel); the time axis is innermost/sequential, carrying the recurrent
state ``h [B_DI, DS]`` in VMEM scratch across time blocks. Within a block
the recurrence is stepped with a ``fori_loop`` over VMEM rows — the op is
VPU-bound elementwise work (no MXU), so the loop costs what the math costs;
what matters is that delta/B/C/x tiles stream HBM->VMEM once and the state
never leaves VMEM.

Inputs are the raw per-token SSM tensors (the [T, di, ds] outer products are
formed *inside* the kernel tile-by-tile and never hit HBM):
    delta [T, DI], xs [T, DI], B [T, DS], C [T, DS], A [DI, DS],
    reset [T, 1] (1 => sequence start: kills the recurrence),
    h0 [DI, DS] (split-chunk carry-in).
Outputs: y [T, DI] (pre-gating), h_last [DI, DS].

Oracle: ``ref.mamba_scan_reference`` composed with the same outer products
(tests/test_kernels.py sweeps shapes and dtypes in interpret mode).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["mamba_scan_pallas", "DEFAULT_BT", "DEFAULT_BDI"]

DEFAULT_BT = 256
DEFAULT_BDI = 512


def _kernel(delta_ref, xs_ref, b_ref, c_ref, a_ref, reset_ref, h0_ref,
            y_ref, hlast_ref,
            h_ref,
            *, n_t: int, bt: int):
    t_idx = pl.program_id(1)

    @pl.when(t_idx == 0)
    def _init():
        h_ref[...] = h0_ref[...].astype(jnp.float32)

    a_mat = a_ref[...].astype(jnp.float32)          # [BDI, DS]
    delta = delta_ref[...].astype(jnp.float32)      # [BT, BDI]
    xs = xs_ref[...].astype(jnp.float32)            # [BT, BDI]
    bmat = b_ref[...].astype(jnp.float32)           # [BT, DS]
    cmat = c_ref[...].astype(jnp.float32)           # [BT, DS]
    reset = reset_ref[...]                          # [BT, 1] int32

    def step(t, h):
        d_t = jax.lax.dynamic_slice_in_dim(delta, t, 1, 0)     # [1, BDI]
        x_t = jax.lax.dynamic_slice_in_dim(xs, t, 1, 0)
        b_t = jax.lax.dynamic_slice_in_dim(bmat, t, 1, 0)      # [1, DS]
        c_t = jax.lax.dynamic_slice_in_dim(cmat, t, 1, 0)
        r_t = jax.lax.dynamic_slice_in_dim(reset, t, 1, 0)     # [1, 1]
        a_t = jnp.exp(d_t.T * a_mat)                           # [BDI, DS]
        a_t = jnp.where(r_t[0, 0] > 0, 0.0, a_t)
        bx_t = (d_t * x_t).T * b_t                             # [BDI, DS]
        h = a_t * h + bx_t
        y_t = jnp.sum(h * c_t, axis=1, keepdims=True).T        # [1, BDI]
        y_ref[pl.dslice(t, 1), :] = y_t.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, bt, step, h_ref[...])
    h_ref[...] = h

    @pl.when(t_idx == n_t - 1)
    def _finish():
        hlast_ref[...] = h.astype(hlast_ref.dtype)


def mamba_scan_pallas(delta, xs, B, C, A, reset, h0, *,
                      block_t: int = DEFAULT_BT,
                      block_di: int = DEFAULT_BDI,
                      interpret: bool = True
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """See module docstring. T must divide block_t (caller pads); DI must
    divide block_di."""
    T, DI = delta.shape
    DS = B.shape[1]
    bt = min(block_t, T)
    bdi = min(block_di, DI)
    assert T % bt == 0 and DI % bdi == 0, (T, bt, DI, bdi)
    n_t, n_di = T // bt, DI // bdi

    kernel = functools.partial(_kernel, n_t=n_t, bt=bt)
    y, h_last = pl.pallas_call(
        kernel,
        grid=(n_di, n_t),
        in_specs=[
            pl.BlockSpec((bt, bdi), lambda d, t: (t, d)),   # delta
            pl.BlockSpec((bt, bdi), lambda d, t: (t, d)),   # xs
            pl.BlockSpec((bt, DS), lambda d, t: (t, 0)),    # B
            pl.BlockSpec((bt, DS), lambda d, t: (t, 0)),    # C
            pl.BlockSpec((bdi, DS), lambda d, t: (d, 0)),   # A
            pl.BlockSpec((bt, 1), lambda d, t: (t, 0)),     # reset
            pl.BlockSpec((bdi, DS), lambda d, t: (d, 0)),   # h0
        ],
        out_specs=[
            pl.BlockSpec((bt, bdi), lambda d, t: (t, d)),   # y
            pl.BlockSpec((bdi, DS), lambda d, t: (d, 0)),   # h_last
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, DI), delta.dtype),
            jax.ShapeDtypeStruct((DI, DS), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bdi, DS), jnp.float32)],
        interpret=interpret,
    )(delta, xs, B, C, A, reset.reshape(T, 1).astype(jnp.int32), h0)
    return y, h_last
