"""Public jit'd wrappers around the Pallas kernels.

Each op pads its inputs to kernel tile geometry, dispatches to the Pallas
kernel (``interpret=True`` everywhere except a real TPU backend) or to the
blocked-jnp fallback, and unpads. ``fused_cross_entropy`` installs a
``custom_vjp`` wiring the streaming forward to the streaming d(hidden)/d(W)
backward kernels, so the `[T, V]` logits never exist in any pass.

``use_pallas`` resolution:
  * explicit True/False wins;
  * None  => Pallas-in-interpret when running tests on CPU is *wasteful*,
    so the default is the blocked-jnp path off-TPU and the Mosaic kernel on
    TPU. The kernels' correctness is pinned by tests/test_kernels.py which
    forces interpret=True.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref
from .cross_entropy import (cross_entropy_bwd_dh_pallas,
                            cross_entropy_bwd_dw_pallas,
                            cross_entropy_fwd_pallas)
from .flash_attention import flash_attention_pallas
from .mamba_scan import mamba_scan_pallas

__all__ = ["flash_attention", "fused_cross_entropy", "mamba_scan",
           "on_tpu"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(use_pallas: Optional[bool]) -> bool:
    if use_pallas is None:
        return on_tpu()
    return use_pallas


def _pad_to(x: jnp.ndarray, mult: int, axis: int, fill=0):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


# ---------------------------------------------------------------------------
# Flash attention.
# ---------------------------------------------------------------------------

def flash_attention(q, k, v, seg_q, seg_kv, pos_q, pos_kv, *,
                    causal: bool = True, window: int = 0,
                    scale: Optional[float] = None,
                    block_q: int = 512, block_kv: int = 512,
                    use_pallas: Optional[bool] = None) -> jnp.ndarray:
    """Packed-varlen attention. Padding rows get seg=-2 (matches nothing)."""
    if not _resolve(use_pallas):
        return ref.blocked_flash_attention(
            q, k, v, seg_q, seg_kv, pos_q, pos_kv,
            causal=causal, window=window, scale=scale)
    T = q.shape[0]
    S = k.shape[0]
    bq = min(block_q, max(8, T))
    bkv = min(block_kv, max(8, S))
    qp = _pad_to(q, bq, 0)
    kp = _pad_to(k, bkv, 0)
    vp = _pad_to(v, bkv, 0)
    seg_qp = _pad_to(seg_q.astype(jnp.int32), bq, 0, fill=-2)
    seg_kvp = _pad_to(seg_kv.astype(jnp.int32), bkv, 0, fill=-2)
    pos_qp = _pad_to(pos_q.astype(jnp.int32), bq, 0)
    pos_kvp = _pad_to(pos_kv.astype(jnp.int32), bkv, 0)
    out = flash_attention_pallas(
        qp, kp, vp, seg_qp, seg_kvp, pos_qp, pos_kvp,
        causal=causal, window=int(window), scale=scale,
        block_q=bq, block_kv=bkv, interpret=not on_tpu())
    return out[:T]


# ---------------------------------------------------------------------------
# Fused streaming cross entropy (custom_vjp).
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def _fused_ce(hidden, w_vocab, targets, valid, block_t, block_v):
    lse, tl = cross_entropy_fwd_pallas(
        hidden, w_vocab, targets, valid,
        block_t=block_t, block_v=block_v, interpret=not on_tpu())
    vf = valid.astype(jnp.float32)
    return ((lse - tl) * vf).sum(), vf.sum()


def _fused_ce_fwd(hidden, w_vocab, targets, valid, block_t, block_v):
    lse, tl = cross_entropy_fwd_pallas(
        hidden, w_vocab, targets, valid,
        block_t=block_t, block_v=block_v, interpret=not on_tpu())
    vf = valid.astype(jnp.float32)
    out = (((lse - tl) * vf).sum(), vf.sum())
    return out, (hidden, w_vocab, targets, valid, lse)


def _fused_ce_bwd(block_t, block_v, res, g):
    hidden, w_vocab, targets, valid, lse = res
    g_loss, _ = g
    g_rows = jnp.where(valid, g_loss, 0.0).astype(jnp.float32)
    interp = not on_tpu()
    dh = cross_entropy_bwd_dh_pallas(hidden, w_vocab, targets, lse, g_rows,
                                     block_t=block_t, block_v=block_v,
                                     interpret=interp)
    dw = cross_entropy_bwd_dw_pallas(hidden, w_vocab, targets, lse, g_rows,
                                     block_t=block_t, block_v=block_v,
                                     interpret=interp)
    return dh, dw, None, None


_fused_ce.defvjp(_fused_ce_fwd, _fused_ce_bwd)


def fused_cross_entropy(hidden, w_vocab, targets, valid, *,
                        block_t: int = 256, block_v: int = 1024,
                        use_pallas: Optional[bool] = None
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(sum_loss, n_valid) with logits never materialized in any pass."""
    if not _resolve(use_pallas):
        return ref.streaming_cross_entropy(hidden, w_vocab,
                                           jnp.maximum(targets, 0), valid)
    T = hidden.shape[0]
    bt = min(block_t, max(8, T))
    hp = _pad_to(hidden, bt, 0)
    tp = _pad_to(targets.astype(jnp.int32), bt, 0, fill=-1)
    vp = _pad_to(valid, bt, 0, fill=False)
    return _fused_ce(hp, w_vocab, tp, vp, bt, block_v)


# ---------------------------------------------------------------------------
# Mamba selective scan.
# ---------------------------------------------------------------------------

def mamba_scan(delta, xs, B, C, A, reset, h0, *,
               block_t: int = 256, block_di: int = 512,
               use_pallas: Optional[bool] = None
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """y [T, DI], h_last [DI, DS] — see mamba_scan.py for semantics."""
    if not _resolve(use_pallas):
        a = jnp.exp(delta.astype(jnp.float32)[:, :, None]
                    * A.astype(jnp.float32)[None])
        a = jnp.where(reset.reshape(-1, 1, 1) > 0, 0.0, a)
        bx = (delta * xs).astype(jnp.float32)[:, :, None] * \
            B.astype(jnp.float32)[:, None, :]
        hs, h_last = ref.mamba_scan_reference(a, bx, h0.astype(jnp.float32))
        y = jnp.einsum("tds,ts->td", hs, C.astype(jnp.float32))
        return y.astype(delta.dtype), h_last
    T = delta.shape[0]
    bt = min(block_t, max(8, T))
    # Padding steps must be state-neutral: delta=0 => a = exp(0*A) = 1 and
    # bx = 0 (identity step), reset=0 so the carried state survives to
    # h_last.
    dp = _pad_to(delta, bt, 0)
    xp = _pad_to(xs, bt, 0)
    Bp = _pad_to(B, bt, 0)
    Cp = _pad_to(C, bt, 0)
    rp = _pad_to(reset.astype(jnp.int32), bt, 0, fill=0)
    y, h_last = mamba_scan_pallas(dp, xp, Bp, Cp, A, rp, h0,
                                  block_t=bt, block_di=block_di,
                                  interpret=not on_tpu())
    return y[:T], h_last
