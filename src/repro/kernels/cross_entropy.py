"""Pallas TPU fused streaming cross-entropy.

The paper (§IV) adopts Megatron's fused *in-place* CE to stop logits
intermediates from blowing up the peak at the start of backward. On TPU we
go one step further (beyond-paper, see DESIGN.md §2.1): the `[T, V]` logits
are never materialized at all —

* **forward** (`cross_entropy_fwd_pallas`): grid (n_token_blocks,
  n_vocab_blocks), vocab innermost/sequential; each step computes the
  `[BT, BV]` logits tile on the MXU and folds it into running
  (max, sumexp, target-logit) VMEM scratch; emits per-token (lse, tgt).
* **backward** (`cross_entropy_bwd_*`): recomputes the logits tile, forms
  `p - onehot` in VMEM and immediately contracts it — into `[BT, D]` for
  d(hidden) (vocab-sequential accumulation) and `[BV, D]` for d(W)
  (token-sequential accumulation). Peak live memory is O(BT*BV + BT*D).

Tiles: BT=256 tokens x BV=1024 vocab => 1 MiB f32 logits tile + a 256xD
accumulator — VMEM-resident at D <= 8192.

ops.py wires these into a custom_vjp; the pure-jnp oracle is
``ref.streaming_cross_entropy`` / ``ref.cross_entropy_reference``.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["cross_entropy_fwd_pallas", "cross_entropy_bwd_dh_pallas",
           "cross_entropy_bwd_dw_pallas", "DEFAULT_BT", "DEFAULT_BV"]

DEFAULT_BT = 256
DEFAULT_BV = 1024
NEG_INF = -1e30


def _fwd_kernel(h_ref, w_ref, tgt_ref, valid_ref,
                lse_ref, tl_ref,
                m_ref, l_ref, t_ref,
                *, n_v: int, bv: int, vocab: int):
    v_idx = pl.program_id(1)

    @pl.when(v_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        t_ref[...] = jnp.zeros_like(t_ref)

    h = h_ref[...].astype(jnp.float32)          # [BT, D]
    w = w_ref[...].astype(jnp.float32)          # [BV, D]
    logits = jax.lax.dot_general(h, w, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    vocab_ids = v_idx * bv + jax.lax.broadcasted_iota(
        jnp.int32, (1, bv), dimension=1)        # [1, BV]
    live = vocab_ids < vocab
    logits = jnp.where(live, logits, NEG_INF)

    m_prev = m_ref[...]                          # [BT, 1]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(
        jnp.exp(logits - m_new), axis=1, keepdims=True)
    m_ref[...] = m_new
    hit = vocab_ids == tgt_ref[...]              # [BT, BV] via broadcast
    t_ref[...] += jnp.sum(jnp.where(hit, logits, 0.0), axis=1, keepdims=True)

    @pl.when(v_idx == n_v - 1)
    def _finish():
        lse = m_ref[...] + jnp.log(jnp.maximum(l_ref[...], 1e-30))
        lse_ref[...] = lse
        tl_ref[...] = t_ref[...]


def cross_entropy_fwd_pallas(hidden, w_vocab, targets, valid, *,
                             block_t: int = DEFAULT_BT,
                             block_v: int = DEFAULT_BV,
                             interpret: bool = True
                             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """hidden [T, D], w_vocab [V, D], targets [T] int32, valid [T] bool ->
    (lse [T], tgt_logit [T]) fp32. T and V are padded by the caller."""
    T, D = hidden.shape
    V = w_vocab.shape[0]
    bt = min(block_t, T)
    bv = min(block_v, V)
    assert T % bt == 0
    padV = (-V) % bv
    if padV:
        w_vocab = jnp.concatenate(
            [w_vocab, jnp.zeros((padV, D), w_vocab.dtype)])
    n_t, n_v = T // bt, w_vocab.shape[0] // bv
    tgt2 = targets.reshape(T, 1).astype(jnp.int32)
    valid2 = valid.reshape(T, 1).astype(jnp.int32)

    kernel = functools.partial(_fwd_kernel, n_v=n_v, bv=bv, vocab=V)
    lse, tl = pl.pallas_call(
        kernel,
        grid=(n_t, n_v),
        in_specs=[
            pl.BlockSpec((bt, D), lambda i, j: (i, 0)),      # hidden
            pl.BlockSpec((bv, D), lambda i, j: (j, 0)),      # w tile
            pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),      # targets
            pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),      # valid
        ],
        out_specs=[
            pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, 1), jnp.float32),
            jax.ShapeDtypeStruct((T, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bt, 1), jnp.float32),
            pltpu.VMEM((bt, 1), jnp.float32),
            pltpu.VMEM((bt, 1), jnp.float32),
        ],
        interpret=interpret,
    )(hidden, w_vocab, tgt2, valid2)
    return lse[:, 0], tl[:, 0]


# ---------------------------------------------------------------------------
# Backward.
# ---------------------------------------------------------------------------

def _bwd_dh_kernel(h_ref, w_ref, tgt_ref, lse_ref, g_ref,
                   dh_ref, acc_ref,
                   *, n_v: int, bv: int, vocab: int):
    v_idx = pl.program_id(1)

    @pl.when(v_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    h = h_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    logits = jax.lax.dot_general(h, w, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    vocab_ids = v_idx * bv + jax.lax.broadcasted_iota(
        jnp.int32, (1, bv), dimension=1)
    live = vocab_ids < vocab
    p = jnp.where(live, jnp.exp(logits - lse_ref[...]), 0.0)  # [BT, BV]
    hit = vocab_ids == tgt_ref[...]
    coef = (p - jnp.where(hit, 1.0, 0.0)) * g_ref[...]        # [BT, BV]
    acc_ref[...] += jax.lax.dot_general(
        coef, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(v_idx == n_v - 1)
    def _finish():
        dh_ref[...] = acc_ref[...].astype(dh_ref.dtype)


def cross_entropy_bwd_dh_pallas(hidden, w_vocab, targets, lse, g_rows, *,
                                block_t: int = DEFAULT_BT,
                                block_v: int = DEFAULT_BV,
                                interpret: bool = True) -> jnp.ndarray:
    """d(hidden): [T, D]. ``g_rows`` [T] = upstream grad * valid mask."""
    T, D = hidden.shape
    V = w_vocab.shape[0]
    bt = min(block_t, T)
    bv = min(block_v, V)
    padV = (-V) % bv
    if padV:
        w_vocab = jnp.concatenate(
            [w_vocab, jnp.zeros((padV, D), w_vocab.dtype)])
    n_t, n_v = T // bt, w_vocab.shape[0] // bv
    kernel = functools.partial(_bwd_dh_kernel, n_v=n_v, bv=bv, vocab=V)
    return pl.pallas_call(
        kernel,
        grid=(n_t, n_v),
        in_specs=[
            pl.BlockSpec((bt, D), lambda i, j: (i, 0)),
            pl.BlockSpec((bv, D), lambda i, j: (j, 0)),
            pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bt, D), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((T, D), hidden.dtype),
        scratch_shapes=[pltpu.VMEM((bt, D), jnp.float32)],
        interpret=interpret,
    )(hidden, w_vocab, targets.reshape(T, 1).astype(jnp.int32),
      lse.reshape(T, 1).astype(jnp.float32),
      g_rows.reshape(T, 1).astype(jnp.float32))


def _bwd_dw_kernel(h_ref, w_ref, tgt_ref, lse_ref, g_ref,
                   dw_ref, acc_ref,
                   *, n_t: int, bv: int, vocab: int):
    t_idx = pl.program_id(1)

    @pl.when(t_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    h = h_ref[...].astype(jnp.float32)          # [BT, D]
    w = w_ref[...].astype(jnp.float32)          # [BV, D]
    v_idx = pl.program_id(0)
    logits = jax.lax.dot_general(h, w, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    vocab_ids = v_idx * bv + jax.lax.broadcasted_iota(
        jnp.int32, (1, bv), dimension=1)
    live = vocab_ids < vocab
    p = jnp.where(live, jnp.exp(logits - lse_ref[...]), 0.0)
    hit = vocab_ids == tgt_ref[...]
    coef = (p - jnp.where(hit, 1.0, 0.0)) * g_ref[...]        # [BT, BV]
    acc_ref[...] += jax.lax.dot_general(
        coef, h, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                   # [BV, D]

    @pl.when(t_idx == n_t - 1)
    def _finish():
        dw_ref[...] = acc_ref[...].astype(dw_ref.dtype)


def cross_entropy_bwd_dw_pallas(hidden, w_vocab, targets, lse, g_rows, *,
                                block_t: int = DEFAULT_BT,
                                block_v: int = DEFAULT_BV,
                                interpret: bool = True) -> jnp.ndarray:
    """d(w_vocab): [V, D]."""
    T, D = hidden.shape
    V = w_vocab.shape[0]
    bt = min(block_t, T)
    bv = min(block_v, V)
    padV = (-V) % bv
    w_pad = w_vocab
    if padV:
        w_pad = jnp.concatenate([w_vocab, jnp.zeros((padV, D), w_vocab.dtype)])
    n_t, n_v = T // bt, w_pad.shape[0] // bv
    kernel = functools.partial(_bwd_dw_kernel, n_t=n_t, bv=bv, vocab=V)
    dw = pl.pallas_call(
        kernel,
        grid=(n_v, n_t),
        in_specs=[
            pl.BlockSpec((bt, D), lambda j, i: (i, 0)),
            pl.BlockSpec((bv, D), lambda j, i: (j, 0)),
            pl.BlockSpec((bt, 1), lambda j, i: (i, 0)),
            pl.BlockSpec((bt, 1), lambda j, i: (i, 0)),
            pl.BlockSpec((bt, 1), lambda j, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bv, D), lambda j, i: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((w_pad.shape[0], D), w_vocab.dtype),
        scratch_shapes=[pltpu.VMEM((bv, D), jnp.float32)],
        interpret=interpret,
    )(hidden, w_pad, targets.reshape(T, 1).astype(jnp.int32),
      lse.reshape(T, 1).astype(jnp.float32),
      g_rows.reshape(T, 1).astype(jnp.float32))
    return dw[:V]
