"""Pallas TPU flash-attention kernel: packed-varlen causal attention with
split-chunk context and sliding-window support.

TPU adaptation of the paper's flash-attn dependency (DESIGN.md §2.1.6):

* grid = (Hq, n_q_blocks, n_kv_blocks) — the kv axis is innermost, which on
  TPU executes sequentially per (head, q-block), so the online-softmax
  running state (m, l, acc) lives in VMEM scratch and persists across kv
  steps; no HBM round-trips for the accumulator.
* BlockSpec tiling: q tile [BQ, Dh], kv tile [BKV, Dh] with Dh padded to a
  multiple of 128 (MXU lane width) by the ops.py wrapper, BQ/BKV multiples
  of 8 (sublane). Default BQ = BKV = 512 keeps the working set
  (q + kv tiles + f32 accumulator ≈ 1.3 MiB at Dh=128) far under the
  ~16 MiB VMEM budget, leaving room for double-buffered input DMA.
* GQA is resolved in the BlockSpec index_map: query head h reads kv head
  ``h // (Hq // Hkv)`` — no KV repetition is materialized.
* the packed-varlen mask (segment equality x causality x window x context
  offsets) is computed in-kernel from [T,1]-shaped seg/pos tiles; fully
  masked kv tiles contribute zeros (the online rescale handles it).

Validated in ``interpret=True`` mode against ``ref.flash_attention_reference``
over shape/dtype sweeps (tests/test_kernels.py).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas", "DEFAULT_BQ", "DEFAULT_BKV"]

DEFAULT_BQ = 512
DEFAULT_BKV = 512
NEG_INF = -1e30


def _kernel(seg_q_ref, pos_q_ref, seg_kv_ref, pos_kv_ref,
            q_ref, k_ref, v_ref,           # inputs
            o_ref,                          # output
            acc_ref, m_ref, l_ref,          # VMEM scratch
            *, scale: float, causal: bool, window: int, n_kv: int):
    kv_idx = pl.program_id(2)

    @pl.when(kv_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...].astype(jnp.float32)      # [BQ, Dh]
    k = k_ref[...].astype(jnp.float32)      # [BKV, Dh]
    v = v_ref[...].astype(jnp.float32)      # [BKV, Dv]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    seg_q = seg_q_ref[...]                  # [BQ, 1]
    seg_kv = seg_kv_ref[...]                # [BKV, 1]
    pos_q = pos_q_ref[...]
    pos_kv = pos_kv_ref[...]
    mask = (seg_q == seg_kv.T) & (seg_q >= 0)
    if causal:
        mask &= pos_kv.T <= pos_q
    if window > 0:
        mask &= (pos_q - pos_kv.T) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                      # [BQ, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                   # [BQ, BKV]
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(kv_idx == n_kv - 1)
    def _finish():
        l = l_ref[...]
        out = acc_ref[...] / jnp.where(l > 0, l, 1.0)
        o_ref[...] = jnp.where(l > 0, out, 0.0).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, seg_q, seg_kv, pos_q, pos_kv, *,
                           causal: bool = True, window: int = 0,
                           scale: Optional[float] = None,
                           block_q: int = DEFAULT_BQ,
                           block_kv: int = DEFAULT_BKV,
                           interpret: bool = True) -> jnp.ndarray:
    """q: [T, Hq, Dh]; k: [S, Hkv, Dh]; v: [S, Hkv, Dv] -> [T, Hq, Dv].

    Preconditions (enforced by the ops.py wrapper): T % block_q == 0,
    S % block_kv == 0 (after padding), Hq % Hkv == 0, ``window``/``causal``
    static.
    """
    T, Hq, Dh = q.shape
    S, Hkv, Dv = v.shape
    scale = scale if scale is not None else Dh ** -0.5
    bq = min(block_q, T)
    bkv = min(block_kv, S)
    assert T % bq == 0 and S % bkv == 0, (T, bq, S, bkv)
    n_q, n_kv = T // bq, S // bkv
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv

    # head-major layout so each (head, block) is a clean 2D tile
    qh = jnp.swapaxes(q, 0, 1)               # [Hq, T, Dh]
    kh = jnp.swapaxes(k, 0, 1)               # [Hkv, S, Dh]
    vh = jnp.swapaxes(v, 0, 1)               # [Hkv, S, Dv]
    seg_q2 = seg_q.reshape(T, 1).astype(jnp.int32)
    seg_kv2 = seg_kv.reshape(S, 1).astype(jnp.int32)
    pos_q2 = pos_q.reshape(T, 1).astype(jnp.int32)
    pos_kv2 = pos_kv.reshape(S, 1).astype(jnp.int32)

    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               window=int(window), n_kv=n_kv)
    out = pl.pallas_call(
        kernel,
        grid=(Hq, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((bq, 1), lambda h, i, j: (i, 0)),         # seg_q
            pl.BlockSpec((bq, 1), lambda h, i, j: (i, 0)),         # pos_q
            pl.BlockSpec((bkv, 1), lambda h, i, j: (j, 0)),        # seg_kv
            pl.BlockSpec((bkv, 1), lambda h, i, j: (j, 0)),        # pos_kv
            pl.BlockSpec((None, bq, Dh), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((None, bkv, Dh),
                         lambda h, i, j: (h // group, j, 0)),
            pl.BlockSpec((None, bkv, Dv),
                         lambda h, i, j: (h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, Dv), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((Hq, T, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, Dv), jnp.float32),   # acc
            pltpu.VMEM((bq, 1), jnp.float32),    # running max
            pltpu.VMEM((bq, 1), jnp.float32),    # running sum
        ],
        interpret=interpret,
    )(seg_q2, pos_q2, seg_kv2, pos_kv2, qh, kh, vh)
    return jnp.swapaxes(out, 0, 1)
