from .adamw import AdamWConfig, adamw_update, global_norm, init_opt_state
from .compression import compressed_psum, init_error_state

__all__ = ["AdamWConfig", "adamw_update", "global_norm", "init_opt_state",
           "compressed_psum", "init_error_state"]
