"""AdamW with ZeRO-3-partitioned state.

Runs on LOCAL shards inside ``shard_map``: because gradients come out of
autodiff with exactly the parameters' sharding (the ZeRO all-gather
transposes to a reduce-scatter), the optimizer never communicates — each
device updates its own param/master/m/v shard. fp32 master weights + m/v;
live params in the executor's dtype (bf16 by default).

Gradient clipping needs one global norm: the caller supplies ``psum_axes``
so the sum of squares can cross the ("data", "model") shards (and "pod"
after the pod gradient reduction).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "init_opt_state", "adamw_update", "global_norm"]


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def init_opt_state(params) -> Dict[str, Any]:
    # copy=True: with float32 params astype() would RETURN THE SAME buffer,
    # and a step that donates both params and opt.master then aborts with
    # "attempt to donate the same buffer twice" (surfaced by the AOT-
    # compiled step path, which does not re-layout already-placed inputs)
    master = jax.tree.map(lambda p: jnp.array(p, jnp.float32, copy=True),
                          params)
    zeros = lambda: jax.tree.map(  # noqa: E731
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"master": master, "m": zeros(), "v": zeros(),
            "step": jnp.zeros((), jnp.int32)}


def lr_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(grads, psum_axes: Sequence[str]) -> jnp.ndarray:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    for ax in psum_axes:
        sq = jax.lax.psum(sq, ax)
    return jnp.sqrt(sq)


def adamw_update(cfg: AdamWConfig, params, grads, state, *,
                 grad_scale: jnp.ndarray,
                 psum_axes: Sequence[str] = (),
                 gnorm: Optional[jnp.ndarray] = None
                 ) -> Tuple[Any, Dict, Dict]:
    """One AdamW step on local shards. ``grad_scale`` rescales summed-loss
    gradients to per-token means (1 / n_valid_tokens). Callers inside
    shard_map pass a precomputed ``gnorm`` (replication-factor aware)."""
    step = state["step"] + 1
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * grad_scale, grads)
    if gnorm is None:
        gnorm = global_norm(grads, psum_axes)
    else:
        gnorm = gnorm * grad_scale
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p_master, g, m, v):
        g = g * clip
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / c1
        vh = v / c2
        new_master = p_master - lr * (
            mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p_master)
        return new_master, m, v

    flat_p, treedef = jax.tree.flatten(state["master"])
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_master = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_params = jax.tree.map(
        lambda live, mast: mast.astype(live.dtype), params, new_master)
    new_state = {"master": new_master, "m": new_m, "v": new_v, "step": step}
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
