"""Int8 gradient compression with error feedback for the inter-pod
all-reduce — the only traffic on the slowest (DCN) links.

Per-leaf symmetric quantization: scale = max|g| / 127 (psum'd so every pod
uses the same scale), quantize, psum int32 (wide enough for n_pods * int8),
dequantize. The quantization residual is fed back into the next step's
gradient (error feedback keeps the scheme convergent; Karimireddy et al.).

4x volume reduction on the DCN all-reduce; enabled with
``TrainStepConfig.compress_pod_grads``.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["compressed_psum", "init_error_state"]


def init_error_state(grads) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _q8_psum(g: jnp.ndarray, err: jnp.ndarray, axis: str
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    g32 = g.astype(jnp.float32) + err
    scale = jnp.max(jnp.abs(g32)) / 127.0
    scale = jax.lax.pmax(scale, axis)            # shared scale across pods
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    new_err = g32 - q.astype(jnp.float32) * scale
    summed = jax.lax.psum(q.astype(jnp.int32), axis)
    return summed.astype(jnp.float32) * scale, new_err


def compressed_psum(grads, err_state, axis: str):
    """psum(grads, axis) in int8 with error feedback. Returns
    (summed_grads fp32, new_err_state)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_state)
    outs = [_q8_psum(g, e, axis) for g, e in zip(flat_g, flat_e)]
    summed = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_err = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return summed, new_err
