"""Parameter/batch/optimizer sharding rules.

Layout (mesh axes: optional "pod" (DP), "data" (pipeline stages), "model"
(SP/FSDP/EP) — DESIGN.md §2.2):

* layer parameters are stage-stacked: leaf [L, ...] -> [d_p, L/d_p, ...],
  dim 0 sharded over "data";
* every leaf is additionally ZeRO-3 sharded over "model" along its largest
  divisible weight dim (the executor all-gathers per layer on use and the
  autodiff transpose emits the matching reduce-scatter);
* MoE expert weights are EP-sharded over "model" along the expert dim
  and are NOT gathered (expert parallelism instead of ZeRO for those);
* embedding / LM head are vocab-sharded over "model" (vocab-parallel
  embed-psum + streaming-CE merge live in runtime/sp.py);
* everything is replicated over "pod" (per-pod gradient psum once per step).
"""

from __future__ import annotations

import re
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

__all__ = ["EP_PATH_RE", "stack_stages", "stack_grouped_stages",
           "stage_active_mask", "interleaved_layer_order", "restack_elastic",
           "unstack_stages", "zero3_dim", "shard_dim_tree",
           "stage_param_specs", "head_param_specs", "batch_specs",
           "tree_paths_map", "mesh_axis_names", "shard_map_compat",
           "gather_layer_params", "gather_stage_params", "gather_params"]

# expert-parallel leaves: sharded on their expert dim, never ZeRO-gathered
EP_PATH_RE = re.compile(r"moe/(w_gate|w_up|w_down)$")


def mesh_axis_names(mesh: Mesh) -> Tuple[Optional[str], str, str]:
    """Returns (pod_axis | None, data_axis, model_axis)."""
    names = mesh.axis_names
    if len(names) == 3:
        return names[0], names[1], names[2]
    if len(names) == 2:
        return None, names[0], names[1]
    raise ValueError(f"expected 2 or 3 mesh axes, got {names}")


def shard_map_compat(f, *, mesh: Mesh, in_specs, out_specs,
                     check_vma: bool = False):
    """``jax.shard_map`` across jax versions: new releases expose it at the
    top level (``check_vma``); older ones only under ``jax.experimental``
    (same knob named ``check_rep``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


def tree_paths_map(fn, tree):
    """tree_map with a '/'-joined key path passed first."""
    def _name(k) -> str:
        if hasattr(k, "key"):
            return str(k.key)
        if hasattr(k, "idx"):
            return str(k.idx)
        return str(k)

    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: fn("/".join(_name(k) for k in path), leaf), tree)


def _stack_one(layers_tree, n_stages: int, L_ps: int, order=None):
    """[L, ...] leaves -> [n_stages, L_ps, ...], zero-padded layer slots.
    ``order`` optionally permutes the padded layer list before reshaping
    (interleaved virtual-stage placement)."""
    def _re(x):
        pad = n_stages * L_ps - x.shape[0]
        if pad:
            x = jnp.concatenate(
                [x, jnp.zeros((pad, *x.shape[1:]), x.dtype)], axis=0)
        if order is not None:
            x = x[order]
        return x.reshape(n_stages, L_ps, *x.shape[1:])
    return jax.tree.map(_re, layers_tree)


def interleaved_layer_order(d_p: int, layers_per_stage: int, v: int):
    """Padded-layer permutation for ``interleaved-1f1b`` stacking.

    Global virtual stage ``s = j * d_p + p`` (device ``p`` hosting local
    virtual stage ``j``) owns the contiguous padded layers
    ``[s * L_v, (s + 1) * L_v)`` where ``L_v = layers_per_stage / v`` — the
    Megatron-style round-robin placement that shortens the pipeline fill by
    ``v``. Returns ``order`` with
    ``stacked[p, j * L_v + l] = layers[order[p * L_ps + j * L_v + l]]``.
    Identity at ``v == 1``.
    """
    import numpy as np
    if layers_per_stage % v:
        raise ValueError(
            f"v={v} must divide layers_per_stage={layers_per_stage}")
    L_v = layers_per_stage // v
    p_idx, j, l = np.meshgrid(np.arange(d_p), np.arange(v), np.arange(L_v),
                              indexing="ij")
    order = ((j * d_p + p_idx) * L_v + l).reshape(-1)
    return order


def stack_stages(layers_tree, d_p: int, n_layers: int, v: int = 1):
    """[L, ...] leaves -> [d_p, ceil(L/d_p), ...], zero-padded.

    Non-divisible depths (gemma3: 26 over 16 stages) pad with inert layer
    slots; :func:`stage_active_mask` marks them and the executor turns the
    padded layers into identity (the compute waste is real and surfaces in
    the roofline's MODEL_FLOPS ratio — DESIGN.md §2.1).

    ``v > 1`` stacks for ``interleaved-1f1b``: device ``p``'s ``v`` local
    virtual-stage blocks hold the layers of global virtual stages
    ``j * d_p + p`` (:func:`interleaved_layer_order`), so the layer order a
    chunk traverses around the ring is the model's own.
    """
    L_ps = -(-n_layers // d_p)
    order = interleaved_layer_order(d_p, L_ps, v) if v > 1 else None
    return _stack_one(layers_tree, d_p, L_ps, order)


def stack_grouped_stages(groups, L_ps: int):
    """Stack several homogeneous layer groups into one stage-stacked tree.

    ``groups`` is a list of ``(layers_tree, n_stages)``: each tree's
    ``[L, ...]`` leaves pad to ``n_stages * L_ps`` inert slots and reshape
    to ``[n_stages, L_ps, ...]``; the groups then concatenate along the
    stage dim (used by the enc-dec pipeline, whose encoder stages precede
    the decoder stages in one uniform pytree)."""
    stacked = [_stack_one(tree, n_stages, L_ps) for tree, n_stages in groups]
    if len(stacked) == 1:
        return stacked[0]
    out = stacked[0]
    for nxt in stacked[1:]:
        out = jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=0),
                           out, nxt)
    return out


def stage_active_mask(d_p: int, n_layers: int, v: int = 1):
    """[d_p, ceil(L/d_p)] bool: True where a real layer lives (under the
    ``v``-way interleaved placement when ``v > 1``)."""
    import numpy as np
    L_ps = -(-n_layers // d_p)
    flat = np.arange(d_p * L_ps) < n_layers
    if v > 1:
        flat = flat[interleaved_layer_order(d_p, L_ps, v)]
    return jnp.asarray(flat.reshape(d_p, L_ps))


def restack_elastic(saved, new_dp: int, new_ls: int, n_layers: int,
                    v: int = 1):
    """Adapt one stage-stacked ``[d_p_old, L_s_old, ...]`` array to a new
    pipeline depth (elastic checkpoint reshard): un-permute the saved
    layout back to model layer order (interleaved placement included),
    strip the old padding, re-pad and re-stack for ``(new_dp, new_ls)``
    under the same ``v``. Host-side numpy; returns ``None`` when the
    layout cannot be adapted (fewer slots than layers, or ``v`` not
    dividing a block size) — the caller falls back to fresh init.
    """
    import numpy as np
    d_p_old, L_s_old = saved.shape[0], saved.shape[1]
    if (new_dp * new_ls < n_layers or L_s_old % max(v, 1)
            or new_ls % max(v, 1)):
        return None
    flat = np.asarray(saved).reshape(d_p_old * L_s_old, *saved.shape[2:])
    if v > 1:
        order = interleaved_layer_order(d_p_old, L_s_old, v)
        inv = np.empty_like(order)
        inv[order] = np.arange(order.size)
        flat = flat[inv]
    flat = flat[:n_layers]
    pad = new_dp * new_ls - n_layers
    if pad:
        flat = np.concatenate(
            [flat, np.zeros((pad, *flat.shape[1:]), flat.dtype)])
    if v > 1:
        flat = flat[interleaved_layer_order(new_dp, new_ls, v)]
    return flat.reshape(new_dp, new_ls, *flat.shape[1:])


def unstack_stages(layers_tree, n_layers: int, v: int = 1):
    def _re(x):
        flat = x.reshape(x.shape[0] * x.shape[1], *x.shape[2:])
        if v > 1:
            import numpy as np
            order = interleaved_layer_order(x.shape[0], x.shape[1], v)
            inv = np.empty_like(order)
            inv[order] = np.arange(order.size)
            flat = flat[inv]
        return flat[:n_layers]
    return jax.tree.map(_re, layers_tree)


def _lookup_path(tree, path: str):
    node = tree
    for key in path.split("/"):
        node = node[key]
    return node


def gather_params(tree, shard_dims, axis: str, *, dim_offset: int):
    """ZeRO-3: materialize full parameters from "model" shards.

    ``shard_dims`` is the precomputed tree of gather dims in FULL-shape
    coordinates (including the [d_p, L_s] stacking prefix); ``dim_offset``
    subtracts the prefix dims already stripped from ``tree``'s leaves
    (2 for a single layer's tree, 1 for a whole stage's [L_s, ...] tree).
    EP leaves carry a marker dim but stay sharded (expert parallelism),
    which :data:`EP_PATH_RE` expresses by pointing at the expert dim; the
    path check below skips them.
    """
    def _g(path, leaf):
        if EP_PATH_RE.search(path):
            return leaf
        zd = _lookup_path(shard_dims, path)
        if zd is None:
            return leaf
        return jax.lax.all_gather(leaf, axis, axis=zd - dim_offset,
                                  tiled=True)
    return tree_paths_map(_g, tree)


def gather_layer_params(lp, shard_dims, axis: str):
    """ZeRO-3 'per_tick' mode: gather one layer's full parameters."""
    return gather_params(lp, shard_dims, axis, dim_offset=2)


def gather_stage_params(stage_params, shard_dims, axis: str):
    """ZeRO-3 'per_step' mode: gather the whole stage's stacked [L_s, ...]
    tree once; leaves keep their L_s dim so the gather axis is zd - 1."""
    return gather_params(stage_params, shard_dims, axis, dim_offset=1)


def zero3_dim(path: str, shape: Tuple[int, ...], d_s: int,
              first_dim: int = 2) -> Optional[int]:
    """Pick the ZeRO-3 shard dim for a stage-stacked leaf [d_p, L_s, ...]:
    the FIRST trailing dim divisible by d_s (None => replicated). Must be
    called with FULL (unsharded) shapes — the executor receives the chosen
    dims precomputed (shard_dim_tree) so local views can't disagree."""
    if EP_PATH_RE.search(path):
        return first_dim  # expert dim ([d_p, L_s, E, ...])
    for d in range(first_dim, len(shape)):
        if shape[d] % d_s == 0:
            return d
    return None


def shard_dim_tree(stacked_tree, d_s: int):
    """Pytree (same structure) of Optional[int] ZeRO gather dims, computed
    from full stacked shapes."""
    return tree_paths_map(
        lambda path, leaf: zero3_dim(path, leaf.shape, d_s), stacked_tree)


def stage_param_specs(stacked_tree, d_s: int, *, pod: Optional[str],
                      data: str = "data", model: str = "model"):
    """PartitionSpec tree for stage-stacked layer params."""
    def _spec(path: str, leaf) -> P:
        dims: List[Optional[str]] = [None] * leaf.ndim
        dims[0] = data
        zd = zero3_dim(path, leaf.shape, d_s)
        if zd is not None:
            dims[zd] = model
        return P(*dims)
    return tree_paths_map(_spec, stacked_tree)


def head_param_specs(head_tree, d_s: int, *, model: str = "model"):
    """Embed / unembed / final_norm: vocab (dim 0) or feature sharding."""
    def _spec(path: str, leaf) -> P:
        if leaf.ndim >= 2:          # [V, D] embed/unembed
            return P(model, *([None] * (leaf.ndim - 1)))
        if leaf.shape and leaf.shape[0] % d_s == 0:
            return P(model)
        return P()
    return tree_paths_map(_spec, head_tree)


def batch_specs(batch_tree, *, pod: Optional[str], model: str = "model",
                replicated: Tuple[str, ...] = ()):
    """Chunked batch arrays [(pods,) n_chunks, cap, ...]: chunk dim over pod
    (if present), token dim over model. Leaves whose key path matches a name
    in ``replicated`` stay fully replicated over the model axis — the
    serving engine's per-token page table is one: every rank gathers cache
    pages it owns for ALL tokens of the step, so it needs the whole table."""
    def _spec(path: str, leaf) -> P:
        dims: List[Optional[str]] = [None] * leaf.ndim
        i = 0
        if pod is not None:
            dims[0] = pod
            i = 1
        if path in replicated:
            return P(*dims)
        if leaf.ndim > i + 1:
            dims[i + 1] = model   # token/capacity dim
        return P(*dims)
    return tree_paths_map(_spec, batch_tree)
