"""Disk-backed persistence for the plan-bucket compile cache.

The in-memory :class:`~repro.runtime.compile_cache.CompileCache` only pays
off while the process lives: every restart — a crash, a multi-run sweep,
and especially the elastic shrink/grow flow the paper motivates —
recompiles every plan bucket from scratch, and recompilation dominates
bootstrap cost for variable-length workloads that touch many buckets.
This module persists compiled executables across restarts via JAX AOT
(``jit(...).lower(...).compile()`` + ``jax.experimental
.serialize_executable``), so a restarted run warm-starts every bucket
whose environment survived the restart and cold-compiles only the rest.

Store layout (one directory, flat, one entry per (key, fingerprint)):

    <cache_dir>/
      <key_hash>__<fp_hash>.bin        pickle of (payload, in_tree,
                                       out_tree) as returned by
                                       serialize_executable.serialize()
      <key_hash>__<fp_hash>.meta.json  sidecar: the full fingerprint dict,
                                       repr(key), compile_seconds of the
                                       original build, payload sha256 +
                                       byte size, created timestamp

``key_hash`` is a sha256 over ``repr(key)`` — any hashable/repr-stable
key works (``ExecutionPlan.bucket_key()`` NamedTuples, decode geometry
tuples, dry-run cell tuples). ``fp_hash`` hashes the fingerprint dict:
entries for the SAME bucket under DIFFERENT topologies coexist (the
elastic shrink/grow flow writes both; growing back finds the original
entry intact).

Invalidation rules — a stale entry is SKIPPED, never loaded wrong:

* **fingerprint mismatch**: every entry records the store's fingerprint
  (mesh axes+shape, device count, backend platform, jax version, ModelSpec
  hash, compute dtype — see :func:`store_fingerprint`). ``load`` compares
  the entry's recorded fingerprint against the current store's, field by
  field; any difference (e.g. the elastic demo's mesh change) counts as a
  ``stale_skips`` and falls back to cold compile.
* **corruption**: the sidecar records the payload's sha256; a truncated
  or bit-flipped blob (and any deserialization error) counts as a
  ``corrupt_skips`` and falls back to cold compile.
* a ``.bin`` without a readable sidecar (or vice versa) is ignored.

Writes are atomic (tmp file + ``os.replace``) so a crash mid-save leaves
no half-written entry that a later run could trip over.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pickle
import time
from pathlib import Path
from typing import Any, Callable, Dict, Hashable, List, Optional

__all__ = ["CacheStore", "StoreStats", "model_fingerprint",
           "store_fingerprint"]

_FORMAT_VERSION = 1


def model_fingerprint(spec) -> str:
    """Stable hash of a :class:`~repro.core.plan.ModelSpec` (or any
    dataclass): two runs agree iff every architecture field agrees."""
    if dataclasses.is_dataclass(spec):
        d = dataclasses.asdict(spec)
    elif isinstance(spec, dict):
        d = spec
    else:
        d = {"repr": repr(spec)}
    blob = json.dumps(d, sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def store_fingerprint(mesh=None, *, spec=None, compute_dtype=None,
                      extra: Optional[Dict[str, Any]] = None
                      ) -> Dict[str, Any]:
    """The topology/config fingerprint a store entry must match to load.

    Captures everything that changes the compiled HLO *outside* the bucket
    key: mesh axes and shape (an elastic reshard invalidates), total device
    count, backend platform, jax/jaxlib versions (XLA output is not stable
    across releases), the ModelSpec hash and the compute dtype.
    """
    import jax

    fp: Dict[str, Any] = {
        "format": _FORMAT_VERSION,
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "n_devices": jax.device_count(),
    }
    try:
        import jaxlib
        fp["jaxlib"] = jaxlib.__version__
    except Exception:  # pragma: no cover - jaxlib always ships with jax
        fp["jaxlib"] = "unknown"
    if mesh is not None:
        fp["mesh"] = [[str(name), int(size)]
                      for name, size in mesh.shape.items()]
    if spec is not None:
        fp["spec"] = model_fingerprint(spec)
    if compute_dtype is not None:
        import numpy as np
        try:
            fp["compute_dtype"] = np.dtype(compute_dtype).name
        except TypeError:
            fp["compute_dtype"] = repr(compute_dtype)
    if extra:
        fp.update(extra)
    return fp


@dataclasses.dataclass
class StoreStats:
    loads: int = 0           # successful warm loads
    saves: int = 0           # entries written
    stale_skips: int = 0     # fingerprint mismatch -> cold compile
    corrupt_skips: int = 0   # bad sha / unreadable blob -> cold compile
    load_errors: int = 0     # deserialize raised -> cold compile
    save_errors: int = 0     # artifact not serializable / IO error
    gc_removed: int = 0      # entries evicted by gc()
    gc_removed_bytes: int = 0

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


class CacheStore:
    """Persistent bucket-key -> serialized-executable store.

    Implements the two-method protocol ``CompileCache`` expects from its
    ``store=`` backend:

    * ``load(key) -> artifact | None`` — ``None`` means "cold compile"
      (missing, stale fingerprint, corrupted, or failed to deserialize);
    * ``save(key, artifact, compile_seconds=...)`` — best-effort; an
      artifact that is not a serializable ``jax.stages.Compiled`` (or a
      full disk) degrades to a no-op, never an exception.
    """

    def __init__(self, directory: str | Path,
                 fingerprint: Optional[Dict[str, Any]] = None, *,
                 log: Optional[Callable[[str], None]] = None):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        # canonicalize to JSON-native form ONCE: load() compares the
        # in-memory fingerprint against one that round-tripped through the
        # sidecar, so tuples must already be lists and exotic values
        # strings — otherwise every entry reads as permanently stale
        self.fingerprint: Dict[str, Any] = json.loads(
            json.dumps(dict(fingerprint or {}), sort_keys=True,
                       default=str))
        self.fp_hash = hashlib.sha256(
            json.dumps(self.fingerprint, sort_keys=True).encode()
        ).hexdigest()[:12]
        self.log = log
        self.stats = StoreStats()

    # ------------------------------------------------------------------
    @staticmethod
    def _key_hash(key: Hashable) -> str:
        return hashlib.sha256(repr(key).encode()).hexdigest()[:24]

    def _paths(self, key: Hashable) -> tuple[Path, Path]:
        h = f"{self._key_hash(key)}__{self.fp_hash}"
        return self.dir / f"{h}.bin", self.dir / f"{h}.meta.json"

    def _say(self, msg: str) -> None:
        if self.log:
            self.log(msg)

    # ------------------------------------------------------------------
    def load(self, key: Hashable) -> Optional[Any]:
        """Deserialize the entry for ``key``; None on any reason to cold
        compile (missing / stale fingerprint / corrupt / load failure)."""
        bin_path, meta_path = self._paths(key)
        if not (bin_path.exists() and meta_path.exists()):
            # entry persisted under a DIFFERENT topology/config only:
            # observable as a stale skip (the elastic shrink sees phase
            # 1's buckets but must not load them)
            if any(self.dir.glob(f"{self._key_hash(key)}__*.bin")):
                self.stats.stale_skips += 1
                self._say(f"[cache-store] stale fingerprint for {key} "
                          f"(topology/config changed) — cold compile")
            return None
        try:
            meta = json.loads(meta_path.read_text())
        except (OSError, json.JSONDecodeError):
            self.stats.corrupt_skips += 1
            self._say(f"[cache-store] unreadable sidecar for {key} — "
                      f"cold compile")
            return None
        if meta.get("fingerprint") != self.fingerprint:
            self.stats.stale_skips += 1
            self._say(f"[cache-store] stale fingerprint for {key} "
                      f"(topology/config changed) — cold compile")
            return None
        try:
            blob = bin_path.read_bytes()
        except OSError:
            self.stats.corrupt_skips += 1
            return None
        if (meta.get("payload_sha") !=
                hashlib.sha256(blob).hexdigest()):
            self.stats.corrupt_skips += 1
            self._say(f"[cache-store] corrupted payload for {key} — "
                      f"cold compile")
            return None
        try:
            from jax.experimental import serialize_executable
            payload, in_tree, out_tree = pickle.loads(blob)
            compiled = serialize_executable.deserialize_and_load(
                payload, in_tree, out_tree)
        except Exception as e:  # noqa: BLE001 - any failure => cold compile
            self.stats.load_errors += 1
            self._say(f"[cache-store] deserialize failed for {key}: "
                      f"{e!r} — cold compile")
            return None
        self.stats.loads += 1
        try:
            # recency marker for gc(): least-recently-LOADED entries are
            # evicted first, not just least-recently-written ones
            os.utime(bin_path)
        except OSError:  # pragma: no cover - touch is best-effort
            pass
        return compiled

    # ------------------------------------------------------------------
    def save(self, key: Hashable, compiled: Any, *,
             compile_seconds: float = 0.0) -> bool:
        """Serialize ``compiled`` (a ``jax.stages.Compiled``) under
        ``key``. Best-effort: returns False instead of raising when the
        artifact cannot be serialized."""
        if compiled is None:
            return False
        try:
            from jax.experimental import serialize_executable
            payload, in_tree, out_tree = serialize_executable.serialize(
                compiled)
            blob = pickle.dumps((payload, in_tree, out_tree))
        except Exception as e:  # noqa: BLE001 - jit fns, plain values, ...
            self.stats.save_errors += 1
            self._say(f"[cache-store] cannot serialize {key}: {e!r}")
            return False
        meta = {
            "fingerprint": self.fingerprint,
            "key": repr(key),
            "compile_seconds": round(float(compile_seconds), 3),
            "payload_sha": hashlib.sha256(blob).hexdigest(),
            "payload_bytes": len(blob),
            "created": time.time(),
        }
        bin_path, meta_path = self._paths(key)
        try:
            # sidecar FIRST: a crash in between leaves an orphan meta
            # (load() sees no .bin => plain miss), never an orphan .bin
            # that would count as a misleading stale skip
            self._atomic_write(meta_path,
                               json.dumps(meta, indent=1).encode())
            self._atomic_write(bin_path, blob)
        except Exception as e:  # noqa: BLE001 - save is best-effort
            self.stats.save_errors += 1
            self._say(f"[cache-store] write failed for {key}: {e!r}")
            return False
        self.stats.saves += 1
        self._say(f"[cache-store] saved bucket {key} "
                  f"({len(blob) / 1e6:.2f} MB)")
        return True

    @staticmethod
    def _atomic_write(path: Path, data: bytes) -> None:
        tmp = path.with_name(path.name + f".tmp{os.getpid()}")
        tmp.write_bytes(data)
        os.replace(tmp, path)

    # ------------------------------------------------------------------
    def gc(self, max_age_s: Optional[float] = None,
           max_bytes: Optional[int] = None) -> Dict[str, Any]:
        """Evict stale bulk from the store: entries not loaded (or written)
        within ``max_age_s`` are removed, then — oldest first — entries are
        removed until the payload total fits ``max_bytes``. ``load()``
        touches an entry's mtime, so "oldest" means least-recently-USED,
        not least-recently-written. ``None`` disables the corresponding
        limit (gc(None, None) is a no-op). Train/serve call this at
        startup; removal order is fingerprint-blind — a topology nobody
        runs anymore ages out like any other entry.

        Deletion removes the ``.bin`` before its sidecar: a crash in
        between leaves an orphan sidecar, which ``load()`` treats as a
        plain miss (never a false stale/corrupt signal)."""
        ents = []
        for bin_path in self.dir.glob("*.bin"):
            try:
                st = bin_path.stat()
            except OSError:
                continue
            ents.append((st.st_mtime, st.st_size, bin_path))
        ents.sort()
        now = time.time()
        removed = removed_bytes = 0
        kept: List[tuple] = []
        for mtime, size, p in ents:
            if max_age_s is not None and now - mtime > max_age_s:
                if self._remove_entry(p):
                    removed += 1
                    removed_bytes += size
            else:
                kept.append((mtime, size, p))
        if max_bytes is not None:
            total = sum(sz for _, sz, _ in kept)
            for mtime, size, p in kept:
                if total <= max_bytes:
                    break
                if self._remove_entry(p):
                    removed += 1
                    removed_bytes += size
                    total -= size
        self.stats.gc_removed += removed
        self.stats.gc_removed_bytes += removed_bytes
        out = {"removed": removed, "removed_bytes": removed_bytes,
               "remaining_bytes": self.size_bytes()}
        if removed:
            self._say(f"[cache-store] gc removed {removed} entries "
                      f"({removed_bytes / 1e6:.2f} MB), "
                      f"{out['remaining_bytes'] / 1e6:.2f} MB remain")
        return out

    def _remove_entry(self, bin_path: Path) -> bool:
        meta_path = bin_path.with_name(
            bin_path.name[:-len(".bin")] + ".meta.json")
        try:
            bin_path.unlink()
        except OSError:
            return False
        try:
            meta_path.unlink()
        except OSError:  # orphan sidecar == plain miss; harmless
            pass
        return True

    # ------------------------------------------------------------------
    def entries(self) -> List[Dict[str, Any]]:
        """Sidecar metadata of every well-formed entry (any fingerprint)."""
        out = []
        for meta_path in sorted(self.dir.glob("*.meta.json")):
            bin_path = meta_path.with_name(
                meta_path.name[:-len(".meta.json")] + ".bin")
            if not bin_path.exists():
                continue
            try:
                out.append(json.loads(meta_path.read_text()))
            except (OSError, json.JSONDecodeError):
                continue
        return out

    def size_bytes(self) -> int:
        return sum(p.stat().st_size for p in self.dir.glob("*.bin"))

    def audit(self) -> List[Dict[str, Any]]:
        """Offline integrity/metadata audit of every on-disk entry — the
        substrate ``python -m repro.lint --cache-dir`` reports over.

        Unlike :meth:`load` this is fingerprint-blind and jax-free: it
        never deserializes an executable, only cross-checks each sidecar
        against its payload. Per entry: the sidecar's recorded
        ``payload_sha``/``payload_bytes`` against the actual ``.bin``
        bytes, required metadata fields, and whether the entry matches
        THIS store's fingerprint (a stale entry is not a finding — gc
        handles age — but a corrupt or truncated one is)."""
        required = ("fingerprint", "key", "payload_sha", "payload_bytes")
        out: List[Dict[str, Any]] = []
        for meta_path in sorted(self.dir.glob("*.meta.json")):
            bin_path = meta_path.with_name(
                meta_path.name[:-len(".meta.json")] + ".bin")
            row: Dict[str, Any] = {"entry": meta_path.name, "problems": []}
            try:
                meta = json.loads(meta_path.read_text())
            except (OSError, json.JSONDecodeError) as e:
                row["problems"].append(f"unreadable sidecar: {e}")
                out.append(row)
                continue
            row["key"] = meta.get("key", "?")
            for f in required:
                if f not in meta:
                    row["problems"].append(f"sidecar missing field {f!r}")
            if not bin_path.exists():
                row["problems"].append("orphan sidecar (no payload .bin)")
                out.append(row)
                continue
            try:
                blob = bin_path.read_bytes()
            except OSError as e:
                row["problems"].append(f"unreadable payload: {e}")
                out.append(row)
                continue
            if "payload_bytes" in meta and len(blob) != meta["payload_bytes"]:
                row["problems"].append(
                    f"payload is {len(blob)} bytes, sidecar recorded "
                    f"{meta['payload_bytes']} (truncated write?)")
            if "payload_sha" in meta:
                sha = hashlib.sha256(blob).hexdigest()
                if sha != meta["payload_sha"]:
                    row["problems"].append(
                        "payload sha256 mismatch (corrupt entry; load() "
                        "would skip it)")
            row["stale"] = meta.get("fingerprint") != self.fingerprint
            out.append(row)
        return out

    def report(self) -> Dict[str, Any]:
        """The block the train log / benchmarks JSON surface per store."""
        entries = self.entries()
        fresh = sum(1 for e in entries
                    if e.get("fingerprint") == self.fingerprint)
        return {
            "dir": str(self.dir),
            "entries": len(entries),
            "entries_current_fingerprint": fresh,
            "size_bytes": self.size_bytes(),
            **self.stats.as_dict(),
        }
