"""Serving: pipelined decode (one new token against a KV cache) and
prefill (the forward pipeline whose context carry IS the cache).

Decode maps the assignment's decode_32k / long_500k shapes:

* the per-pod request batch splits into ``d_p`` microbatches that flow
  through the stage pipeline exactly like training chunks (ppermute ticks),
  so all stages stay busy — pipelined decode;
* the KV cache is sharded: stage dim over "data", sequence dim over
  "model"; decode attention is *flash-decode*: every "model" rank scores
  its local cache rows and the partial (m, l, acc) merge with a psum-LSE
  (works for any head count — kv=1 MQA included);
* the new token's KV row is written by the rank owning position
  ``cache_len``; SSM archs carry (h, conv_tail) instead — O(1) state;
* sliding-window layers (gemma3) mask rows outside the window (the cache
  is allocated full-length for shape uniformity; ring-buffer compaction is
  a recorded hillclimb lever — EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.models import DecoderLM
from repro.models.attention import mla_expand_ctx, project_qkv
from repro.models.config import ArchConfig, LayerKind
from repro.models.layers import rms_norm, swiglu_apply
from repro.models.ssm import dt_rank_of

from . import executor, sp
from .program import StageProgram
from .sharding import (gather_layer_params, mesh_axis_names, shard_dim_tree,
                       shard_map_compat)
from .train_step import param_pspecs, prepare_params

__all__ = ["DecodeGeometry", "decode_step_fn", "decode_state_struct",
           "DecodeStepBuilder",
           "EngineGeometry", "EngineStepBuilder", "make_engine_geometry",
           "engine_step_fn", "engine_pool_struct", "engine_pool_specs",
           "engine_batch_struct", "engine_copy_fn", "engine_copy_struct"]


def _dtype_name(dtype) -> str:
    """Canonical dtype string for bucket keys (the compiled HLO differs
    per compute dtype, so keys must too)."""
    import numpy as _np
    return _np.dtype(dtype).name


def _layer_tables(cfg: ArchConfig, d_p: int, L_s: int):
    """Per-stage ``[d_p, L_s]`` sliding-window sizes + active-layer mask
    (padded layer slots inactive) — shared by the decode and engine step
    builders so window/padding semantics can never diverge between the
    two serve paths."""
    import numpy as _np
    L_pad = d_p * L_s
    win = [cfg.layer_window(i) for i in range(cfg.spec.n_layers)]
    win += [0] * (L_pad - cfg.spec.n_layers)
    windows = jnp.asarray(win, jnp.int32).reshape(d_p, L_s)
    active = jnp.asarray(
        (_np.arange(L_pad) < cfg.spec.n_layers).reshape(d_p, L_s))
    return windows, active


@dataclass(frozen=True)
class DecodeGeometry:
    batch_per_pod: int
    cache_len: int                # S: current context size (static per bucket)
    d_p: int
    d_s: int
    layers_per_stage: int
    n_micro: int                  # microbatches (== d_p unless batch < d_p)
    compute_dtype: Any = jnp.bfloat16

    @property
    def bm(self) -> int:
        return max(1, self.batch_per_pod // self.n_micro)

    @property
    def s_cap(self) -> int:
        """Cache capacity: one extra row per shard so the new token's KV
        always has a home (position ``cache_len`` is written this step)."""
        return self.cache_len + self.d_s

    @property
    def s_loc(self) -> int:
        return self.s_cap // self.d_s

    @property
    def dtype_name(self) -> str:
        return _dtype_name(self.compute_dtype)


def make_decode_geometry(cfg: ArchConfig, mesh: Mesh, *, batch_per_pod: int,
                         cache_len: int,
                         compute_dtype=jnp.bfloat16) -> DecodeGeometry:
    pod, data, model = mesh_axis_names(mesh)
    d_p, d_s = mesh.shape[data], mesh.shape[model]
    n_micro = min(d_p, max(1, batch_per_pod))
    return DecodeGeometry(
        batch_per_pod=batch_per_pod, cache_len=cache_len, d_p=d_p, d_s=d_s,
        layers_per_stage=-(-cfg.spec.n_layers // d_p), n_micro=n_micro,
        compute_dtype=compute_dtype)


def decode_state_struct(cfg: ArchConfig, geom: DecodeGeometry,
                        n_pods: int) -> Dict:
    """Global ShapeDtypeStructs for the serving state (cache etc.)."""
    s = cfg.spec
    lead = (n_pods,) if n_pods > 1 else ()
    L_s, nm, bm = geom.layers_per_stage, geom.n_micro, geom.bm
    dt = geom.compute_dtype
    out: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((*lead, nm, bm), jnp.int32),
    }
    if s.is_encoder_decoder:
        # stub memory: 1/4 of the decode context worth of encoder frames
        s_mem = max(geom.d_s, (geom.cache_len // 4) // geom.d_s * geom.d_s)
        out["memory"] = jax.ShapeDtypeStruct(
            (*lead, nm, bm, s_mem, s.d_model), dt)
    if not s.attn_free:
        if s.kv_lora_rank > 0:
            row = (s.kv_lora_rank + s.qk_rope_dim,)
            out["cache_k"] = jax.ShapeDtypeStruct(
                (*lead, geom.d_p, nm, L_s, bm, geom.s_cap, 1, *row), dt)
        else:
            out["cache_k"] = jax.ShapeDtypeStruct(
                (*lead, geom.d_p, nm, L_s, bm, geom.s_cap,
                 s.n_kv_heads, s.head_dim), dt)
            out["cache_v"] = jax.ShapeDtypeStruct(out["cache_k"].shape, dt)
    if s.ssm_state > 0:
        out["ssm_h"] = jax.ShapeDtypeStruct(
            (*lead, geom.d_p, nm, L_s, bm, s.inner, s.ssm_state), jnp.float32)
        out["conv_tail"] = jax.ShapeDtypeStruct(
            (*lead, geom.d_p, nm, L_s, bm, s.ssm_conv - 1, s.inner), dt)
    return out


def decode_state_specs(cfg: ArchConfig, geom: DecodeGeometry, *,
                       pod: Optional[str], data: str, model: str) -> Dict:
    s = cfg.spec
    lead = (pod,) if pod else ()
    out: Dict[str, Any] = {"tokens": P(*lead, None, None)}
    if s.is_encoder_decoder:
        # cross-attention memory [.., nm, bm, S_mem, D]: frames over model
        out["memory"] = P(*lead, None, None, model, None)
    if not s.attn_free:
        # [.., d_p, nm, L_s, bm, S, Hkv, Dh]: stage over data, seq over model
        out["cache_k"] = P(*lead, data, None, None, None, model, None, None)
        if s.kv_lora_rank == 0:
            out["cache_v"] = out["cache_k"]
    if s.ssm_state > 0:
        # channel dim over model
        out["ssm_h"] = P(*lead, data, None, None, None, model, None)
        out["conv_tail"] = P(*lead, data, None, None, None, None, model)
    return out


# ---------------------------------------------------------------------------
# Flash-decode attention (sequence-sharded cache, LSE merge over "model").
# ---------------------------------------------------------------------------

def _flash_decode(q, k_loc, v_loc, *, valid_rows, scale, model_axis):
    """q: [Bm, Hq, Dh]; k/v_loc: [Bm, S_loc, Hkv(+), Dh]; valid_rows:
    [S_loc] bool. Returns [Bm, Hq, Dv]."""
    Hq = q.shape[1]
    Hkv = k_loc.shape[2]
    if Hkv != Hq:
        rep = Hq // Hkv
        k_loc = jnp.repeat(k_loc, rep, axis=2)
        v_loc = jnp.repeat(v_loc, rep, axis=2)
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                   k_loc.astype(jnp.float32)) * scale
    s = jnp.where(valid_rows[None, None, :], s, -1e30)
    m = s.max(axis=-1)
    m_g = jax.lax.pmax(m, model_axis)
    p = jnp.exp(s - m_g[..., None])
    l = jax.lax.psum(p.sum(axis=-1), model_axis)
    acc = jnp.einsum("bhs,bshd->bhd", p, v_loc.astype(jnp.float32))
    acc = jax.lax.psum(acc, model_axis)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return jnp.where(l[..., None] > 0, out, 0.0).astype(q.dtype)


def decode_step_fn(cfg: ArchConfig, geom: DecodeGeometry, shard_dims, *,
                   pod_axis: Optional[str], data_axis: str = "data",
                   model_axis: str = "model") -> Callable:
    """Returns step_local(params, state) -> (next_ids [nm, bm], new state);
    call inside shard_map."""
    s = cfg.spec
    L_s, d_p, d_s = geom.layers_per_stage, geom.d_p, geom.d_s
    nm, bm = geom.n_micro, geom.bm
    dt = geom.compute_dtype
    S, S_loc = geom.cache_len, geom.s_loc
    windows_all, active_all = _layer_tables(cfg, d_p, L_s)
    scale = 1.0 / math.sqrt(s.head_dim + (s.qk_rope_dim if s.kv_lora_rank
                                          else 0)) if not s.attn_free else 0.0

    moe_fn = None
    if s.n_experts > 0:
        from .ep import make_moe_ep
        moe_fn = make_moe_ep(model_axis, d_s)

    def _attn_decode(lp, h, cache_k_l, cache_v_l, window):
        """One microbatch, one layer. h: [bm, D]."""
        pos = jnp.full((bm,), S, jnp.int32)
        q, k_new, v_new = project_qkv(cfg, lp, h, pos)
        # write the new row into the shard owning position S
        shard_off = jax.lax.axis_index(model_axis) * S_loc
        loc = S - shard_off
        ok = (loc >= 0) & (loc < S_loc)
        locc = jnp.clip(loc, 0, S_loc - 1)
        upd_k = jax.lax.dynamic_update_slice_in_dim(
            cache_k_l, k_new[:, None].astype(cache_k_l.dtype), locc, axis=1)
        cache_k_l = jnp.where(ok, upd_k, cache_k_l)
        if cache_v_l is not None:
            upd_v = jax.lax.dynamic_update_slice_in_dim(
                cache_v_l, v_new[:, None].astype(cache_v_l.dtype), locc,
                axis=1)
            cache_v_l = jnp.where(ok, upd_v, cache_v_l)
        rows = shard_off + jnp.arange(S_loc)
        valid = rows <= S
        big = jnp.int32(2 ** 30)
        w = jnp.where(window > 0, window, big)
        valid &= (S - rows) < w
        if s.kv_lora_rank > 0:
            kk, vv = jax.vmap(
                lambda c: mla_expand_ctx(cfg, lp, c))(cache_k_l)
            out = _flash_decode(q, kk, vv, valid_rows=valid, scale=scale,
                                model_axis=model_axis)
            out = out[..., :s.head_dim]
        else:
            out = _flash_decode(q, cache_k_l, cache_v_l, valid_rows=valid,
                                scale=scale, model_axis=model_axis)
        y = jnp.einsum("bh,hd->bd", out.reshape(bm, -1),
                       lp["wo"].astype(h.dtype))
        return y, cache_k_l, cache_v_l

    def _mamba_decode(lp, h, h_state, tail):
        """One-step SSM update; channels sharded over model.

        h: [bm, D]; h_state: [bm, di_loc, ds]; tail: [bm, K-1, di_loc].
        in/out projections are ZeRO-gathered full, so slice the local
        channel block."""
        di = s.inner
        di_loc = h_state.shape[-2]
        c_off = jax.lax.axis_index(model_axis) * di_loc
        dtr = dt_rank_of(cfg)
        xz = jnp.einsum("bd,dh->bh", h, lp["in_proj"].astype(h.dtype))
        xs_f, z_f = xz[:, :di], xz[:, di:]
        xs = jax.lax.dynamic_slice_in_dim(xs_f, c_off, di_loc, axis=1)
        z = jax.lax.dynamic_slice_in_dim(z_f, c_off, di_loc, axis=1)
        conv_w = jax.lax.dynamic_slice_in_dim(lp["conv_w"], c_off, di_loc, 1)
        conv_b = jax.lax.dynamic_slice_in_dim(lp["conv_b"], c_off, di_loc, 0)
        window = jnp.concatenate([tail, xs[:, None, :]], axis=1)  # [bm,K,dl]
        xc = jnp.einsum("bkd,kd->bd", window.astype(jnp.float32),
                        conv_w.astype(jnp.float32)) + conv_b.astype(jnp.float32)
        xc = jax.nn.silu(xc).astype(h.dtype)
        # x_proj/dt act on the full channel dim; gather local xc
        xc_full = jax.lax.all_gather(xc, model_axis, axis=1, tiled=True)
        proj = jnp.einsum("bd,dh->bh", xc_full, lp["x_proj"].astype(h.dtype))
        delta_in = proj[:, :dtr]
        Bv = proj[:, dtr:dtr + s.ssm_state].astype(jnp.float32)
        Cv = proj[:, dtr + s.ssm_state:dtr + 2 * s.ssm_state].astype(jnp.float32)
        delta_f = jax.nn.softplus(
            jnp.einsum("br,rd->bd", delta_in,
                       lp["dt_proj"].astype(h.dtype)).astype(jnp.float32)
            + lp["dt_bias"].astype(jnp.float32))
        delta = jax.lax.dynamic_slice_in_dim(delta_f, c_off, di_loc, axis=1)
        A = -jnp.exp(jax.lax.dynamic_slice_in_dim(
            lp["a_log"].astype(jnp.float32), c_off, di_loc, 0))
        a = jnp.exp(delta[..., None] * A[None])
        bx = (delta * xc.astype(jnp.float32))[..., None] * Bv[:, None, :]
        h_new = a * h_state + bx
        y = jnp.einsum("bds,bs->bd", h_new, Cv)
        dskip = jax.lax.dynamic_slice_in_dim(
            lp["d_skip"].astype(jnp.float32), c_off, di_loc, 0)
        y = y + dskip[None] * xc.astype(jnp.float32)
        y = y * jax.nn.silu(z.astype(jnp.float32))
        y_full = jax.lax.all_gather(y.astype(h.dtype), model_axis, axis=1,
                                    tiled=True)
        out = jnp.einsum("bd,dh->bh", y_full, lp["out_proj"].astype(h.dtype))
        new_tail = window[:, 1:, :].astype(tail.dtype)
        return out, h_new, new_tail

    def _cross_decode(lp, h, mem):
        """Cross-attention for one decode token per sequence.
        h: [bm, D]; mem: [bm, S_mem_loc, D] (frames sharded over model)."""
        Dh, Hq, Hkv = s.head_dim, s.n_heads, s.n_kv_heads
        dtl = h.dtype
        q = jnp.einsum("bd,dh->bh", h, lp["wq"].astype(dtl)
                       ).reshape(bm, Hq, Dh)
        k = jnp.einsum("bsd,dh->bsh", mem, lp["wk"].astype(dtl)
                       ).reshape(bm, -1, Hkv, Dh)
        v = jnp.einsum("bsd,dh->bsh", mem, lp["wv"].astype(dtl)
                       ).reshape(bm, -1, Hkv, Dh)
        valid = jnp.ones((k.shape[1],), bool)
        out = _flash_decode(q, k, v, valid_rows=valid,
                            scale=1.0 / math.sqrt(Dh),
                            model_axis=model_axis)
        return jnp.einsum("bh,hd->bd", out.reshape(bm, -1),
                          lp["wo"].astype(dtl))

    def step_local(params, state):
        p_idx = jax.lax.axis_index(data_axis)
        stage_params = jax.tree.map(lambda x: x[0], params["stages"])
        windows = windows_all[p_idx]
        active = active_all[p_idx]
        fn_gamma = params["final_norm"]
        if fn_gamma.shape[0] != s.d_model:
            fn_gamma = jax.lax.all_gather(fn_gamma, model_axis, axis=0,
                                          tiled=True)
        head_w = params.get("unembed", params["embed"])
        tokens = state["tokens"].reshape(nm, bm)  # lead dims are 1

        n_lead = (1 if pod_axis else 0) + 1  # (pod) + stage dims

        def sq(name):
            if name not in state:
                return None
            a = state[name]
            return a.reshape(a.shape[n_lead:]) if a is not None else None

        memory = None
        if "memory" in state:
            a = state["memory"]
            memory = a.reshape(a.shape[-(4):]) if pod_axis is None \
                else a.reshape(a.shape[-4:])
        cache_k = sq("cache_k")      # [nm, L_s, bm, S_loc, Hkv, Dh]
        cache_v = sq("cache_v")
        ssm_h = sq("ssm_h")          # [nm, L_s, bm, di_loc, ds]
        conv_tail = sq("conv_tail")

        def tick(tc, x_recv, state, out_ids):
            ck, cv, hh, tl = state
            idxc, valid = tc.idxc, tc.valid
            tok = tokens[idxc]
            x_emb = sp.sharded_embed(params["embed"], tok, model_axis, dt)
            if cfg.embed_scale:
                x_emb = x_emb * jnp.asarray(s.d_model ** 0.5, dt)
            x = jnp.where(tc.is_first_stage, x_emb, x_recv)

            new_ck, new_cv = ck, cv
            new_hh, new_tl = hh, tl
            for l in range(L_s):
                lp = gather_layer_params(
                    jax.tree.map(lambda a: a[l], stage_params),
                    shard_dims, model_axis)
                act = active[l]
                h_in = rms_norm(x, lp["ln1"], cfg.rms_eps)
                mix = jnp.zeros_like(x)
                if not s.attn_free and "attn" in lp:
                    ckl = ck[idxc, l] if ck is not None else None
                    cvl = cv[idxc, l] if cv is not None else None
                    y, ckl2, cvl2 = _attn_decode(lp["attn"], h_in, ckl, cvl,
                                                 windows[l])
                    if s.is_encoder_decoder and "cross" in lp:
                        hx = rms_norm(x + y, lp["ln_x"], cfg.rms_eps)
                        y = y + _cross_decode(lp["cross"], hx,
                                              memory[idxc])
                    if ck is not None:
                        new_ck = new_ck.at[idxc, l].set(
                            jnp.where(act & valid, ckl2, ckl))
                    if cv is not None:
                        new_cv = new_cv.at[idxc, l].set(
                            jnp.where(act & valid, cvl2, cvl))
                if s.ssm_state > 0:
                    y2, hh2, tl2 = _mamba_decode(lp["mamba"], h_in,
                                                 hh[idxc, l], tl[idxc, l])
                    if cfg.layer_kind == LayerKind.HYBRID:
                        mix = 0.5 * (mix + y2)
                    else:
                        mix = y2
                    new_hh = new_hh.at[idxc, l].set(
                        jnp.where(act & valid, hh2, hh[idxc, l]))
                    new_tl = new_tl.at[idxc, l].set(
                        jnp.where(act & valid, tl2, tl[idxc, l]))
                x_new = x + mix
                if cfg.layer_kind != LayerKind.MAMBA:
                    h2 = rms_norm(x_new, lp["ln2"], cfg.rms_eps)
                    if s.n_experts > 0:
                        x_new = x_new + moe_fn(cfg, lp["moe"], h2)
                    else:
                        x_new = x_new + swiglu_apply(lp["mlp"], h2)
                x = jnp.where(act, x_new, x)
                ck, cv, hh, tl = new_ck, new_cv, new_hh, new_tl

            h_last = rms_norm(x, fn_gamma, cfg.rms_eps)
            out_ids = executor.fold_greedy_ids(
                tc, h_last, head_w, out_ids,
                model_axis=model_axis, vocab_true=s.vocab)
            return x, (ck, cv, hh, tl), out_ids

        x0 = jnp.zeros((bm, s.d_model), dt)
        ids0 = jnp.zeros((nm, bm), jnp.int32)
        program = StageProgram(n_items=nm, d_p=d_p, data_axis=data_axis,
                               tick=tick, psum_acc=True)
        xf, (ck, cv, hh, tl), out_ids = executor.run_stage_program(
            program, x0, (cache_k, cache_v, ssm_h, conv_tail), ids0)

        new_state = dict(state)
        new_state["tokens"] = out_ids.reshape(state["tokens"].shape)

        def unsq(a, ref):
            return a.reshape(ref.shape) if a is not None else None
        if "cache_k" in state:
            new_state["cache_k"] = unsq(ck, state["cache_k"])
        if "cache_v" in state:
            new_state["cache_v"] = unsq(cv, state["cache_v"])
        if "ssm_h" in state:
            new_state["ssm_h"] = unsq(hh, state["ssm_h"])
        if "conv_tail" in state:
            new_state["conv_tail"] = unsq(tl, state["conv_tail"])
        return out_ids, new_state

    return step_local


# ===========================================================================
# Continuous-batching serving engine: one stage program for chunked prefill
# AND k-token (speculative) decode over a PAGED, sequence-sharded KV pool.
#
# The unit of work is a *packed token chunk* — the trainer's chunk
# abstraction reborn for serving. Every engine-step item is a fixed-shape
# buffer of ``cap_t`` tokens carrying per-token metadata:
#
#   tokens[t]    the token id fed at this position
#   pos[t]       absolute position in the owning sequence; the cache home of
#                this token's KV row is page ``pages[t, pos // page_sz]``,
#                row ``pos % page_sz``
#   seg[t]       item-local segment id (-1 = padding); intra-chunk attention
#                is same-segment causal
#   ctx_base[t]  committed cache rows of the segment at step start; cache
#                attention sees logical rows [0, ctx_base) only
#   pages[t, e]  the owning request's page table (``n_pages`` = sentinel:
#                unmapped entries; padding and bubble-tick writes land in
#                the trash page). Replicated over the model axis — every
#                rank serves the pages IT owns for all cap_t tokens.
#
# The pool is PAGE-granular and sequence-sharded: global page id
# ``p ∈ [0, n_pages)`` lives on model-rank ``p // n_pages_loc`` at local
# index ``p % n_pages_loc``; each rank also keeps one local trash page.
# Capacity therefore scales with the model axis (d_s ranks hold d_s× the
# pages of one device), at the cost of each rank scoring all cap_t queries
# against its local pages — the partial (m, l, acc) merge with the
# flash-decode psum-LSE, plus the intra-chunk rows computed replicated.
#
# A prefill chunk is a segment of prompt tokens (pos = offset..offset+c-1,
# ctx_base = offset); a decode tick is a segment of k tokens (the last
# accepted token + k-1 draft tokens, ctx_base = committed length). Both run
# the SAME compiled program: per token, attention = LSE-merge over
# [page-gathered cache rows ‖ intra-chunk same-segment causal rows], then
# the token's KV row is scattered into (page, pos % page_sz) by the rank
# owning the page. Rows at pos >= ctx_base written by rejected drafts are
# invisible (masked) until overwritten.
#
# Per-stream lengths are DATA, not shape: one executable serves every
# request mix, so the engine's bucket-key set is closed
# (compile_cache.engine_bucket_key + engine_copy_bucket_key — the second
# program is the copy-on-write page copy below). Decode runs remat-free
# (static l_ckpt=0 — the ROADMAP's per-chunk remat-free decode item).
# ===========================================================================


@dataclass(frozen=True)
class EngineGeometry:
    """Static geometry of one compiled engine step (a serve bucket)."""
    n_items: int             # packed chunk items per engine step
    cap_t: int               # tokens per item (global; sharded over model)
    n_pages: int             # user KV pages pool-wide (n_pages % d_s == 0)
    page_sz: int             # cache rows per page
    pages_per_seq: int       # page-table entries per request (max context)
    k: int                   # decode tokens per stream per step (1 = greedy)
    d_p: int
    d_s: int
    layers_per_stage: int
    copy_cap: int = 4        # COW page copies per copy-program call
    compute_dtype: Any = jnp.bfloat16

    @property
    def trash_page(self) -> int:
        """Sentinel page id: unmapped table entries, padding/bubble writes."""
        return self.n_pages

    @property
    def n_pages_loc(self) -> int:
        """User pages resident per model rank (+1 local trash page)."""
        return self.n_pages // self.d_s

    @property
    def max_ctx(self) -> int:
        """Rows a full page table can address (max prompt + generated)."""
        return self.pages_per_seq * self.page_sz

    @property
    def dtype_name(self) -> str:
        return _dtype_name(self.compute_dtype)


def make_engine_geometry(cfg: ArchConfig, mesh: Mesh, *, n_items: int,
                         cap_t: int, n_pages: int, page_sz: int,
                         pages_per_seq: Optional[int] = None, k: int = 1,
                         copy_cap: int = 4,
                         compute_dtype=jnp.bfloat16) -> EngineGeometry:
    s = cfg.spec
    if s.attn_free or s.ssm_state > 0:
        raise NotImplementedError(
            "serving engine supports attention archs only (SSM/hybrid decode "
            "uses the one-shot decode_step_fn path)")
    if s.is_encoder_decoder:
        raise NotImplementedError("serving engine is decoder-only")
    if s.kv_lora_rank > 0:
        raise NotImplementedError(
            "MLA latent cache rows are not wired into the page pool yet "
            "(see ROADMAP follow-ons)")
    pod, data, model = mesh_axis_names(mesh)
    if pod is not None:
        raise NotImplementedError("engine runs on a (data, model) mesh; "
                                  "multi-pod request routing is a ROADMAP "
                                  "follow-on")
    d_p, d_s = mesh.shape[data], mesh.shape[model]
    if cap_t % d_s:
        raise ValueError(f"cap_t={cap_t} must be divisible by the model "
                         f"axis d_s={d_s}")
    if min(n_items, cap_t, n_pages, page_sz, k, copy_cap) < 1:
        raise ValueError(
            "n_items/cap_t/n_pages/page_sz/k/copy_cap must all be >= 1")
    if n_pages % d_s:
        raise ValueError(f"n_pages={n_pages} must be divisible by the model "
                         f"axis d_s={d_s} (the pool is sequence-sharded "
                         f"page-blockwise)")
    pp = n_pages if pages_per_seq is None else pages_per_seq
    if not (1 <= pp <= n_pages):
        raise ValueError(f"pages_per_seq={pp} must be in [1, n_pages="
                         f"{n_pages}]")
    if k > cap_t:
        raise ValueError(f"k={k} cannot exceed cap_t={cap_t}")
    return EngineGeometry(
        n_items=n_items, cap_t=cap_t, n_pages=n_pages, page_sz=page_sz,
        pages_per_seq=pp, k=k, d_p=d_p, d_s=d_s,
        layers_per_stage=-(-cfg.spec.n_layers // d_p), copy_cap=copy_cap,
        compute_dtype=compute_dtype)


def engine_pool_struct(cfg: ArchConfig, geom: EngineGeometry) -> Dict:
    """Global ShapeDtypeStructs of the paged KV pool: per stage (d_p over
    "data"), per layer, ``n_pages + d_s`` pages of ``page_sz`` rows with the
    page axis sharded over the model axis — each rank holds its
    ``n_pages_loc`` user pages plus one local trash page (the last local
    index), so at d_s=1 the shape is exactly ``[d_p, L_s, n_pages + 1,
    page_sz, Hkv, Dh]``. Capacity scales with the mesh: pages are NOT
    replicated."""
    s = cfg.spec
    shape = (geom.d_p, geom.layers_per_stage, geom.n_pages + geom.d_s,
             geom.page_sz, s.n_kv_heads, s.head_dim)
    st = jax.ShapeDtypeStruct(shape, geom.compute_dtype)
    return {"cache_k": st, "cache_v": st}


def engine_pool_specs(data: str = "data", model: str = "model") -> Dict:
    p = P(data, None, model, None, None, None)
    return {"cache_k": p, "cache_v": p}


def engine_batch_struct(geom: EngineGeometry) -> Dict:
    """Per-step packed chunk buffers (global shapes; token dim sharded over
    the model axis like the trainer's chunk buffers, the page table
    replicated — see ``sharding.batch_specs(replicated=("pages",))``)."""
    n, c = geom.n_items, geom.cap_t
    st = jax.ShapeDtypeStruct((n, c), jnp.int32)
    return {"tokens": st, "pos": st, "seg": st, "ctx_base": st,
            "pages": jax.ShapeDtypeStruct((n, c, geom.pages_per_seq),
                                          jnp.int32)}


def engine_copy_struct(geom: EngineGeometry) -> Dict:
    """Copy-program operands: ``copy_cap`` (src, dst) global page-id pairs;
    ``n_pages`` sentinels are no-ops, so one fixed-shape program serves any
    number of copy-on-write copies per step."""
    st = jax.ShapeDtypeStruct((geom.copy_cap,), jnp.int32)
    return {"src": st, "dst": st}


def _paged_attention(q, k_page, v_page, k_intra, v_intra, ok_page,
                     ok_intra, *, scale, model_axis):
    """Per-token attention over [page-gathered cache rows ‖ intra rows].

    q: [T, Hq, Dh] (all cap_t queries, every rank); k/v_page:
    [T, R, Hkv, Dh] — THIS rank's resident rows for each token's page table
    (R = pages_per_seq * page_sz); k/v_intra: [T, Hkv, Dh] (the whole
    chunk, replicated); ok_page: [T, R] bool (false off-rank); ok_intra:
    [T, T] bool. Cache partials (m, l, acc) merge across the model axis
    with the flash-decode psum-LSE; intra contributions are replicated and
    added once. Returns [T, Hq, Dh] on every rank."""
    Hq, Hkv = q.shape[1], k_intra.shape[1]
    if Hkv != Hq:
        rep = Hq // Hkv
        k_page = jnp.repeat(k_page, rep, axis=2)
        v_page = jnp.repeat(v_page, rep, axis=2)
        k_intra = jnp.repeat(k_intra, rep, axis=1)
        v_intra = jnp.repeat(v_intra, rep, axis=1)
    qf = q.astype(jnp.float32)
    s_c = jnp.einsum("thd,tshd->ths", qf,
                     k_page.astype(jnp.float32)) * scale
    s_i = jnp.einsum("thd,shd->ths", qf,
                     k_intra.astype(jnp.float32)) * scale
    s_c = jnp.where(ok_page[:, None, :], s_c, -1e30)
    s_i = jnp.where(ok_intra[:, None, :], s_i, -1e30)
    m_c = jax.lax.pmax(s_c.max(axis=-1), model_axis)
    m = jnp.maximum(m_c, s_i.max(axis=-1))        # same on every rank
    p_c = jnp.exp(s_c - m[..., None])
    p_i = jnp.exp(s_i - m[..., None])
    l = jax.lax.psum(p_c.sum(axis=-1), model_axis) + p_i.sum(axis=-1)
    acc = jax.lax.psum(
        jnp.einsum("ths,tshd->thd", p_c, v_page.astype(jnp.float32)),
        model_axis)
    acc = acc + jnp.einsum("ths,shd->thd", p_i,
                           v_intra.astype(jnp.float32))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.astype(q.dtype)


def engine_step_fn(cfg: ArchConfig, geom: EngineGeometry, shard_dims, *,
                   data_axis: str = "data",
                   model_axis: str = "model") -> Callable:
    """Returns step_local(params, pool, batch) -> (ids [n, cap_loc], pool');
    call inside shard_map. ``ids[i, t]`` is the greedy next-token id after
    consuming batch token ``(i, t)`` (the same fold the prefill path uses);
    the host reads decode/prefill outputs at its packed offsets."""
    s = cfg.spec
    L_s, d_p, d_s = geom.layers_per_stage, geom.d_p, geom.d_s
    n = geom.n_items
    ps, pp = geom.page_sz, geom.pages_per_seq
    n_loc = geom.n_pages_loc
    dt = geom.compute_dtype
    windows_all, active_all = _layer_tables(cfg, d_p, L_s)
    scale = 1.0 / math.sqrt(s.head_dim)
    moe_fn = None
    if s.n_experts > 0:
        from .ep import make_moe_ep
        moe_fn = make_moe_ep(model_axis, d_s)

    def step_local(params, pool, batch):
        p_idx = jax.lax.axis_index(data_axis)
        m_idx = jax.lax.axis_index(model_axis)
        stage_params = jax.tree.map(lambda x: x[0], params["stages"])
        windows = windows_all[p_idx]
        active = active_all[p_idx]
        fn_gamma = params["final_norm"]
        if fn_gamma.shape[0] != s.d_model:
            fn_gamma = jax.lax.all_gather(fn_gamma, model_axis, axis=0,
                                          tiled=True)
        head_w = params.get("unembed", params["embed"])
        cap_loc = batch["tokens"].shape[-1]

        tokens_a = batch["tokens"].reshape(n, cap_loc)
        pos_a = batch["pos"].reshape(n, cap_loc)
        seg_a = batch["seg"].reshape(n, cap_loc)
        base_a = batch["ctx_base"].reshape(n, cap_loc)
        pages_a = batch["pages"].reshape(n, geom.cap_t, pp)   # replicated

        # local pool view: drop the stage dim sharded over "data"; the page
        # axis is already the LOCAL n_loc + 1 block of this model rank
        ck0 = pool["cache_k"].reshape(pool["cache_k"].shape[1:])
        cv0 = pool["cache_v"].reshape(pool["cache_v"].shape[1:])
        # logical row of gathered-page entry (e, r): e * page_sz + r
        rows_log = jnp.arange(pp * ps)
        big = jnp.int32(2 ** 30)

        def tick(tc, x_recv, state, ids_acc):
            ck, cv = state
            idxc = tc.idxc
            tok = tokens_a[idxc]
            seg_l = jnp.where(tc.valid, seg_a[idxc], -1)
            pos_l = pos_a[idxc]
            # full-chunk metadata: intra attention, the paged gathers and
            # the page-owner writes need every rank to see all cap_t rows
            seg_g = jax.lax.all_gather(seg_l, model_axis, axis=0, tiled=True)
            pos_g = jax.lax.all_gather(pos_l, model_axis, axis=0, tiled=True)
            base_g = jax.lax.all_gather(base_a[idxc], model_axis, axis=0,
                                        tiled=True)
            pages_t = pages_a[idxc]                        # [cap_t, pp]
            # which table entries live on THIS rank (sentinel n_pages maps
            # to owner d_s — never a real rank — so it is off-rank
            # everywhere and reads mask out / writes trash below)
            owner = pages_t // n_loc
            mine = owner == m_idx
            loc = jnp.where(mine, pages_t % n_loc, n_loc)  # n_loc = trash
            mine_rows = jnp.repeat(mine, ps, axis=1)       # [cap_t, pp*ps]

            x_emb = sp.sharded_embed(params["embed"], tok, model_axis, dt)
            if cfg.embed_scale:
                x_emb = x_emb * jnp.asarray(s.d_model ** 0.5, dt)
            x = jnp.where(tc.is_first_stage, x_emb, x_recv)

            # write targets: the page holding row ``pos`` per token; tokens
            # past the table (pos >= max_ctx), padding, bubble ticks and
            # unmapped entries all land in the LOCAL trash page
            entry_w = jnp.clip(pos_g // ps, 0, pp - 1)
            pid_w = jnp.take_along_axis(pages_t, entry_w[:, None],
                                        axis=1)[:, 0]
            row_w = jnp.clip(pos_g % ps, 0, ps - 1)

            def layer_body(x, per_layer):
                lp, w, act, ck_l, cv_l = per_layer
                lp = gather_layer_params(lp, shard_dims, model_axis)
                h_in = rms_norm(x, lp["ln1"], cfg.rms_eps)
                q, k_new, v_new = project_qkv(cfg, lp["attn"], h_in, pos_l)
                q_g = jax.lax.all_gather(q, model_axis, axis=0, tiled=True)
                k_g = jax.lax.all_gather(k_new, model_axis, axis=0,
                                         tiled=True)
                v_g = jax.lax.all_gather(v_new, model_axis, axis=0,
                                         tiled=True)
                w_eff = jnp.where(w > 0, w, big)
                # this rank's resident rows of each token's page table:
                # [cap_t, pp, page_sz, Hkv, Dh] -> flatten the page dims
                kc = ck_l[loc].reshape(geom.cap_t, pp * ps, *ck_l.shape[2:])
                vc = cv_l[loc].reshape(geom.cap_t, pp * ps, *cv_l.shape[2:])
                # cache rows: committed prefix, resident here, window-masked
                ok_c = mine_rows \
                    & (rows_log[None, :] < base_g[:, None]) \
                    & (seg_g >= 0)[:, None] \
                    & ((pos_g[:, None] - rows_log[None, :]) < w_eff)
                # intra-chunk: same segment, causal, window-masked
                ok_i = (seg_g[None, :] == seg_g[:, None]) \
                    & (seg_g >= 0)[:, None] \
                    & (pos_g[None, :] <= pos_g[:, None]) \
                    & ((pos_g[:, None] - pos_g[None, :]) < w_eff)
                out = _paged_attention(q_g, kc, vc, k_g, v_g, ok_c, ok_i,
                                       scale=scale, model_axis=model_axis)
                # every rank computed all cap_t outputs; keep my token block
                out_l = jax.lax.dynamic_slice_in_dim(
                    out, m_idx * cap_loc, cap_loc, axis=0)
                y = jnp.einsum("th,hd->td",
                               out_l.reshape(out_l.shape[0], -1),
                               lp["attn"]["wo"].astype(x.dtype))
                # scatter the chunk's KV rows into (page, pos % page_sz):
                # only the page's owner writes; everything else trashes
                w_ok = (seg_g >= 0) & tc.valid & act \
                    & (pos_g < pp * ps) & ((pid_w // n_loc) == m_idx)
                page_w = jnp.where(w_ok, pid_w % n_loc, n_loc)
                ck_l = ck_l.at[page_w, row_w].set(k_g.astype(ck_l.dtype))
                cv_l = cv_l.at[page_w, row_w].set(v_g.astype(cv_l.dtype))
                x_new = x + y
                h2 = rms_norm(x_new, lp["ln2"], cfg.rms_eps)
                if s.n_experts > 0:
                    x_new = x_new + moe_fn(cfg, lp["moe"], h2)
                else:
                    x_new = x_new + swiglu_apply(lp["mlp"], h2)
                x = jnp.where(act, x_new, x)
                return x, (ck_l, cv_l)

            # remat-free: serving never differentiates, so l_ckpt=0 keeps
            # the plain single-scan layer path
            x_out, (ck, cv) = executor.run_stage_layers(
                layer_body, x, (stage_params, windows, active, ck, cv),
                l_ckpt=0, n_layers=L_s)
            h_last = rms_norm(x_out, fn_gamma, cfg.rms_eps)
            ids_acc = executor.fold_greedy_ids(
                tc, h_last, head_w, ids_acc,
                model_axis=model_axis, vocab_true=s.vocab,
                token_sharded=True)
            return x_out, (ck, cv), ids_acc

        x0 = jnp.zeros((cap_loc, s.d_model), dt)
        ids0 = jnp.zeros((n, cap_loc), jnp.int32)
        program = StageProgram(n_items=n, d_p=d_p, data_axis=data_axis,
                               tick=tick, psum_acc=True)
        _, (ck, cv), ids = executor.run_stage_program(
            program, x0, (ck0, cv0), ids0)
        new_pool = {"cache_k": ck.reshape(pool["cache_k"].shape),
                    "cache_v": cv.reshape(pool["cache_v"].shape)}
        return ids, new_pool

    return step_local


def engine_copy_fn(geom: EngineGeometry, *,
                   model_axis: str = "model") -> Callable:
    """Device-side page copy for copy-on-write: returns
    copy_local(pool, copies) -> pool' for use inside shard_map.

    ``copies`` is {"src", "dst"}: ``copy_cap`` global page-id pairs
    (sentinel ``n_pages`` pairs are no-ops). For each pair the source
    owner broadcasts the page over the model axis (psum of a single
    non-zero contribution) and the destination owner writes it — src and
    dst may live on different ranks. Each pipeline stage copies its own
    layer slab; no data-axis collectives."""
    n_loc = geom.n_pages_loc

    def copy_local(pool, copies):
        m_idx = jax.lax.axis_index(model_axis)
        ck = pool["cache_k"].reshape(pool["cache_k"].shape[1:])
        cv = pool["cache_v"].reshape(pool["cache_v"].shape[1:])

        def body(carry, sd):
            ck, cv = carry
            src, dst = sd
            s_mine = (src // n_loc) == m_idx      # sentinel: no owner
            s_loc = jnp.where(s_mine, src % n_loc, n_loc)
            pk = jax.lax.psum(
                jnp.where(s_mine, ck[:, s_loc], 0), model_axis)
            pv = jax.lax.psum(
                jnp.where(s_mine, cv[:, s_loc], 0), model_axis)
            d_mine = (dst // n_loc) == m_idx
            d_loc = jnp.where(d_mine, dst % n_loc, n_loc)
            ck = ck.at[:, d_loc].set(
                jnp.where(d_mine, pk.astype(ck.dtype), ck[:, d_loc]))
            cv = cv.at[:, d_loc].set(
                jnp.where(d_mine, pv.astype(cv.dtype), cv[:, d_loc]))
            return (ck, cv), None

        (ck, cv), _ = jax.lax.scan(body, (ck, cv),
                                   (copies["src"], copies["dst"]))
        return {"cache_k": ck.reshape(pool["cache_k"].shape),
                "cache_v": cv.reshape(pool["cache_v"].shape)}

    return copy_local


@dataclass
class EngineStepBuilder:
    """Builds the AOT-compiled engine step for a mesh + engine geometry.

    AOT (``lower().compile()``) so the executable is serializable into the
    persistent :class:`~repro.runtime.cache_store.CacheStore` — a serving
    restart warm-starts its two engine buckets (step + COW page copy)."""
    cfg: ArchConfig
    mesh: Mesh
    geom: EngineGeometry
    param_dtype: Any = jnp.float32

    def __post_init__(self):
        self.pod_axis, self.data_axis, self.model_axis = \
            mesh_axis_names(self.mesh)
        if self.pod_axis is not None:
            raise NotImplementedError("engine runs on a (data, model) mesh")

    # ------------------------------------------------------------------
    def init_params(self, key) -> Dict:
        raw = DecoderLM(self.cfg).init(key, jnp.float32)
        return prepare_params(self.cfg, raw, self.mesh, self.param_dtype)

    def abstract_params(self, key=None) -> Dict:
        key = key if key is not None else jax.random.PRNGKey(0)
        return jax.eval_shape(lambda k: self.init_params(k), key)

    def init_pool(self) -> Dict:
        return {k: jnp.zeros(v.shape, v.dtype)
                for k, v in engine_pool_struct(self.cfg, self.geom).items()}

    # ------------------------------------------------------------------
    def build(self, params_shape=None):
        params_shape = params_shape or self.abstract_params()
        pspecs = param_pspecs(self.cfg, params_shape, self.mesh)
        shard_dims = shard_dim_tree(params_shape["stages"],
                                    self.mesh.shape[self.model_axis])
        from .sharding import batch_specs
        bspecs = batch_specs(engine_batch_struct(self.geom), pod=None,
                             model=self.model_axis, replicated=("pages",))
        poolspecs = engine_pool_specs(self.data_axis, self.model_axis)
        fn = engine_step_fn(self.cfg, self.geom, shard_dims,
                            data_axis=self.data_axis,
                            model_axis=self.model_axis)
        mapped = shard_map_compat(
            fn, mesh=self.mesh,
            in_specs=(pspecs, poolspecs, bspecs),
            out_specs=(P(None, self.model_axis), poolspecs),
            check_vma=False)
        pool_struct = engine_pool_struct(self.cfg, self.geom)
        batch_struct_ = engine_batch_struct(self.geom)
        return jax.jit(mapped).lower(
            params_shape, pool_struct, batch_struct_).compile()

    def build_copy(self):
        """AOT-compile the COW page-copy program (its own cache bucket —
        see ``compile_cache.engine_copy_bucket_key``)."""
        poolspecs = engine_pool_specs(self.data_axis, self.model_axis)
        cspecs = {"src": P(None), "dst": P(None)}
        fn = engine_copy_fn(self.geom, model_axis=self.model_axis)
        mapped = shard_map_compat(
            fn, mesh=self.mesh, in_specs=(poolspecs, cspecs),
            out_specs=poolspecs, check_vma=False)
        pool_struct = engine_pool_struct(self.cfg, self.geom)
        return jax.jit(mapped).lower(
            pool_struct, engine_copy_struct(self.geom)).compile()
