"""Serving: pipelined decode (one new token against a KV cache) and
prefill (the forward pipeline whose context carry IS the cache).

Decode maps the assignment's decode_32k / long_500k shapes:

* the per-pod request batch splits into ``d_p`` microbatches that flow
  through the stage pipeline exactly like training chunks (ppermute ticks),
  so all stages stay busy — pipelined decode;
* the KV cache is sharded: stage dim over "data", sequence dim over
  "model"; decode attention is *flash-decode*: every "model" rank scores
  its local cache rows and the partial (m, l, acc) merge with a psum-LSE
  (works for any head count — kv=1 MQA included);
* the new token's KV row is written by the rank owning position
  ``cache_len``; SSM archs carry (h, conv_tail) instead — O(1) state;
* sliding-window layers (gemma3) mask rows outside the window (the cache
  is allocated full-length for shape uniformity; ring-buffer compaction is
  a recorded hillclimb lever — EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.models import DecoderLM
from repro.models.attention import mla_expand_ctx, project_qkv
from repro.models.config import ArchConfig, LayerKind
from repro.models.layers import rms_norm, swiglu_apply
from repro.models.moe import moe_apply_dense
from repro.models.ssm import dt_rank_of

from . import executor, sp
from .program import StageProgram
from .sharding import gather_layer_params, mesh_axis_names, shard_dim_tree
from .train_step import param_pspecs, prepare_params

__all__ = ["DecodeGeometry", "decode_step_fn", "decode_state_struct",
           "DecodeStepBuilder"]


@dataclass(frozen=True)
class DecodeGeometry:
    batch_per_pod: int
    cache_len: int                # S: current context size (static per bucket)
    d_p: int
    d_s: int
    layers_per_stage: int
    n_micro: int                  # microbatches (== d_p unless batch < d_p)
    compute_dtype: Any = jnp.bfloat16

    @property
    def bm(self) -> int:
        return max(1, self.batch_per_pod // self.n_micro)

    @property
    def s_cap(self) -> int:
        """Cache capacity: one extra row per shard so the new token's KV
        always has a home (position ``cache_len`` is written this step)."""
        return self.cache_len + self.d_s

    @property
    def s_loc(self) -> int:
        return self.s_cap // self.d_s


def make_decode_geometry(cfg: ArchConfig, mesh: Mesh, *, batch_per_pod: int,
                         cache_len: int,
                         compute_dtype=jnp.bfloat16) -> DecodeGeometry:
    pod, data, model = mesh_axis_names(mesh)
    d_p, d_s = mesh.shape[data], mesh.shape[model]
    n_micro = min(d_p, max(1, batch_per_pod))
    return DecodeGeometry(
        batch_per_pod=batch_per_pod, cache_len=cache_len, d_p=d_p, d_s=d_s,
        layers_per_stage=-(-cfg.spec.n_layers // d_p), n_micro=n_micro,
        compute_dtype=compute_dtype)


def decode_state_struct(cfg: ArchConfig, geom: DecodeGeometry,
                        n_pods: int) -> Dict:
    """Global ShapeDtypeStructs for the serving state (cache etc.)."""
    s = cfg.spec
    lead = (n_pods,) if n_pods > 1 else ()
    L_s, nm, bm = geom.layers_per_stage, geom.n_micro, geom.bm
    dt = geom.compute_dtype
    out: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((*lead, nm, bm), jnp.int32),
    }
    if s.is_encoder_decoder:
        # stub memory: 1/4 of the decode context worth of encoder frames
        s_mem = max(geom.d_s, (geom.cache_len // 4) // geom.d_s * geom.d_s)
        out["memory"] = jax.ShapeDtypeStruct(
            (*lead, nm, bm, s_mem, s.d_model), dt)
    if not s.attn_free:
        if s.kv_lora_rank > 0:
            row = (s.kv_lora_rank + s.qk_rope_dim,)
            out["cache_k"] = jax.ShapeDtypeStruct(
                (*lead, geom.d_p, nm, L_s, bm, geom.s_cap, 1, *row), dt)
        else:
            out["cache_k"] = jax.ShapeDtypeStruct(
                (*lead, geom.d_p, nm, L_s, bm, geom.s_cap,
                 s.n_kv_heads, s.head_dim), dt)
            out["cache_v"] = jax.ShapeDtypeStruct(out["cache_k"].shape, dt)
    if s.ssm_state > 0:
        out["ssm_h"] = jax.ShapeDtypeStruct(
            (*lead, geom.d_p, nm, L_s, bm, s.inner, s.ssm_state), jnp.float32)
        out["conv_tail"] = jax.ShapeDtypeStruct(
            (*lead, geom.d_p, nm, L_s, bm, s.ssm_conv - 1, s.inner), dt)
    return out


def decode_state_specs(cfg: ArchConfig, geom: DecodeGeometry, *,
                       pod: Optional[str], data: str, model: str) -> Dict:
    s = cfg.spec
    lead = (pod,) if pod else ()
    out: Dict[str, Any] = {"tokens": P(*lead, None, None)}
    if s.is_encoder_decoder:
        # cross-attention memory [.., nm, bm, S_mem, D]: frames over model
        out["memory"] = P(*lead, None, None, model, None)
    if not s.attn_free:
        # [.., d_p, nm, L_s, bm, S, Hkv, Dh]: stage over data, seq over model
        out["cache_k"] = P(*lead, data, None, None, None, model, None, None)
        if s.kv_lora_rank == 0:
            out["cache_v"] = out["cache_k"]
    if s.ssm_state > 0:
        # channel dim over model
        out["ssm_h"] = P(*lead, data, None, None, None, model, None)
        out["conv_tail"] = P(*lead, data, None, None, None, None, model)
    return out


# ---------------------------------------------------------------------------
# Flash-decode attention (sequence-sharded cache, LSE merge over "model").
# ---------------------------------------------------------------------------

def _flash_decode(q, k_loc, v_loc, *, valid_rows, scale, model_axis):
    """q: [Bm, Hq, Dh]; k/v_loc: [Bm, S_loc, Hkv(+), Dh]; valid_rows:
    [S_loc] bool. Returns [Bm, Hq, Dv]."""
    Hq = q.shape[1]
    Hkv = k_loc.shape[2]
    if Hkv != Hq:
        rep = Hq // Hkv
        k_loc = jnp.repeat(k_loc, rep, axis=2)
        v_loc = jnp.repeat(v_loc, rep, axis=2)
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                   k_loc.astype(jnp.float32)) * scale
    s = jnp.where(valid_rows[None, None, :], s, -1e30)
    m = s.max(axis=-1)
    m_g = jax.lax.pmax(m, model_axis)
    p = jnp.exp(s - m_g[..., None])
    l = jax.lax.psum(p.sum(axis=-1), model_axis)
    acc = jnp.einsum("bhs,bshd->bhd", p, v_loc.astype(jnp.float32))
    acc = jax.lax.psum(acc, model_axis)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return jnp.where(l[..., None] > 0, out, 0.0).astype(q.dtype)


def decode_step_fn(cfg: ArchConfig, geom: DecodeGeometry, shard_dims, *,
                   pod_axis: Optional[str], data_axis: str = "data",
                   model_axis: str = "model") -> Callable:
    """Returns step_local(params, state) -> (next_ids [nm, bm], new state);
    call inside shard_map."""
    s = cfg.spec
    L_s, d_p, d_s = geom.layers_per_stage, geom.d_p, geom.d_s
    nm, bm = geom.n_micro, geom.bm
    dt = geom.compute_dtype
    S, S_loc = geom.cache_len, geom.s_loc
    L_pad = d_p * L_s
    import numpy as _np
    win_flat = [cfg.layer_window(i) for i in range(s.n_layers)]
    win_flat += [0] * (L_pad - s.n_layers)
    windows_all = jnp.asarray(win_flat, jnp.int32).reshape(d_p, L_s)
    active_all = jnp.asarray(
        (_np.arange(L_pad) < s.n_layers).reshape(d_p, L_s))
    scale = 1.0 / math.sqrt(s.head_dim + (s.qk_rope_dim if s.kv_lora_rank
                                          else 0)) if not s.attn_free else 0.0

    moe_fn = None
    if s.n_experts > 0:
        from .ep import make_moe_ep
        moe_fn = make_moe_ep(model_axis, d_s)

    def _attn_decode(lp, h, cache_k_l, cache_v_l, window):
        """One microbatch, one layer. h: [bm, D]."""
        pos = jnp.full((bm,), S, jnp.int32)
        q, k_new, v_new = project_qkv(cfg, lp, h, pos)
        # write the new row into the shard owning position S
        shard_off = jax.lax.axis_index(model_axis) * S_loc
        loc = S - shard_off
        ok = (loc >= 0) & (loc < S_loc)
        locc = jnp.clip(loc, 0, S_loc - 1)
        upd_k = jax.lax.dynamic_update_slice_in_dim(
            cache_k_l, k_new[:, None].astype(cache_k_l.dtype), locc, axis=1)
        cache_k_l = jnp.where(ok, upd_k, cache_k_l)
        if cache_v_l is not None:
            upd_v = jax.lax.dynamic_update_slice_in_dim(
                cache_v_l, v_new[:, None].astype(cache_v_l.dtype), locc,
                axis=1)
            cache_v_l = jnp.where(ok, upd_v, cache_v_l)
        rows = shard_off + jnp.arange(S_loc)
        valid = rows <= S
        big = jnp.int32(2 ** 30)
        w = jnp.where(window > 0, window, big)
        valid &= (S - rows) < w
        if s.kv_lora_rank > 0:
            kk, vv = jax.vmap(
                lambda c: mla_expand_ctx(cfg, lp, c))(cache_k_l)
            out = _flash_decode(q, kk, vv, valid_rows=valid, scale=scale,
                                model_axis=model_axis)
            out = out[..., :s.head_dim]
        else:
            out = _flash_decode(q, cache_k_l, cache_v_l, valid_rows=valid,
                                scale=scale, model_axis=model_axis)
        y = jnp.einsum("bh,hd->bd", out.reshape(bm, -1),
                       lp["wo"].astype(h.dtype))
        return y, cache_k_l, cache_v_l

    def _mamba_decode(lp, h, h_state, tail):
        """One-step SSM update; channels sharded over model.

        h: [bm, D]; h_state: [bm, di_loc, ds]; tail: [bm, K-1, di_loc].
        in/out projections are ZeRO-gathered full, so slice the local
        channel block."""
        di = s.inner
        di_loc = h_state.shape[-2]
        c_off = jax.lax.axis_index(model_axis) * di_loc
        dtr = dt_rank_of(cfg)
        xz = jnp.einsum("bd,dh->bh", h, lp["in_proj"].astype(h.dtype))
        xs_f, z_f = xz[:, :di], xz[:, di:]
        xs = jax.lax.dynamic_slice_in_dim(xs_f, c_off, di_loc, axis=1)
        z = jax.lax.dynamic_slice_in_dim(z_f, c_off, di_loc, axis=1)
        conv_w = jax.lax.dynamic_slice_in_dim(lp["conv_w"], c_off, di_loc, 1)
        conv_b = jax.lax.dynamic_slice_in_dim(lp["conv_b"], c_off, di_loc, 0)
        window = jnp.concatenate([tail, xs[:, None, :]], axis=1)  # [bm,K,dl]
        xc = jnp.einsum("bkd,kd->bd", window.astype(jnp.float32),
                        conv_w.astype(jnp.float32)) + conv_b.astype(jnp.float32)
        xc = jax.nn.silu(xc).astype(h.dtype)
        # x_proj/dt act on the full channel dim; gather local xc
        xc_full = jax.lax.all_gather(xc, model_axis, axis=1, tiled=True)
        proj = jnp.einsum("bd,dh->bh", xc_full, lp["x_proj"].astype(h.dtype))
        delta_in = proj[:, :dtr]
        Bv = proj[:, dtr:dtr + s.ssm_state].astype(jnp.float32)
        Cv = proj[:, dtr + s.ssm_state:dtr + 2 * s.ssm_state].astype(jnp.float32)
        delta_f = jax.nn.softplus(
            jnp.einsum("br,rd->bd", delta_in,
                       lp["dt_proj"].astype(h.dtype)).astype(jnp.float32)
            + lp["dt_bias"].astype(jnp.float32))
        delta = jax.lax.dynamic_slice_in_dim(delta_f, c_off, di_loc, axis=1)
        A = -jnp.exp(jax.lax.dynamic_slice_in_dim(
            lp["a_log"].astype(jnp.float32), c_off, di_loc, 0))
        a = jnp.exp(delta[..., None] * A[None])
        bx = (delta * xc.astype(jnp.float32))[..., None] * Bv[:, None, :]
        h_new = a * h_state + bx
        y = jnp.einsum("bds,bs->bd", h_new, Cv)
        dskip = jax.lax.dynamic_slice_in_dim(
            lp["d_skip"].astype(jnp.float32), c_off, di_loc, 0)
        y = y + dskip[None] * xc.astype(jnp.float32)
        y = y * jax.nn.silu(z.astype(jnp.float32))
        y_full = jax.lax.all_gather(y.astype(h.dtype), model_axis, axis=1,
                                    tiled=True)
        out = jnp.einsum("bd,dh->bh", y_full, lp["out_proj"].astype(h.dtype))
        new_tail = window[:, 1:, :].astype(tail.dtype)
        return out, h_new, new_tail

    def _cross_decode(lp, h, mem):
        """Cross-attention for one decode token per sequence.
        h: [bm, D]; mem: [bm, S_mem_loc, D] (frames sharded over model)."""
        Dh, Hq, Hkv = s.head_dim, s.n_heads, s.n_kv_heads
        dtl = h.dtype
        q = jnp.einsum("bd,dh->bh", h, lp["wq"].astype(dtl)
                       ).reshape(bm, Hq, Dh)
        k = jnp.einsum("bsd,dh->bsh", mem, lp["wk"].astype(dtl)
                       ).reshape(bm, -1, Hkv, Dh)
        v = jnp.einsum("bsd,dh->bsh", mem, lp["wv"].astype(dtl)
                       ).reshape(bm, -1, Hkv, Dh)
        valid = jnp.ones((k.shape[1],), bool)
        out = _flash_decode(q, k, v, valid_rows=valid,
                            scale=1.0 / math.sqrt(Dh),
                            model_axis=model_axis)
        return jnp.einsum("bh,hd->bd", out.reshape(bm, -1),
                          lp["wo"].astype(dtl))

    def step_local(params, state):
        p_idx = jax.lax.axis_index(data_axis)
        stage_params = jax.tree.map(lambda x: x[0], params["stages"])
        windows = windows_all[p_idx]
        active = active_all[p_idx]
        fn_gamma = params["final_norm"]
        if fn_gamma.shape[0] != s.d_model:
            fn_gamma = jax.lax.all_gather(fn_gamma, model_axis, axis=0,
                                          tiled=True)
        head_w = params.get("unembed", params["embed"])
        tokens = state["tokens"].reshape(nm, bm)  # lead dims are 1

        n_lead = (1 if pod_axis else 0) + 1  # (pod) + stage dims

        def sq(name):
            if name not in state:
                return None
            a = state[name]
            return a.reshape(a.shape[n_lead:]) if a is not None else None

        memory = None
        if "memory" in state:
            a = state["memory"]
            memory = a.reshape(a.shape[-(4):]) if pod_axis is None \
                else a.reshape(a.shape[-4:])
        cache_k = sq("cache_k")      # [nm, L_s, bm, S_loc, Hkv, Dh]
        cache_v = sq("cache_v")
        ssm_h = sq("ssm_h")          # [nm, L_s, bm, di_loc, ds]
        conv_tail = sq("conv_tail")

        def tick(tc, x_recv, state, out_ids):
            ck, cv, hh, tl = state
            idxc, valid = tc.idxc, tc.valid
            tok = tokens[idxc]
            x_emb = sp.sharded_embed(params["embed"], tok, model_axis, dt)
            if cfg.embed_scale:
                x_emb = x_emb * jnp.asarray(s.d_model ** 0.5, dt)
            x = jnp.where(tc.is_first_stage, x_emb, x_recv)

            new_ck, new_cv = ck, cv
            new_hh, new_tl = hh, tl
            for l in range(L_s):
                lp = gather_layer_params(
                    jax.tree.map(lambda a: a[l], stage_params),
                    shard_dims, model_axis)
                act = active[l]
                h_in = rms_norm(x, lp["ln1"], cfg.rms_eps)
                mix = jnp.zeros_like(x)
                if not s.attn_free and "attn" in lp:
                    ckl = ck[idxc, l] if ck is not None else None
                    cvl = cv[idxc, l] if cv is not None else None
                    y, ckl2, cvl2 = _attn_decode(lp["attn"], h_in, ckl, cvl,
                                                 windows[l])
                    if s.is_encoder_decoder and "cross" in lp:
                        hx = rms_norm(x + y, lp["ln_x"], cfg.rms_eps)
                        y = y + _cross_decode(lp["cross"], hx,
                                              memory[idxc])
                    if ck is not None:
                        new_ck = new_ck.at[idxc, l].set(
                            jnp.where(act & valid, ckl2, ckl))
                    if cv is not None:
                        new_cv = new_cv.at[idxc, l].set(
                            jnp.where(act & valid, cvl2, cvl))
                if s.ssm_state > 0:
                    y2, hh2, tl2 = _mamba_decode(lp["mamba"], h_in,
                                                 hh[idxc, l], tl[idxc, l])
                    if cfg.layer_kind == LayerKind.HYBRID:
                        mix = 0.5 * (mix + y2)
                    else:
                        mix = y2
                    new_hh = new_hh.at[idxc, l].set(
                        jnp.where(act & valid, hh2, hh[idxc, l]))
                    new_tl = new_tl.at[idxc, l].set(
                        jnp.where(act & valid, tl2, tl[idxc, l]))
                x_new = x + mix
                if cfg.layer_kind != LayerKind.MAMBA:
                    h2 = rms_norm(x_new, lp["ln2"], cfg.rms_eps)
                    if s.n_experts > 0:
                        x_new = x_new + moe_fn(cfg, lp["moe"], h2)
                    else:
                        x_new = x_new + swiglu_apply(lp["mlp"], h2)
                x = jnp.where(act, x_new, x)
                ck, cv, hh, tl = new_ck, new_cv, new_hh, new_tl

            h_last = rms_norm(x, fn_gamma, cfg.rms_eps)
            out_ids = executor.fold_greedy_ids(
                tc, h_last, head_w, out_ids,
                model_axis=model_axis, vocab_true=s.vocab)
            return x, (ck, cv, hh, tl), out_ids

        x0 = jnp.zeros((bm, s.d_model), dt)
        ids0 = jnp.zeros((nm, bm), jnp.int32)
        program = StageProgram(n_items=nm, d_p=d_p, data_axis=data_axis,
                               tick=tick, psum_acc=True)
        xf, (ck, cv, hh, tl), out_ids = executor.run_stage_program(
            program, x0, (cache_k, cache_v, ssm_h, conv_tail), ids0)

        new_state = dict(state)
        new_state["tokens"] = out_ids.reshape(state["tokens"].shape)

        def unsq(a, ref):
            return a.reshape(ref.shape) if a is not None else None
        if "cache_k" in state:
            new_state["cache_k"] = unsq(ck, state["cache_k"])
        if "cache_v" in state:
            new_state["cache_v"] = unsq(cv, state["cache_v"])
        if "ssm_h" in state:
            new_state["ssm_h"] = unsq(hh, state["ssm_h"])
        if "conv_tail" in state:
            new_state["conv_tail"] = unsq(tl, state["conv_tail"])
        return out_ids, new_state

    return step_local
