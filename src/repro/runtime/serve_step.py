"""Serving: pipelined decode (one new token against a KV cache) and
prefill (the forward pipeline whose context carry IS the cache).

Decode maps the assignment's decode_32k / long_500k shapes:

* the per-pod request batch splits into ``d_p`` microbatches that flow
  through the stage pipeline exactly like training chunks (ppermute ticks),
  so all stages stay busy — pipelined decode;
* the KV cache is sharded: stage dim over "data", sequence dim over
  "model"; decode attention is *flash-decode*: every "model" rank scores
  its local cache rows and the partial (m, l, acc) merge with a psum-LSE
  (works for any head count — kv=1 MQA included);
* the new token's KV row is written by the rank owning position
  ``cache_len``; SSM archs carry (h, conv_tail) instead — O(1) state;
* sliding-window layers (gemma3) mask rows outside the window (the cache
  is allocated full-length for shape uniformity; ring-buffer compaction is
  a recorded hillclimb lever — EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.models import DecoderLM
from repro.models.attention import mla_expand_ctx, project_qkv
from repro.models.config import ArchConfig, LayerKind
from repro.models.layers import rms_norm, swiglu_apply
from repro.models.ssm import dt_rank_of

from . import executor, sp
from .program import StageProgram
from .sharding import (gather_layer_params, mesh_axis_names, shard_dim_tree,
                       shard_map_compat)
from .train_step import param_pspecs, prepare_params

__all__ = ["DecodeGeometry", "decode_step_fn", "decode_state_struct",
           "DecodeStepBuilder",
           "EngineGeometry", "EngineStepBuilder", "make_engine_geometry",
           "engine_step_fn", "engine_pool_struct", "engine_pool_specs",
           "engine_batch_struct"]


def _dtype_name(dtype) -> str:
    """Canonical dtype string for bucket keys (the compiled HLO differs
    per compute dtype, so keys must too)."""
    import numpy as _np
    return _np.dtype(dtype).name


def _layer_tables(cfg: ArchConfig, d_p: int, L_s: int):
    """Per-stage ``[d_p, L_s]`` sliding-window sizes + active-layer mask
    (padded layer slots inactive) — shared by the decode and engine step
    builders so window/padding semantics can never diverge between the
    two serve paths."""
    import numpy as _np
    L_pad = d_p * L_s
    win = [cfg.layer_window(i) for i in range(cfg.spec.n_layers)]
    win += [0] * (L_pad - cfg.spec.n_layers)
    windows = jnp.asarray(win, jnp.int32).reshape(d_p, L_s)
    active = jnp.asarray(
        (_np.arange(L_pad) < cfg.spec.n_layers).reshape(d_p, L_s))
    return windows, active


@dataclass(frozen=True)
class DecodeGeometry:
    batch_per_pod: int
    cache_len: int                # S: current context size (static per bucket)
    d_p: int
    d_s: int
    layers_per_stage: int
    n_micro: int                  # microbatches (== d_p unless batch < d_p)
    compute_dtype: Any = jnp.bfloat16

    @property
    def bm(self) -> int:
        return max(1, self.batch_per_pod // self.n_micro)

    @property
    def s_cap(self) -> int:
        """Cache capacity: one extra row per shard so the new token's KV
        always has a home (position ``cache_len`` is written this step)."""
        return self.cache_len + self.d_s

    @property
    def s_loc(self) -> int:
        return self.s_cap // self.d_s

    @property
    def dtype_name(self) -> str:
        return _dtype_name(self.compute_dtype)


def make_decode_geometry(cfg: ArchConfig, mesh: Mesh, *, batch_per_pod: int,
                         cache_len: int,
                         compute_dtype=jnp.bfloat16) -> DecodeGeometry:
    pod, data, model = mesh_axis_names(mesh)
    d_p, d_s = mesh.shape[data], mesh.shape[model]
    n_micro = min(d_p, max(1, batch_per_pod))
    return DecodeGeometry(
        batch_per_pod=batch_per_pod, cache_len=cache_len, d_p=d_p, d_s=d_s,
        layers_per_stage=-(-cfg.spec.n_layers // d_p), n_micro=n_micro,
        compute_dtype=compute_dtype)


def decode_state_struct(cfg: ArchConfig, geom: DecodeGeometry,
                        n_pods: int) -> Dict:
    """Global ShapeDtypeStructs for the serving state (cache etc.)."""
    s = cfg.spec
    lead = (n_pods,) if n_pods > 1 else ()
    L_s, nm, bm = geom.layers_per_stage, geom.n_micro, geom.bm
    dt = geom.compute_dtype
    out: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((*lead, nm, bm), jnp.int32),
    }
    if s.is_encoder_decoder:
        # stub memory: 1/4 of the decode context worth of encoder frames
        s_mem = max(geom.d_s, (geom.cache_len // 4) // geom.d_s * geom.d_s)
        out["memory"] = jax.ShapeDtypeStruct(
            (*lead, nm, bm, s_mem, s.d_model), dt)
    if not s.attn_free:
        if s.kv_lora_rank > 0:
            row = (s.kv_lora_rank + s.qk_rope_dim,)
            out["cache_k"] = jax.ShapeDtypeStruct(
                (*lead, geom.d_p, nm, L_s, bm, geom.s_cap, 1, *row), dt)
        else:
            out["cache_k"] = jax.ShapeDtypeStruct(
                (*lead, geom.d_p, nm, L_s, bm, geom.s_cap,
                 s.n_kv_heads, s.head_dim), dt)
            out["cache_v"] = jax.ShapeDtypeStruct(out["cache_k"].shape, dt)
    if s.ssm_state > 0:
        out["ssm_h"] = jax.ShapeDtypeStruct(
            (*lead, geom.d_p, nm, L_s, bm, s.inner, s.ssm_state), jnp.float32)
        out["conv_tail"] = jax.ShapeDtypeStruct(
            (*lead, geom.d_p, nm, L_s, bm, s.ssm_conv - 1, s.inner), dt)
    return out


def decode_state_specs(cfg: ArchConfig, geom: DecodeGeometry, *,
                       pod: Optional[str], data: str, model: str) -> Dict:
    s = cfg.spec
    lead = (pod,) if pod else ()
    out: Dict[str, Any] = {"tokens": P(*lead, None, None)}
    if s.is_encoder_decoder:
        # cross-attention memory [.., nm, bm, S_mem, D]: frames over model
        out["memory"] = P(*lead, None, None, model, None)
    if not s.attn_free:
        # [.., d_p, nm, L_s, bm, S, Hkv, Dh]: stage over data, seq over model
        out["cache_k"] = P(*lead, data, None, None, None, model, None, None)
        if s.kv_lora_rank == 0:
            out["cache_v"] = out["cache_k"]
    if s.ssm_state > 0:
        # channel dim over model
        out["ssm_h"] = P(*lead, data, None, None, None, model, None)
        out["conv_tail"] = P(*lead, data, None, None, None, None, model)
    return out


# ---------------------------------------------------------------------------
# Flash-decode attention (sequence-sharded cache, LSE merge over "model").
# ---------------------------------------------------------------------------

def _flash_decode(q, k_loc, v_loc, *, valid_rows, scale, model_axis):
    """q: [Bm, Hq, Dh]; k/v_loc: [Bm, S_loc, Hkv(+), Dh]; valid_rows:
    [S_loc] bool. Returns [Bm, Hq, Dv]."""
    Hq = q.shape[1]
    Hkv = k_loc.shape[2]
    if Hkv != Hq:
        rep = Hq // Hkv
        k_loc = jnp.repeat(k_loc, rep, axis=2)
        v_loc = jnp.repeat(v_loc, rep, axis=2)
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                   k_loc.astype(jnp.float32)) * scale
    s = jnp.where(valid_rows[None, None, :], s, -1e30)
    m = s.max(axis=-1)
    m_g = jax.lax.pmax(m, model_axis)
    p = jnp.exp(s - m_g[..., None])
    l = jax.lax.psum(p.sum(axis=-1), model_axis)
    acc = jnp.einsum("bhs,bshd->bhd", p, v_loc.astype(jnp.float32))
    acc = jax.lax.psum(acc, model_axis)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return jnp.where(l[..., None] > 0, out, 0.0).astype(q.dtype)


def decode_step_fn(cfg: ArchConfig, geom: DecodeGeometry, shard_dims, *,
                   pod_axis: Optional[str], data_axis: str = "data",
                   model_axis: str = "model") -> Callable:
    """Returns step_local(params, state) -> (next_ids [nm, bm], new state);
    call inside shard_map."""
    s = cfg.spec
    L_s, d_p, d_s = geom.layers_per_stage, geom.d_p, geom.d_s
    nm, bm = geom.n_micro, geom.bm
    dt = geom.compute_dtype
    S, S_loc = geom.cache_len, geom.s_loc
    windows_all, active_all = _layer_tables(cfg, d_p, L_s)
    scale = 1.0 / math.sqrt(s.head_dim + (s.qk_rope_dim if s.kv_lora_rank
                                          else 0)) if not s.attn_free else 0.0

    moe_fn = None
    if s.n_experts > 0:
        from .ep import make_moe_ep
        moe_fn = make_moe_ep(model_axis, d_s)

    def _attn_decode(lp, h, cache_k_l, cache_v_l, window):
        """One microbatch, one layer. h: [bm, D]."""
        pos = jnp.full((bm,), S, jnp.int32)
        q, k_new, v_new = project_qkv(cfg, lp, h, pos)
        # write the new row into the shard owning position S
        shard_off = jax.lax.axis_index(model_axis) * S_loc
        loc = S - shard_off
        ok = (loc >= 0) & (loc < S_loc)
        locc = jnp.clip(loc, 0, S_loc - 1)
        upd_k = jax.lax.dynamic_update_slice_in_dim(
            cache_k_l, k_new[:, None].astype(cache_k_l.dtype), locc, axis=1)
        cache_k_l = jnp.where(ok, upd_k, cache_k_l)
        if cache_v_l is not None:
            upd_v = jax.lax.dynamic_update_slice_in_dim(
                cache_v_l, v_new[:, None].astype(cache_v_l.dtype), locc,
                axis=1)
            cache_v_l = jnp.where(ok, upd_v, cache_v_l)
        rows = shard_off + jnp.arange(S_loc)
        valid = rows <= S
        big = jnp.int32(2 ** 30)
        w = jnp.where(window > 0, window, big)
        valid &= (S - rows) < w
        if s.kv_lora_rank > 0:
            kk, vv = jax.vmap(
                lambda c: mla_expand_ctx(cfg, lp, c))(cache_k_l)
            out = _flash_decode(q, kk, vv, valid_rows=valid, scale=scale,
                                model_axis=model_axis)
            out = out[..., :s.head_dim]
        else:
            out = _flash_decode(q, cache_k_l, cache_v_l, valid_rows=valid,
                                scale=scale, model_axis=model_axis)
        y = jnp.einsum("bh,hd->bd", out.reshape(bm, -1),
                       lp["wo"].astype(h.dtype))
        return y, cache_k_l, cache_v_l

    def _mamba_decode(lp, h, h_state, tail):
        """One-step SSM update; channels sharded over model.

        h: [bm, D]; h_state: [bm, di_loc, ds]; tail: [bm, K-1, di_loc].
        in/out projections are ZeRO-gathered full, so slice the local
        channel block."""
        di = s.inner
        di_loc = h_state.shape[-2]
        c_off = jax.lax.axis_index(model_axis) * di_loc
        dtr = dt_rank_of(cfg)
        xz = jnp.einsum("bd,dh->bh", h, lp["in_proj"].astype(h.dtype))
        xs_f, z_f = xz[:, :di], xz[:, di:]
        xs = jax.lax.dynamic_slice_in_dim(xs_f, c_off, di_loc, axis=1)
        z = jax.lax.dynamic_slice_in_dim(z_f, c_off, di_loc, axis=1)
        conv_w = jax.lax.dynamic_slice_in_dim(lp["conv_w"], c_off, di_loc, 1)
        conv_b = jax.lax.dynamic_slice_in_dim(lp["conv_b"], c_off, di_loc, 0)
        window = jnp.concatenate([tail, xs[:, None, :]], axis=1)  # [bm,K,dl]
        xc = jnp.einsum("bkd,kd->bd", window.astype(jnp.float32),
                        conv_w.astype(jnp.float32)) + conv_b.astype(jnp.float32)
        xc = jax.nn.silu(xc).astype(h.dtype)
        # x_proj/dt act on the full channel dim; gather local xc
        xc_full = jax.lax.all_gather(xc, model_axis, axis=1, tiled=True)
        proj = jnp.einsum("bd,dh->bh", xc_full, lp["x_proj"].astype(h.dtype))
        delta_in = proj[:, :dtr]
        Bv = proj[:, dtr:dtr + s.ssm_state].astype(jnp.float32)
        Cv = proj[:, dtr + s.ssm_state:dtr + 2 * s.ssm_state].astype(jnp.float32)
        delta_f = jax.nn.softplus(
            jnp.einsum("br,rd->bd", delta_in,
                       lp["dt_proj"].astype(h.dtype)).astype(jnp.float32)
            + lp["dt_bias"].astype(jnp.float32))
        delta = jax.lax.dynamic_slice_in_dim(delta_f, c_off, di_loc, axis=1)
        A = -jnp.exp(jax.lax.dynamic_slice_in_dim(
            lp["a_log"].astype(jnp.float32), c_off, di_loc, 0))
        a = jnp.exp(delta[..., None] * A[None])
        bx = (delta * xc.astype(jnp.float32))[..., None] * Bv[:, None, :]
        h_new = a * h_state + bx
        y = jnp.einsum("bds,bs->bd", h_new, Cv)
        dskip = jax.lax.dynamic_slice_in_dim(
            lp["d_skip"].astype(jnp.float32), c_off, di_loc, 0)
        y = y + dskip[None] * xc.astype(jnp.float32)
        y = y * jax.nn.silu(z.astype(jnp.float32))
        y_full = jax.lax.all_gather(y.astype(h.dtype), model_axis, axis=1,
                                    tiled=True)
        out = jnp.einsum("bd,dh->bh", y_full, lp["out_proj"].astype(h.dtype))
        new_tail = window[:, 1:, :].astype(tail.dtype)
        return out, h_new, new_tail

    def _cross_decode(lp, h, mem):
        """Cross-attention for one decode token per sequence.
        h: [bm, D]; mem: [bm, S_mem_loc, D] (frames sharded over model)."""
        Dh, Hq, Hkv = s.head_dim, s.n_heads, s.n_kv_heads
        dtl = h.dtype
        q = jnp.einsum("bd,dh->bh", h, lp["wq"].astype(dtl)
                       ).reshape(bm, Hq, Dh)
        k = jnp.einsum("bsd,dh->bsh", mem, lp["wk"].astype(dtl)
                       ).reshape(bm, -1, Hkv, Dh)
        v = jnp.einsum("bsd,dh->bsh", mem, lp["wv"].astype(dtl)
                       ).reshape(bm, -1, Hkv, Dh)
        valid = jnp.ones((k.shape[1],), bool)
        out = _flash_decode(q, k, v, valid_rows=valid,
                            scale=1.0 / math.sqrt(Dh),
                            model_axis=model_axis)
        return jnp.einsum("bh,hd->bd", out.reshape(bm, -1),
                          lp["wo"].astype(dtl))

    def step_local(params, state):
        p_idx = jax.lax.axis_index(data_axis)
        stage_params = jax.tree.map(lambda x: x[0], params["stages"])
        windows = windows_all[p_idx]
        active = active_all[p_idx]
        fn_gamma = params["final_norm"]
        if fn_gamma.shape[0] != s.d_model:
            fn_gamma = jax.lax.all_gather(fn_gamma, model_axis, axis=0,
                                          tiled=True)
        head_w = params.get("unembed", params["embed"])
        tokens = state["tokens"].reshape(nm, bm)  # lead dims are 1

        n_lead = (1 if pod_axis else 0) + 1  # (pod) + stage dims

        def sq(name):
            if name not in state:
                return None
            a = state[name]
            return a.reshape(a.shape[n_lead:]) if a is not None else None

        memory = None
        if "memory" in state:
            a = state["memory"]
            memory = a.reshape(a.shape[-(4):]) if pod_axis is None \
                else a.reshape(a.shape[-4:])
        cache_k = sq("cache_k")      # [nm, L_s, bm, S_loc, Hkv, Dh]
        cache_v = sq("cache_v")
        ssm_h = sq("ssm_h")          # [nm, L_s, bm, di_loc, ds]
        conv_tail = sq("conv_tail")

        def tick(tc, x_recv, state, out_ids):
            ck, cv, hh, tl = state
            idxc, valid = tc.idxc, tc.valid
            tok = tokens[idxc]
            x_emb = sp.sharded_embed(params["embed"], tok, model_axis, dt)
            if cfg.embed_scale:
                x_emb = x_emb * jnp.asarray(s.d_model ** 0.5, dt)
            x = jnp.where(tc.is_first_stage, x_emb, x_recv)

            new_ck, new_cv = ck, cv
            new_hh, new_tl = hh, tl
            for l in range(L_s):
                lp = gather_layer_params(
                    jax.tree.map(lambda a: a[l], stage_params),
                    shard_dims, model_axis)
                act = active[l]
                h_in = rms_norm(x, lp["ln1"], cfg.rms_eps)
                mix = jnp.zeros_like(x)
                if not s.attn_free and "attn" in lp:
                    ckl = ck[idxc, l] if ck is not None else None
                    cvl = cv[idxc, l] if cv is not None else None
                    y, ckl2, cvl2 = _attn_decode(lp["attn"], h_in, ckl, cvl,
                                                 windows[l])
                    if s.is_encoder_decoder and "cross" in lp:
                        hx = rms_norm(x + y, lp["ln_x"], cfg.rms_eps)
                        y = y + _cross_decode(lp["cross"], hx,
                                              memory[idxc])
                    if ck is not None:
                        new_ck = new_ck.at[idxc, l].set(
                            jnp.where(act & valid, ckl2, ckl))
                    if cv is not None:
                        new_cv = new_cv.at[idxc, l].set(
                            jnp.where(act & valid, cvl2, cvl))
                if s.ssm_state > 0:
                    y2, hh2, tl2 = _mamba_decode(lp["mamba"], h_in,
                                                 hh[idxc, l], tl[idxc, l])
                    if cfg.layer_kind == LayerKind.HYBRID:
                        mix = 0.5 * (mix + y2)
                    else:
                        mix = y2
                    new_hh = new_hh.at[idxc, l].set(
                        jnp.where(act & valid, hh2, hh[idxc, l]))
                    new_tl = new_tl.at[idxc, l].set(
                        jnp.where(act & valid, tl2, tl[idxc, l]))
                x_new = x + mix
                if cfg.layer_kind != LayerKind.MAMBA:
                    h2 = rms_norm(x_new, lp["ln2"], cfg.rms_eps)
                    if s.n_experts > 0:
                        x_new = x_new + moe_fn(cfg, lp["moe"], h2)
                    else:
                        x_new = x_new + swiglu_apply(lp["mlp"], h2)
                x = jnp.where(act, x_new, x)
                ck, cv, hh, tl = new_ck, new_cv, new_hh, new_tl

            h_last = rms_norm(x, fn_gamma, cfg.rms_eps)
            out_ids = executor.fold_greedy_ids(
                tc, h_last, head_w, out_ids,
                model_axis=model_axis, vocab_true=s.vocab)
            return x, (ck, cv, hh, tl), out_ids

        x0 = jnp.zeros((bm, s.d_model), dt)
        ids0 = jnp.zeros((nm, bm), jnp.int32)
        program = StageProgram(n_items=nm, d_p=d_p, data_axis=data_axis,
                               tick=tick, psum_acc=True)
        xf, (ck, cv, hh, tl), out_ids = executor.run_stage_program(
            program, x0, (cache_k, cache_v, ssm_h, conv_tail), ids0)

        new_state = dict(state)
        new_state["tokens"] = out_ids.reshape(state["tokens"].shape)

        def unsq(a, ref):
            return a.reshape(ref.shape) if a is not None else None
        if "cache_k" in state:
            new_state["cache_k"] = unsq(ck, state["cache_k"])
        if "cache_v" in state:
            new_state["cache_v"] = unsq(cv, state["cache_v"])
        if "ssm_h" in state:
            new_state["ssm_h"] = unsq(hh, state["ssm_h"])
        if "conv_tail" in state:
            new_state["conv_tail"] = unsq(tl, state["conv_tail"])
        return out_ids, new_state

    return step_local


# ===========================================================================
# Continuous-batching serving engine: one stage program for chunked prefill
# AND k-token (speculative) decode over a SLOTTED KV-cache pool.
#
# The unit of work is a *packed token chunk* — the trainer's chunk
# abstraction reborn for serving. Every engine-step item is a fixed-shape
# buffer of ``cap_t`` tokens carrying per-token metadata:
#
#   tokens[t]    the token id fed at this position
#   slot[t]      the KV slot its segment owns (``n_slots`` = trash slot:
#                padding and bubble-tick writes land there)
#   pos[t]       absolute position in the owning sequence == the cache row
#                this token's KV is written to
#   seg[t]       item-local segment id (-1 = padding); intra-chunk attention
#                is same-segment causal
#   ctx_base[t]  committed cache rows of the segment's slot at step start;
#                cache attention sees rows [0, ctx_base) only
#
# A prefill chunk is a segment of prompt tokens (pos = offset..offset+c-1,
# ctx_base = offset); a decode tick is a segment of k tokens (the last
# accepted token + k-1 draft tokens, ctx_base = committed length). Both run
# the SAME compiled program: per token, attention = softmax over
# [slot-gathered cache rows ‖ intra-chunk same-segment causal rows], then
# the token's KV row is scattered into (slot, pos). Rows at pos >= ctx_base
# written by rejected drafts are invisible (masked) until overwritten.
#
# Per-stream lengths are DATA, not shape: one executable serves every
# request mix, so the engine's bucket-key set is closed
# (compile_cache.engine_bucket_key). Decode runs remat-free (static
# l_ckpt=0 — the ROADMAP's per-chunk remat-free decode item).
# ===========================================================================


@dataclass(frozen=True)
class EngineGeometry:
    """Static geometry of one compiled engine step (a serve bucket)."""
    n_items: int             # packed chunk items per engine step
    cap_t: int               # tokens per item (global; sharded over model)
    n_slots: int             # user KV slots (buffer holds n_slots + 1)
    s_cap: int               # cache rows per slot (max prompt + generated)
    k: int                   # decode tokens per stream per step (1 = greedy)
    d_p: int
    d_s: int
    layers_per_stage: int
    compute_dtype: Any = jnp.bfloat16

    @property
    def trash_slot(self) -> int:
        """Write target for padding/bubble/out-of-range rows."""
        return self.n_slots

    @property
    def dtype_name(self) -> str:
        return _dtype_name(self.compute_dtype)


def make_engine_geometry(cfg: ArchConfig, mesh: Mesh, *, n_items: int,
                         cap_t: int, n_slots: int, s_cap: int, k: int = 1,
                         compute_dtype=jnp.bfloat16) -> EngineGeometry:
    s = cfg.spec
    if s.attn_free or s.ssm_state > 0:
        raise NotImplementedError(
            "serving engine supports attention archs only (SSM/hybrid decode "
            "uses the one-shot decode_step_fn path)")
    if s.is_encoder_decoder:
        raise NotImplementedError("serving engine is decoder-only")
    if s.kv_lora_rank > 0:
        raise NotImplementedError(
            "MLA latent cache rows are not wired into the slot pool yet "
            "(see ROADMAP follow-ons)")
    pod, data, model = mesh_axis_names(mesh)
    if pod is not None:
        raise NotImplementedError("engine runs on a (data, model) mesh; "
                                  "multi-pod request routing is a ROADMAP "
                                  "follow-on")
    d_p, d_s = mesh.shape[data], mesh.shape[model]
    if cap_t % d_s:
        raise ValueError(f"cap_t={cap_t} must be divisible by the model "
                         f"axis d_s={d_s}")
    if min(n_items, cap_t, n_slots, s_cap, k) < 1:
        raise ValueError("n_items/cap_t/n_slots/s_cap/k must all be >= 1")
    if k > cap_t:
        raise ValueError(f"k={k} cannot exceed cap_t={cap_t}")
    return EngineGeometry(
        n_items=n_items, cap_t=cap_t, n_slots=n_slots, s_cap=s_cap, k=k,
        d_p=d_p, d_s=d_s,
        layers_per_stage=-(-cfg.spec.n_layers // d_p),
        compute_dtype=compute_dtype)


def engine_pool_struct(cfg: ArchConfig, geom: EngineGeometry) -> Dict:
    """Global ShapeDtypeStructs of the slotted KV pool: per stage (d_p over
    "data"), per layer, ``n_slots + 1`` slots (last = trash) of ``s_cap``
    rows, replicated over the model axis (every rank owns full rows and
    performs every write — sequence-sharding the pool is the paged-attention
    follow-on)."""
    s = cfg.spec
    shape = (geom.d_p, geom.layers_per_stage, geom.n_slots + 1, geom.s_cap,
             s.n_kv_heads, s.head_dim)
    st = jax.ShapeDtypeStruct(shape, geom.compute_dtype)
    return {"cache_k": st, "cache_v": st}


def engine_pool_specs(data: str = "data") -> Dict:
    p = P(data, None, None, None, None, None)
    return {"cache_k": p, "cache_v": p}


def engine_batch_struct(geom: EngineGeometry) -> Dict:
    """Per-step packed chunk buffers (global shapes; token dim sharded over
    the model axis like the trainer's chunk buffers)."""
    n, c = geom.n_items, geom.cap_t
    st = jax.ShapeDtypeStruct((n, c), jnp.int32)
    return {"tokens": st, "slot": st, "pos": st, "seg": st, "ctx_base": st}


def _engine_attention(q, k_cache, v_cache, k_intra, v_intra, ok_cache,
                      ok_intra, *, scale):
    """Per-token attention over [slot cache rows ‖ intra-chunk rows].

    q: [T, Hq, Dh]; k/v_cache: [T, S, Hkv, Dh] (rows gathered per token by
    slot); k/v_intra: [C, Hkv, Dh] (the whole chunk, all ranks);
    ok_cache: [T, S] bool; ok_intra: [T, C] bool. One softmax over the
    concatenated row axis — no cross-source LSE merge needed because both
    sources are fully resident. Returns [T, Hq, Dh]."""
    Hq, Hkv = q.shape[1], k_intra.shape[1]
    if Hkv != Hq:
        rep = Hq // Hkv
        k_cache = jnp.repeat(k_cache, rep, axis=2)
        v_cache = jnp.repeat(v_cache, rep, axis=2)
        k_intra = jnp.repeat(k_intra, rep, axis=1)
        v_intra = jnp.repeat(v_intra, rep, axis=1)
    qf = q.astype(jnp.float32)
    s_c = jnp.einsum("thd,tshd->ths", qf,
                     k_cache.astype(jnp.float32)) * scale
    s_i = jnp.einsum("thd,shd->ths", qf,
                     k_intra.astype(jnp.float32)) * scale
    s_c = jnp.where(ok_cache[:, None, :], s_c, -1e30)
    s_i = jnp.where(ok_intra[:, None, :], s_i, -1e30)
    s_all = jnp.concatenate([s_c, s_i], axis=-1)
    m = s_all.max(axis=-1)
    p = jnp.exp(s_all - m[..., None])
    l = p.sum(axis=-1)
    n_s = s_c.shape[-1]
    acc = jnp.einsum("ths,tshd->thd", p[..., :n_s],
                     v_cache.astype(jnp.float32))
    acc = acc + jnp.einsum("ths,shd->thd", p[..., n_s:],
                           v_intra.astype(jnp.float32))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.astype(q.dtype)


def engine_step_fn(cfg: ArchConfig, geom: EngineGeometry, shard_dims, *,
                   data_axis: str = "data",
                   model_axis: str = "model") -> Callable:
    """Returns step_local(params, pool, batch) -> (ids [n, cap_loc], pool');
    call inside shard_map. ``ids[i, t]`` is the greedy next-token id after
    consuming batch token ``(i, t)`` (the same fold the prefill path uses);
    the host reads decode/prefill outputs at its packed offsets."""
    s = cfg.spec
    L_s, d_p, d_s = geom.layers_per_stage, geom.d_p, geom.d_s
    n = geom.n_items
    dt = geom.compute_dtype
    windows_all, active_all = _layer_tables(cfg, d_p, L_s)
    scale = 1.0 / math.sqrt(s.head_dim)
    moe_fn = None
    if s.n_experts > 0:
        from .ep import make_moe_ep
        moe_fn = make_moe_ep(model_axis, d_s)

    def step_local(params, pool, batch):
        p_idx = jax.lax.axis_index(data_axis)
        stage_params = jax.tree.map(lambda x: x[0], params["stages"])
        windows = windows_all[p_idx]
        active = active_all[p_idx]
        fn_gamma = params["final_norm"]
        if fn_gamma.shape[0] != s.d_model:
            fn_gamma = jax.lax.all_gather(fn_gamma, model_axis, axis=0,
                                          tiled=True)
        head_w = params.get("unembed", params["embed"])
        cap_loc = batch["tokens"].shape[-1]

        tokens_a = batch["tokens"].reshape(n, cap_loc)
        slot_a = batch["slot"].reshape(n, cap_loc)
        pos_a = batch["pos"].reshape(n, cap_loc)
        seg_a = batch["seg"].reshape(n, cap_loc)
        base_a = batch["ctx_base"].reshape(n, cap_loc)

        # local pool view: drop the stage dim sharded over "data"
        ck0 = pool["cache_k"].reshape(pool["cache_k"].shape[1:])
        cv0 = pool["cache_v"].reshape(pool["cache_v"].shape[1:])
        rows = jnp.arange(geom.s_cap)
        big = jnp.int32(2 ** 30)

        def tick(tc, x_recv, state, ids_acc):
            ck, cv = state
            idxc = tc.idxc
            tok = tokens_a[idxc]
            seg_l = jnp.where(tc.valid, seg_a[idxc], -1)
            pos_l = pos_a[idxc]
            slot_l = slot_a[idxc]
            base_l = base_a[idxc]
            # full-chunk metadata: intra attention + the replicated writes
            # need every rank to see all cap_t rows
            seg_g = jax.lax.all_gather(seg_l, model_axis, axis=0, tiled=True)
            pos_g = jax.lax.all_gather(pos_l, model_axis, axis=0, tiled=True)
            slot_g = jax.lax.all_gather(slot_l, model_axis, axis=0,
                                        tiled=True)

            x_emb = sp.sharded_embed(params["embed"], tok, model_axis, dt)
            if cfg.embed_scale:
                x_emb = x_emb * jnp.asarray(s.d_model ** 0.5, dt)
            x = jnp.where(tc.is_first_stage, x_emb, x_recv)

            def layer_body(x, per_layer):
                lp, w, act, ck_l, cv_l = per_layer
                lp = gather_layer_params(lp, shard_dims, model_axis)
                h_in = rms_norm(x, lp["ln1"], cfg.rms_eps)
                q, k_new, v_new = project_qkv(cfg, lp["attn"], h_in, pos_l)
                k_g = jax.lax.all_gather(k_new, model_axis, axis=0,
                                         tiled=True)
                v_g = jax.lax.all_gather(v_new, model_axis, axis=0,
                                         tiled=True)
                w_eff = jnp.where(w > 0, w, big)
                # cache rows: committed prefix of my slot, window-masked
                ok_c = (rows[None, :] < base_l[:, None]) \
                    & (seg_l >= 0)[:, None] \
                    & ((pos_l[:, None] - rows[None, :]) < w_eff)
                # intra-chunk: same segment, causal, window-masked
                ok_i = (seg_g[None, :] == seg_l[:, None]) \
                    & (seg_l >= 0)[:, None] \
                    & (pos_g[None, :] <= pos_l[:, None]) \
                    & ((pos_l[:, None] - pos_g[None, :]) < w_eff)
                out = _engine_attention(q, ck_l[slot_l], cv_l[slot_l],
                                        k_g, v_g, ok_c, ok_i, scale=scale)
                y = jnp.einsum("th,hd->td", out.reshape(out.shape[0], -1),
                               lp["attn"]["wo"].astype(x.dtype))
                # scatter the chunk's KV rows into (slot, pos); padding,
                # bubble ticks, inactive layer slots and out-of-range rows
                # all land in the trash slot
                w_ok = (seg_g >= 0) & tc.valid & act \
                    & (pos_g < geom.s_cap)
                slot_w = jnp.where(w_ok, slot_g, geom.trash_slot)
                row_w = jnp.clip(pos_g, 0, geom.s_cap - 1)
                ck_l = ck_l.at[slot_w, row_w].set(k_g.astype(ck_l.dtype))
                cv_l = cv_l.at[slot_w, row_w].set(v_g.astype(cv_l.dtype))
                x_new = x + y
                h2 = rms_norm(x_new, lp["ln2"], cfg.rms_eps)
                if s.n_experts > 0:
                    x_new = x_new + moe_fn(cfg, lp["moe"], h2)
                else:
                    x_new = x_new + swiglu_apply(lp["mlp"], h2)
                x = jnp.where(act, x_new, x)
                return x, (ck_l, cv_l)

            # remat-free: serving never differentiates, so l_ckpt=0 keeps
            # the plain single-scan layer path
            x_out, (ck, cv) = executor.run_stage_layers(
                layer_body, x, (stage_params, windows, active, ck, cv),
                l_ckpt=0, n_layers=L_s)
            h_last = rms_norm(x_out, fn_gamma, cfg.rms_eps)
            ids_acc = executor.fold_greedy_ids(
                tc, h_last, head_w, ids_acc,
                model_axis=model_axis, vocab_true=s.vocab,
                token_sharded=True)
            return x_out, (ck, cv), ids_acc

        x0 = jnp.zeros((cap_loc, s.d_model), dt)
        ids0 = jnp.zeros((n, cap_loc), jnp.int32)
        program = StageProgram(n_items=n, d_p=d_p, data_axis=data_axis,
                               tick=tick, psum_acc=True)
        _, (ck, cv), ids = executor.run_stage_program(
            program, x0, (ck0, cv0), ids0)
        new_pool = {"cache_k": ck.reshape(pool["cache_k"].shape),
                    "cache_v": cv.reshape(pool["cache_v"].shape)}
        return ids, new_pool

    return step_local


@dataclass
class EngineStepBuilder:
    """Builds the AOT-compiled engine step for a mesh + engine geometry.

    AOT (``lower().compile()``) so the executable is serializable into the
    persistent :class:`~repro.runtime.cache_store.CacheStore` — a serving
    restart warm-starts its (single) engine bucket."""
    cfg: ArchConfig
    mesh: Mesh
    geom: EngineGeometry
    param_dtype: Any = jnp.float32

    def __post_init__(self):
        self.pod_axis, self.data_axis, self.model_axis = \
            mesh_axis_names(self.mesh)
        if self.pod_axis is not None:
            raise NotImplementedError("engine runs on a (data, model) mesh")

    # ------------------------------------------------------------------
    def init_params(self, key) -> Dict:
        raw = DecoderLM(self.cfg).init(key, jnp.float32)
        return prepare_params(self.cfg, raw, self.mesh, self.param_dtype)

    def abstract_params(self, key=None) -> Dict:
        key = key if key is not None else jax.random.PRNGKey(0)
        return jax.eval_shape(lambda k: self.init_params(k), key)

    def init_pool(self) -> Dict:
        return {k: jnp.zeros(v.shape, v.dtype)
                for k, v in engine_pool_struct(self.cfg, self.geom).items()}

    # ------------------------------------------------------------------
    def build(self, params_shape=None):
        params_shape = params_shape or self.abstract_params()
        pspecs = param_pspecs(self.cfg, params_shape, self.mesh)
        shard_dims = shard_dim_tree(params_shape["stages"],
                                    self.mesh.shape[self.model_axis])
        from .sharding import batch_specs
        bspecs = batch_specs(engine_batch_struct(self.geom), pod=None,
                             model=self.model_axis)
        poolspecs = engine_pool_specs(self.data_axis)
        fn = engine_step_fn(self.cfg, self.geom, shard_dims,
                            data_axis=self.data_axis,
                            model_axis=self.model_axis)
        mapped = shard_map_compat(
            fn, mesh=self.mesh,
            in_specs=(pspecs, poolspecs, bspecs),
            out_specs=(P(None, self.model_axis), poolspecs),
            check_vma=False)
        pool_struct = engine_pool_struct(self.cfg, self.geom)
        batch_struct_ = engine_batch_struct(self.geom)
        return jax.jit(mapped).lower(
            params_shape, pool_struct, batch_struct_).compile()
