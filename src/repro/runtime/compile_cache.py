"""First-class plan-bucket compile cache.

The planner emits a fresh :class:`~repro.core.plan.ExecutionPlan` every
step, but plans land in a small number of *buckets* — chunk-count rounded
up, capacity rounded to the SP degree, context capacity rounded to the
capacity (§III: "emit bucketed chunk geometry so the compiled program is
reused"). One bucket = one compiled executable; this module owns the
bucket-key -> executable mapping that used to live as private helpers in
``launch/train.py``, and is reused by ``launch/serve.py`` and
``launch/dryrun.py``.

Deliberately jax-free: keys are plain tuples (from
``ExecutionPlan.bucket_key()`` or :func:`decode_bucket_key`) and values are
whatever the builder returns (a jit'd step, a (builder, step) pair, a
compiled lowering). Hit/miss/eviction/compile-time statistics are kept per
cache and aggregated process-wide (:func:`global_cache_stats`) so the
train-loop log, ``launch/analysis.py`` and ``benchmarks/run.py`` can all
surface them.
"""

from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Hashable, List, Optional, Tuple

__all__ = ["CacheStats", "CompileCache", "decode_bucket_key",
           "global_cache_stats", "reset_global_caches"]

# every live cache registers here so process-wide stats can be aggregated
_REGISTRY: List["CompileCache"] = []


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    compile_seconds: float = 0.0
    compile_seconds_per_key: Dict[str, float] = field(default_factory=dict)

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    def as_dict(self) -> Dict[str, Any]:
        return {
            "buckets_compiled": self.misses,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "hit_rate": round(self.hit_rate, 4),
            "compile_seconds": round(self.compile_seconds, 3),
        }

    def summary(self) -> str:
        return (f"buckets={self.misses} hits={self.hits} "
                f"hit_rate={self.hit_rate:.2%} "
                f"evictions={self.evictions} "
                f"compile_s={self.compile_seconds:.2f}")


class CompileCache:
    """LRU cache from bucket key -> compiled artifact, with stats.

    ``capacity=None`` means unbounded (the train loop's default — bucket
    geometry converges to a handful of keys). A bounded cache evicts the
    least-recently-used executable, which XLA then garbage-collects with
    the last reference.
    """

    def __init__(self, name: str = "default",
                 capacity: Optional[int] = None,
                 log: Optional[Callable[[str], None]] = None):
        if capacity is not None and capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.name = name
        self.capacity = capacity
        self.log = log
        self.stats = CacheStats()
        self._entries: "OrderedDict[Hashable, Any]" = OrderedDict()
        _REGISTRY.append(self)

    # ------------------------------------------------------------------
    def __contains__(self, key: Hashable) -> bool:
        return key in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    def keys(self) -> Tuple[Hashable, ...]:
        return tuple(self._entries.keys())

    # ------------------------------------------------------------------
    def get(self, key: Hashable, build: Callable[[], Any]) -> Any:
        """Return the cached artifact for ``key``, building (and timing)
        it on a miss."""
        if key in self._entries:
            self.stats.hits += 1
            self._entries.move_to_end(key)
            return self._entries[key]
        self.stats.misses += 1
        t0 = time.perf_counter()
        value = build()
        dt = time.perf_counter() - t0
        self.stats.compile_seconds += dt
        self.stats.compile_seconds_per_key[repr(key)] = round(dt, 3)
        self._entries[key] = value
        if self.log:
            self.log(f"[compile:{self.name}] bucket {key} ({dt:.2f}s)")
        if self.capacity is not None:
            while len(self._entries) > self.capacity:
                evicted, _ = self._entries.popitem(last=False)
                self.stats.evictions += 1
                if self.log:
                    self.log(f"[compile:{self.name}] evict {evicted}")
        return value

    def clear(self) -> None:
        self._entries.clear()


def decode_bucket_key(geom) -> Tuple:
    """Bucket key for a pipelined-decode executable: the static decode
    geometry (one compiled program per (batch, cache-length) bucket)."""
    return ("decode", geom.batch_per_pod, geom.cache_len, geom.d_p,
            geom.d_s, geom.n_micro)


def global_cache_stats() -> Dict[str, Any]:
    """Aggregate stats over every cache created in this process, plus the
    per-cache breakdown — the shape benchmarks/run.py emits as JSON."""
    agg = CacheStats()
    per_cache = {}
    for c in _REGISTRY:
        agg.hits += c.stats.hits
        agg.misses += c.stats.misses
        agg.evictions += c.stats.evictions
        agg.compile_seconds += c.stats.compile_seconds
        per_cache[c.name] = c.stats.as_dict()
    out = agg.as_dict()
    out["caches"] = per_cache
    return out


def reset_global_caches() -> None:
    """Drop the registry (tests; a fresh train run in the same process)."""
    _REGISTRY.clear()
