"""First-class plan-bucket compile cache.

The planner emits a fresh :class:`~repro.core.plan.ExecutionPlan` every
step, but plans land in a small number of *buckets* — schedule backend,
chunk-count rounded up, capacity rounded to the SP degree, context capacity
rounded to the capacity (§III: "emit bucketed chunk geometry so the
compiled program is reused"). One bucket = one compiled executable; this
module owns the bucket-key -> executable mapping that used to live as
private helpers in ``launch/train.py``, and is reused by
``launch/serve.py`` and ``launch/dryrun.py``.

Deliberately jax-free: keys are plain tuples (from
``ExecutionPlan.bucket_key()`` or :func:`decode_bucket_key`) and values are
whatever the builder returns (a jit'd step, a (builder, step) pair, a
compiled lowering). Hit/miss/eviction/compile-time statistics are kept per
cache and aggregated process-wide (:func:`global_cache_stats`) so the
train-loop log, ``launch/analysis.py`` and ``benchmarks/run.py`` can all
surface them.

A cache may be backed by a persistent ``store`` (duck-typed; see
``runtime/cache_store.CacheStore`` for the disk+JAX-AOT implementation):
an in-memory miss first consults ``store.load(key)`` — success is a
**warm hit** (``CacheStats.warm_hits``, no fresh compile) — and every
fresh compile is offered to ``store.save(...)`` so the NEXT process
restart warm-starts. The store decides validity (fingerprint, integrity);
the cache only distinguishes warm hits from cold compiles.

Eviction is LRU by default; ``eviction="cost"`` weights the choice by
each resident bucket's rebuild cost (``compile_seconds_per_key``), so
cheap-to-rebuild buckets — including warm-loaded ones, whose rebuild cost
is a disk reload — are evicted first, with LRU order as the tie-break.

The process-wide registry holds caches *weakly*: a cache (and every
executable it pins) is freed with its last strong reference, so repeated
in-process train/serve runs do not leak executables through the stats
aggregation. Live-bucket count and recompile count are tracked separately —
``misses`` over-counts live buckets as soon as a bounded cache evicts and
recompiles a key — and per-key compile-second stats are pruned on eviction
so they cannot grow without bound.
"""

from __future__ import annotations

import threading
import time
import weakref
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Hashable, Optional, Set, Tuple

__all__ = ["CacheStats", "CompileCache", "decode_bucket_key",
           "engine_bucket_key", "engine_copy_bucket_key",
           "global_cache_stats", "reset_global_caches"]

# every live cache registers here (weakly) so process-wide stats can be
# aggregated without keeping dead caches — and their executables — alive
_REGISTRY: "weakref.WeakSet[CompileCache]" = weakref.WeakSet()


@dataclass
class CacheStats:
    hits: int = 0
    warm_hits: int = 0          # misses served from the persistent store
    misses: int = 0             # cold compiles (store had nothing valid)
    evictions: int = 0
    cleared: int = 0            # resident executables dropped by clear()
    recompiles: int = 0         # misses on keys that were compiled before
    buckets_live: int = 0       # executables currently resident
    compile_seconds: float = 0.0
    # program-auditor results over this cache's cold compiles (the
    # CompileCache(lint=...) hook; see src/repro/lint/)
    lint_findings: int = 0      # total findings across audited compiles
    lint_errors: int = 0        # error-severity subset
    # per-key REBUILD cost of the RESIDENT buckets (pruned on eviction):
    # compile time for cold-compiled buckets, store reload time for
    # warm-loaded ones — the weight cost-aware eviction minimizes losing
    compile_seconds_per_key: Dict[str, float] = field(default_factory=dict)

    @property
    def lookups(self) -> int:
        return self.hits + self.warm_hits + self.misses

    @property
    def hit_rate(self) -> float:
        """Fraction of lookups that avoided a fresh compile — in-memory
        hits AND store warm hits both count (misses are the cold
        compiles)."""
        if not self.lookups:
            return 0.0
        return (self.hits + self.warm_hits) / self.lookups

    def as_dict(self) -> Dict[str, Any]:
        return {
            "buckets_live": self.buckets_live,
            "recompiles": self.recompiles,
            "hits": self.hits,
            "warm_hits": self.warm_hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "cleared": self.cleared,
            "hit_rate": round(self.hit_rate, 4),
            "compile_seconds": round(self.compile_seconds, 3),
            "lint_findings": self.lint_findings,
            "lint_errors": self.lint_errors,
        }

    def summary(self) -> str:
        return (f"buckets={self.buckets_live} hits={self.hits} "
                f"warm_hits={self.warm_hits} "
                f"hit_rate={self.hit_rate:.2%} "
                f"evictions={self.evictions} "
                f"recompiles={self.recompiles} "
                f"compile_s={self.compile_seconds:.2f} "
                f"lint_findings={self.lint_findings}")


class CompileCache:
    """LRU cache from bucket key -> compiled artifact, with stats.

    ``capacity=None`` means unbounded (the train loop's default — bucket
    geometry converges to a handful of keys). A bounded cache evicts the
    least-recently-used executable (``eviction="lru"``) or the
    cheapest-to-rebuild one (``eviction="cost"``); XLA garbage-collects
    the executable with its last reference.

    ``store`` (optional) is a persistent backend with ``load(key) ->
    value | None`` and ``save(key, value, compile_seconds=...)`` — see
    ``runtime/cache_store.CacheStore``.

    ``lint`` (optional) is the program-auditor hook (``repro.lint
    .make_cache_lint``): called as ``lint(key, value)`` on every COLD
    compile, before the artifact enters the cache or the store. It
    returns a report whose finding counts land in ``CacheStats``
    (``lint_findings``/``lint_errors``) — or raises ``LintError`` in
    ``--lint error`` mode, in which case the hazardous executable is
    neither cached nor persisted. Hits and store warm-starts are never
    re-audited: a bucket is linted once, when it is born.
    """

    _COMPILED_KEYS_CAP = 65536

    def __init__(self, name: str = "default",
                 capacity: Optional[int] = None,
                 log: Optional[Callable[[str], None]] = None,
                 store: Optional[Any] = None,
                 eviction: str = "lru",
                 lint: Optional[Callable[[Hashable, Any], Any]] = None):
        if capacity is not None and capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if eviction not in ("lru", "cost"):
            raise ValueError(f"eviction must be 'lru' or 'cost', "
                             f"got {eviction!r}")
        self.name = name
        self.capacity = capacity
        self.log = log
        self.store = store
        self.eviction = eviction
        self.lint = lint
        self.stats = CacheStats()
        self._entries: "OrderedDict[Hashable, Any]" = OrderedDict()
        self._compiled_keys: Set[Hashable] = set()
        # dict/stats mutations are lock-protected so a background
        # precompile (telemetry/replan.py warming a fresh bucket
        # off-thread) can share the cache with the training loop; builds
        # and store I/O run OUTSIDE the lock — a hit never waits on a
        # concurrent compile
        self._lock = threading.RLock()
        _REGISTRY.add(self)

    # ------------------------------------------------------------------
    def __contains__(self, key: Hashable) -> bool:
        return key in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    def keys(self) -> Tuple[Hashable, ...]:
        return tuple(self._entries.keys())

    # ------------------------------------------------------------------
    def _evict_victim(self) -> Hashable:
        """Pick the entry to drop: LRU, or under ``eviction="cost"`` the
        cheapest-to-rebuild resident bucket (LRU order breaks ties). The
        most-recently-inserted entry is never the victim."""
        keys = list(self._entries.keys())
        candidates = keys[:-1] if len(keys) > 1 else keys
        if self.eviction == "cost":
            per_key = self.stats.compile_seconds_per_key
            return min(enumerate(candidates),
                       key=lambda ik: (per_key.get(repr(ik[1]), 0.0),
                                       ik[0]))[1]
        return candidates[0]

    def _enforce_capacity(self) -> None:
        if self.capacity is None:
            return
        while len(self._entries) > self.capacity:
            victim = self._evict_victim()
            del self._entries[victim]
            self.stats.evictions += 1
            self.stats.compile_seconds_per_key.pop(repr(victim), None)
            if self.log:
                self.log(f"[compile:{self.name}] evict {victim}")

    # ------------------------------------------------------------------
    def get(self, key: Hashable, build: Callable[[], Any]) -> Any:
        """Return the cached artifact for ``key``: resident -> hit;
        otherwise try the persistent store (warm hit, no compile);
        otherwise ``build()`` (cold compile, timed, offered to the
        store). Safe to call from a background thread concurrently with
        the training loop: builds and store I/O happen outside the lock,
        so a resident hit never waits on another thread's compile (two
        threads cold-building the SAME key may both compile; the first
        insert wins)."""
        with self._lock:
            if key in self._entries:
                self.stats.hits += 1
                self._entries.move_to_end(key)
                return self._entries[key]

        if self.store is not None:
            t0 = time.perf_counter()
            value = self.store.load(key)
            if value is not None:
                dt = time.perf_counter() - t0
                with self._lock:
                    if key in self._entries:  # raced with another loader
                        self.stats.hits += 1
                        self._entries.move_to_end(key)
                        return self._entries[key]
                    self.stats.warm_hits += 1
                    # rebuild cost of a warm bucket is a disk reload
                    self.stats.compile_seconds_per_key[repr(key)] = \
                        round(dt, 3)
                    if len(self._compiled_keys) < self._COMPILED_KEYS_CAP:
                        # a later cold rebuild of this key (evicted AND its
                        # store entry gone) must still count as a recompile
                        self._compiled_keys.add(key)
                    self._entries[key] = value
                    self._enforce_capacity()
                    self.stats.buckets_live = len(self._entries)
                if self.log:
                    self.log(f"[compile:{self.name}] warm-start bucket "
                             f"{key} ({dt:.2f}s load, no compile)")
                return value

        with self._lock:
            if key in self._entries:  # raced during the store probe
                self.stats.hits += 1
                self._entries.move_to_end(key)
                return self._entries[key]
            self.stats.misses += 1
            if key in self._compiled_keys:
                self.stats.recompiles += 1
            elif len(self._compiled_keys) < self._COMPILED_KEYS_CAP:
                # bounded recompile tracking: beyond the cap (far past any
                # real bucket churn) new keys go uncounted rather than
                # growing this set for the life of the cache — recompiles
                # become a lower bound instead of a leak
                self._compiled_keys.add(key)
        t0 = time.perf_counter()
        value = build()
        dt = time.perf_counter() - t0
        if self.lint is not None:
            # audit the newborn program BEFORE it becomes reusable state:
            # in error mode the hook raises and the executable is neither
            # cached nor persisted
            report = self.lint(key, value)
            if report is not None:
                with self._lock:
                    n = len(report.findings)
                    self.stats.lint_findings += n
                    self.stats.lint_errors += len(report.errors)
                if n and self.log:
                    self.log(f"[compile:{self.name}] lint: "
                             f"{report.summary()}")
        with self._lock:
            self.stats.compile_seconds += dt
            self.stats.compile_seconds_per_key[repr(key)] = round(dt, 3)
            self._entries[key] = value
            self._enforce_capacity()
            self.stats.buckets_live = len(self._entries)
        if self.log:
            self.log(f"[compile:{self.name}] bucket {key} ({dt:.2f}s)")
        if self.store is not None:
            self.store.save(key, value, compile_seconds=dt)
        return value

    def clear(self, reset_stats: bool = False) -> None:
        """Drop every resident executable — observably: the number of
        entries dropped is added to ``stats.cleared`` so a later
        ``global_cache_stats()`` read accounts for where the resident
        executables went. ``reset_stats=True`` also zeroes the counters
        and the compiled-key history (a fresh run in the same process);
        otherwise hit/miss history survives — including which keys were
        compiled before, so a post-clear rebuild still counts as a
        recompile."""
        with self._lock:
            dropped = len(self._entries)
            self._entries.clear()
            if reset_stats:
                self._compiled_keys.clear()
                self.stats = CacheStats()
                return
            self.stats.cleared += dropped
            self.stats.buckets_live = 0
            self.stats.compile_seconds_per_key.clear()
        if dropped and self.log:
            self.log(f"[compile:{self.name}] cleared {dropped} "
                     f"resident executables")

    def deregister(self) -> None:
        """Remove this cache from the process-wide stats registry (it keeps
        working as a plain cache). The weak registry already drops a cache
        with its last reference; this is for module-global caches that
        should stop contributing to :func:`global_cache_stats` early."""
        _REGISTRY.discard(self)


def decode_bucket_key(geom) -> Tuple:
    """Bucket key for a pipelined-decode executable: the static decode
    geometry (one compiled program per (batch, cache-length) bucket).
    ``cache_len`` and the compute dtype are both part of executable
    identity — a decode step compiled for one context size must never be
    handed a state of another."""
    return ("decode", geom.batch_per_pod, geom.cache_len, geom.d_p,
            geom.d_s, geom.n_micro, getattr(geom, "dtype_name", "bfloat16"))


def engine_bucket_key(geom) -> Tuple:
    """Bucket key for a serving-engine step executable. The engine's whole
    point is that this set is CLOSED: per-request lengths are data, so one
    (items, cap_t, pages, page_sz, pages_per_seq, k) geometry serves every
    request mix and the second pass over any trace compiles nothing."""
    return ("engine", geom.n_items, geom.cap_t, geom.n_pages, geom.page_sz,
            geom.pages_per_seq, geom.k, geom.d_p, geom.d_s, geom.dtype_name)


def engine_copy_bucket_key(geom) -> Tuple:
    """Bucket key for the engine's copy-on-write page-copy executable —
    the second (and last) member of the closed serve bucket set. Identity
    is the pool layout plus ``copy_cap`` (pairs per call)."""
    return ("engine-copy", geom.n_pages, geom.page_sz, geom.copy_cap,
            geom.d_p, geom.d_s, geom.dtype_name)


def global_cache_stats() -> Dict[str, Any]:
    """Aggregate stats over every LIVE cache in this process, plus the
    per-cache breakdown — the shape benchmarks/run.py emits as JSON.
    Caches with a persistent store also report the store block
    (entries, size, stale/corrupt skips)."""
    agg = CacheStats()
    per_cache = {}
    for c in list(_REGISTRY):
        agg.hits += c.stats.hits
        agg.warm_hits += c.stats.warm_hits
        agg.misses += c.stats.misses
        agg.evictions += c.stats.evictions
        agg.cleared += c.stats.cleared
        agg.recompiles += c.stats.recompiles
        agg.buckets_live += c.stats.buckets_live
        agg.compile_seconds += c.stats.compile_seconds
        agg.lint_findings += c.stats.lint_findings
        agg.lint_errors += c.stats.lint_errors
        d = c.stats.as_dict()
        if c.store is not None and hasattr(c.store, "report"):
            d["store"] = c.store.report()
        per_cache[c.name] = d
    out = agg.as_dict()
    out["caches"] = per_cache
    return out


def reset_global_caches() -> None:
    """Drop the registry (tests; a fresh train run in the same process)."""
    _REGISTRY.clear()
