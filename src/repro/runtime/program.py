"""StageProgram IR: the backend-independent description of one scanned
1F1B stage program.

Every EPP executable in this repo — decoder-only training/prefill
(``runtime/pipeline.py``), pipelined encoder-decoder training
(``runtime/encdec_pipeline.py``) and pipelined decode
(``runtime/serve_step.py``) — is the *same* machine: a ``lax.scan`` over
``n_items + d_p - 1`` ticks in which every pipeline stage

  1. selects its work item for this tick (``idx = t - p_idx``; out-of-range
     ticks are bubbles computing on masked garbage),
  2. runs its stage body (inject first-stage input, advance the per-stage
     state — KV/SSM context carry or decode cache),
  3. folds the last stage's output into an accumulator (streaming CE,
     greedy ids), and
  4. hands its streamed activations to the right neighbor via a
     left-to-right ``ppermute``.

``StageProgram`` captures exactly that decomposition; the engine that runs
it lives in ``runtime/executor.py``. Backends differ only in their ``tick``
hook — which streams flow between stages (one hidden state; an
(h_enc, h_dec) pair), what the per-stage state is, and what gets folded.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

__all__ = ["TickContext", "StageProgram"]


@dataclass(frozen=True)
class TickContext:
    """Per-tick coordinates handed to the backend's ``tick`` hook.

    ``t``/``idx``/``idxc``/``valid``/``p_idx`` are traced scalars inside the
    scan; ``n_items``/``d_p`` are the static geometry they derive from.
    """

    t: Any            # global tick index in [0, n_items + d_p - 1)
    idx: Any          # this stage's item index: t - p_idx (may be out of range)
    idxc: Any         # idx clipped to [0, n_items) — safe to gather with
    valid: Any        # bool: idx in range (False => bubble tick)
    p_idx: Any        # this stage's index along the pipeline ("data") axis
    n_items: int      # chunks (train/prefill) or microbatches (decode)
    d_p: int          # pipeline depth

    @property
    def is_first_stage(self):
        return self.p_idx == 0

    @property
    def is_last_stage(self):
        return self.p_idx == self.d_p - 1


@dataclass(frozen=True)
class StageProgram:
    """One compiled stage program (a plan bucket's executable schedule).

    tick(tc, streams, state, acc) -> (streams, state, acc)
      * ``streams``: the pytree that rides the stage-to-stage ppermute
        (hidden state(s) of the chunk in flight). The engine permutes every
        leaf left-to-right after the hook returns.
      * ``state``: per-stage resident state that does NOT move between
        stages (split-chunk KV/SSM context carry, decode caches).
      * ``acc``: the output accumulator (streaming-CE partial sums, decoded
        ids). Psummed over the pipeline axis at the end when ``psum_acc``.
    """

    n_items: int
    d_p: int
    data_axis: str
    tick: Callable[..., Any]
    psum_acc: bool = True

    @property
    def n_ticks(self) -> int:
        return self.n_items + self.d_p - 1
