"""StageProgram IR: the backend-independent description of one scanned
pipeline stage program.

Every EPP executable in this repo — decoder-only training/prefill
(``runtime/pipeline.py``), pipelined encoder-decoder training
(``runtime/encdec_pipeline.py``) and pipelined decode
(``runtime/serve_step.py``) — is the *same* machine: a ``lax.scan`` over
the schedule backend's tick count in which every pipeline stage

  1. selects its work item for this tick (the schedule backend's
     ``tick_coords`` mapping; out-of-range ticks are bubbles computing on
     masked garbage),
  2. runs its stage body (inject first-stage input, advance the per-stage
     state — KV/SSM context carry or decode cache),
  3. folds the last stage's output into an accumulator (streaming CE,
     greedy ids), and
  4. hands its streamed activations to the right neighbor via a
     left-to-right ``ppermute``.

``StageProgram`` captures exactly that decomposition; the engine that runs
it lives in ``runtime/executor.py``. Backends differ along two independent
axes:

* the ``tick`` hook — which streams flow between stages (one hidden state;
  an (h_enc, h_dec) pair), what the per-stage state is, what gets folded;
* the **schedule backend** (``schedule`` + ``v``, resolved against
  ``repro.core.schedule``'s registry) — how ticks map to ``(item,
  virtual stage)`` pairs: ``gpipe-1f1b`` (the classic ``idx = t - p``
  diagonal), ``interleaved-1f1b`` (each device hosts ``v`` virtual stages
  riding the same ppermute ring), ``zero-bubble-h1`` (1F1B tick shape; the
  B-grad/W-grad split lives in the solver's bubble model — see
  runtime/README.md).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

from repro.core.schedule import get_schedule

__all__ = ["TickContext", "StageProgram"]


@dataclass(frozen=True)
class TickContext:
    """Per-tick coordinates handed to the backend's ``tick`` hook.

    ``t``/``idx``/``idxc``/``valid``/``p_idx``/``v_idx`` are traced scalars
    inside the scan (``v_idx`` stays the python int 0 when ``v == 1`` so
    single-virtual-stage programs trace exactly as before);
    ``n_items``/``d_p``/``v`` are the static geometry they derive from.
    """

    t: Any            # global tick index in [0, n_ticks)
    idx: Any          # this stage's item index for this tick (may be invalid)
    idxc: Any         # idx clipped to [0, n_items) — safe to gather with
    valid: Any        # bool: idx in range (False => bubble tick)
    p_idx: Any        # this stage's index along the pipeline ("data") axis
    n_items: int      # chunks (train/prefill) or microbatches (decode)
    d_p: int          # pipeline depth (devices)
    v_idx: Any = 0    # local virtual-stage index in [0, v)
    v: int = 1        # virtual stages per device

    @property
    def is_first_stage(self):
        """First *virtual* stage of the pipeline (stream injection point)."""
        if self.v == 1:
            return self.p_idx == 0
        return (self.p_idx == 0) & (self.v_idx == 0)

    @property
    def is_last_stage(self):
        """Last *virtual* stage of the pipeline (output folding point)."""
        if self.v == 1:
            return self.p_idx == self.d_p - 1
        return (self.p_idx == self.d_p - 1) & (self.v_idx == self.v - 1)


@dataclass(frozen=True)
class StageProgram:
    """One compiled stage program (a plan bucket's executable schedule).

    tick(tc, streams, state, acc) -> (streams, state, acc)
      * ``streams``: the pytree that rides the stage-to-stage ppermute
        (hidden state(s) of the chunk in flight). The engine permutes every
        leaf left-to-right after the hook returns — around the full ring
        when ``v > 1`` (the wrap carries a chunk from device ``d_p - 1``
        back to device 0's next virtual stage).
      * ``state``: per-stage resident state that does NOT move between
        stages (split-chunk KV/SSM context carry, decode caches). With
        ``v > 1`` its leaves carry one slice per virtual stage.
      * ``acc``: the output accumulator (streaming-CE partial sums, decoded
        ids). Psummed over the pipeline axis at the end when ``psum_acc``.

    ``schedule``/``v`` name the schedule backend in
    ``repro.core.schedule``'s registry; the engine mirrors its
    ``tick_coords`` mapping in traced arithmetic and runs ``spec.
    scan_ticks(n_items, d_p)`` ticks.

    Optional hooks extending the tick map (see
    ``executor.run_stage_program``):

    * ``fold(tc, streams, state, acc) -> acc`` — double-buffered hand-off:
      when set, the tick hook must NOT touch ``acc``; the engine issues
      the stream ppermute first and folds the pre-permute buffer while the
      collective is in flight.
    * ``split_bwd`` — zero-bubble B/W split: the tick hook is called as
      ``tick(tc, streams, state, acc, stash) -> (streams, state, acc,
      stash)`` and must thread the stash through
      ``executor.split_backward_stage``; ``init_stash`` is the zero-filled
      stash (``executor.make_stash``), ``drain_tick(j, entry,
      stage_params, aux) -> params-cotangent`` recomputes slot ``j``'s
      stage weight grads (``drain_aux``: the float-cast pytree of traced
      values it needs — custom_vjp hooks cannot close over tracers), and
      ``stage_params`` is the tree those cotangents accumulate into.
    """

    n_items: int
    d_p: int
    data_axis: str
    tick: Callable[..., Any]
    psum_acc: bool = True
    schedule: str = "gpipe-1f1b"
    v: int = 1
    fold: Any = None
    split_bwd: bool = False
    init_stash: Any = None
    drain_tick: Any = None
    stage_params: Any = None
    drain_aux: Any = ()

    @property
    def spec(self):
        return get_schedule(self.schedule, self.v)

    @property
    def n_ticks(self) -> int:
        return self.spec.scan_ticks(self.n_items, self.d_p)
