"""The shared stage-program executor core.

One engine runs every scanned 1F1B pipeline in the repo. Backends
(`runtime/pipeline.py`, `runtime/encdec_pipeline.py`,
`runtime/serve_step.py`) are thin adapters that build a
:class:`~repro.runtime.program.StageProgram` with a backend-specific
``tick`` hook; everything schedule-shaped lives here:

* :func:`run_stage_program` — the ``lax.scan`` tick loop over
  ``n_items + d_p - 1`` ticks and the left-neighbor ``ppermute`` stage
  hand-off (backward = the autodiff transpose: reverse tick order,
  reversed ppermute, context-carry cotangents — the paper's dKV
  dependency, Eq. 5);
* :func:`run_stage_layers` — remat-split per-stage layer execution: the
  solver-chosen leading ``l_ckpt`` layers run under ``jax.checkpoint``
  (layer-granular recomputation, Eq. 9-11), the rest keep activations;
* :func:`reset_ssm_at_boundary` — the split-chunk context-carry rule: a
  chunk with ``ctx_len == 0`` starts a new sequence, so SSM state resets
  (KV buffers reset implicitly by overwriting from offset 0);
* :func:`fold_streaming_ce` / :func:`fold_greedy_ids` — last-stage output
  folding into the scan accumulator (streaming vocab-parallel CE for
  training; greedy next-token ids for prefill/decode).

Bubble ticks compute on garbage (seg = -1 masks attention and loss): the
lockstep-SPMD analogue of pipeline bubbles. They inflate compiled HLO FLOPs
by (n + d_p - 1)/n — the roofline's MODEL_FLOPS ratio surfaces this.
"""

from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from . import sp
from .program import StageProgram, TickContext

__all__ = ["run_stage_program", "run_stage_layers", "ppermute_streams",
           "reset_ssm_at_boundary", "fold_streaming_ce", "fold_greedy_ids"]


def ppermute_streams(streams, data_axis: str, d_p: int):
    """Left-neighbor hand-off: every stream leaf moves stage p -> p + 1."""
    if d_p <= 1:
        return streams
    perm = [(i, i + 1) for i in range(d_p - 1)]
    return jax.tree.map(
        lambda x: jax.lax.ppermute(x, data_axis, perm), streams)


def run_stage_program(program: StageProgram, init_streams, init_state,
                      init_acc) -> Tuple[Any, Any, Any]:
    """Run one stage program: the scanned tick loop all backends share.

    Returns the final ``(streams, state, acc)``; ``acc`` is psummed over
    the pipeline axis when ``program.psum_acc`` (only the last stage folds
    real output, the rest contribute zeros / stale rows).
    """
    n, d_p = program.n_items, program.d_p

    def _tick(carry, t):
        streams, state, acc = carry
        p_idx = jax.lax.axis_index(program.data_axis)
        idx = t - p_idx
        valid = (idx >= 0) & (idx < n)
        idxc = jnp.clip(idx, 0, n - 1)
        tc = TickContext(t=t, idx=idx, idxc=idxc, valid=valid, p_idx=p_idx,
                         n_items=n, d_p=d_p)
        streams, state, acc = program.tick(tc, streams, state, acc)
        streams = ppermute_streams(streams, program.data_axis, d_p)
        return (streams, state, acc), None

    (streams, state, acc), _ = jax.lax.scan(
        _tick, (init_streams, init_state, init_acc),
        jnp.arange(program.n_ticks))
    if program.psum_acc:
        acc = jax.tree.map(
            lambda a: jax.lax.psum(a, program.data_axis), acc)
    return streams, state, acc


def run_stage_layers(layer_body: Callable, carry, xs, *, l_ckpt: int,
                     n_layers: int):
    """Scan one stage's layers with the solver's remat split.

    ``layer_body(carry, per_layer) -> (carry, y)`` advances the chunk
    activation(s) through one layer; ``xs`` is any pytree whose leaves have
    leading dim ``n_layers`` (stacked layer params, per-layer context
    slices, masks). The first ``l_ckpt`` layers recompute in backward —
    only their input + un-freeable KV persist (Eq. 9) — the rest keep
    activations. Returns ``(carry, ys)`` with the two partial scans' ys
    concatenated back to leading dim ``n_layers`` (None leaves pass
    through).
    """
    l_ck = max(0, min(l_ckpt, n_layers))

    def split(a, b):
        return jax.tree.map(lambda t: t[a:b], xs)

    ys_parts = []
    if l_ck > 0:
        body_ck = jax.checkpoint(layer_body, prevent_cse=False)
        carry, ys = jax.lax.scan(body_ck, carry, split(0, l_ck))
        ys_parts.append(ys)
    if l_ck < n_layers:
        carry, ys = jax.lax.scan(layer_body, carry, split(l_ck, n_layers))
        ys_parts.append(ys)
    if len(ys_parts) == 2:
        ys = jax.tree.map(
            lambda a, b: jnp.concatenate([a, b], axis=0) if a is not None
            else None, ys_parts[0], ys_parts[1],
            is_leaf=lambda t: t is None)
    else:
        ys = ys_parts[0]
    return carry, ys


def reset_ssm_at_boundary(ctx, ctx_len):
    """SSM state resets at sequence starts (``ctx_len == 0``); KV buffers
    reset implicitly by appending from offset 0."""
    if getattr(ctx, "ssm_h", None) is None:
        return ctx
    return ctx._replace(ssm_h=jnp.where(ctx_len == 0, 0.0, ctx.ssm_h))


def fold_streaming_ce(tc: TickContext, h_last, head_w, tgt, seg, acc, *,
                      model_axis: str, vocab_true: int):
    """Fold one chunk into the streaming vocab-parallel CE accumulator.

    Only the last stage on a valid tick contributes; bubbles and earlier
    stages fold a fully-masked chunk (exactly zero loss and zero grad).
    ``acc`` is ``(loss_sum, n_valid)``.
    """
    ce_valid = (seg >= 0) & (tgt >= 0) & tc.valid & tc.is_last_stage
    l_sum, n_val = sp.sharded_ce(h_last, head_w, jnp.maximum(tgt, 0),
                                 ce_valid, model_axis,
                                 vocab_true=vocab_true)
    return acc[0] + l_sum, acc[1] + n_val


def fold_greedy_ids(tc: TickContext, h_last, head_w, ids_acc, *,
                    model_axis: str, vocab_true: int):
    """Fold one item's greedy next-token ids into ``ids_acc`` at row
    ``tc.idxc`` (prefill and pipelined decode share this)."""
    ids = sp.sharded_greedy(h_last, head_w, model_axis,
                            vocab_true=vocab_true)
    sel = tc.valid & tc.is_last_stage
    new_ids = jnp.where(sel, ids, ids_acc[tc.idxc])
    return ids_acc.at[tc.idxc].set(new_ids)
