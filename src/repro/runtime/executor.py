"""The shared stage-program executor core.

One engine runs every scanned pipeline in the repo. Backends
(`runtime/pipeline.py`, `runtime/encdec_pipeline.py`,
`runtime/serve_step.py`) are thin adapters that build a
:class:`~repro.runtime.program.StageProgram` with a backend-specific
``tick`` hook; everything schedule-shaped lives here:

* :func:`run_stage_program` — the ``lax.scan`` tick loop (tick count and
  per-tick ``(item, virtual stage)`` mapping come from the program's
  schedule backend, mirroring ``repro.core.schedule.ScheduleSpec.
  tick_coords`` in traced arithmetic) and the left-neighbor ``ppermute``
  stage hand-off (backward = the autodiff transpose: reverse tick order,
  reversed ppermute, context-carry cotangents — the paper's dKV
  dependency, Eq. 5);
* :func:`run_stage_layers` — remat-split per-stage layer execution: the
  solver-chosen leading ``l_ckpt`` layers run under ``jax.checkpoint``
  (layer-granular recomputation, Eq. 9-11), the rest keep activations.
  ``l_ckpt`` may be a static int (one split point baked into the scan) or
  a traced scalar — the stage-aware per-(stage, chunk) policy, where
  :func:`remat_tick_count` looks the active depth up from the plan's
  checkpoint table at every tick;
* :func:`reset_ssm_at_boundary` — the split-chunk context-carry rule: a
  chunk with ``ctx_len == 0`` starts a new sequence, so SSM state resets
  (KV buffers reset implicitly by overwriting from offset 0);
* :func:`fold_streaming_ce` / :func:`fold_greedy_ids` — last-stage output
  folding into the scan accumulator (streaming vocab-parallel CE for
  training; greedy next-token ids for prefill/decode).

Bubble ticks compute on garbage (seg = -1 masks attention and loss): the
lockstep-SPMD analogue of pipeline bubbles. They inflate compiled HLO FLOPs
by ``spec.scan_ticks(n, d_p) / (n * v)`` — ``(n + d_p - 1)/n`` for plain
1F1B, divided by ~``v`` under ``interleaved-1f1b`` because every tick is
``1/v`` of a stage — the roofline's MODEL_FLOPS ratio surfaces this.
"""

from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import sp
from .program import StageProgram, TickContext

__all__ = ["run_stage_program", "run_stage_layers", "ppermute_streams",
           "schedule_tick_coords", "remat_tick_count",
           "canonical_ckpt_table", "split_backward_stage", "make_stash",
           "reset_ssm_at_boundary", "fold_streaming_ce", "fold_greedy_ids"]


def canonical_ckpt_table(table, *, d_p: int, n_chunks: int):
    """Validate + canonicalize a per-(stage, chunk) checkpoint table to the
    hashable ``(d_p, n_chunks)`` tuple-of-tuples the frozen geometries
    store (None passes through: the uniform policy). The single shape
    gatekeeper for every geometry factory and ``__post_init__`` — a wrong
    shape must fail loudly before it is baked into a compiled step."""
    if table is None:
        return None
    out = tuple(tuple(int(v) for v in row) for row in table)
    if len(out) != d_p or any(len(r) != n_chunks for r in out):
        raise ValueError(
            f"ckpt_table must be (d_p={d_p}, n_chunks={n_chunks}); got "
            f"({len(out)}, {sorted(set(len(r) for r in out))})")
    return out


def schedule_tick_coords(t, p_idx, *, n: int, d_p: int, v: int,
                         n_groups: int):
    """``(idx, v_idx, valid)`` for tick ``t`` on device ``p_idx`` — the
    engine-side mirror of ``repro.core.schedule.ScheduleSpec.tick_coords``.

    Written in overloaded arithmetic only (floor ``//`` / ``%``), so it
    evaluates identically on traced jnp scalars inside the scan and on
    plain python ints — ``tests/test_schedule_backends.py`` sweeps both
    against the spec to keep executor and simulator in lockstep.
    """
    u = t - p_idx
    if v == 1:
        return u, 0, (u >= 0) & (u < n)
    r = u // d_p               # floor division: negative u stays invalid
    q = u - r * d_p
    v_idx = r % v
    idx = (r // v) * d_p + q
    valid = (u >= 0) & (u < n_groups * v * d_p) & (idx < n)
    return idx, v_idx, valid


def remat_tick_count(table, p_idx, idxc, valid, *, v: int = 1,
                     l_max: int = None):
    """Active remat depth for the ``(stage, virtual-stage, chunk)`` a tick
    runs — the engine-side lookup into the solver's per-(stage, chunk)
    checkpoint table (Eq. 9-11 made stage-aware).

    ``table`` is a ``(d_p, n_chunks)`` integer array; like
    :func:`schedule_tick_coords` this is written in overloaded arithmetic
    only (indexing + ``*`` / floor ``//``), so it evaluates identically on
    traced jnp scalars inside the scan and on plain ints/NumPy in the
    host-side simulators and tests — PROVIDED ``idxc`` is the CLIPPED
    in-range item index (``TickContext.idxc``, never the raw ``idx``):
    bubble ticks carry out-of-range raw indices that jnp would clamp but
    NumPy would reject. Bubble ticks (``valid`` False) remat nothing; with
    ``v`` virtual stages the stage's budget splits ``ceil(l / v)`` per
    block — the same memory-safe rounding the uniform path uses
    (over-remat bounded by ``v - 1`` layers). ``l_max`` clips to the
    block's layer count.
    """
    l = table[p_idx, idxc] * valid
    if v > 1:
        l = -((-l) // v)
    if l_max is not None:
        l = l + (l_max - l) * (l > l_max)   # min(l, l_max), overloaded
    return l


def ppermute_streams(streams, data_axis: str, d_p: int, *,
                     ring: bool = False):
    """Left-neighbor hand-off: every stream leaf moves stage p -> p + 1.

    ``ring=True`` closes the loop (``d_p - 1 -> 0``) — interleaved
    schedules route a chunk leaving the last device back to the first
    device's next virtual stage.
    """
    if d_p <= 1:
        return streams
    from repro.core.schedule import stream_perm
    perm = stream_perm(d_p, ring=ring)
    return jax.tree.map(
        lambda x: jax.lax.ppermute(x, data_axis, perm), streams)


# ---------------------------------------------------------------------------
# Split backward (zero-bubble B-grad / W-grad): the stage wrapper and the
# W-drain tick map.
#
# The lockstep scan runs ONE tick HLO on every device, so a masked or
# conditional W-grad inside the existing ticks realizes nothing — the win
# needs ticks with *different* HLO. The compiled structure:
#
# * every forward tick wraps its stage computation in
#   :func:`split_backward_stage` — a ``jax.custom_vjp`` whose forward is the
#   unmodified stage math (loss stays bitwise-identical) saving the stage's
#   ``jax.vjp`` closure as residuals, and whose backward (the B-grad tick)
#   applies the saved vjp, returns ONLY the input/context cotangents —
#   dropping the weight cotangents, so XLA dead-code-eliminates exactly the
#   wgrad GEMMs off the critical path — and pushes the boundary pair
#   ``(x_in, ctx_in, ybar, ctx_bar)`` into a per-item stash slot;
# * the stash rides the scan carry as a *cotangent mailbox*: its primal is
#   dead zeros threaded through untouched, while its cotangent accumulates
#   the pushed entries as the transposed scan walks ticks in reverse;
# * ``spec.drain_ticks`` dedicated W-grad ticks are prepended to the
#   *primal* program as a no-op scan (:func:`_run_drain_scan`) feeding the
#   stash into the forward scan — so in the autodiff transpose they run
#   LAST, exactly the backward cooldown, each popping one slot and
#   computing that item's stage weight grads (ZB-H1's W-grad fill, now in
#   the HLO).
#
# Bubble ticks push nothing (the push is valid-masked); their weight-grad
# contribution is exactly zero in the fused transpose too — bubble outputs
# never reach the loss, so their cotangents are exact zeros — which keeps
# split-vs-fused gradients at parity (tests/test_split_backward.py).
# ---------------------------------------------------------------------------


def make_stash(entry_struct, n_slots: int):
    """Zero-filled stash: one buffer of ``n_slots`` rows per (non-None)
    leaf of ``entry_struct`` — the pytree a single
    :func:`split_backward_stage` push writes, i.e.
    ``(x_in, ctx_in, ybar_like_x, ctx_bar_like_ctx)``."""
    return jax.tree.map(
        lambda a: jnp.zeros((n_slots, *a.shape), a.dtype), entry_struct)


def _stash_push(stash, slot, entry, valid):
    """Write ``entry`` at row ``slot`` of every stash leaf; bubble ticks
    (``valid`` False) leave the stash untouched."""
    return jax.tree.map(
        lambda buf, leaf: buf.at[slot].set(
            jnp.where(valid, leaf.astype(buf.dtype), buf[slot])),
        stash, entry)


def split_backward_stage(stage_fn: Callable, x, ctx, params, stash, slot,
                         valid, aux=()):
    """Run ``stage_fn(x, ctx, params, aux) -> (y, new_ctx)`` with the
    zero-bubble B/W split.

    Forward: the unmodified stage computation (its ``jax.vjp`` closure is
    saved as the custom_vjp residuals — no recompute on the critical
    path); the stash passes through untouched. Backward (B-grad tick):
    apply the saved vjp, drop the weight cotangents (their GEMMs become
    dead code on this tick) and push ``(x, ctx, ybar, ctx_bar)`` into
    ``stash``'s cotangent at row ``slot`` — the W-drain ticks pop it
    during cooldown (see :func:`_run_drain_scan`).

    ``stage_fn`` must NOT close over any traced value: the custom_vjp's
    backward is re-traced at scan-transpose time, when closure-captured
    tracers of the (long-dead) forward scan trace are leaked garbage —
    everything per-tick comes in through ``aux``, a pytree of FLOAT arrays
    (cast integer values to float32 at the caller; exact below 2**24, and
    its zero cotangent then stays an ordinary float — custom_vjp cannot
    return cotangents for integer operands). ``slot``/``valid`` enter as
    float32 for the same reason. Returns ``(y, new_ctx, stash)``.
    """

    def _run(x, ctx, params, stash, slot_f, valid_f, aux):
        y, new_ctx = stage_fn(x, ctx, params, aux)
        return y, new_ctx, stash

    def _fwd(x, ctx, params, stash, slot_f, valid_f, aux):
        (y, new_ctx), f_vjp = jax.vjp(
            lambda xx, cc, pp: stage_fn(xx, cc, pp, aux), x, ctx, params)
        return (y, new_ctx, stash), (f_vjp, x, ctx, slot_f, valid_f)

    def _bwd(res, cots):
        f_vjp, x, ctx, slot_f, valid_f = res
        ybar, ctx_bar, stash_bar = cots
        xbar, ctxbar, _wbar = f_vjp((ybar, ctx_bar))  # _wbar dropped: DCE
        slot = slot_f.astype(jnp.int32)
        valid = valid_f > 0.5
        stash_bar = _stash_push(stash_bar, slot, (x, ctx, ybar, ctx_bar),
                                valid)
        wzero = jax.tree.map(jnp.zeros_like, params)
        return (xbar, ctxbar, wzero, stash_bar,
                jnp.zeros_like(slot_f), jnp.zeros_like(valid_f),
                jax.tree.map(jnp.zeros_like, aux))

    run = jax.custom_vjp(_run)
    run.defvjp(_fwd, _bwd)
    return run(x, ctx, params, stash,
               jnp.asarray(slot, jnp.float32),
               jnp.asarray(valid, jnp.float32), aux)


def _run_drain_scan(drain_tick: Callable, stage_params, init_stash,
                    n_drain: int, aux=()):
    """The split-backward W-grad tick map: a primal no-op scan over
    ``n_drain`` slots threading the stash through one custom_vjp per tick.

    In the transposed program these ticks run after every B-grad tick (the
    backward cooldown); tick ``j`` pops stash row ``j`` and calls
    ``drain_tick(j, entry, stage_params, aux) -> params-cotangent`` — the
    backend's weight-grad recomputation for that (item, virtual-stage)
    slot. The per-tick contributions accumulate into ``stage_params``'s
    cotangent through the scan transpose. Like
    :func:`split_backward_stage`, ``drain_tick`` must not close over
    traced values — batch lookups etc. come in through ``aux`` (float
    arrays only).
    """

    def _nop(stash, params, j_f, aux):
        return stash

    def _fwd(stash, params, j_f, aux):
        return stash, (params, j_f, aux)

    def _bwd(res, stash_bar):
        params, j_f, aux = res
        j = j_f.astype(jnp.int32)
        entry = jax.tree.map(lambda buf: buf[j], stash_bar)
        wbar = drain_tick(j, entry, params, aux)
        return (stash_bar, wbar, jnp.zeros_like(j_f),
                jax.tree.map(jnp.zeros_like, aux))

    drain = jax.custom_vjp(_nop)
    drain.defvjp(_fwd, _bwd)

    def body(stash, j):
        return drain(stash, stage_params, j.astype(jnp.float32), aux), None

    stash, _ = jax.lax.scan(body, init_stash, jnp.arange(n_drain))
    return stash


def run_stage_program(program: StageProgram, init_streams, init_state,
                      init_acc) -> Tuple[Any, Any, Any]:
    """Run one stage program: the scanned tick loop all backends share.

    The per-tick ``(idx, v_idx, valid)`` coordinates are the traced mirror
    of the schedule backend's ``tick_coords``:

    * ``v == 1`` (``gpipe-1f1b``, ``zero-bubble-h1``, interleaved at one
      virtual stage): the classic diagonal ``idx = t - p``;
    * ``v > 1`` (``interleaved-1f1b``): wave index ``u = t - p`` decomposes
      into round ``r = u // d_p`` and offset ``q = u % d_p``; the device
      runs local virtual stage ``v_idx = r % v`` on item
      ``(r // v) * d_p + q`` — items advance through the ``v * d_p``
      virtual-stage ring in round-robin groups of ``d_p``, and the stream
      ppermute closes into a full ring.

    Returns the final ``(streams, state, acc)``; ``acc`` is psummed over
    the pipeline axis when ``program.psum_acc`` (only the last stage folds
    real output, the rest contribute zeros / stale rows).

    Two optional program hooks extend the tick map:

    * ``program.fold`` — double-buffered stage hand-off: the tick hook
      only computes, the engine issues the stream ``ppermute`` against the
      carry's (second) receive buffer, and ``fold(tc, streams, state,
      acc)`` then folds the *pre-permute* buffer into the accumulator —
      the permute-independent fold work (the vocab-parallel CE matmul is
      the big one) overlaps the in-flight collective under XLA's async
      collectives + latency-hiding scheduler (launch/mesh.py flags). Same
      values, same per-value op order: losses stay bitwise-identical.
    * ``program.split_bwd`` — the zero-bubble B/W split: the engine runs
      ``spec.drain_ticks`` W-grad ticks (:func:`_run_drain_scan`) feeding
      a stash buffer into the forward scan's carry; the tick hook (called
      as ``tick(tc, streams, state, acc, stash)``) threads it through
      :func:`split_backward_stage`. In the transpose the W ticks run
      after the whole B-grad scan — the cooldown drain.
    """
    n, d_p, v = program.n_items, program.d_p, program.v
    n_groups = program.spec.n_groups(n, d_p)
    split = program.split_bwd

    def _tick(carry, t):
        if split:
            streams, state, acc, stash = carry
        else:
            streams, state, acc = carry
        p_idx = jax.lax.axis_index(program.data_axis)
        idx, v_idx, valid = schedule_tick_coords(
            t, p_idx, n=n, d_p=d_p, v=v, n_groups=n_groups)
        idxc = jnp.clip(idx, 0, n - 1)
        tc = TickContext(t=t, idx=idx, idxc=idxc, valid=valid, p_idx=p_idx,
                         n_items=n, d_p=d_p, v_idx=v_idx, v=v)
        if split:
            streams, state, acc, stash = program.tick(tc, streams, state,
                                                      acc, stash)
        else:
            streams, state, acc = program.tick(tc, streams, state, acc)
        sent = ppermute_streams(streams, program.data_axis, d_p,
                                ring=(v > 1))
        if program.fold is not None:
            # double-buffered hand-off: fold the pre-permute buffer while
            # the collective is in flight
            acc = program.fold(tc, streams, state, acc)
        if split:
            return (sent, state, acc, stash), None
        return (sent, state, acc), None

    if split:
        # one W tick per (item, virtual stage) — ``spec.drain_ticks`` for
        # split_bwd backends, but derived from the program geometry so the
        # split path also runs under fused-schedule names (parity tests)
        n_drain = n * v
        stash0 = _run_drain_scan(program.drain_tick, program.stage_params,
                                 program.init_stash, n_drain,
                                 aux=program.drain_aux)
        (streams, state, acc, _), _ = jax.lax.scan(
            _tick, (init_streams, init_state, init_acc, stash0),
            jnp.arange(program.n_ticks))
    else:
        (streams, state, acc), _ = jax.lax.scan(
            _tick, (init_streams, init_state, init_acc),
            jnp.arange(program.n_ticks))
    if program.psum_acc:
        acc = jax.tree.map(
            lambda a: jax.lax.psum(a, program.data_axis), acc)
    return streams, state, acc


def run_stage_layers(layer_body: Callable, carry, xs, *, l_ckpt,
                     n_layers: int):
    """Scan one stage's layers with the solver's remat split.

    ``layer_body(carry, per_layer) -> (carry, y)`` advances the chunk
    activation(s) through one layer; ``xs`` is any pytree whose leaves have
    leading dim ``n_layers`` (stacked layer params, per-layer context
    slices, masks). The first ``l_ckpt`` layers recompute in backward —
    only their input + un-freeable KV persist (Eq. 9) — the rest keep
    activations. Returns ``(carry, ys)`` with the two partial scans' ys
    concatenated back to leading dim ``n_layers`` (None leaves pass
    through).

    ``l_ckpt`` may be:

    * a static python int — the split point is baked into the trace as two
      partial scans (the uniform policy; unchanged, bitwise-stable path);
    * a traced scalar (the stage-aware per-(stage, chunk) policy, looked
      up per tick via :func:`remat_tick_count`) — one scan over all
      ``n_layers`` whose body selects per layer, via ``lax.cond`` on
      ``layer_idx < l_ckpt``, between the ``jax.checkpoint``-wrapped body
      and the plain one. Values and gradients are identical either way —
      remat never changes the math (tests/test_remat_parity.py) — only
      which residuals the backward rematerializes.
    """
    if isinstance(l_ckpt, (int, np.integer)):
        l_ck = max(0, min(l_ckpt, n_layers))

        def split(a, b):
            return jax.tree.map(lambda t: t[a:b], xs)

        ys_parts = []
        if l_ck > 0:
            body_ck = jax.checkpoint(layer_body, prevent_cse=False)
            carry, ys = jax.lax.scan(body_ck, carry, split(0, l_ck))
            ys_parts.append(ys)
        if l_ck < n_layers:
            carry, ys = jax.lax.scan(layer_body, carry,
                                     split(l_ck, n_layers))
            ys_parts.append(ys)
        if len(ys_parts) == 2:
            ys = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b], axis=0) if a is not None
                else None, ys_parts[0], ys_parts[1],
                is_leaf=lambda t: t is None)
        else:
            ys = ys_parts[0]
        return carry, ys

    # traced l_ckpt: per-layer runtime selection between remat / plain
    body_ck = jax.checkpoint(layer_body, prevent_cse=False)
    remat_flags = jnp.arange(n_layers) < l_ckpt

    def body(c, per_layer):
        flag, xs_layer = per_layer
        return jax.lax.cond(flag, body_ck, layer_body, c, xs_layer)

    return jax.lax.scan(body, carry, (remat_flags, xs))


def reset_ssm_at_boundary(ctx, ctx_len):
    """SSM state resets at sequence starts (``ctx_len == 0``); KV buffers
    reset implicitly by appending from offset 0."""
    if getattr(ctx, "ssm_h", None) is None:
        return ctx
    return ctx._replace(ssm_h=jnp.where(ctx_len == 0, 0.0, ctx.ssm_h))


def fold_streaming_ce(tc: TickContext, h_last, head_w, tgt, seg, acc, *,
                      model_axis: str, vocab_true: int):
    """Fold one chunk into the streaming vocab-parallel CE accumulator.

    Only the last stage on a valid tick contributes; bubbles and earlier
    stages fold a fully-masked chunk (exactly zero loss and zero grad).
    ``acc`` is ``(loss_sum, n_valid)``.
    """
    ce_valid = (seg >= 0) & (tgt >= 0) & tc.valid & tc.is_last_stage
    l_sum, n_val = sp.sharded_ce(h_last, head_w, jnp.maximum(tgt, 0),
                                 ce_valid, model_axis,
                                 vocab_true=vocab_true)
    return acc[0] + l_sum, acc[1] + n_val


def fold_greedy_ids(tc: TickContext, h_last, head_w, ids_acc, *,
                    model_axis: str, vocab_true: int,
                    token_sharded: bool = False):
    """Fold one item's greedy next-token ids into ``ids_acc`` at row
    ``tc.idxc`` (prefill, the serving engine and pipelined decode share
    this).

    ``sharded_greedy``'s cross-rank argmax merge assumes every model rank
    holds the SAME tokens (true for decode, whose psum'd attention leaves
    ``h_last`` replicated). Prefill/engine hidden states are TOKEN-sharded
    over the model axis — pass ``token_sharded=True`` so the rows are
    all-gathered before the vocab-parallel argmax (same collective the
    streaming-CE fold already pays) and this rank's block sliced back out;
    without it the pmax/pmin merge compares argmax candidates of
    *different* tokens across ranks and the ids are garbage whenever
    ``d_s > 1``.
    """
    if token_sharded:
        loc = h_last.shape[0]
        h_g = jax.lax.all_gather(h_last, model_axis, axis=0, tiled=True)
        ids_full = sp.sharded_greedy(h_g, head_w, model_axis,
                                     vocab_true=vocab_true)
        off = jax.lax.axis_index(model_axis) * loc
        ids = jax.lax.dynamic_slice_in_dim(ids_full, off, loc, axis=0)
    else:
        ids = sp.sharded_greedy(h_last, head_w, model_axis,
                                vocab_true=vocab_true)
    sel = tc.valid & tc.is_last_stage
    new_ids = jnp.where(sel, ids, ids_acc[tc.idxc])
    return ids_acc.at[tc.idxc].set(new_ids)
