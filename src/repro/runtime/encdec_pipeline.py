"""Pipelined encoder-decoder executor (seamless-m4t backbone): a thin
adapter over the shared stage-program executor (runtime/executor.py).

Stage split: the first ``enc_stages = d_p * L_enc / (L_enc + L_dec)`` pipeline
stages hold encoder layers; the rest hold decoder layers. A chunk's
streamed activation is the PAIR ``(h_enc, h_dec)``:

* encoder stages advance ``h_enc`` over the (stub) frame embeddings —
  non-causal, packed (batched chunks only; splitting a bidirectional
  encoder would change the math, DESIGN.md §4);
* the first decoder stage receives the finished ``h_enc`` as the
  cross-attention MEMORY and injects the token embeddings into ``h_dec``;
* decoder stages advance ``h_dec`` with causal self-attention (allgather-KV
  policy, split-chunk context carry) + cross-attention to ``h_enc`` (which
  keeps riding the ppermute unchanged) — so the memory reaches every
  decoder stage with no extra collective.

Layer-slot homogeneity: encoder layer params are embedded in the decoder
layer structure (their cross/ln_x slots are zero and unused), so the
stage-stacked tree has one uniform pytree — the price is ~4*D*HqDh dead
bytes per encoder layer, recorded in DESIGN.md §8.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ref import blocked_flash_attention
from repro.models import EncDecLM, LayerCtx
from repro.models.config import ArchConfig
from repro.models.layers import rms_norm, swiglu_apply

from . import executor, sp
from .program import StageProgram
from .sharding import (gather_layer_params, mesh_axis_names,
                       stack_grouped_stages)

__all__ = ["EncDecGeometry", "encdec_pipeline_loss_fn", "prepare_encdec_params",
           "encdec_batch_struct", "encdec_stage_split"]


@dataclass(frozen=True)
class EncDecGeometry:
    n_chunks: int
    cap: int                  # decoder tokens per chunk
    cap_enc: int              # encoder frames per chunk
    ctx_cap: int
    d_p: int
    d_s: int
    l_ckpt: int               # max remat depth (uniform policy value)
    enc_stages: int
    layers_per_stage: int     # max(enc, dec) layers per stage
    compute_dtype: Any = jnp.bfloat16
    policy: str = "allgather_kv"
    # schedule backend. Only single-virtual-stage backends are supported:
    # the grouped enc+dec stacking has no interleaved placement, so
    # v_stages is pinned at 1 (interleaved-1f1b still runs — at v=1 its
    # tick map is the classic diagonal).
    schedule: str = "gpipe-1f1b"
    v_stages: int = 1
    # stage-aware checkpointing table, (d_p, n_chunks) tuple-of-tuples —
    # this is WHERE encoder and decoder stages get different remat depths
    # (solver roles from core.checkpointing.stage_roles); None = uniform.
    ckpt_table: Optional[Tuple[Tuple[int, ...], ...]] = None

    def __post_init__(self) -> None:
        if self.v_stages != 1:
            raise ValueError(
                "enc-dec pipelines support v_stages=1 only (the grouped "
                f"enc+dec layer stacking has no interleaved placement); "
                f"got {self.v_stages}")
        executor.canonical_ckpt_table(self.ckpt_table, d_p=self.d_p,
                                      n_chunks=self.n_chunks)


def encdec_stage_split(cfg: ArchConfig, d_p: int) -> Tuple[int, int]:
    """(enc_stages, dec_stages) — delegates to the core solver's
    :func:`~repro.core.checkpointing.encoder_stage_split` so the executor's
    stage split and the checkpointing ILP's stage roles agree by
    construction."""
    from repro.core.checkpointing import encoder_stage_split
    s = cfg.spec
    return encoder_stage_split(s.n_encoder_layers, s.n_layers, d_p)


def make_encdec_geometry(cfg: ArchConfig, mesh, *, n_chunks: int, cap: int,
                         cap_enc: int, ctx_cap: int, l_ckpt: int = 0,
                         compute_dtype=jnp.bfloat16,
                         schedule: str = "gpipe-1f1b",
                         ckpt_table=None) -> EncDecGeometry:
    pod, data, model = mesh_axis_names(mesh)
    d_p, d_s = mesh.shape[data], mesh.shape[model]
    enc_st, dec_st = encdec_stage_split(cfg, d_p)
    L_ps = max(-(-cfg.spec.n_encoder_layers // enc_st),
               -(-cfg.spec.n_layers // dec_st))
    ckpt_table = executor.canonical_ckpt_table(ckpt_table, d_p=d_p,
                                               n_chunks=n_chunks)
    return EncDecGeometry(n_chunks=n_chunks, cap=cap, cap_enc=cap_enc,
                          ctx_cap=ctx_cap, d_p=d_p, d_s=d_s, l_ckpt=l_ckpt,
                          enc_stages=enc_st, layers_per_stage=L_ps,
                          compute_dtype=compute_dtype, schedule=schedule,
                          ckpt_table=ckpt_table)


def prepare_encdec_params(cfg: ArchConfig, raw: Dict, geom: EncDecGeometry,
                          param_dtype=jnp.bfloat16) -> Dict:
    """Stack enc+dec layers into one homogeneous [d_p, L_ps, ...] tree.

    Encoder layers borrow the decoder layer structure (zero cross/ln_x);
    the grouped stage-stacking itself is runtime/sharding.py's.
    """
    s = cfg.spec
    L_ps = geom.layers_per_stage
    enc_st = geom.enc_stages
    dec_st = geom.d_p - enc_st
    cast = lambda t: jax.tree.map(  # noqa: E731
        lambda x: x.astype(param_dtype), t)
    enc, dec = cast(raw["enc_layers"]), cast(raw["dec_layers"])

    # embed encoder layers into the decoder structure
    enc_lifted = {}
    for k, v in dec.items():
        if k in enc:
            enc_lifted[k] = enc[k]
        else:
            enc_lifted[k] = jax.tree.map(
                lambda x: jnp.zeros((s.n_encoder_layers, *x.shape[1:]),
                                    x.dtype), dec[k])

    stages = stack_grouped_stages([(enc_lifted, enc_st), (dec, dec_st)],
                                  L_ps)
    vocab_pad = (-s.vocab) % geom.d_s
    embed = cast(raw["embed"])
    if vocab_pad:
        embed = jnp.concatenate(
            [embed, jnp.zeros((vocab_pad, embed.shape[1]), embed.dtype)])
    return {
        "stages": stages,
        "embed": embed,
        "enc_norm": cast(raw["enc_norm"]),
        "final_norm": cast(raw["final_norm"]),
    }


def prepare_encdec_decode_params(cfg: ArchConfig, raw: Dict, d_p: int,
                                 d_s: int, param_dtype=jnp.bfloat16) -> Dict:
    """Decode-time layout: decoder layers only, stacked over ALL d_p stages
    (the encoder ran at prefill; its output is the decode state's memory)."""
    from .sharding import stack_stages
    s = cfg.spec
    cast = lambda t: jax.tree.map(  # noqa: E731
        lambda x: x.astype(param_dtype), t)
    embed = cast(raw["embed"])
    pad = (-s.vocab) % d_s
    if pad:
        embed = jnp.concatenate(
            [embed, jnp.zeros((pad, embed.shape[1]), embed.dtype)])
    return {
        "stages": stack_stages(cast(raw["dec_layers"]), d_p, s.n_layers),
        "embed": embed,
        "final_norm": cast(raw["final_norm"]),
    }


def encdec_batch_struct(geom: EncDecGeometry, cfg: ArchConfig,
                        n_pods: int) -> Dict:
    lead = (n_pods,) if n_pods > 1 else ()
    n, cap, cape = geom.n_chunks, geom.cap, geom.cap_enc
    i32 = jnp.int32
    return {
        "tokens": jax.ShapeDtypeStruct((*lead, n, cap), i32),
        "targets": jax.ShapeDtypeStruct((*lead, n, cap), i32),
        "seg": jax.ShapeDtypeStruct((*lead, n, cap), i32),
        "pos": jax.ShapeDtypeStruct((*lead, n, cap), i32),
        "ctx_len": jax.ShapeDtypeStruct((*lead, n), i32),
        "frames": jax.ShapeDtypeStruct((*lead, n, cape, cfg.spec.d_model),
                                       geom.compute_dtype),
        "seg_enc": jax.ShapeDtypeStruct((*lead, n, cape), i32),
        "pos_enc": jax.ShapeDtypeStruct((*lead, n, cape), i32),
    }


def encdec_pipeline_loss_fn(cfg: ArchConfig, geom: EncDecGeometry,
                            shard_dims, *, pod_axis: Optional[str],
                            data_axis: str = "data",
                            model_axis: str = "model") -> Callable:
    s = cfg.spec
    d_p, d_s = geom.d_p, geom.d_s
    L_ps = geom.layers_per_stage
    enc_st = geom.enc_stages
    dec_st = d_p - enc_st
    dt = geom.compute_dtype
    model = EncDecLM(cfg)
    self_policy = sp.make_allgather_kv_policy(model_axis)
    nc_policy = sp.make_allgather_kv_policy(model_axis)

    import numpy as _np
    act_enc = (_np.arange(enc_st * L_ps) < s.n_encoder_layers)
    act_dec = (_np.arange(dec_st * L_ps) < s.n_layers)
    active_all = jnp.asarray(
        _np.concatenate([act_enc, act_dec]).reshape(d_p, L_ps))
    scale = 1.0 / math.sqrt(s.head_dim)
    # stage-aware checkpointing: encoder rows of the table carry the
    # solver's encoder-role depths, decoder rows the decoder-role ones
    ckpt_tab = None if geom.ckpt_table is None else \
        jnp.asarray(geom.ckpt_table, jnp.int32)

    def _cross(lp, h, memory, seg_q, seg_mem):
        dtl = h.dtype
        Dh, Hq, Hkv = s.head_dim, s.n_heads, s.n_kv_heads
        q = jnp.einsum("td,dh->th", h, lp["wq"].astype(dtl)
                       ).reshape(-1, Hq, Dh)
        k = jnp.einsum("sd,dh->sh", memory, lp["wk"].astype(dtl)
                       ).reshape(-1, Hkv, Dh)
        v = jnp.einsum("sd,dh->sh", memory, lp["wv"].astype(dtl)
                       ).reshape(-1, Hkv, Dh)
        # memory is model-sharded on frames: gather KV (frames dim)
        k = jax.lax.all_gather(k, model_axis, axis=0, tiled=True)
        v = jax.lax.all_gather(v, model_axis, axis=0, tiled=True)
        sm = jax.lax.all_gather(seg_mem, model_axis, axis=0, tiled=True)
        z_q = jnp.zeros((q.shape[0],), jnp.int32)
        z_k = jnp.zeros((k.shape[0],), jnp.int32)
        out = blocked_flash_attention(q, k, v, seg_q, sm, z_q, z_k,
                                      causal=False, window=0, scale=scale)
        return jnp.einsum("th,hd->td", out.reshape(h.shape[0], -1),
                          lp["wo"].astype(dtl))

    def loss_local(params, batch):
        p_idx = jax.lax.axis_index(data_axis)
        stage_params = jax.tree.map(lambda x: x[0], params["stages"])
        active = active_all[p_idx]
        n = geom.n_chunks
        cap_loc = batch["tokens"].shape[-1]
        cape_loc = batch["frames"].shape[-2]
        is_enc = p_idx < enc_st

        head_w = params["embed"]
        fn_gamma = params["final_norm"]
        if fn_gamma.shape[0] != s.d_model:
            fn_gamma = jax.lax.all_gather(fn_gamma, model_axis, axis=0, tiled=True)
        en_gamma = params["enc_norm"]
        if en_gamma.shape[0] != s.d_model:
            en_gamma = jax.lax.all_gather(en_gamma, model_axis, axis=0, tiled=True)

        kcap = geom.ctx_cap
        ctx0 = LayerCtx(
            jnp.zeros((L_ps, kcap, s.n_kv_heads, s.head_dim), dt),
            jnp.zeros((L_ps, kcap, s.n_kv_heads, s.head_dim), dt),
            None, None)

        def tick(tc, streams, ctx, acc):
            h_enc, h_dec = streams
            idxc = tc.idxc
            tokens = batch["tokens"][idxc]
            seg = jnp.where(tc.valid, batch["seg"][idxc], -1)
            pos = batch["pos"][idxc]
            tgt = batch["targets"][idxc]
            ctx_len = jnp.where(tc.valid, batch["ctx_len"][idxc], 0)
            seg_e = jnp.where(tc.valid, batch["seg_enc"][idxc], -1)
            pos_e = batch["pos_enc"][idxc]

            h_enc = jnp.where(tc.is_first_stage, batch["frames"][idxc],
                              h_enc)
            x_emb = sp.sharded_embed(params["embed"], tokens, model_axis, dt)
            h_dec = jnp.where(tc.p_idx == enc_st, x_emb, h_dec)
            # the first decoder stage receives the FINISHED encoder output;
            # normalize it once there
            h_enc = jnp.where(tc.p_idx == enc_st,
                              rms_norm(h_enc, en_gamma, cfg.rms_eps), h_enc)

            def layer_body(carry2, per_layer):
                he, hd = carry2
                lp, act, lctx = per_layer
                lp = gather_layer_params(lp, shard_dims, model_axis)
                # --- encoder path ---
                h1 = rms_norm(he, lp["ln1"], cfg.rms_eps)
                from repro.models.attention import attention_block
                eo, _, _ = attention_block(
                    cfg, lp["attn"], h1, pos=pos_e, seg=seg_e, ctx_k=None,
                    ctx_v=None, ctx_len=None, window=0, attn_fn=nc_policy,
                    causal=False)
                he_new = he + eo
                he_new = he_new + swiglu_apply(
                    lp["mlp"], rms_norm(he_new, lp["ln2"], cfg.rms_eps))
                # --- decoder path ---
                d1 = rms_norm(hd, lp["ln1"], cfg.rms_eps)
                do, nk, nv = attention_block(
                    cfg, lp["attn"], d1, pos=pos, seg=seg, ctx_k=lctx.k,
                    ctx_v=lctx.v, ctx_len=ctx_len, window=0,
                    attn_fn=self_policy, causal=True)
                hd_new = hd + do
                hx = rms_norm(hd_new, lp["ln_x"], cfg.rms_eps)
                hd_new = hd_new + _cross(lp["cross"], hx, h_enc, seg, seg_e)
                hd_new = hd_new + swiglu_apply(
                    lp["mlp"], rms_norm(hd_new, lp["ln2"], cfg.rms_eps))
                # select by stage role and activity
                he_out = jnp.where(act & is_enc, he_new, he)
                hd_out = jnp.where(act & (~is_enc), hd_new, hd)
                new_ctx = LayerCtx(
                    jnp.where(act & (~is_enc), nk, lctx.k),
                    jnp.where(act & (~is_enc), nv, lctx.v), None, None)
                return (he_out, hd_out), new_ctx

            l_act = geom.l_ckpt if ckpt_tab is None else \
                executor.remat_tick_count(ckpt_tab, tc.p_idx, tc.idxc,
                                          tc.valid)
            (h_enc2, h_dec2), new_ctx = executor.run_stage_layers(
                layer_body, (h_enc, h_dec), (stage_params, active, ctx),
                l_ckpt=l_act, n_layers=L_ps)

            h_last = rms_norm(h_dec2, fn_gamma, cfg.rms_eps)
            acc = executor.fold_streaming_ce(
                tc, h_last, head_w, tgt, seg, acc,
                model_axis=model_axis, vocab_true=s.vocab)
            return (h_enc2, h_dec2), new_ctx, acc

        he0 = jnp.zeros((cape_loc, s.d_model), dt)
        hd0 = jnp.zeros((cap_loc, s.d_model), dt)
        program = StageProgram(n_items=n, d_p=d_p, data_axis=data_axis,
                               tick=tick, psum_acc=True,
                               schedule=geom.schedule, v=geom.v_stages)
        _, ctxf, (loss, n_val) = executor.run_stage_program(
            program, (he0, hd0), ctx0, (jnp.float32(0), jnp.float32(0)))
        return loss, n_val

    return loss_local
