"""Builds the jit'd EPP training step for a mesh + plan-bucket geometry.

Pieces assembled here:

* parameter preparation: model-zoo init -> executor layout (stage-stacked
  layers, vocab padded to d_s, ZeRO/EP/stage PartitionSpecs);
* the shard_map'd step: pipeline loss (runtime/pipeline.py) -> autodiff ->
  head-param grad psum over stages -> pod gradient all-reduce (optionally
  int8-compressed with error feedback) -> ZeRO AdamW on local shards;
* batch specs for the chunk buffers the planner materializes.

Everything static (geometry, remat, policies) is baked per bucket; the
returned step is reused across iterations of the same bucket (§2.3).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.models import DecoderLM
from repro.models.config import ArchConfig
from repro.optim import (AdamWConfig, adamw_update, compressed_psum,
                         init_error_state, init_opt_state)

from . import sp
from .pipeline import PipelineGeometry, pipeline_loss_fn
from .sharding import (batch_specs, mesh_axis_names, shard_dim_tree,
                       shard_map_compat, stack_stages, stage_param_specs)

__all__ = ["TrainStepBuilder", "prepare_params", "make_geometry",
           "batch_struct"]


def _pad_vocab(w: jnp.ndarray, d_s: int) -> jnp.ndarray:
    pad = (-w.shape[0]) % d_s
    if pad:
        w = jnp.concatenate([w, jnp.zeros((pad, *w.shape[1:]), w.dtype)])
    return w


def make_geometry(cfg: ArchConfig, mesh: Mesh, *, n_chunks: int, cap: int,
                  ctx_cap: int, l_ckpt: int = 0,
                  compute_dtype=jnp.bfloat16,
                  zero3_mode: str = "per_tick",
                  schedule: str = "gpipe-1f1b",
                  v_stages: int = 1,
                  ckpt_table=None,
                  split_bwd: Optional[bool] = None,
                  overlap_handoff: bool = True,
                  sp_policy: Optional[str] = None,
                  sp_degree: int = 0) -> PipelineGeometry:
    """``ckpt_table`` (optional): the solver's per-(stage, chunk) remat
    matrix — any (d_p, n_chunks) nested sequence; canonicalized to the
    hashable tuple-of-tuples the frozen geometry stores. None keeps the
    uniform ``l_ckpt`` policy.

    ``split_bwd`` (optional): force the zero-bubble B/W backward split on
    or off; None defaults to the schedule backend's capability
    (``ScheduleSpec.split_bwd`` — i.e. on for ``zero-bubble-h1``).

    ``sp_policy``/``sp_degree`` (optional): the plan's SP axis
    (``ExecutionPlan.sp`` / ``bucket_key().sp_policy/d_s_eff``). Defaults
    — policy None, degree 0 — resolve to the core heuristic at the full
    model-axis size, which is the legacy sp-less-plan behavior."""
    from .executor import canonical_ckpt_table
    from repro.core.schedule import get_schedule
    pod, data, model = mesh_axis_names(mesh)
    d_p = mesh.shape[data]
    d_s = mesh.shape[model]
    d_s_eff = sp_degree or d_s
    ckpt_table = canonical_ckpt_table(ckpt_table, d_p=d_p,
                                      n_chunks=n_chunks)
    if split_bwd is None:
        split_bwd = get_schedule(schedule, v_stages).split_bwd
    return PipelineGeometry(
        n_chunks=n_chunks, cap=cap, ctx_cap=ctx_cap, d_p=d_p, d_s=d_s,
        l_ckpt=l_ckpt,
        layers_per_stage=-(-cfg.spec.n_layers // d_p),
        policy=sp_policy or sp.choose_policy(cfg, d_s_eff),
        d_s_eff=d_s_eff,
        compute_dtype=compute_dtype,
        zero3_mode=zero3_mode,
        schedule=schedule,
        v_stages=v_stages,
        ckpt_table=ckpt_table,
        split_bwd=split_bwd,
        overlap_handoff=overlap_handoff)


def prepare_params(cfg: ArchConfig, raw_params: Dict, mesh: Mesh,
                   param_dtype=jnp.bfloat16, v_stages: int = 1) -> Dict:
    """Model-zoo params -> executor layout (host-side, un-sharded arrays).

    ``v_stages > 1`` bakes the interleaved-1f1b virtual-stage placement
    into the stage stacking (sharding.interleaved_layer_order) — the layout
    is schedule-shaped, which is why the schedule leads
    ``ExecutionPlan.bucket_key()`` and is pinned per training run."""
    pod, data, model = mesh_axis_names(mesh)
    d_p, d_s = mesh.shape[data], mesh.shape[model]
    cast = lambda t: jax.tree.map(  # noqa: E731
        lambda x: x.astype(param_dtype), t)
    out = {
        "stages": stack_stages(cast(raw_params["layers"]), d_p,
                               cfg.spec.n_layers, v=v_stages),
        "embed": _pad_vocab(cast(raw_params["embed"]), d_s),
        "final_norm": cast(raw_params["final_norm"]),
    }
    if "unembed" in raw_params:
        out["unembed"] = _pad_vocab(cast(raw_params["unembed"]), d_s)
    return out


def param_pspecs(cfg: ArchConfig, params_shape: Dict, mesh: Mesh) -> Dict:
    pod, data, model = mesh_axis_names(mesh)
    d_s = mesh.shape[model]
    specs = {
        "stages": stage_param_specs(params_shape["stages"], d_s, pod=pod,
                                    data=data, model=model),
        "embed": P(model, None),
        "final_norm": P(model) if
        params_shape["final_norm"].shape[0] % d_s == 0 else P(),
    }
    if "unembed" in params_shape:
        specs["unembed"] = P(model, None)
    return specs


def batch_struct(geom: PipelineGeometry, n_pods: int) -> Dict:
    """ShapeDtypeStructs for one bucket's chunk buffers (global shapes)."""
    lead = (n_pods,) if n_pods > 1 else ()
    n, cap = geom.n_chunks, geom.cap
    i32 = jnp.int32
    return {
        "tokens": jax.ShapeDtypeStruct((*lead, n, cap), i32),
        "targets": jax.ShapeDtypeStruct((*lead, n, cap), i32),
        "seg": jax.ShapeDtypeStruct((*lead, n, cap), i32),
        "pos": jax.ShapeDtypeStruct((*lead, n, cap), i32),
        "ctx_len": jax.ShapeDtypeStruct((*lead, n), i32),
    }


@dataclass
class TrainStepBuilder:
    cfg: ArchConfig
    mesh: Mesh
    geom: PipelineGeometry
    adamw: AdamWConfig = field(default_factory=AdamWConfig)
    compress_pod_grads: bool = False
    param_dtype: Any = jnp.bfloat16

    def __post_init__(self):
        self.pod_axis, self.data_axis, self.model_axis = \
            mesh_axis_names(self.mesh)
        self.n_pods = self.mesh.shape[self.pod_axis] if self.pod_axis else 1

    # ------------------------------------------------------------------
    def init_params(self, key) -> Dict:
        model = DecoderLM(self.cfg)
        raw = model.init(key, jnp.float32)
        return prepare_params(self.cfg, raw, self.mesh, self.param_dtype,
                              v_stages=self.geom.v_stages)

    def abstract_params(self, key=None) -> Dict:
        key = key if key is not None else jax.random.PRNGKey(0)
        return jax.eval_shape(lambda k: self.init_params(k), key)

    def specs(self, params_shape) -> Tuple[Dict, Dict, Dict]:
        pspecs = param_pspecs(self.cfg, params_shape, self.mesh)
        ospecs = {"master": pspecs, "m": pspecs, "v": pspecs, "step": P()}
        bspecs = batch_specs(batch_struct(self.geom, self.n_pods),
                             pod=self.pod_axis, model=self.model_axis)
        return pspecs, ospecs, bspecs

    # ------------------------------------------------------------------
    def _norm_factors(self, pspecs) -> Any:
        """Per-leaf replication factor over the (data, model) axes — needed
        so the global grad norm counts every shard exactly once."""
        d_p = self.mesh.shape[self.data_axis]
        d_s = self.mesh.shape[self.model_axis]

        def fac(spec) -> float:
            names = {n for part in spec if part is not None
                     for n in ((part,) if isinstance(part, str) else part)}
            f = 1.0
            if self.data_axis not in names:
                f *= d_p
            if self.model_axis not in names:
                f *= d_s
            return f
        return jax.tree.map(fac, pspecs,
                            is_leaf=lambda x: isinstance(x, P))

    def _global_gnorm(self, grads, factors) -> jnp.ndarray:
        sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) / f
                 for g, f in zip(jax.tree.leaves(grads),
                                 jax.tree.leaves(factors)))
        sq = jax.lax.psum(sq, self.data_axis)
        sq = jax.lax.psum(sq, self.model_axis)
        return jnp.sqrt(sq)

    def _step_local(self, shard_dims, norm_factors, params, opt_state,
                    err_state, batch):
        cfg, geom = self.cfg, self.geom
        if self.pod_axis and self.n_pods > 1:
            batch = jax.tree.map(lambda x: x[0], batch)  # drop pod dim
        loss_fn = pipeline_loss_fn(
            cfg, geom, shard_dims, pod_axis=self.pod_axis,
            data_axis=self.data_axis, model_axis=self.model_axis)

        def objective(p):
            loss, n = loss_fn(p, batch)
            return loss, n

        (loss, n_valid), grads = jax.value_and_grad(
            objective, has_aux=True)(params)

        # head params are replicated across stages but used by a single
        # stage each; their true gradient is the sum over stages.
        for name in ("embed", "final_norm", "unembed"):
            if name in grads:
                grads[name] = jax.lax.psum(grads[name], self.data_axis)

        new_err = err_state
        if self.pod_axis and self.n_pods > 1:
            loss = jax.lax.psum(loss, self.pod_axis)
            n_valid = jax.lax.psum(n_valid, self.pod_axis)
            if self.compress_pod_grads:
                grads, new_err = compressed_psum(grads, err_state,
                                                 self.pod_axis)
            else:
                grads = jax.lax.psum(grads, self.pod_axis)

        grad_scale = 1.0 / jnp.maximum(n_valid, 1.0)
        gnorm = self._global_gnorm(grads, norm_factors)
        new_params, new_opt, metrics = adamw_update(
            self.adamw, params, grads, opt_state, grad_scale=grad_scale,
            gnorm=gnorm)
        metrics["loss"] = loss / jnp.maximum(n_valid, 1.0)
        metrics["tokens"] = n_valid
        return new_params, new_opt, new_err, metrics

    # ------------------------------------------------------------------
    def build(self, params_shape=None) -> Callable:
        params_shape = params_shape or self.abstract_params()
        pspecs, ospecs, bspecs = self.specs(params_shape)
        shard_dims = shard_dim_tree(params_shape["stages"],
                                    self.mesh.shape[self.model_axis])
        norm_factors = self._norm_factors(pspecs)

        mspec = {"loss": P(), "tokens": P(), "grad_norm": P(), "lr": P()}
        fn = functools.partial(self._step_local, shard_dims, norm_factors)
        mapped = shard_map_compat(
            fn, mesh=self.mesh,
            in_specs=(pspecs, ospecs,
                      pspecs if self.compress_pod_grads else None,
                      bspecs),
            out_specs=(pspecs, ospecs,
                       pspecs if self.compress_pod_grads else None,
                       mspec),
            check_vma=False)
        # donate the error-feedback state too: with compress_pod_grads its
        # leaves are params-sized and updated in place every step — leaving
        # them out doubles that footprint (program-donation lint finding).
        # Donating the None placeholder on the uncompressed path is a no-op.
        return jax.jit(mapped, donate_argnums=(0, 1, 2))

    def init_all(self, key):
        params = self.init_params(key)
        opt = init_opt_state(params)
        err = init_error_state(params) if self.compress_pod_grads else None
        return params, opt, err
