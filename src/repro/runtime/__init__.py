from .pipeline import PipelineGeometry, pipeline_loss_fn
from .train_step import TrainStepBuilder, batch_struct, make_geometry, prepare_params

__all__ = ["PipelineGeometry", "pipeline_loss_fn", "TrainStepBuilder",
           "batch_struct", "make_geometry", "prepare_params"]
