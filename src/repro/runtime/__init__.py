"""Runtime package. The compile cache (and the StageProgram IR) are
jax-free and import eagerly; everything that pulls in jax + the model
stack resolves lazily so host-side callers (launch/analysis.py,
benchmarks) can use the cache on installs without a device runtime."""

from .cache_store import CacheStore, model_fingerprint, store_fingerprint
from .compile_cache import CompileCache, global_cache_stats
from .program import StageProgram, TickContext

__all__ = ["PipelineGeometry", "pipeline_loss_fn", "TrainStepBuilder",
           "batch_struct", "make_geometry", "prepare_params",
           "StageProgram", "TickContext", "CompileCache", "CacheStore",
           "model_fingerprint", "store_fingerprint",
           "global_cache_stats", "sp"]

_LAZY = {
    "PipelineGeometry": ".pipeline",
    "pipeline_loss_fn": ".pipeline",
    "TrainStepBuilder": ".train_step",
    "batch_struct": ".train_step",
    "make_geometry": ".train_step",
    "prepare_params": ".train_step",
}

# stable lazy submodules: sequence parallelism (sp) graduated when the
# planner started choosing the SP policy/degree per plan — its policy
# factories, subgroup_info, and the vocab-parallel embed/CE are consumed
# by the pipeline builders AND by external callers building custom
# geometries (the per-plan SP axis rides PipelineGeometry.policy/d_s_eff).
STABLE_SUBMODULES = ("sp",)

# experimental submodules: expert-parallel MoE dispatch (ep) is consumed
# internally by the pipeline builders; its function signatures are NOT
# stable API and it is deliberately absent from __all__. Import it
# explicitly as repro.runtime.ep if you accept the churn.
EXPERIMENTAL_SUBMODULES = ("ep",)


def __getattr__(name):
    if name in _LAZY:
        import importlib
        return getattr(importlib.import_module(_LAZY[name], __name__), name)
    if name in STABLE_SUBMODULES + EXPERIMENTAL_SUBMODULES:
        import importlib
        return importlib.import_module(f".{name}", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
