"""Sequence-parallel collectives: the two SP attention policies, the
vocab-parallel embed/CE, and the distributed SSM prefix scan.

All functions here run INSIDE ``shard_map`` (manual collectives). Tokens of
a chunk are sharded over the "model" axis in contiguous blocks; the policies
reconstruct whatever global view their algorithm needs:

* ``ulysses``      — Eq. 3's four all-to-alls: tokens gather / heads scatter
                     around the flash core; split-chunk context is stored
                     HEAD-SHARDED (no communication to attend to it).
* ``allgather_kv`` — K/V (or the MLA latent rows — tiny) of the current
                     chunk are all-gathered once; queries stay local; the
                     context buffer is REPLICATED per device (gathered rows
                     are appended), so later slices attend for free. Legal
                     for any head count (DESIGN.md §2.1.3).
"""

from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.sp import choose_sp_policy
from repro.kernels.ref import blocked_flash_attention, streaming_ce_stats
from repro.models.config import ArchConfig

__all__ = ["make_ulysses_policy", "make_allgather_kv_policy",
           "sharded_embed", "sharded_ce", "sharded_greedy",
           "make_sp_ssm_scan", "make_sp_conv_tail_exchange",
           "choose_policy", "subgroup_info"]


def choose_policy(cfg: ArchConfig, d_s: int) -> str:
    """Default SP policy at effective degree ``d_s``.

    Delegates to the ONE heuristic in ``repro.core.sp`` — the planner and
    the cost model resolve "auto" through the same function, so the
    runtime can never disagree with what the solver costed
    (tests/test_sp_policy.py pins this)."""
    return choose_sp_policy(cfg.spec, d_s)


def subgroup_info(d_s: int, d_s_eff: int):
    """Sub-group layout for an effective SP degree ``d_s_eff <= d_s``.

    Model-axis device ``m`` holds token shard ``m // r`` where
    ``r = d_s // d_s_eff`` is the replication factor. Returns
    ``(r, sp_groups, replica_groups)``:

    * ``sp_groups[j] = [k*r + j for k in range(d_s_eff)]`` — one device
      per token shard (all with replica index ``j``); every SP collective
      (a2a / KV gather / scan summary) runs with these as its
      ``axis_index_groups``, so the ``r`` replicas never interact;
    * ``replica_groups[s] = [s*r + j for j in range(r)]`` — the ``r``
      devices sharing shard ``s``. They are CONTIGUOUS on the axis, so a
      tiled in-group all_gather of the full-axis batch shards
      reconstructs the sub-group shard's rows in order.

    Both group lists are ``None`` at full degree (``r == 1``); collectives
    then span the whole axis with no group indirection.
    """
    d_s_eff = d_s_eff or d_s
    if d_s % d_s_eff:
        raise ValueError(f"d_s_eff={d_s_eff} must divide d_s={d_s}")
    r = d_s // d_s_eff
    if r == 1:
        return 1, None, None
    sp_groups = [[k * r + j for k in range(d_s_eff)] for j in range(r)]
    replica_groups = [[s * r + j for j in range(r)] for s in range(d_s_eff)]
    return r, sp_groups, replica_groups


# ---------------------------------------------------------------------------
# Attention policies.
# ---------------------------------------------------------------------------

def make_allgather_kv_policy(axis: str, flash=None, *,
                             groups=None) -> Callable:
    """``groups`` (optional ``axis_index_groups``): the SP sub-groups from
    :func:`subgroup_info` when the plan runs at ``d_s_eff < d_s`` — every
    collective here stays inside one sub-group; None spans the axis."""
    flash = flash or blocked_flash_attention

    def policy(q, k_cur, v_cur, *, seg, pos, ctx_k, ctx_v, ctx_len,
               causal, window, scale, expand_fn=None):
        # gather the current chunk's KV rows (or MLA cache rows) + metadata
        k_g = jax.lax.all_gather(k_cur, axis, axis=0, tiled=True,
                                 axis_index_groups=groups)
        v_g = jax.lax.all_gather(v_cur, axis, axis=0, tiled=True,
                                 axis_index_groups=groups)
        seg_g = jax.lax.all_gather(seg, axis, axis=0, tiled=True,
                                   axis_index_groups=groups)
        pos_g = jax.lax.all_gather(pos, axis, axis=0, tiled=True,
                                   axis_index_groups=groups)
        # MLA ships zero-width v (values live in the latent cache rows);
        # ONE condition gates both the attend-path concat and the
        # update-path write so the two can never disagree on what counts
        # as "has values"
        has_v = ctx_v is not None and ctx_v.shape[-1] != 0
        if ctx_k is not None:
            C_cap = ctx_k.shape[0]
            kk = jnp.concatenate([ctx_k, k_g.astype(ctx_k.dtype)], axis=0)
            vv = jnp.concatenate([ctx_v, v_g.astype(ctx_v.dtype)], axis=0) \
                if has_v else ctx_v
            kv_seg = jnp.concatenate([
                jnp.where(jnp.arange(C_cap) < ctx_len, 0, -1), seg_g])
            kv_pos = jnp.concatenate(
                [jnp.arange(C_cap, dtype=pos.dtype), pos_g])
            new_k = jax.lax.dynamic_update_slice_in_dim(
                ctx_k, k_g.astype(ctx_k.dtype), ctx_len, axis=0)
            new_v = jax.lax.dynamic_update_slice_in_dim(
                ctx_v, v_g.astype(ctx_v.dtype), ctx_len, axis=0) \
                if has_v else ctx_v
        else:
            kk, vv, kv_seg, kv_pos = k_g, v_g, seg_g, pos_g
            new_k = new_v = None
        if expand_fn is not None:
            kk, vv = expand_fn(kk)
        out = flash(q, kk, vv, seg, kv_seg, pos, kv_pos,
                    causal=causal, window=window, scale=scale)
        return out, new_k, new_v

    return policy


def make_ulysses_policy(axis: str, d_s: int, flash=None, *,
                        groups=None) -> Callable:
    """``d_s`` is the EFFECTIVE degree (the sub-group size when
    ``groups`` — :func:`subgroup_info`'s SP groups — is set)."""
    flash = flash or blocked_flash_attention

    def policy(q, k_cur, v_cur, *, seg, pos, ctx_k, ctx_v, ctx_len,
               causal, window, scale, expand_fn=None):
        assert expand_fn is None, "MLA uses the allgather_kv policy"
        # tokens -> full sequence, heads -> sharded (4 a2a's: q, k, v, out)
        q_g = jax.lax.all_to_all(q, axis, split_axis=1, concat_axis=0,
                                 tiled=True, axis_index_groups=groups)
        k_g = jax.lax.all_to_all(k_cur, axis, split_axis=1, concat_axis=0,
                                 tiled=True, axis_index_groups=groups)
        v_g = jax.lax.all_to_all(v_cur, axis, split_axis=1, concat_axis=0,
                                 tiled=True, axis_index_groups=groups)
        seg_g = jax.lax.all_gather(seg, axis, axis=0, tiled=True,
                                   axis_index_groups=groups)
        pos_g = jax.lax.all_gather(pos, axis, axis=0, tiled=True,
                                   axis_index_groups=groups)
        if ctx_k is not None:
            # context is head-sharded: concat along the sequence dim
            C_cap = ctx_k.shape[0]
            kk = jnp.concatenate([ctx_k, k_g.astype(ctx_k.dtype)], axis=0)
            vv = jnp.concatenate([ctx_v, v_g.astype(ctx_v.dtype)], axis=0)
            kv_seg = jnp.concatenate([
                jnp.where(jnp.arange(C_cap) < ctx_len, 0, -1), seg_g])
            kv_pos = jnp.concatenate(
                [jnp.arange(C_cap, dtype=pos.dtype), pos_g])
            new_k = jax.lax.dynamic_update_slice_in_dim(
                ctx_k, k_g.astype(ctx_k.dtype), ctx_len, axis=0)
            new_v = jax.lax.dynamic_update_slice_in_dim(
                ctx_v, v_g.astype(ctx_v.dtype), ctx_len, axis=0)
        else:
            kk, vv, kv_seg, kv_pos = k_g, v_g, seg_g, pos_g
            new_k = new_v = None
        out_g = flash(q_g, kk, vv, seg_g, kv_seg, pos_g, kv_pos,
                      causal=causal, window=window, scale=scale)
        out = jax.lax.all_to_all(out_g, axis, split_axis=0, concat_axis=1,
                                 tiled=True, axis_index_groups=groups)
        return out, new_k, new_v

    return policy


# ---------------------------------------------------------------------------
# Vocab-parallel embedding + cross entropy.
# ---------------------------------------------------------------------------

def sharded_embed(embed_local: jnp.ndarray, tokens: jnp.ndarray, axis: str,
                  compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    """Vocab-parallel embedding for token-sharded inputs.

    embed_local: [V/d_s, D] (this device's vocab rows); tokens: [cap_loc]
    (this device's token block). The ids are all-gathered (tiny), every
    device looks the full chunk up in its vocab shard, and the partial rows
    reduce-scatter back to token shards — one collective each way.
    """
    ids = jax.lax.all_gather(tokens, axis, axis=0, tiled=True)   # [cap]
    vs = embed_local.shape[0]
    off = jax.lax.axis_index(axis) * vs
    loc = ids - off
    ok = (loc >= 0) & (loc < vs)
    rows = embed_local[jnp.clip(loc, 0, vs - 1)].astype(compute_dtype)
    rows = jnp.where(ok[:, None], rows, 0)
    return jax.lax.psum_scatter(rows, axis, scatter_dimension=0, tiled=True)


def sharded_ce(hidden_local: jnp.ndarray, w_local: jnp.ndarray,
               targets_local: jnp.ndarray, valid_local: jnp.ndarray,
               axis: str, vocab_true: Optional[int] = None
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Vocab-parallel streaming CE (logits never materialized).

    hidden/targets/valid are token-sharded over ``axis``; w_local is the
    vocab shard (possibly padded — ``vocab_true`` masks padded rows). The
    hidden rows are all-gathered (Megatron-style vocab-parallel head);
    per-token stats merge with a distributed LSE.
    Returns (sum_loss, n_valid) REPLICATED across ``axis``.
    """
    h_g = jax.lax.all_gather(hidden_local, axis, axis=0, tiled=True)
    t_g = jax.lax.all_gather(targets_local, axis, axis=0, tiled=True)
    v_g = jax.lax.all_gather(valid_local, axis, axis=0, tiled=True)
    vs = w_local.shape[0]
    off = jax.lax.axis_index(axis) * vs
    m, l, tgt = streaming_ce_stats(h_g, w_local, t_g - off,
                                   global_offset=off,
                                   vocab_true=vocab_true)
    # the max-shift is pure numerics: logsumexp is shift-invariant, so a
    # stop_gradient keeps the backward exact (and pmax has no grad rule).
    m_g = jax.lax.stop_gradient(jax.lax.pmax(jax.lax.stop_gradient(m), axis))
    l_g = jax.lax.psum(l * jnp.exp(m - m_g), axis)
    tgt_g = jax.lax.psum(tgt, axis)
    lse = m_g + jnp.log(jnp.maximum(l_g, 1e-30))
    loss = jnp.where(v_g, lse - tgt_g, 0.0)
    return loss.sum(), v_g.astype(jnp.float32).sum()


def sharded_greedy(hidden_local: jnp.ndarray, w_local: jnp.ndarray,
                   axis: str, vocab_true: Optional[int] = None,
                   block_v: int = 2048) -> jnp.ndarray:
    """Vocab-parallel greedy sampling: argmax over the full vocabulary
    without materializing logits. Returns int32 ids for LOCAL tokens."""
    T, D = hidden_local.shape
    vs = w_local.shape[0]
    off = jax.lax.axis_index(axis) * vs
    v_hi = vs if vocab_true is None else vocab_true
    pad = (-vs) % block_v
    w = w_local
    if pad:
        w = jnp.concatenate([w, jnp.zeros((pad, D), w.dtype)])
    nb = w.shape[0] // block_v
    wb = w.reshape(nb, block_v, D)

    def body(carry, inp):
        best_v, best_i = carry
        wt, bidx = inp
        # logits in f32 via accumulation dtype, operands stay bf16
        logits = jnp.einsum("td,vd->tv", hidden_local, wt,
                            preferred_element_type=jnp.float32)
        ids = bidx * block_v + jnp.arange(block_v)
        live = (ids[None, :] < vs) & ((off + ids)[None, :] < v_hi)
        logits = jnp.where(live, logits, -jnp.inf)
        m = logits.max(axis=1)
        am = jnp.argmax(logits, axis=1).astype(jnp.int32) + bidx * block_v
        take = m > best_v
        return (jnp.where(take, m, best_v),
                jnp.where(take, am, best_i)), None

    v0 = jnp.full((T,), -jnp.inf, jnp.float32)
    i0 = jnp.zeros((T,), jnp.int32)
    (val, idx), _ = jax.lax.scan(body, (v0, i0), (wb, jnp.arange(nb)))
    gid = idx + off
    gmax = jax.lax.pmax(val, axis)
    cand = jnp.where(val >= gmax, gid, jnp.int32(2 ** 30))
    return jax.lax.pmin(cand, axis).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Distributed SSM: sequence-parallel prefix scan + conv halo exchange.
# ---------------------------------------------------------------------------

def make_sp_ssm_scan(axis: str, d_s: int, local_scan, *,
                     groups=None, rep: int = 1) -> Callable:
    """Wrap a local scan (a, bx, h0) -> (hs, h_last) into a cross-shard
    prefix scan over token shards laid out contiguously along ``axis``.

    Associativity of h_t = a_t h_{t-1} + b_t gives per-shard summaries
    (A_prod, h_last0) with h_last0 the last state when starting from zero.
    The exclusive prefix over shards (tiny [d_s, di, ds] elementwise chain)
    produces each shard's true h0; the local scan is re-run with it
    (recompute beats materializing per-token cumulative products).

    ``d_s`` is the EFFECTIVE shard count; with sub-groups
    (``groups``/``rep`` from :func:`subgroup_info`) the summary gather
    stays inside one SP group and device ``m`` holds shard ``m // rep``.
    """

    def scan(a, bx, h0):
        zeros = jnp.zeros_like(h0)
        _, h_last0 = local_scan(a, bx, zeros)
        a_prod = jnp.prod(a, axis=0)  # elementwise — resets (a=0) propagate
        summ = jax.lax.all_gather(
            jnp.stack([a_prod, h_last0]), axis,
            axis_index_groups=groups)                    # [d_s, 2, di, ds]
        my = jax.lax.axis_index(axis) // rep

        def fold(carry, i):
            # carry = state entering shard i (starting from global h0)
            ap, hl = summ[i, 0], summ[i, 1]
            nxt = ap * carry + hl
            return nxt, carry

        _, entering = jax.lax.scan(fold, h0, jnp.arange(d_s))
        my_h0 = entering[my]
        hs, h_last = local_scan(a, bx, my_h0)
        # global final state = state leaving the last shard
        a_all = summ[:, 0]
        h_all = summ[:, 1]
        def fold2(carry, i):
            return a_all[i] * carry + h_all[i], None
        gfinal, _ = jax.lax.scan(fold2, h0, jnp.arange(d_s))
        return hs, gfinal

    return scan


def make_sp_conv_tail_exchange(axis: str, d_s: int, *,
                               rep: int = 1) -> Callable:
    """Conv halo: shard i's causal-conv tail is shard i-1's trailing rows.

    Shard 0 continues from the PREVIOUS CHUNK, whose globally-last tokens
    live on the LAST rank — so the carried tail is ppermuted (d_s-1 -> 0).
    Each rank stores its own trailing rows after the chunk (ssm.mamba_apply),
    which makes this exchange self-consistent across consecutive split
    chunks.

    ``d_s`` is the EFFECTIVE shard count; ``rep > 1`` replays the same
    ring inside each of the ``rep`` SP sub-groups (device ``k*rep + j``
    is shard ``k`` of group ``j`` — :func:`subgroup_info`'s layout).
    """
    # ppermute takes explicit (src, dst) device pairs, so the sub-group
    # structure is baked into the permutation rather than group lists
    shift = [(k * rep + j, (k + 1) * rep + j)
             for j in range(rep) for k in range(d_s - 1)]
    wrap = [((d_s - 1) * rep + j, j) for j in range(rep)]

    def exchange(xs: jnp.ndarray, carried_tail: jnp.ndarray) -> jnp.ndarray:
        K1 = carried_tail.shape[0]
        my_tail = jax.lax.dynamic_slice_in_dim(
            xs, xs.shape[0] - K1, K1, axis=0)
        from_left = jax.lax.ppermute(my_tail, axis, shift)
        prev_chunk = jax.lax.ppermute(carried_tail.astype(xs.dtype), axis,
                                      wrap)
        my = jax.lax.axis_index(axis) // rep
        return jnp.where(my == 0, prev_chunk, from_left)

    return exchange
