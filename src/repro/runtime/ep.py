"""Expert parallelism over the "model" axis.

Two dispatch strategies (selectable; both exact, no token dropping):

* ``gather`` (default): all-gather the token block, compute the LOCAL
  experts' contribution for every token, reduce-scatter the combined
  output back to token shards. Perfectly load-balanced regardless of
  routing skew; comm = one all-gather + one reduce-scatter of [T, D] per
  MoE layer. The right choice when top_k*D_ff_expert is small relative to
  D (olmoe: 8*1024 vs 2048; deepseek: 6*1408 vs 2048).
* ``a2a``: capacity-based token dispatch with all-to-alls (Switch-style).
  Lower comm volume when top_k/E is small, but pays capacity padding and
  drops on overflow. Implemented as a hillclimb lever (EXPERIMENTS.md §Perf).

Expert weights arrive EP-sharded: [E/d_s, D, F] local views (the ZeRO
gather skips them — sharding.EP_PATH_RE). The router and shared experts are
ordinary ZeRO parameters.
"""

from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.moe import _expert_ffn, router_weights

__all__ = ["make_moe_ep"]


def make_moe_ep(axis: str, d_s: int, impl: str = "gather") -> Callable:
    if impl != "gather":
        raise NotImplementedError("a2a dispatch lands with the perf pass")

    def moe_fn(cfg: ArchConfig, p: Dict, x_local: jnp.ndarray) -> jnp.ndarray:
        s = cfg.spec
        e_loc = p["w_gate"].shape[0]          # E / d_s
        x_full = jax.lax.all_gather(x_local, axis, axis=0, tiled=True)
        Tf = x_full.shape[0]
        w, idx = router_weights(cfg, p, x_full)          # router is gathered
        combine = jnp.zeros((Tf, s.n_experts), jnp.float32)
        combine = combine.at[jnp.arange(Tf)[:, None], idx].add(w)
        e_off = jax.lax.axis_index(axis) * e_loc
        my_combine = jax.lax.dynamic_slice_in_dim(
            combine, e_off, e_loc, axis=1)               # [Tf, E_loc]

        def body(acc, per_e):
            wg, wu, wd, cw = per_e
            y = _expert_ffn(wg, wu, wd, x_full)
            return acc + y.astype(jnp.float32) * cw[:, None], None

        acc0 = jnp.zeros((Tf, s.d_model), jnp.float32)
        acc, _ = jax.lax.scan(
            body, acc0, (p["w_gate"], p["w_up"], p["w_down"], my_combine.T))
        y_local = jax.lax.psum_scatter(acc, axis, scatter_dimension=0,
                                       tiled=True).astype(x_local.dtype)
        if s.n_shared_experts > 0:
            sh = p["shared"]
            y_local = y_local + _expert_ffn(
                sh["w_gate"], sh["w_up"], sh["w_down"], x_local)
        return y_local

    return moe_fn
