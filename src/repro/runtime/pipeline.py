"""The EPP pipeline executor: a statically-scheduled, scanned 1F1B pipeline
expressed in XLA SPMD (DESIGN.md §2.1.1).

Runs INSIDE ``shard_map`` over ("pod",) "data", "model":

* the "data" axis carries pipeline stages; stage p's layer parameters are
  the local shard of the stage-stacked tree;
* forward is a ``lax.scan`` over ``n_chunks + d_p - 1`` ticks. Each tick a
  stage (1) takes the embedded chunk (stage 0) or the ppermute'd activation
  from its left neighbor, (2) runs its layers — with the solver-chosen
  number of leading layers under ``jax.checkpoint`` (Eq. 9-11's layer-
  granular remat), (3) the last stage folds the chunk into the streaming
  vocab-parallel CE;
* the split-chunk context (KV buffers per the SP policy's layout + SSM
  state) is scan *carry* per stage, appended at offset ``ctx_len[k]``; a
  chunk with ctx_len == 0 implicitly resets the buffers (overwrite from 0)
  and the SSM state (explicit ``where``);
* backward = the autodiff transpose of the scan: reverse tick order,
  reversed ppermute, and the context-carry cotangent reproduces the paper's
  dKV dependency (Eq. 5) exactly.

Bubble ticks compute on garbage (seg = -1 masks attention and loss): the
lockstep-SPMD analogue of pipeline bubbles. They inflate compiled HLO FLOPs
by (n + d_p - 1)/n — the roofline's MODEL_FLOPS ratio surfaces this.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import DecoderLM, LayerCtx
from repro.models.config import ArchConfig
from repro.models.layers import rms_norm

from . import sp
from .sharding import EP_PATH_RE, tree_paths_map

__all__ = ["PipelineGeometry", "pipeline_loss_fn", "gather_layer_params",
           "init_stage_ctx"]


@dataclass(frozen=True)
class PipelineGeometry:
    """Static geometry of one compiled executable (a plan bucket)."""
    n_chunks: int            # chunks per pod
    cap: int                 # tokens per chunk (global, pre-SP-sharding)
    ctx_cap: int             # context buffer rows (policy layout dependent)
    d_p: int
    d_s: int
    l_ckpt: int              # uniform remat: leading layers checkpointed
    layers_per_stage: int
    policy: str              # "ulysses" | "allgather_kv" | "none"
    compute_dtype: Any = jnp.bfloat16
    # ZeRO-3 gather cadence: "per_tick" re-gathers every layer's weights for
    # every chunk (paper-faithful DeepSpeed ZeRO-3 semantics); "per_step"
    # gathers the stage's weights ONCE per training step and keeps them
    # resident (ZeRO-2-like compute path, ZeRO-3 storage) — the first
    # beyond-paper optimization, see EXPERIMENTS.md §Perf.
    zero3_mode: str = "per_tick"


def gather_layer_params(lp, shard_dims, axis: str):
    """ZeRO-3: materialize one layer's full parameters from "model" shards.

    ``shard_dims`` is the precomputed tree of gather dims (full-shape
    coordinates, including the [d_p, L_s] prefix — hence the -2). EP leaves
    carry a marker dim but stay sharded (expert parallelism), which
    ``sharding.EP_PATH_RE`` expresses by pointing at the expert dim; the
    path check below skips them.
    """
    def _g(path, leaf):
        if EP_PATH_RE.search(path):
            return leaf
        zd = _lookup(shard_dims, path)
        if zd is None:
            return leaf
        return jax.lax.all_gather(leaf, axis, axis=zd - 2, tiled=True)
    return tree_paths_map(_g, lp)


def _lookup(tree, path: str):
    node = tree
    for key in path.split("/"):
        node = node[key]
    return node


def gather_stage_params(stage_params, shard_dims, axis: str):
    """ZeRO-3 'per_step' mode: gather the whole stage's stacked [L_s, ...]
    tree once; leaves keep their L_s dim so the gather axis is zd - 1."""
    def _g(path, leaf):
        if EP_PATH_RE.search(path):
            return leaf
        zd = _lookup(shard_dims, path)
        if zd is None:
            return leaf
        return jax.lax.all_gather(leaf, axis, axis=zd - 1, tiled=True)
    return tree_paths_map(_g, stage_params)


def init_stage_ctx(cfg: ArchConfig, geom: PipelineGeometry) -> LayerCtx:
    """Per-stage context carry. KV layout depends on the SP policy:
    ulysses => head-sharded [ctx_cap, Hkv/d_s, Dh]; allgather_kv =>
    replicated [ctx_cap, Hkv, Dh] (or MLA cache rows [ctx_cap, 1, r+rr])."""
    s = cfg.spec
    L_s = geom.layers_per_stage
    k = v = hh = tail = None
    if not s.attn_free:
        if s.kv_lora_rank > 0:
            kshape = (geom.ctx_cap, 1, s.kv_lora_rank + s.qk_rope_dim)
            vshape = (geom.ctx_cap, 1, 0)
        elif geom.policy == "ulysses":
            kshape = (geom.ctx_cap, s.n_kv_heads // geom.d_s, s.head_dim)
            vshape = kshape
        else:
            kshape = (geom.ctx_cap, s.n_kv_heads, s.head_dim)
            vshape = kshape
        k = jnp.zeros((L_s, *kshape), geom.compute_dtype)
        v = jnp.zeros((L_s, *vshape), geom.compute_dtype)
    if s.ssm_state > 0:
        di_loc = s.inner  # full: SSM is token-sharded, channels intact
        hh = jnp.zeros((L_s, di_loc, s.ssm_state), jnp.float32)
        tail = jnp.zeros((L_s, s.ssm_conv - 1, di_loc), geom.compute_dtype)
    return LayerCtx(k, v, hh, tail)


def _make_model(cfg: ArchConfig, geom: PipelineGeometry,
                model_axis: str) -> DecoderLM:
    if geom.policy == "ulysses":
        attn = sp.make_ulysses_policy(model_axis, geom.d_s)
    elif geom.policy == "allgather_kv":
        attn = sp.make_allgather_kv_policy(model_axis)
    else:
        attn = None  # attn-free arch never calls it
    moe_fn = None
    if cfg.spec.n_experts > 0:
        from .ep import make_moe_ep
        moe_fn = make_moe_ep(model_axis, geom.d_s)
    ssm_scan = ssm_tail = None
    if cfg.spec.ssm_state > 0:
        from repro.models.ssm import _blocked_ssm
        ssm_scan = sp.make_sp_ssm_scan(model_axis, geom.d_s, _blocked_ssm)
        ssm_tail = sp.make_sp_conv_tail_exchange(model_axis, geom.d_s)
    return DecoderLM(cfg, attn_fn=attn, moe_fn=moe_fn,
                     ssm_scan_fn=ssm_scan, ssm_tail_exchange=ssm_tail)


def _run_stage_layers(model: DecoderLM, geom: PipelineGeometry,
                      stage_params, shard_dims, x, ctx: LayerCtx, *,
                      seg, pos, ctx_len, windows, active, model_axis: str):
    """Scan this stage's layers with the solver's remat split: the first
    ``l_ckpt`` layers recompute in backward (only their input + un-freeable
    KV persist — Eq. 9), the rest keep activations. ``active`` masks padded
    layer slots (non-divisible depths) into identity."""

    def layer_body(x, per_layer):
        lp, w, act, lctx = per_layer
        lp_full = lp if geom.zero3_mode == "per_step" else \
            gather_layer_params(lp, shard_dims, model_axis)
        x_new, new_ctx = model.layer_apply(
            lp_full, x, pos=pos, seg=seg, ctx=lctx, ctx_len=ctx_len,
            window=w)
        x_out = jnp.where(act, x_new, x)
        new_ctx = jax.tree.map(
            lambda new, old: jnp.where(act, new, old) if new is not None
            else None, new_ctx, lctx, is_leaf=lambda t: t is None)
        return x_out, new_ctx

    L_s = geom.layers_per_stage
    l_ck = max(0, min(geom.l_ckpt, L_s))

    def split(tree, a, b):
        return jax.tree.map(lambda t: t[a:b], tree)

    ctx_parts = []
    if l_ck > 0:
        body_ck = jax.checkpoint(layer_body, prevent_cse=False)
        x, ctx_a = jax.lax.scan(
            body_ck, x, (split(stage_params, 0, l_ck),
                         windows[:l_ck], active[:l_ck],
                         split(ctx, 0, l_ck)))
        ctx_parts.append(ctx_a)
    if l_ck < L_s:
        x, ctx_b = jax.lax.scan(
            layer_body, x, (split(stage_params, l_ck, L_s),
                            windows[l_ck:], active[l_ck:],
                            split(ctx, l_ck, L_s)))
        ctx_parts.append(ctx_b)
    if len(ctx_parts) == 2:
        new_ctx = jax.tree.map(
            lambda a, b: jnp.concatenate([a, b], axis=0) if a is not None
            else None, ctx_parts[0], ctx_parts[1],
            is_leaf=lambda t: t is None)
    else:
        new_ctx = ctx_parts[0]
    return x, new_ctx


def pipeline_loss_fn(cfg: ArchConfig, geom: PipelineGeometry,
                     shard_dims, *,
                     pod_axis: Optional[str], data_axis: str = "data",
                     model_axis: str = "model",
                     mode: str = "train") -> Callable:
    """Returns loss_local(params, batch) to be called inside shard_map.

    params (local views):
      {"stages": stage-stacked layer tree [1, L_s, ...shards...],
       "embed": [V/d_s, D], "final_norm": [D or D/d_s],
       "unembed": optional [V/d_s, D]}
    batch (local views):
      {"tokens"/"targets"/"seg"/"pos": [n_chunks, cap/d_s],
       "ctx_len": [n_chunks]} (+ leading pod dim already sharded away)

    Returns (sum_loss, n_valid) replicated over data/model (psum'd).
    """
    model = _make_model(cfg, geom, model_axis)
    s = cfg.spec
    L_pad = geom.d_p * geom.layers_per_stage
    win_flat = [cfg.layer_window(i) for i in range(s.n_layers)]
    win_flat += [0] * (L_pad - s.n_layers)
    windows_all = jnp.asarray(win_flat, jnp.int32).reshape(
        geom.d_p, geom.layers_per_stage)
    import numpy as _np
    active_all = jnp.asarray(
        (_np.arange(L_pad) < s.n_layers).reshape(geom.d_p,
                                                 geom.layers_per_stage))

    def loss_local(params, batch):
        p_idx = jax.lax.axis_index(data_axis)
        stage_params = jax.tree.map(lambda x: x[0], params["stages"])
        if geom.zero3_mode == "per_step":
            stage_params = gather_stage_params(stage_params, shard_dims,
                                               model_axis)
        windows = windows_all[p_idx]
        active = active_all[p_idx]
        n, d_p = geom.n_chunks, geom.d_p
        cap_loc = batch["tokens"].shape[-1]
        dt = geom.compute_dtype

        tokens_a = batch["tokens"].reshape(n, cap_loc)
        targets_a = batch["targets"].reshape(n, cap_loc)
        seg_a = batch["seg"].reshape(n, cap_loc)
        pos_a = batch["pos"].reshape(n, cap_loc)
        ctxlen_a = batch["ctx_len"].reshape(n)

        # final-norm gamma may be feature-sharded; gather once
        fn_gamma = params["final_norm"]
        if fn_gamma.shape[0] != s.d_model:
            fn_gamma = jax.lax.all_gather(fn_gamma, model_axis, axis=0,
                                          tiled=True)
        head_w = params.get("unembed", params["embed"])

        ctx0 = init_stage_ctx(cfg, geom)
        x0 = jnp.zeros((cap_loc, s.d_model), dt)

        def tick(carry, t):
            x_recv, ctx, acc0_c, acc1_c = carry
            loss_acc = (acc0_c, acc1_c)
            idx = t - p_idx
            valid = (idx >= 0) & (idx < n)
            idxc = jnp.clip(idx, 0, n - 1)
            tokens = tokens_a[idxc]
            seg = jnp.where(valid, seg_a[idxc], -1)
            pos = pos_a[idxc]
            tgt = targets_a[idxc]
            ctx_len = jnp.where(valid, ctxlen_a[idxc], 0)

            x_emb = sp.sharded_embed(params["embed"], tokens, model_axis, dt)
            if cfg.embed_scale:
                x_emb = x_emb * jnp.asarray(s.d_model ** 0.5, dt)
            x_in = jnp.where(p_idx == 0, x_emb, x_recv)

            # SSM state resets at sequence starts (ctx_len == 0)
            if ctx.ssm_h is not None:
                hh = jnp.where(ctx_len == 0, 0.0, ctx.ssm_h)
                ctx = ctx._replace(ssm_h=hh)

            x_out, ctx = _run_stage_layers(
                model, geom, stage_params, shard_dims, x_in, ctx,
                seg=seg, pos=pos, ctx_len=ctx_len, windows=windows,
                active=active, model_axis=model_axis)

            h_last = rms_norm(x_out, fn_gamma, cfg.rms_eps)
            if mode == "train":
                ce_valid = (seg >= 0) & (tgt >= 0) & valid \
                    & (p_idx == d_p - 1)
                l_sum, n_val = sp.sharded_ce(h_last, head_w,
                                             jnp.maximum(tgt, 0), ce_valid,
                                             model_axis, vocab_true=s.vocab)
                out_acc = (loss_acc[0] + l_sum, loss_acc[1] + n_val)
            else:
                # prefill: greedy next-token ids per position (the KV fills
                # the context carry — it IS the prefill cache)
                ids = sp.sharded_greedy(h_last, head_w, model_axis,
                                        vocab_true=s.vocab)
                sel = valid & (p_idx == d_p - 1)
                new_ids = jnp.where(sel, ids, loss_acc[0][idxc])
                out_acc = (loss_acc[0].at[idxc].set(new_ids), loss_acc[1])

            if d_p > 1:
                x_send = jax.lax.ppermute(
                    x_out, data_axis,
                    [(i, i + 1) for i in range(d_p - 1)])
            else:
                x_send = x_out
            return (x_send, ctx, out_acc[0], out_acc[1]), None

        if mode == "train":
            acc0: Tuple = (jnp.float32(0), jnp.float32(0))
        else:
            acc0 = (jnp.zeros((n, cap_loc), jnp.int32), jnp.float32(0))
        init = (x0, ctx0, acc0[0], acc0[1])
        (xf, ctxf, a0, a1), _ = jax.lax.scan(
            tick, init, jnp.arange(n + d_p - 1))
        if mode == "train":
            # only the last stage accumulated loss; broadcast-sum over stages
            loss = jax.lax.psum(a0, data_axis)
            n_val = jax.lax.psum(a1, data_axis)
            return loss, n_val
        ids = jax.lax.psum(a0, data_axis)  # only last stage nonzero... see note
        return ids, ctxf

    return loss_local
