"""Decoder-only EPP pipeline: a thin adapter over the shared stage-program
executor (DESIGN.md §2.1.1, runtime/executor.py).

Runs INSIDE ``shard_map`` over ("pod",) "data", "model":

* the "data" axis carries pipeline stages; stage p's layer parameters are
  the local shard of the stage-stacked tree;
* the tick loop, ppermute hand-off, remat split and streaming-CE folding
  are the executor core's; this module supplies the decoder-only hooks:
  embed injection at stage 0, per-layer ZeRO-3 gather + ``layer_apply``,
  and the split-chunk KV/SSM context carry appended at offset
  ``ctx_len[k]`` (a chunk with ctx_len == 0 implicitly resets the buffers
  and — explicitly — the SSM state);
* backward = the autodiff transpose of the scan: reverse tick order,
  reversed ppermute, and the context-carry cotangent reproduces the paper's
  dKV dependency (Eq. 5) exactly.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import DecoderLM, LayerCtx
from repro.models.config import ArchConfig
from repro.models.layers import rms_norm

from . import executor, sp
from .program import StageProgram
from .sharding import gather_layer_params, gather_stage_params

__all__ = ["PipelineGeometry", "pipeline_loss_fn", "gather_layer_params",
           "gather_stage_params", "init_stage_ctx"]


@dataclass(frozen=True)
class PipelineGeometry:
    """Static geometry of one compiled executable (a plan bucket)."""
    n_chunks: int            # chunks per pod
    cap: int                 # tokens per chunk (global, pre-SP-sharding)
    ctx_cap: int             # context buffer rows (policy layout dependent)
    d_p: int
    d_s: int
    l_ckpt: int              # max remat depth (uniform policy value)
    layers_per_stage: int
    policy: str              # "ulysses" | "allgather_kv" | "none"
    compute_dtype: Any = jnp.bfloat16
    # effective sequence-parallel degree (the plan's SP axis): tokens of a
    # chunk are sharded over d_s_eff SUB-GROUPS of the model axis and the
    # chunk's compute replicates d_s // d_s_eff times (sp.subgroup_info's
    # layout). 0 normalizes to the full d_s — the pre-SP-axis behavior.
    # Parameters, the vocab axis, and the batch's resting sharding stay
    # over the FULL model axis regardless.
    d_s_eff: int = 0
    # ZeRO-3 gather cadence: "per_tick" re-gathers every layer's weights for
    # every chunk (paper-faithful DeepSpeed ZeRO-3 semantics); "per_step"
    # gathers the stage's weights ONCE per training step and keeps them
    # resident (ZeRO-2-like compute path, ZeRO-3 storage) — the first
    # beyond-paper optimization, see EXPERIMENTS.md §Perf.
    zero3_mode: str = "per_tick"
    # schedule backend (core/schedule.py registry name) + virtual stages per
    # device. v_stages > 1 (interleaved-1f1b) splits each stage's layer
    # block into v virtual stages riding the same ppermute ring — it must
    # divide layers_per_stage and is baked into the parameter stacking
    # (sharding.interleaved_layer_order), so it is fixed per training run.
    schedule: str = "gpipe-1f1b"
    v_stages: int = 1
    # stage-aware adaptive checkpointing (Eq. 9-11): the solver's
    # per-(stage, chunk) layer-count matrix as a hashable (d_p, n_chunks)
    # tuple-of-tuples — None means the uniform policy (every tick remats
    # the leading l_ckpt layers via a static scan split). When set, each
    # tick looks its (stage, v_idx, chunk) depth up in traced arithmetic
    # (executor.remat_tick_count) and the whole table is baked into the
    # compiled step — which is why ExecutionPlan.bucket_key() carries the
    # table's digest.
    ckpt_table: Optional[Tuple[Tuple[int, ...], ...]] = None
    # zero-bubble split backward: B-grad ticks drop the weight-grad GEMMs
    # from the critical path (executor.split_backward_stage) and dedicated
    # W-drain ticks replay them during the backward cooldown. Train mode
    # only; runs under any schedule backend (the parity tests exercise all
    # three), defaulted on by zero-bubble-h1 in make_geometry.
    split_bwd: bool = False
    # double-buffered stage hand-off: the executor issues the stream
    # ppermute before the accumulator fold, so the fold (the vocab-parallel
    # CE matmul) overlaps the in-flight collective under the latency-hiding
    # XLA flags (launch/mesh.py). Bitwise-identical results.
    overlap_handoff: bool = True

    def __post_init__(self) -> None:
        if self.v_stages < 1 or self.layers_per_stage % self.v_stages:
            raise ValueError(
                f"v_stages={self.v_stages} must divide "
                f"layers_per_stage={self.layers_per_stage}")
        if self.d_s_eff == 0:
            object.__setattr__(self, "d_s_eff", self.d_s)
        if self.d_s_eff < 1 or self.d_s % self.d_s_eff:
            raise ValueError(
                f"d_s_eff={self.d_s_eff} must divide d_s={self.d_s}")
        if self.policy == "ulysses" and self.d_s_eff == 1:
            raise ValueError("ulysses at d_s_eff=1 is meaningless; the "
                             "planner emits policy 'none' there")
        executor.canonical_ckpt_table(self.ckpt_table, d_p=self.d_p,
                                      n_chunks=self.n_chunks)

    @property
    def sp_rep(self) -> int:
        """Chunk-compute replication factor of the SP sub-grouping."""
        return self.d_s // self.d_s_eff


def init_stage_ctx(cfg: ArchConfig, geom: PipelineGeometry) -> LayerCtx:
    """Per-stage context carry. KV layout depends on the SP policy:
    ulysses => head-sharded [ctx_cap, Hkv/d_s_eff, Dh]; allgather_kv and
    "none" => replicated [ctx_cap, Hkv, Dh] (or MLA cache rows
    [ctx_cap, 1, r+rr])."""
    s = cfg.spec
    L_s = geom.layers_per_stage
    k = v = hh = tail = None
    if not s.attn_free:
        if s.kv_lora_rank > 0:
            kshape = (geom.ctx_cap, 1, s.kv_lora_rank + s.qk_rope_dim)
            vshape = (geom.ctx_cap, 1, 0)
        elif geom.policy == "ulysses":
            kshape = (geom.ctx_cap, s.n_kv_heads // geom.d_s_eff,
                      s.head_dim)
            vshape = kshape
        else:
            kshape = (geom.ctx_cap, s.n_kv_heads, s.head_dim)
            vshape = kshape
        k = jnp.zeros((L_s, *kshape), geom.compute_dtype)
        v = jnp.zeros((L_s, *vshape), geom.compute_dtype)
    if s.ssm_state > 0:
        di_loc = s.inner  # full: SSM is token-sharded, channels intact
        hh = jnp.zeros((L_s, di_loc, s.ssm_state), jnp.float32)
        tail = jnp.zeros((L_s, s.ssm_conv - 1, di_loc), geom.compute_dtype)
    return LayerCtx(k, v, hh, tail)


def _make_model(cfg: ArchConfig, geom: PipelineGeometry,
                model_axis: str) -> DecoderLM:
    rep, sp_groups, _ = sp.subgroup_info(geom.d_s, geom.d_s_eff)
    if geom.policy == "ulysses":
        attn = sp.make_ulysses_policy(model_axis, geom.d_s_eff,
                                      groups=sp_groups)
    elif geom.policy == "allgather_kv":
        attn = sp.make_allgather_kv_policy(model_axis, groups=sp_groups)
    else:
        # "none": with attention present this is d_s_eff == 1 — every
        # device holds the whole chunk, so DecoderLM's default LOCAL
        # policy is exactly right; attn-free archs never call it at all
        attn = None
    moe_fn = None
    if cfg.spec.n_experts > 0:
        from .ep import make_moe_ep
        moe_fn = make_moe_ep(model_axis, geom.d_s)
    ssm_scan = ssm_tail = None
    if cfg.spec.ssm_state > 0:
        from repro.models.ssm import _blocked_ssm
        ssm_scan = sp.make_sp_ssm_scan(model_axis, geom.d_s_eff,
                                       _blocked_ssm, groups=sp_groups,
                                       rep=rep)
        ssm_tail = sp.make_sp_conv_tail_exchange(model_axis, geom.d_s_eff,
                                                 rep=rep)
    return DecoderLM(cfg, attn_fn=attn, moe_fn=moe_fn,
                     ssm_scan_fn=ssm_scan, ssm_tail_exchange=ssm_tail)


def _run_stage_layers(model: DecoderLM, geom: PipelineGeometry,
                      stage_params, shard_dims, x, ctx: LayerCtx, *,
                      seg, pos, ctx_len, windows, active, model_axis: str,
                      n_layers: Optional[int] = None,
                      l_ckpt: Optional[Any] = None):
    """This backend's layer body under the executor's remat split:
    ZeRO-3 gather (per-tick mode), ``layer_apply`` with the context carry,
    and ``active`` masking padded layer slots into identity.

    ``n_layers``/``l_ckpt`` override the geometry defaults when the tick
    runs a single virtual-stage block instead of the whole stage;
    ``l_ckpt`` may be a traced scalar (the stage-aware per-(stage, chunk)
    lookup) — the executor then selects remat per layer at runtime."""

    def layer_body(x, per_layer):
        lp, w, act, lctx = per_layer
        lp_full = lp if geom.zero3_mode == "per_step" else \
            gather_layer_params(lp, shard_dims, model_axis)
        x_new, new_ctx = model.layer_apply(
            lp_full, x, pos=pos, seg=seg, ctx=lctx, ctx_len=ctx_len,
            window=w)
        x_out = jnp.where(act, x_new, x)
        new_ctx = jax.tree.map(
            lambda new, old: jnp.where(act, new, old) if new is not None
            else None, new_ctx, lctx, is_leaf=lambda t: t is None)
        return x_out, new_ctx

    return executor.run_stage_layers(
        layer_body, x, (stage_params, windows, active, ctx),
        l_ckpt=geom.l_ckpt if l_ckpt is None else l_ckpt,
        n_layers=(geom.layers_per_stage if n_layers is None else n_layers))


def pipeline_loss_fn(cfg: ArchConfig, geom: PipelineGeometry,
                     shard_dims, *,
                     pod_axis: Optional[str], data_axis: str = "data",
                     model_axis: str = "model",
                     mode: str = "train") -> Callable:
    """Returns loss_local(params, batch) to be called inside shard_map.

    params (local views):
      {"stages": stage-stacked layer tree [1, L_s, ...shards...],
       "embed": [V/d_s, D], "final_norm": [D or D/d_s],
       "unembed": optional [V/d_s, D]}
    batch (local views):
      {"tokens"/"targets"/"seg"/"pos": [n_chunks, cap/d_s],
       "ctx_len": [n_chunks]} (+ leading pod dim already sharded away)

    Returns (sum_loss, n_valid) replicated over data/model (psum'd).
    """
    if mode != "train" and geom.d_s_eff != geom.d_s:
        raise ValueError(
            f"mode={mode!r} requires d_s_eff == d_s "
            f"({geom.d_s_eff} != {geom.d_s}): the greedy fold's "
            "token-sharded gather assumes unreplicated shards")
    model = _make_model(cfg, geom, model_axis)
    rep, _, replica_groups = sp.subgroup_info(geom.d_s, geom.d_s_eff)
    s = cfg.spec
    v_st, L_s = geom.v_stages, geom.layers_per_stage
    L_v = L_s // v_st
    L_pad = geom.d_p * L_s
    import numpy as _np
    win_flat = _np.asarray([cfg.layer_window(i) for i in range(s.n_layers)]
                           + [0] * (L_pad - s.n_layers), _np.int32)
    act_flat = _np.arange(L_pad) < s.n_layers
    if v_st > 1:
        # virtual-stage placement: device p's local block (j, l) holds
        # global layer (j*d_p + p)*L_v + l — same order the params stack in
        from .sharding import interleaved_layer_order
        order = interleaved_layer_order(geom.d_p, L_s, v_st)
        win_flat, act_flat = win_flat[order], act_flat[order]
    windows_all = jnp.asarray(win_flat.reshape(geom.d_p, L_s))
    active_all = jnp.asarray(act_flat.reshape(geom.d_p, L_s))
    # stage-aware checkpointing: the solver's (d_p, n_chunks) table as a
    # baked-in constant; None keeps the uniform static-split path
    ckpt_tab = None if geom.ckpt_table is None else \
        jnp.asarray(geom.ckpt_table, jnp.int32)

    def loss_local(params, batch):
        p_idx = jax.lax.axis_index(data_axis)
        stage_params = jax.tree.map(lambda x: x[0], params["stages"])
        if geom.zero3_mode == "per_step":
            stage_params = gather_stage_params(stage_params, shard_dims,
                                               model_axis)
        windows = windows_all[p_idx]
        active = active_all[p_idx]
        n, d_p = geom.n_chunks, geom.d_p
        cap_loc = batch["tokens"].shape[-1]
        dt = geom.compute_dtype

        tokens_a = batch["tokens"].reshape(n, cap_loc)
        targets_a = batch["targets"].reshape(n, cap_loc)
        seg_a = batch["seg"].reshape(n, cap_loc)
        pos_a = batch["pos"].reshape(n, cap_loc)
        ctxlen_a = batch["ctx_len"].reshape(n)
        if rep > 1:
            # the batch rests sharded over the FULL model axis (cap/d_s
            # rows/device); at d_s_eff < d_s each device needs its
            # SUB-GROUP shard (cap/d_s_eff rows). The replica groups are
            # contiguous, so a tiled in-group gather concatenates the r
            # full-axis blocks back into the sub-group shard — replicated
            # across the r devices that share it. All-int arrays: no grad
            # flows through this gather.
            def _regather(t):
                return jax.lax.all_gather(t, model_axis, axis=1, tiled=True,
                                          axis_index_groups=replica_groups)
            tokens_a, targets_a, seg_a, pos_a = (
                _regather(tokens_a), _regather(targets_a),
                _regather(seg_a), _regather(pos_a))
            cap_loc *= rep

        # final-norm gamma may be feature-sharded; gather once
        fn_gamma = params["final_norm"]
        if fn_gamma.shape[0] != s.d_model:
            fn_gamma = jax.lax.all_gather(fn_gamma, model_axis, axis=0,
                                          tiled=True)
        head_w = params.get("unembed", params["embed"])

        ctx0 = init_stage_ctx(cfg, geom)
        x0 = jnp.zeros((cap_loc, s.d_model), dt)
        split = geom.split_bwd and mode == "train"

        def fold_acc(tc, x_out, ctx, acc):  # noqa: ARG001 (ctx unused)
            """Fold one tick's output into the accumulator (CE / greedy
            ids). With ``overlap_handoff`` the executor calls this AFTER
            issuing the stream ppermute, so the vocab-parallel matmul here
            overlaps the in-flight collective (double-buffered hand-off).
            """
            seg = jnp.where(tc.valid, seg_a[tc.idxc], -1)
            if rep > 1:
                # the rep replicas of each sub-group computed identical
                # chunks; only the PRIMARY replica (replica index 0) folds
                # CE, so the full-axis psum inside sharded_ce counts every
                # token exactly once — non-primary copies contribute
                # exactly-zero loss AND exactly-zero cotangents (the mask
                # rides `seg`, which only the CE valid-test consumes here)
                primary = jax.lax.axis_index(model_axis) % rep == 0
                seg = jnp.where(primary, seg, -1)
            tgt = targets_a[tc.idxc]
            h_last = rms_norm(x_out, fn_gamma, cfg.rms_eps)
            if mode == "train":
                return executor.fold_streaming_ce(
                    tc, h_last, head_w, tgt, seg, acc,
                    model_axis=model_axis, vocab_true=s.vocab)
            # prefill: greedy next-token ids per position (the KV fills
            # the context carry — it IS the prefill cache). h_last is
            # token-sharded here, unlike decode's replicated rows.
            ids = executor.fold_greedy_ids(
                tc, h_last, head_w, acc[0],
                model_axis=model_axis, vocab_true=s.vocab,
                token_sharded=True)
            return (ids, acc[1])

        def _pack_aux(seg, pos, ctx_len, l_act, start=None):
            """Float32-cast pytree of the traced per-tick values the split
            stage closure needs: executor.split_backward_stage's backward
            is re-traced at scan-transpose time, so NOTHING traced may be
            closure-captured — it all rides through this explicit aux (and
            float32 keeps the cotangents ordinary zeros; every value here
            is an integer far below 2**24, so the round trip is exact)."""
            aux = {"seg": seg, "pos": pos, "ctx_len": ctx_len,
                   "windows": windows, "active": active}
            if l_act is not None and not isinstance(l_act, int):
                aux["l_ckpt"] = l_act
            if start is not None:
                aux["start"] = start
            return jax.tree.map(
                lambda a: jnp.asarray(a).astype(jnp.float32), aux)

        def _unpack_aux(af):
            i32 = lambda k: af[k].astype(jnp.int32)  # noqa: E731
            return (i32("seg"), i32("pos"), i32("ctx_len"), i32("windows"),
                    af["active"] > 0.5,
                    i32("l_ckpt") if "l_ckpt" in af else None,
                    i32("start") if "start" in af else None)

        def tick(tc, x_recv, ctx, acc, stash=None):
            tokens = tokens_a[tc.idxc]
            seg = jnp.where(tc.valid, seg_a[tc.idxc], -1)
            pos = pos_a[tc.idxc]
            ctx_len = jnp.where(tc.valid, ctxlen_a[tc.idxc], 0)

            x_emb = sp.sharded_embed(params["embed"], tokens, model_axis, dt)
            if cfg.embed_scale:
                x_emb = x_emb * jnp.asarray(s.d_model ** 0.5, dt)
            x_in = jnp.where(tc.is_first_stage, x_emb, x_recv)

            if v_st == 1:
                ctx_in = executor.reset_ssm_at_boundary(ctx, ctx_len)
                l_act = None if ckpt_tab is None else \
                    executor.remat_tick_count(ckpt_tab, tc.p_idx, tc.idxc,
                                              tc.valid)
                if split:
                    # zero-bubble B/W split: the custom_vjp drops the wgrad
                    # GEMMs from this tick's backward and stashes the
                    # boundary pair at the item's slot for the W drain
                    def sfn(xx, cc, pp, af):
                        a_seg, a_pos, a_cl, a_win, a_act, a_l, _ = \
                            _unpack_aux(af)
                        return _run_stage_layers(
                            model, geom, pp, shard_dims, xx, cc,
                            seg=a_seg, pos=a_pos, ctx_len=a_cl,
                            windows=a_win, active=a_act,
                            model_axis=model_axis,
                            l_ckpt=geom.l_ckpt if a_l is None else a_l)
                    x_out, ctx, stash = executor.split_backward_stage(
                        sfn, x_in, ctx_in, stage_params, stash,
                        tc.idxc, tc.valid,
                        aux=_pack_aux(seg, pos, ctx_len, l_act))
                else:
                    x_out, ctx = _run_stage_layers(
                        model, geom, stage_params, shard_dims, x_in, ctx_in,
                        seg=seg, pos=pos, ctx_len=ctx_len, windows=windows,
                        active=active, model_axis=model_axis, l_ckpt=l_act)
            else:
                # interleaved-1f1b: this tick runs ONE virtual stage — the
                # L_v-layer block (and its context-carry slice) at
                # tc.v_idx; everything else on the device stays untouched.
                start = tc.v_idx * L_v

                def _slc(t):
                    return jax.lax.dynamic_slice_in_dim(t, start, L_v, 0)

                ctx_v = jax.tree.map(
                    lambda t: _slc(t) if t is not None else None, ctx,
                    is_leaf=lambda t: t is None)
                ctx_v = executor.reset_ssm_at_boundary(ctx_v, ctx_len)
                # spread the solver's remat budget over the v virtual
                # blocks: ceil keeps total checkpointed layers >= the
                # stage's depth (memory-safe direction; over-remat bounded
                # by v - 1 layers, NOT v * l_ckpt). Stage-aware tables
                # look the (stage, chunk) depth up per tick first.
                l_act = min(-(-geom.l_ckpt // v_st), L_v) \
                    if ckpt_tab is None else \
                    executor.remat_tick_count(ckpt_tab, tc.p_idx, tc.idxc,
                                              tc.valid, v=v_st, l_max=L_v)
                if split:
                    # slot = (virtual stage, item); the stage_fn takes the
                    # FULL stage tree and slices inside (by the traced
                    # start riding aux), so the drain's weight grads land
                    # on the right block via the dynamic_slice transpose
                    def sfn(xx, cc, pp, af):
                        a_seg, a_pos, a_cl, a_win, a_act, a_l, a_st = \
                            _unpack_aux(af)

                        def _s(t):
                            return jax.lax.dynamic_slice_in_dim(
                                t, a_st, L_v, 0)
                        return _run_stage_layers(
                            model, geom, jax.tree.map(_s, pp),
                            shard_dims, xx, cc,
                            seg=a_seg, pos=a_pos, ctx_len=a_cl,
                            windows=_s(a_win), active=_s(a_act),
                            model_axis=model_axis, n_layers=L_v,
                            l_ckpt=l_act if a_l is None else a_l)
                    x_out, ctx_v, stash = executor.split_backward_stage(
                        sfn, x_in, ctx_v, stage_params, stash,
                        tc.v_idx * n + tc.idxc, tc.valid,
                        aux=_pack_aux(seg, pos, ctx_len, l_act, start))
                else:
                    x_out, ctx_v = _run_stage_layers(
                        model, geom, jax.tree.map(_slc, stage_params),
                        shard_dims, x_in, ctx_v,
                        seg=seg, pos=pos, ctx_len=ctx_len,
                        windows=_slc(windows), active=_slc(active),
                        model_axis=model_axis, n_layers=L_v,
                        l_ckpt=l_act)
                ctx = jax.tree.map(
                    lambda full, new: jax.lax.dynamic_update_slice_in_dim(
                        full, new, start, 0) if full is not None else None,
                    ctx, ctx_v, is_leaf=lambda t: t is None)

            if not geom.overlap_handoff:
                acc = fold_acc(tc, x_out, ctx, acc)
            if split:
                return x_out, ctx, acc, stash
            return x_out, ctx, acc

        def drain_tick(j, entry, sp_full, af):
            """W-grad tick ``j`` (transposed cooldown): replay stage
            weight grads for item ``j % n`` / virtual block ``j // n``
            from the stashed ``(x_in, ctx_in, ybar, ctx_bar)`` boundary
            pair. ``l_ckpt=0``: this IS a recompute, no nested remat.
            Batch lookups come through ``af`` (the float-cast drain aux) —
            custom_vjp hooks cannot close over traced values."""
            x_st, ctx_st, ybar, cbar = entry
            m = j % n
            seg = af["seg_a"].astype(jnp.int32)[m]
            pos = af["pos_a"].astype(jnp.int32)[m]
            ctx_len = af["ctxlen_a"].astype(jnp.int32)[m]
            d_win = af["windows"].astype(jnp.int32)
            d_act = af["active"] > 0.5
            if v_st == 1:
                def f(pp):
                    return _run_stage_layers(
                        model, geom, pp, shard_dims, x_st, ctx_st,
                        seg=seg, pos=pos, ctx_len=ctx_len, windows=d_win,
                        active=d_act, model_axis=model_axis, l_ckpt=0)
            else:
                start = (j // n) * L_v

                def _slcj(t):
                    return jax.lax.dynamic_slice_in_dim(t, start, L_v, 0)

                def f(pp):
                    return _run_stage_layers(
                        model, geom, jax.tree.map(_slcj, pp), shard_dims,
                        x_st, ctx_st,
                        seg=seg, pos=pos, ctx_len=ctx_len,
                        windows=_slcj(d_win), active=_slcj(d_act),
                        model_axis=model_axis, n_layers=L_v, l_ckpt=0)
            _, wv = jax.vjp(f, sp_full)
            (wbar,) = wv((ybar, cbar))
            return wbar

        if mode == "train":
            acc0: Tuple = (jnp.float32(0), jnp.float32(0))
        else:
            acc0 = (jnp.zeros((n, cap_loc), jnp.int32), jnp.float32(0))
        stash0 = None
        drain_aux = ()
        if split:
            ctx_struct = ctx0 if v_st == 1 else jax.tree.map(
                lambda t: t[:L_v], ctx0)
            stash0 = executor.make_stash(
                (x0, ctx_struct, x0, ctx_struct), n * v_st)
            drain_aux = jax.tree.map(
                lambda a: a.astype(jnp.float32),
                {"seg_a": seg_a, "pos_a": pos_a, "ctxlen_a": ctxlen_a,
                 "windows": windows, "active": active})
        program = StageProgram(n_items=n, d_p=d_p, data_axis=data_axis,
                               tick=tick, psum_acc=(mode == "train"),
                               schedule=geom.schedule, v=geom.v_stages,
                               fold=fold_acc if geom.overlap_handoff
                               else None,
                               split_bwd=split, init_stash=stash0,
                               drain_tick=drain_tick if split else None,
                               stage_params=stage_params if split else None,
                               drain_aux=drain_aux)
        xf, ctxf, acc = executor.run_stage_program(program, x0, ctx0, acc0)
        if mode == "train":
            # only the last stage accumulated loss; psum'd by the executor
            loss, n_val = acc
            return loss, n_val
        ids = jax.lax.psum(acc[0], data_axis)  # only last stage nonzero
        return ids, ctxf

    return loss_local
