"""Alg. 1 sequence-processor invariants (unit + hypothesis property)."""

from collections import defaultdict

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (ChunkKind, ClusterSpec, CostModel, ModelSpec,
                        chunk_sequences)


def _cm(d_p=4, d_s=4):
    m = ModelSpec(name="t", n_layers=8, d_model=256, n_heads=8, n_kv_heads=4,
                  head_dim=32, d_ff=1024, vocab=512)
    return CostModel(m, ClusterSpec(d_p=d_p, d_s=d_s))


def _check_coverage(lengths, result):
    """Every sequence covered exactly once by contiguous in-order slices."""
    per_seq = defaultdict(list)
    for c in result.chunks:
        for s in c.slices:
            per_seq[s.seq_id].append(s)
    assert set(per_seq) == set(range(len(lengths)))
    for sid, slices in per_seq.items():
        slices.sort(key=lambda s: s.start)
        off = 0
        for i, s in enumerate(slices):
            assert s.start == off
            off += s.length
            is_last = i == len(slices) - 1
            assert s.is_tail == is_last
        assert off == lengths[sid]


def test_k1_is_pure_batch_level(cost_model, skewed_lengths):
    res = chunk_sequences(cost_model, skewed_lengths, 1)
    assert all(c.kind is ChunkKind.BATCHED for c in res.chunks)
    _check_coverage(skewed_lengths, res)


def test_long_sequence_is_split(cost_model, skewed_lengths):
    res = chunk_sequences(cost_model, skewed_lengths, 4)
    kinds = {c.kind for c in res.chunks}
    assert ChunkKind.SPLIT in kinds
    _check_coverage(skewed_lengths, res)
    # split chunk context equals its slice's start offset
    for c in res.chunks:
        if c.kind in (ChunkKind.SPLIT, ChunkKind.HYBRID):
            assert c.context == c.slices[0].start


def test_no_two_tails_in_one_chunk(cost_model, skewed_lengths):
    """Footnote 1: packing two tail slices is forbidden."""
    for k in (2, 4, 8):
        res = chunk_sequences(cost_model, skewed_lengths, k)
        for c in res.chunks:
            tails_of_long = [s for s in c.slices
                             if s.is_tail and s.start > 0]
            assert len(tails_of_long) <= 1


def test_chunk_token_threshold(cost_model, skewed_lengths):
    for k in (1, 3, 6):
        res = chunk_sequences(cost_model, skewed_lengths, k)
        for c in res.chunks:
            assert c.tokens <= res.t_m


def test_execution_order_longest_first(cost_model, skewed_lengths):
    """§III-C1: longer sequences scheduled first; slices causally ordered."""
    res = chunk_sequences(cost_model, skewed_lengths, 4)
    seen_ctx = {}
    for c in res.chunks:
        if c.kind is ChunkKind.BATCHED:
            continue
        sid = c.seq_id
        prev = seen_ctx.get(sid, -1)
        assert c.context > prev  # slices of a sequence appear in order
        seen_ctx[sid] = c.context


def test_mesh_matches_paper_example():
    """Paper §III-B: with mesh {8K,4K,2K}, a >12K sequence becomes 8K + 4K
    slices plus a variable-length remainder."""
    cm = _cm()
    lengths = [14336, 13000, 9000, 5000, 1000]
    res = chunk_sequences(cm, lengths, 3)
    mesh = res.mesh
    assert len(mesh) == 3 and sum(mesh) == 14336
    per_seq = defaultdict(list)
    for c in res.chunks:
        for s in c.slices:
            per_seq[s.seq_id].append(s)
    s13k = sorted(per_seq[1], key=lambda s: s.start)
    assert [s.length for s in s13k[:-1]] == [mesh[0], mesh[1]]
    assert s13k[-1].length == 13000 - mesh[0] - mesh[1]
    # the 5000 sequence is shorter than mesh[0] -> not split
    assert len(per_seq[3]) == 1


@given(st.lists(st.integers(min_value=16, max_value=30000),
                min_size=1, max_size=40),
       st.integers(min_value=1, max_value=8))
@settings(max_examples=50, deadline=None)
def test_chunking_coverage_property(lengths, k):
    cm = _cm()
    res = chunk_sequences(cm, lengths, k)
    _check_coverage(lengths, res)
    assert sum(c.tokens for c in res.chunks) == sum(lengths)
    # sequence infos agree with the chunks
    for si in res.sequences:
        assert si.length == lengths[si.seq_id]
        assert si.n_chunks == len(si.chunk_ids)
        for cid in si.chunk_ids:
            assert any(s.seq_id == si.seq_id for s in res.chunks[cid].slices)
