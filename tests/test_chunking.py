"""Alg. 1 sequence-processor invariants (unit + hypothesis property)."""

from collections import defaultdict

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (ChunkKind, ClusterSpec, CostModel, ModelSpec,
                        chunk_sequences)
from repro.core.chunking import seq_workload


def _cm(d_p=4, d_s=4):
    m = ModelSpec(name="t", n_layers=8, d_model=256, n_heads=8, n_kv_heads=4,
                  head_dim=32, d_ff=1024, vocab=512)
    return CostModel(m, ClusterSpec(d_p=d_p, d_s=d_s))


def _check_coverage(lengths, result):
    """Every sequence covered exactly once by contiguous in-order slices."""
    per_seq = defaultdict(list)
    for c in result.chunks:
        for s in c.slices:
            per_seq[s.seq_id].append(s)
    assert set(per_seq) == set(range(len(lengths)))
    for sid, slices in per_seq.items():
        slices.sort(key=lambda s: s.start)
        off = 0
        for i, s in enumerate(slices):
            assert s.start == off
            off += s.length
            is_last = i == len(slices) - 1
            assert s.is_tail == is_last
        assert off == lengths[sid]


def test_k1_is_pure_batch_level(cost_model, skewed_lengths):
    res = chunk_sequences(cost_model, skewed_lengths, 1)
    assert all(c.kind is ChunkKind.BATCHED for c in res.chunks)
    _check_coverage(skewed_lengths, res)


def test_long_sequence_is_split(cost_model, skewed_lengths):
    res = chunk_sequences(cost_model, skewed_lengths, 4)
    kinds = {c.kind for c in res.chunks}
    assert ChunkKind.SPLIT in kinds
    _check_coverage(skewed_lengths, res)
    # split chunk context equals its slice's start offset
    for c in res.chunks:
        if c.kind in (ChunkKind.SPLIT, ChunkKind.HYBRID):
            assert c.context == c.slices[0].start


def test_no_two_tails_in_one_chunk(cost_model, skewed_lengths):
    """Footnote 1: packing two tail slices is forbidden."""
    for k in (2, 4, 8):
        res = chunk_sequences(cost_model, skewed_lengths, k)
        for c in res.chunks:
            tails_of_long = [s for s in c.slices
                             if s.is_tail and s.start > 0]
            assert len(tails_of_long) <= 1


def test_chunk_token_threshold(cost_model, skewed_lengths):
    for k in (1, 3, 6):
        res = chunk_sequences(cost_model, skewed_lengths, k)
        for c in res.chunks:
            assert c.tokens <= res.t_m


def test_execution_order_longest_first(cost_model, skewed_lengths):
    """§III-C1: longer sequences scheduled first; slices causally ordered."""
    res = chunk_sequences(cost_model, skewed_lengths, 4)
    seen_ctx = {}
    for c in res.chunks:
        if c.kind is ChunkKind.BATCHED:
            continue
        sid = c.seq_id
        prev = seen_ctx.get(sid, -1)
        assert c.context > prev  # slices of a sequence appear in order
        seen_ctx[sid] = c.context


def test_mesh_matches_paper_example():
    """Paper §III-B: with mesh {8K,4K,2K}, a >12K sequence becomes 8K + 4K
    slices plus a variable-length remainder."""
    cm = _cm()
    lengths = [14336, 13000, 9000, 5000, 1000]
    res = chunk_sequences(cm, lengths, 3)
    mesh = res.mesh
    assert len(mesh) == 3 and sum(mesh) == 14336
    per_seq = defaultdict(list)
    for c in res.chunks:
        for s in c.slices:
            per_seq[s.seq_id].append(s)
    s13k = sorted(per_seq[1], key=lambda s: s.start)
    assert [s.length for s in s13k[:-1]] == [mesh[0], mesh[1]]
    assert s13k[-1].length == 13000 - mesh[0] - mesh[1]
    # the 5000 sequence is shorter than mesh[0] -> not split
    assert len(per_seq[3]) == 1


# ---------------------------------------------------------------------------
# Alg. 1 line-14 loosening is per-placement (regression: a single outlier
# placement used to raise T_t PERMANENTLY, relaxing the time threshold for
# every subsequent short and degrading workload balance).
# ---------------------------------------------------------------------------

def _chunk_time(cm, chunk):
    """Summed member workloads of one chunk (s0 at its context, shorts at 0)."""
    tot = 0.0
    for i, s in enumerate(chunk.slices):
        ctx = chunk.context if (i == 0 and
                                chunk.kind is not ChunkKind.BATCHED) else 0
        tot += seq_workload(cm, s.length, ctx)
    return tot


def _pack_times(cm, res):
    """Packing-bucket times: hybrid/tail + batched chunks (mesh slices are
    fixed by line 1 and excluded)."""
    return [_chunk_time(cm, c) for c in res.chunks
            if c.kind is ChunkKind.BATCHED or c.slices[0].is_tail]


def _pack_old_loosening(cm, lengths, k):
    """FROZEN pre-fix packing loop (verbatim semantics: line 14 raises t_t
    permanently). Returns (bucket times, final t_t) — the quality baseline
    the fixed packer must never be worse than."""
    from repro.core.chunking import _Bucket, _mesh_thresholds
    from repro.core.plan import Slice
    mesh, t_t, t_m = _mesh_thresholds(cm, max(lengths), k, None)
    order = sorted(range(len(lengths)), key=lambda i: -lengths[i])
    long_tails, shorts = [], []
    for sid in order:
        ln = lengths[sid]
        if k == 1 or ln <= mesh[0]:
            shorts.append(Slice(sid, 0, ln, True))
            continue
        off = 0
        for m_len in mesh[:-1]:
            if ln - off <= m_len:
                break
            off += m_len
        long_tails.append((Slice(sid, off, ln - off, True), off))
    buckets = []
    for tail, ctx in long_tails:
        b = _Bucket(tail=tail, tail_context=ctx)
        b.tot_time = seq_workload(cm, tail.length, ctx)
        b.tot_tokens = tail.length
        buckets.append(b)
    shorts.sort(key=lambda s: -seq_workload(cm, s.length))
    n_forced = 0
    for s in shorts:
        t_s = seq_workload(cm, s.length)
        placed = False
        while not placed:
            min_tok = min((b.tot_tokens for b in buckets), default=t_m + 1)
            if min_tok + s.length > t_m:
                nb = _Bucket()
                nb.add(s, t_s)
                buckets.append(nb)
                placed = True
                break
            for b in sorted(buckets, key=lambda b: b.metric):
                if (b.tot_time + t_s <= t_t + 1e-18
                        and b.tot_tokens + s.length <= t_m):
                    b.add(s, t_s)
                    placed = True
                    break
            if not placed:
                feas = [b for b in buckets if b.tot_tokens + s.length <= t_m]
                if not feas:
                    nb = _Bucket()
                    nb.add(s, t_s)
                    buckets.append(nb)
                    placed = True
                else:
                    n_forced += 1
                    t_t = min(b.tot_time for b in feas) + t_s  # THE BUG
    return [b.tot_time for b in buckets], t_t, n_forced


def test_loosening_is_per_placement():
    """Skewed batch that forces loosened placements: T_t must come back
    unchanged (the old code returned — and kept packing against — the
    drifted threshold), and balance must be no worse than the old loop."""
    cm = _cm()
    lengths = [65536, 50000] + [1500] * 60
    k = 3
    old_times, old_t_t, n_forced = _pack_old_loosening(cm, lengths, k)
    assert n_forced > 0, "fixture must exercise the forced-placement branch"
    res = chunk_sequences(cm, lengths, k)
    t_t0 = seq_workload(cm, res.mesh[0], 0)  # Alg. 1 line-1 value
    assert old_t_t > t_t0 + 1e-15            # old code drifted ...
    assert res.t_t == pytest.approx(t_t0, rel=0, abs=0.0)  # ... fixed doesn't
    new_times = _pack_times(cm, res)
    assert max(new_times) <= max(old_times) + 1e-15
    # forced placements target the cheapest feasible bucket, so the two
    # hybrid buckets end up time-balanced
    import numpy as np
    assert float(np.std(new_times)) <= float(np.std(old_times)) + 1e-15


def test_no_threshold_drift_across_skew_sweep():
    """The returned T_t equals the line-1 mesh threshold for every skew —
    loosening never persists."""
    cm = _cm()
    for k in (2, 3, 4):
        for shorts in ([512] * 30, [4096] * 20, list(range(256, 8192, 512))):
            lengths = [70000, 40000] + shorts
            res = chunk_sequences(cm, lengths, k)
            expect = seq_workload(cm, res.mesh[0], 0) if res.mesh else 0.0
            assert res.t_t == pytest.approx(expect, rel=0, abs=0.0), (k, len(shorts))


@given(st.lists(st.integers(min_value=16, max_value=30000),
                min_size=1, max_size=40),
       st.integers(min_value=1, max_value=8))
@settings(max_examples=50, deadline=None)
def test_chunking_coverage_property(lengths, k):
    cm = _cm()
    res = chunk_sequences(cm, lengths, k)
    _check_coverage(lengths, res)
    assert sum(c.tokens for c in res.chunks) == sum(lengths)
    # sequence infos agree with the chunks
    for si in res.sequences:
        assert si.length == lengths[si.seq_id]
        assert si.n_chunks == len(si.chunk_ids)
        for cid in si.chunk_ids:
            assert any(s.seq_id == si.seq_id for s in res.chunks[cid].slices)
