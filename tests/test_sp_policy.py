"""SP axis: one policy heuristic, planner-chosen (policy, d_s_eff).

Pins the three consumers of the SP heuristic — ``core.sp.choose_sp_policy``,
the cost model's ``"auto"`` resolution, and ``runtime.sp.choose_policy`` —
to a single definition (they diverged once: the old inline copy in
``core/costs.py`` picked ulysses for MLA with divisible heads while the
runtime picked allgather_kv). Also covers legality, the planner sweep
choosing different SP points for different length mixes, and the bucket-key
identity of pinned plans.
"""

import pytest

from repro.core import (ClusterSpec, CostModel, ModelSpec, PlannerConfig,
                        SPConfig, plan_batch)
from repro.core.plan import ExecutionPlan
from repro.core.sp import (choose_sp_policy, legal_degrees, sp_candidates,
                           sp_legal)


def _spec(**kw):
    base = dict(name="z", n_layers=8, d_model=256, n_heads=8, n_kv_heads=4,
                head_dim=32, d_ff=1024, vocab=512)
    base.update(kw)
    return ModelSpec(**base)


# the zoo: every legality branch of the heuristic
ZOO = [
    _spec(),                                           # GQA, divisible heads
    _spec(name="mla", n_kv_heads=8, kv_lora_rank=64,
          qk_rope_dim=16),                             # MLA (the divergence)
    _spec(name="odd", n_heads=6, n_kv_heads=3),        # odd head counts
    _spec(name="mqa", n_kv_heads=1),                   # MQA: kv not divisible
    _spec(name="ssm", attn_free=True, n_layers=8,
          ssm_state=16, ssm_conv=4, d_inner=512),      # pure SSM
]


# ---------------------------------------------------------------------------
# satellite 1: the dedup regression — three consumers, one heuristic
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", ZOO, ids=lambda s: s.name)
@pytest.mark.parametrize("d", [1, 2, 4, 8])
def test_heuristic_consumers_never_diverge(spec, d):
    want = choose_sp_policy(spec, d)
    assert sp_legal(spec, want, d), \
        "the heuristic must always pick a legal policy"

    # cost model's "auto" resolution
    cm = CostModel(spec, ClusterSpec(d_p=2, d_s=8), sp_degree=d)
    assert cm.sp_policy == want

    # runtime heuristic (wraps the spec in an ArchConfig)
    from repro.models.config import ArchConfig
    from repro.runtime.sp import choose_policy
    assert choose_policy(ArchConfig(spec=spec), d) == want


def test_mla_divergence_case_pinned():
    """The historical bug: MLA with head counts divisible by d. The old
    costs.py inline heuristic checked divisibility before the MLA guard
    and picked ulysses; ulysses is illegal for MLA (one logical latent
    head)."""
    spec = _spec(name="mla8", n_heads=8, n_kv_heads=8, kv_lora_rank=64,
                 qk_rope_dim=16)
    for d in (2, 4, 8):
        assert choose_sp_policy(spec, d) == "allgather_kv"
        assert not sp_legal(spec, "ulysses", d)
        cm = CostModel(spec, ClusterSpec(d_p=2, d_s=8), sp_degree=d)
        assert cm.sp_policy == "allgather_kv"


# ---------------------------------------------------------------------------
# legality / candidate enumeration
# ---------------------------------------------------------------------------

def test_sp_legal_matrix():
    gqa, ssm = ZOO[0], ZOO[4]
    assert sp_legal(gqa, "none", 1)
    assert not sp_legal(gqa, "none", 2)        # attention: none only at d=1
    assert not sp_legal(gqa, "ulysses", 1)     # degree-1 must use none
    assert sp_legal(gqa, "ulysses", 4)
    assert not sp_legal(gqa, "ulysses", 8)     # n_kv_heads=4 not divisible
    assert sp_legal(gqa, "allgather_kv", 8)
    assert sp_legal(ssm, "none", 8)            # SSM scan shards any degree
    assert not sp_legal(ssm, "allgather_kv", 2)
    assert not sp_legal(gqa, "ring", 2)        # unknown policy
    assert not sp_legal(gqa, "allgather_kv", 0)


def test_legal_degrees_and_candidates():
    gqa = ZOO[0]
    assert legal_degrees(gqa, 8) == [8, 4, 2, 1]
    cands = sp_candidates(gqa, 4)
    assert cands[0] == SPConfig("ulysses", 4)  # default-first per degree
    assert SPConfig("allgather_kv", 4) in cands
    assert cands[-1] == SPConfig("none", 1)
    for c in cands:
        assert sp_legal(gqa, c.policy, c.d_s_eff)
    # ssm: only "none", every degree
    assert sp_candidates(ZOO[4], 4) == [SPConfig("none", d)
                                        for d in (4, 2, 1)]


def test_spconfig_validation_and_json():
    with pytest.raises(ValueError):
        SPConfig("ring", 2)
    with pytest.raises(ValueError):
        SPConfig("ulysses", 0)
    sp = SPConfig("allgather_kv", 4)
    assert SPConfig.from_json(sp.to_json()) == sp
    assert SPConfig.from_json(None) is None


def test_cost_model_rejects_illegal_sp():
    with pytest.raises(ValueError):
        CostModel(ZOO[0], ClusterSpec(d_p=2, d_s=8), sp_degree=3)
    with pytest.raises(ValueError):
        CostModel(ZOO[1], ClusterSpec(d_p=2, d_s=8),
                  sp_policy="ulysses", sp_degree=4)  # MLA


# ---------------------------------------------------------------------------
# the planner uses the axis (acceptance: two mixes, two SP points)
# ---------------------------------------------------------------------------

PLANNER_SPEC = ModelSpec(name="t", n_layers=8, d_model=512, n_heads=8,
                         n_kv_heads=4, head_dim=64, d_ff=2048, vocab=32000)
SHORT_MIX = [256] * 64
LONG_MIX = [131072, 65536, 32768] + [8192] * 8


def _cm():
    return CostModel(PLANNER_SPEC, ClusterSpec(d_p=4, d_s=4))


def test_planner_chooses_sp_per_mix():
    plan_s = plan_batch(_cm(), SHORT_MIX, PlannerConfig())
    plan_l = plan_batch(_cm(), LONG_MIX, PlannerConfig())
    assert plan_s.sp is not None and plan_l.sp is not None
    # short chunks are latency-bound: full sharding starves the MXU
    assert plan_s.sp == SPConfig("none", 1)
    # long-context chunks want the full axis
    assert plan_l.sp.d_s_eff == 4
    assert (plan_s.sp.policy, plan_s.sp.d_s_eff) != \
        (plan_l.sp.policy, plan_l.sp.d_s_eff)
    # the sweep is recorded for offline analysis
    assert any("@" in k for k in plan_s.meta["sp_sweep"])


def test_sp_differing_plans_never_alias_buckets():
    k_s = plan_batch(_cm(), SHORT_MIX, PlannerConfig()).bucket_key(4)
    k_l = plan_batch(_cm(), LONG_MIX, PlannerConfig()).bucket_key(4)
    assert (k_s.sp_policy, k_s.d_s_eff) != (k_l.sp_policy, k_l.d_s_eff)
    assert k_s != k_l


def test_pinned_sp_gets_own_compile_identity():
    auto = plan_batch(_cm(), SHORT_MIX, PlannerConfig())
    pinned = plan_batch(_cm(), SHORT_MIX,
                        PlannerConfig(sp_policy="allgather_kv", sp_degree=4))
    assert pinned.sp == SPConfig("allgather_kv", 4)
    assert pinned.bucket_key(4) != auto.bucket_key(4)
    assert pinned.bucket_key(4).sp_policy == "allgather_kv"


def test_planner_pin_validation():
    with pytest.raises(ValueError):
        plan_batch(_cm(), SHORT_MIX, PlannerConfig(sp_degree=3))
    with pytest.raises(ValueError):
        # ulysses at degree 1 is never legal
        plan_batch(_cm(), SHORT_MIX,
                   PlannerConfig(sp_policy="ulysses", sp_degree=1))


def test_plan_json_roundtrip_carries_sp():
    plan = plan_batch(_cm(), LONG_MIX, PlannerConfig())
    back = ExecutionPlan.loads(plan.dumps())
    assert back.sp == plan.sp
    assert back.bucket_key(4) == plan.bucket_key(4)


def test_legacy_spless_plan_bucket_key():
    """Plans without an SP axis (deserialized from old artifacts) key as
    ("auto", d_s) — the legacy identity — so old caches stay valid."""
    plan = plan_batch(_cm(), SHORT_MIX, PlannerConfig())
    import dataclasses
    legacy = dataclasses.replace(plan, sp=None)
    key = legacy.bucket_key(4)
    assert key.sp_policy == "auto"
    assert key.d_s_eff == 4
