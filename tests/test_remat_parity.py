"""Remat/schedule parity harness: recomputation must never change the math.

The stage-aware adaptive-checkpointing refactor threads per-(stage, chunk)
``l_ckpt`` vectors from the ILP all the way into the compiled step
(solver -> ``ExecutionPlan.ckpt_table`` -> ``bucket_key().ckpt`` ->
``executor.remat_tick_count`` -> ``run_stage_layers``). This suite pins the
semantic contract for every pipeline backend (decoder train, enc-dec
train, serve/prefill) under every schedule backend (``gpipe-1f1b``,
``interleaved-1f1b`` at the highest supported v, ``zero-bubble-h1``):

* **losses / prefill ids are bitwise identical** across remat policies
  ``l_ckpt = 0``, the uniform max, and a non-uniform per-(stage, chunk)
  vector — remat choices may only move memory, never a single output bit;
* **gradients agree to the repo's grad-parity standard** (allclose at
  rtol=1e-6 / atol=1e-7 — the same bound the executor-core refactor tests
  use). They are NOT asserted bitwise across *different* remat depths:
  ``jax.checkpoint`` itself reorders backward fusion, so even the two
  pre-existing static splits (l=0 vs l=2) differ in final-ULP noise;
* at **equal depth** the static split path (uniform int) and the traced
  per-tick path (constant table) ARE bitwise identical — loss AND grads —
  which locks the new dynamic ``lax.cond`` remat machinery against drift.

Runs in subprocesses with XLA_FLAGS=--xla_force_host_platform_device_count=8
so the main pytest session keeps seeing one CPU device (see conftest.py).
"""

import os
import subprocess
import sys
import textwrap

_COMMON = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    jax.config.update("jax_default_matmul_precision", "highest")

    from repro.configs import get_arch
    from repro.models import DecoderLM, EncDecLM
    from repro.runtime import TrainStepBuilder, make_geometry
    from repro.runtime.pipeline import pipeline_loss_fn
    from repro.runtime.sharding import (batch_specs, shard_dim_tree,
                                        shard_map_compat, stage_param_specs)
    from repro.runtime.train_step import prepare_params

    SCHEDULES = [("gpipe-1f1b", 1), ("interleaved-1f1b", 2),
                 ("zero-bubble-h1", 1)]

    def decoder_case(l_ckpt=0, ckpt_table=None, schedule="gpipe-1f1b",
                     v_stages=1, mode="train"):
        cfg = get_arch("llama3.2-3b").reduced(n_layers=4, d_model=64,
                                              n_heads=4, head_dim=16,
                                              vocab=256)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        n, cap = 4, 32
        rng = np.random.default_rng(0)
        batch = {
            "tokens": rng.integers(0, 256, (n, cap)).astype(np.int32),
            "targets": rng.integers(0, 256, (n, cap)).astype(np.int32),
            "seg": np.repeat(np.arange(n, dtype=np.int32)[:, None], cap, 1),
            "pos": np.tile(np.arange(cap, dtype=np.int32), (n, 1)),
            "ctx_len": np.zeros((n,), np.int32),
        }
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        geom = make_geometry(cfg, mesh, n_chunks=n, cap=cap, ctx_cap=2 * cap,
                             l_ckpt=l_ckpt, compute_dtype=jnp.float32,
                             schedule=schedule, v_stages=v_stages,
                             ckpt_table=ckpt_table)
        builder = TrainStepBuilder(cfg, mesh, geom, param_dtype=jnp.float32)
        raw = DecoderLM(cfg).init(jax.random.PRNGKey(7), jnp.float32)
        params = prepare_params(cfg, raw, mesh, jnp.float32,
                                v_stages=v_stages)
        pspecs, _, bspecs = builder.specs(jax.eval_shape(lambda: params))
        sd = shard_dim_tree(params["stages"], 4)
        loss = pipeline_loss_fn(cfg, geom, sd, pod_axis=None, mode=mode)
        if mode == "prefill":
            def ids_only(p, b):
                ids, _ctx = loss(p, b)
                return ids
            fn = jax.jit(shard_map_compat(
                ids_only, mesh=mesh, in_specs=(pspecs, bspecs),
                out_specs=P(None, "model"), check_vma=False))
        else:
            fn = jax.jit(shard_map_compat(
                loss, mesh=mesh, in_specs=(pspecs, bspecs),
                out_specs=(P(), P()), check_vma=False))
        return fn, params, batch

    def encdec_case(l_ckpt=0, ckpt_table=None, schedule="gpipe-1f1b"):
        from repro.runtime.encdec_pipeline import (
            encdec_batch_struct, encdec_pipeline_loss_fn,
            make_encdec_geometry, prepare_encdec_params)
        cfg = get_arch("seamless-m4t-v2").reduced(n_layers=2, d_model=64,
                                                  n_heads=4, head_dim=16,
                                                  vocab=256)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        n, cap = 3, 32
        geom = make_encdec_geometry(cfg, mesh, n_chunks=n, cap=cap,
                                    cap_enc=cap, ctx_cap=2 * cap,
                                    l_ckpt=l_ckpt, ckpt_table=ckpt_table,
                                    compute_dtype=jnp.float32,
                                    schedule=schedule)
        raw = EncDecLM(cfg).init(jax.random.PRNGKey(5), jnp.float32)
        params = prepare_encdec_params(cfg, raw, geom, jnp.float32)
        pspecs = {
            "stages": stage_param_specs(
                jax.eval_shape(lambda: params)["stages"], 4, pod=None),
            "embed": P("model", None),
            "enc_norm": P("model"),
            "final_norm": P("model"),
        }
        sd = shard_dim_tree(params["stages"], 4)
        bstruct = encdec_batch_struct(geom, cfg, 1)
        bspecs = batch_specs(bstruct, pod=None, model="model")
        rng = np.random.default_rng(2)
        batch = {}
        for k, v in bstruct.items():
            if v.dtype == jnp.int32:
                if k.startswith("seg") or k == "ctx_len":
                    arr = np.zeros(v.shape, np.int32)
                elif k.startswith("pos"):
                    arr = np.tile(np.arange(v.shape[-1], dtype=np.int32),
                                  (*v.shape[:-1], 1))
                else:
                    arr = rng.integers(0, 256, v.shape).astype(np.int32)
            else:
                arr = rng.normal(0, 0.5, v.shape).astype(np.float32)
            batch[k] = jnp.asarray(arr)
        fn = jax.jit(shard_map_compat(
            encdec_pipeline_loss_fn(cfg, geom, sd, pod_axis=None),
            mesh=mesh, in_specs=(pspecs, bspecs), out_specs=(P(), P()),
            check_vma=False))
        return fn, params, batch

    def loss_and_grads(fn, params, batch):
        def scalar(p):
            l, n = fn(p, batch)
            return l / n
        l, nv = fn(params, batch)
        g = jax.grad(scalar)(params)
        return (np.asarray(l), float(nv),
                [np.asarray(x) for x in jax.tree.leaves(g)])

    def check_parity(results, tag):
        # results: {policy: (loss, n_valid, grad_leaves)}
        (l0, n0, g0) = next(iter(results.values()))
        for name, (l, n, g) in results.items():
            assert n == n0, (tag, name, n, n0)
            assert l.tobytes() == l0.tobytes(), \\
                (tag, name, float(l), float(l0))
            for a, b in zip(g, g0):
                np.testing.assert_allclose(
                    a, b, rtol=1e-6, atol=1e-7,
                    err_msg=f"{tag}/{name}: grads drifted across remat")
""")


def _run(case: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _COMMON + textwrap.dedent(case)],
                       capture_output=True, text=True, env=env, timeout=900)
    if r.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-4000:]}")
    assert "OK" in r.stdout


# ---------------------------------------------------------------------------
# Decoder backend: all three schedule backends x {0, uniform, vector}.
# ---------------------------------------------------------------------------

def test_decoder_remat_parity_all_schedules():
    _run("""
        # non-uniform per-(stage, chunk) table: stages AND chunks differ
        TAB = ((2, 0, 1, 2), (1, 2, 0, 0))
        for schedule, v in SCHEDULES:
            results = {}
            for policy, kw in [
                ("l0", dict(l_ckpt=0)),
                ("uniform", dict(l_ckpt=2)),
                ("vector", dict(l_ckpt=2, ckpt_table=TAB)),
            ]:
                fn, params, batch = decoder_case(
                    schedule=schedule, v_stages=v, **kw)
                results[policy] = loss_and_grads(fn, params, batch)
            check_parity(results, f"decoder/{schedule}-v{v}")
            print("parity", schedule, v,
                  float(results["vector"][0]))
        print("OK decoder remat parity")
    """)


# ---------------------------------------------------------------------------
# Enc-dec backend: encoder rows of the vector differ from decoder rows.
# ---------------------------------------------------------------------------

def test_encdec_remat_parity_all_schedules():
    _run("""
        # stage 0 is the encoder stage, stage 1 the decoder stage — give
        # them DIFFERENT depths per chunk (the ROADMAP's enc/dec split)
        TAB = ((1, 0, 2), (0, 2, 1))
        # grouped enc+dec stacking has no interleaved placement, so the
        # interleaved backend runs at v=1 (tick map == the 1F1B diagonal)
        for schedule in ("gpipe-1f1b", "interleaved-1f1b", "zero-bubble-h1"):
            results = {}
            for policy, kw in [
                ("l0", dict(l_ckpt=0)),
                ("uniform", dict(l_ckpt=2)),
                ("vector", dict(l_ckpt=2, ckpt_table=TAB)),
            ]:
                fn, params, batch = encdec_case(schedule=schedule, **kw)
                results[policy] = loss_and_grads(fn, params, batch)
            check_parity(results, f"encdec/{schedule}")
            print("parity encdec", schedule, float(results["vector"][0]))
        print("OK encdec remat parity")
    """)


def test_encdec_rejects_virtual_stages():
    """EncDecGeometry pins v_stages=1: the grouped enc+dec layer stacking
    has no interleaved placement, so requesting v>1 must be a loud error,
    never a silently wrong layout."""
    import pytest

    from repro.runtime.encdec_pipeline import EncDecGeometry
    with pytest.raises(ValueError, match="v_stages=1"):
        EncDecGeometry(n_chunks=2, cap=32, cap_enc=32, ctx_cap=64, d_p=2,
                       d_s=4, l_ckpt=0, enc_stages=1, layers_per_stage=2,
                       v_stages=2)


# ---------------------------------------------------------------------------
# Serve backend (prefill): forward-only — greedy ids bitwise across remat.
# ---------------------------------------------------------------------------

def test_serve_prefill_remat_parity_all_schedules():
    _run("""
        TAB = ((2, 0, 1, 2), (1, 2, 0, 0))
        for schedule, v in SCHEDULES:
            ids = {}
            for policy, kw in [
                ("l0", dict(l_ckpt=0)),
                ("uniform", dict(l_ckpt=2)),
                ("vector", dict(l_ckpt=2, ckpt_table=TAB)),
            ]:
                fn, params, batch = decoder_case(
                    schedule=schedule, v_stages=v, mode="prefill", **kw)
                ids[policy] = np.asarray(fn(params, batch))
            base = ids["l0"]
            for name, got in ids.items():
                np.testing.assert_array_equal(
                    got, base, err_msg=f"prefill/{schedule}/{name}")
            print("parity prefill", schedule, v)
        print("OK prefill remat parity")
    """)


# ---------------------------------------------------------------------------
# Static split == traced per-tick lookup at equal depth, BITWISE (loss AND
# grads): locks the dynamic lax.cond remat path against numerical drift.
# ---------------------------------------------------------------------------

def test_static_and_dynamic_paths_bitwise_at_equal_depth():
    _run("""
        CONST = ((2, 2, 2, 2), (2, 2, 2, 2))
        fs, ps, bs = decoder_case(l_ckpt=2)
        fd, pd, bd = decoder_case(l_ckpt=2, ckpt_table=CONST)
        ls, ns, gs = loss_and_grads(fs, ps, bs)
        ld, nd, gd = loss_and_grads(fd, pd, bd)
        assert ns == nd
        assert ls.tobytes() == ld.tobytes(), (float(ls), float(ld))
        for a, b in zip(gs, gd):
            assert a.tobytes() == b.tobytes(), \\
                "dynamic remat path drifted from the static split"
        print("OK static==dynamic bitwise", float(ld))
    """)
