"""Unit + property tests for the Eq. 1-11 cost model."""

import math

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (Chunk, ChunkKind, ClusterSpec, CostModel, ModelSpec,
                        Slice, analytic_coefficients, fit_coefficients)


def _batched(*lengths, seq0=0):
    return Chunk(kind=ChunkKind.BATCHED, context=0,
                 slices=tuple(Slice(seq_id=seq0 + i, start=0, length=l,
                                    is_tail=True)
                              for i, l in enumerate(lengths)))


def _split(length, context, tail=False, seq=0):
    return Chunk(kind=ChunkKind.SPLIT, context=context,
                 slices=(Slice(seq_id=seq, start=context, length=length,
                               is_tail=tail),))


def test_coefficients_positive(cost_model):
    co = cost_model.coeffs
    assert co.alpha1 > 0 and co.alpha2 > 0 and co.m_token > 0
    assert co.m_logits == 16.0  # streaming fused CE: only per-token stats


def test_ce_modes_order(tiny_model, small_cluster):
    stream = CostModel(tiny_model, small_cluster, ce_mode="streaming")
    inplace = CostModel(tiny_model, small_cluster, ce_mode="inplace")
    naive = CostModel(tiny_model, small_cluster, ce_mode="naive")
    assert (stream.coeffs.m_logits < inplace.coeffs.m_logits
            < naive.coeffs.m_logits)


def test_tcomp_monotone_in_tokens(cost_model):
    prev = 0.0
    for ln in (128, 512, 2048, 8192):
        t = cost_model.t_comp(_batched(ln))
        assert t > prev
        prev = t


def test_split_chunk_cost_grows_with_context(cost_model):
    # same slice length, larger context => more attention work (causal)
    t0 = cost_model.t_comp(_split(1024, 0))
    t1 = cost_model.t_comp(_split(1024, 8192))
    t2 = cost_model.t_comp(_split(1024, 32768))
    assert t0 < t1 < t2


def test_quadratic_context_identity(cost_model):
    """Eq. 1: cost(C, s) - cost(0, s) must equal alpha1 * C * s / N exactly."""
    co, cl = cost_model.coeffs, cost_model.cluster
    s, C = 2048, 16384
    a = cost_model.t_comp(_split(s, C))
    b = cost_model.t_comp(_split(s, 0))
    u = cost_model.utilization(_split(s, C))
    expect = co.alpha1 * 0.5 * ((C + s) ** 2 - C ** 2 - s ** 2) / cl.n_devices / u
    assert math.isclose(a - b, expect, rel_tol=1e-9)


def test_utilization_saturates(cost_model):
    u_small = cost_model.utilization(_batched(32))
    u_big = cost_model.utilization(_batched(65536))
    assert 0 < u_small < u_big <= 1.0


def test_backward_is_2x_forward(cost_model):
    c = _batched(4096)
    assert math.isclose(cost_model.t_comp_bwd(c),
                        2.0 * cost_model.t_comp(c), rel_tol=1e-12)


def test_sp_policies_differ_and_positive(tiny_model, small_cluster):
    ul = CostModel(tiny_model, small_cluster, sp_policy="ulysses")
    ag = CostModel(tiny_model, small_cluster, sp_policy="allgather_kv")
    c = _batched(4096)
    assert ul.t_sp_comm(c) > 0 and ag.t_sp_comm(c) > 0
    # GQA (4 kv heads vs 8 q heads): gathering KV moves less than 4 a2a's
    assert ag.t_sp_comm(c) < ul.t_sp_comm(c)
    assert ul.kv_replication == 1 and ag.kv_replication == small_cluster.d_s


def test_auto_policy_head_divisibility(small_cluster):
    divisible = ModelSpec(name="m", n_layers=4, d_model=256, n_heads=8,
                          n_kv_heads=4, head_dim=32, d_ff=512, vocab=128)
    odd = ModelSpec(name="m", n_layers=4, d_model=256, n_heads=7,
                    n_kv_heads=7, head_dim=32, d_ff=512, vocab=128)
    assert CostModel(divisible, small_cluster).sp_policy == "ulysses"
    assert CostModel(odd, small_cluster).sp_policy == "allgather_kv"


def test_mdkv_only_for_dependent_chunks(cost_model):
    dep = _split(1024, 4096, tail=False)
    tail = _split(1024, 4096, tail=True)
    batched = _batched(1024)
    assert cost_model.m_dkv(dep) > 0
    assert cost_model.m_dkv(tail) == 0
    assert cost_model.m_dkv(batched) == 0


def test_mact_decreases_with_ckpt(cost_model):
    c = _batched(8192)
    vals = [cost_model.m_act(1, c, l) for l in range(3)]
    assert vals[0] > vals[1] > vals[2] > 0


def test_last_stage_carries_logits(cost_model):
    c = _batched(8192)
    assert (cost_model.m_act(cost_model.cluster.d_p, c)
            > cost_model.m_act(1, c))


def test_model_states_fit_reasonably(cost_model):
    for p in range(1, cost_model.cluster.d_p + 1):
        ms = cost_model.m_model_states(p)
        assert 0 < ms < cost_model.cluster.hbm_bytes


def test_token_capacity_positive(cost_model):
    assert cost_model.token_capacity() > 4096


def test_split_balanced_properties(cost_model):
    for k in (1, 2, 3, 5, 8):
        mesh = cost_model.split_balanced(65536, k)
        assert sum(mesh) == 65536
        assert len(mesh) <= k
        # earlier slices longer (they lack context): non-increasing
        assert all(a >= b for a, b in zip(mesh, mesh[1:]))
        # workload balance: bwd cost of each slice within 25% of the mean
        if k > 1:
            costs = []
            off = 0
            for s in mesh:
                costs.append(cost_model.t_comp(_split(s, off)))
                off += s
            mean = sum(costs) / len(costs)
            assert max(costs) / mean < 1.35 and min(costs) / mean > 0.6


@given(st.integers(min_value=64, max_value=200000),
       st.integers(min_value=1, max_value=12))
@settings(max_examples=60, deadline=None)
def test_split_balanced_conserves_tokens(length, k):
    m = ModelSpec(name="t", n_layers=4, d_model=128, n_heads=4, n_kv_heads=4,
                  head_dim=32, d_ff=512, vocab=256)
    cm = CostModel(m, ClusterSpec(d_p=2, d_s=2))
    mesh = cm.split_balanced(length, k)
    assert sum(mesh) == length
    assert all(s > 0 for s in mesh)


def test_fit_coefficients_recovers_ground_truth(cost_model):
    """Generate synthetic timings from known coefficients, refit, compare."""
    co, cl = cost_model.coeffs, cost_model.cluster
    rng = np.random.default_rng(0)
    samples = []
    for _ in range(64):
        ln = int(rng.integers(128, 16384))
        ctx = int(rng.integers(0, 2)) * int(rng.integers(0, 16384))
        ch = _split(ln, ctx)
        C, s0 = float(ch.context), float(ch.s0)
        quad = 0.5 * ((C + s0) ** 2 - C ** 2)
        t = (co.alpha1 * quad + co.alpha2 * s0) / cl.n_devices + co.beta1 / cl.d_p
        samples.append((ch, t))
    fit = fit_coefficients(co, cl, samples)
    assert math.isclose(fit.alpha1, co.alpha1, rel_tol=1e-6)
    assert math.isclose(fit.alpha2, co.alpha2, rel_tol=1e-6)


def test_straggler_slowdown_inflates_stage(cost_model):
    slow = cost_model.with_slowdowns([1.0, 2.0, 1.0, 1.0])
    c = _batched(4096)
    assert math.isclose(slow.t_comp(c, stage=2), 2 * slow.t_comp(c, stage=1),
                        rel_tol=1e-9)


def test_param_count_families():
    dense = ModelSpec(name="d", n_layers=28, d_model=3072, n_heads=24,
                      n_kv_heads=8, head_dim=128, d_ff=8192, vocab=128256)
    # llama3.2-3b is ~3.2B params
    assert 2.5e9 < dense.param_count() < 4.0e9
    moe = ModelSpec(name="m", n_layers=16, d_model=2048, n_heads=16,
                    n_kv_heads=16, head_dim=128, d_ff=1024, vocab=50304,
                    n_experts=64, top_k=8, d_ff_expert=1024)
    # olmoe: ~6.9B total, ~1.3B active
    assert 5.5e9 < moe.param_count() < 8.5e9
    assert 0.9e9 < moe.active_param_count() < 2.2e9
