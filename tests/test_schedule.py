"""1F1B schedule, chunks-window, and simulator tests."""

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (Chunk, ChunkKind, ClusterSpec, CostModel, ModelSpec,
                        PipelineSimulator, Slice, TickOp, backward_order,
                        build_schedule, chunk_sequences, enumerate_windows,
                        window_limit)


def _mk_chunks(seq_layout):
    """seq_layout: list of n_slices per long sequence (1 => batched)."""
    chunks = []
    for sid, n in enumerate(seq_layout):
        if n == 1:
            chunks.append(Chunk(kind=ChunkKind.BATCHED, context=0,
                                slices=(Slice(sid, 0, 1024, True),)))
        else:
            off = 0
            for i in range(n):
                chunks.append(Chunk(
                    kind=ChunkKind.SPLIT, context=off,
                    slices=(Slice(sid, off, 1024, i == n - 1),)))
                off += 1024
    return chunks


def test_backward_order_reverses_within_sequence():
    chunks = _mk_chunks([3, 1, 2])
    f2b = backward_order(chunks)
    # fwd: A1 A2 A3 | B | C1 C2  ->  bwd: A3 A2 A1 | B | C2 C1
    assert f2b == [2, 1, 0, 3, 5, 4]


def test_schedule_complete_and_in_order():
    n, d_p, ns = 7, 4, 3
    chunks = _mk_chunks([3, 1, 1, 1, 1])
    f2b = backward_order(chunks)
    sched = build_schedule(n, d_p, ns, f2b)
    assert len(sched) == d_p
    for row in sched:
        fs = [t.chunk for t in row if t.op is TickOp.FWD]
        bs = [t.chunk for t in row if t.op is TickOp.BWD]
        assert fs == list(range(n))                     # fwd in order
        assert [f2b[k] for k in bs] == sorted(f2b[k] for k in bs)  # bwd order
        # every bwd after its own fwd at this stage
        seen_f = set()
        for t in row:
            if t.op is TickOp.FWD:
                seen_f.add(t.chunk)
            else:
                assert t.chunk in seen_f


@given(st.lists(st.integers(min_value=1, max_value=4), min_size=1,
                max_size=8),
       st.integers(min_value=2, max_value=8))
@settings(max_examples=50, deadline=None)
def test_window_limit_eq7(seq_layout, d_p):
    """Eq. 7: resident chunks at stage p never exceed d_p - p + N_split."""
    chunks = _mk_chunks(seq_layout)
    n = len(chunks)
    ns = max(seq_layout)
    f2b = backward_order(chunks)
    windows = enumerate_windows(n, d_p, ns, f2b)
    for p in range(1, d_p + 1):
        cap = min(window_limit(d_p, p, ns), n)
        assert max((len(w) for w in windows[p - 1]), default=0) <= cap
        # deepest stage (p=1) actually reaches the cap when enough chunks
        if p == 1 and n >= cap:
            assert max(len(w) for w in windows[0]) == cap


def _sim(seq_layout, d_p=4, ckpt=None):
    m = ModelSpec(name="t", n_layers=8, d_model=256, n_heads=8, n_kv_heads=4,
                  head_dim=32, d_ff=1024, vocab=512)
    cm = CostModel(m, ClusterSpec(d_p=d_p, d_s=4))
    chunks = _mk_chunks(seq_layout)
    f2b = backward_order(chunks)
    sim = PipelineSimulator(cm, chunks, f2b, max(seq_layout), ckpt)
    return cm, chunks, f2b, sim.run()


def test_simulator_dependencies_respected():
    d_p = 4
    cm, chunks, f2b, res = _sim([3, 1, 1, 1, 1], d_p)
    ot = res.op_times
    n = len(chunks)
    for k in range(n):
        for p in range(2, d_p + 1):
            assert ot[(p, "F", k)][0] >= ot[(p - 1, "F", k)][1] - 1e-12
        for p in range(1, d_p):
            assert ot[(p, "B", k)][0] >= ot[(p + 1, "B", k)][1] - 1e-12
        # bwd after own fwd on the same stage
        for p in range(1, d_p + 1):
            assert ot[(p, "B", k)][0] >= ot[(p, "F", k)][1] - 1e-12
    # token-level PP: slice i's bwd after slice i+1's bwd (same stage)
    for p in range(1, d_p + 1):
        assert ot[(p, "B", 0)][0] >= ot[(p, "B", 1)][1] - 1e-12
        assert ot[(p, "B", 1)][0] >= ot[(p, "B", 2)][1] - 1e-12


def test_simulator_bubble_sane():
    _, _, _, res = _sim([1] * 32, d_p=4)
    assert 0.0 <= res.bubble_ratio < 0.5  # many chunks => low bubble
    _, _, _, few = _sim([1, 1], d_p=4)
    assert few.bubble_ratio > res.bubble_ratio  # few chunks => more bubble


def test_simulator_makespan_lower_bound():
    cm, chunks, f2b, res = _sim([2, 1, 1, 1])
    # makespan >= total per-stage work of any single stage
    per_stage = sum(cm.t_tot(c, per_stage=True)
                    + cm.t_tot(c, bwd=True, per_stage=True) for c in chunks)
    assert res.makespan >= per_stage - 1e-9


def test_recompute_increases_makespan():
    layout = [2, 1, 1, 1]
    n = sum(layout) if False else len(_mk_chunks(layout))
    _, _, _, base = _sim(layout)
    full = [[2] * n for _ in range(4)]
    _, _, _, ck = _sim(layout, ckpt=full)
    assert ck.makespan > base.makespan
    assert ck.breakdown["recompute"] > 0


def test_straggler_slows_pipeline():
    m = ModelSpec(name="t", n_layers=8, d_model=256, n_heads=8, n_kv_heads=4,
                  head_dim=32, d_ff=1024, vocab=512)
    cm = CostModel(m, ClusterSpec(d_p=4, d_s=4))
    chunks = _mk_chunks([1] * 12)
    f2b = backward_order(chunks)
    base = PipelineSimulator(cm, chunks, f2b, 1).run()
    slow_cm = cm.with_slowdowns([1.0, 1.0, 1.6, 1.0])
    slow = PipelineSimulator(slow_cm, chunks, f2b, 1).run()
    assert slow.makespan > base.makespan * 1.2


def test_peak_memory_monotone_in_ckpt():
    layout = [2, 1, 1, 1]
    chunks = _mk_chunks(layout)
    n = len(chunks)
    _, _, _, no = _sim(layout)
    _, _, _, full = _sim(layout, ckpt=[[2] * n for _ in range(4)])
    assert max(full.per_stage_peak_mem) < max(no.per_stage_peak_mem)
