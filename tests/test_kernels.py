"""Per-kernel allclose tests: Pallas (interpret=True) vs pure-jnp oracles,
sweeping shapes and dtypes per the assignment."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ref
from repro.kernels.cross_entropy import (cross_entropy_bwd_dh_pallas,
                                         cross_entropy_bwd_dw_pallas,
                                         cross_entropy_fwd_pallas)
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.mamba_scan import mamba_scan_pallas
from repro.kernels.ops import fused_cross_entropy, mamba_scan

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _varlen_meta(key, T, n_seq, ctx=0):
    """Random packed layout: n_seq segments + optional context prefix rows."""
    cuts = np.sort(np.asarray(
        jax.random.choice(key, np.arange(1, T), (n_seq - 1,), replace=False)))
    bounds = np.concatenate([[0], cuts, [T]])
    seg = np.full((T,), -1, np.int32)
    pos = np.zeros((T,), np.int32)
    for s in range(n_seq):
        a, b = bounds[s], bounds[s + 1]
        seg[a:b] = s
        pos[a:b] = np.arange(b - a) + (ctx if s == 0 else 0)
    return jnp.asarray(seg), jnp.asarray(pos)


# ---------------------------------------------------------------------------
# Flash attention.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("T,Hq,Hkv,Dh,nseq", [
    (64, 4, 4, 32, 1),      # MHA single sequence
    (128, 8, 2, 64, 3),     # GQA packed
    (96, 4, 1, 128, 2),     # MQA (gemma3-style kv=1)
    (256, 2, 2, 16, 4),     # many segments, small heads
])
def test_flash_attention_matches_oracle(dtype, T, Hq, Hkv, Dh, nseq):
    key = jax.random.PRNGKey(T + Hq)
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (T, Hq, Dh), dtype)
    k = jax.random.normal(ks[1], (T, Hkv, Dh), dtype)
    v = jax.random.normal(ks[2], (T, Hkv, Dh), dtype)
    seg, pos = _varlen_meta(ks[3], T, nseq)
    out_p = flash_attention_pallas(q, k, v, seg, seg, pos, pos,
                                   block_q=32, block_kv=32, interpret=True)
    out_r = ref.flash_attention_reference(q, k, v, seg, seg, pos, pos)
    np.testing.assert_allclose(np.asarray(out_p, np.float32),
                               np.asarray(out_r, np.float32), **TOL[dtype])


def test_flash_attention_context_kv():
    """Split-chunk case: KV includes a context prefix of an earlier slice."""
    key = jax.random.PRNGKey(7)
    T, C, H, Dh = 64, 32, 4, 32
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (T, H, Dh))
    k = jax.random.normal(ks[1], (C + T, H, Dh))
    v = jax.random.normal(ks[2], (C + T, H, Dh))
    seg_q, pos_q = _varlen_meta(key, T, 2, ctx=C)
    seg_kv = jnp.concatenate([jnp.zeros(C, jnp.int32), seg_q])
    pos_kv = jnp.concatenate([jnp.arange(C), pos_q])
    out_p = flash_attention_pallas(q, k, v, seg_q, seg_kv, pos_q, pos_kv,
                                   block_q=32, block_kv=32)
    out_r = ref.flash_attention_reference(q, k, v, seg_q, seg_kv,
                                          pos_q, pos_kv)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [1, 8, 31])
def test_flash_attention_sliding_window(window):
    key = jax.random.PRNGKey(11)
    T, H, Dh = 128, 2, 32
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (T, H, Dh))
    k = jax.random.normal(ks[1], (T, H, Dh))
    v = jax.random.normal(ks[2], (T, H, Dh))
    seg, pos = _varlen_meta(ks[2], T, 2)
    out_p = flash_attention_pallas(q, k, v, seg, seg, pos, pos,
                                   window=window, block_q=32, block_kv=32)
    out_r = ref.flash_attention_reference(q, k, v, seg, seg, pos, pos,
                                          window=window)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r),
                               rtol=2e-5, atol=2e-5)


def test_blocked_ref_matches_naive_ref():
    """The dry-run's blocked-jnp path is pinned to the same oracle."""
    key = jax.random.PRNGKey(3)
    T, H, Dh = 160, 4, 32
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (T, H, Dh))
    k = jax.random.normal(ks[1], (T, H, Dh))
    v = jax.random.normal(ks[2], (T, H, Dh))
    seg, pos = _varlen_meta(ks[1], T, 3)
    a = ref.blocked_flash_attention(q, k, v, seg, seg, pos, pos, block_kv=64)
    b = ref.flash_attention_reference(q, k, v, seg, seg, pos, pos)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Fused cross entropy.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("T,D,V", [(32, 16, 100), (64, 32, 1000),
                                   (128, 64, 517)])
def test_ce_forward_matches_oracle(dtype, T, D, V):
    key = jax.random.PRNGKey(T + V)
    ks = jax.random.split(key, 3)
    h = jax.random.normal(ks[0], (T, D), dtype) * 0.5
    w = jax.random.normal(ks[1], (V, D), dtype) * 0.5
    tgt = jax.random.randint(ks[2], (T,), 0, V)
    valid = jnp.arange(T) % 5 != 0
    lse, tl = cross_entropy_fwd_pallas(h, w, tgt, valid, block_t=16,
                                       block_v=64, interpret=True)
    loss_p = ((lse - tl) * valid).sum()
    loss_r, n_r = ref.cross_entropy_reference(h, w, tgt, valid)
    tol = TOL[dtype].copy()
    np.testing.assert_allclose(float(loss_p), float(loss_r),
                               rtol=max(tol["rtol"], 1e-4))


def test_ce_backward_matches_autodiff():
    key = jax.random.PRNGKey(5)
    T, D, V = 48, 24, 301
    ks = jax.random.split(key, 3)
    h = jax.random.normal(ks[0], (T, D)) * 0.3
    w = jax.random.normal(ks[1], (V, D)) * 0.3
    tgt = jax.random.randint(ks[2], (T,), 0, V)
    valid = jnp.arange(T) % 3 != 1

    def loss_ref(h, w):
        s, n = ref.cross_entropy_reference(h, w, tgt, valid)
        return s
    dh_r, dw_r = jax.grad(loss_ref, argnums=(0, 1))(h, w)

    lse, _ = cross_entropy_fwd_pallas(h, w, tgt, valid, block_t=16,
                                      block_v=64, interpret=True)
    g_rows = valid.astype(jnp.float32)
    dh_p = cross_entropy_bwd_dh_pallas(h, w, tgt, lse, g_rows, block_t=16,
                                       block_v=64, interpret=True)
    dw_p = cross_entropy_bwd_dw_pallas(h, w, tgt, lse, g_rows, block_t=16,
                                       block_v=64, interpret=True)
    np.testing.assert_allclose(np.asarray(dh_p), np.asarray(dh_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(dw_p), np.asarray(dw_r),
                               rtol=1e-4, atol=1e-4)


def test_fused_ce_custom_vjp_end_to_end():
    """ops.fused_cross_entropy(use_pallas=True) gradient == naive autodiff."""
    key = jax.random.PRNGKey(9)
    T, D, V = 40, 16, 130
    ks = jax.random.split(key, 3)
    h = jax.random.normal(ks[0], (T, D)) * 0.3
    w = jax.random.normal(ks[1], (V, D)) * 0.3
    tgt = jax.random.randint(ks[2], (T,), 0, V)
    valid = jnp.ones((T,), bool)

    def mean_p(h, w):
        s, n = fused_cross_entropy(h, w, tgt, valid, block_t=16, block_v=64,
                                   use_pallas=True)
        return s / n

    def mean_r(h, w):
        s, n = ref.cross_entropy_reference(h, w, tgt, valid)
        return s / n

    lp, gp = jax.value_and_grad(mean_p, argnums=(0, 1))(h, w)
    lr, gr = jax.value_and_grad(mean_r, argnums=(0, 1))(h, w)
    np.testing.assert_allclose(float(lp), float(lr), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(gp[0]), np.asarray(gr[0]),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gp[1]), np.asarray(gr[1]),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Mamba scan.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("T,DI,DS", [(32, 16, 4), (64, 32, 8), (96, 8, 16)])
def test_mamba_scan_matches_oracle(dtype, T, DI, DS):
    key = jax.random.PRNGKey(T + DI)
    ks = jax.random.split(key, 6)
    delta = jax.nn.softplus(jax.random.normal(ks[0], (T, DI))).astype(dtype)
    xs = jax.random.normal(ks[1], (T, DI), dtype)
    B = jax.random.normal(ks[2], (T, DS), dtype)
    C = jax.random.normal(ks[3], (T, DS), dtype)
    A = -jnp.exp(jax.random.normal(ks[4], (DI, DS)) * 0.3)
    reset = (jax.random.uniform(ks[5], (T,)) < 0.1).astype(jnp.int32)
    reset = reset.at[0].set(1)
    h0 = jax.random.normal(key, (DI, DS))

    y_p, h_p = mamba_scan_pallas(delta, xs, B, C, A.astype(dtype), reset,
                                 h0, block_t=16, block_di=8, interpret=True)
    # oracle
    a = jnp.exp(delta.astype(jnp.float32)[:, :, None] * A[None])
    a = jnp.where(reset.reshape(-1, 1, 1) > 0, 0.0, a)
    bx = (delta * xs).astype(jnp.float32)[:, :, None] * \
        B.astype(jnp.float32)[:, None, :]
    hs, h_r = ref.mamba_scan_reference(a, bx, h0.astype(jnp.float32))
    y_r = jnp.einsum("tds,ts->td", hs, C.astype(jnp.float32))
    # bf16 scan outputs accumulate like the carried state: same 3e-2 bound
    np.testing.assert_allclose(np.asarray(y_p, np.float32),
                               np.asarray(y_r, np.float32),
                               rtol=2e-5 if dtype == jnp.float32 else 3e-2,
                               atol=2e-5 if dtype == jnp.float32 else 3e-2)
    np.testing.assert_allclose(np.asarray(h_p), np.asarray(h_r),
                               rtol=1e-4 if dtype == jnp.float32 else 3e-2,
                               atol=1e-4 if dtype == jnp.float32 else 3e-2)


def test_mamba_scan_carry_state():
    """Scanning [0:T/2] then [T/2:T] with the carried state == one scan,
    including through the ops.py wrapper with padding."""
    key = jax.random.PRNGKey(21)
    T, DI, DS = 50, 8, 4   # deliberately not a multiple of the block
    ks = jax.random.split(key, 5)
    delta = jax.nn.softplus(jax.random.normal(ks[0], (T, DI)))
    xs = jax.random.normal(ks[1], (T, DI))
    B = jax.random.normal(ks[2], (T, DS))
    C = jax.random.normal(ks[3], (T, DS))
    A = -jnp.exp(jax.random.normal(ks[4], (DI, DS)) * 0.3)
    reset = jnp.zeros((T,), jnp.int32).at[0].set(1)
    h0 = jnp.zeros((DI, DS))

    y_full, h_full = mamba_scan(delta, xs, B, C, A, reset, h0,
                                block_t=16, use_pallas=True)
    half = T // 2
    y1, h_mid = mamba_scan(delta[:half], xs[:half], B[:half], C[:half], A,
                           reset[:half], h0, block_t=16, use_pallas=True)
    y2, h_end = mamba_scan(delta[half:], xs[half:], B[half:], C[half:], A,
                           jnp.zeros((T - half,), jnp.int32), h_mid,
                           block_t=16, use_pallas=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2])),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_end), np.asarray(h_full),
                               rtol=1e-4, atol=1e-4)


@given(st.integers(2, 5), st.integers(1, 3), st.integers(0, 10 ** 6))
@settings(max_examples=20, deadline=None)
def test_flash_attention_property(n_heads_pow, nseq, seed):
    """Hypothesis sweep: random GQA ratios and segment layouts."""
    key = jax.random.PRNGKey(seed)
    Hq = 2 ** (n_heads_pow - 1)
    Hkv = max(1, Hq // 2)
    T, Dh = 64, 16
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (T, Hq, Dh))
    k = jax.random.normal(ks[1], (T, Hkv, Dh))
    v = jax.random.normal(ks[2], (T, Hkv, Dh))
    seg, pos = _varlen_meta(ks[3], T, nseq)
    out_p = flash_attention_pallas(q, k, v, seg, seg, pos, pos,
                                   block_q=16, block_kv=16, interpret=True)
    out_r = ref.flash_attention_reference(q, k, v, seg, seg, pos, pos)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r),
                               rtol=3e-5, atol=3e-5)
