"""Alg. 2 stage-aware chunk-level adaptive checkpointing tests."""

import math

import numpy as np
import pytest

from repro.core import (ClusterSpec, CostModel, ModelSpec, backward_order,
                        chunk_sequences, diag_index, enumerate_windows,
                        solve_checkpointing)


def _setup(hbm=16e9, d_p=4, d_s=4, lengths=None, k=3):
    m = ModelSpec(name="t", n_layers=16, d_model=1024, n_heads=16,
                  n_kv_heads=8, head_dim=64, d_ff=4096, vocab=32000)
    cm = CostModel(m, ClusterSpec(d_p=d_p, d_s=d_s, hbm_bytes=hbm))
    lengths = lengths or [65536, 30000, 8000, 8000, 4000, 2000, 1000, 500]
    res = chunk_sequences(cm, lengths, k)
    f2b = backward_order(res.chunks)
    ns = max(s.n_chunks for s in res.sequences)
    return cm, res, f2b, ns


def test_diag_index_ranges():
    d_p, n = 4, 6
    idxs = {diag_index(d_p, p, b) for p in range(1, d_p + 1)
            for b in range(n)}
    assert min(idxs) == 0 and max(idxs) == n + d_p - 2


def test_no_ckpt_when_memory_ample():
    cm, res, f2b, ns = _setup(hbm=16e9)
    sol = solve_checkpointing(cm, res.chunks, f2b, ns)
    assert sol.status in ("optimal", "feasible")
    # tiny model, huge memory: nothing to checkpoint
    assert sol.total_layers == 0
    assert sol.recompute_time == 0.0


def test_ckpt_activates_under_pressure():
    # shrink memory until the ILP must checkpoint
    cm, res, f2b, ns = _setup(hbm=16e9)
    need = None
    for frac in (0.2, 0.1, 0.05, 0.02, 0.01):
        sol = solve_checkpointing(cm, res.chunks, f2b, ns,
                                  capacity=cm.cluster.hbm_bytes * frac)
        if sol.status in ("optimal", "feasible") and sol.total_layers > 0:
            need = sol
            break
    assert need is not None
    assert need.recompute_time > 0
    # Eq. 16 structure: table[p][k] == diag[dp - p + f2b[k]]
    d_p = cm.cluster.d_p
    for p in range(1, d_p + 1):
        for k in range(len(res.chunks)):
            assert need.table[p - 1][k] == need.diag[diag_index(d_p, p, f2b[k])]
    # bound: never more than the layers a stage owns
    per_stage = cm.model.n_layers // d_p
    assert all(v <= per_stage for v in need.diag)


def test_solution_satisfies_memory_constraints():
    cm, res, f2b, ns = _setup(hbm=16e9)
    cap = None
    for frac in (0.15, 0.08, 0.04):
        sol = solve_checkpointing(cm, res.chunks, f2b, ns,
                                  capacity=cm.cluster.hbm_bytes * frac)
        if sol.status not in ("optimal", "feasible"):
            continue
        cap = cm.cluster.hbm_bytes * frac
        d_p = cm.cluster.d_p
        windows = enumerate_windows(len(res.chunks), d_p, ns, f2b)
        for p in range(1, d_p + 1):
            budget = cap - cm.m_model_states(p)
            for w in windows[p - 1]:
                tot = sum(cm.m_act(p, res.chunks[k], sol.table[p - 1][k])
                          for k in w)
                assert tot <= budget * (1 + 1e-9) + 1.0
    assert cap is not None


def test_infeasible_when_capacity_tiny():
    cm, res, f2b, ns = _setup()
    sol = solve_checkpointing(cm, res.chunks, f2b, ns, capacity=1e6)
    assert sol.status == "infeasible"
    assert math.isinf(sol.recompute_time)


def test_stage_awareness_window_depth():
    """Eq. 7: stage 1 keeps the deepest chunks window, so its no-ckpt peak
    activation need exceeds the last stage's (streaming CE => no logits
    blow-up on the last stage). This is the asymmetry the stage-aware ILP
    exploits; the exact per-stage ckpt split is solution-degenerate, so we
    assert the underlying need, and that the ILP's solution respects every
    stage's own constraint set (checked in
    test_solution_satisfies_memory_constraints)."""
    cm, res, f2b, ns = _setup()
    windows = enumerate_windows(len(res.chunks), cm.cluster.d_p, ns, f2b)
    need = []
    for p in (1, cm.cluster.d_p):
        need.append(max(sum(cm.m_act(p, res.chunks[k], 0) for k in w)
                        for w in windows[p - 1]))
    assert need[0] >= need[1]


# ---------------------------------------------------------------------------
# Stage-aware roles: encoder vs decoder stages get different coefficients
# (and so can get different l_ckpt depths) — the ROADMAP's enc/dec split.
# ---------------------------------------------------------------------------

def _encdec_setup(hbm=16e9, d_p=4, d_s=4, k=3):
    from repro.core import chunk_sequences
    m = ModelSpec(name="ed", n_layers=16, d_model=1024, n_heads=16,
                  n_kv_heads=8, head_dim=64, d_ff=4096, vocab=32000,
                  is_encoder_decoder=True, n_encoder_layers=16)
    cm = CostModel(m, ClusterSpec(d_p=d_p, d_s=d_s, hbm_bytes=hbm))
    lengths = [65536, 30000, 8000, 8000, 4000, 2000, 1000, 500]
    res = chunk_sequences(cm, lengths, k)
    f2b = backward_order(res.chunks)
    ns = max(s.n_chunks for s in res.sequences)
    return cm, res, f2b, ns


def test_stage_roles_vector():
    from repro.core import encoder_stage_split, stage_roles
    dec = ModelSpec(name="d", n_layers=8, d_model=256, n_heads=8,
                    n_kv_heads=4, head_dim=32, d_ff=1024, vocab=512)
    assert stage_roles(dec, 4) == ("decoder",) * 4
    ed = ModelSpec(name="e", n_layers=8, d_model=256, n_heads=8,
                   n_kv_heads=4, head_dim=32, d_ff=1024, vocab=512,
                   is_encoder_decoder=True, n_encoder_layers=8)
    roles = stage_roles(ed, 4)
    assert roles == ("encoder", "encoder", "decoder", "decoder")
    # split is clamped so both sides keep at least one stage
    assert encoder_stage_split(100, 1, 4) == (3, 1)
    assert encoder_stage_split(1, 100, 4) == (1, 3)


def test_all_decoder_roles_reproduce_roleless_problem():
    cm, res, f2b, ns = _setup()
    for frac in (0.2, 0.1):
        cap = cm.cluster.hbm_bytes * frac
        base = solve_checkpointing(cm, res.chunks, f2b, ns, capacity=cap)
        roled = solve_checkpointing(cm, res.chunks, f2b, ns, capacity=cap,
                                    roles=("decoder",) * cm.cluster.d_p)
        assert base.status == roled.status
        assert base.table == roled.table and base.diag == roled.diag
        assert roled.roles == ("decoder",) * cm.cluster.d_p


def test_solution_matrix_and_per_stage_views():
    cm, res, f2b, ns = _setup()
    sol = solve_checkpointing(cm, res.chunks, f2b, ns,
                              capacity=cm.cluster.hbm_bytes * 0.1)
    assert sol.status in ("optimal", "feasible")
    mat = sol.as_matrix()
    assert mat.shape == (cm.cluster.d_p, len(res.chunks))
    assert sol.per_stage_max() == [int(r.max()) for r in mat]
    assert (mat >= 0).all()


def test_encoder_stages_can_checkpoint_differently():
    """Under the encoder coefficient set a checkpointed layer frees the
    FULL per-layer slab (no un-freeable KV), so for dependent-KV-heavy
    chunks the encoder-role saving F is strictly larger: the same memory
    need is coverable with fewer checkpointed layers. Assert the
    structural fact on the coefficients and that the roled solve is never
    worse (in total checkpointed layers) than the all-decoder solve."""
    from repro.core.checkpointing import _coefficients
    cm, res, f2b, ns = _encdec_setup()
    I_d, F_d, _ = _coefficients(cm, res.chunks, "decoder")
    I_e, F_e, _ = _coefficients(cm, res.chunks, "encoder")
    dep = [c.has_dependents for c in res.chunks]
    assert any(dep), "fixture needs split chunks with dependents"
    for k, d in enumerate(dep):
        if d:
            assert F_e[k] > F_d[k]   # encoder frees more per layer
            assert I_e[k] < I_d[k]   # and carries no dependent-KV base
        else:
            assert F_e[k] == F_d[k] and I_e[k] == I_d[k]

    from repro.core import stage_roles
    roles = stage_roles(cm.model, cm.cluster.d_p)
    assert "encoder" in roles and "decoder" in roles
    for frac in (0.12, 0.08, 0.05):
        cap = cm.cluster.hbm_bytes * frac
        plain = solve_checkpointing(cm, res.chunks, f2b, ns, capacity=cap)
        roled = solve_checkpointing(cm, res.chunks, f2b, ns, capacity=cap,
                                    roles=roles)
        if roled.status == "infeasible" or plain.status == "infeasible":
            continue
        assert roled.total_layers <= plain.total_layers
        if roled.table != plain.table:
            return  # roles changed the solution — the point of the test
    # at minimum the coefficient asymmetry above held; a solution change
    # is workload-dependent, so only warn via assert on the last resort
    assert True


def test_roles_length_validated():
    cm, res, f2b, ns = _setup()
    with pytest.raises(ValueError, match="one entry per stage"):
        solve_checkpointing(cm, res.chunks, f2b, ns, roles=("decoder",))


def test_constant_table_collapses_to_uniform_despite_padding():
    """Bucket padding appends masked all-zero columns; they must NOT block
    the constant-table collapse — an effectively-uniform plan shares the
    uniform executable and digests as "uN" (regression: the collapse check
    once ran over the padded table and only ever fired when n_chunks was
    an exact multiple of the rounding)."""
    from repro.core import PlannerConfig, plan_batch
    m = ModelSpec(name="t", n_layers=16, d_model=1024, n_heads=16,
                  n_kv_heads=8, head_dim=64, d_ff=4096, vocab=32000)
    cm = CostModel(m, ClusterSpec(d_p=4, d_s=4, hbm_bytes=16e9))
    plan = plan_batch(cm, [4096] * 5, PlannerConfig(
        bucket_rounding=64, remat_mode="stage_aware", full_ckpt=True))
    key = plan.bucket_key(4)
    n_real = sum(p.n_chunks for p in plan.pipelines)
    assert key.n_chunks > n_real, "fixture must exercise bucket padding"
    depth = plan.uniform_ckpt()
    assert depth > 0
    flat = {v for row in plan.ckpt_table() for v in row}
    assert flat == {depth}, "full_ckpt fixture must give a constant table"
    l_max, table, digest = plan.ckpt_policy(key.n_chunks)
    assert table is None and digest == f"u{depth}" == key.ckpt
    assert l_max == depth


def test_role_capacity_bounds_respect_asymmetric_layer_counts():
    """Encoder stacks with n_encoder_layers != n_layers: each stage's
    solved depth must be bounded by the layers THAT stage actually holds
    under the enc/dec split — not by the decoder-only n_layers // d_p
    (which would both over-cap encoder stages and let the solver certify
    memory bounds the executor cannot realize)."""
    from repro.core import chunk_sequences, encoder_stage_split, stage_roles
    m = ModelSpec(name="ed", n_layers=8, d_model=1024, n_heads=16,
                  n_kv_heads=8, head_dim=64, d_ff=4096, vocab=32000,
                  is_encoder_decoder=True, n_encoder_layers=32)
    cm = CostModel(m, ClusterSpec(d_p=4, d_s=4, hbm_bytes=16e9))
    roles = stage_roles(m, 4)
    enc_st, dec_st = encoder_stage_split(32, 8, 4)
    cap_enc = -(-32 // enc_st)
    cap_dec = -(-8 // dec_st)
    lengths = [65536, 30000, 8000, 8000, 4000, 2000, 1000, 500]
    res = chunk_sequences(cm, lengths, 3)
    f2b = backward_order(res.chunks)
    ns = max(s.n_chunks for s in res.sequences)
    solved = False
    for frac in (0.15, 0.1, 0.06):
        sol = solve_checkpointing(cm, res.chunks, f2b, ns, roles=roles,
                                  capacity=cm.cluster.hbm_bytes * frac)
        if sol.status == "infeasible" or sol.total_layers == 0:
            continue
        solved = True
        for p, row in enumerate(sol.table):
            cap = cap_enc if roles[p] == "encoder" else cap_dec
            assert max(row) <= cap, (p, roles[p], max(row), cap)
        # the old uniform bound (n_layers // d_p == 2) would have capped
        # every stage at 2; the role-aware solve may exceed it on stages
        # that genuinely hold more layers
        assert max(max(r) for r in sol.table) <= max(cap_enc, cap_dec)
    assert solved, "fixture never forced checkpointing"
