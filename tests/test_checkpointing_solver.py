"""Alg. 2 stage-aware chunk-level adaptive checkpointing tests."""

import math

import numpy as np
import pytest

from repro.core import (ClusterSpec, CostModel, ModelSpec, backward_order,
                        chunk_sequences, diag_index, enumerate_windows,
                        solve_checkpointing)


def _setup(hbm=16e9, d_p=4, d_s=4, lengths=None, k=3):
    m = ModelSpec(name="t", n_layers=16, d_model=1024, n_heads=16,
                  n_kv_heads=8, head_dim=64, d_ff=4096, vocab=32000)
    cm = CostModel(m, ClusterSpec(d_p=d_p, d_s=d_s, hbm_bytes=hbm))
    lengths = lengths or [65536, 30000, 8000, 8000, 4000, 2000, 1000, 500]
    res = chunk_sequences(cm, lengths, k)
    f2b = backward_order(res.chunks)
    ns = max(s.n_chunks for s in res.sequences)
    return cm, res, f2b, ns


def test_diag_index_ranges():
    d_p, n = 4, 6
    idxs = {diag_index(d_p, p, b) for p in range(1, d_p + 1)
            for b in range(n)}
    assert min(idxs) == 0 and max(idxs) == n + d_p - 2


def test_no_ckpt_when_memory_ample():
    cm, res, f2b, ns = _setup(hbm=16e9)
    sol = solve_checkpointing(cm, res.chunks, f2b, ns)
    assert sol.status in ("optimal", "feasible")
    # tiny model, huge memory: nothing to checkpoint
    assert sol.total_layers == 0
    assert sol.recompute_time == 0.0


def test_ckpt_activates_under_pressure():
    # shrink memory until the ILP must checkpoint
    cm, res, f2b, ns = _setup(hbm=16e9)
    need = None
    for frac in (0.2, 0.1, 0.05, 0.02, 0.01):
        sol = solve_checkpointing(cm, res.chunks, f2b, ns,
                                  capacity=cm.cluster.hbm_bytes * frac)
        if sol.status in ("optimal", "feasible") and sol.total_layers > 0:
            need = sol
            break
    assert need is not None
    assert need.recompute_time > 0
    # Eq. 16 structure: table[p][k] == diag[dp - p + f2b[k]]
    d_p = cm.cluster.d_p
    for p in range(1, d_p + 1):
        for k in range(len(res.chunks)):
            assert need.table[p - 1][k] == need.diag[diag_index(d_p, p, f2b[k])]
    # bound: never more than the layers a stage owns
    per_stage = cm.model.n_layers // d_p
    assert all(v <= per_stage for v in need.diag)


def test_solution_satisfies_memory_constraints():
    cm, res, f2b, ns = _setup(hbm=16e9)
    cap = None
    for frac in (0.15, 0.08, 0.04):
        sol = solve_checkpointing(cm, res.chunks, f2b, ns,
                                  capacity=cm.cluster.hbm_bytes * frac)
        if sol.status not in ("optimal", "feasible"):
            continue
        cap = cm.cluster.hbm_bytes * frac
        d_p = cm.cluster.d_p
        windows = enumerate_windows(len(res.chunks), d_p, ns, f2b)
        for p in range(1, d_p + 1):
            budget = cap - cm.m_model_states(p)
            for w in windows[p - 1]:
                tot = sum(cm.m_act(p, res.chunks[k], sol.table[p - 1][k])
                          for k in w)
                assert tot <= budget * (1 + 1e-9) + 1.0
    assert cap is not None


def test_infeasible_when_capacity_tiny():
    cm, res, f2b, ns = _setup()
    sol = solve_checkpointing(cm, res.chunks, f2b, ns, capacity=1e6)
    assert sol.status == "infeasible"
    assert math.isinf(sol.recompute_time)


def test_stage_awareness_window_depth():
    """Eq. 7: stage 1 keeps the deepest chunks window, so its no-ckpt peak
    activation need exceeds the last stage's (streaming CE => no logits
    blow-up on the last stage). This is the asymmetry the stage-aware ILP
    exploits; the exact per-stage ckpt split is solution-degenerate, so we
    assert the underlying need, and that the ILP's solution respects every
    stage's own constraint set (checked in
    test_solution_satisfies_memory_constraints)."""
    cm, res, f2b, ns = _setup()
    windows = enumerate_windows(len(res.chunks), cm.cluster.d_p, ns, f2b)
    need = []
    for p in (1, cm.cluster.d_p):
        need.append(max(sum(cm.m_act(p, res.chunks[k], 0) for k in w)
                        for w in windows[p - 1]))
    assert need[0] >= need[1]
