"""Property tests for the SP runtime primitives.

The collectives run under ``jax.vmap(axis_name=...)`` — vmap's collective
rules are semantically the axis-grouped SPMD program, so every property
checks the REAL ``runtime/sp.py`` code against a single-device dense
reference without spawning shard_map subprocesses (those live in
tests/test_sp_parity.py, which also covers the ``axis_index_groups``
sub-group paths vmap cannot emulate).

Hypothesis drives the shapes/seeds where installed (CI does); each
property also has a fixed-seed deterministic twin so a bare interpreter
still exercises the invariant.
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402
from hypothesis import HealthCheck, given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.runtime.sp import (make_allgather_kv_policy, make_sp_ssm_scan,
                              sharded_ce, sharded_embed, subgroup_info)

jax.config.update("jax_default_matmul_precision", "highest")


# ---------------------------------------------------------------------------
# subgroup layout (pure python)
# ---------------------------------------------------------------------------

def test_subgroup_info_full_degree_is_groupless():
    assert subgroup_info(4, 4) == (1, None, None)
    assert subgroup_info(4, 0) == (1, None, None)  # 0 => full degree


def test_subgroup_info_layout():
    r, sp_groups, replica_groups = subgroup_info(8, 4)
    assert r == 2
    # one device per token shard in each SP group; replicas contiguous
    assert sp_groups == [[0, 2, 4, 6], [1, 3, 5, 7]]
    assert replica_groups == [[0, 1], [2, 3], [4, 5], [6, 7]]
    # every device appears exactly once per partition
    assert sorted(sum(sp_groups, [])) == list(range(8))
    assert sorted(sum(replica_groups, [])) == list(range(8))


def test_subgroup_info_rejects_non_divisor():
    with pytest.raises(ValueError):
        subgroup_info(8, 3)


# ---------------------------------------------------------------------------
# distributed SSM prefix scan
# ---------------------------------------------------------------------------

def _local_scan(a, bx, h0):
    def f(c, inp):
        aa, bb = inp
        c = aa * c + bb
        return c, c
    h_last, hs = jax.lax.scan(f, h0, (a, bx))
    return hs, h_last


def _check_sp_scan(seed: int, d_s: int, t_loc: int, resets):
    """SP scan over d_s shards == one dense scan over the concatenation,
    including a=0 resets landing anywhere (shard boundaries included)."""
    di, ds = 3, 2
    T = d_s * t_loc
    rng = np.random.default_rng(seed)
    a = rng.uniform(0.5, 1.0, (T, di, ds))
    for t in resets:
        a[t % T] = 0.0  # reset: history must not cross this token
    bx = rng.normal(size=(T, di, ds))
    a, bx = jnp.asarray(a), jnp.asarray(bx)
    h0 = jnp.asarray(rng.normal(size=(di, ds)))

    hs_ref, last_ref = _local_scan(a, bx, h0)

    sc = make_sp_ssm_scan("x", d_s, _local_scan)
    hs, gfinal = jax.vmap(lambda aa, bb: sc(aa, bb, h0), axis_name="x")(
        a.reshape(d_s, t_loc, di, ds), bx.reshape(d_s, t_loc, di, ds))
    np.testing.assert_allclose(hs.reshape(T, di, ds), hs_ref,
                               rtol=1e-5, atol=1e-6)
    # the global final state is replicated to every shard
    for s in range(d_s):
        np.testing.assert_allclose(gfinal[s], last_ref, rtol=1e-5, atol=1e-6)
    return np.asarray(hs.reshape(T, di, ds))


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(seed=st.integers(0, 2**31 - 1), d_s=st.sampled_from([1, 2, 4, 8]),
       t_loc=st.integers(1, 6),
       resets=st.lists(st.integers(0, 47), max_size=4))
def test_sp_scan_matches_dense_property(seed, d_s, t_loc, resets):
    _check_sp_scan(seed, d_s, t_loc, resets)


def test_sp_scan_reset_at_shard_boundary():
    # reset exactly at the first token of shard 1: shard 0's history must
    # not leak through the summary chain
    _check_sp_scan(0, 4, 4, resets=[4])
    _check_sp_scan(0, 4, 4, resets=[0, 4, 8, 12])


@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(seed=st.integers(0, 2**31 - 1),
       resets=st.lists(st.integers(0, 23), max_size=3))
def test_sp_scan_shard_count_invariance_property(seed, resets):
    # the same 24-token stream split 1/2/4 ways produces identical states
    outs = [_check_sp_scan(seed, d_s, 24 // d_s, resets)
            for d_s in (1, 2, 4)]
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(outs[0], outs[2], rtol=1e-5, atol=1e-6)


def test_sp_scan_shard_count_invariance_fixed():
    outs = [_check_sp_scan(7, d_s, 24 // d_s, [5, 13]) for d_s in (1, 2, 4)]
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(outs[0], outs[2], rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# vocab-parallel embed / CE vs the dense single-device reference
# ---------------------------------------------------------------------------

def _check_embed_ce(seed: int, d_s: int, v_loc: int, cap_loc: int):
    V, cap, D = d_s * v_loc, d_s * cap_loc, 8
    rng = np.random.default_rng(seed)
    emb = jnp.asarray(rng.normal(size=(V, D)).astype(np.float32))
    toks = jnp.asarray(rng.integers(0, V, (cap,)).astype(np.int32))
    tgts = jnp.asarray(rng.integers(0, V, (cap,)).astype(np.int32))
    valid = jnp.asarray(rng.uniform(size=cap) > 0.3)
    hid = jnp.asarray(rng.normal(size=(cap, D)).astype(np.float32))

    out = jax.vmap(lambda e, t: sharded_embed(e, t, "x", jnp.float32),
                   axis_name="x")(emb.reshape(d_s, v_loc, D),
                                  toks.reshape(d_s, cap_loc))
    np.testing.assert_allclose(out.reshape(cap, D), emb[toks],
                               rtol=1e-5, atol=1e-6)

    def ce(h, w, t, v):
        return sharded_ce(h, w, t, v, "x", vocab_true=V)

    loss, n = jax.vmap(ce, axis_name="x")(
        hid.reshape(d_s, cap_loc, D), emb.reshape(d_s, v_loc, D),
        tgts.reshape(d_s, cap_loc), valid.reshape(d_s, cap_loc))
    logits = hid @ emb.T
    lse = jax.scipy.special.logsumexp(logits, axis=1)
    ref = jnp.where(valid, lse - logits[jnp.arange(cap), tgts], 0.0).sum()
    # (loss, n) come back replicated across the axis
    for s in range(d_s):
        np.testing.assert_allclose(loss[s], ref, rtol=1e-4, atol=1e-5)
        assert int(n[s]) == int(valid.sum())


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(seed=st.integers(0, 2**31 - 1), d_s=st.sampled_from([1, 2, 4]),
       v_loc=st.integers(2, 9), cap_loc=st.integers(1, 6))
def test_embed_ce_match_dense_property(seed, d_s, v_loc, cap_loc):
    _check_embed_ce(seed, d_s, v_loc, cap_loc)


def test_embed_ce_match_dense_fixed():
    _check_embed_ce(0, 4, 4, 2)
    _check_embed_ce(1, 2, 8, 5)


def test_sharded_ce_grads_match_dense():
    """The distributed-LSE merge (pmax double-stop_gradient) must leave
    the hidden-state gradient exact."""
    d_s, v_loc, cap_loc, D = 4, 4, 2, 8
    V, cap = d_s * v_loc, d_s * cap_loc
    rng = np.random.default_rng(3)
    emb = jnp.asarray(rng.normal(size=(V, D)).astype(np.float32))
    tgts = jnp.asarray(rng.integers(0, V, (cap,)).astype(np.int32))
    valid = jnp.asarray(rng.uniform(size=cap) > 0.3)
    hid = jnp.asarray(rng.normal(size=(cap, D)).astype(np.float32))

    def dist_loss(h):
        loss, _ = jax.vmap(
            lambda hh, w, t, v: sharded_ce(hh, w, t, v, "x", vocab_true=V),
            axis_name="x")(h.reshape(d_s, cap_loc, D),
                           emb.reshape(d_s, v_loc, D),
                           tgts.reshape(d_s, cap_loc),
                           valid.reshape(d_s, cap_loc))
        return loss[0]

    def ref_loss(h):
        logits = h @ emb.T
        lse = jax.scipy.special.logsumexp(logits, axis=1)
        return jnp.where(valid, lse - logits[jnp.arange(cap), tgts],
                         0.0).sum()

    g_d = jax.grad(dist_loss)(hid)
    g_r = jax.grad(ref_loss)(hid)
    np.testing.assert_allclose(g_d, g_r, rtol=1e-4, atol=1e-6)


# ---------------------------------------------------------------------------
# satellite 2: MLA-shaped allgather_kv — symmetric zero-width ctx_v guards
# ---------------------------------------------------------------------------

def test_allgather_kv_zero_width_ctx_v():
    """MLA ships a zero-width v (values live in the latent cache rows).
    Both the attend-path concat and the update-path write must skip it —
    the guards were asymmetric once, and the policy must match the local
    oracle on the same (gathered) inputs."""
    from repro.models.attention import make_local_attention_policy

    d_s, t_loc, C_cap, ctx_len = 2, 4, 16, 5  # C_cap >= ctx_len + T
    Hq, W = 2, 5  # cache width W; q width must match expanded K
    T = d_s * t_loc
    rng = np.random.default_rng(11)
    q = jnp.asarray(rng.normal(size=(T, Hq, W)).astype(np.float32))
    cache = jnp.asarray(rng.normal(size=(T, 1, W)).astype(np.float32))
    v_zero = jnp.zeros((T, 1, 0), jnp.float32)
    ctx_k = jnp.asarray(rng.normal(size=(C_cap, 1, W)).astype(np.float32))
    ctx_v = jnp.zeros((C_cap, 1, 0), jnp.float32)
    seg = jnp.zeros((T,), jnp.int32)
    pos = jnp.arange(ctx_len, ctx_len + T, dtype=jnp.int32)

    def expand(rows):  # stand-in for mla_expand_ctx: rows -> (K, V)
        k = jnp.broadcast_to(rows, (rows.shape[0], Hq, W))
        return k, k[..., :3]

    kw = dict(ctx_len=ctx_len, causal=True, window=0, scale=1.0,
              expand_fn=expand)
    pol = make_allgather_kv_policy("x")
    out, new_k, new_v = jax.vmap(
        lambda qq, kk, vv, ss, pp: pol(qq, kk, vv, seg=ss, pos=pp,
                                       ctx_k=ctx_k, ctx_v=ctx_v, **kw),
        axis_name="x")(
        q.reshape(d_s, t_loc, Hq, W), cache.reshape(d_s, t_loc, 1, W),
        v_zero.reshape(d_s, t_loc, 1, 0), seg.reshape(d_s, t_loc),
        pos.reshape(d_s, t_loc))
    # the replicated-per-lane context buffers agree across lanes
    np.testing.assert_allclose(new_k[0], new_k[-1], rtol=0, atol=0)
    new_k, new_v = new_k[0], new_v[0]

    # update path: the zero-width buffer passes through untouched
    assert new_v.shape == ctx_v.shape and new_v.shape[-1] == 0
    # the gathered cache rows landed in the context at ctx_len
    np.testing.assert_allclose(new_k[ctx_len:ctx_len + T], cache,
                               rtol=1e-6, atol=1e-7)

    ref_out, ref_k, ref_v = make_local_attention_policy()(
        q, cache, v_zero, seg=seg, pos=pos, ctx_k=ctx_k, ctx_v=ctx_v, **kw)
    assert ref_v.shape[-1] == 0  # oracle guard is symmetric too
    np.testing.assert_allclose(out.reshape(T, Hq, 3), ref_out,
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(new_k, ref_k, rtol=1e-6, atol=1e-7)


def test_allgather_kv_matches_local_oracle_gqa():
    """Dense-v variant: allgather_kv over 4 shards == the local policy."""
    from repro.models.attention import make_local_attention_policy

    d_s, t_loc, C_cap, ctx_len = 4, 3, 20, 7  # C_cap >= ctx_len + T
    Hkv, Dh = 2, 4
    T = d_s * t_loc
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.normal(size=(T, Hkv, Dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(T, Hkv, Dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(T, Hkv, Dh)).astype(np.float32))
    ctx_k = jnp.asarray(rng.normal(size=(C_cap, Hkv, Dh)).astype(np.float32))
    ctx_v = jnp.asarray(rng.normal(size=(C_cap, Hkv, Dh)).astype(np.float32))
    seg = jnp.zeros((T,), jnp.int32)
    pos = jnp.arange(ctx_len, ctx_len + T, dtype=jnp.int32)
    kw = dict(ctx_len=ctx_len, causal=True, window=0, scale=Dh ** -0.5)

    pol = make_allgather_kv_policy("x")
    out, new_k, new_v = jax.vmap(
        lambda qq, kk, vv, ss, pp: pol(qq, kk, vv, seg=ss, pos=pp,
                                       ctx_k=ctx_k, ctx_v=ctx_v, **kw),
        axis_name="x")(
        q.reshape(d_s, t_loc, Hkv, Dh), k.reshape(d_s, t_loc, Hkv, Dh),
        v.reshape(d_s, t_loc, Hkv, Dh), seg.reshape(d_s, t_loc),
        pos.reshape(d_s, t_loc))
    new_k, new_v = new_k[0], new_v[0]

    ref_out, ref_k, ref_v = make_local_attention_policy()(
        q, k, v, seg=seg, pos=pos, ctx_k=ctx_k, ctx_v=ctx_v, **kw)
    np.testing.assert_allclose(out.reshape(T, Hkv, Dh), ref_out,
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(new_k, ref_k, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(new_v, ref_v, rtol=1e-6, atol=1e-7)
